"""L2: actor-critic networks for the JaxUED maze student and PAIRED adversary.

Both networks follow the paper (Table 3): a single 3x3 convolution (16
filters for the student, 128 for the adversary), a 32-unit hidden dense
layer, and separate policy/value heads. Every matmul — including the
convolution, expressed as im2col — routes through the L1 Pallas
`fused_dense` kernel, so the whole forward *and* backward hot path runs on
the custom kernels.

Observation formats (kept in sync with the Rust env via artifacts/manifest.json):

  Student:   obs_img  (B, 5, 5, 3) f32 — egocentric 5x5 crop, agent at the
             bottom-center facing up; channels = {wall, goal, out-of-bounds}.
             obs_dir  (B, 4) f32 — one-hot absolute facing direction.
             Actions: 3 (turn-left, turn-right, forward).

  Adversary: grid (B, 13, 13, 3) f32 — channels {wall, agent, goal};
             tstep (B, 1) f32 — editor step / total;
             noise (B, 16) f32 — per-level random conditioning z.
             Actions: 169 = flat cell index (place agent -> goal -> walls).
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import fused_dense

Params = Dict[str, jax.Array]

# Maze geometry (must match rust/src/env/maze.rs).
GRID_W = 13
GRID_H = 13
VIEW = 5
OBS_CHANNELS = 3
NUM_ACTIONS = 3
NUM_DIRECTIONS = 4

ADV_CHANNELS = 3
ADV_NUM_ACTIONS = GRID_W * GRID_H  # 169
ADV_NOISE_DIM = 16

# Fixed parameter ordering — the artifact ABI. rust/src/runtime/params.rs
# reads this ordering from the manifest; never reorder without bumping it.
PARAM_ORDER: List[str] = [
    "conv_w", "conv_b",
    "trunk_w", "trunk_b",
    "pi_w", "pi_b",
    "v_w", "v_b",
]


def _im2col(x: jax.Array, k: int = 3) -> jax.Array:
    """Extract kxk VALID patches: (B, H, W, C) -> (B*P*Q, k*k*C).

    Row layout is (i, j, c)-major, matching the conv weight layout
    (k*k*C, F). Unrolled slicing: XLA fuses the 9 slices into one gather.
    """
    b, h, w, c = x.shape
    p, q = h - k + 1, w - k + 1
    patches = jnp.stack(
        [x[:, i : i + p, j : j + q, :] for i in range(k) for j in range(k)],
        axis=3,
    )  # (B, P, Q, k*k, C)
    return patches.reshape(b * p * q, k * k * c)


def _conv3x3(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """3x3 VALID conv + bias + relu via im2col + the Pallas fused kernel."""
    bsz, h, wd, _ = x.shape
    p, q = h - 2, wd - 2
    cols = _im2col(x, 3)
    out = fused_dense(cols, w, b, "relu")  # (B*P*Q, F)
    return out.reshape(bsz, p * q * w.shape[1])


def student_param_specs(filters: int = 16, hidden: int = 32) -> Dict[str, Tuple[int, ...]]:
    conv_in = 3 * 3 * OBS_CHANNELS
    flat = (VIEW - 2) * (VIEW - 2) * filters  # 3*3*16 = 144
    trunk_in = flat + NUM_DIRECTIONS
    return {
        "conv_w": (conv_in, filters),
        "conv_b": (filters,),
        "trunk_w": (trunk_in, hidden),
        "trunk_b": (hidden,),
        "pi_w": (hidden, NUM_ACTIONS),
        "pi_b": (NUM_ACTIONS,),
        "v_w": (hidden, 1),
        "v_b": (1,),
    }


def adversary_param_specs(filters: int = 128, hidden: int = 32) -> Dict[str, Tuple[int, ...]]:
    conv_in = 3 * 3 * ADV_CHANNELS
    flat = (GRID_H - 2) * (GRID_W - 2) * filters  # 11*11*128 = 15488
    trunk_in = flat + 1 + ADV_NOISE_DIM
    return {
        "conv_w": (conv_in, filters),
        "conv_b": (filters,),
        "trunk_w": (trunk_in, hidden),
        "trunk_b": (hidden,),
        "pi_w": (hidden, ADV_NUM_ACTIONS),
        "pi_b": (ADV_NUM_ACTIONS,),
        "v_w": (hidden, 1),
        "v_b": (1,),
    }


def init_params(key: jax.Array, specs: Dict[str, Tuple[int, ...]]) -> Params:
    """Scaled-normal init: He (sqrt(2/fan_in)) for relu layers, 0.01-scale
    for the policy head, 1/sqrt(fan_in) for the value head, zero biases.

    (The original uses orthogonal init; QR lowering is not supported by the
    pinned xla_extension CPU plugin, so we substitute scaled normals —
    documented in DESIGN.md. The variance scaling matches.)
    """
    params: Params = {}
    keys = jax.random.split(key, len(PARAM_ORDER))
    for k, name in zip(keys, PARAM_ORDER):
        shape = specs[name]
        if name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
            continue
        fan_in = shape[0]
        if name == "pi_w":
            scale = 0.01
        elif name == "v_w":
            scale = 1.0 / math.sqrt(fan_in)
        else:
            scale = math.sqrt(2.0 / fan_in)
        params[name] = scale * jax.random.normal(k, shape, jnp.float32)
    return params


def student_apply(params: Params, obs: Tuple[jax.Array, ...]) -> Tuple[jax.Array, jax.Array]:
    """Student forward: (obs_img (B,5,5,3), obs_dir (B,4)) -> (logits (B,3), value (B,))."""
    obs_img, obs_dir = obs
    feats = _conv3x3(obs_img, params["conv_w"], params["conv_b"])
    h = fused_dense(
        jnp.concatenate([feats, obs_dir], axis=1),
        params["trunk_w"], params["trunk_b"], "relu",
    )
    logits = fused_dense(h, params["pi_w"], params["pi_b"], "id")
    value = fused_dense(h, params["v_w"], params["v_b"], "id")[:, 0]
    return logits, value


def adversary_apply(params: Params, obs: Tuple[jax.Array, ...]) -> Tuple[jax.Array, jax.Array]:
    """Adversary forward: (grid (B,13,13,3), tstep (B,1), noise (B,16))
    -> (logits (B,169), value (B,))."""
    grid, tstep, noise = obs
    feats = _conv3x3(grid, params["conv_w"], params["conv_b"])
    h = fused_dense(
        jnp.concatenate([feats, tstep, noise], axis=1),
        params["trunk_w"], params["trunk_b"], "relu",
    )
    logits = fused_dense(h, params["pi_w"], params["pi_b"], "id")
    value = fused_dense(h, params["v_w"], params["v_b"], "id")[:, 0]
    return logits, value


def student_obs_shapes(b: int) -> List[Tuple[int, ...]]:
    return [(b, VIEW, VIEW, OBS_CHANNELS), (b, NUM_DIRECTIONS)]


def adversary_obs_shapes(b: int) -> List[Tuple[int, ...]]:
    return [(b, GRID_H, GRID_W, ADV_CHANNELS), (b, 1), (b, ADV_NOISE_DIM)]
