"""AOT compile path: lower every L2 function to XLA HLO *text* artifacts.

Run once via `make artifacts` (no-op when inputs are unchanged); the Rust
coordinator is self-contained afterwards. Python is never on the request
path.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the pinned xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Emits `artifacts/manifest.json` describing every artifact's positional ABI
(parameter ordering, input/output shapes, baked hyperparameters, env
geometry constants) — rust/src/runtime/manifest.rs is the consumer.

Usage: python -m compile.aot --out ../artifacts [--variants std,small]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .ppo import (
    METRIC_NAMES,
    PpoHp,
    SCORE_OUTPUT_NAMES,
    adam_init,
    make_score_fn,
    make_train_step,
)

HP = PpoHp()  # Table 3 constants, baked into every artifact.

# Rollout-shape variants. `std` matches the paper (T=256, B=32); `small`
# keeps tests and CI fast. PAIRED adversary editor-horizons 25 and 60 match
# the paper's PAIRED-25 / PAIRED-60 runs.
VARIANTS: Dict[str, Dict[str, int]] = {
    "std": {"T": 256, "B": 32, "T_adv": 60},
    "std25": {"T": 256, "B": 32, "T_adv": 25, "adv_only": 1},
    "small": {"T": 32, "B": 8, "T_adv": 13},
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(x) -> Dict:
    return {"shape": list(x.shape), "dtype": x.dtype.name}


def _specs(shapes: Sequence[Tuple[int, ...]], dtype=jnp.float32):
    return [jax.ShapeDtypeStruct(s, dtype) for s in shapes]


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.artifacts: List[Dict] = []

    def emit(self, name: str, fn, example_args: List, meta: Dict) -> None:
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *example_args)
        entry = {
            "name": name,
            "file": fname,
            "inputs": [_shape_entry(a) for a in example_args],
            "outputs": [_shape_entry(a) for a in out_avals],
            **meta,
        }
        self.artifacts.append(entry)
        print(f"  wrote {fname}  ({len(text)} chars, "
              f"{len(entry['inputs'])} in / {len(entry['outputs'])} out)")


def _network_defs():
    return {
        "student": {
            "specs": model.student_param_specs(),
            "apply": model.student_apply,
            "obs_shapes": model.student_obs_shapes,
            "n_obs": 2,
        },
        "adversary": {
            "specs": model.adversary_param_specs(),
            "apply": model.adversary_apply,
            "obs_shapes": model.adversary_obs_shapes,
            "n_obs": 3,
        },
    }


def emit_network_artifacts(em: Emitter, role: str, net: Dict, t: int, b: int,
                           tag: str) -> None:
    specs = net["specs"]
    order = model.PARAM_ORDER
    apply_fn = net["apply"]
    n_obs = net["n_obs"]

    # --- init: seed -> (params…, m…, v…, count) -----------------------------
    def init_fn(seed):
        params = model.init_params(jax.random.PRNGKey(seed), specs)
        m, v, count = adam_init(params)
        out = [params[k] for k in order] + [m[k] for k in order] \
            + [v[k] for k in order] + [count]
        return tuple(out)

    init_name = f"{role}_init"
    if not any(a["name"] == init_name for a in em.artifacts):
        em.emit(init_name, init_fn,
                [jax.ShapeDtypeStruct((), jnp.int32)],
                {"kind": "init", "network": role})

    # --- policy apply: (params…, obs…) -> (logits, value) -------------------
    def apply_flat(*args):
        params = dict(zip(order, args[: len(order)]))
        obs = tuple(args[len(order):])
        return apply_fn(params, obs)

    apply_name = f"{role}_apply_b{b}"
    if not any(a["name"] == apply_name for a in em.artifacts):
        param_args = _specs([specs[k] for k in order])
        obs_args = _specs(net["obs_shapes"](b))
        em.emit(apply_name, apply_flat, param_args + obs_args,
                {"kind": "apply", "network": role, "B": b})

    # --- train step ----------------------------------------------------------
    ts = make_train_step(apply_fn, order, n_obs, HP)
    param_args = _specs([specs[k] for k in order])
    obs_seq = _specs([(t,) + s for s in net["obs_shapes"](b)])
    # squeeze per-step obs shapes: obs_shapes gives (B, ...) -> (T, B, ...)
    obs_seq = _specs([(t, b) + tuple(s[1:]) for s in net["obs_shapes"](b)])
    tb = [(t, b)]
    args = (
        param_args                      # params
        + param_args                    # m
        + param_args                    # v
        + _specs([()])                  # count
        + _specs([()])                  # lr
        + obs_seq
        + _specs(tb, jnp.int32)         # actions
        + _specs(tb) * 4                # old_logp, old_values, rewards, dones
        + _specs([(b,)])                # last_value
    )
    em.emit(f"{role}_train_step_{tag}", ts, args,
            {"kind": "train_step", "network": role, "T": t, "B": b,
             "metrics": METRIC_NAMES})


def emit_score(em: Emitter, t: int, b: int, tag: str) -> None:
    score = make_score_fn(HP)
    tb = [(t, b)]
    args = _specs(tb) * 3 + _specs([(b,)]) * 2
    em.emit(f"score_{tag}", score, args,
            {"kind": "score", "T": t, "B": b, "outputs_names": SCORE_OUTPUT_NAMES})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variants", default="std,std25,small")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    em = Emitter(args.out)
    nets = _network_defs()

    for vname in args.variants.split(","):
        v = VARIANTS[vname]
        t, b, t_adv = v["T"], v["B"], v["T_adv"]
        print(f"variant {vname}: T={t} B={b} T_adv={t_adv}")
        if not v.get("adv_only"):
            emit_network_artifacts(em, "student", nets["student"], t, b,
                                   f"t{t}_b{b}")
            emit_score(em, t, b, f"t{t}_b{b}")
        emit_network_artifacts(em, "adversary", nets["adversary"], t_adv, b,
                               f"t{t_adv}_b{b}")

    manifest = {
        "version": 1,
        "constants": {
            "grid_w": model.GRID_W,
            "grid_h": model.GRID_H,
            "view": model.VIEW,
            "obs_channels": model.OBS_CHANNELS,
            "num_actions": model.NUM_ACTIONS,
            "num_directions": model.NUM_DIRECTIONS,
            "adv_channels": model.ADV_CHANNELS,
            "adv_num_actions": model.ADV_NUM_ACTIONS,
            "adv_noise_dim": model.ADV_NOISE_DIM,
        },
        "hyperparameters": {
            "gamma": HP.gamma,
            "gae_lambda": HP.gae_lambda,
            "clip_eps": HP.clip_eps,
            "epochs": HP.epochs,
            "vf_coef": HP.vf_coef,
            "ent_coef": HP.ent_coef,
            "max_grad_norm": HP.max_grad_norm,
            "adam_eps": HP.adam_eps,
        },
        "metric_names": METRIC_NAMES,
        "score_output_names": SCORE_OUTPUT_NAMES,
        "networks": {
            role: {
                "param_order": model.PARAM_ORDER,
                "params": [
                    {"name": k, "shape": list(net["specs"][k])}
                    for k in model.PARAM_ORDER
                ],
                "n_obs": net["n_obs"],
            }
            for role, net in nets.items()
        },
        "artifacts": em.artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(em.artifacts)} artifacts")


if __name__ == "__main__":
    main()
