"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written
with plain jax.numpy only (no pallas, no custom control flow). pytest
(`python/tests/test_kernels.py`) sweeps shapes/dtypes with hypothesis and
asserts allclose between kernel and oracle for values *and* gradients.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.matmul(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def fused_dense_ref(x, w, b, act: str = "id"):
    z = matmul_ref(x, w) + b.astype(jnp.float32)
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "tanh":
        return jnp.tanh(z)
    return z


def gae_ref(values, rewards, dones, last_value, gamma: float, lam: float):
    """Reference GAE via lax.scan (reverse)."""
    values = values.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    dones = dones.astype(jnp.float32)
    next_values = jnp.concatenate(
        [values[1:], last_value.astype(jnp.float32)[None, :]], axis=0
    )

    def step(carry, xs):
        v, nv, r, d = xs
        nonterm = 1.0 - d
        delta = r + gamma * nv * nonterm - v
        adv = delta + gamma * lam * nonterm * carry
        return adv, adv

    _, advs = jax.lax.scan(
        step,
        jnp.zeros_like(values[0]),
        (values, next_values, rewards, dones),
        reverse=True,
    )
    return advs


def discounted_return_to_go_ref(rewards, dones, gamma: float):
    rewards = rewards.astype(jnp.float32)
    dones = dones.astype(jnp.float32)
    out = []
    carry = jnp.zeros_like(rewards[0])
    for t in range(rewards.shape[0] - 1, -1, -1):
        carry = rewards[t] + gamma * (1.0 - dones[t]) * carry
        out.append(carry)
    return jnp.stack(out[::-1], axis=0)
