"""L1 Pallas kernel: Generalized Advantage Estimation as a reverse scan.

GAE is the sequential hot-spot of every PPO update *and* of the PLR scoring
path (PVL scores are clipped GAE means), so there is exactly one
implementation, used by both the `train_step` and `score` artifacts — the
Rust coordinator never re-implements this math.

Recurrence (PureJaxRL convention: done_t = 1 iff the transition at step t
ended the episode, so the bootstrap across t -> t+1 is cut by done_t):

    delta_t = r_t + gamma * V_{t+1} * (1 - done_t) - V_t
    A_t     = delta_t + gamma * lam * (1 - done_t) * A_{t+1}
    V_T     = last_value  (bootstrap), A_T = 0

TPU structure: the grid is the time axis (T steps, executed sequentially —
the Pallas grid on TPU is a sequential loop, which is exactly what a scan
needs); each grid step processes a (1, B) row resident in VMEM, with the
(1, B) carry A_{t+1} held in a VMEM scratch accumulator across grid steps.
B is lane-padded to a multiple of 128 by the wrapper. `interpret=True` for
the CPU plugin; the interpreter preserves sequential grid order so the carry
pattern is exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gae_kernel(v_ref, nv_ref, r_ref, d_ref, adv_ref, carry_ref, *, gamma, lam):
    # Grid step i visits t = T-1-i (reverse time order via the index_map).
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    nonterm = 1.0 - d_ref[...]
    delta = r_ref[...] + gamma * nv_ref[...] * nonterm - v_ref[...]
    adv = delta + gamma * lam * nonterm * carry_ref[...]
    adv_ref[...] = adv
    carry_ref[...] = adv


def gae(values, rewards, dones, last_value, gamma: float, lam: float):
    """Compute GAE advantages.

    values:     (T, B) f32 — V(s_t)
    rewards:    (T, B) f32
    dones:      (T, B) f32 — 1.0 iff transition t terminated the episode
    last_value: (B,)   f32 — V(s_T) bootstrap
    Returns advantages (T, B) f32. Value targets are advantages + values.
    """
    t, b = values.shape
    values = values.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    dones = dones.astype(jnp.float32)
    next_values = jnp.concatenate(
        [values[1:], last_value.astype(jnp.float32).reshape(1, b)], axis=0
    )

    # Lane-pad B to a multiple of 128 for VPU-friendly (1, B) rows.
    bp = ((b + 127) // 128) * 128
    pad = bp - b
    if pad:
        pz = ((0, 0), (0, pad))
        values = jnp.pad(values, pz)
        next_values = jnp.pad(next_values, pz)
        rewards = jnp.pad(rewards, pz)
        dones = jnp.pad(dones, pz)

    spec = pl.BlockSpec((1, bp), lambda i: (t - 1 - i, 0))
    adv = pl.pallas_call(
        functools.partial(_gae_kernel, gamma=gamma, lam=lam),
        grid=(t,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((t, bp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bp), jnp.float32)],
        interpret=True,
    )(values, next_values, rewards, dones)
    return adv[:, :b]


def discounted_return_to_go(rewards, dones, gamma: float):
    """R_t = r_t + gamma * (1 - done_t) * R_{t+1}, reverse scan.

    Used by the score artifact for MaxMC return tracking. Pure-jnp lax.scan:
    it shares the artifact with the Pallas GAE kernel and XLA fuses it with
    the surrounding elementwise ops; a second sequential Pallas kernel here
    would buy nothing (same recurrence structure, no matmul content).
    """

    def step(carry, xs):
        r, d = xs
        ret = r + gamma * (1.0 - d) * carry
        return ret, ret

    _, rets = jax.lax.scan(
        step, jnp.zeros_like(rewards[0]), (rewards, dones), reverse=True
    )
    return rets
