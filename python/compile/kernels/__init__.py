"""L1 Pallas kernels (interpret-mode) + pure-jnp oracles."""
from .fused_dense import fused_dense, matmul, matmul_tn  # noqa: F401
from .gae import gae, discounted_return_to_go  # noqa: F401
