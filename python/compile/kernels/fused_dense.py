"""L1 Pallas kernels: tiled matmul and fused dense (matmul + bias + activation).

This is the compute hot-spot of the whole stack: every dense layer and every
convolution (via im2col) in both the student actor-critic and the PAIRED
adversary routes through `fused_dense`, for the forward pass *and* (through a
custom VJP whose operands are themselves Pallas matmuls) the backward pass.

TPU-oriented structure (see DESIGN.md §Hardware-Adaptation):

  * Blocks are (bm, K) x (K, bn) with K whole: every matmul in this model
    has K <= 15505, so a K-grid + scratch accumulator is unnecessary. M is
    split into a handful of large sublane-aligned tiles (see `_pick_bm` for
    the measured rationale); on a real TPU the same BlockSpecs would be
    re-tiled to (128, 128) MXU blocks — the mapping is analytic, the
    schedule expression (grid + index_map) is identical.
  * Accumulation is in float32 (`preferred_element_type`), the MXU-native
    accumulation type.
  * Inputs are padded to block multiples by the wrapper (`_pad2`); Pallas
    BlockSpec then expresses the HBMxVMEM schedule that a CUDA version
    would express with threadblocks.

All `pallas_call`s use `interpret=True`: the image's PJRT plugin is CPU-only
and real TPU lowering emits Mosaic custom-calls it cannot execute. The
interpreter executes the same program structure, so numerics (checked against
`ref.py` by pytest) are the correctness signal; MXU utilization is estimated
analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation codes (baked into the kernel closure, not traced).
ACT_ID = "id"
ACT_RELU = "relu"
ACT_TANH = "tanh"

_ACTS = (ACT_ID, ACT_RELU, ACT_TANH)


def _apply_act(z, act: str):
    if act == ACT_RELU:
        return jnp.maximum(z, 0.0)
    if act == ACT_TANH:
        return jnp.tanh(z)
    return z


def _act_grad_from_out(y, act: str):
    """d act(z) / dz expressed in terms of the *output* y = act(z)."""
    if act == ACT_RELU:
        return (y > 0.0).astype(y.dtype)
    if act == ACT_TANH:
        return 1.0 - y * y
    return jnp.ones_like(y)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Block sizing. On a real TPU the natural tile is the (128, 128) MXU block
# and the grid pipeline hides per-step latency; under the CPU interpreter
# every grid step is a sequential dynamic-slice + dot with ~0.3 ms overhead,
# so per-step overhead dominates at 128-row tiles (measured 4.8 s/call for
# the std train step at 128-tiles, EXPERIMENTS.md §Perf). We therefore tile
# M into the *fewest* blocks that respect an analytic VMEM budget
# (bm*K + K*bn + bm*bn floats <= ~16 MiB) — the same constraint a TPU
# schedule optimizes, just solved for a different per-step cost model. The
# BlockSpec/grid structure (the HBM->VMEM schedule) is unchanged either way.
_TARGET_M_STEPS = 2
_VMEM_BUDGET_FLOATS = 4 << 20  # 16 MiB of f32


def _pick_bn(n: int) -> int:
    """N-block: whole output width (all layers here have n <= 169; the MXU
    would pad the lane dim to 128 internally — explicit padding buys nothing
    and costs 4-8x interpreter work)."""
    return _round_up(max(n, 1), 8)


def _pick_bm(m: int, k: int = 256, bn: int = 32) -> int:
    """M-block: ceil(m / TARGET) rounded to the 8-row sublane, shrunk to fit
    the VMEM budget for the given (K, bn) footprint."""
    target = _round_up((m + _TARGET_M_STEPS - 1) // _TARGET_M_STEPS, 8)
    cap = max(8, (_VMEM_BUDGET_FLOATS - k * bn) // (k + bn) // 8 * 8)
    return min(target, cap, _round_up(max(m, 1), 8))


def _pad2(x, bm: int, bn: int):
    m, n = x.shape
    pm, pn = _round_up(m, bm) - m, _round_up(n, bn) - n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


# ---------------------------------------------------------------------------
# Plain tiled matmul kernel
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, w_ref, o_ref):
    # One (bm, K) x (K, bn) -> (bm, bn) tile; K whole, f32 accumulate (MXU).
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N), f32."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bn = _pick_bn(n)
    bm = _pick_bm(m, k, bn)
    xp = _pad2(x.astype(jnp.float32), bm, 1)
    wp = _pad2(w.astype(jnp.float32), 1, bn)
    mp, np_ = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Transposed-LHS matmul kernel: x^T @ g without materializing x^T
# ---------------------------------------------------------------------------


def _matmul_tn_kernel(x_ref, g_ref, o_ref):
    # One (M, bk)^T x (M, bn) -> (bk, bn) tile: contract over axis 0 of both
    # operands (dot_general), so the (M, K) activation matrix is read in its
    # native layout — the backward pass never materializes a transpose.
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], g_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def matmul_tn(x: jax.Array, g: jax.Array) -> jax.Array:
    """x^T @ g for x (M, K), g (M, N) -> (K, N), reading x untransposed.

    This is the `dw` contraction of the dense backward pass. For the PAIRED
    adversary trunk x is (1920, 15505): an explicit `x.T` would copy ~119 MB
    per epoch (measured §Perf iteration 2); contracting over axis 0 in the
    kernel avoids it. Grid tiles the *output rows* (K); M stays whole per
    block, matching the forward kernel's whole-K policy.
    """
    m, k = x.shape
    m2, n = g.shape
    assert m == m2, f"contraction mismatch {m} vs {m2}"
    bn = _pick_bn(n)
    bk = _pick_bm(k, m, bn)  # output rows tile like M; contraction dim is m
    xp = _pad2(x.astype(jnp.float32), 1, bk)
    gp = _pad2(g.astype(jnp.float32), 1, bn)
    kp, np_ = xp.shape[1], gp.shape[1]
    out = pl.pallas_call(
        _matmul_tn_kernel,
        grid=(kp // bk, np_ // bn),
        in_specs=[
            pl.BlockSpec((m, bk), lambda i, j: (0, i)),
            pl.BlockSpec((m, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kp, np_), jnp.float32),
        interpret=True,
    )(xp, gp)
    return out[:k, :n]


# ---------------------------------------------------------------------------
# Fused dense: act(x @ w + b)
# ---------------------------------------------------------------------------


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...]  # (1, bn) broadcast over rows
    o_ref[...] = _apply_act(z, act)


def _fused_dense_fwd_impl(x, w, b, act: str):
    m, k = x.shape
    _, n = w.shape
    bn = _pick_bn(n)
    bm = _pick_bm(m, k, bn)
    xp = _pad2(x.astype(jnp.float32), bm, 1)
    wp = _pad2(w.astype(jnp.float32), 1, bn)
    bp = _pad2(b.astype(jnp.float32).reshape(1, -1), 1, bn)
    mp, np_ = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        functools.partial(_fused_dense_kernel, act=act),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_dense(x, w, b, act: str = ACT_ID):
    """y = act(x @ w + b), forward and backward both as Pallas kernels.

    x: (M, K) float32, w: (K, N) float32, b: (N,) float32.
    `act` in {"id", "relu", "tanh"} (static).
    """
    assert act in _ACTS, act
    return _fused_dense_fwd_impl(x, w, b, act)


def _fused_dense_fwd(x, w, b, act: str):
    y = _fused_dense_fwd_impl(x, w, b, act)
    # Save the *output* only: all supported activations have gradients
    # expressible in terms of y, so the pre-activation is never materialized.
    return y, (x, w, y)


def _fused_dense_bwd(act: str, res, g):
    x, w, y = res
    gz = g * _act_grad_from_out(y, act)  # (M, N)
    # Both gradient contractions are Pallas matmuls (the backward hot path).
    # w.T is tiny (K x N weights); x would be huge transposed, so dw uses
    # the transposed-LHS kernel instead.
    dx = matmul(gz, w.T)  # (M, K)
    dw = matmul_tn(x, gz)  # (K, N)
    db = jnp.sum(gz, axis=0)  # cheap VPU reduction; XLA fuses it
    return dx, dw, db


fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)
