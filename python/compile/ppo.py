"""L2: the complete PPO update and the PLR scoring function, as jittable
functions lowered to single AOT artifacts.

Design decision 1 in DESIGN.md: the *entire* update-cycle compute — GAE
(Pallas kernel), advantage normalization, the 5-epoch clipped-PPO loop, and
hand-rolled Adam with global-norm clipping — lives inside one
`train_step` function. The Rust coordinator makes exactly one PJRT call per
update-cycle and threads device-resident parameter/optimizer buffers through
`execute_b`, so the L3<->runtime boundary is off the hot path.

Hyperparameters (Table 3) are baked into the artifact at lowering time
(they are physical constants of the paper's experiments); the learning rate
is a runtime input because the paper anneals it linearly.

No optax/flax on this path: Adam is ~15 lines and keeping the artifact
dependency-free makes the lowered HLO auditable.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import gae as gae_kernel
from .kernels.gae import discounted_return_to_go

Params = Dict[str, jax.Array]
ApplyFn = Callable[[Params, Tuple[jax.Array, ...]], Tuple[jax.Array, jax.Array]]

# Names of the metrics vector returned by train_step, in order (ABI).
METRIC_NAMES: List[str] = [
    "total_loss", "pg_loss", "value_loss", "entropy",
    "approx_kl", "clip_frac", "grad_norm", "adv_mean",
]


@dataclasses.dataclass(frozen=True)
class PpoHp:
    """PPO hyperparameters, paper Table 3 defaults."""

    gamma: float = 0.995
    gae_lambda: float = 0.98
    clip_eps: float = 0.2
    epochs: int = 5
    vf_coef: float = 0.5
    ent_coef: float = 1e-3
    max_grad_norm: float = 0.5
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-5
    normalize_adv: bool = True
    clip_value: bool = True


# ---------------------------------------------------------------------------
# Categorical distribution helpers
# ---------------------------------------------------------------------------


def log_softmax(logits: jax.Array) -> jax.Array:
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    return shifted - jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))


def action_log_prob(logits: jax.Array, actions: jax.Array) -> jax.Array:
    logp = log_softmax(logits)
    return jnp.take_along_axis(logp, actions[:, None].astype(jnp.int32), axis=1)[:, 0]


def entropy(logits: jax.Array) -> jax.Array:
    logp = log_softmax(logits)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


# ---------------------------------------------------------------------------
# Adam with global-norm clipping
# ---------------------------------------------------------------------------


def adam_init(params: Params) -> Tuple[Params, Params, jax.Array]:
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return zeros, {k: jnp.zeros_like(v) for k, v in params.items()}, jnp.zeros((), jnp.float32)


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(v * v) for v in tree.values()))


def adam_update(
    params: Params, grads: Params, m: Params, v: Params, count: jax.Array,
    lr: jax.Array, hp: PpoHp,
) -> Tuple[Params, Params, Params, jax.Array, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.max_grad_norm / (gnorm + 1e-9))
    grads = {k: g * scale for k, g in grads.items()}
    count = count + 1.0
    b1c = 1.0 - hp.adam_b1 ** count
    b2c = 1.0 - hp.adam_b2 ** count
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        new_m[k] = hp.adam_b1 * m[k] + (1.0 - hp.adam_b1) * grads[k]
        new_v[k] = hp.adam_b2 * v[k] + (1.0 - hp.adam_b2) * grads[k] ** 2
        mhat = new_m[k] / b1c
        vhat = new_v[k] / b2c
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + hp.adam_eps)
    return new_p, new_m, new_v, count, gnorm


# ---------------------------------------------------------------------------
# PPO loss
# ---------------------------------------------------------------------------


def ppo_loss(
    params: Params, apply_fn: ApplyFn, obs: Tuple[jax.Array, ...],
    actions: jax.Array, old_logp: jax.Array, old_values: jax.Array,
    advantages: jax.Array, targets: jax.Array, hp: PpoHp,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """Clipped-surrogate PPO loss over a flat (N,) batch."""
    logits, values = apply_fn(params, obs)
    logp = action_log_prob(logits, actions)
    ratio = jnp.exp(logp - old_logp)

    pg1 = ratio * advantages
    pg2 = jnp.clip(ratio, 1.0 - hp.clip_eps, 1.0 + hp.clip_eps) * advantages
    pg_loss = -jnp.mean(jnp.minimum(pg1, pg2))

    if hp.clip_value:
        v_clipped = old_values + jnp.clip(
            values - old_values, -hp.clip_eps, hp.clip_eps
        )
        v_loss = 0.5 * jnp.mean(
            jnp.maximum((values - targets) ** 2, (v_clipped - targets) ** 2)
        )
    else:
        v_loss = 0.5 * jnp.mean((values - targets) ** 2)

    ent = jnp.mean(entropy(logits))
    total = pg_loss + hp.vf_coef * v_loss - hp.ent_coef * ent

    approx_kl = jnp.mean(old_logp - logp)
    clip_frac = jnp.mean((jnp.abs(ratio - 1.0) > hp.clip_eps).astype(jnp.float32))
    return total, (pg_loss, v_loss, ent, approx_kl, clip_frac)


# ---------------------------------------------------------------------------
# Full update-cycle: GAE + multi-epoch PPO + Adam, one artifact call
# ---------------------------------------------------------------------------


def make_train_step(
    apply_fn: ApplyFn, param_order: Sequence[str], n_obs: int, hp: PpoHp,
):
    """Build the flat-signature train_step for AOT lowering.

    Signature (all f32 unless noted):
      inputs:  [params…(P), m…(P), v…(P), count(), lr(),
                obs…(n_obs arrays, each (T,B,…)), actions (T,B) i32,
                old_logp (T,B), old_values (T,B), rewards (T,B),
                dones (T,B), last_value (B,)]
      outputs: [params'…(P), m'…(P), v'…(P), count'(), metrics (8,)]

    Flat lists (not pytrees) because the PJRT executable ABI is positional;
    `param_order` pins the ordering recorded in the manifest.
    """
    p = len(param_order)

    def train_step(*args):
        params = dict(zip(param_order, args[:p]))
        m = dict(zip(param_order, args[p : 2 * p]))
        v = dict(zip(param_order, args[2 * p : 3 * p]))
        count = args[3 * p]
        lr = args[3 * p + 1]
        rest = args[3 * p + 2 :]
        obs_seq = rest[:n_obs]
        actions, old_logp, old_values, rewards, dones, last_value = rest[n_obs:]

        t, b = actions.shape
        advantages = gae_kernel(
            old_values, rewards, dones, last_value, hp.gamma, hp.gae_lambda
        )
        targets = advantages + old_values
        adv_mean = jnp.mean(advantages)
        if hp.normalize_adv:
            adv = (advantages - adv_mean) / (jnp.std(advantages) + 1e-8)
        else:
            adv = advantages

        # Flatten (T, B, ...) -> (T*B, ...). One minibatch per epoch
        # (Table 3: minibatches = 1) so no permutation is needed.
        flat_obs = tuple(o.reshape((t * b,) + o.shape[2:]) for o in obs_seq)
        flat = dict(
            actions=actions.reshape(-1),
            old_logp=old_logp.reshape(-1),
            old_values=old_values.reshape(-1),
            adv=adv.reshape(-1),
            targets=targets.reshape(-1),
        )

        grad_fn = jax.value_and_grad(ppo_loss, has_aux=True)

        def epoch(_, carry):
            params, m, v, count, _metrics = carry
            (total, aux), grads = grad_fn(
                params, apply_fn, flat_obs, flat["actions"], flat["old_logp"],
                flat["old_values"], flat["adv"], flat["targets"], hp,
            )
            params, m, v, count, gnorm = adam_update(params, grads, m, v, count, lr, hp)
            pg_loss, v_loss, ent, approx_kl, clip_frac = aux
            metrics = jnp.stack(
                [total, pg_loss, v_loss, ent, approx_kl, clip_frac, gnorm, adv_mean]
            )
            return params, m, v, count, metrics

        init_metrics = jnp.zeros((len(METRIC_NAMES),), jnp.float32)
        params, m, v, count, metrics = jax.lax.fori_loop(
            0, hp.epochs, epoch, (params, m, v, count, init_metrics)
        )

        out: List[jax.Array] = []
        out += [params[k] for k in param_order]
        out += [m[k] for k in param_order]
        out += [v[k] for k in param_order]
        out += [count, metrics]
        return tuple(out)

    return train_step


# ---------------------------------------------------------------------------
# Level scoring (PLR / ACCEL): PVL and MaxMC from a rollout
# ---------------------------------------------------------------------------

SCORE_OUTPUT_NAMES: List[str] = ["pvl", "maxmc", "max_return", "mean_value"]


def make_score_fn(hp: PpoHp):
    """Build the score artifact: per-level regret estimates from a rollout.

    inputs:  values (T,B), rewards (T,B), dones (T,B), last_value (B,),
             prev_max_return (B,)   — the level_extra max-return carry
    outputs: pvl (B,), maxmc (B,), max_return (B,), mean_value (B,)

    PVL  (Positive Value Loss): mean_t max(GAE_t, 0)          (Jiang 2021a)
    MaxMC (Maximum Monte Carlo): mean_t max(R* - V_t, 0), with R* the max
           discounted return-to-go ever observed on the level (tracked
           across rollouts via prev_max_return / level_extra).
    """

    def score(values, rewards, dones, last_value, prev_max_return):
        adv = gae_kernel(values, rewards, dones, last_value, hp.gamma, hp.gae_lambda)
        pvl = jnp.mean(jnp.maximum(adv, 0.0), axis=0)

        rets = discounted_return_to_go(rewards, dones, hp.gamma)  # (T, B)
        max_ret = jnp.maximum(jnp.max(rets, axis=0), prev_max_return)
        maxmc = jnp.mean(jnp.maximum(max_ret[None, :] - values, 0.0), axis=0)
        mean_value = jnp.mean(values, axis=0)
        return pvl, maxmc, max_ret, mean_value

    return score
