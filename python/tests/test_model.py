"""L2 model tests: shapes, init statistics, and the im2col convolution
against jax.lax's native convolution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def test_student_param_specs_match_paper():
    specs = model.student_param_specs()
    assert specs["conv_w"] == (27, 16)  # 3x3x3 -> 16 filters (Table 3)
    assert specs["trunk_w"] == (144 + 4, 32)  # hidden dim 32
    assert specs["pi_w"] == (32, 3)
    assert specs["v_w"] == (32, 1)


def test_adversary_param_specs_match_paper():
    specs = model.adversary_param_specs()
    assert specs["conv_w"] == (27, 128)  # 128 filters (Table 3)
    assert specs["trunk_w"] == (11 * 11 * 128 + 1 + 16, 32)
    assert specs["pi_w"] == (32, 169)


def test_init_deterministic_and_scaled():
    specs = model.student_param_specs()
    a = model.init_params(jax.random.PRNGKey(0), specs)
    b = model.init_params(jax.random.PRNGKey(0), specs)
    c = model.init_params(jax.random.PRNGKey(1), specs)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert any(
        not np.array_equal(np.asarray(a[k]), np.asarray(c[k])) for k in a
    )
    # biases zero, policy head small
    assert np.all(np.asarray(a["conv_b"]) == 0.0)
    assert np.abs(np.asarray(a["pi_w"])).max() < 0.1
    # He scaling for the trunk: std ~ sqrt(2/fan_in)
    std = np.asarray(a["trunk_w"]).std()
    expect = np.sqrt(2.0 / 148)
    assert 0.5 * expect < std < 1.5 * expect


@pytest.mark.parametrize("b", [1, 5, 8])
def test_student_apply_shapes(b):
    specs = model.student_param_specs()
    params = model.init_params(jax.random.PRNGKey(0), specs)
    obs = (
        jnp.zeros((b, 5, 5, 3), jnp.float32),
        jnp.zeros((b, 4), jnp.float32),
    )
    logits, value = model.student_apply(params, obs)
    assert logits.shape == (b, 3)
    assert value.shape == (b,)


def test_adversary_apply_shapes():
    specs = model.adversary_param_specs()
    params = model.init_params(jax.random.PRNGKey(0), specs)
    obs = (
        jnp.zeros((4, 13, 13, 3), jnp.float32),
        jnp.zeros((4, 1), jnp.float32),
        jnp.zeros((4, 16), jnp.float32),
    )
    logits, value = model.adversary_apply(params, obs)
    assert logits.shape == (4, 169)
    assert value.shape == (4,)


def test_im2col_conv_matches_lax_conv():
    """The im2col + Pallas path must equal jax.lax.conv_general_dilated."""
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (6, 5, 5, 3), jnp.float32)
    w_flat = jax.random.normal(k2, (27, 16), jnp.float32) * 0.1
    b = jax.random.normal(k3, (16,), jnp.float32)

    from compile.model import _conv3x3

    ours = _conv3x3(x, w_flat, b)  # (6, 3*3*16)

    # reference: NHWC conv with HWIO weights
    w_hwio = w_flat.reshape(3, 3, 3, 16)
    ref = jax.lax.conv_general_dilated(
        x, w_hwio, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + b
    ref = jnp.maximum(ref, 0.0).reshape(6, -1)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_apply_sensitive_to_observation():
    specs = model.student_param_specs()
    params = model.init_params(jax.random.PRNGKey(0), specs)
    obs0 = (
        jnp.zeros((1, 5, 5, 3), jnp.float32),
        jnp.zeros((1, 4), jnp.float32).at[0, 0].set(1.0),
    )
    obs1 = (
        jnp.ones((1, 5, 5, 3), jnp.float32),
        jnp.zeros((1, 4), jnp.float32).at[0, 0].set(1.0),
    )
    l0, v0 = model.student_apply(params, obs0)
    l1, v1 = model.student_apply(params, obs1)
    assert not np.allclose(np.asarray(l0), np.asarray(l1)) or not np.allclose(
        np.asarray(v0), np.asarray(v1)
    )


def test_grads_flow_to_all_params():
    specs = model.student_param_specs()
    params = model.init_params(jax.random.PRNGKey(0), specs)
    obs = (
        jax.random.normal(jax.random.PRNGKey(1), (4, 5, 5, 3)),
        jnp.ones((4, 4), jnp.float32) * 0.25,
    )

    def loss(p):
        logits, value = model.student_apply(p, obs)
        return (logits**2).sum() + (value**2).sum()

    g = jax.grad(loss)(params)
    for k, v in g.items():
        assert np.abs(np.asarray(v)).sum() > 0, f"no gradient reaches {k}"
