"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

The core correctness signal of the compute stack. hypothesis sweeps shapes
and value ranges; every comparison covers forward values AND gradients
(the backward pass is also Pallas — custom VJP).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_dense, gae, matmul
from compile.kernels.fused_dense import ACT_ID, ACT_RELU, ACT_TANH
from compile.kernels.gae import discounted_return_to_go
from compile.kernels.ref import (
    discounted_return_to_go_ref,
    fused_dense_ref,
    gae_ref,
    matmul_ref,
)

ACTS = [ACT_ID, ACT_RELU, ACT_TANH]


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 40),
    n=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, m, k)
    w = rand(seed + 1, k, n)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w)), np.asarray(matmul_ref(x, w)),
        rtol=1e-4, atol=1e-4,
    )


def test_matmul_block_boundary_shapes():
    # exactly at and just past the 128-block boundaries
    for m in (127, 128, 129, 256):
        for n in (127, 128, 129):
            x = rand(m * n, m, 16)
            w = rand(m + n, 16, n)
            np.testing.assert_allclose(
                np.asarray(matmul(x, w)), np.asarray(matmul_ref(x, w)),
                rtol=1e-4, atol=1e-4,
            )


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 60),
    k=st.integers(1, 50),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tn_matches_transpose(m, k, n, seed):
    from compile.kernels import matmul_tn

    x = rand(seed, m, k)
    g = rand(seed + 1, m, n)
    np.testing.assert_allclose(
        np.asarray(matmul_tn(x, g)), np.asarray(matmul_ref(x.T, g)),
        rtol=1e-4, atol=1e-4,
    )


def test_matmul_tn_adversary_trunk_shape():
    # the dw contraction this kernel exists for: (M, K)^T @ (M, N)
    from compile.kernels import matmul_tn

    x = rand(0, 130, 517)  # scaled-down stand-in for (1920, 15505)
    g = rand(1, 130, 32)
    np.testing.assert_allclose(
        np.asarray(matmul_tn(x, g)), np.asarray(matmul_ref(x.T, g)),
        rtol=1e-4, atol=1e-4,
    )


def test_matmul_mxu_sized():
    # the adversary trunk shape: (B*P*Q, 27) @ (27, 128)
    x = rand(0, 968, 27)
    w = rand(1, 27, 128)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w)), np.asarray(matmul_ref(x, w)),
        rtol=1e-4, atol=1e-4,
    )


# ---------------------------------------------------------------------------
# fused dense
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act", ACTS)
def test_fused_dense_values(act):
    x, w, b = rand(0, 33, 27), rand(1, 27, 16), rand(2, 16)
    np.testing.assert_allclose(
        np.asarray(fused_dense(x, w, b, act)),
        np.asarray(fused_dense_ref(x, w, b, act)),
        rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("act", ACTS)
def test_fused_dense_grads(act):
    x, w, b = rand(3, 9, 12), rand(4, 12, 7), rand(5, 7)

    def f(fn):
        return lambda *a: (fn(*a, act) ** 2).sum()

    g_kernel = jax.grad(f(fused_dense), argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(f(fused_dense_ref), argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 32),
    n=st.integers(1, 40),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_dense_shape_sweep(m, k, n, act, seed):
    x, w, b = rand(seed, m, k), rand(seed + 1, k, n), rand(seed + 2, n)
    out = fused_dense(x, w, b, act)
    assert out.shape == (m, n)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(fused_dense_ref(x, w, b, act)),
        rtol=1e-4, atol=1e-4,
    )


def test_fused_dense_relu_kills_gradient_at_negative():
    # gradient must be exactly zero where relu clamps
    x = jnp.array([[-5.0, -5.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2, jnp.float32)
    g = jax.grad(lambda x: fused_dense(x, w, b, ACT_RELU).sum())(x)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_fused_dense_jit_compatible():
    f = jax.jit(lambda x, w, b: fused_dense(x, w, b, ACT_RELU))
    x, w, b = rand(6, 8, 8), rand(7, 8, 8), rand(8, 8)
    np.testing.assert_allclose(
        np.asarray(f(x, w, b)),
        np.asarray(fused_dense_ref(x, w, b, ACT_RELU)),
        rtol=1e-5, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# GAE
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 40),
    b=st.integers(1, 20),
    gamma=st.floats(0.5, 1.0),
    lam=st.floats(0.0, 1.0),
    p_done=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_gae_matches_ref(t, b, gamma, lam, p_done, seed):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    values = jax.random.normal(k1, (t, b))
    rewards = jax.random.normal(k2, (t, b))
    dones = (jax.random.uniform(k3, (t, b)) < p_done).astype(jnp.float32)
    last_value = jax.random.normal(k4, (b,))
    np.testing.assert_allclose(
        np.asarray(gae(values, rewards, dones, last_value, gamma, lam)),
        np.asarray(gae_ref(values, rewards, dones, last_value, gamma, lam)),
        rtol=1e-4, atol=1e-4,
    )


def test_gae_done_cuts_bootstrap():
    # with done everywhere, A_t = r_t - V_t exactly
    t, b = 5, 3
    values = rand(0, t, b)
    rewards = rand(1, t, b)
    dones = jnp.ones((t, b), jnp.float32)
    lv = rand(2, b)
    adv = gae(values, rewards, dones, lv, 0.99, 0.95)
    np.testing.assert_allclose(
        np.asarray(adv), np.asarray(rewards - values), rtol=1e-5, atol=1e-5
    )


def test_gae_paper_hyperparams_long_horizon():
    # T=256 B=32, gamma/lambda from Table 3 — the std-variant shape
    t, b = 256, 32
    values = rand(0, t, b)
    rewards = rand(1, t, b) * 0.1
    dones = (rand(2, t, b) > 1.2).astype(jnp.float32)
    lv = rand(3, b)
    np.testing.assert_allclose(
        np.asarray(gae(values, rewards, dones, lv, 0.995, 0.98)),
        np.asarray(gae_ref(values, rewards, dones, lv, 0.995, 0.98)),
        rtol=1e-4, atol=1e-4,
    )


def test_gae_zero_lambda_is_td_error():
    t, b = 8, 4
    values = rand(0, t, b)
    rewards = rand(1, t, b)
    dones = jnp.zeros((t, b), jnp.float32)
    lv = rand(2, b)
    adv = gae(values, rewards, dones, lv, 0.9, 0.0)
    next_values = jnp.concatenate([values[1:], lv[None]], axis=0)
    expect = rewards + 0.9 * next_values - values
    np.testing.assert_allclose(np.asarray(adv), np.asarray(expect), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(1, 30),
    b=st.integers(1, 8),
    gamma=st.floats(0.5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_return_to_go_matches_ref(t, b, gamma, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    rewards = jax.random.normal(k1, (t, b))
    dones = (jax.random.uniform(k2, (t, b)) < 0.2).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(discounted_return_to_go(rewards, dones, gamma)),
        np.asarray(discounted_return_to_go_ref(rewards, dones, gamma)),
        rtol=1e-4, atol=1e-4,
    )
