"""AOT pipeline tests: HLO text emission and manifest integrity.

These validate the python side of the artifact ABI; the Rust integration
tests (`rust/tests/`) validate the consumer side against the same files.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.aot import to_hlo_text, VARIANTS
from compile.ppo import METRIC_NAMES, SCORE_OUTPUT_NAMES

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_basic():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # text format only — serialized protos are rejected by xla_extension 0.5.1
    assert "ENTRY" in text


def test_variants_table():
    assert VARIANTS["std"] == {"T": 256, "B": 32, "T_adv": 60}
    assert VARIANTS["small"]["B"] == 8


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_constants_match_model(manifest):
    c = manifest["constants"]
    assert c["grid_w"] == model.GRID_W
    assert c["view"] == model.VIEW
    assert c["num_actions"] == model.NUM_ACTIONS
    assert c["adv_num_actions"] == model.ADV_NUM_ACTIONS
    assert manifest["metric_names"] == METRIC_NAMES
    assert manifest["score_output_names"] == SCORE_OUTPUT_NAMES


def test_manifest_files_exist(manifest):
    for a in manifest["artifacts"]:
        path = os.path.join(ARTIFACTS, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), a["file"]


def test_manifest_param_order_is_abi(manifest):
    for net in manifest["networks"].values():
        assert net["param_order"] == model.PARAM_ORDER


def test_apply_artifact_shapes(manifest):
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    for name, a in by_name.items():
        if a.get("kind") != "apply":
            continue
        b = a["B"]
        # last output pair: logits (B, A), value (B,)
        logits, value = a["outputs"]
        assert logits["shape"][0] == b
        assert value["shape"] == [b]


def test_hyperparameters_are_table3(manifest):
    hp = manifest["hyperparameters"]
    assert hp["gamma"] == 0.995
    assert hp["gae_lambda"] == 0.98
    assert hp["clip_eps"] == 0.2
    assert hp["epochs"] == 5
    assert hp["vf_coef"] == 0.5
    assert hp["ent_coef"] == pytest.approx(1e-3)
    assert hp["max_grad_norm"] == 0.5


def test_init_lowering_roundtrip():
    """Lower a fresh init fn and verify executing the HLO path end-to-end in
    the jax CPU client (proxy for the Rust PJRT client)."""
    specs = model.student_param_specs()

    def init_fn(seed):
        params = model.init_params(jax.random.PRNGKey(seed), specs)
        return tuple(params[k] for k in model.PARAM_ORDER)

    out = jax.jit(init_fn)(jnp.int32(3))
    assert len(out) == len(model.PARAM_ORDER)
    text = to_hlo_text(jax.jit(init_fn).lower(jax.ShapeDtypeStruct((), jnp.int32)))
    assert "HloModule" in text
