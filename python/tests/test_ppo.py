"""L2 PPO machinery tests: loss, Adam, the fused train step, and scoring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.ppo import (
    METRIC_NAMES,
    PpoHp,
    adam_init,
    adam_update,
    action_log_prob,
    entropy,
    global_norm,
    log_softmax,
    make_score_fn,
    make_train_step,
    ppo_loss,
)

HP = PpoHp()


def test_log_softmax_normalizes():
    logits = jnp.array([[1.0, 2.0, 3.0], [0.0, 0.0, 0.0]])
    p = jnp.exp(log_softmax(logits))
    np.testing.assert_allclose(np.asarray(p.sum(axis=1)), 1.0, rtol=1e-6)


def test_action_log_prob_selects():
    logits = jnp.array([[0.0, jnp.log(3.0)]])
    lp = action_log_prob(logits, jnp.array([1]))
    np.testing.assert_allclose(np.asarray(lp), np.log(0.75), rtol=1e-5)


def test_entropy_uniform_max():
    assert abs(float(entropy(jnp.zeros((1, 4)))[0]) - np.log(4)) < 1e-5
    assert float(entropy(jnp.array([[100.0, 0.0, 0.0, 0.0]]))[0]) < 1e-3


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def test_adam_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    m, v, count = adam_init(params)
    lr = jnp.float32(0.1)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        params, m, v, count, _ = adam_update(params, grads, m, v, count, lr, HP)
    assert np.abs(np.asarray(params["w"])).max() < 1e-2


def test_adam_grad_clipping():
    params = {"w": jnp.zeros(4)}
    m, v, count = adam_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, _, _, gnorm = adam_update(params, grads, m, v, count, jnp.float32(1e-3), HP)
    assert float(gnorm) == pytest.approx(2e6, rel=1e-5)
    # the applied update must correspond to the clipped gradient
    # (norm max_grad_norm), i.e. finite and small
    assert np.isfinite(float(gnorm))


def test_global_norm():
    tree = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# PPO loss
# ---------------------------------------------------------------------------


def _toy_apply(params, obs):
    (x,) = obs
    logits = x @ params["w"]
    value = (x @ params["vw"])[:, 0]
    return logits, value


def _toy_params(key):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    return {
        "w": jax.random.normal(k1, (4, 3)) * 0.1,
        "vw": jax.random.normal(k2, (4, 1)) * 0.1,
    }


def test_ppo_loss_zero_advantage_pg_term():
    params = _toy_params(0)
    n = 16
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 4))
    logits, values = _toy_apply(params, (x,))
    actions = jnp.zeros(n, jnp.int32)
    old_logp = action_log_prob(logits, actions)
    adv = jnp.zeros(n)
    targets = values
    total, (pg, vl, ent, kl, cf) = ppo_loss(
        params, _toy_apply, (x,), actions, old_logp, values, adv, targets, HP
    )
    # ratio = 1 everywhere, advantage 0: pg term exactly 0; value loss 0; kl 0
    assert float(pg) == pytest.approx(0.0, abs=1e-6)
    assert float(vl) == pytest.approx(0.0, abs=1e-6)
    assert float(kl) == pytest.approx(0.0, abs=1e-6)
    assert float(cf) == 0.0


def test_ppo_loss_gradient_improves_objective():
    params = _toy_params(2)
    n = 64
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 4))
    logits, values = _toy_apply(params, (x,))
    actions = jnp.argmax(logits, axis=1)  # act greedily
    old_logp = action_log_prob(logits, actions)
    adv = jnp.ones(n)  # taken actions were good
    targets = values + 1.0

    def loss_fn(p):
        return ppo_loss(p, _toy_apply, (x,), actions, old_logp, values, adv, targets, HP)[0]

    l0 = float(loss_fn(params))
    g = jax.grad(loss_fn)(params)
    stepped = {k: params[k] - 0.05 * g[k] for k in params}
    l1 = float(loss_fn(stepped))
    assert l1 < l0


# ---------------------------------------------------------------------------
# Fused train step (tiny student network, real maze obs shapes)
# ---------------------------------------------------------------------------


def _train_step_args(t=4, b=3, seed=0):
    specs = model.student_param_specs()
    params = model.init_params(jax.random.PRNGKey(seed), specs)
    order = model.PARAM_ORDER
    m, v, count = adam_init(params)
    k = jax.random.PRNGKey(seed + 1)
    ks = jax.random.split(k, 8)
    obs_img = jax.random.uniform(ks[0], (t, b, 5, 5, 3))
    obs_dir = jnp.zeros((t, b, 4)).at[..., 0].set(1.0)
    actions = jax.random.randint(ks[1], (t, b), 0, 3)
    old_logp = -jnp.log(3.0) * jnp.ones((t, b))
    old_values = jax.random.normal(ks[2], (t, b)) * 0.1
    rewards = (jax.random.uniform(ks[3], (t, b)) < 0.1).astype(jnp.float32)
    dones = (jax.random.uniform(ks[4], (t, b)) < 0.2).astype(jnp.float32)
    last_value = jax.random.normal(ks[5], (b,)) * 0.1
    args = (
        [params[k] for k in order]
        + [m[k] for k in order]
        + [v[k] for k in order]
        + [count, jnp.float32(1e-3)]
        + [obs_img, obs_dir, actions, old_logp, old_values, rewards, dones, last_value]
    )
    return args, order


def test_train_step_output_structure():
    ts = make_train_step(model.student_apply, model.PARAM_ORDER, 2, HP)
    args, order = _train_step_args()
    out = ts(*args)
    p = len(order)
    assert len(out) == 3 * p + 2
    # count advanced by `epochs`
    assert float(out[3 * p]) == HP.epochs
    metrics = out[-1]
    assert metrics.shape == (len(METRIC_NAMES),)
    assert np.all(np.isfinite(np.asarray(metrics)))


def test_train_step_changes_params():
    ts = make_train_step(model.student_apply, model.PARAM_ORDER, 2, HP)
    args, order = _train_step_args(seed=5)
    out = ts(*args)
    changed = 0
    for i in range(len(order)):
        if not np.allclose(np.asarray(out[i]), np.asarray(args[i])):
            changed += 1
    assert changed >= 6, f"only {changed} params changed"


def test_train_step_zero_lr_is_identity_on_params():
    ts = make_train_step(model.student_apply, model.PARAM_ORDER, 2, HP)
    args, order = _train_step_args(seed=6)
    args[3 * len(order) + 1] = jnp.float32(0.0)  # lr = 0
    out = ts(*args)
    for i in range(len(order)):
        np.testing.assert_allclose(
            np.asarray(out[i]), np.asarray(args[i]), rtol=1e-6, atol=1e-7
        )


def test_train_step_jit_lowerable():
    """The exact thing aot.py does: jit + lower + HLO text emission."""
    ts = make_train_step(model.student_apply, model.PARAM_ORDER, 2, HP)
    args, _ = _train_step_args()
    lowered = jax.jit(ts).lower(*args)
    from compile.aot import to_hlo_text

    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 10_000


# ---------------------------------------------------------------------------
# Score function
# ---------------------------------------------------------------------------


def test_score_outputs_and_maxmc_carry():
    score = make_score_fn(HP)
    t, b = 6, 4
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 4)
    values = jax.random.normal(ks[0], (t, b)) * 0.1
    rewards = (jax.random.uniform(ks[1], (t, b)) < 0.3).astype(jnp.float32)
    dones = (jax.random.uniform(ks[2], (t, b)) < 0.3).astype(jnp.float32)
    lv = jax.random.normal(ks[3], (b,)) * 0.1
    prev = jnp.zeros(b)
    pvl, maxmc, max_ret, mean_v = score(values, rewards, dones, lv, prev)
    assert pvl.shape == (b,) and maxmc.shape == (b,)
    assert np.all(np.asarray(pvl) >= 0)
    assert np.all(np.asarray(maxmc) >= 0)
    # carry: raising prev_max_return can only raise max_ret and maxmc
    prev_hi = jnp.full(b, 10.0)
    _, maxmc2, max_ret2, _ = score(values, rewards, dones, lv, prev_hi)
    assert np.all(np.asarray(max_ret2) >= np.asarray(max_ret) - 1e-6)
    assert np.all(np.asarray(maxmc2) >= np.asarray(maxmc) - 1e-6)
    np.testing.assert_allclose(np.asarray(max_ret2), 10.0, rtol=1e-6)


def test_score_pvl_zero_when_perfect_values():
    """If values exactly equal returns (and rewards are deterministic),
    advantages are ~0 so PVL ~ 0."""
    score = make_score_fn(PpoHp(gamma=1.0, gae_lambda=1.0))
    t, b = 5, 2
    rewards = jnp.zeros((t, b)).at[-1].set(1.0)
    dones = jnp.zeros((t, b)).at[-1].set(1.0)
    # V_t = 1 (undiscounted return-to-go) for all t
    values = jnp.ones((t, b))
    lv = jnp.zeros(b)
    pvl, _, _, _ = score(values, rewards, dones, lv, jnp.zeros(b))
    np.testing.assert_allclose(np.asarray(pvl), 0.0, atol=1e-6)
