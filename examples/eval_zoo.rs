//! Evaluation zoo: per-level holdout breakdown + Figure-2 montage + a
//! rendered trajectory on the hardest solved maze.
//!
//! Works with a trained checkpoint or (default) a freshly-initialized
//! policy so it runs standalone:
//!
//! ```sh
//! cargo run --release --example eval_zoo -- --ckpt runs/accel_s0/student.ckpt --trials 10
//! ```

use anyhow::Result;

use jaxued::config::TrainConfig;
use jaxued::env::holdout::named_levels;
use jaxued::env::maze::{MazeEnv, NUM_ACTIONS};
use jaxued::env::render::{render_montage, render_trajectory};
use jaxued::env::shortest_path::solve_distance;
use jaxued::env::{MazeFamily, UnderspecifiedEnv};
use jaxued::eval::for_family;
use jaxued::rollout::sampler::sample_action;
use jaxued::rollout::Policy;
use jaxued::runtime::{ParamSet, Runtime};
use jaxued::util::cli::Args;
use jaxued::util::rng::Pcg64;

fn main() -> Result<()> {
    let args = Args::parse();
    let cfg = TrainConfig::from_args(&args)?;
    let trials = args.get_usize("trials", 5);
    let out_dir = std::path::PathBuf::from(args.get_str("out-dir", "runs/eval_zoo"));
    std::fs::create_dir_all(&out_dir)?;

    let rt = Runtime::new(std::path::Path::new(&cfg.artifacts_dir))?;
    let params = match args.get("ckpt") {
        Some(path) => {
            println!("loading checkpoint {path}");
            ParamSet::load(std::path::Path::new(path), "student")?
        }
        None => {
            println!("no --ckpt given: evaluating a fresh random-init policy");
            rt.init_params("student", cfg.seed as i32)?
        }
    };
    let apply = rt.load(&cfg.student_apply_artifact())?;
    let policy = Policy { apply, params: &params.params, num_actions: NUM_ACTIONS };

    // 1. Per-level table over the full suite (this zoo is a maze-family
    //    analysis tool, so it names the family explicitly).
    let evaluator = for_family(MazeFamily, &cfg, trials, 20);
    let mut rng = Pcg64::new(cfg.seed, 0x7a6f); // "zo"
    let report = evaluator.run(&policy, &mut rng)?;
    println!("\n{:<22} {:>8} {:>12} {:>10}", "level", "solve", "mean_steps", "opt_dist");
    for (l, (_, level)) in report.levels.iter().zip(&evaluator.levels) {
        let opt = solve_distance(level).map(|d| d.to_string()).unwrap_or("-".into());
        println!(
            "{:<22} {:>8.3} {:>12.1} {:>10}",
            l.name, l.solve_rate, l.mean_steps, opt
        );
    }
    println!(
        "\nmean = {:.3}   IQM = {:.3}",
        report.mean_solve_rate, report.iqm_solve_rate
    );

    // 2. Figure-2 montage of the holdout suite.
    let levels: Vec<_> = evaluator.levels.iter().map(|(_, l)| *l).collect();
    let montage = render_montage(&levels, 6);
    montage.write_ppm(&out_dir.join("figure2_holdout.ppm"))?;
    println!("wrote {}", out_dir.join("figure2_holdout.ppm").display());

    // 3. Trajectory frames on the Labyrinth (or first named maze).
    let target = named_levels()
        .into_iter()
        .find(|n| n.name == "Labyrinth")
        .unwrap();
    let env = MazeEnv::new(cfg.max_episode_steps);
    let mut state = env.reset_to_level(&target.level, &mut rng);
    let mut frames = vec![state.clone()];
    // step with the policy until done (single env through the B-batched
    // artifact: replicate the obs across the batch, read row 0)
    let mut engine_obs = vec![0.0f32; env.obs_len()];
    let b = cfg.variant.b;
    let comps = env.obs_components();
    let mut staged: Vec<jaxued::util::tensor::TensorF32> = comps
        .iter()
        .map(|&c| jaxued::util::tensor::TensorF32::zeros(&[b, c]))
        .collect();
    for _ in 0..env.max_steps {
        env.observe(&state, &mut engine_obs);
        let mut off = 0;
        for (k, &c) in comps.iter().enumerate() {
            for bi in 0..b {
                staged[k].data_mut()[bi * c..(bi + 1) * c]
                    .copy_from_slice(&engine_obs[off..off + c]);
            }
            off += c;
        }
        let (logits, _) = policy.forward(&staged)?;
        let (action, _) = sample_action(&logits[..NUM_ACTIONS], &mut rng);
        let r = env.step(&mut state, action, &mut rng);
        frames.push(state.clone());
        if r.done {
            println!(
                "Labyrinth episode: {} steps, {}",
                frames.len() - 1,
                if r.reward > 0.0 { "SOLVED" } else { "timeout" }
            );
            break;
        }
    }
    let paths = render_trajectory(&target.level, &frames, &out_dir.join("traj"), "labyrinth")?;
    println!("wrote {} trajectory frames to {}", paths.len(), out_dir.join("traj").display());
    Ok(())
}
