//! End-to-end UED training driver (the §6-style experiment runner).
//!
//! Trains any of the five algorithms on any registered environment family
//! with the paper's Table-3 hyperparameters (scaled budget by default),
//! logging the full loss / solve-rate curve to
//! `runs/<run-name>/metrics.csv` and printing per-level holdout results at
//! the end. `--env` selects the environment exactly the way `--algo`
//! selects the method. This is the run recorded in EXPERIMENTS.md
//! §End-to-end.
//!
//! ```sh
//! cargo run --release --example train_ued -- --algo accel --env-steps 1000000
//! cargo run --release --example train_ued -- --algo paired --variant small
//! cargo run --release --example train_ued -- --algo accel --env lava
//! cargo run --release --example train_ued -- --algo plr --seeds 0..4
//! ```
//!
//! With `--seeds a..b` / `--num-seeds N` every seed trains concurrently
//! in this process over one shared rollout pool, and the run reports the
//! paper's cross-seed aggregate (mean/IQM ± stderr) instead of a single
//! curve — see the "Seed packs" section of README.md.

use anyhow::Result;

use jaxued::algo::{train, train_pack};
use jaxued::config::TrainConfig;
use jaxued::eval::evaluate_params;
use jaxued::runtime::{ParamSet, Runtime};
use jaxued::util::cli::Args;
use jaxued::util::rng::Pcg64;
use jaxued::util::stats;

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    // sensible example defaults: 1M steps
    if !argv.iter().any(|a| a.starts_with("--env-steps")) {
        argv.push("--env-steps".into());
        argv.push("1000000".into());
    }
    let args = Args::parse_from(argv);
    let cfg = TrainConfig::from_args(&args)?;

    if !cfg.pack_seeds.is_empty() {
        return run_pack(&cfg);
    }

    println!(
        "=== train_ued: {} on {} | seed {} | {} env steps ({} cycles of {}×{}) ===",
        cfg.algo.name(), cfg.env.name(), cfg.seed, cfg.env_steps_budget,
        cfg.num_cycles(), cfg.variant.t, cfg.variant.b,
    );
    let rt = Runtime::with_geometry(
        std::path::Path::new(&cfg.artifacts_dir),
        &cfg.env.geometry(),
    )?;
    let outcome = train(&rt, &cfg, false)?;

    println!("\n=== final holdout report ===");
    println!("{:<22} {:>8}", "level", "solve");
    for l in &outcome.final_eval.levels {
        println!("{:<22} {:>8.3}", l.name, l.solve_rate);
    }
    println!(
        "\nmean solve = {:.3}   IQM = {:.3}",
        outcome.final_eval.mean_solve_rate, outcome.final_eval.iqm_solve_rate
    );
    println!(
        "wallclock = {:.1}s   throughput = {:.0} env-steps/s   Table-1 extrapolation = {:.2} h",
        outcome.wallclock_secs,
        outcome.env_steps as f64 / outcome.wallclock_secs,
        outcome.table1_hours
    );

    // Re-load the saved checkpoint and re-evaluate: proves the checkpoint
    // path round-trips (the eval numbers must match up to sampling noise).
    let run_dir = std::path::Path::new(&cfg.out_dir).join(cfg.run_name());
    let params = ParamSet::load(&run_dir.join("student.ckpt"), "student")?;
    let mut rng = Pcg64::new(cfg.seed, 1);
    let recheck = evaluate_params(&rt, &cfg, &params, cfg.eval_trials, 20, &mut rng)?;
    println!(
        "checkpoint re-eval: mean solve = {:.3} (ckpt at {})",
        recheck.mean_solve_rate,
        run_dir.join("student.ckpt").display()
    );
    Ok(())
}

/// Seed-pack path: N concurrent runs over one shared pool, Figure-3
/// style cross-seed aggregates at the end.
fn run_pack(cfg: &TrainConfig) -> Result<()> {
    let seeds = cfg.seed_list();
    println!(
        "=== train_ued: {} on {} | seed pack {:?} | {} env steps/seed ({} cycles) ===",
        cfg.algo.name(), cfg.env.name(), seeds, cfg.env_steps_budget, cfg.num_cycles(),
    );
    let rt = Runtime::with_geometry(
        std::path::Path::new(&cfg.artifacts_dir),
        &cfg.env.geometry(),
    )?;
    let pack = train_pack(&rt, cfg, false)?;
    println!("\n=== per-seed final holdout ===");
    for (seed, o) in pack.seeds.iter().zip(&pack.outcomes) {
        println!(
            "seed {seed}: mean solve = {:.3}  IQM = {:.3}",
            o.final_eval.mean_solve_rate, o.final_eval.iqm_solve_rate,
        );
    }
    let finals = pack.final_mean_solves();
    println!(
        "\ncross-seed (Figure-3): mean = {:.3}  IQM = {:.3}  stderr = {:.3}",
        stats::mean(&finals), stats::iqm(&finals), stats::std_err(&finals),
    );
    println!(
        "aggregate curve + manifest: {}",
        pack.pack_dir.display(),
    );
    Ok(())
}
