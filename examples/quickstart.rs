//! Quickstart: the smallest end-to-end use of the library.
//!
//! Loads the AOT artifacts, trains Domain Randomization for a small budget
//! on the selected UPOMDP family, evaluates on its holdout suite, and
//! renders one generated level. The environment is picked exactly like the
//! algorithm — one config field — so the same code trains the maze or the
//! lava grid:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! cargo run --release --example quickstart -- --env lava
//! ```

use anyhow::Result;

use jaxued::algo::train;
use jaxued::config::{Algo, TrainConfig, VARIANT_SMALL};
use jaxued::env::gen::MazeLevelGenerator;
use jaxued::env::render::render_level;
use jaxued::env::EnvId;
use jaxued::runtime::Runtime;
use jaxued::util::cli::Args;
use jaxued::util::rng::Pcg64;

fn main() -> Result<()> {
    // 1. Configure DR with a small smoke budget (Table 3 defaults
    //    otherwise). `--env lava` switches the whole stack to the lava
    //    grid; no other line changes.
    let args = Args::parse();
    let mut cfg = TrainConfig::defaults(Algo::Dr);
    cfg.env = EnvId::parse(&args.get_str("env", "maze"))?;
    cfg.variant = VARIANT_SMALL;
    cfg.env_steps_budget = 64_000; // 250 update cycles at T=32, B=8
    cfg.eval_interval = 50;
    cfg.eval_trials = 2;
    cfg.out_dir = "runs/quickstart".into();

    // 2. The runtime: PJRT CPU client + compiled artifacts, validated
    //    against the selected family's geometry.
    let rt = Runtime::from_env_with_geometry(&cfg.env.geometry())?;
    println!("platform: {}", rt.client.platform_name());

    // 3. Train.
    let outcome = train(&rt, &cfg, false)?;
    println!(
        "\ntrained {} cycles ({} env steps) on {} in {:.1}s — {:.0} env-steps/s",
        outcome.cycles,
        outcome.env_steps,
        cfg.env.name(),
        outcome.wallclock_secs,
        outcome.env_steps as f64 / outcome.wallclock_secs
    );
    println!(
        "holdout: mean solve rate {:.3}, IQM {:.3}",
        outcome.final_eval.mean_solve_rate, outcome.final_eval.iqm_solve_rate
    );

    // 4. Render one level from the maze DR distribution (rendering is a
    //    maze-family tool).
    let gen = MazeLevelGenerator::new(60);
    let mut rng = Pcg64::seed_from_u64(7);
    let level = gen.generate_solvable(&mut rng, 100);
    let img = render_level(&level, None);
    img.write_ppm(std::path::Path::new("runs/quickstart/level.ppm"))?;
    println!("wrote runs/quickstart/level.ppm:\n{}", level.to_ascii());
    Ok(())
}
