//! PAIRED in the open: watch the adversary's level distribution evolve.
//!
//! Runs PAIRED (paper §5.3) and, every few cycles, renders a montage of the
//! levels the adversary currently generates plus its regret signal — the
//! qualitative picture of the emergent curriculum (from empty-ish rooms
//! toward structured mazes as the protagonist improves).
//!
//! ```sh
//! cargo run --release --example paired -- --variant small --cycles 60
//! ```

use anyhow::Result;

use jaxued::algo::paired::PairedAlgo;
use jaxued::algo::UedAlgorithm;
use jaxued::config::{Algo, TrainConfig, Variant};
use jaxued::env::editor::{EditorEnv, EditorTask};
use jaxued::env::render::render_montage;
use jaxued::env::shortest_path::is_solvable;
use jaxued::env::{MazeFamily, UnderspecifiedEnv};
use jaxued::rollout::Policy;
use jaxued::runtime::Runtime;
use jaxued::util::cli::Args;
use jaxued::util::rng::Pcg64;

fn main() -> Result<()> {
    let args = Args::parse();
    let mut cfg = TrainConfig::defaults(Algo::Paired);
    cfg.variant = Variant::parse(&args.get_str("variant", "small"))?;
    cfg.seed = args.get_u64("seed", 0);
    let cycles = args.get_usize("cycles", 60);
    let render_every = args.get_usize("render-every", 20);
    cfg.env_steps_budget = (cycles as u64) * cfg.env_steps_per_cycle();

    let rt = Runtime::new(std::path::Path::new(&cfg.artifacts_dir))?;
    let mut algo = PairedAlgo::new(MazeFamily, &rt, &cfg)?;
    let mut rng = Pcg64::new(cfg.seed, 0x7061); // "pa"
    let out_dir = std::path::Path::new("runs/paired_example");
    std::fs::create_dir_all(out_dir)?;

    println!("PAIRED: {} cycles, editor horizon {}", cycles, cfg.editor_horizon());
    for cycle in 0..cycles {
        let m = algo.cycle(&mut rng)?;
        if cycle % 5 == 0 {
            println!(
                "cycle {cycle:>4}: regret={:.4} prot_solve={:.3} adv_loss={:.4}",
                m.mean_regret, m.train_solve_rate, m.adversary_loss
            );
        }
        if cycle % render_every == 0 || cycle + 1 == cycles {
            let levels = sample_adversary_levels(&rt, &cfg, &algo, &mut rng)?;
            let solvable = levels.iter().filter(|l| is_solvable(l)).count();
            let walls: f64 = levels.iter().map(|l| l.num_walls() as f64).sum::<f64>()
                / levels.len() as f64;
            println!(
                "  adversary batch: {}/{} solvable, {:.1} mean walls",
                solvable, levels.len(), walls
            );
            let img = render_montage(&levels, 4);
            let path = out_dir.join(format!("levels_{cycle:04}.ppm"));
            img.write_ppm(&path)?;
        }
    }
    println!("montages written to {}", out_dir.display());
    Ok(())
}

/// Sample a fresh batch of levels from the *current* adversary (outside the
/// training loop, purely for visualization).
fn sample_adversary_levels(
    rt: &Runtime, cfg: &TrainConfig, algo: &PairedAlgo<MazeFamily>, rng: &mut Pcg64,
) -> Result<Vec<jaxued::env::level::Level>> {
    let env = EditorEnv::new(cfg.editor_horizon());
    let apply = rt.load(&cfg.adversary_apply_artifact())?;
    let b = cfg.variant.b;
    let policy = Policy {
        apply,
        params: algo.adversary_params(),
        num_actions: env.num_actions(),
    };
    let mut states: Vec<_> = (0..b)
        .map(|_| env.reset_to_level(&EditorTask::sample(rng), rng))
        .collect();
    let mut engine = jaxued::rollout::RolloutEngine::new(&env, b);
    let mut traj = jaxued::rollout::Trajectory::new(cfg.editor_horizon(), b, &env.obs_components());
    engine.collect(&env, &mut states, &policy, &mut traj, rng)?;
    Ok(states.iter().map(|s| s.to_level()).collect())
}
