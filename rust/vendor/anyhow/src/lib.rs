//! Vendored offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io registry, so this path crate
//! provides exactly the API subset jaxued uses — `Result`, `Error`, the
//! `Context` extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros — with the same call-site semantics. Errors are flattened to an
//! owned message chain at construction (no downcasting support; jaxued
//! never downcasts). To switch back to the real crate, point the `anyhow`
//! entry of `rust/Cargo.toml` at crates.io instead of this path.

use std::fmt::{self, Debug, Display};

/// `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an owned chain of messages, outermost
/// context first (matching anyhow's `Display`/`Debug` presentation).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std(e: &(dyn std::error::Error + 'static)) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

mod ext {
    use super::Error;

    /// Private conversion trait so [`super::Context`] covers both plain
    /// `std::error::Error` results and already-`anyhow` results (the same
    /// coherence trick the real crate uses: `Error` is local and does not
    /// implement `std::error::Error`, so the impls cannot overlap).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn context_chains_display_and_debug() {
        let r: Result<()> = Err(io_err()).context("opening manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("disk on fire"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
    }

    #[test]
    fn with_context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("inner {}", 7);
        }
        let e = inner().with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e}"), "outer 1");
        assert!(format!("{e:?}").contains("inner 7"));
    }

    #[test]
    fn ensure_and_question_mark() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            let s: String = String::from_utf8(vec![b'o', b'k'])?;
            assert_eq!(s, "ok");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(30).is_err());
    }
}
