//! Vendored stub of the `xla` (xla-rs) API surface jaxued compiles
//! against.
//!
//! The offline build environment carries no XLA/PJRT shared libraries, so
//! this path crate splits the binding in two:
//!
//! * **Host-side [`Literal`] operations are fully functional** pure Rust
//!   (`vec1`/`scalar`/`reshape`/`to_vec`/`element_count`/`to_tuple`/
//!   `array_shape`): trajectory staging, checkpoint IO, and every unit
//!   test that manipulates literals work unchanged.
//! * **The PJRT device path is gated off**: [`PjRtClient::cpu`] returns an
//!   error, so artifact-backed code paths fail loudly at runtime-startup
//!   (exactly where a missing `make artifacts` already fails) instead of
//!   numerically.
//!
//! To run compiled artifacts, point the `xla` entry of `rust/Cargo.toml`
//! at a real xla-rs binding; no jaxued source changes are required.

use std::fmt;

/// Error type mirroring `xla::Error`'s role (message-only).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "XLA/PJRT backend unavailable: built against the vendored stub \
     `xla` crate (rust/vendor/xla); point Cargo.toml at a real xla-rs \
     binding to execute compiled artifacts";

/// Element storage of a [`Literal`]. Public only so [`NativeType`] can
/// name it; not part of the stable surface.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap_slice(d: &Data) -> Option<&[Self]>;
    #[doc(hidden)]
    fn unwrap_slice_mut(d: &mut Data) -> Option<&mut [Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }

    fn unwrap_slice(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }

    fn unwrap_slice_mut(d: &mut Data) -> Option<&mut [f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }

    fn unwrap_slice(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }

    fn unwrap_slice_mut(d: &mut Data) -> Option<&mut [i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host tensor literal (dense, row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: Vec::new() }
    }

    /// Total number of elements (summed over tuple members).
    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.iter().map(Literal::element_count).sum(),
        }
    }

    /// Same data under new dimensions (element count must match; the
    /// empty dim list is a scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out the elements (dtype-checked).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap_slice(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error::new("literal dtype mismatch in to_vec"))
    }

    /// Copy the elements into a caller-owned reusable buffer (cleared
    /// and refilled) — [`to_vec`](Literal::to_vec) without the per-call
    /// allocation once the buffer has grown to size.
    pub fn to_vec_into<T: NativeType>(&self, out: &mut Vec<T>) -> Result<()> {
        let s = T::unwrap_slice(&self.data)
            .ok_or_else(|| Error::new("literal dtype mismatch in to_vec_into"))?;
        out.clear();
        out.extend_from_slice(s);
        Ok(())
    }

    /// Overwrite the elements in place (dtype- and length-checked,
    /// dims unchanged). The resident-buffer staging path: a literal
    /// uploaded once is refilled each step instead of reallocated — with
    /// a real binding this becomes a device-buffer update, so the swap
    /// stays a drop-in.
    pub fn copy_from<T: NativeType>(&mut self, src: &[T]) -> Result<()> {
        let dst = T::unwrap_slice_mut(&mut self.data)
            .ok_or_else(|| Error::new("literal dtype mismatch in copy_from"))?;
        if dst.len() != src.len() {
            return Err(Error::new(format!(
                "copy_from length mismatch: literal holds {} elements, source has {}",
                dst.len(),
                src.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    /// Overwrite this literal's elements from another literal of the
    /// same dims and dtype (tuples rejected) — the in-place analogue of
    /// cloning a parameter literal into a staged argument slot.
    pub fn copy_from_literal(&mut self, src: &Literal) -> Result<()> {
        if self.dims != src.dims {
            return Err(Error::new(format!(
                "copy_from_literal dims mismatch: {:?} vs {:?}",
                self.dims, src.dims
            )));
        }
        match (&mut self.data, &src.data) {
            (Data::F32(d), Data::F32(s)) if d.len() == s.len() => {
                d.copy_from_slice(s);
                Ok(())
            }
            (Data::I32(d), Data::I32(s)) if d.len() == s.len() => {
                d.copy_from_slice(s);
                Ok(())
            }
            _ => Err(Error::new("copy_from_literal dtype/length mismatch")),
        }
    }

    /// Destructure a tuple literal into its members.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(t) => Ok(t),
            _ => Err(Error::new("to_tuple on a non-tuple literal")),
        }
    }

    /// The array shape (errors on tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error::new("tuple literal has no array shape"));
        }
        Ok(ArrayShape { dims: self.dims.clone() })
    }
}

/// Dimensions of a non-tuple literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module text (held opaquely; only a real backend lowers it).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// Computation wrapper over a proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. The stub cannot create one, which gates every
/// device code path at startup.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Compiled executable handle (unreachable through the stub client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Device buffer handle (unreachable through the stub client).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_shape() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        assert!(s.array_shape().unwrap().dims().is_empty());
        // vec1 -> reshape(&[]) is the checkpoint-reader scalar path
        let s2 = Literal::vec1(&[0.5f32]).reshape(&[]).unwrap();
        assert_eq!(s2.to_vec::<f32>().unwrap(), vec![0.5]);
    }

    #[test]
    fn in_place_copy_from() {
        let mut l = Literal::vec1(&[0.0f32; 4]).reshape(&[2, 2]).unwrap();
        l.copy_from(&[1.0f32, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        // dims survive the in-place refill
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        // length and dtype mismatches are rejected, data untouched
        assert!(l.copy_from(&[1.0f32; 3]).is_err());
        assert!(l.copy_from(&[1i32; 4]).is_err());
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn in_place_copy_from_literal() {
        let mut dst = Literal::vec1(&[0.0f32; 4]).reshape(&[2, 2]).unwrap();
        let src = Literal::vec1(&[9.0f32, 8.0, 7.0, 6.0]).reshape(&[2, 2]).unwrap();
        dst.copy_from_literal(&src).unwrap();
        assert_eq!(dst.to_vec::<f32>().unwrap(), vec![9.0, 8.0, 7.0, 6.0]);
        // dims mismatch rejected even at equal element count
        let flat = Literal::vec1(&[1.0f32; 4]);
        assert!(dst.copy_from_literal(&flat).is_err());
        // dtype mismatch rejected
        let ints = Literal::vec1(&[1i32; 4]).reshape(&[2, 2]).unwrap();
        assert!(dst.copy_from_literal(&ints).is_err());
    }

    #[test]
    fn to_vec_into_reuses_buffer() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        let mut buf = vec![9.0f32; 7];
        l.to_vec_into(&mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0]);
        let mut wrong: Vec<i32> = Vec::new();
        assert!(l.to_vec_into(&mut wrong).is_err());
    }

    #[test]
    fn device_path_gated() {
        assert!(PjRtClient::cpu().is_err());
    }
}
