//! Integration tests over the full training stack: every algorithm runs a
//! short small-variant budget end-to-end, producing finite metrics, a
//! working checkpoint, and (for the PLR family) a filling level buffer.
//! Requires `make artifacts`.

use std::path::PathBuf;

use jaxued::algo::plr::PlrAlgo;
use jaxued::algo::{build_algo, train, train_pack, UedAlgorithm};
use jaxued::config::{Algo, TrainConfig, VARIANT_SMALL};
use jaxued::env::MazeFamily;
use jaxued::runtime::{PackManifest, Runtime};
use jaxued::util::rng::Pcg64;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Runtime {
    Runtime::new(&artifacts_dir()).expect("run `make artifacts` first")
}

fn cfg_for(algo: Algo, cycles: u64, out: &str) -> TrainConfig {
    let mut cfg = TrainConfig::defaults(algo);
    cfg.variant = VARIANT_SMALL;
    cfg.env_steps_budget = cycles * cfg.env_steps_per_cycle();
    cfg.eval_interval = 0;
    cfg.eval_trials = 1;
    cfg.out_dir = std::env::temp_dir()
        .join("jaxued_it")
        .join(out)
        .to_string_lossy()
        .into_owned();
    cfg
}

#[test]
fn dr_trains_end_to_end() {
    let rt = runtime();
    let cfg = cfg_for(Algo::Dr, 12, "dr");
    let outcome = train(&rt, &cfg, true).unwrap();
    assert_eq!(outcome.cycles, 12);
    assert_eq!(outcome.env_steps, 12 * 32 * 8);
    assert!(outcome.final_eval.mean_solve_rate.is_finite());
    // checkpoint written
    let ckpt = std::path::Path::new(&cfg.out_dir).join("dr_s0").join("student.ckpt");
    assert!(ckpt.exists());
    // metrics CSV has one row per cycle (+ header)
    let csv = std::path::Path::new(&cfg.out_dir).join("dr_s0").join("metrics.csv");
    let lines = std::fs::read_to_string(csv).unwrap().trim().lines().count();
    assert_eq!(lines, 13);
}

#[test]
fn plr_buffer_fills_and_replays() {
    let rt = runtime();
    let mut cfg = cfg_for(Algo::Plr, 0, "plr");
    cfg.buffer_size = 24; // small buffer so replay starts quickly
    let mut rng = Pcg64::seed_from_u64(0);
    let mut algo = PlrAlgo::new(MazeFamily, &rt, &cfg).unwrap();
    let mut kinds = std::collections::BTreeMap::new();
    for _ in 0..20 {
        let m = algo.cycle(&mut rng).unwrap();
        *kinds.entry(m.kind).or_insert(0usize) += 1;
    }
    assert!(algo.sampler.len() > 0, "buffer never filled");
    assert!(kinds.contains_key("new"), "{kinds:?}");
    assert!(kinds.contains_key("replay"), "replay never triggered: {kinds:?}");
    assert!(!kinds.contains_key("mutate"), "PLR must not mutate: {kinds:?}");
}

#[test]
fn accel_mutates_after_replay() {
    let rt = runtime();
    let mut cfg = cfg_for(Algo::Accel, 0, "accel");
    cfg.buffer_size = 24;
    let mut rng = Pcg64::seed_from_u64(1);
    let mut algo = PlrAlgo::new(MazeFamily, &rt, &cfg).unwrap();
    let mut last_kind = "";
    let mut saw_mutate = false;
    for _ in 0..24 {
        let m = algo.cycle(&mut rng).unwrap();
        if m.kind == "mutate" {
            saw_mutate = true;
            assert_eq!(last_kind, "replay", "mutate must follow replay");
        }
        last_kind = m.kind;
    }
    assert!(saw_mutate, "ACCEL (q=1) never mutated");
}

#[test]
fn robust_plr_never_updates_on_new_levels() {
    let rt = runtime();
    let mut cfg = cfg_for(Algo::RobustPlr, 0, "rplr");
    cfg.buffer_size = 24;
    let mut rng = Pcg64::seed_from_u64(2);
    let mut algo = PlrAlgo::new(MazeFamily, &rt, &cfg).unwrap();
    for _ in 0..16 {
        let m = algo.cycle(&mut rng).unwrap();
        match m.kind {
            "new" => assert!(!m.updated, "PLR⊥ must not train on new levels"),
            "replay" => assert!(m.updated, "PLR⊥ must train on replay"),
            _ => {}
        }
    }
}

#[test]
fn plain_plr_updates_on_new_levels() {
    let rt = runtime();
    let cfg = cfg_for(Algo::Plr, 0, "plr2");
    let mut rng = Pcg64::seed_from_u64(3);
    let mut algo = PlrAlgo::new(MazeFamily, &rt, &cfg).unwrap();
    let m = algo.cycle(&mut rng).unwrap();
    assert_eq!(m.kind, "new");
    assert!(m.updated, "plain PLR trains on new-level cycles");
}

#[test]
fn paired_produces_regret_and_levels() {
    let rt = runtime();
    let cfg = cfg_for(Algo::Paired, 4, "paired");
    let mut rng = Pcg64::seed_from_u64(4);
    let mut algo = build_algo(&rt, &cfg, &mut rng).unwrap();
    for _ in 0..4 {
        let m = algo.cycle(&mut rng).unwrap();
        assert_eq!(m.kind, "paired");
        assert!(m.mean_regret.is_finite());
        assert!(m.mean_regret >= 0.0);
        assert!(m.adversary_loss.is_finite());
    }
}

#[test]
fn training_is_seed_deterministic() {
    let rt = runtime();
    let run = |seed: u64| {
        let mut cfg = cfg_for(Algo::Dr, 6, &format!("det{seed}"));
        cfg.seed = seed;
        train(&rt, &cfg, true).unwrap().final_eval.mean_solve_rate
    };
    let a = run(9);
    let b = run(9);
    let c = run(10);
    assert_eq!(a, b, "same seed must reproduce exactly");
    // different seed virtually always differs (rates are coarse; allow equal
    // only if both are 0, which the assert below tolerates)
    if a != 0.0 || c != 0.0 {
        // don't hard-fail on an unlucky tie of nonzero rates; just check
        // the full metric stream differs is overkill here
    }
    let _ = c;
}

#[test]
fn seed_pack_matches_solo_run() {
    // Unlike its siblings this test skips gracefully when the artifact
    // set is absent, because the artifact-free CI fallback covers the
    // same invariant through tests/pack_determinism.rs — here we pin the
    // *full* train() path (PPO + checkpoints + CSVs) on top of it.
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("seed_pack_matches_solo_run: artifacts missing, skipping");
        return;
    }
    let rt = match Runtime::new(&artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("seed_pack_matches_solo_run: runtime unavailable ({e}), skipping");
            return;
        }
    };
    let mut cfg = cfg_for(Algo::Dr, 6, "pack");
    cfg.pack_seeds = vec![0, 1, 3];
    let pack = train_pack(&rt, &cfg, true).unwrap();
    assert_eq!(pack.seeds, vec![0, 1, 3]);
    assert_eq!(pack.outcomes.len(), 3);

    // pack artifacts: manifest round-trips, aggregate has a row per cycle
    let pm = PackManifest::load(&pack.pack_dir).unwrap();
    assert_eq!(pm.seeds, vec![0, 1, 3]);
    assert_eq!(pm.run_dirs, vec!["dr_s0", "dr_s1", "dr_s3"]);
    let agg = std::fs::read_to_string(pack.pack_dir.join(&pm.aggregate_csv)).unwrap();
    assert_eq!(agg.trim().lines().count(), 6 + 1, "aggregate rows");

    // seed 3 inside the pack == seed 3 alone: final eval and every
    // deterministic CSV column (steps_per_sec and the four phase-timer
    // ns columns are wallclock-derived, so stripped)
    let mut solo_cfg = cfg_for(Algo::Dr, 6, "pack_solo");
    solo_cfg.seed = 3;
    let solo = train(&rt, &solo_cfg, true).unwrap();
    assert_eq!(
        solo.final_eval.mean_solve_rate,
        pack.outcomes[2].final_eval.mean_solve_rate
    );
    assert_eq!(
        solo.final_eval.iqm_solve_rate,
        pack.outcomes[2].final_eval.iqm_solve_rate
    );
    let strip_wallclock = |p: &std::path::Path| -> String {
        std::fs::read_to_string(p)
            .unwrap()
            .trim()
            .lines()
            .map(|l| {
                let cols: Vec<&str> = l.split(',').collect();
                assert!(cols.len() > 5, "metrics.csv narrower than expected");
                cols[..cols.len() - 5].join(",")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    let pack_csv = std::path::Path::new(&cfg.out_dir).join("dr_s3").join("metrics.csv");
    let solo_csv =
        std::path::Path::new(&solo_cfg.out_dir).join("dr_s3").join("metrics.csv");
    assert_eq!(strip_wallclock(&pack_csv), strip_wallclock(&solo_csv));
    // both checkpoints exist and are byte-identical
    let pack_ckpt =
        std::fs::read(std::path::Path::new(&cfg.out_dir).join("dr_s3").join("student.ckpt"))
            .unwrap();
    let solo_ckpt = std::fs::read(
        std::path::Path::new(&solo_cfg.out_dir).join("dr_s3").join("student.ckpt"),
    )
    .unwrap();
    assert_eq!(pack_ckpt, solo_ckpt);
}

#[test]
fn all_algos_via_factory() {
    let rt = runtime();
    let mut rng = Pcg64::seed_from_u64(5);
    for algo in [Algo::Dr, Algo::Plr, Algo::RobustPlr, Algo::Accel, Algo::Paired] {
        let cfg = cfg_for(algo, 1, "factory");
        let mut driver = build_algo(&rt, &cfg, &mut rng).unwrap();
        let m = driver.cycle(&mut rng).unwrap();
        assert!(m.episodes < 10_000);
        assert!(!driver.student_params().is_empty());
        assert_eq!(driver.name().is_empty(), false);
    }
}

#[test]
fn lava_env_runs_all_algos_via_config_only() {
    // The API-redesign acceptance check: the second environment trains
    // under every algorithm with *only* cfg.env changed — no algorithm
    // code knows it exists.
    let rt = runtime();
    let mut rng = Pcg64::seed_from_u64(6);
    for algo in [Algo::Dr, Algo::Plr, Algo::RobustPlr, Algo::Accel, Algo::Paired] {
        let mut cfg = cfg_for(algo, 1, "lava_factory");
        cfg.env = jaxued::env::EnvId::Lava;
        let mut driver = build_algo(&rt, &cfg, &mut rng).unwrap();
        let m = driver.cycle(&mut rng).unwrap();
        assert!(m.episodes < 10_000);
        assert!(!driver.student_params().is_empty());
    }
}

#[test]
fn lava_trains_end_to_end_with_scoped_run_dir() {
    let rt = runtime();
    let mut cfg = cfg_for(Algo::Dr, 8, "lava_e2e");
    cfg.env = jaxued::env::EnvId::Lava;
    let outcome = train(&rt, &cfg, true).unwrap();
    assert_eq!(outcome.cycles, 8);
    assert!(outcome.final_eval.mean_solve_rate.is_finite());
    // env-scoped run dir: lava_{algo}_s{seed}
    let ckpt = std::path::Path::new(&cfg.out_dir)
        .join("lava_dr_s0")
        .join("student.ckpt");
    assert!(ckpt.exists(), "missing {ckpt:?}");
}
