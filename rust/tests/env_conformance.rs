//! Env-trait conformance: the reusable property suite from
//! `jaxued::env::conformance` run against every registered family, plus
//! registry-level invariants. Needs no compiled artifacts — this is pure
//! env-layer behaviour.

use jaxued::env::conformance::{
    check_decode_hardening, check_editor_conformance, check_family_conformance,
};
use jaxued::env::registry::{dispatch, EnvVisitor};
use jaxued::env::{
    EnvFamily, EnvId, EnvParams, LavaFamily, LevelGenerator, LevelMeta, MazeFamily,
};
use jaxued::util::rng::Pcg64;

#[test]
fn maze_family_conforms() {
    check_family_conformance(MazeFamily, &EnvParams::default(), 200);
}

#[test]
fn lava_family_conforms() {
    check_family_conformance(LavaFamily, &EnvParams::default(), 200);
}

#[test]
fn maze_decode_survives_hostile_bytes() {
    check_decode_hardening(MazeFamily, &EnvParams::default(), 500);
}

#[test]
fn lava_decode_survives_hostile_bytes() {
    check_decode_hardening(LavaFamily, &EnvParams::default(), 500);
}

#[test]
fn every_registered_env_decode_hardened_via_dispatch() {
    struct Check;
    impl EnvVisitor for Check {
        type Out = ();
        fn visit<F: EnvFamily>(self, family: F) {
            check_decode_hardening(family, &EnvParams::default(), 100);
        }
    }
    for id in EnvId::ALL {
        dispatch(id, Check);
    }
}

#[test]
fn every_registered_env_conforms_via_dispatch() {
    // The registry path the trainer takes: every EnvId must dispatch to a
    // family that passes the suite (new envs get covered automatically).
    struct Check;
    impl EnvVisitor for Check {
        type Out = ();
        fn visit<F: EnvFamily>(self, family: F) {
            check_family_conformance(family, &EnvParams::default(), 50);
            check_editor_conformance(family, &EnvParams::default(), 8);
        }
    }
    for id in EnvId::ALL {
        dispatch(id, Check);
    }
}

#[test]
fn editor_budget_respected_for_both_palettes() {
    struct Check;
    impl EnvVisitor for Check {
        type Out = ();
        fn visit<F: EnvFamily>(self, family: F) {
            let params = EnvParams { editor_steps: 13, ..EnvParams::default() };
            check_editor_conformance(family, &params, 4);
        }
    }
    for id in EnvId::ALL {
        dispatch(id, Check);
    }
}

#[test]
fn fingerprints_discriminate_within_each_family() {
    // 200 base-distribution draws per family: distinct encodings must hash
    // to distinct fingerprints (FNV collisions at this scale would break
    // the PLR buffer's de-duplication).
    fn check<F: EnvFamily>(family: F) {
        let gen = family.make_generator(&EnvParams::default());
        let mut rng = Pcg64::seed_from_u64(99);
        let levels = gen.sample_batch(200, &mut rng);
        for i in 0..levels.len() {
            for j in (i + 1)..levels.len() {
                if levels[i].encode() != levels[j].encode() {
                    assert_ne!(
                        levels[i].fingerprint(),
                        levels[j].fingerprint(),
                        "[{}] fingerprint collision between draws {i} and {j}",
                        family.id()
                    );
                }
            }
        }
    }
    check(MazeFamily);
    check(LavaFamily);
}
