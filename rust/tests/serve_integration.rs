//! End-to-end tests for `ued-serve` over real loopback sockets.
//!
//! The acceptance properties for the serving subsystem:
//!
//! * **Batched == solo, bit-for-bit** — N concurrent `/eval` requests,
//!   micro-batched together by the server, produce per-level numbers
//!   identical (`f64::to_bits`) to a solo `evaluate_levels` run with the
//!   same master seed, because episode RNG streams are content-keyed.
//! * **Cache serves repeats with zero forward passes** — an identical
//!   repeat request leaves the `/metrics` forward-pass counter untouched.
//!
//! The zoo is synthetic (no compiled artifacts in CI), which exercises
//! every layer except the XLA executable itself — the engine, batcher,
//! cache, zoo LRU, router, and HTTP stack all run for real.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use jaxued::config::ServeConfig;
use jaxued::env::holdout::named_levels;
use jaxued::env::{EnvFamily, LevelMeta, MazeFamily, UnderspecifiedEnv};
use jaxued::eval::evaluate_levels;
use jaxued::rollout::{SyntheticPolicy, WorkerPool};
use jaxued::serve::router::hex_encode;
use jaxued::serve::{serve, ServerHandle};
use jaxued::util::cli::Args;
use jaxued::util::json::Json;

const MAX_STEPS: usize = 40;
const TRIALS: usize = 3;
const MASTER: u64 = 7;

fn start_server(extra: &[&str]) -> ServerHandle {
    let mut argv = vec![
        "--serve-addr".to_string(),
        "127.0.0.1:0".to_string(),
        "--synthetic-zoo".to_string(),
        "2".to_string(),
        "--max-batch".to_string(),
        "4".to_string(),
        "--trials".to_string(),
        TRIALS.to_string(),
        "--max-episode-steps".to_string(),
        MAX_STEPS.to_string(),
    ];
    argv.extend(extra.iter().map(|s| s.to_string()));
    let cfg = ServeConfig::from_args(&Args::parse_from(argv)).unwrap();
    serve(MazeFamily, cfg, None).unwrap()
}

/// One raw HTTP exchange; returns (status, parsed JSON body).
fn exchange(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .split(' ')
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {raw:?}"))
        .parse()
        .unwrap();
    let payload = raw.split("\r\n\r\n").nth(1).unwrap_or("");
    (status, Json::parse(payload).unwrap())
}

fn eval_body(policy: &str, level_hexes: &[String], seed: u64) -> String {
    let levels: Vec<String> =
        level_hexes.iter().map(|h| format!("\"{h}\"")).collect();
    format!(
        "{{\"policy\":\"{policy}\",\"levels\":[{}],\"trials\":{TRIALS},\"seed\":{seed}}}",
        levels.join(",")
    )
}

/// The solo reference: `evaluate_levels` on the same levels with the
/// same master seed, levels named by fingerprint like the server does.
fn solo_reference(levels: &[(String, jaxued::env::level::Level)]) -> Vec<(String, u64, u64)> {
    let family = MazeFamily;
    let params = jaxued::env::EnvParams {
        max_episode_steps: MAX_STEPS,
        ..jaxued::env::EnvParams::default()
    };
    let env = family.make_env(&params);
    let policy = SyntheticPolicy { num_actions: env.num_actions() };
    let pool = Arc::new(WorkerPool::new(1));
    let report = evaluate_levels(
        &env, &policy, levels, TRIALS, MAX_STEPS, 4, MASTER, pool,
    )
    .unwrap();
    report
        .levels
        .iter()
        .map(|l| (l.name.clone(), l.solve_rate.to_bits(), l.mean_steps.to_bits()))
        .collect()
}

#[test]
fn concurrent_eval_is_bit_identical_to_solo() {
    let handle = start_server(&[]);
    let addr = handle.addr;

    let named: Vec<(String, jaxued::env::level::Level)> = named_levels()
        .into_iter()
        .take(4)
        .map(|n| (format!("{:016x}", n.level.fingerprint()), n.level))
        .collect();
    let hexes: Vec<String> =
        named.iter().map(|(_, l)| hex_encode(&l.encode())).collect();
    let reference = solo_reference(&named);

    // Six concurrent clients, alternating policies, rotating level order
    // so micro-batches mix requests — results must not depend on any of
    // that.
    let clients: Vec<std::thread::JoinHandle<(usize, Json)>> = (0..6)
        .map(|i| {
            let hexes = hexes.clone();
            std::thread::spawn(move || {
                let mut order: Vec<usize> = (0..hexes.len()).collect();
                order.rotate_left(i % hexes.len());
                let picked: Vec<String> =
                    order.iter().map(|&j| hexes[j].clone()).collect();
                let policy = format!("synthetic{}", i % 2);
                let (status, body) =
                    exchange(addr, "POST", "/eval", &eval_body(&policy, &picked, MASTER));
                assert_eq!(status, 200, "{body:?}");
                (i, body)
            })
        })
        .collect();

    for client in clients {
        let (i, body) = client.join().unwrap();
        let report = body.get("report").unwrap();
        let rows = report.get("levels").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        for (slot, row) in rows.iter().enumerate() {
            // Undo this client's rotation to find the reference row.
            let j = (slot + (i % 4)) % 4;
            let (ref_name, ref_rate, ref_steps) = &reference[j];
            assert_eq!(row.get("name").unwrap().as_str(), Some(ref_name.as_str()));
            assert_eq!(
                row.get("solve_rate").unwrap().as_f64().unwrap().to_bits(),
                *ref_rate,
                "client {i} level {j}: batched solve_rate diverged from solo"
            );
            assert_eq!(
                row.get("mean_steps").unwrap().as_f64().unwrap().to_bits(),
                *ref_steps,
                "client {i} level {j}: batched mean_steps diverged from solo"
            );
        }
    }

    // All 6 clients × 4 levels × 3 trials ran (some from cache).
    let (status, m) = exchange(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert_eq!(m.get("eval_requests").unwrap().as_usize(), Some(6));
    assert!(m.get("forward_passes").unwrap().as_f64().unwrap() > 0.0);

    handle.shutdown_and_join();
}

#[test]
fn repeat_requests_hit_the_cache_with_zero_forward_passes() {
    let handle = start_server(&[]);
    let addr = handle.addr;
    let hexes: Vec<String> = named_levels()
        .into_iter()
        .take(3)
        .map(|n| hex_encode(&n.level.encode()))
        .collect();
    let body = eval_body("synthetic0", &hexes, 5);

    let (status, first) = exchange(addr, "POST", "/eval", &body);
    assert_eq!(status, 200);
    assert_eq!(first.get("cached_levels").unwrap().as_usize(), Some(0));
    let (_, m1) = exchange(addr, "GET", "/metrics", "");
    let fp1 = m1.get("forward_passes").unwrap().as_f64().unwrap();
    assert!(fp1 > 0.0, "first request must run episodes");

    let (status, second) = exchange(addr, "POST", "/eval", &body);
    assert_eq!(status, 200);
    assert_eq!(second.get("cached_levels").unwrap().as_usize(), Some(3));
    assert_eq!(
        second.get("report").unwrap().get("forward_passes").unwrap().as_f64(),
        Some(0.0),
        "fully cached reply costs no forward passes"
    );
    // The report payloads are bit-identical.
    assert_eq!(
        first.get("report").unwrap().to_string(),
        second.get("report").unwrap().to_string()
    );
    // The acceptance criterion: the server-wide forward-pass counter did
    // not move for the repeat request.
    let (_, m2) = exchange(addr, "GET", "/metrics", "");
    assert_eq!(m2.get("forward_passes").unwrap().as_f64().unwrap(), fp1);
    assert!(m2.get("cache_hits").unwrap().as_f64().unwrap() >= 3.0);

    // A different seed is a different cache key: misses again.
    let (status, third) =
        exchange(addr, "POST", "/eval", &eval_body("synthetic0", &hexes, 6));
    assert_eq!(status, 200);
    assert_eq!(third.get("cached_levels").unwrap().as_usize(), Some(0));

    handle.shutdown_and_join();
}

#[test]
fn endpoints_and_validation_over_loopback() {
    let handle = start_server(&[]);
    let addr = handle.addr;

    let (status, body) = exchange(addr, "GET", "/healthz", "");
    assert_eq!((status, body.to_string().as_str()), (200, "{\"ok\":true}"));

    let (status, body) = exchange(addr, "GET", "/zoo", "");
    assert_eq!(status, 200);
    let rows = body.get("policies").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!(rows[0].get("synthetic").unwrap().as_bool(), Some(true));

    let hex = hex_encode(&named_levels()[0].level.encode());
    let (status, _) =
        exchange(addr, "POST", "/eval", &eval_body("ghost", &[hex], 0));
    assert_eq!(status, 404, "unknown policy");

    let (status, _) = exchange(
        addr,
        "POST",
        "/eval",
        "{\"policy\":\"synthetic0\",\"levels\":[\"zz\"]}",
    );
    assert_eq!(status, 400, "invalid hex");

    let (status, _) = exchange(addr, "GET", "/nope", "");
    assert_eq!(status, 404);

    handle.shutdown_and_join();
}

#[test]
fn generate_endpoint_is_deterministic_and_evaluable() {
    let handle = start_server(&[]);
    let addr = handle.addr;

    let body = "{\"seed\": 11, \"mutations\": 5}";
    let (s1, g1) = exchange(addr, "POST", "/levels/generate", body);
    let (s2, g2) = exchange(addr, "POST", "/levels/generate", body);
    assert_eq!((s1, s2), (200, 200));
    assert_eq!(g1.to_string(), g2.to_string(), "same seed → same level");
    assert_eq!(g1.get("valid").unwrap().as_bool(), Some(true));

    // The generated level feeds straight back into /eval.
    let hex = g1.get("bytes").unwrap().as_str().unwrap().to_string();
    let (status, body) =
        exchange(addr, "POST", "/eval", &eval_body("synthetic1", &[hex], 1));
    assert_eq!(status, 200, "{body:?}");
    assert_eq!(
        body.get("report").unwrap().get("levels").unwrap().as_arr().unwrap().len(),
        1
    );

    handle.shutdown_and_join();
}
