//! Integration tests over the PJRT runtime: artifact loading, parameter
//! lifecycle, numerical sanity of the apply/train/score artifacts, and
//! checkpoint round-trips. Requires `make artifacts`.

use std::path::{Path, PathBuf};

use jaxued::config::{Algo, TrainConfig, VARIANT_SMALL};
use jaxued::env::gen::MazeLevelGenerator;
use jaxued::env::maze::{MazeEnv, NUM_ACTIONS};
use jaxued::env::wrappers::AutoReplayWrapper;
use jaxued::env::UnderspecifiedEnv;
use jaxued::ppo::{LrSchedule, PpoTrainer};
use jaxued::rollout::{Policy, RolloutEngine, Trajectory};
use jaxued::runtime::{ParamSet, Runtime};
use jaxued::util::rng::Pcg64;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Runtime {
    Runtime::new(&artifacts_dir()).expect("run `make artifacts` first")
}

fn small_cfg(algo: Algo) -> TrainConfig {
    let mut cfg = TrainConfig::defaults(algo);
    cfg.variant = VARIANT_SMALL;
    cfg
}

fn literals_equal(a: &xla::Literal, b: &xla::Literal) -> bool {
    a.to_vec::<f32>().unwrap() == b.to_vec::<f32>().unwrap()
}

#[test]
fn init_is_seed_deterministic() {
    let rt = runtime();
    let a = rt.init_params("student", 42).unwrap();
    let b = rt.init_params("student", 42).unwrap();
    let c = rt.init_params("student", 43).unwrap();
    for (x, y) in a.params.iter().zip(&b.params) {
        assert!(literals_equal(x, y));
    }
    assert!(a.params.iter().zip(&c.params).any(|(x, y)| !literals_equal(x, y)));
    // optimizer state starts at zero
    for m in &a.m {
        assert!(m.to_vec::<f32>().unwrap().iter().all(|&v| v == 0.0));
    }
    assert_eq!(a.step_count().unwrap(), 0);
}

#[test]
fn param_shapes_match_manifest() {
    let rt = runtime();
    let ps = rt.init_params("student", 0).unwrap();
    let net = rt.manifest.network("student").unwrap();
    assert_eq!(ps.params.len(), net.num_params());
    for (lit, shape) in ps.params.iter().zip(&net.param_shapes) {
        assert_eq!(lit.element_count(), shape.iter().product::<usize>());
    }
    assert_eq!(ps.num_parameters(), net.total_elements());
}

#[test]
fn apply_outputs_finite_and_batch_consistent() {
    let rt = runtime();
    let ps = rt.init_params("student", 7).unwrap();
    let apply = rt.load("student_apply_b8").unwrap();
    let policy = Policy { apply, params: &ps.params, num_actions: NUM_ACTIONS };

    // same obs replicated across the batch must give identical rows
    let env = MazeEnv::default();
    let gen = MazeLevelGenerator::new(30);
    let mut rng = Pcg64::seed_from_u64(0);
    let level = gen.generate_solvable(&mut rng, 100);
    let state = env.reset_to_level(&level, &mut rng);
    let mut flat = vec![0.0f32; env.obs_len()];
    env.observe(&state, &mut flat);
    let comps = env.obs_components();
    let mut staged: Vec<jaxued::util::tensor::TensorF32> = comps
        .iter()
        .map(|&c| jaxued::util::tensor::TensorF32::zeros(&[8, c]))
        .collect();
    let mut off = 0;
    for (k, &c) in comps.iter().enumerate() {
        for b in 0..8 {
            staged[k].data_mut()[b * c..(b + 1) * c].copy_from_slice(&flat[off..off + c]);
        }
        off += c;
    }
    let (logits, values) = policy.forward(&staged).unwrap();
    assert_eq!(logits.len(), 8 * NUM_ACTIONS);
    assert_eq!(values.len(), 8);
    assert!(logits.iter().all(|x| x.is_finite()));
    for b in 1..8 {
        assert_eq!(logits[0..3], logits[b * 3..b * 3 + 3], "batch row {b} differs");
        assert_eq!(values[0], values[b]);
    }
}

#[test]
fn train_step_learns_on_synthetic_advantage() {
    // Repeatedly updating on the same trajectory must reduce the loss.
    let rt = runtime();
    let cfg = small_cfg(Algo::Dr);
    let schedule = LrSchedule { lr0: 1e-3, anneal: false, total_updates: 100 };
    let mut trainer =
        PpoTrainer::new(&rt, "student", &cfg.student_train_artifact(), 3, schedule).unwrap();
    let apply = rt.load(&cfg.student_apply_artifact()).unwrap();

    let env = AutoReplayWrapper::new(MazeEnv::new(cfg.max_episode_steps));
    let gen = MazeLevelGenerator::new(10);
    let mut rng = Pcg64::seed_from_u64(5);
    let levels = gen.generate_batch(8, &mut rng);
    let mut states: Vec<_> = levels.iter().map(|l| env.reset_to_level(l, &mut rng)).collect();
    let mut engine = RolloutEngine::new(&env, 8);
    let mut traj = Trajectory::new(32, 8, &env.obs_components());
    {
        let policy = Policy { apply, params: &trainer.params.params, num_actions: NUM_ACTIONS };
        engine.collect(&env, &mut states, &policy, &mut traj, &mut rng).unwrap();
    }
    let m0 = trainer.update(&traj).unwrap();
    let mut last = f32::INFINITY;
    for _ in 0..5 {
        let m = trainer.update(&traj).unwrap();
        last = m.total_loss();
        assert!(last.is_finite());
    }
    // KL shrinks relative learning signal; loss should not blow up and the
    // step count must advance 5 epochs per update (6 updates total).
    assert_eq!(trainer.params.step_count().unwrap(), 6 * 5);
    assert!(m0.total_loss().is_finite());
    assert!(last.abs() < 100.0, "loss diverged: {last}");
}

#[test]
fn score_artifact_sane() {
    use jaxued::algo::scoring::Scorer;
    use jaxued::config::ScoreFn;
    let rt = runtime();
    let scorer = Scorer::new(rt.load("score_t32_b8").unwrap(), ScoreFn::MaxMc).unwrap();
    let mut traj = Trajectory::new(32, 8, &[75, 4]);
    // column 0 gets a reward spike; its regret estimates should be positive
    traj.rewards.set(&[10, 0], 1.0);
    traj.dones.set(&[10, 0], 1.0);
    let batch = scorer.score(&traj, &[0.0; 8]).unwrap();
    assert_eq!(batch.scores.len(), 8);
    assert!(batch.scores.iter().all(|s| s.is_finite() && *s >= 0.0));
    assert!(batch.scores[0] > batch.scores[1], "{:?}", batch.scores);
    assert!(batch.extras[0].max_return > 0.9);
    // carry: prev max return dominates
    let batch2 = scorer.score(&traj, &[5.0; 8]).unwrap();
    assert!((batch2.extras[0].max_return - 5.0).abs() < 1e-5);
    assert!(batch2.scores[0] > batch.scores[0]);
}

#[test]
fn checkpoint_roundtrip_preserves_params() {
    let rt = runtime();
    let ps = rt.init_params("student", 11).unwrap();
    let dir = std::env::temp_dir().join("jaxued_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("s.ckpt");
    ps.save(&path).unwrap();
    let loaded = ParamSet::load(&path, "student").unwrap();
    for (a, b) in ps.params.iter().zip(&loaded.params) {
        assert!(literals_equal(a, b));
    }
    for (a, b) in ps.v.iter().zip(&loaded.v) {
        assert!(literals_equal(a, b));
    }
    assert_eq!(loaded.step_count().unwrap(), 0);
    // wrong network name is rejected
    assert!(ParamSet::load(&path, "adversary").is_err());
}

#[test]
fn checkpoint_policy_equivalence() {
    // a reloaded checkpoint must produce byte-identical policy outputs
    let rt = runtime();
    let ps = rt.init_params("student", 13).unwrap();
    let dir = std::env::temp_dir().join("jaxued_ckpt_test2");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("s.ckpt");
    ps.save(&path).unwrap();
    let loaded = ParamSet::load(&path, "student").unwrap();

    let apply = rt.load("student_apply_b8").unwrap();
    let staged: Vec<jaxued::util::tensor::TensorF32> = vec![
        jaxued::util::tensor::TensorF32::zeros(&[8, 75]),
        jaxued::util::tensor::TensorF32::zeros(&[8, 4]),
    ];
    let p1 = Policy { apply: apply.clone(), params: &ps.params, num_actions: 3 };
    let p2 = Policy { apply, params: &loaded.params, num_actions: 3 };
    let (l1, v1) = p1.forward(&staged).unwrap();
    let (l2, v2) = p2.forward(&staged).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(v1, v2);
}

#[test]
fn manifest_env_validation_works() {
    // loading from a bogus dir fails cleanly
    assert!(Runtime::new(Path::new("/nonexistent/artifacts")).is_err());
}

#[test]
fn adversary_artifacts_load() {
    let rt = runtime();
    let ps = rt.init_params("adversary", 0).unwrap();
    let net = rt.manifest.network("adversary").unwrap();
    assert_eq!(ps.num_parameters(), net.total_elements());
    let apply = rt.load("adversary_apply_b8").unwrap();
    let staged: Vec<jaxued::util::tensor::TensorF32> = vec![
        jaxued::util::tensor::TensorF32::zeros(&[8, 507]),
        jaxued::util::tensor::TensorF32::zeros(&[8, 1]),
        jaxued::util::tensor::TensorF32::zeros(&[8, 16]),
    ];
    let policy = Policy { apply, params: &ps.params, num_actions: 169 };
    let (logits, values) = policy.forward(&staged).unwrap();
    assert_eq!(logits.len(), 8 * 169);
    assert!(values.iter().all(|v| v.is_finite()));
}
