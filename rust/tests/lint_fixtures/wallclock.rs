//! Violation fixture: wallclock reads (a crate-wide rule — real time
//! must never feed results; `metrics::Stopwatch` is the one reader).

pub fn stamp() -> u64 {
    let t0 = std::time::Instant::now();
    let s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    s.wrapping_add(t0.elapsed().as_secs())
}
