//! Service-profile fixture: models `serve/` batcher code. Under the
//! service profile (`ordered_collections` + `wallclock_exempt`) the
//! wallclock reads below are legitimate (request timeouts, latency
//! accounting) and must NOT be flagged — but grouping queued requests
//! through a `HashMap` MUST be: hasher iteration order is per-process,
//! so draining groups from it would assign requests to batch columns in
//! a schedule-dependent order. Batcher request ordering is pinned
//! FIFO-deterministic; serve code sticks to `Vec`/`BTreeMap`.

use std::collections::HashMap;
use std::time::{Duration, Instant};

pub struct Queued {
    pub policy: u64,
    pub episodes: usize,
}

/// Wallclock use a server legitimately needs: deadline bookkeeping.
pub fn deadline_expired(started: Instant, budget: Duration) -> bool {
    Instant::now().duration_since(started) > budget
}

/// The violation: batch columns filled by iterating a hash map. Which
/// request lands in which column now depends on the hasher seed.
pub fn column_order(works: &[Queued]) -> Vec<u64> {
    let mut groups: HashMap<u64, usize> = HashMap::new();
    for w in works {
        *groups.entry(w.policy).or_insert(0) += w.episodes;
    }
    groups.into_keys().collect()
}
