//! An item-scoped allow ends with its item: `first` is covered, the
//! structurally identical `second` is not. Exactly one violation.

// ued-lint: allow(wallclock) — covers `first` only; `second` must still flag
pub fn first() -> u128 {
    let t = Instant::now();
    t.elapsed().as_nanos()
}

pub fn second() -> u128 {
    let t = Instant::now();
    t.elapsed().as_nanos()
}
