//! Violation fixture: malformed allow directives. A bad directive is
//! itself reported (`bad-allow`) and suppresses nothing — the ambient
//! RNG under the reason-less allow below must still be flagged.

pub fn no_reason() -> u64 {
    // ued-lint: allow(thread-rng)
    let mut rng = thread_rng();
    rng.next_u64()
}

pub fn unknown_rule() -> f32 {
    // ued-lint: allow(fast-math) — no such rule exists
    1.0f32
}
