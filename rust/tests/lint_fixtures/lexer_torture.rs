//! Lexer-hardening torture: raw strings (with embedded quotes and rule
//! trigger words), nested block comments, lifetime-vs-char ambiguity,
//! raw identifiers, byte/byte-string literals, and backslash-newline
//! string continuations. The only real violation is the wallclock read
//! in `timing_probe` — if the lexer miscounts a line anywhere above,
//! the test pinning that violation's line number goes red.

pub fn torture<'a>(r#type: &'a str) -> usize {
    let raw = r#"not // a comment, not "done" yet: Instant::now() thread_rng()"#;
    /* nested /* inner block */ still one comment */
    let s = "continued \
        across \
        three lines";
    let c = 'x';
    let nl = '\n';
    let byte = b'q';
    let bytes = b"escaped \
        tail";
    let _lt: &'static str = "static";
    raw.len() + s.len() + r#type.len() + (c as usize) + (nl as usize) + (byte as usize) + bytes.len()
}

pub fn timing_probe() -> bool {
    let t = Instant::now();
    t.elapsed().as_nanos() > 0
}
