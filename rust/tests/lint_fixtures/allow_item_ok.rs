//! Item-scoped allow: a directive on the line directly above an item
//! (attribute run included) covers the item's whole span, so the
//! wallclock read three lines into the body is suppressed.

// ued-lint: allow(wallclock) — benchmark shim; the timing never reaches results
#[inline]
pub fn bench_probe() -> u128 {
    let pad = 1u128;
    let t = Instant::now();
    t.elapsed().as_nanos() + pad
}
