//! Violation fixture: ambient RNG in a deterministic module. All
//! randomness must flow from the seeded per-column Pcg64 streams.

pub fn ambient_draws() -> (u64, u64) {
    let mut rng = rand::thread_rng();
    let a = rng.gen();
    let b = rand::random::<u64>();
    (a, b)
}
