//! Violation fixture: a pointer address cast to an integer. Addresses
//! vary run to run, so address-derived keys are nondeterministic.

pub fn level_key(level: &[u8]) -> u64 {
    (level as *const [u8] as *const u8 as usize) as u64
}
