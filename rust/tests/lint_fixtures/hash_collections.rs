//! Violation fixture: hash-ordered collections in a deterministic
//! module. The fold below depends on per-process hasher iteration order.

use std::collections::HashMap;

pub fn schedule_dependent_sum(xs: &[(u64, f32)]) -> f32 {
    let mut m: HashMap<u64, f32> = HashMap::new();
    for &(k, v) in xs {
        *m.entry(k).or_insert(0.0) += v;
    }
    let mut acc = 0.0;
    for (_k, v) in m.iter() {
        acc = acc * 0.5 + v;
    }
    acc
}
