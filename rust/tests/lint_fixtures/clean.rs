//! A fixture that follows every `ued-lint` rule: ordered collections,
//! seeded randomness (with one documented escape hatch), and fully
//! audited unsafety. Linted as a deterministic module; must be clean.
//! Not compiled — lexed by `tests/lint_self.rs` only.

use std::collections::BTreeMap;

pub fn ordered_histogram(xs: &[u32]) -> BTreeMap<u32, u32> {
    let mut out = BTreeMap::new();
    for &x in xs {
        *out.entry(x).or_insert(0) += 1;
    }
    out
}

pub fn demo_allowed_ambient_draw() -> u64 {
    // ued-lint: allow(thread-rng) — fixture demo of the escape hatch; not rollout code
    let mut rng = thread_rng();
    rng.next_u64()
}

/// Reads the first element without a bounds check.
///
/// # Safety
///
/// `xs` must be non-empty; the caller guarantees it.
pub unsafe fn first_unchecked(xs: &[u64]) -> u64 {
    // SAFETY: the caller contract above guarantees `xs` is non-empty.
    unsafe { *xs.as_ptr() }
}
