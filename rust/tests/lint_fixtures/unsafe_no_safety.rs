//! Violation fixture: unaudited unsafety — an `unsafe impl` and an
//! `unsafe` block, neither carrying a SAFETY comment.

pub struct Wrapper(pub *mut u8);

unsafe impl Send for Wrapper {}

pub fn read_first(xs: &[u8]) -> u8 {
    unsafe { *xs.as_ptr() }
}
