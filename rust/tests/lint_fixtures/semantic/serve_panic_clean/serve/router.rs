//! Clean counterpart of `serve_panic_bad`: the handler returns `Result`
//! and propagates errors with `?` (so its index sites are exempt), and
//! the helper's unwrap is replaced by error propagation.

pub fn handle(body: &[u8]) -> Result<Vec<u8>, String> {
    let first = body.first().copied().ok_or("empty body")?;
    let tail = body.get(1).copied().ok_or("one-byte body")?;
    let n = crate::util::must_parse("12")?;
    Ok(vec![first, tail, n as u8])
}
