//! Panic-free helper: parse failures travel back as errors.

pub fn must_parse(s: &str) -> Result<u64, String> {
    s.parse().map_err(|_| format!("bad number {s:?}"))
}
