//! Two lock classes acquired in opposite orders *through the call
//! graph*: `fwd` holds `a` and calls a helper that takes `b`; `rev`
//! holds `b` and calls a helper that takes `a`. Neither function is
//! suspicious on its own — only lock-order propagation sees the cycle.

use std::sync::Mutex;

pub struct Pair {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl Pair {
    pub fn fwd(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let v = self.bump_b();
        *ga + v
    }

    fn bump_b(&self) -> u32 {
        *self.b.lock().unwrap()
    }

    pub fn rev(&self) -> u32 {
        let gb = self.b.lock().unwrap();
        let v = self.bump_a();
        *gb + v
    }

    fn bump_a(&self) -> u32 {
        *self.a.lock().unwrap()
    }
}
