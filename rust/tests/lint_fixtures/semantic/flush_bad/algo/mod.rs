//! PR 7's data-loss bug as a fixture: a cycle error propagates with
//! `?` while the per-unit sinks only flush after the loop, so every
//! buffered row from the aborted run is lost on the error path.

pub struct Unit;

impl Unit {
    pub fn step_cycle(&mut self) -> Result<(), String> {
        Ok(())
    }

    pub fn flush_sinks(&mut self) {}
}

pub fn drive(units: &mut [Unit]) -> Result<(), String> {
    for u in units.iter_mut() {
        u.step_cycle()?;
    }
    for u in units.iter_mut() {
        u.flush_sinks();
    }
    Ok(())
}
