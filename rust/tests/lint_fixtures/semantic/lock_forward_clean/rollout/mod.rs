//! Clean lock-across-forward shapes: the guard is dropped before the
//! blocking call, or confined to an inner scope that closes first.

use std::sync::Mutex;

pub struct Engine {
    slots: Mutex<Vec<f32>>,
}

impl Engine {
    pub fn forward_direct(&self, buf: &mut [f32]) {
        let _ = buf;
    }

    pub fn infer(&self, buf: &mut [f32]) {
        let guard = self.slots.lock().unwrap();
        let n = guard.len();
        drop(guard);
        self.forward_direct(&mut buf[..n]);
    }

    pub fn scoped(&self, buf: &mut [f32]) {
        {
            let guard = self.slots.lock().unwrap();
            let _ = guard.len();
        }
        self.forward_direct(buf);
    }
}
