//! Deterministic root calling the trait's default method — the only
//! path to the wallclock read in util/.

pub struct Step;

impl Stamped for Step {}

pub fn rollout_step(s: &Step) -> u64 {
    s.coarse_stamp()
}
