//! A trait whose *default* method body reads the wallclock. The source
//! is invisible to the taint pass unless trait default bodies are
//! parsed like any other fn.

pub trait Stamped {
    fn coarse_stamp(&self) -> u64 {
        // ued-lint: allow(wallclock) — fixture: catching the seeded source is the taint pass's job
        let t = std::time::Instant::now();
        let _ = t;
        0
    }
}
