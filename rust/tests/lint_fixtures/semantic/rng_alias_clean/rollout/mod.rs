//! Clean rng-lineage shapes: the same key on *disjoint* branches is
//! fine (only one stream exists per execution), and sequential
//! construction is fine when every key is distinct.

pub struct Pcg64;

impl Pcg64 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let _ = (seed, stream);
        Pcg64
    }
}

pub fn branch_stream(seed: u64, resume: bool) {
    let s = if resume {
        Pcg64::new(seed, 1)
    } else {
        Pcg64::new(seed, 1)
    };
    let t = Pcg64::new(seed, 2);
    let _ = (s, t);
}
