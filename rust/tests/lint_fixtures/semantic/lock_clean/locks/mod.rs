//! Clean counterpart of `lock_cycle_bad`: both public entry points
//! acquire `a` before `b`, so the propagated order graph is acyclic.

use std::sync::Mutex;

pub struct Pair {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl Pair {
    pub fn fwd(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let v = self.bump_b();
        *ga + v
    }

    pub fn fwd_again(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga + *gb
    }

    fn bump_b(&self) -> u32 {
        *self.b.lock().unwrap()
    }
}
