//! The fixed shape of the flush_bad tree: every error path flushes the
//! buffered sinks before propagating, so no metrics row is lost.

pub struct Unit;

impl Unit {
    pub fn step_cycle(&mut self) -> Result<(), String> {
        Ok(())
    }

    pub fn flush_sinks(&mut self) {}
}

pub fn drive(u: &mut Unit) -> Result<(), String> {
    for _ in 0..4 {
        if let Err(e) = u.step_cycle() {
            u.flush_sinks();
            return Err(e);
        }
    }
    u.flush_sinks();
    Ok(())
}
