//! Deterministic module that transitively reaches the wallclock helper.

/// Mixing a timestamp into a rollout seed: invisible to per-file rules
/// (the wallclock read lives in `util/`), caught by determinism taint.
pub fn rollout_step(seed: u64) -> u64 {
    seed ^ crate::util::coarse_timestamp()
}
