//! The seeded taint bug (ISSUE 9 acceptance criterion): a wallclock
//! helper whose per-file violation is silenced by an `allow(wallclock)`,
//! so the old per-file rules report nothing — but it is called from
//! `rollout/`, so the call-graph determinism-taint pass must flag it.

/// "Coarse timestamp" helper a well-meaning contributor might add.
pub fn coarse_timestamp() -> u64 {
    // ued-lint: allow(wallclock) — timing is local to this helper (per-file pass is green; the taint pass must still object)
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
