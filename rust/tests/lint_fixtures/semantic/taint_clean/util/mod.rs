//! Clean counterpart of `taint_bad`: the helper carries a sanctioned
//! `det-taint` allow as well, so both the per-file pass and the
//! call-graph taint pass accept it.

pub fn coarse_timestamp() -> u64 {
    // ued-lint: allow(wallclock, det-taint) — sanctioned diagnostic clock; callers never let it feed results
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
