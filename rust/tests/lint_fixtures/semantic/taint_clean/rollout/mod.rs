//! Same deterministic caller as `taint_bad` — clean because the helper's
//! wallclock read carries an explicit `det-taint` allow.

pub fn rollout_step(seed: u64) -> u64 {
    seed ^ crate::util::coarse_timestamp()
}
