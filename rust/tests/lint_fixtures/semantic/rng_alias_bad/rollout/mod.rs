//! Seeded rng-lineage bugs: two streams minted from one (seed, stream)
//! key on the same path, and a generator forked with `.clone()`. Both
//! replay identical sequences into consumers that believe they are
//! independent.

pub struct Pcg64;

impl Pcg64 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let _ = (seed, stream);
        Pcg64
    }
}

pub fn collect_rollout(seed: u64) {
    let actor = Pcg64::new(seed, 3);
    let critic = Pcg64::new(seed, 3);
    let _ = (actor, critic);
}

pub fn fork_stream(seed: u64) {
    let base = Pcg64::new(seed, 0);
    let forked = base.clone();
    let _ = (base, forked);
}
