//! Seeded lock-across-forward bugs: one guard held directly across a
//! blocking `forward_direct`, and one held across a helper that reaches
//! the same blocking leaf through the call graph.

use std::sync::Mutex;

pub struct Engine {
    slots: Mutex<Vec<f32>>,
}

impl Engine {
    pub fn forward_direct(&self, buf: &mut [f32]) {
        let _ = buf;
    }

    pub fn infer_locked(&self, buf: &mut [f32]) {
        let guard = self.slots.lock().unwrap();
        self.forward_direct(buf);
        drop(guard);
    }

    pub fn helper(&self, buf: &mut [f32]) {
        self.forward_direct(buf);
    }

    pub fn infer_via_helper(&self, buf: &mut [f32]) {
        let guard = self.slots.lock().unwrap();
        self.helper(buf);
        drop(guard);
    }
}
