//! Helper outside `serve/` whose unwrap is reachable from the handler —
//! only the call-graph audit can see it.

pub fn must_parse(s: &str) -> u64 {
    s.parse().unwrap()
}
