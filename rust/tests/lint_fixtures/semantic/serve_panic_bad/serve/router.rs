//! A request handler with panic paths a malformed request can reach:
//! one direct unwrap, one unchecked slice index (the handler does not
//! return `Result`, so the index is audited), and a transitive unwrap
//! in a helper outside `serve/`. Three `serve-panic` violations.

pub fn handle(body: &[u8]) -> Vec<u8> {
    let first = body.first().copied().unwrap();
    let tail = body[1];
    let n = crate::util::must_parse("12");
    vec![first, tail, n as u8]
}
