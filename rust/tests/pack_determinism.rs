//! Seed-pack determinism suite — artifact-free (synthetic stand-in
//! policy), so it runs everywhere the env layer runs, including the CI
//! fallback path without `make artifacts`.
//!
//! Pins the orchestrator's acceptance invariant: seed *s* trained inside
//! a pack (`--seeds 0..N` semantics: N units stepped over ONE shared
//! `WorkerPool`) is bit-identical to seed *s* trained alone — same
//! per-cycle metrics, same final level-sampler contents — at any
//! `--rollout-threads` count *and any `--drivers` count* (multi-driver
//! packs put the pool in fused multi-driver mode, exactly as
//! `train_pack_family` does, so the fused engine schedule is exercised
//! here too), on both registered env families. The units run a PLR-shaped
//! loop (generate/replay → rollout → score → buffer) through the real
//! engine, sampler, and orchestrator core; only the PPO/PJRT layer is
//! substituted. Also pins the abort contract: a mid-pack `step_cycle`
//! failure flushes every unit's sinks and leaves only complete aggregate
//! rows behind.

use std::sync::Arc;

use anyhow::Result;

use jaxued::algo::orchestrator::{run_pack, SeedUnit, PACK_AGGREGATE_METRICS};
use jaxued::algo::CycleMetrics;
use jaxued::env::wrappers::AutoReplayWrapper;
use jaxued::env::{
    EnvFamily, EnvParams, LavaFamily, LevelGenerator, LevelMeta, MazeFamily,
    UnderspecifiedEnv,
};
use jaxued::level_sampler::{LevelSampler, SamplerConfig};
use jaxued::metrics::CrossSeedSink;
use jaxued::rollout::{RolloutEngine, SyntheticPolicy, Trajectory, WorkerPool};
use jaxued::util::rng::Pcg64;

const T: usize = 32;
const B: usize = 8;
const CYCLES: usize = 12;

/// One per-cycle metrics row, bit-exact (f64s compared via to_bits).
type Row = (&'static str, u32, u64, u64, u64);

/// Final sampler contents, bit-exact: (fingerprint, score bits,
/// last_touch, extra bits) per slot in slot order.
type SamplerDump = Vec<(u64, u64, u64, u32)>;

/// A PLR-shaped training unit over the synthetic policy: every RNG draw,
/// rollout, score, and buffer op flows through the unit's own state, with
/// only the worker pool shared — exactly the isolation contract
/// `TrainSeedRun` relies on.
struct SyntheticSeedRun<F: EnvFamily> {
    seed: u64,
    rng: Pcg64,
    env: AutoReplayWrapper<F::Env>,
    gen: F::Generator,
    engine: RolloutEngine,
    traj: Trajectory,
    policy: SyntheticPolicy,
    sampler: LevelSampler<F::Level, f32>,
    cycle: usize,
    rows: Vec<Row>,
}

impl<F: EnvFamily> SyntheticSeedRun<F> {
    fn new(family: F, seed: u64, pool: Arc<WorkerPool>) -> SyntheticSeedRun<F> {
        let params = EnvParams::default();
        let env = AutoReplayWrapper::new(family.make_env(&params));
        let gen = family.make_generator(&params);
        let engine = RolloutEngine::with_pool(&env, B, pool);
        let traj = Trajectory::new(T, B, &env.obs_components());
        let policy = SyntheticPolicy { num_actions: env.num_actions() };
        SyntheticSeedRun {
            seed,
            rng: Pcg64::new(seed, 0x7261_696e),
            env,
            gen,
            engine,
            traj,
            policy,
            sampler: LevelSampler::new(SamplerConfig {
                capacity: 24,
                ..Default::default()
            }),
            cycle: 0,
            rows: Vec::new(),
        }
    }

    fn sampler_dump(&self) -> SamplerDump {
        (0..self.sampler.len())
            .map(|i| {
                let s = self.sampler.get(i);
                (
                    s.fingerprint,
                    s.score.to_bits(),
                    s.last_touch,
                    s.extra.to_bits(),
                )
            })
            .collect()
    }
}

impl<F: EnvFamily> SeedUnit for SyntheticSeedRun<F> {
    fn seed(&self) -> u64 {
        self.seed
    }

    fn total_cycles(&self) -> usize {
        CYCLES
    }

    fn env_steps(&self) -> u64 {
        (self.cycle * T * B) as u64
    }

    fn step_cycle(&mut self) -> Result<CycleMetrics> {
        let replay = self.sampler.sample_replay_decision(0.5, &mut self.rng);
        let (kind, replay_idx, levels) = if replay {
            let indices = self.sampler.sample_replay_indices(B, &mut self.rng);
            let mut idx = indices.clone();
            while idx.len() < B {
                idx.push(idx[idx.len() % indices.len()]);
            }
            let levels: Vec<F::Level> = idx
                .iter()
                .map(|&i| self.sampler.get(i).level.clone())
                .collect();
            ("replay", Some(idx), levels)
        } else {
            ("new", None, self.gen.sample_batch(B, &mut self.rng))
        };

        let mut states: Vec<_> = levels
            .iter()
            .map(|l| self.env.reset_to_level(l, &mut self.rng))
            .collect();
        self.engine
            .collect(&self.env, &mut states, &self.policy, &mut self.traj, &mut self.rng)?;
        let stats = self.traj.episode_stats();

        // synthetic regret stand-in: terminal-reward mean + episode bonus
        let scores: Vec<f64> = stats
            .iter()
            .map(|s| s.mean_end_reward + 0.01 * s.episodes as f64)
            .collect();
        let extras: Vec<f32> = stats.iter().map(|s| s.max_end_reward).collect();
        match replay_idx {
            Some(idx) => self.sampler.update_batch(&idx, &scores, &extras),
            None => {
                let fps: Vec<u64> = levels.iter().map(|l| l.fingerprint()).collect();
                self.sampler.insert_batch(&levels, &scores, &fps, &extras);
            }
        }

        let m = CycleMetrics::from_rollout(
            kind,
            None,
            &stats,
            self.sampler.proportion_filled(),
        );
        self.rows.push((
            m.kind,
            m.episodes,
            m.train_solve_rate.to_bits(),
            m.mean_reward.to_bits(),
            m.buffer_fill.to_bits(),
        ));
        self.cycle += 1;
        Ok(m)
    }
}

/// Train one seed alone (its own pool) and return its bit-exact history.
fn run_solo<F: EnvFamily>(family: F, seed: u64, threads: usize) -> (Vec<Row>, SamplerDump) {
    let pool = Arc::new(WorkerPool::new(threads));
    let mut unit = SyntheticSeedRun::new(family, seed, pool);
    for _ in 0..CYCLES {
        unit.step_cycle().unwrap();
    }
    (unit.rows.clone(), unit.sampler_dump())
}

/// Train a pack of seeds over one shared pool through the orchestrator
/// core (including the cross-seed aggregate sink), on `drivers` driver
/// threads; returns per-seed bit-exact histories plus the aggregate CSV
/// text. Mirrors `train_pack_family`: a multi-driver pack switches the
/// pool to the fused engine schedule.
fn run_packed<F: EnvFamily>(
    family: F, seeds: &[u64], threads: usize, drivers: usize, label: &str,
) -> (Vec<(Vec<Row>, SamplerDump)>, String) {
    let pool = Arc::new(WorkerPool::new(threads));
    pool.set_multi_driver(drivers > 1);
    let mut units: Vec<SyntheticSeedRun<F>> = seeds
        .iter()
        .map(|&s| SyntheticSeedRun::new(family, s, pool.clone()))
        .collect();
    let dir = std::env::temp_dir().join(format!("jaxued_pack_det_{label}"));
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("aggregate.csv");
    let mut aggregate =
        CrossSeedSink::create(&csv_path, PACK_AGGREGATE_METRICS, seeds.len()).unwrap();
    run_pack(&mut units, &mut aggregate, drivers).unwrap();
    aggregate.flush().unwrap();
    let histories = units
        .iter()
        .map(|u| (u.rows.clone(), u.sampler_dump()))
        .collect();
    (histories, std::fs::read_to_string(&csv_path).unwrap())
}

fn check_pack_vs_solo<F: EnvFamily>(family: F) {
    let id = family.id();
    let seeds = [0u64, 1, 2, 3];
    // the full drivers × rollout-threads grid, every cell vs solo
    let (base, csv_base) = run_packed(family, &seeds, 1, 1, &format!("{id}_t1_d1"));
    for (threads, drivers) in [(4, 1), (1, 4), (4, 4), (4, 2)] {
        let label = format!("{id}_t{threads}_d{drivers}");
        let (pack, csv) = run_packed(family, &seeds, threads, drivers, &label);
        assert_eq!(
            pack, base,
            "[{id}] pack not invariant at threads={threads} drivers={drivers}"
        );
        assert_eq!(
            csv, csv_base,
            "[{id}] aggregate CSV not invariant at threads={threads} drivers={drivers}"
        );
    }
    for (si, &seed) in seeds.iter().enumerate() {
        let solo1 = run_solo(family, seed, 1);
        let solo4 = run_solo(family, seed, 4);
        assert_eq!(
            base[si].0, solo1.0,
            "[{id}] seed {seed}: pack metrics != solo metrics"
        );
        assert_eq!(
            base[si].1, solo1.1,
            "[{id}] seed {seed}: pack sampler != solo sampler"
        );
        assert_eq!(
            solo4, solo1,
            "[{id}] seed {seed}: solo not thread-invariant"
        );
    }
    // distinct seeds must actually differ (the pack isn't training one
    // seed four times)
    assert_ne!(base[0].1, base[3].1, "[{id}] seeds 0 and 3 identical");
    // the aggregate CSV is shaped as documented
    let lines: Vec<&str> = csv_base.trim().lines().collect();
    assert_eq!(lines.len(), CYCLES + 1, "[{id}] one aggregate row per cycle");
    let header_cols = lines[0].split(',').count();
    assert_eq!(header_cols, 2 + 3 * PACK_AGGREGATE_METRICS.len());
    assert_eq!(lines[1].split(',').count(), header_cols);
}

#[test]
fn pack_is_bit_identical_to_solo_maze() {
    check_pack_vs_solo(MazeFamily);
}

#[test]
fn pack_is_bit_identical_to_solo_lava() {
    check_pack_vs_solo(LavaFamily);
}

#[test]
fn pack_of_one_matches_solo() {
    // an oversized --drivers request clamps to the pack size
    let (pack, _) = run_packed(MazeFamily, &[5], 2, 4, "maze_single");
    let solo = run_solo(MazeFamily, 5, 2);
    assert_eq!(pack[0], solo);
}

/// A unit that fails at a chosen cycle, recording whether the
/// orchestrator flushed it on the abort path.
struct FlakyUnit {
    cycle: usize,
    fail_at: Option<usize>,
    flushed: bool,
}

impl SeedUnit for FlakyUnit {
    fn seed(&self) -> u64 {
        0
    }

    fn total_cycles(&self) -> usize {
        CYCLES
    }

    fn env_steps(&self) -> u64 {
        (self.cycle * 100) as u64
    }

    fn step_cycle(&mut self) -> Result<CycleMetrics> {
        if self.fail_at == Some(self.cycle) {
            anyhow::bail!("synthetic mid-pack failure");
        }
        self.cycle += 1;
        Ok(CycleMetrics::default())
    }

    fn flush_sinks(&mut self) -> Result<()> {
        self.flushed = true;
        Ok(())
    }
}

#[test]
fn mid_pack_failure_flushes_sinks_and_keeps_complete_rows() {
    const FAIL_AT: usize = 8;
    let mut units = vec![
        FlakyUnit { cycle: 0, fail_at: None, flushed: false },
        FlakyUnit { cycle: 0, fail_at: None, flushed: false },
        FlakyUnit { cycle: 0, fail_at: Some(FAIL_AT), flushed: false },
        FlakyUnit { cycle: 0, fail_at: None, flushed: false },
    ];
    let dir = std::env::temp_dir().join("jaxued_pack_det_abort");
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("aggregate.csv");
    let mut aggregate =
        CrossSeedSink::create(&csv_path, PACK_AGGREGATE_METRICS, units.len()).unwrap();
    let err = run_pack(&mut units, &mut aggregate, 2).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("cycle 8"), "error names the failing cycle: {msg}");
    assert!(msg.contains("synthetic mid-pack failure"), "root cause kept: {msg}");
    // every unit's sinks were flushed despite the abort
    assert!(units.iter().all(|u| u.flushed), "abort path must flush all units");
    // the aggregate holds exactly the complete cycles (0..FAIL_AT), all
    // flushed to disk
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    let lines: Vec<&str> = csv.trim().lines().collect();
    assert_eq!(lines.len(), FAIL_AT + 1, "header + one row per complete cycle");
}
