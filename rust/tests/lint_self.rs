//! `ued-lint` integration suite: the fixture corpus under
//! `tests/lint_fixtures/` (one clean file, one file per violation
//! class), plus the lint's most important property — the real crate's
//! own `src/` tree is lint-clean. CI runs this alongside the `ued_lint`
//! binary; if you add an `unsafe` site without a SAFETY comment, or an
//! ambient RNG / hash map / wallclock read to a deterministic module,
//! `real_crate_is_lint_clean` is the test that goes red.

use std::fs;
use std::path::Path;

use jaxued::analysis::{lint_crate, lint_source, LintConfig, Rule, Violation};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Fixtures model code in deterministic modules (all rules active).
fn det() -> LintConfig {
    LintConfig { deterministic: true, ..LintConfig::default() }
}

/// The `serve/` profile: wallclock exempt, hash-collections still active.
fn service() -> LintConfig {
    LintConfig { ordered_collections: true, wallclock_exempt: true, ..LintConfig::default() }
}

fn render(violations: &[Violation]) -> String {
    violations
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn clean_fixture_passes() {
    let v = lint_source("clean.rs", &fixture("clean.rs"), &det());
    assert!(v.is_empty(), "clean fixture must lint clean, got:\n{}", render(&v));
}

#[test]
fn each_violation_fixture_fails_with_its_rule() {
    let table: &[(&str, Rule)] = &[
        ("hash_collections.rs", Rule::HashCollections),
        ("thread_rng.rs", Rule::ThreadRng),
        ("wallclock.rs", Rule::Wallclock),
        ("addr_hash.rs", Rule::AddrHash),
        ("unsafe_no_safety.rs", Rule::SafetyComment),
        ("bad_allow.rs", Rule::BadAllow),
    ];
    for &(file, rule) in table {
        let v = lint_source(file, &fixture(file), &det());
        assert!(!v.is_empty(), "{file}: expected violations, got none");
        assert!(
            v.iter().any(|x| x.rule == rule),
            "{file}: expected a [{}] violation, got:\n{}",
            rule.name(),
            render(&v)
        );
    }
}

#[test]
fn violation_fixtures_flag_every_seeded_site() {
    // Beyond "at least one": the multi-site fixtures must report each
    // seeded violation (distinct lines are never collapsed).
    let rng = lint_source("thread_rng.rs", &fixture("thread_rng.rs"), &det());
    assert_eq!(rng.iter().filter(|v| v.rule == Rule::ThreadRng).count(), 2, "{}", render(&rng));
    let wall = lint_source("wallclock.rs", &fixture("wallclock.rs"), &det());
    assert_eq!(wall.iter().filter(|v| v.rule == Rule::Wallclock).count(), 2, "{}", render(&wall));
    let uns = lint_source("unsafe_no_safety.rs", &fixture("unsafe_no_safety.rs"), &det());
    assert_eq!(uns.iter().filter(|v| v.rule == Rule::SafetyComment).count(), 2, "{}", render(&uns));
}

#[test]
fn malformed_allows_suppress_nothing() {
    // bad_allow.rs: both bad directives are reported, and the ambient
    // RNG sitting under the reason-less one still surfaces.
    let v = lint_source("bad_allow.rs", &fixture("bad_allow.rs"), &det());
    assert_eq!(v.iter().filter(|x| x.rule == Rule::BadAllow).count(), 2, "{}", render(&v));
    assert!(
        v.iter().any(|x| x.rule == Rule::ThreadRng),
        "a malformed allow must not suppress the violation under it:\n{}",
        render(&v)
    );
}

#[test]
fn allow_comment_is_required_for_suppression() {
    // Strip the escape hatch from the clean fixture: its (previously
    // allowed) ambient draw must surface as a violation.
    let stripped: String = fixture("clean.rs")
        .lines()
        .filter(|l| !l.contains("ued-lint:"))
        .map(|l| format!("{l}\n"))
        .collect();
    let v = lint_source("clean.rs", &stripped, &det());
    assert!(
        v.iter().any(|x| x.rule == Rule::ThreadRng),
        "without its allow, the demo draw must be flagged, got:\n{}",
        render(&v)
    );
}

#[test]
fn serve_fixture_pins_the_service_profile() {
    // serve_batcher.rs models batcher code: its wallclock reads are fine
    // under the service profile, but the HashMap ordering batch columns is
    // exactly what the profile must keep flagging — request ordering is
    // FIFO-deterministic only while serve code sticks to ordered
    // containers.
    let src = fixture("serve_batcher.rs");
    let v = lint_source("serve/batcher.rs", &src, &service());
    assert_eq!(
        v.iter().map(|x| x.rule).collect::<Vec<_>>(),
        [Rule::HashCollections],
        "service profile must flag the hash map and nothing else:\n{}",
        render(&v)
    );
    // The same file under the plain crate-wide profile also flags its
    // wallclock reads — the exemption is what the service profile adds.
    let plain = lint_source("serve/batcher.rs", &src, &LintConfig::default());
    assert!(
        plain.iter().any(|x| x.rule == Rule::Wallclock),
        "without the exemption the wallclock reads must surface:\n{}",
        render(&plain)
    );
}

#[test]
fn nondeterministic_modules_skip_determinism_rules_but_not_the_audit() {
    let cfg = LintConfig::default();
    // Determinism rules are scoped to deterministic modules …
    let rng = lint_source("thread_rng.rs", &fixture("thread_rng.rs"), &cfg);
    assert!(rng.is_empty(), "thread-rng must not fire outside deterministic modules:\n{}", render(&rng));
    // … the unsafety audit is crate-wide …
    let uns = lint_source("unsafe_no_safety.rs", &fixture("unsafe_no_safety.rs"), &cfg);
    assert_eq!(uns.iter().filter(|v| v.rule == Rule::SafetyComment).count(), 2, "{}", render(&uns));
    // … and so is the wallclock rule.
    let wall = lint_source("wallclock.rs", &fixture("wallclock.rs"), &cfg);
    assert_eq!(wall.iter().filter(|v| v.rule == Rule::Wallclock).count(), 2, "{}", render(&wall));
}

#[test]
fn real_crate_is_lint_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_crate(&src).expect("walking src/");
    assert!(report.files > 10, "expected to visit the whole crate, saw {} files", report.files);
    assert!(
        report.violations.is_empty(),
        "the crate's own source must be ued-lint clean; {} violation(s):\n{}",
        report.violations.len(),
        render(&report.violations)
    );
}
