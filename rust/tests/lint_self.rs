//! `ued-lint` integration suite: the fixture corpus under
//! `tests/lint_fixtures/` (one clean file, one file per violation
//! class, and one source *tree* per semantic analysis under
//! `semantic/`), plus the lint's most important property — the real
//! crate's own `src/` tree is lint-clean, semantic analyses included.
//! CI runs this alongside the `ued_lint` binary; if you add an `unsafe`
//! site without a SAFETY comment, an ambient RNG / hash map / wallclock
//! read to a deterministic module, or a helper that leaks
//! nondeterminism or panics into the rollout / serving paths,
//! `real_crate_is_lint_clean` is the test that goes red.

use std::fs;
use std::path::{Path, PathBuf};

use jaxued::analysis::{
    lint_crate, lint_crate_with, lint_source, lint_tree_with, CrateReport, LintConfig,
    LintOptions, Rule, TreeKind, Violation,
};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

fn semantic_dir(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures/semantic").join(name)
}

/// Full lint (per-file + semantic) over one fixture tree.
fn lint_tree(name: &str) -> CrateReport {
    lint_crate(&semantic_dir(name)).unwrap_or_else(|e| panic!("linting {name}: {e}"))
}

/// Fixtures model code in deterministic modules (all rules active).
fn det() -> LintConfig {
    LintConfig { deterministic: true, ..LintConfig::default() }
}

/// The `serve/` profile: wallclock exempt, hash-collections still active.
fn service() -> LintConfig {
    LintConfig { ordered_collections: true, wallclock_exempt: true, ..LintConfig::default() }
}

fn render(violations: &[Violation]) -> String {
    violations
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn clean_fixture_passes() {
    let v = lint_source("clean.rs", &fixture("clean.rs"), &det());
    assert!(v.is_empty(), "clean fixture must lint clean, got:\n{}", render(&v));
}

#[test]
fn each_violation_fixture_fails_with_its_rule() {
    let table: &[(&str, Rule)] = &[
        ("hash_collections.rs", Rule::HashCollections),
        ("thread_rng.rs", Rule::ThreadRng),
        ("wallclock.rs", Rule::Wallclock),
        ("addr_hash.rs", Rule::AddrHash),
        ("unsafe_no_safety.rs", Rule::SafetyComment),
        ("bad_allow.rs", Rule::BadAllow),
    ];
    for &(file, rule) in table {
        let v = lint_source(file, &fixture(file), &det());
        assert!(!v.is_empty(), "{file}: expected violations, got none");
        assert!(
            v.iter().any(|x| x.rule == rule),
            "{file}: expected a [{}] violation, got:\n{}",
            rule.name(),
            render(&v)
        );
    }
}

#[test]
fn violation_fixtures_flag_every_seeded_site() {
    // Beyond "at least one": the multi-site fixtures must report each
    // seeded violation (distinct lines are never collapsed).
    let rng = lint_source("thread_rng.rs", &fixture("thread_rng.rs"), &det());
    assert_eq!(rng.iter().filter(|v| v.rule == Rule::ThreadRng).count(), 2, "{}", render(&rng));
    let wall = lint_source("wallclock.rs", &fixture("wallclock.rs"), &det());
    assert_eq!(wall.iter().filter(|v| v.rule == Rule::Wallclock).count(), 2, "{}", render(&wall));
    let uns = lint_source("unsafe_no_safety.rs", &fixture("unsafe_no_safety.rs"), &det());
    assert_eq!(uns.iter().filter(|v| v.rule == Rule::SafetyComment).count(), 2, "{}", render(&uns));
}

#[test]
fn malformed_allows_suppress_nothing() {
    // bad_allow.rs: both bad directives are reported, and the ambient
    // RNG sitting under the reason-less one still surfaces.
    let v = lint_source("bad_allow.rs", &fixture("bad_allow.rs"), &det());
    assert_eq!(v.iter().filter(|x| x.rule == Rule::BadAllow).count(), 2, "{}", render(&v));
    assert!(
        v.iter().any(|x| x.rule == Rule::ThreadRng),
        "a malformed allow must not suppress the violation under it:\n{}",
        render(&v)
    );
}

#[test]
fn allow_comment_is_required_for_suppression() {
    // Strip the escape hatch from the clean fixture: its (previously
    // allowed) ambient draw must surface as a violation.
    let stripped: String = fixture("clean.rs")
        .lines()
        .filter(|l| !l.contains("ued-lint:"))
        .map(|l| format!("{l}\n"))
        .collect();
    let v = lint_source("clean.rs", &stripped, &det());
    assert!(
        v.iter().any(|x| x.rule == Rule::ThreadRng),
        "without its allow, the demo draw must be flagged, got:\n{}",
        render(&v)
    );
}

#[test]
fn serve_fixture_pins_the_service_profile() {
    // serve_batcher.rs models batcher code: its wallclock reads are fine
    // under the service profile, but the HashMap ordering batch columns is
    // exactly what the profile must keep flagging — request ordering is
    // FIFO-deterministic only while serve code sticks to ordered
    // containers.
    let src = fixture("serve_batcher.rs");
    let v = lint_source("serve/batcher.rs", &src, &service());
    assert_eq!(
        v.iter().map(|x| x.rule).collect::<Vec<_>>(),
        [Rule::HashCollections],
        "service profile must flag the hash map and nothing else:\n{}",
        render(&v)
    );
    // The same file under the plain crate-wide profile also flags its
    // wallclock reads — the exemption is what the service profile adds.
    let plain = lint_source("serve/batcher.rs", &src, &LintConfig::default());
    assert!(
        plain.iter().any(|x| x.rule == Rule::Wallclock),
        "without the exemption the wallclock reads must surface:\n{}",
        render(&plain)
    );
}

#[test]
fn nondeterministic_modules_skip_determinism_rules_but_not_the_audit() {
    let cfg = LintConfig::default();
    // Determinism rules are scoped to deterministic modules …
    let rng = lint_source("thread_rng.rs", &fixture("thread_rng.rs"), &cfg);
    assert!(rng.is_empty(), "thread-rng must not fire outside deterministic modules:\n{}", render(&rng));
    // … the unsafety audit is crate-wide …
    let uns = lint_source("unsafe_no_safety.rs", &fixture("unsafe_no_safety.rs"), &cfg);
    assert_eq!(uns.iter().filter(|v| v.rule == Rule::SafetyComment).count(), 2, "{}", render(&uns));
    // … and so is the wallclock rule.
    let wall = lint_source("wallclock.rs", &fixture("wallclock.rs"), &cfg);
    assert_eq!(wall.iter().filter(|v| v.rule == Rule::Wallclock).count(), 2, "{}", render(&wall));
}

#[test]
fn lexer_torture_keeps_line_numbers_exact() {
    // Raw strings, nested comments, byte literals, raw identifiers, and
    // backslash-newline continuations all precede the one real wallclock
    // read; a single miscounted line above it moves the violation.
    let v = lint_source("lexer_torture.rs", &fixture("lexer_torture.rs"), &det());
    assert_eq!(
        v.iter().map(|x| (x.rule, x.line)).collect::<Vec<_>>(),
        [(Rule::Wallclock, 24)],
        "torture fixture must yield exactly the line-24 wallclock read:\n{}",
        render(&v)
    );
}

#[test]
fn item_allow_covers_the_item_and_only_the_item() {
    // Pass side: the directive above the fn covers a violation deep in
    // its body (the old two-line window would miss it).
    let ok = lint_source("allow_item_ok.rs", &fixture("allow_item_ok.rs"), &det());
    assert!(ok.is_empty(), "item-scoped allow must cover the whole fn:\n{}", render(&ok));
    // Fail side: the allow ends with its item, so the identical read in
    // the *next* fn still flags — exactly one violation, in `second`.
    let leak = lint_source("allow_item_leak.rs", &fixture("allow_item_leak.rs"), &det());
    assert_eq!(
        leak.iter().map(|x| (x.rule, x.line)).collect::<Vec<_>>(),
        [(Rule::Wallclock, 11)],
        "the allow must not leak past its item:\n{}",
        render(&leak)
    );
}

#[test]
fn seeded_taint_bug_is_invisible_to_per_file_rules_but_caught_by_taint_pass() {
    // The ISSUE-9 acceptance criterion: a wallclock helper in util/
    // carrying allow(wallclock), called from rollout/. Per-file rules:
    // green. Semantic det-taint: exactly one violation, naming the
    // witness path from the deterministic root.
    let per_file =
        lint_crate_with(&semantic_dir("taint_bad"), &LintOptions { semantic: false, cache_path: None })
            .expect("per-file lint");
    assert!(
        per_file.violations.is_empty(),
        "old per-file rules must NOT see the seeded taint bug:\n{}",
        render(&per_file.violations)
    );
    let full = lint_tree("taint_bad");
    assert_eq!(
        full.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect::<Vec<_>>(),
        [(Rule::DetTaint, "util/mod.rs", 9)],
        "semantic pass must report exactly the seeded taint:\n{}",
        render(&full.violations)
    );
    let msg = &full.violations[0].message;
    assert!(msg.contains("Instant::now"), "message names the source: {msg}");
    assert!(msg.contains("rollout_step"), "message shows the witness path: {msg}");
}

#[test]
fn det_taint_allow_must_name_det_taint() {
    // Same tree, but the helper's allow also names det-taint: clean.
    let report = lint_tree("taint_clean");
    assert!(
        report.violations.is_empty(),
        "allow(wallclock, det-taint) must satisfy both passes:\n{}",
        render(&report.violations)
    );
}

#[test]
fn serve_path_panics_flagged_at_exact_sites() {
    let report = lint_tree("serve_panic_bad");
    let got: Vec<(Rule, &str, usize)> =
        report.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect();
    assert_eq!(
        got,
        [
            (Rule::ServePanic, "serve/router.rs", 7), // direct unwrap
            (Rule::ServePanic, "serve/router.rs", 8), // slice index, non-Result fn
            (Rule::ServePanic, "util/mod.rs", 5),     // transitive unwrap via call graph
        ],
        "expected the three seeded serve-panic sites:\n{}",
        render(&report.violations)
    );
    assert!(
        report.violations[2].message.contains("handle"),
        "the transitive finding shows its serve-side witness path: {}",
        report.violations[2].message
    );
}

#[test]
fn result_returning_handlers_are_panic_free() {
    let report = lint_tree("serve_panic_clean");
    assert!(
        report.violations.is_empty(),
        "Result-returning handler + error-propagating helper must be clean:\n{}",
        render(&report.violations)
    );
}

#[test]
fn lock_order_cycle_detected_through_the_call_graph() {
    let report = lint_tree("lock_cycle_bad");
    assert_eq!(
        report.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect::<Vec<_>>(),
        [(Rule::LockOrder, "locks/mod.rs", 26)],
        "expected exactly the propagated a->b / b->a cycle:\n{}",
        render(&report.violations)
    );
    let msg = &report.violations[0].message;
    assert!(msg.contains("Pair::a") && msg.contains("Pair::b"), "cycle names both classes: {msg}");
}

#[test]
fn consistent_lock_order_is_clean() {
    let report = lint_tree("lock_clean");
    assert!(
        report.violations.is_empty(),
        "consistent a-before-b ordering must be clean:\n{}",
        render(&report.violations)
    );
}

#[test]
fn rng_lineage_flags_aliased_keys_and_clone_forks() {
    let report = lint_tree("rng_alias_bad");
    assert_eq!(
        report.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect::<Vec<_>>(),
        [
            (Rule::RngLineage, "rollout/mod.rs", 17), // second stream from the same key
            (Rule::RngLineage, "rollout/mod.rs", 23), // `.clone()` fork
        ],
        "expected the aliased key and the clone fork:\n{}",
        render(&report.violations)
    );
    let dup = &report.violations[0].message;
    assert!(dup.contains("line 16"), "the duplicate cites the earlier site: {dup}");
    assert!(report.violations[1].message.contains("clone"), "{}", report.violations[1].message);
}

#[test]
fn branch_exclusive_streams_and_distinct_keys_are_clean() {
    // The same key on disjoint if/else branches never coexists on one
    // path — flow-sensitivity is what keeps this from flagging.
    let report = lint_tree("rng_alias_clean");
    assert!(
        report.violations.is_empty(),
        "branch-exclusive reuse and distinct keys must be clean:\n{}",
        render(&report.violations)
    );
}

#[test]
fn flush_on_error_catches_the_pack_loss_bug() {
    // The PR 7 shape: `step_cycle()?` inside the drive loop propagates
    // before the post-loop flush — rows from the aborted run are lost.
    let report = lint_tree("flush_bad");
    assert_eq!(
        report.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect::<Vec<_>>(),
        [(Rule::FlushOnError, "algo/mod.rs", 17)],
        "expected exactly the unflushed `?` exit:\n{}",
        render(&report.violations)
    );
    let msg = &report.violations[0].message;
    assert!(msg.contains("line 17") && msg.contains("flush_sinks"), "{msg}");
}

#[test]
fn flush_before_propagating_is_clean() {
    // Same loop, but the error arm flushes before returning: the
    // backward pass sees a flush on every path to the error exit.
    let report = lint_tree("flush_clean");
    assert!(
        report.violations.is_empty(),
        "flushing on the error path must satisfy flush-on-error:\n{}",
        render(&report.violations)
    );
}

#[test]
fn lock_across_forward_flags_direct_and_transitive_holds() {
    let report = lint_tree("lock_forward_bad");
    assert_eq!(
        report.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect::<Vec<_>>(),
        [
            (Rule::LockAcrossForward, "rollout/mod.rs", 18), // guard across forward_direct
            (Rule::LockAcrossForward, "rollout/mod.rs", 28), // …across helper -> forward_direct
        ],
        "expected the direct and the call-graph-transitive hold:\n{}",
        render(&report.violations)
    );
    let transitive = &report.violations[1].message;
    assert!(
        transitive.contains("via Engine::helper"),
        "the transitive finding shows its witness chain: {transitive}"
    );
}

#[test]
fn dropped_or_scoped_guards_are_clean() {
    // `drop(guard)` before the blocking call, or a guard confined to an
    // inner scope, must both satisfy lock-across-forward.
    let report = lint_tree("lock_forward_clean");
    assert!(
        report.violations.is_empty(),
        "released guards must not flag:\n{}",
        render(&report.violations)
    );
}

#[test]
fn trait_default_bodies_carry_taint() {
    // The wallclock read lives only in a trait *default* method body;
    // skipping default bodies would lose the whole finding.
    let report = lint_tree("trait_default_taint_bad");
    assert_eq!(
        report.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect::<Vec<_>>(),
        [(Rule::DetTaint, "util/mod.rs", 8)],
        "expected the default-body wallclock taint:\n{}",
        render(&report.violations)
    );
    let msg = &report.violations[0].message;
    assert!(msg.contains("Stamped::coarse_stamp"), "names the default method: {msg}");
    assert!(msg.contains("rollout_step"), "shows the deterministic root: {msg}");
}

#[test]
fn cache_roundtrip_preserves_flow_summaries() {
    // The lock-across-forward findings are recomputed from cached
    // per-fn summaries (`held_may_calls`), so a warm all-hits run over
    // the flow fixture must reproduce the cold report exactly.
    let cache =
        std::env::temp_dir().join(format!("ued-lint-flow-cache-{}.json", std::process::id()));
    let _ = fs::remove_file(&cache);
    let opts = LintOptions { semantic: true, cache_path: Some(cache.clone()) };
    let cold = lint_crate_with(&semantic_dir("lock_forward_bad"), &opts).expect("cold run");
    assert_eq!(cold.cache_hits, 0, "first run must be cold");
    let warm = lint_crate_with(&semantic_dir("lock_forward_bad"), &opts).expect("warm run");
    assert_eq!(warm.cache_hits, warm.files, "second run must be all cache hits");
    assert_eq!(
        render(&warm.violations),
        render(&cold.violations),
        "flow summaries must survive the cache roundtrip"
    );
    assert!(
        warm.violations.iter().any(|v| v.rule == Rule::LockAcrossForward),
        "the warm report still carries the flow findings:\n{}",
        render(&warm.violations)
    );
    let _ = fs::remove_file(&cache);
}

#[test]
fn benches_and_examples_trees_are_lint_clean() {
    // The default binary run also lints benches/ (wallclock-exempt — a
    // bench's whole job is timing) and the repo-level examples/.
    let crate_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let opts = LintOptions { semantic: true, cache_path: None };
    let benches = lint_tree_with(&crate_root.join("benches"), TreeKind::Bench, &opts)
        .expect("walking benches/");
    assert!(benches.files > 0, "expected bench sources");
    assert!(
        benches.violations.is_empty(),
        "benches/ must be clean under the bench profile:\n{}",
        render(&benches.violations)
    );
    let examples =
        lint_tree_with(&crate_root.join("../examples"), TreeKind::Example, &opts)
            .expect("walking examples/");
    assert!(examples.files > 0, "expected example sources");
    assert!(
        examples.violations.is_empty(),
        "examples/ must be clean under the default profile:\n{}",
        render(&examples.violations)
    );
}

#[test]
fn cache_roundtrip_preserves_the_report() {
    // Two runs over the same tree through one cache file: the second is
    // all hits and reports the identical violations (including the
    // semantic ones, which are recomputed from cached fn summaries).
    let cache = std::env::temp_dir().join(format!("ued-lint-cache-test-{}.json", std::process::id()));
    let _ = fs::remove_file(&cache);
    let opts = LintOptions { semantic: true, cache_path: Some(cache.clone()) };
    let cold = lint_crate_with(&semantic_dir("serve_panic_bad"), &opts).expect("cold run");
    assert_eq!(cold.cache_hits, 0, "first run must be cold");
    let warm = lint_crate_with(&semantic_dir("serve_panic_bad"), &opts).expect("warm run");
    assert_eq!(warm.cache_hits, warm.files, "second run must be all cache hits");
    assert_eq!(warm.files, cold.files);
    assert_eq!(
        render(&warm.violations),
        render(&cold.violations),
        "cached and cold reports must be identical"
    );
    let _ = fs::remove_file(&cache);
}

#[test]
fn real_crate_is_lint_clean() {
    // The full pass — per-file rules, the flow analyses (rng-lineage,
    // flush-on-error), AND the call-graph analyses (det-taint,
    // serve-panic, lock-order, lock-across-forward) — over the crate's
    // own src/.
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_crate(&src).expect("walking src/");
    assert!(report.files > 10, "expected to visit the whole crate, saw {} files", report.files);
    assert!(
        report.violations.is_empty(),
        "the crate's own source must be ued-lint clean; {} violation(s):\n{}",
        report.violations.len(),
        render(&report.violations)
    );
}
