//! Determinism suite for the pipelined rollout engine — artifact-free
//! (pure-Rust stand-in policy), so it runs everywhere the env layer runs,
//! including the CI fallback path without `make artifacts`.
//!
//! Pins the refactor's safety-net invariants:
//!
//! 1. `collect` produces bit-identical trajectories and episode stats at
//!    `--rollout-threads 1` vs 4, on both registered env families;
//! 2. the fused multi-driver collect schedule (forward outside pool
//!    phases, writeback folded into the step phase) is bit-identical to
//!    the default overlapped schedule;
//! 3. the work-queue evaluator reproduces the legacy chunked evaluator's
//!    per-level solve rates exactly under a fixed seed, at any thread
//!    count, while issuing no more device forward passes.

use std::sync::Arc;

use jaxued::env::wrappers::AutoReplayWrapper;
use jaxued::env::{EnvFamily, EnvParams, LavaFamily, LevelGenerator, MazeFamily, UnderspecifiedEnv};
use jaxued::eval::{EvalMode, EvalReport, Evaluator};
use jaxued::rollout::{EpisodeStats, RolloutEngine, SyntheticPolicy, Trajectory, WorkerPool};
use jaxued::util::rng::Pcg64;

const B: usize = 8;
const T: usize = 32;

fn collect_rollout_scheduled<F: EnvFamily>(
    family: F, threads: usize, multi_driver: bool,
) -> (Trajectory, Vec<EpisodeStats>) {
    let params = EnvParams::default();
    let env = AutoReplayWrapper::new(family.make_env(&params));
    let gen = family.make_generator(&params);
    let mut rng = Pcg64::new(42, 7);
    let levels = gen.sample_batch(B, &mut rng);
    let mut states: Vec<_> = levels
        .iter()
        .map(|l| env.reset_to_level(l, &mut rng))
        .collect();
    let pool = Arc::new(WorkerPool::new(threads));
    pool.set_multi_driver(multi_driver);
    let mut engine = RolloutEngine::with_pool(&env, B, pool);
    let mut traj = Trajectory::new(T, B, &env.obs_components());
    let policy = SyntheticPolicy { num_actions: env.num_actions() };
    engine
        .collect(&env, &mut states, &policy, &mut traj, &mut rng)
        .unwrap();
    let stats = traj.episode_stats();
    (traj, stats)
}

fn collect_rollout<F: EnvFamily>(family: F, threads: usize) -> (Trajectory, Vec<EpisodeStats>) {
    collect_rollout_scheduled(family, threads, false)
}

fn assert_traj_equal(a: &Trajectory, b: &Trajectory, label: &str) {
    for (k, (oa, ob)) in a.obs.iter().zip(&b.obs).enumerate() {
        assert_eq!(oa.data(), ob.data(), "[{label}] obs component {k} differs");
    }
    assert_eq!(a.actions.data(), b.actions.data(), "[{label}] actions differ");
    assert_eq!(a.logp.data(), b.logp.data(), "[{label}] logp differs");
    assert_eq!(a.values.data(), b.values.data(), "[{label}] values differ");
    assert_eq!(a.rewards.data(), b.rewards.data(), "[{label}] rewards differ");
    assert_eq!(a.dones.data(), b.dones.data(), "[{label}] dones differ");
    assert_eq!(
        a.last_value.data(),
        b.last_value.data(),
        "[{label}] last_value differs"
    );
}

fn check_collect_thread_invariant<F: EnvFamily>(family: F) {
    let id = family.id();
    let (t1, s1) = collect_rollout(family, 1);
    let (t4, s4) = collect_rollout(family, 4);
    assert_traj_equal(&t1, &t4, id);
    assert_eq!(s1, s4, "[{id}] episode stats differ across thread counts");
    // sanity: the rollout actually did something
    let total_eps: u32 = s1.iter().map(|s| s.episodes).sum();
    assert!(t1.dones.data().iter().any(|&d| d > 0.5) == (total_eps > 0));
}

#[test]
fn collect_is_thread_invariant_maze() {
    check_collect_thread_invariant(MazeFamily);
}

#[test]
fn collect_is_thread_invariant_lava() {
    check_collect_thread_invariant(LavaFamily);
}

fn check_fused_schedule_matches_overlapped<F: EnvFamily>(family: F) {
    let id = family.id();
    let (base, sbase) = collect_rollout_scheduled(family, 1, false);
    for (threads, multi) in [(1, true), (4, true)] {
        let (t, s) = collect_rollout_scheduled(family, threads, multi);
        assert_traj_equal(&base, &t, &format!("{id} fused t{threads}"));
        assert_eq!(sbase, s, "[{id}] fused episode stats differ at t{threads}");
    }
}

#[test]
fn fused_schedule_matches_overlapped_maze() {
    check_fused_schedule_matches_overlapped(MazeFamily);
}

#[test]
fn fused_schedule_matches_overlapped_lava() {
    check_fused_schedule_matches_overlapped(LavaFamily);
}

fn eval_report<F: EnvFamily>(family: F, mode: EvalMode, threads: usize) -> EvalReport {
    let params = EnvParams::default();
    let env = family.make_env(&params);
    let levels = family.holdout(4);
    let policy = SyntheticPolicy { num_actions: env.num_actions() };
    let pool = Arc::new(WorkerPool::new(threads));
    // short step cap keeps the random-ish policy's episodes cheap
    let ev = Evaluator::with_pool(env, levels, 3, B, 60, pool);
    let mut rng = Pcg64::new(7, 1);
    ev.run_with_mode(mode, &policy, &mut rng).unwrap()
}

fn assert_reports_equal(a: &EvalReport, b: &EvalReport, label: &str) {
    assert_eq!(a.levels.len(), b.levels.len());
    for (la, lb) in a.levels.iter().zip(&b.levels) {
        assert_eq!(la.name, lb.name, "[{label}] level order differs");
        assert_eq!(
            la.solve_rate, lb.solve_rate,
            "[{label}] solve rate differs on {}", la.name
        );
        assert_eq!(
            la.mean_steps, lb.mean_steps,
            "[{label}] mean steps differs on {}", la.name
        );
    }
    assert_eq!(a.mean_solve_rate, b.mean_solve_rate, "[{label}] mean differs");
    assert_eq!(a.iqm_solve_rate, b.iqm_solve_rate, "[{label}] iqm differs");
}

fn check_eval_modes_agree<F: EnvFamily>(family: F) {
    let id = family.id();
    let q1 = eval_report(family, EvalMode::WorkQueue, 1);
    let q4 = eval_report(family, EvalMode::WorkQueue, 4);
    let c1 = eval_report(family, EvalMode::Chunked, 1);
    let c4 = eval_report(family, EvalMode::Chunked, 4);
    assert_reports_equal(&q1, &q4, &format!("{id} queue 1v4"));
    assert_reports_equal(&c1, &c4, &format!("{id} chunked 1v4"));
    assert_reports_equal(&q1, &c1, &format!("{id} queue-vs-chunked"));
    // the whole point of the work-queue: no more forwards than the
    // padded-chunk reference, on any suite
    assert!(
        q1.forward_passes <= c1.forward_passes,
        "[{id}] queue used {} forwards, chunked {}",
        q1.forward_passes,
        c1.forward_passes
    );
    assert!(q1.forward_passes > 0);
}

#[test]
fn eval_modes_agree_maze() {
    check_eval_modes_agree(MazeFamily);
}

#[test]
fn eval_modes_agree_lava() {
    check_eval_modes_agree(LavaFamily);
}

#[test]
fn work_queue_handles_fewer_episodes_than_columns() {
    // n_episodes < B exercises the dead-pad slots
    let params = EnvParams::default();
    let env = MazeFamily.make_env(&params);
    let mut levels = MazeFamily.holdout(0);
    levels.truncate(3);
    let policy = SyntheticPolicy { num_actions: env.num_actions() };
    let ev = Evaluator::new(env, levels, 1, B, 40);
    let mut rng = Pcg64::new(11, 2);
    let queue = ev.run_with_mode(EvalMode::WorkQueue, &policy, &mut rng).unwrap();
    let mut rng = Pcg64::new(11, 2);
    let chunked = ev.run_with_mode(EvalMode::Chunked, &policy, &mut rng).unwrap();
    assert_reports_equal(&queue, &chunked, "tiny-suite");
    assert_eq!(queue.levels.len(), 3);
}
