//! Microbenchmarks: PJRT artifact call latencies (the true hot path).
//!
//! One update cycle = T `policy_apply` calls + 1 `train_step` (+1 `score`
//! for the PLR family), so apply latency × T bounds rollout speed. §Perf
//! tracks these numbers before/after optimization.

use std::path::Path;
use std::time::Instant;

use jaxued::runtime::Runtime;
use jaxued::util::cli::Args;
use jaxued::util::tensor::{TensorF32, TensorI32};

fn bench<F: FnMut() -> anyhow::Result<u64>>(name: &str, mut f: F) -> anyhow::Result<()> {
    f()?;
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let ops = f()?;
        best = best.min(t0.elapsed().as_secs_f64() / ops as f64);
    }
    let (scaled, unit) = if best < 1e-3 {
        (best * 1e6, "µs")
    } else {
        (best * 1e3, "ms")
    };
    println!("{name:<42} {scaled:>10.1} {unit}/call");
    Ok(())
}

fn zeros_f32(shape: &[usize]) -> xla::Literal {
    TensorF32::zeros(shape).to_literal().unwrap()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let rt = Runtime::new(Path::new(&args.get_str("artifacts", "artifacts")))?;
    println!("=== micro_runtime: PJRT call latencies (CPU client) ===");

    for (variant, t, b) in [("small", 32usize, 8usize), ("std", 256, 32)] {
        let params = rt.init_params("student", 0)?;
        // --- policy apply -----------------------------------------------------
        let apply = rt.load(&format!("student_apply_b{b}"))?;
        let obs_img = zeros_f32(&[b, 5, 5, 3]);
        let obs_dir = zeros_f32(&[b, 4]);
        let mut apply_args: Vec<xla::Literal> = params.params.clone();
        apply_args.push(obs_img);
        apply_args.push(obs_dir);
        bench(&format!("[{variant}] student_apply (B={b})"), || {
            let n = 200u64;
            for _ in 0..n {
                std::hint::black_box(apply.call(&apply_args)?);
            }
            Ok(n)
        })?;

        // --- train step -------------------------------------------------------
        let ts = rt.load(&format!("student_train_step_t{t}_b{b}"))?;
        let mut ts_args = params.train_args();
        ts_args.push(xla::Literal::scalar(1e-4f32));
        ts_args.push(zeros_f32(&[t, b, 5, 5, 3]));
        ts_args.push(zeros_f32(&[t, b, 4]));
        ts_args.push(TensorI32::zeros(&[t, b]).to_literal()?);
        for _ in 0..4 {
            ts_args.push(zeros_f32(&[t, b]));
        }
        ts_args.push(zeros_f32(&[b]));
        bench(&format!("[{variant}] student_train_step (T={t},B={b})"), || {
            let n = 10u64;
            for _ in 0..n {
                std::hint::black_box(ts.call(&ts_args)?);
            }
            Ok(n)
        })?;

        // --- score ------------------------------------------------------------
        let score = rt.load(&format!("score_t{t}_b{b}"))?;
        let score_args = vec![
            zeros_f32(&[t, b]),
            zeros_f32(&[t, b]),
            zeros_f32(&[t, b]),
            zeros_f32(&[b]),
            zeros_f32(&[b]),
        ];
        bench(&format!("[{variant}] score (T={t},B={b})"), || {
            let n = 50u64;
            for _ in 0..n {
                std::hint::black_box(score.call(&score_args)?);
            }
            Ok(n)
        })?;
    }

    // --- adversary (PAIRED bottleneck) ----------------------------------------
    let adv_params = rt.init_params("adversary", 0)?;
    let adv_apply = rt.load("adversary_apply_b32")?;
    let mut adv_args: Vec<xla::Literal> = adv_params.params.clone();
    adv_args.push(zeros_f32(&[32, 13, 13, 3]));
    adv_args.push(zeros_f32(&[32, 1]));
    adv_args.push(zeros_f32(&[32, 16]));
    bench("[std] adversary_apply (B=32)", || {
        let n = 50u64;
        for _ in 0..n {
            std::hint::black_box(adv_apply.call(&adv_args)?);
        }
        Ok(n)
    })?;

    let (t_adv, b) = (60usize, 32usize);
    let adv_ts = rt.load(&format!("adversary_train_step_t{t_adv}_b{b}"))?;
    let mut args2 = adv_params.train_args();
    args2.push(xla::Literal::scalar(1e-4f32));
    args2.push(zeros_f32(&[t_adv, b, 13, 13, 3]));
    args2.push(zeros_f32(&[t_adv, b, 1]));
    args2.push(zeros_f32(&[t_adv, b, 16]));
    args2.push(TensorI32::zeros(&[t_adv, b]).to_literal()?);
    for _ in 0..4 {
        args2.push(zeros_f32(&[t_adv, b]));
    }
    args2.push(zeros_f32(&[b]));
    bench("[std] adversary_train_step (T=60,B=32)", || {
        let n = 3u64;
        for _ in 0..n {
            std::hint::black_box(adv_ts.call(&args2)?);
        }
        Ok(n)
    })?;

    Ok(())
}
