//! Microbenchmarks: the LevelSampler at the paper's buffer size (K=4000).
//!
//! Replay sampling is O(K) per draw batch (weight construction dominates);
//! with one batch per update cycle the budget is generous, but the §Perf
//! pass tracks it because rank prioritization sorts the whole buffer.

use std::time::Instant;

use jaxued::env::gen::MazeLevelGenerator;
use jaxued::env::level::Level;
use jaxued::level_sampler::{LevelSampler, SamplerConfig};
use jaxued::util::rng::Pcg64;

fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let ops = f();
        best = best.min(t0.elapsed().as_secs_f64() / ops as f64);
    }
    let (scaled, unit) = if best < 1e-6 {
        (best * 1e9, "ns")
    } else if best < 1e-3 {
        (best * 1e6, "µs")
    } else {
        (best * 1e3, "ms")
    };
    println!("{name:<40} {scaled:>9.2} {unit}/op ({:>12.0} ops/s)", 1.0 / best);
}

fn full_sampler(levels: &[Level]) -> LevelSampler<Level, f32> {
    let mut s = LevelSampler::new(SamplerConfig { capacity: 4000, ..Default::default() });
    let mut rng = Pcg64::seed_from_u64(9);
    for (i, l) in levels.iter().enumerate() {
        s.insert(*l, rng.next_f64(), l.fingerprint() ^ i as u64, 0.0);
    }
    s
}

fn main() {
    let mut rng = Pcg64::seed_from_u64(0);
    let gen = MazeLevelGenerator::new(60);
    let levels = gen.generate_batch(4000, &mut rng);

    println!("=== micro_sampler: LevelSampler (K=4000, rank prioritization) ===");

    bench("insert into full buffer (evicting)", || {
        let mut s = full_sampler(&levels);
        let mut rng = Pcg64::seed_from_u64(1);
        let n = 50_000u64;
        for i in 0..n {
            let l = &levels[(i % 4000) as usize];
            s.insert(*l, 0.5 + rng.next_f64(), rng.next_u64(), 0.0);
        }
        n
    });

    bench("sample replay batch of 32", || {
        let mut s = full_sampler(&levels);
        let mut rng = Pcg64::seed_from_u64(2);
        let n = 2_000u64;
        for _ in 0..n {
            std::hint::black_box(s.sample_replay_indices(32, &mut rng));
        }
        n
    });

    bench("update batch of 32 scores", || {
        let mut s = full_sampler(&levels);
        let idx: Vec<usize> = (0..32).collect();
        let scores = vec![0.7f64; 32];
        let extras = vec![0.0f32; 32];
        let n = 200_000u64;
        for _ in 0..n {
            s.update_batch(&idx, &scores, &extras);
        }
        n
    });

    bench("replay distribution (full K)", || {
        let s = full_sampler(&levels);
        let n = 2_000u64;
        for _ in 0..n {
            std::hint::black_box(s.replay_distribution());
        }
        n
    });
}
