//! Microbenchmarks: the L3 environment substrate hot paths.
//!
//! jaxued's training loop budget is dominated by PJRT calls; these benches
//! verify the Rust env layer stays far off the critical path (§Perf target:
//! < 1 µs per env step+observe).

use std::time::Instant;

use jaxued::env::gen::LevelGenerator;
use jaxued::env::level::Level;
use jaxued::env::maze::{MazeEnv, ACT_FORWARD, ACT_LEFT, ACT_RIGHT};
use jaxued::env::mutate::Mutator;
use jaxued::env::render::render_level;
use jaxued::env::shortest_path::distance_field;
use jaxued::env::UnderspecifiedEnv;
use jaxued::util::rng::Pcg64;

fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    // warmup
    f();
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let ops = f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt / ops as f64);
    }
    let (scaled, unit) = if best < 1e-6 {
        (best * 1e9, "ns")
    } else if best < 1e-3 {
        (best * 1e6, "µs")
    } else {
        (best * 1e3, "ms")
    };
    println!("{name:<32} {scaled:>9.1} {unit}/op   ({:>12.0} ops/s)", 1.0 / best);
}

fn main() {
    let mut rng = Pcg64::seed_from_u64(0);
    let gen = LevelGenerator::new(60);
    let env = MazeEnv::default();
    let levels: Vec<Level> = gen.generate_batch(64, &mut rng);

    println!("=== micro_env: L3 substrate hot paths ===");

    bench("maze step+observe", || {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut obs = vec![0.0f32; env.obs_len()];
        let mut state = env.reset_to_level(&levels[0], &mut rng);
        let n = 1_000_000u64;
        let actions = [ACT_LEFT, ACT_RIGHT, ACT_FORWARD];
        for i in 0..n {
            let r = env.step(&mut state, actions[(i % 3) as usize], &mut rng);
            env.observe(&state, &mut obs);
            if r.done {
                state = env.reset_to_level(&levels[(i % 64) as usize], &mut rng);
            }
        }
        n
    });

    bench("maze step only", || {
        let mut rng = Pcg64::seed_from_u64(2);
        let mut state = env.reset_to_level(&levels[1], &mut rng);
        let n = 4_000_000u64;
        for i in 0..n {
            let r = env.step(&mut state, (i % 3) as usize, &mut rng);
            if r.done {
                state = env.reset_to_level(&levels[(i % 64) as usize], &mut rng);
            }
        }
        n
    });

    bench("level generation (60 walls)", || {
        let mut rng = Pcg64::seed_from_u64(3);
        let n = 200_000u64;
        for _ in 0..n {
            std::hint::black_box(gen.generate(&mut rng));
        }
        n
    });

    bench("ACCEL mutation (20 edits)", || {
        let mut rng = Pcg64::seed_from_u64(4);
        let m = Mutator::default();
        let n = 200_000u64;
        for i in 0..n {
            std::hint::black_box(m.mutate(&levels[(i % 64) as usize], &mut rng));
        }
        n
    });

    bench("BFS distance field", || {
        let n = 200_000u64;
        for i in 0..n {
            std::hint::black_box(distance_field(&levels[(i % 64) as usize]));
        }
        n
    });

    bench("level fingerprint", || {
        let n = 2_000_000u64;
        for i in 0..n {
            std::hint::black_box(levels[(i % 64) as usize].fingerprint());
        }
        n
    });

    bench("render level (104x104 px)", || {
        let n = 20_000u64;
        for i in 0..n {
            std::hint::black_box(render_level(&levels[(i % 64) as usize], None));
        }
        n
    });
}
