//! Microbenchmarks: the L3 environment substrate hot paths, for *every*
//! registered env family.
//!
//! jaxued's training loop budget is dominated by PJRT calls; these benches
//! verify the Rust env layer stays far off the critical path (§Perf target:
//! < 1 µs per env step+observe). Both the maze and the lava grid are
//! measured so per-env step/reset/generate/mutate cost is tracked from the
//! moment a family lands.

use std::time::Instant;

use jaxued::env::gen::MazeLevelGenerator;
use jaxued::env::level::Level;
use jaxued::env::render::render_level;
use jaxued::env::shortest_path::distance_field;
use jaxued::env::{
    EnvFamily, EnvParams, LavaFamily, LevelGenerator, LevelMeta, LevelMutator,
    MazeFamily, UnderspecifiedEnv,
};
use jaxued::util::rng::Pcg64;

fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    // warmup
    f();
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let ops = f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt / ops as f64);
    }
    let (scaled, unit) = if best < 1e-6 {
        (best * 1e9, "ns")
    } else if best < 1e-3 {
        (best * 1e6, "µs")
    } else {
        (best * 1e3, "ms")
    };
    println!("{name:<32} {scaled:>9.1} {unit}/op   ({:>12.0} ops/s)", 1.0 / best);
}

/// The family-generic hot-path suite: step+observe, step, generate,
/// mutate, fingerprint — identical code for every registered env.
fn bench_family<F: EnvFamily>(family: F) {
    let id = family.id();
    let params = EnvParams::default();
    let env = family.make_env(&params);
    let gen = family.make_generator(&params);
    let mutator = family.make_mutator(&params);
    let mut rng = Pcg64::seed_from_u64(0);
    let levels: Vec<F::Level> = gen.sample_batch(64, &mut rng);
    let actions = env.num_actions();

    println!("--- family: {id} ---");

    bench(&format!("[{id}] step+observe"), || {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut obs = vec![0.0f32; env.obs_len()];
        let mut state = env.reset_to_level(&levels[0], &mut rng);
        let n = 1_000_000u64;
        for i in 0..n {
            let r = env.step(&mut state, (i % actions as u64) as usize, &mut rng);
            env.observe(&state, &mut obs);
            if r.done {
                state = env.reset_to_level(&levels[(i % 64) as usize], &mut rng);
            }
        }
        n
    });

    bench(&format!("[{id}] step only"), || {
        let mut rng = Pcg64::seed_from_u64(2);
        let mut state = env.reset_to_level(&levels[1], &mut rng);
        let n = 4_000_000u64;
        for i in 0..n {
            let r = env.step(&mut state, (i % actions as u64) as usize, &mut rng);
            if r.done {
                state = env.reset_to_level(&levels[(i % 64) as usize], &mut rng);
            }
        }
        n
    });

    bench(&format!("[{id}] level generation"), || {
        let mut rng = Pcg64::seed_from_u64(3);
        let n = 200_000u64;
        for _ in 0..n {
            std::hint::black_box(gen.sample_level(&mut rng));
        }
        n
    });

    bench(&format!("[{id}] mutation (20 edits)"), || {
        let mut rng = Pcg64::seed_from_u64(4);
        let n = 200_000u64;
        for i in 0..n {
            std::hint::black_box(mutator.mutate_level(&levels[(i % 64) as usize], &mut rng));
        }
        n
    });

    bench(&format!("[{id}] fingerprint"), || {
        let n = 2_000_000u64;
        for i in 0..n {
            std::hint::black_box(levels[(i % 64) as usize].fingerprint());
        }
        n
    });

    bench(&format!("[{id}] solvability check"), || {
        let n = 200_000u64;
        for i in 0..n {
            std::hint::black_box(levels[(i % 64) as usize].is_solvable());
        }
        n
    });
}

fn main() {
    println!("=== micro_env: L3 substrate hot paths ===");

    // Family-generic suite over every registered env.
    bench_family(MazeFamily);
    bench_family(LavaFamily);

    // Maze-specific extras (tools the family-generic suite can't cover).
    let mut rng = Pcg64::seed_from_u64(0);
    let gen = MazeLevelGenerator::new(60);
    let levels: Vec<Level> = gen.generate_batch(64, &mut rng);

    println!("--- maze extras ---");

    bench("BFS distance field", || {
        let n = 200_000u64;
        for i in 0..n {
            std::hint::black_box(distance_field(&levels[(i % 64) as usize]));
        }
        n
    });

    bench("render level (104x104 px)", || {
        let n = 20_000u64;
        for i in 0..n {
            std::hint::black_box(render_level(&levels[(i % 64) as usize], None));
        }
        n
    });
}
