//! Table 1 — total wallclock per algorithm.
//!
//! Times a fixed number of update cycles per algorithm on this machine and
//! extrapolates to the paper's full 245,760,000-env-step budget. The `dcd`
//! row is quoted from the paper (Jiang et al. 2023 measurements) as the
//! CPU-era baseline anchor; we reproduce the *shape* (JaxUED ≫ DCD, and the
//! relative ordering among JaxUED algorithms), not A40 absolutes — see
//! DESIGN.md §Hardware-Adaptation.
//!
//! Flags: --cycles N (default 12) --variant std|small --algos dr,plr,…

use std::path::Path;

use jaxued::algo::build_algo;
use jaxued::config::{Algo, TrainConfig, Variant};
use jaxued::metrics::Stopwatch;
use jaxued::runtime::Runtime;
use jaxued::util::cli::Args;
use jaxued::util::rng::Pcg64;

const PAPER_BUDGET: u64 = 245_760_000;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let cycles = args.get_usize("cycles", 4);
    let variant = Variant::parse(&args.get_str("variant", "std"))?;
    let algo_list = args.get_str("algos", "dr,plr,robust_plr,accel,paired");
    let rt = Runtime::new(Path::new(&args.get_str("artifacts", "artifacts")))?;

    println!("=== Table 1: wallclock time (hours) for {PAPER_BUDGET} env steps ===");
    println!("(measured over {cycles} update cycles, variant {})\n", variant.name);

    // Paper rows, for side-by-side comparison.
    let paper_dcd = [("DR", 63.0), ("PLR", f64::NAN), ("PLR⊥", 119.0), ("ACCEL", 104.0), ("PAIRED", 213.0)];
    let paper_jaxued = [("DR", 1.5), ("PLR", 1.5), ("PLR⊥", 1.0), ("ACCEL", 1.0), ("PAIRED", 1.7)];

    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for name in algo_list.split(',') {
        let algo = Algo::parse(name)?;
        let mut cfg = TrainConfig::defaults(algo);
        cfg.variant = variant;
        cfg.env_steps_budget = (cycles as u64) * cfg.env_steps_per_cycle();
        cfg.eval_interval = 0;
        let mut rng = Pcg64::new(1234, 0x5431); // fixed bench seed
        let mut driver = build_algo(&rt, &cfg, &mut rng)?;
        // one warmup cycle (compilation, caches)
        driver.cycle(&mut rng)?;
        let mut watch = Stopwatch::new();
        for _ in 0..cycles {
            driver.cycle(&mut rng)?;
            watch.add_steps(cfg.env_steps_per_cycle());
        }
        let hours = watch.extrapolate_hours(PAPER_BUDGET);
        rows.push((name.to_string(), watch.steps_per_sec(), hours));
        println!(
            "  {:<12} {:>10.0} env-steps/s  -> {:>8.2} h per 245.76M steps",
            name, watch.steps_per_sec(), hours
        );
    }

    println!("\n{:<28}{:>8}{:>8}{:>8}{:>8}{:>8}", "", "DR", "PLR", "PLR⊥", "ACCEL", "PAIRED");
    print!("{:<28}", "dcd (paper, A40+CPU impl)");
    for (_, h) in paper_dcd {
        print!("{:>8}", if h.is_nan() { "-".into() } else { format!("{h:.0}") });
    }
    print!("\n{:<28}", "JaxUED (paper, A40)");
    for (_, h) in paper_jaxued {
        print!("{:>8.1}", h);
    }
    print!("\n{:<28}", "this repo (CPU PJRT)");
    for name in ["dr", "plr", "robust_plr", "accel", "paired"] {
        match rows.iter().find(|(n, _, _)| n == name) {
            Some((_, _, h)) => print!("{:>8.1}", h),
            None => print!("{:>8}", "-"),
        }
    }
    println!();
    println!("\nshape check: every row of this repo must be far below the dcd row;");
    println!("PAIRED is the most expensive JaxUED method (adversary network).");
    Ok(())
}
