//! End-to-end rollout/eval throughput: collect + evaluation steps/sec for
//! both rollout variants at 1 vs N threads, the work-queue vs
//! padded-chunk forward-pass comparison, and seed-pack throughput at
//! `--drivers 1` vs N (the driver-thread overlap win) — the BENCH perf
//! trajectory. Emits `BENCH_rollout.json` at the repo root.
//!
//! The policy is a synthetic host-side stand-in (fixed linear map), so
//! the numbers isolate the host rollout path this engine parallelizes:
//! observe/staging, action sampling, env stepping, trajectory writeback,
//! and batch scheduling. PJRT device-call latencies are tracked
//! separately by `micro_runtime`.

use std::sync::Arc;
use std::time::Instant;

use jaxued::algo::orchestrator::{run_pack, SeedUnit, PACK_AGGREGATE_METRICS};
use jaxued::algo::CycleMetrics;
use jaxued::env::wrappers::AutoReplayWrapper;
use jaxued::env::{EnvFamily, EnvParams, LevelGenerator, MazeFamily, UnderspecifiedEnv};
use jaxued::eval::{EvalMode, Evaluator};
use jaxued::metrics::CrossSeedSink;
use jaxued::rollout::{auto_threads, RolloutEngine, SyntheticPolicy, Trajectory, WorkerPool};
use jaxued::util::cli::Args;
use jaxued::util::rng::Pcg64;

struct Row {
    variant: &'static str,
    threads: usize,
    collect_sps: f64,
    eval_queue_sps: f64,
    eval_chunked_sps: f64,
    forwards_queue: u64,
    forwards_chunked: u64,
}

fn bench_collect(t: usize, b: usize, threads: usize, iters: usize) -> f64 {
    let params = EnvParams::default();
    let env = AutoReplayWrapper::new(MazeFamily.make_env(&params));
    let gen = MazeFamily.make_generator(&params);
    let mut rng = Pcg64::new(0xBE, 0);
    let levels = gen.sample_batch(b, &mut rng);
    let mut states: Vec<_> = levels
        .iter()
        .map(|l| env.reset_to_level(l, &mut rng))
        .collect();
    let pool = Arc::new(WorkerPool::new(threads));
    let mut engine = RolloutEngine::with_pool(&env, b, pool);
    let mut traj = Trajectory::new(t, b, &env.obs_components());
    let policy = SyntheticPolicy { num_actions: env.num_actions() };
    // warmup
    engine
        .collect(&env, &mut states, &policy, &mut traj, &mut rng)
        .unwrap();
    let t0 = Instant::now();
    for _ in 0..iters {
        engine
            .collect(&env, &mut states, &policy, &mut traj, &mut rng)
            .unwrap();
    }
    (t * b * iters) as f64 / t0.elapsed().as_secs_f64()
}

const PACK_T: usize = 32;
const PACK_B: usize = 8;

/// A collect-only seed unit for the pack bench: same engine/pool path as
/// `TrainSeedRun`'s rollout, with the PPO/PJRT layer substituted.
struct PackUnit {
    seed: u64,
    rng: Pcg64,
    env: AutoReplayWrapper<<MazeFamily as EnvFamily>::Env>,
    gen: <MazeFamily as EnvFamily>::Generator,
    engine: RolloutEngine,
    traj: Trajectory,
    policy: SyntheticPolicy,
    cycle: usize,
    total: usize,
}

impl PackUnit {
    fn new(seed: u64, total: usize, pool: Arc<WorkerPool>) -> PackUnit {
        let params = EnvParams::default();
        let env = AutoReplayWrapper::new(MazeFamily.make_env(&params));
        let gen = MazeFamily.make_generator(&params);
        let engine = RolloutEngine::with_pool(&env, PACK_B, pool);
        let traj = Trajectory::new(PACK_T, PACK_B, &env.obs_components());
        let policy = SyntheticPolicy { num_actions: env.num_actions() };
        PackUnit {
            seed,
            rng: Pcg64::new(seed, 0x7261_696e),
            env,
            gen,
            engine,
            traj,
            policy,
            cycle: 0,
            total,
        }
    }
}

impl SeedUnit for PackUnit {
    fn seed(&self) -> u64 {
        self.seed
    }

    fn total_cycles(&self) -> usize {
        self.total
    }

    fn env_steps(&self) -> u64 {
        (self.cycle * PACK_T * PACK_B) as u64
    }

    fn step_cycle(&mut self) -> anyhow::Result<CycleMetrics> {
        let levels = self.gen.sample_batch(PACK_B, &mut self.rng);
        let mut states: Vec<_> = levels
            .iter()
            .map(|l| self.env.reset_to_level(l, &mut self.rng))
            .collect();
        self.engine
            .collect(&self.env, &mut states, &self.policy, &mut self.traj, &mut self.rng)?;
        let stats = self.traj.episode_stats();
        self.cycle += 1;
        Ok(CycleMetrics::from_rollout("bench", None, &stats, 0.0))
    }
}

/// Steps/sec for a seed pack run through the real orchestrator core at a
/// given driver count (multi-driver packs flip the pool to the fused
/// schedule, exactly as `train_pack_family` does).
fn bench_pack(seeds: usize, threads: usize, drivers: usize, cycles: usize) -> f64 {
    let pool = Arc::new(WorkerPool::new(threads));
    pool.set_multi_driver(drivers > 1);
    let mut units: Vec<PackUnit> = (0..seeds as u64)
        .map(|s| PackUnit::new(s, cycles, pool.clone()))
        .collect();
    let dir = std::env::temp_dir().join(format!("jaxued_bench_pack_t{threads}_d{drivers}"));
    std::fs::create_dir_all(&dir).unwrap();
    let mut aggregate =
        CrossSeedSink::create(&dir.join("aggregate.csv"), PACK_AGGREGATE_METRICS, seeds)
            .unwrap();
    // warmup pass (first collect per unit pays allocation/faulting costs)
    for u in units.iter_mut() {
        u.step_cycle().unwrap();
        u.cycle = 0;
    }
    let t0 = Instant::now();
    run_pack(&mut units, &mut aggregate, drivers).unwrap();
    (seeds * cycles * PACK_T * PACK_B) as f64 / t0.elapsed().as_secs_f64()
}

/// (steps/sec, forward passes) for one evaluation pass of the standard
/// holdout suite (named + 12 procedural levels, 3 trials).
fn bench_eval(b: usize, threads: usize, mode: EvalMode, reps: usize) -> (f64, u64) {
    let params = EnvParams::default();
    let env = MazeFamily.make_env(&params);
    let levels = MazeFamily.holdout(12);
    let policy = SyntheticPolicy { num_actions: env.num_actions() };
    let pool = Arc::new(WorkerPool::new(threads));
    let ev = Evaluator::with_pool(env, levels, 3, b, params.max_episode_steps, pool);
    let mut rng = Pcg64::new(0xEA, 1);
    // warmup + forward-pass count
    let warm = ev.run_with_mode(mode, &policy, &mut rng).unwrap();
    let forwards = warm.forward_passes;
    let mut steps = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut rng = Pcg64::new(0xEA, 1);
        let r = ev.run_with_mode(mode, &policy, &mut rng).unwrap();
        steps += r
            .levels
            .iter()
            .map(|l| (l.mean_steps * ev.trials as f64) as u64)
            .sum::<u64>();
    }
    (steps as f64 / t0.elapsed().as_secs_f64(), forwards)
}

fn main() {
    // Benchmarks must never measure the debug-only ColumnAccess race
    // detector; benches build with the release profile, where the
    // per-element claim map is compiled out entirely.
    assert!(
        !jaxued::rollout::race_detector_enabled(),
        "bench_rollout built with the race detector enabled (debug profile?) — \
         numbers would include per-access atomics; build with --release"
    );
    #[cfg(not(debug_assertions))]
    {
        use jaxued::rollout::actors::ColumnAccess;
        // The accessor must be back to exactly (ptr, len) — no claim map.
        assert_eq!(
            std::mem::size_of::<ColumnAccess<'static, f32>>(),
            std::mem::size_of::<*mut f32>() + std::mem::size_of::<usize>(),
            "release ColumnAccess carries detector state"
        );
    }
    let args = Args::parse();
    let iters = args.get_usize("iters", 8);
    let reps = args.get_usize("reps", 2);
    assert!(iters > 0 && reps > 0, "--iters and --reps must be positive");
    let n_threads = auto_threads();
    let thread_settings: Vec<usize> =
        if n_threads > 1 { vec![1, n_threads] } else { vec![1] };

    println!("=== bench_rollout: host rollout/eval throughput (synthetic policy) ===");
    let mut rows = Vec::new();
    for &(variant, t, b) in &[("std", 256usize, 32usize), ("small", 32, 8)] {
        for &threads in &thread_settings {
            let collect_sps = bench_collect(t, b, threads, iters);
            let (q_sps, q_fwd) = bench_eval(b, threads, EvalMode::WorkQueue, reps);
            let (c_sps, c_fwd) = bench_eval(b, threads, EvalMode::Chunked, reps);
            println!(
                "[{variant:<5} threads={threads:>2}] collect {collect_sps:>12.0} steps/s | \
                 eval queue {q_sps:>11.0} steps/s ({q_fwd} fwd) | \
                 eval chunked {c_sps:>11.0} steps/s ({c_fwd} fwd)"
            );
            rows.push(Row {
                variant,
                threads,
                collect_sps,
                eval_queue_sps: q_sps,
                eval_chunked_sps: c_sps,
                forwards_queue: q_fwd,
                forwards_chunked: c_fwd,
            });
        }
    }

    // Seed-pack throughput through the real orchestrator core (`run_pack`,
    // exactly what `train --seeds` drives): drivers=1 is the legacy
    // single-thread cycle interleave, drivers=N overlaps every seed's
    // device forward with every other seed's host sweep.
    let pack_seeds = 4usize;
    let pack_cycles = args.get_usize("pack-cycles", 24);
    let mut pack_rows: Vec<(usize, usize, f64)> = Vec::new();
    for &threads in &thread_settings {
        for drivers in [1usize, pack_seeds] {
            if drivers > 1 && pack_seeds == 1 {
                continue;
            }
            let sps = bench_pack(pack_seeds, threads, drivers, pack_cycles);
            println!(
                "[pack  threads={threads:>2} drivers={drivers}] collect {sps:>12.0} steps/s \
                 ({pack_seeds} seeds x {pack_cycles} cycles)"
            );
            pack_rows.push((threads, drivers, sps));
        }
    }
    assert!(
        pack_rows.iter().all(|&(_, _, s)| s.is_finite() && s > 0.0),
        "pack bench produced non-positive or non-finite throughput — refusing to emit"
    );

    // Refuse to overwrite the committed JSON with a zeroed placeholder
    // shape: a broken harness (stopped clock, empty suite, zero work)
    // must fail loudly here, never publish zeros that look "measured".
    let all_zero = rows.iter().all(|r| {
        r.collect_sps <= 0.0 && r.eval_queue_sps <= 0.0 && r.eval_chunked_sps <= 0.0
    });
    assert!(
        !all_zero,
        "bench_rollout measured all-zero throughput across every variant — \
         refusing to emit BENCH_rollout.json (is the harness broken?)"
    );
    assert!(
        rows.iter().all(|r| {
            r.collect_sps.is_finite()
                && r.eval_queue_sps.is_finite()
                && r.eval_chunked_sps.is_finite()
        }),
        "bench_rollout produced non-finite throughput — refusing to emit"
    );

    // Emit BENCH_rollout.json at the repo root (rust/..). `measured` is
    // always true here: the committed `measured: false` placeholder can
    // only be authored by hand, never by this bench.
    let mut json = String::from("{\n  \"bench\": \"rollout\",\n");
    json.push_str(
        "  \"policy\": \"synthetic host-side stand-in (device forward excluded; see micro_runtime)\",\n",
    );
    json.push_str("  \"unit\": \"env steps per second\",\n");
    json.push_str("  \"measured\": true,\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"variant\": \"{}\", \"threads\": {}, \"collect_steps_per_sec\": {:.1}, \
             \"eval_queue_steps_per_sec\": {:.1}, \"eval_chunked_steps_per_sec\": {:.1}, \
             \"eval_forward_passes_queue\": {}, \"eval_forward_passes_chunked\": {}}}{}\n",
            r.variant,
            r.threads,
            r.collect_sps,
            r.eval_queue_sps,
            r.eval_chunked_sps,
            r.forwards_queue,
            r.forwards_chunked,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"pack\": {{\"seeds\": {pack_seeds}, \"cycles\": {pack_cycles}, \
         \"rollout_t\": {PACK_T}, \"rollout_b\": {PACK_B}}},\n"
    ));
    json.push_str("  \"pack_results\": [\n");
    for (i, &(threads, drivers, sps)) in pack_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {}, \"drivers\": {}, \"collect_steps_per_sec\": {:.1}}}{}\n",
            threads,
            drivers,
            sps,
            if i + 1 < pack_rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_rollout.json");
    std::fs::write(&out, json).expect("writing BENCH_rollout.json");
    println!("wrote {}", out.display());
}
