//! Table 2 — mean solve rate ± std on the holdout suite, per algorithm,
//! plus the 25-wall-limit row.
//!
//! Trains each algorithm for a scaled env-step budget across several seeds
//! and evaluates on the holdout suite (named DCD mazes + seeded minimax-
//! recipe procedural levels). The paper rows (dcd / minimax / JaxUED at
//! 245.76M steps, 10 seeds) are printed alongside for shape comparison; at
//! the default scaled budget the absolute rates are necessarily lower —
//! the claim reproduced is the *ordering band* (DR competitive with the
//! UED methods; nothing dominated by an order of magnitude).
//!
//! Flags: --env-steps N (default 250k) --seeds S (default 2)
//!        --algos dr,plr,… --variant std|small --wall-limit-row

use std::path::Path;

use jaxued::algo::train;
use jaxued::config::{Algo, TrainConfig, Variant};
use jaxued::runtime::Runtime;
use jaxued::util::stats::{mean, std_dev};

fn run_row(
    rt: &Runtime, algo: Algo, variant: Variant, env_steps: u64, seeds: u64,
    max_walls: usize,
) -> anyhow::Result<(f64, f64)> {
    let mut rates = Vec::new();
    for seed in 0..seeds {
        let mut cfg = TrainConfig::defaults(algo);
        cfg.variant = variant;
        cfg.env_steps_budget = env_steps;
        cfg.seed = seed;
        cfg.max_walls = max_walls;
        cfg.eval_interval = 0;
        cfg.eval_trials = 3;
        cfg.out_dir = "runs/bench_table2".into();
        let outcome = train(rt, &cfg, true)?;
        rates.push(outcome.final_eval.mean_solve_rate);
        eprintln!(
            "  {} walls={} seed={}: mean_solve={:.3}",
            algo.name(), max_walls, seed, outcome.final_eval.mean_solve_rate
        );
    }
    Ok((mean(&rates), std_dev(&rates)))
}

fn main() -> anyhow::Result<()> {
    let args = jaxued::util::cli::Args::parse();
    let env_steps = args.get_u64("env-steps", 100_000);
    let seeds = args.get_u64("seeds", 1);
    let variant = Variant::parse(&args.get_str("variant", "std"))?;
    let algo_list = args.get_str("algos", "dr,plr,robust_plr,accel,paired");
    let wall_limit_row = args.get_bool("wall-limit-row", true);
    let rt = Runtime::new(Path::new(&args.get_str("artifacts", "artifacts")))?;

    println!("=== Table 2: mean solve rate on the holdout suite ===");
    println!("(scaled budget: {env_steps} env steps, {seeds} seeds, variant {})\n", variant.name);

    println!("paper rows (245.76M steps, 10 seeds):");
    println!("  dcd (reported)      DR 0.62±0.05  PAIRED 0.52±0.13  PLR⊥ 0.71±0.04  ACCEL 0.75±0.03");
    println!("  minimax (reported)  DR 0.55±0.05  PAIRED 0.63±0.04  PLR⊥ 0.70±0.03  ACCEL 0.73±0.05");
    println!("  JaxUED (paper)      DR 0.69±0.05  PAIRED 0.61±0.16  PLR 0.72±0.08  PLR⊥ 0.66±0.09  ACCEL 0.72±0.05");
    println!("  JaxUED 25-wall      DR 0.54±0.12  PAIRED 0.17±0.16  PLR 0.47±0.11  PLR⊥ 0.46±0.09\n");

    println!("this repo (scaled):");
    for name in algo_list.split(',') {
        let algo = Algo::parse(name)?;
        let (m, s) = run_row(&rt, algo, variant, env_steps, seeds, 60)?;
        println!("  {:<12} {:.2} ± {:.2}", name, m, s);
    }
    if wall_limit_row {
        println!("\nthis repo, 25-wall limit:");
        for name in algo_list.split(',').filter(|n| *n != "accel") {
            let algo = Algo::parse(name)?;
            let (m, s) = run_row(&rt, algo, variant, env_steps, seeds, 25)?;
            println!("  {:<12} {:.2} ± {:.2}", name, m, s);
        }
    }
    Ok(())
}
