//! Figure 3 — IQM of mean solve rate with min–max error bars over seeds,
//! for each method at both base-distribution wall budgets (25 and 60).
//!
//! Regenerates the figure's data series (printed as rows; plot with any
//! tool from the emitted CSV `runs/bench_fig3/fig3.csv`).
//!
//! Flags: --env-steps N (default 250k) --seeds S (default 3)
//!        --algos dr,plr,… --walls 25,60 --variant std|small

use std::path::Path;

use jaxued::algo::train;
use jaxued::config::{Algo, TrainConfig, Variant};
use jaxued::metrics::CsvSink;
use jaxued::runtime::Runtime;
use jaxued::util::stats::{iqm, min_max};

fn main() -> anyhow::Result<()> {
    let args = jaxued::util::cli::Args::parse();
    let env_steps = args.get_u64("env-steps", 100_000);
    let seeds = args.get_u64("seeds", 2);
    let variant = Variant::parse(&args.get_str("variant", "std"))?;
    let algo_list = args.get_str("algos", "dr,accel");
    let walls_list = args.get_str("walls", "25,60");
    let rt = Runtime::new(Path::new(&args.get_str("artifacts", "artifacts")))?;

    let mut csv = CsvSink::create(
        Path::new("runs/bench_fig3/fig3.csv"),
        &["algo", "max_walls", "seed", "mean_solve", "iqm_solve"],
    )?;

    println!("=== Figure 3: IQM of mean solve rate (error bars = min–max over seeds) ===");
    println!("(scaled budget: {env_steps} env steps, {seeds} seeds)\n");
    println!("{:<16} {:>6} {:>8} {:>8} {:>8}", "method", "walls", "IQM", "min", "max");

    for name in algo_list.split(',') {
        let algo = Algo::parse(name)?;
        for walls_s in walls_list.split(',') {
            let walls: usize = walls_s.parse()?;
            let mut per_seed = Vec::new();
            for seed in 0..seeds {
                let mut cfg = TrainConfig::defaults(algo);
                cfg.variant = variant;
                cfg.env_steps_budget = env_steps;
                cfg.seed = seed;
                cfg.max_walls = walls;
                cfg.eval_interval = 0;
                cfg.eval_trials = 3;
                cfg.out_dir = "runs/bench_fig3".into();
                let outcome = train(&rt, &cfg, true)?;
                // Figure 3 aggregates the IQM (over levels) of each seed's
                // mean solve rate; we track both.
                per_seed.push(outcome.final_eval.mean_solve_rate);
                csv.write_row(&[
                    algo as usize as f64,
                    walls as f64,
                    seed as f64,
                    outcome.final_eval.mean_solve_rate,
                    outcome.final_eval.iqm_solve_rate,
                ])?;
            }
            let (lo, hi) = min_max(&per_seed);
            println!(
                "{:<16} {:>6} {:>8.3} {:>8.3} {:>8.3}",
                format!("{}-{}", name, walls_s), walls, iqm(&per_seed), lo, hi
            );
        }
    }
    println!("\nseries written to runs/bench_fig3/fig3.csv");
    println!("paper shape: DR-25 strongest under the 25-wall budget; all methods");
    println!("in one band at 60 walls (DR competitive — the paper's surprise).");
    Ok(())
}
