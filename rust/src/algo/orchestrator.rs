//! Seed-pack orchestration: N full training runs interleaved in one
//! process over one shared rollout [`WorkerPool`].
//!
//! The paper's headline numbers (Figure 3, Table 1) are IQM aggregates
//! over many independent seeds, which JaxUED gets almost for free from
//! `jax.vmap`. The Rust port gets the same economy differently: a pack
//! (`--seeds 0..8` / `--num-seeds N`) builds one [`TrainSeedRun`] per
//! seed — each an ordinary solo run down to its run directory and CSV —
//! and steps their update cycles concurrently, so every phase of host
//! work flows through the *single* per-process pool (saturated, never
//! N-fold oversubscribed; the pool's FIFO phase lock keeps contending
//! engines fair).
//!
//! # Driver threads
//!
//! [`run_pack`] splits the units into `drivers` contiguous chunks and
//! gives each chunk its own OS thread; within a chunk cycles stay
//! cycle-major (every unit advances through cycle k before any unit
//! starts k+1). With `drivers == 1` this is exactly the classic
//! round-robin loop. With more drivers, one seed's *device forward* (a
//! PJRT call that holds no pool lock — the pool is put in multi-driver
//! mode, so engines run forwards outside any pool phase and fuse the
//! writeback into the step phase) overlaps every other seed's host sweep.
//! Driver threads report each finished cycle over a channel; the calling
//! thread gathers reports into cycle-indexed slots and writes the
//! cross-seed aggregate strictly in cycle order, so `aggregate.csv` is
//! byte-identical at any driver count.
//!
//! **Bit-identity invariant.** Seed *s* trained inside a pack is
//! bit-identical to seed *s* trained alone — same per-cycle metrics, same
//! final sampler contents, at any `--rollout-threads` count *and any
//! `--drivers` count*. It holds structurally: every unit owns its RNG
//! streams, trajectory, trainer and sampler; the shared pool only
//! schedules column work, which the per-column RNG-stream design already
//! makes schedule-independent; and the fused multi-driver schedule writes
//! the same bytes to the same disjoint per-column locations with the same
//! per-column draw order as the overlapped one. The artifact-free
//! `pack_determinism` integration test pins it on both env families
//! across the drivers × rollout-threads grid.
//!
//! **Error handling.** If any unit's `step_cycle` fails, the pack aborts:
//! the failing driver raises the shared abort flag, the other drivers
//! stop at their next step boundary, and `run_pack` flushes every unit's
//! buffered sinks ([`SeedUnit::flush_sinks`]) plus the aggregate before
//! propagating the first error (lowest cycle, then lowest unit index) —
//! so a mid-pack crash leaves complete CSV rows on disk, not truncated
//! buffers.
//!
//! Alongside the per-seed CSVs the pack writes a cross-seed
//! [`CrossSeedSink`] aggregate (mean / IQM / stderr per cycle — the
//! Figure-3 quantities) and a [`PackManifest`] naming every member run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::Result;

use super::{build_algo_for_with_pool, CycleMetrics, TrainOutcome, UedAlgorithm};
use crate::config::TrainConfig;
use crate::env::registry::{dispatch, EnvVisitor};
use crate::env::EnvFamily;
use crate::eval::{for_family_with_pool, Evaluator};
use crate::metrics::{log_stdout_tagged, CrossSeedSink, CsvSink, Stopwatch};
use crate::rollout::{Policy, WorkerPool};
use crate::runtime::executor::Executable;
use crate::runtime::{PackManifest, Runtime};
use crate::util::rng::Pcg64;

/// Metrics aggregated across seeds every cycle, in [`run_pack`]'s column
/// order. A [`CrossSeedSink`] handed to `run_pack` must be created with
/// exactly this list.
pub const PACK_AGGREGATE_METRICS: &[&str] = &[
    "loss",
    "train_solve_rate",
    "mean_reward",
    "buffer_fill",
    "eval_mean_solve",
    "eval_iqm_solve",
];

/// One seed's training run viewed as a steppable unit. The orchestrator
/// only needs "advance one cycle and tell me what happened", so packs are
/// testable artifact-free with synthetic-policy units. Units must be
/// `Send` (the bound sits on [`run_pack`]): each one lives on a driver
/// thread for the duration of the pack.
pub trait SeedUnit {
    fn seed(&self) -> u64;
    fn total_cycles(&self) -> usize;
    /// Cumulative env steps so far (the aggregate sink's x-axis).
    fn env_steps(&self) -> u64;
    /// Run one update cycle; returns that cycle's metrics row.
    fn step_cycle(&mut self) -> Result<CycleMetrics>;
    /// (mean_solve, iqm_solve) of the latest periodic evaluation; NaN
    /// before the first eval or for units that never evaluate.
    fn last_eval(&self) -> (f64, f64) {
        (f64::NAN, f64::NAN)
    }
    /// Flush any buffered per-unit sinks so a mid-pack abort leaves
    /// complete rows on disk. Default: nothing buffered.
    fn flush_sinks(&mut self) -> Result<()> {
        Ok(())
    }
}

/// One unit's finished cycle, reported from a driver thread to the
/// gathering thread.
struct CycleReport {
    cycle: usize,
    /// Global unit index (position in `run_pack`'s `units` slice).
    unit: usize,
    env_steps: u64,
    /// Values in [`PACK_AGGREGATE_METRICS`] order.
    metrics: Vec<f64>,
}

/// Per-cycle gather slot: aggregate inputs accumulate here until every
/// unit has reported the cycle, then the row is written.
struct CycleSlot {
    filled: usize,
    /// Unit 0's cumulative env steps at this cycle (the x-axis value the
    /// classic single-driver loop used).
    env_steps: u64,
    /// `[metric][unit]`, NaN until that unit reports.
    per_metric: Vec<Vec<f64>>,
}

impl CycleSlot {
    fn new(n_units: usize) -> CycleSlot {
        CycleSlot {
            filled: 0,
            env_steps: 0,
            per_metric: (0..PACK_AGGREGATE_METRICS.len())
                .map(|_| vec![f64::NAN; n_units])
                .collect(),
        }
    }
}

/// Drive a pack of seed units to completion over `drivers` driver
/// threads, writing one cross-seed aggregate row per cycle, strictly in
/// cycle order. Every unit must agree on the cycle count (they share one
/// config). `drivers` is clamped to `[1, units.len()]`; units are split
/// into contiguous chunks, one driver thread per chunk, and each chunk is
/// stepped cycle-major — so `drivers == 1` reproduces the classic
/// round-robin schedule exactly.
///
/// On any `step_cycle` error the pack aborts cooperatively, every unit's
/// sinks and the aggregate are flushed, and the first error (lowest
/// cycle, then lowest unit index) propagates.
pub fn run_pack<U: SeedUnit + Send>(
    units: &mut [U], aggregate: &mut CrossSeedSink, drivers: usize,
) -> Result<()> {
    anyhow::ensure!(!units.is_empty(), "empty seed pack");
    let total = units[0].total_cycles();
    anyhow::ensure!(
        units.iter().all(|u| u.total_cycles() == total),
        "seed units disagree on cycle count"
    );
    let n = units.len();
    let drivers = drivers.clamp(1, n);
    let chunk_len = n.div_ceil(drivers);

    let abort = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<CycleReport>();

    // (cycle, unit, error) per failed driver; first by (cycle, unit) wins.
    let mut driver_errs: Vec<(usize, usize, anyhow::Error)> = Vec::new();
    let mut gather_err: Option<anyhow::Error> = None;

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(drivers);
        for (d, chunk) in units.chunks_mut(chunk_len).enumerate() {
            let tx = tx.clone();
            let abort = &abort;
            let base = d * chunk_len;
            handles.push(scope.spawn(
                move || -> Result<(), (usize, usize, anyhow::Error)> {
                    for cycle in 0..total {
                        for (i, u) in chunk.iter_mut().enumerate() {
                            if abort.load(Ordering::Relaxed) {
                                return Ok(());
                            }
                            // ued-lint: allow(flush-on-error) — the Err return only aborts this driver thread; run_pack flushes every unit's sinks after the scope joins
                            match u.step_cycle() {
                                Ok(m) => {
                                    let (eval_mean, eval_iqm) = u.last_eval();
                                    let report = CycleReport {
                                        cycle,
                                        unit: base + i,
                                        env_steps: u.env_steps(),
                                        metrics: vec![
                                            m.total_loss,
                                            m.train_solve_rate,
                                            m.mean_reward,
                                            m.buffer_fill,
                                            eval_mean,
                                            eval_iqm,
                                        ],
                                    };
                                    // A closed channel means the gatherer
                                    // bailed (aggregate I/O error); its
                                    // error wins — stop quietly.
                                    if tx.send(report).is_err() {
                                        return Ok(());
                                    }
                                }
                                Err(e) => {
                                    abort.store(true, Ordering::Relaxed);
                                    return Err((cycle, base + i, e));
                                }
                            }
                        }
                    }
                    Ok(())
                },
            ));
        }
        // Drop the gatherer's clone so `rx` disconnects once every driver
        // finishes.
        drop(tx);

        // Gather: buffer out-of-order reports per cycle, emit aggregate
        // rows strictly in cycle order as cycles complete.
        let mut next = 0usize;
        let mut pending: BTreeMap<usize, CycleSlot> = BTreeMap::new();
        'recv: while let Ok(r) = rx.recv() {
            let slot = pending.entry(r.cycle).or_insert_with(|| CycleSlot::new(n));
            for (m, v) in r.metrics.iter().enumerate() {
                slot.per_metric[m][r.unit] = *v;
            }
            if r.unit == 0 {
                slot.env_steps = r.env_steps;
            }
            slot.filled += 1;
            while pending.get(&next).is_some_and(|s| s.filled == n) {
                let slot = pending.remove(&next).expect("slot just observed");
                if let Err(e) =
                    aggregate.write_cycle(next, slot.env_steps, &slot.per_metric)
                {
                    abort.store(true, Ordering::Relaxed);
                    gather_err = Some(e);
                    break 'recv;
                }
                next += 1;
            }
        }
        // Dropping `rx` here closes the channel; aborted drivers stop at
        // their next step boundary regardless.
        drop(rx);

        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err((cycle, unit, e))) => driver_errs.push((cycle, unit, e)),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });

    let first_err = driver_errs
        .into_iter()
        .min_by_key(|(c, u, _)| (*c, *u))
        .map(|(c, u, e)| e.context(format!("seed pack aborted at cycle {c} (unit {u})")))
        .or(gather_err);
    if let Some(err) = first_err {
        // Leave complete rows on disk before propagating: a mid-pack
        // abort must not truncate the survivors' buffered CSV rows.
        for u in units.iter_mut() {
            let _ = u.flush_sinks();
        }
        let _ = aggregate.flush();
        return Err(err);
    }
    Ok(())
}

/// One seed's full training run — driver, evaluator, per-seed CSV and
/// checkpointing — as a unit the orchestrator (or the solo `train_family`
/// loop, which uses exactly this type) steps one cycle at a time.
pub struct TrainSeedRun<F: EnvFamily> {
    cfg: TrainConfig,
    quiet: bool,
    /// Log-line prefix (`"s3 "` inside a pack, empty solo).
    tag: String,
    rng: Pcg64,
    algo: Box<dyn UedAlgorithm>,
    evaluator: Evaluator<F::Env>,
    stu_apply: Arc<Executable>,
    run_dir: PathBuf,
    csv: CsvSink,
    watch: Stopwatch,
    last_eval: (f64, f64),
    cycle: usize,
    total_cycles: usize,
    per_cycle: u64,
}

impl<F: EnvFamily> TrainSeedRun<F> {
    /// Build the unit over a caller-owned pool. The construction sequence
    /// (RNG stream, driver, evaluator, apply artifact, CSV) matches the
    /// solo path draw-for-draw — that is what makes pack and solo runs of
    /// one seed bit-identical.
    pub fn new(
        family: F, rt: &Runtime, cfg: &TrainConfig, quiet: bool, tag: &str,
        pool: Arc<WorkerPool>,
    ) -> Result<TrainSeedRun<F>> {
        let cfg = cfg.clone();
        let mut rng = Pcg64::new(cfg.seed, 0x7261_696e); // "rain"
        let algo = build_algo_for_with_pool(family, rt, &cfg, &mut rng, pool)?;
        let evaluator =
            for_family_with_pool(family, &cfg, cfg.eval_trials, 20, algo.rollout_pool());
        let stu_apply = rt.load_scoped(
            cfg.env.artifact_prefix(),
            &cfg.student_apply_artifact(),
        )?;
        let run_dir = Path::new(&cfg.out_dir).join(cfg.run_name());
        let csv = CsvSink::create(
            &run_dir.join("metrics.csv"),
            &[
                "cycle", "env_steps", "loss", "value_loss", "entropy",
                "train_solve_rate", "episodes", "buffer_fill", "mean_regret",
                "eval_mean_solve", "eval_iqm_solve", "steps_per_sec",
                "stage_ns", "forward_ns", "step_ns", "writeback_ns",
            ],
        )?;
        let total_cycles = cfg.num_cycles();
        let per_cycle = cfg.env_steps_per_cycle();
        Ok(TrainSeedRun {
            cfg,
            quiet,
            tag: tag.to_string(),
            rng,
            algo,
            evaluator,
            stu_apply,
            run_dir,
            csv,
            watch: Stopwatch::new(),
            last_eval: (f64::NAN, f64::NAN),
            cycle: 0,
            total_cycles,
            per_cycle,
        })
    }

    pub fn done(&self) -> bool {
        self.cycle >= self.total_cycles
    }

    /// One update cycle: algorithm cycle, periodic eval, CSV row, logs.
    pub fn step_cycle(&mut self) -> Result<CycleMetrics> {
        anyhow::ensure!(
            self.cycle < self.total_cycles,
            "seed {} already ran its {} cycles",
            self.cfg.seed,
            self.total_cycles
        );
        let cycle = self.cycle;
        let m = self.algo.cycle(&mut self.rng)?;
        self.watch.add_steps(self.per_cycle);

        let do_eval =
            self.cfg.eval_interval > 0 && (cycle + 1) % self.cfg.eval_interval == 0;
        if do_eval {
            let policy = Policy {
                apply: self.stu_apply.clone(),
                params: self.algo.student_params(),
                num_actions: self.evaluator.num_actions(),
            };
            let report = self.evaluator.run(&policy, &mut self.rng)?;
            self.last_eval = (report.mean_solve_rate, report.iqm_solve_rate);
            if !self.quiet {
                log_stdout_tagged(
                    &self.tag,
                    cycle,
                    self.watch.env_steps,
                    &[
                        ("eval_mean_solve", report.mean_solve_rate),
                        ("eval_iqm_solve", report.iqm_solve_rate),
                        ("sps", self.watch.steps_per_sec()),
                    ],
                );
            }
        }
        self.csv.write_row(&[
            cycle as f64,
            self.watch.env_steps as f64,
            m.total_loss,
            m.value_loss,
            m.entropy,
            m.train_solve_rate,
            m.episodes as f64,
            m.buffer_fill,
            m.mean_regret,
            self.last_eval.0,
            self.last_eval.1,
            self.watch.steps_per_sec(),
            m.timers.stage_ns as f64,
            m.timers.forward_ns as f64,
            m.timers.step_ns as f64,
            m.timers.writeback_ns as f64,
        ])?;
        if !self.quiet && (cycle % 16 == 0) {
            log_stdout_tagged(
                &self.tag,
                cycle,
                self.watch.env_steps,
                &[
                    ("loss", m.total_loss),
                    ("train_solve", m.train_solve_rate),
                    ("buffer", m.buffer_fill),
                    ("sps", self.watch.steps_per_sec()),
                ],
            );
        }
        self.cycle += 1;
        Ok(m)
    }

    /// Final checkpoint + evaluation (the tail of the solo loop).
    pub fn finish(mut self) -> Result<TrainOutcome> {
        anyhow::ensure!(
            self.done(),
            "seed {} finished only {}/{} cycles",
            self.cfg.seed,
            self.cycle,
            self.total_cycles
        );
        // surface buffered-row I/O errors (a full disk) here instead of
        // letting BufWriter's drop swallow them after an Ok return
        self.csv.flush()?;
        self.algo
            .student_trainer()
            .params
            .save(&self.run_dir.join("student.ckpt"))?;
        let policy = Policy {
            apply: self.stu_apply.clone(),
            params: self.algo.student_params(),
            num_actions: self.evaluator.num_actions(),
        };
        let final_eval = self.evaluator.run(&policy, &mut self.rng)?;
        Ok(TrainOutcome {
            cycles: self.total_cycles,
            env_steps: self.watch.env_steps,
            wallclock_secs: self.watch.elapsed_secs(),
            table1_hours: self.watch.extrapolate_hours(245_760_000),
            final_eval,
        })
    }
}

impl<F: EnvFamily> SeedUnit for TrainSeedRun<F> {
    fn seed(&self) -> u64 {
        self.cfg.seed
    }

    fn total_cycles(&self) -> usize {
        self.total_cycles
    }

    fn env_steps(&self) -> u64 {
        self.watch.env_steps
    }

    fn step_cycle(&mut self) -> Result<CycleMetrics> {
        TrainSeedRun::step_cycle(self)
    }

    fn last_eval(&self) -> (f64, f64) {
        self.last_eval
    }

    fn flush_sinks(&mut self) -> Result<()> {
        self.csv.flush()
    }
}

/// Outcome of a full seed pack.
pub struct PackOutcome {
    pub seeds: Vec<u64>,
    /// Per-seed outcomes, in `seeds` order.
    pub outcomes: Vec<TrainOutcome>,
    /// The pack directory (aggregate CSV + manifest).
    pub pack_dir: PathBuf,
}

impl PackOutcome {
    /// Final-evaluation mean solve rate per seed (Figure-3 raw points).
    pub fn final_mean_solves(&self) -> Vec<f64> {
        self.outcomes
            .iter()
            .map(|o| o.final_eval.mean_solve_rate)
            .collect()
    }

    pub fn total_env_steps(&self) -> u64 {
        self.outcomes.iter().map(|o| o.env_steps).sum()
    }
}

/// Train every seed of `cfg.seed_list()` concurrently in this process
/// over one shared worker pool (the `--seeds` entry point, env-erased).
pub fn train_pack(rt: &Runtime, cfg: &TrainConfig, quiet: bool) -> Result<PackOutcome> {
    struct V<'a> {
        rt: &'a Runtime,
        cfg: &'a TrainConfig,
        quiet: bool,
    }
    impl EnvVisitor for V<'_> {
        type Out = Result<PackOutcome>;
        fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
            train_pack_family(family, self.rt, self.cfg, self.quiet)
        }
    }
    dispatch(cfg.env, V { rt, cfg, quiet })
}

/// [`train_pack`] in a statically-known env family.
pub fn train_pack_family<F: EnvFamily>(
    family: F, rt: &Runtime, cfg: &TrainConfig, quiet: bool,
) -> Result<PackOutcome> {
    let seeds = cfg.seed_list();
    let drivers = cfg.resolve_drivers(seeds.len());
    let pool = Arc::new(WorkerPool::new(cfg.resolve_rollout_threads()));
    // With more than one driver, engines switch to the fused schedule:
    // device forwards run outside pool phases so one seed's forward
    // overlaps other seeds' host sweeps (bit-identical either way).
    pool.set_multi_driver(drivers > 1);
    let pack_dir = Path::new(&cfg.out_dir).join(cfg.pack_name());

    let mut units: Vec<TrainSeedRun<F>> = Vec::with_capacity(seeds.len());
    for &s in &seeds {
        units.push(TrainSeedRun::new(
            family,
            rt,
            &cfg.for_seed(s),
            quiet,
            &format!("s{s} "),
            pool.clone(),
        )?);
    }

    let mut aggregate = CrossSeedSink::create(
        &pack_dir.join("aggregate.csv"),
        PACK_AGGREGATE_METRICS,
        seeds.len(),
    )?;
    run_pack(&mut units, &mut aggregate, drivers)?;
    aggregate.flush()?;

    let mut outcomes = Vec::with_capacity(units.len());
    for u in units {
        outcomes.push(u.finish()?);
    }

    let manifest = PackManifest {
        env: cfg.env.name().to_string(),
        algo: cfg.algo.name().to_string(),
        variant: cfg.variant.name.to_string(),
        seeds: seeds.clone(),
        run_dirs: seeds.iter().map(|&s| cfg.for_seed(s).run_name()).collect(),
        aggregate_csv: "aggregate.csv".to_string(),
        env_steps_budget: cfg.env_steps_budget,
        rollout_threads: pool.threads(),
    };
    manifest.write(&pack_dir)?;

    Ok(PackOutcome { seeds, outcomes, pack_dir })
}
