//! PAIRED (paper §5.3, Dennis et al. 2020), generic over the environment
//! family.
//!
//! Three agents: an *adversary* policy that builds levels in the family's
//! editor environment, and two students — *protagonist* and *antagonist* —
//! that play them. Per cycle:
//!
//!   1. roll the adversary in the editor env (fresh noise z per column) to
//!      generate B levels (extracted via `EnvFamily::editor_level`);
//!   2. roll both students on those levels (AutoReplay: several episodes
//!      sharpen the estimates);
//!   3. regret(level) = max antagonist terminal reward − mean protagonist
//!      terminal reward (clamped at 0);
//!   4. adversary trains on its editor trajectory with the sparse regret
//!      reward at the final edit step; students train on their rollouts
//!      with the ordinary env reward.
//!
//! Env-step accounting (paper §6): both students count, editor steps do not.

use std::sync::Arc;

use anyhow::Result;

use super::{CycleMetrics, UedAlgorithm};
use crate::config::TrainConfig;
use crate::env::editor::{EditorState, EditorTask};
use crate::env::wrappers::AutoReplayWrapper;
use crate::env::{EnvFamily, UnderspecifiedEnv};
use crate::ppo::{LrSchedule, PpoTrainer};
use crate::rollout::{Policy, RolloutEngine, Trajectory, WorkerPool};
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

/// The PAIRED driver.
pub struct PairedAlgo<F: EnvFamily> {
    family: F,
    editor_env: F::Editor,
    student_env: AutoReplayWrapper<F::Env>,
    adversary: PpoTrainer,
    protagonist: PpoTrainer,
    antagonist: PpoTrainer,
    adv_apply: Arc<crate::runtime::executor::Executable>,
    stu_apply: Arc<crate::runtime::executor::Executable>,
    editor_engine: RolloutEngine,
    student_engine: RolloutEngine,
    editor_traj: Trajectory,
    prot_traj: Trajectory,
    ant_traj: Trajectory,
    adv_num_actions: usize,
    stu_num_actions: usize,
    b: usize,
    /// Mean regret of the last cycle (logged).
    pub last_mean_regret: f64,
}

impl<F: EnvFamily> PairedAlgo<F> {
    /// Driver with its own worker pool sized by `cfg.rollout_threads`.
    pub fn new(family: F, rt: &Runtime, cfg: &TrainConfig) -> Result<PairedAlgo<F>> {
        let pool = Arc::new(WorkerPool::new(cfg.resolve_rollout_threads()));
        Self::with_pool(family, rt, cfg, pool)
    }

    /// Driver over a caller-owned pool (shared across a seed pack; the
    /// three agents already share one pool within a driver).
    pub fn with_pool(
        family: F, rt: &Runtime, cfg: &TrainConfig, pool: Arc<WorkerPool>,
    ) -> Result<PairedAlgo<F>> {
        let schedule = LrSchedule {
            lr0: cfg.lr,
            anneal: cfg.anneal_lr,
            total_updates: cfg.num_cycles(),
        };
        let seed = cfg.seed as i32;
        let prefix = cfg.env.artifact_prefix();
        let adversary = PpoTrainer::new(
            rt,
            "adversary",
            &rt.resolve_name(prefix, &cfg.adversary_train_artifact()),
            seed,
            schedule,
        )?;
        let protagonist = PpoTrainer::new(
            rt,
            "student",
            &rt.resolve_name(prefix, &cfg.student_train_artifact()),
            seed.wrapping_add(1),
            schedule,
        )?;
        let antagonist = PpoTrainer::new(
            rt,
            "student",
            &rt.resolve_name(prefix, &cfg.student_train_artifact()),
            seed.wrapping_add(2),
            schedule,
        )?;
        let adv_apply = rt.load_scoped(prefix, &cfg.adversary_apply_artifact())?;
        let stu_apply = rt.load_scoped(prefix, &cfg.student_apply_artifact())?;
        let params = cfg.env_params();
        let editor_env = family.make_editor(&params);
        let student_env = AutoReplayWrapper::new(family.make_env(&params));
        let (t_adv, b) = adversary.rollout_shape();
        let (t, b2) = protagonist.rollout_shape();
        anyhow::ensure!(b == b2, "adversary/student batch mismatch: {b} vs {b2}");
        anyhow::ensure!(
            t_adv == cfg.editor_horizon(),
            "adversary artifact horizon {t_adv} != configured editor steps {}",
            cfg.editor_horizon()
        );
        // All three agents' rollouts (adversary in the editor env, both
        // students in the task env) share one persistent worker pool.
        let editor_engine = RolloutEngine::with_pool(&editor_env, b, pool.clone());
        let student_engine = RolloutEngine::with_pool(&student_env, b, pool);
        let editor_traj = Trajectory::new(t_adv, b, &editor_env.obs_components());
        let prot_traj = Trajectory::new(t, b, &student_env.obs_components());
        let ant_traj = Trajectory::new(t, b, &student_env.obs_components());
        let adv_num_actions = editor_env.num_actions();
        let stu_num_actions = student_env.num_actions();
        Ok(PairedAlgo {
            family,
            editor_env,
            student_env,
            adversary,
            protagonist,
            antagonist,
            adv_apply,
            stu_apply,
            editor_engine,
            student_engine,
            editor_traj,
            prot_traj,
            ant_traj,
            adv_num_actions,
            stu_num_actions,
            b,
            last_mean_regret: 0.0,
        })
    }

    /// Current adversary parameters (visualization / analysis).
    pub fn adversary_params(&self) -> &[xla::Literal] {
        &self.adversary.params.params
    }

    /// Roll the adversary in the editor env; returns the generated levels
    /// (the editor trajectory stays in `self.editor_traj` for training).
    fn generate_levels(&mut self, rng: &mut Pcg64) -> Result<Vec<F::Level>> {
        let mut states: Vec<EditorState> = (0..self.b)
            .map(|_| {
                let task = EditorTask::sample(rng);
                self.editor_env.reset_to_level(&task, rng)
            })
            .collect();
        let policy = Policy {
            apply: self.adv_apply.clone(),
            params: &self.adversary.params.params,
            num_actions: self.adv_num_actions,
        };
        self.editor_engine.collect(
            &self.editor_env, &mut states, &policy, &mut self.editor_traj, rng,
        )?;
        Ok(states.iter().map(|s| self.family.editor_level(s)).collect())
    }

    fn student_rollout(
        engine: &mut RolloutEngine, env: &AutoReplayWrapper<F::Env>,
        trainer: &PpoTrainer, apply: &Arc<crate::runtime::executor::Executable>,
        traj: &mut Trajectory, levels: &[F::Level], num_actions: usize, rng: &mut Pcg64,
    ) -> Result<()> {
        let mut states: Vec<_> = levels
            .iter()
            .map(|l| env.reset_to_level(l, rng))
            .collect();
        let policy = Policy {
            apply: apply.clone(),
            params: &trainer.params.params,
            num_actions,
        };
        engine.collect(env, &mut states, &policy, traj, rng)
    }
}

impl<F: EnvFamily> UedAlgorithm for PairedAlgo<F> {
    fn name(&self) -> &'static str {
        "paired"
    }

    fn cycle(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        // 1. adversary generates levels
        let levels = self.generate_levels(rng)?;

        // 2. both students play them
        Self::student_rollout(
            &mut self.student_engine, &self.student_env, &self.protagonist,
            &self.stu_apply, &mut self.prot_traj, &levels, self.stu_num_actions, rng,
        )?;
        Self::student_rollout(
            &mut self.student_engine, &self.student_env, &self.antagonist,
            &self.stu_apply, &mut self.ant_traj, &levels, self.stu_num_actions, rng,
        )?;

        // 3. regret per level: max antagonist − mean protagonist terminal
        //    reward (0 when the antagonist never finished an episode).
        let prot_stats = self.prot_traj.episode_stats();
        let ant_stats = self.ant_traj.episode_stats();
        let t_adv = self.editor_traj.t;
        let mut regret_sum = 0.0;
        {
            let last_row = self.editor_traj.rewards.slice_mut(t_adv - 1);
            for b in 0..self.b {
                let regret = (ant_stats[b].max_end_reward as f64
                    - prot_stats[b].mean_end_reward)
                    .max(0.0);
                last_row[b] = regret as f32;
                regret_sum += regret;
            }
        }
        self.last_mean_regret = regret_sum / self.b as f64;

        // 4. updates: adversary on sparse regret, students on env reward.
        let adv_metrics = self.adversary.update(&self.editor_traj)?;
        let prot_metrics = self.protagonist.update(&self.prot_traj)?;
        let _ant_metrics = self.antagonist.update(&self.ant_traj)?;

        let mut m = CycleMetrics::from_rollout(
            "paired", Some(prot_metrics), &prot_stats, 0.0,
        );
        m.mean_regret = self.last_mean_regret;
        m.adversary_loss = adv_metrics.total_loss() as f64;
        m.timers = self.editor_engine.take_timers();
        m.timers.accumulate(self.student_engine.take_timers());
        Ok(m)
    }

    fn student_params(&self) -> &[xla::Literal] {
        &self.protagonist.params.params
    }

    fn student_trainer(&mut self) -> &mut PpoTrainer {
        &mut self.protagonist
    }

    fn rollout_pool(&self) -> Arc<WorkerPool> {
        self.student_engine.pool().clone()
    }
}
