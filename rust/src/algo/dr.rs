//! Domain Randomization (paper §5.2).
//!
//! PureJaxRL-style training: B parallel envs roll the same policy on
//! uniformly-sampled levels and every trajectory trains the policy. Unlike
//! the PLR family, episode boundaries do *not* align with update cycles:
//! the `AutoResetWrapper` samples a fresh level whenever an episode ends,
//! and trailing episodes continue across update boundaries — the standard
//! RL treatment the paper argues for (its §5.2 critique of bundling DR
//! into PLR's fixed-level rollout scheme).

use anyhow::Result;

use super::{CycleMetrics, UedAlgorithm};
use crate::config::TrainConfig;
use crate::env::gen::LevelGenerator;
use crate::env::level::Level;
use crate::env::maze::{MazeEnv, MazeState, NUM_ACTIONS};
use crate::env::wrappers::AutoResetWrapper;
use crate::env::UnderspecifiedEnv;
use crate::ppo::{LrSchedule, PpoTrainer};
use crate::rollout::{Policy, RolloutEngine, Trajectory};
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

type DrEnv = AutoResetWrapper<MazeEnv, Box<dyn Fn(&mut Pcg64) -> Level>>;

/// The DR baseline.
pub struct DrAlgo {
    env: DrEnv,
    states: Vec<MazeState>,
    engine: RolloutEngine,
    traj: Trajectory,
    trainer: PpoTrainer,
    apply: std::rc::Rc<crate::runtime::executor::Executable>,
}

impl DrAlgo {
    pub fn new(rt: &Runtime, cfg: &TrainConfig, rng: &mut Pcg64) -> Result<DrAlgo> {
        let gen = LevelGenerator::new(cfg.max_walls);
        let maze = MazeEnv::new(cfg.max_episode_steps);
        let env: DrEnv = AutoResetWrapper::new(
            maze,
            Box::new(move |r: &mut Pcg64| gen.generate(r)) as Box<dyn Fn(&mut Pcg64) -> Level>,
        );
        let schedule = LrSchedule {
            lr0: cfg.lr,
            anneal: cfg.anneal_lr,
            total_updates: cfg.num_cycles(),
        };
        let trainer = PpoTrainer::new(
            rt, "student", &cfg.student_train_artifact(), cfg.seed as i32, schedule,
        )?;
        let apply = rt.load(&cfg.student_apply_artifact())?;
        let (t, b) = trainer.rollout_shape();
        let states = (0..b)
            .map(|_| {
                let l = gen.generate(rng);
                env.reset_to_level(&l, rng)
            })
            .collect();
        let engine = RolloutEngine::new(&env, b);
        let traj = Trajectory::new(t, b, &env.obs_components());
        Ok(DrAlgo { env, states, engine, traj, trainer, apply })
    }
}

impl UedAlgorithm for DrAlgo {
    fn name(&self) -> &'static str {
        "dr"
    }

    fn cycle(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        {
            let policy = Policy {
                apply: self.apply.clone(),
                params: &self.trainer.params.params,
                num_actions: NUM_ACTIONS,
            };
            self.engine.collect(&self.env, &mut self.states, &policy, &mut self.traj, rng)?;
        }
        let ppo = self.trainer.update(&self.traj)?;
        let stats = self.traj.episode_stats();
        Ok(CycleMetrics::from_rollout("dr", Some(ppo), &stats, 0.0))
    }

    fn student_params(&self) -> &[xla::Literal] {
        &self.trainer.params.params
    }

    fn student_trainer(&mut self) -> &mut PpoTrainer {
        &mut self.trainer
    }
}
