//! Domain Randomization (paper §5.2), generic over the environment family.
//!
//! PureJaxRL-style training: B parallel envs roll the same policy on
//! uniformly-sampled levels and every trajectory trains the policy. Unlike
//! the PLR family, episode boundaries do *not* align with update cycles:
//! the `AutoResetWrapper` samples a fresh level from the family's base
//! generator whenever an episode ends, and trailing episodes continue
//! across update boundaries — the standard RL treatment the paper argues
//! for (its §5.2 critique of bundling DR into PLR's fixed-level rollout
//! scheme).

use std::sync::Arc;

use anyhow::Result;

use super::{CycleMetrics, UedAlgorithm};
use crate::config::TrainConfig;
use crate::env::wrappers::AutoResetWrapper;
use crate::env::{EnvFamily, LevelGenerator, UnderspecifiedEnv};
use crate::ppo::{LrSchedule, PpoTrainer};
use crate::rollout::{Policy, RolloutEngine, Trajectory, WorkerPool};
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

type DrEnv<F> = AutoResetWrapper<<F as EnvFamily>::Env, <F as EnvFamily>::Generator>;
type DrState<F> = <<F as EnvFamily>::Env as UnderspecifiedEnv>::State;

/// The DR baseline.
pub struct DrAlgo<F: EnvFamily> {
    env: DrEnv<F>,
    states: Vec<DrState<F>>,
    engine: RolloutEngine,
    traj: Trajectory,
    trainer: PpoTrainer,
    apply: Arc<crate::runtime::executor::Executable>,
    num_actions: usize,
}

impl<F: EnvFamily> DrAlgo<F> {
    /// Driver with its own worker pool sized by `cfg.rollout_threads`.
    pub fn new(family: F, rt: &Runtime, cfg: &TrainConfig, rng: &mut Pcg64) -> Result<DrAlgo<F>> {
        let pool = Arc::new(WorkerPool::new(cfg.resolve_rollout_threads()));
        Self::with_pool(family, rt, cfg, rng, pool)
    }

    /// Driver over a caller-owned pool (seed packs hand every per-seed
    /// driver the same one so the host isn't oversubscribed N-fold).
    pub fn with_pool(
        family: F, rt: &Runtime, cfg: &TrainConfig, rng: &mut Pcg64,
        pool: Arc<WorkerPool>,
    ) -> Result<DrAlgo<F>> {
        let params = cfg.env_params();
        let env: DrEnv<F> = AutoResetWrapper::new(
            family.make_env(&params),
            family.make_generator(&params),
        );
        let schedule = LrSchedule {
            lr0: cfg.lr,
            anneal: cfg.anneal_lr,
            total_updates: cfg.num_cycles(),
        };
        let prefix = cfg.env.artifact_prefix();
        let trainer = PpoTrainer::new(
            rt,
            "student",
            &rt.resolve_name(prefix, &cfg.student_train_artifact()),
            cfg.seed as i32,
            schedule,
        )?;
        let apply = rt.load_scoped(prefix, &cfg.student_apply_artifact())?;
        let (t, b) = trainer.rollout_shape();
        let states = (0..b)
            .map(|_| {
                let l = env.generator.sample_level(rng);
                env.reset_to_level(&l, rng)
            })
            .collect();
        let engine = RolloutEngine::with_pool(&env, b, pool);
        let traj = Trajectory::new(t, b, &env.obs_components());
        let num_actions = env.num_actions();
        Ok(DrAlgo { env, states, engine, traj, trainer, apply, num_actions })
    }
}

impl<F: EnvFamily> UedAlgorithm for DrAlgo<F> {
    fn name(&self) -> &'static str {
        "dr"
    }

    fn cycle(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        {
            let policy = Policy {
                apply: self.apply.clone(),
                params: &self.trainer.params.params,
                num_actions: self.num_actions,
            };
            self.engine.collect(&self.env, &mut self.states, &policy, &mut self.traj, rng)?;
        }
        let ppo = self.trainer.update(&self.traj)?;
        let stats = self.traj.episode_stats();
        let mut m = CycleMetrics::from_rollout("dr", Some(ppo), &stats, 0.0);
        m.timers = self.engine.take_timers();
        Ok(m)
    }

    fn student_params(&self) -> &[xla::Literal] {
        &self.trainer.params.params
    }

    fn student_trainer(&mut self) -> &mut PpoTrainer {
        &mut self.trainer
    }

    fn rollout_pool(&self) -> Arc<WorkerPool> {
        self.engine.pool().clone()
    }
}
