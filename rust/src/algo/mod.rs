//! UED algorithm drivers and the shared training loop, generic over the
//! environment family.
//!
//! `UedAlgorithm` is the object-safe one-update-cycle interface every
//! method implements; the drivers themselves — [`dr::DrAlgo`],
//! [`plr::PlrAlgo`], [`paired::PairedAlgo`] — are generic over
//! [`EnvFamily`], so DR, the PLR family, and PAIRED run on *any* registered
//! environment with zero algorithm-code changes: [`build_algo`] and
//! [`train`] dispatch `cfg.env` through the env registry exactly the way
//! `cfg.algo` selects the method. [`train`] iterates cycles against the
//! paper's env-interaction budget accounting (§6), evaluating on the
//! selected family's holdout suite at a fixed cadence and logging CSV +
//! stdout metrics. [`orchestrator`] scales that to seed packs: N
//! concurrent per-seed runs interleaved over one shared worker pool
//! ([`train_pack`]), bit-identical to the solo runs.

pub mod dr;
pub mod meta_policy;
pub mod orchestrator;
pub mod paired;
pub mod plr;
pub mod scoring;

use std::sync::Arc;

use anyhow::Result;

pub use orchestrator::{train_pack, PackOutcome};

use crate::config::{Algo, TrainConfig};
use crate::env::registry::{dispatch, EnvVisitor};
use crate::env::EnvFamily;
use crate::eval::EvalReport;
use crate::ppo::{PpoTrainer, UpdateMetrics};
use crate::rollout::storage::EpisodeStats;
use crate::rollout::{PhaseTimers, WorkerPool};
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

/// Per-cycle summary returned by every algorithm.
#[derive(Clone, Debug, Default)]
pub struct CycleMetrics {
    /// Which subroutine ran ("dr" | "new" | "replay" | "mutate" | "paired").
    pub kind: &'static str,
    /// PPO metrics when a gradient update happened this cycle.
    pub total_loss: f64,
    pub value_loss: f64,
    pub entropy: f64,
    pub updated: bool,
    /// Rollout episode statistics (student / protagonist).
    pub episodes: u32,
    pub train_solve_rate: f64,
    pub mean_reward: f64,
    /// Level-buffer fill fraction (PLR family; 0 otherwise).
    pub buffer_fill: f64,
    /// PAIRED extras.
    pub mean_regret: f64,
    pub adversary_loss: f64,
    /// Per-phase engine wall times for this cycle (PAIRED sums its
    /// engines) — surfaced as `metrics.csv` columns so the
    /// forward/host-sweep overlap is verifiable per run.
    pub timers: PhaseTimers,
}

impl CycleMetrics {
    pub fn from_rollout(
        kind: &'static str, ppo: Option<UpdateMetrics>, stats: &[EpisodeStats],
        buffer_fill: f64,
    ) -> CycleMetrics {
        let episodes: u32 = stats.iter().map(|s| s.episodes).sum();
        let solved: u32 = stats.iter().map(|s| s.solved).sum();
        let reward: f64 = stats.iter().map(|s| s.reward_sum).sum();
        let mut m = CycleMetrics {
            kind,
            episodes,
            train_solve_rate: if episodes > 0 {
                solved as f64 / episodes as f64
            } else {
                0.0
            },
            // Per-*episode* mean reward: divide by completed episodes, not
            // by rollout columns (a column can finish several episodes —
            // or none — within one rollout).
            mean_reward: if episodes > 0 { reward / episodes as f64 } else { 0.0 },
            buffer_fill,
            ..Default::default()
        };
        if let Some(u) = ppo {
            m.updated = true;
            m.total_loss = u.total_loss() as f64;
            m.value_loss = u.get("value_loss").unwrap_or(f32::NAN) as f64;
            m.entropy = u.get("entropy").unwrap_or(f32::NAN) as f64;
        }
        m
    }
}

/// One-update-cycle interface implemented by every UED method; object-safe
/// so the training loop can hold any (algorithm × env family) pairing.
/// `Send` because a seed pack moves each driver onto its own thread
/// (`orchestrator::run_pack` scatter/gathers `TrainSeedRun`s).
pub trait UedAlgorithm: Send {
    fn name(&self) -> &'static str;

    /// Perform one update cycle (the Figure-1 unit of training).
    fn cycle(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics>;

    /// Student (protagonist) parameters, for evaluation.
    fn student_params(&self) -> &[xla::Literal];

    /// Student trainer (checkpointing).
    fn student_trainer(&mut self) -> &mut PpoTrainer;

    /// The driver's rollout worker pool — the training loop hands it to
    /// the evaluator so one process runs exactly one pool.
    fn rollout_pool(&self) -> Arc<WorkerPool>;
}

/// Instantiate the configured algorithm in a statically-known env family,
/// with its own worker pool sized by `cfg.rollout_threads`.
pub fn build_algo_for<F: EnvFamily>(
    family: F, rt: &Runtime, cfg: &TrainConfig, rng: &mut Pcg64,
) -> Result<Box<dyn UedAlgorithm>> {
    let pool = Arc::new(WorkerPool::new(cfg.resolve_rollout_threads()));
    build_algo_for_with_pool(family, rt, cfg, rng, pool)
}

/// [`build_algo_for`] over a caller-owned pool — the seed-pack
/// orchestrator hands every per-seed driver the same one, so one process
/// keeps exactly one pool no matter how many seeds it trains.
pub fn build_algo_for_with_pool<F: EnvFamily>(
    family: F, rt: &Runtime, cfg: &TrainConfig, rng: &mut Pcg64, pool: Arc<WorkerPool>,
) -> Result<Box<dyn UedAlgorithm>> {
    Ok(match cfg.algo {
        Algo::Dr => Box::new(dr::DrAlgo::with_pool(family, rt, cfg, rng, pool)?),
        Algo::Plr | Algo::RobustPlr | Algo::Accel => {
            Box::new(plr::PlrAlgo::with_pool(family, rt, cfg, pool)?)
        }
        Algo::Paired => Box::new(paired::PairedAlgo::with_pool(family, rt, cfg, pool)?),
    })
}

/// Instantiate the configured algorithm on the configured environment.
pub fn build_algo(
    rt: &Runtime, cfg: &TrainConfig, rng: &mut Pcg64,
) -> Result<Box<dyn UedAlgorithm>> {
    struct V<'a, 'r> {
        rt: &'a Runtime,
        cfg: &'a TrainConfig,
        rng: &'r mut Pcg64,
    }
    impl EnvVisitor for V<'_, '_> {
        type Out = Result<Box<dyn UedAlgorithm>>;
        fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
            build_algo_for(family, self.rt, self.cfg, self.rng)
        }
    }
    dispatch(cfg.env, V { rt, cfg, rng })
}

/// Outcome of a full training run.
pub struct TrainOutcome {
    pub final_eval: EvalReport,
    pub cycles: usize,
    pub env_steps: u64,
    pub wallclock_secs: f64,
    /// Extrapolated hours to the paper's 245.76M-step budget (Table 1).
    pub table1_hours: f64,
}

/// The shared training loop on the configured environment.
pub fn train(rt: &Runtime, cfg: &TrainConfig, quiet: bool) -> Result<TrainOutcome> {
    struct V<'a> {
        rt: &'a Runtime,
        cfg: &'a TrainConfig,
        quiet: bool,
    }
    impl EnvVisitor for V<'_> {
        type Out = Result<TrainOutcome>;
        fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
            train_family(family, self.rt, self.cfg, self.quiet)
        }
    }
    dispatch(cfg.env, V { rt, cfg, quiet })
}

/// The shared training loop: cycles → periodic eval → final report. Fully
/// generic — nothing in here (or below it) names a concrete environment.
/// A solo run is literally a [`orchestrator::TrainSeedRun`] (the seed-pack
/// unit) driven to completion, so pack and solo runs share one code path.
pub fn train_family<F: EnvFamily>(
    family: F, rt: &Runtime, cfg: &TrainConfig, quiet: bool,
) -> Result<TrainOutcome> {
    use orchestrator::SeedUnit as _;
    let pool = Arc::new(WorkerPool::new(cfg.resolve_rollout_threads()));
    let mut run = orchestrator::TrainSeedRun::new(family, rt, cfg, quiet, "", pool)?;
    while !run.done() {
        if let Err(e) = run.step_cycle() {
            // A mid-run failure still owes its buffered rows to disk:
            // flush before propagating so the abort loses nothing.
            let _ = run.flush_sinks();
            return Err(e);
        }
    }
    run.finish()
}
