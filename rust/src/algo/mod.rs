//! UED algorithm drivers and the shared training loop, generic over the
//! environment family.
//!
//! `UedAlgorithm` is the object-safe one-update-cycle interface every
//! method implements; the drivers themselves — [`dr::DrAlgo`],
//! [`plr::PlrAlgo`], [`paired::PairedAlgo`] — are generic over
//! [`EnvFamily`], so DR, the PLR family, and PAIRED run on *any* registered
//! environment with zero algorithm-code changes: [`build_algo`] and
//! [`train`] dispatch `cfg.env` through the env registry exactly the way
//! `cfg.algo` selects the method. [`train`] iterates cycles against the
//! paper's env-interaction budget accounting (§6), evaluating on the
//! selected family's holdout suite at a fixed cadence and logging CSV +
//! stdout metrics.

pub mod dr;
pub mod meta_policy;
pub mod paired;
pub mod plr;
pub mod scoring;

use std::sync::Arc;

use anyhow::Result;

use crate::config::{Algo, TrainConfig};
use crate::env::registry::{dispatch, EnvVisitor};
use crate::env::EnvFamily;
use crate::eval::{for_family_with_pool, EvalReport};
use crate::metrics::{log_stdout, CsvSink, Stopwatch};
use crate::ppo::{PpoTrainer, UpdateMetrics};
use crate::rollout::storage::EpisodeStats;
use crate::rollout::{Policy, WorkerPool};
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

/// Per-cycle summary returned by every algorithm.
#[derive(Clone, Debug, Default)]
pub struct CycleMetrics {
    /// Which subroutine ran ("dr" | "new" | "replay" | "mutate" | "paired").
    pub kind: &'static str,
    /// PPO metrics when a gradient update happened this cycle.
    pub total_loss: f64,
    pub value_loss: f64,
    pub entropy: f64,
    pub updated: bool,
    /// Rollout episode statistics (student / protagonist).
    pub episodes: u32,
    pub train_solve_rate: f64,
    pub mean_reward: f64,
    /// Level-buffer fill fraction (PLR family; 0 otherwise).
    pub buffer_fill: f64,
    /// PAIRED extras.
    pub mean_regret: f64,
    pub adversary_loss: f64,
}

impl CycleMetrics {
    pub fn from_rollout(
        kind: &'static str, ppo: Option<UpdateMetrics>, stats: &[EpisodeStats],
        buffer_fill: f64,
    ) -> CycleMetrics {
        let episodes: u32 = stats.iter().map(|s| s.episodes).sum();
        let solved: u32 = stats.iter().map(|s| s.solved).sum();
        let reward: f64 = stats.iter().map(|s| s.reward_sum).sum();
        let mut m = CycleMetrics {
            kind,
            episodes,
            train_solve_rate: if episodes > 0 {
                solved as f64 / episodes as f64
            } else {
                0.0
            },
            // Per-*episode* mean reward: divide by completed episodes, not
            // by rollout columns (a column can finish several episodes —
            // or none — within one rollout).
            mean_reward: if episodes > 0 { reward / episodes as f64 } else { 0.0 },
            buffer_fill,
            ..Default::default()
        };
        if let Some(u) = ppo {
            m.updated = true;
            m.total_loss = u.total_loss() as f64;
            m.value_loss = u.get("value_loss").unwrap_or(f32::NAN) as f64;
            m.entropy = u.get("entropy").unwrap_or(f32::NAN) as f64;
        }
        m
    }
}

/// One-update-cycle interface implemented by every UED method; object-safe
/// so the training loop can hold any (algorithm × env family) pairing.
pub trait UedAlgorithm {
    fn name(&self) -> &'static str;

    /// Perform one update cycle (the Figure-1 unit of training).
    fn cycle(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics>;

    /// Student (protagonist) parameters, for evaluation.
    fn student_params(&self) -> &[xla::Literal];

    /// Student trainer (checkpointing).
    fn student_trainer(&mut self) -> &mut PpoTrainer;

    /// The driver's rollout worker pool — the training loop hands it to
    /// the evaluator so one process runs exactly one pool.
    fn rollout_pool(&self) -> Arc<WorkerPool>;
}

/// Instantiate the configured algorithm in a statically-known env family.
pub fn build_algo_for<F: EnvFamily>(
    family: F, rt: &Runtime, cfg: &TrainConfig, rng: &mut Pcg64,
) -> Result<Box<dyn UedAlgorithm>> {
    Ok(match cfg.algo {
        Algo::Dr => Box::new(dr::DrAlgo::new(family, rt, cfg, rng)?),
        Algo::Plr | Algo::RobustPlr | Algo::Accel => {
            Box::new(plr::PlrAlgo::new(family, rt, cfg)?)
        }
        Algo::Paired => Box::new(paired::PairedAlgo::new(family, rt, cfg)?),
    })
}

/// Instantiate the configured algorithm on the configured environment.
pub fn build_algo(
    rt: &Runtime, cfg: &TrainConfig, rng: &mut Pcg64,
) -> Result<Box<dyn UedAlgorithm>> {
    struct V<'a, 'r> {
        rt: &'a Runtime,
        cfg: &'a TrainConfig,
        rng: &'r mut Pcg64,
    }
    impl EnvVisitor for V<'_, '_> {
        type Out = Result<Box<dyn UedAlgorithm>>;
        fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
            build_algo_for(family, self.rt, self.cfg, self.rng)
        }
    }
    dispatch(cfg.env, V { rt, cfg, rng })
}

/// Outcome of a full training run.
pub struct TrainOutcome {
    pub final_eval: EvalReport,
    pub cycles: usize,
    pub env_steps: u64,
    pub wallclock_secs: f64,
    /// Extrapolated hours to the paper's 245.76M-step budget (Table 1).
    pub table1_hours: f64,
}

/// The shared training loop on the configured environment.
pub fn train(rt: &Runtime, cfg: &TrainConfig, quiet: bool) -> Result<TrainOutcome> {
    struct V<'a> {
        rt: &'a Runtime,
        cfg: &'a TrainConfig,
        quiet: bool,
    }
    impl EnvVisitor for V<'_> {
        type Out = Result<TrainOutcome>;
        fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
            train_family(family, self.rt, self.cfg, self.quiet)
        }
    }
    dispatch(cfg.env, V { rt, cfg, quiet })
}

/// The shared training loop: cycles → periodic eval → final report. Fully
/// generic — nothing in here (or below it) names a concrete environment.
pub fn train_family<F: EnvFamily>(
    family: F, rt: &Runtime, cfg: &TrainConfig, quiet: bool,
) -> Result<TrainOutcome> {
    let mut rng = Pcg64::new(cfg.seed, 0x7261_696e); // "rain"
    let mut algo = build_algo_for(family, rt, cfg, &mut rng)?;
    let evaluator =
        for_family_with_pool(family, cfg, cfg.eval_trials, 20, algo.rollout_pool());
    let stu_apply = rt.load_scoped(
        cfg.env.artifact_prefix(),
        &cfg.student_apply_artifact(),
    )?;

    let run_dir = std::path::Path::new(&cfg.out_dir).join(cfg.run_name());
    let mut csv = CsvSink::create(
        &run_dir.join("metrics.csv"),
        &[
            "cycle", "env_steps", "loss", "value_loss", "entropy",
            "train_solve_rate", "episodes", "buffer_fill", "mean_regret",
            "eval_mean_solve", "eval_iqm_solve", "steps_per_sec",
        ],
    )?;

    let mut watch = Stopwatch::new();
    let total_cycles = cfg.num_cycles();
    let per_cycle = cfg.env_steps_per_cycle();
    let mut last_eval = (f64::NAN, f64::NAN);

    for cycle in 0..total_cycles {
        let m = algo.cycle(&mut rng)?;
        watch.add_steps(per_cycle);

        let do_eval = cfg.eval_interval > 0 && (cycle + 1) % cfg.eval_interval == 0;
        if do_eval {
            let policy = Policy {
                apply: stu_apply.clone(),
                params: algo.student_params(),
                num_actions: evaluator.num_actions(),
            };
            let report = evaluator.run(&policy, &mut rng)?;
            last_eval = (report.mean_solve_rate, report.iqm_solve_rate);
            if !quiet {
                log_stdout(
                    cycle,
                    watch.env_steps,
                    &[
                        ("eval_mean_solve", report.mean_solve_rate),
                        ("eval_iqm_solve", report.iqm_solve_rate),
                        ("sps", watch.steps_per_sec()),
                    ],
                );
            }
        }
        csv.write_row(&[
            cycle as f64,
            watch.env_steps as f64,
            m.total_loss,
            m.value_loss,
            m.entropy,
            m.train_solve_rate,
            m.episodes as f64,
            m.buffer_fill,
            m.mean_regret,
            last_eval.0,
            last_eval.1,
            watch.steps_per_sec(),
        ])?;
        if !quiet && (cycle % 16 == 0) {
            log_stdout(
                cycle,
                watch.env_steps,
                &[
                    ("loss", m.total_loss),
                    ("train_solve", m.train_solve_rate),
                    ("buffer", m.buffer_fill),
                    ("sps", watch.steps_per_sec()),
                ],
            );
        }
    }

    // Final checkpoint + evaluation.
    algo.student_trainer()
        .params
        .save(&run_dir.join("student.ckpt"))?;
    let policy = Policy {
        apply: stu_apply,
        params: algo.student_params(),
        num_actions: evaluator.num_actions(),
    };
    let final_eval = evaluator.run(&policy, &mut rng)?;
    Ok(TrainOutcome {
        cycles: total_cycles,
        env_steps: watch.env_steps,
        wallclock_secs: watch.elapsed_secs(),
        table1_hours: watch.extrapolate_hours(245_760_000),
        final_eval,
    })
}
