//! The replay-based UED family (paper §5.1): PLR, robust PLR (PLR⊥), and
//! ACCEL, as one driver with three subroutines — `on_new_levels`,
//! `on_replay_levels`, `on_mutate_levels` — selected each cycle by the
//! Figure-1 meta-policy. Generic over the environment family: level
//! generation, mutation, fingerprinting, and buffering all go through the
//! `LevelGenerator`/`LevelMutator`/`LevelMeta` capability traits.
//!
//! * PLR       (p = 0.5, q = 0): trains on new *and* replay cycles.
//! * PLR⊥      (p = 0.5, q = 0): trains on replay cycles only.
//! * ACCEL     (p = 0.8, q = 1): PLR⊥ + mutation cycles after every replay.
//!
//! Rollouts use `AutoReplayWrapper`: an episode that ends mid-rollout
//! restarts *the same level*, so a level's regret estimate can average over
//! multiple episodes (§5.2).

use std::sync::Arc;

use anyhow::Result;

use super::meta_policy::{Cycle, MetaPolicy};
use super::scoring::{LevelExtra, Scorer};
use super::{CycleMetrics, UedAlgorithm};
use crate::config::{Algo, TrainConfig};
use crate::env::wrappers::{AutoReplayWrapper, ReplayState};
use crate::env::{EnvFamily, LevelGenerator, LevelMeta, LevelMutator, UnderspecifiedEnv};
use crate::level_sampler::LevelSampler;
use crate::ppo::{LrSchedule, PpoTrainer};
use crate::rollout::{Policy, RolloutEngine, Trajectory, WorkerPool};
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

type PlrEnv<F> = AutoReplayWrapper<<F as EnvFamily>::Env>;

/// PLR / PLR⊥ / ACCEL driver.
pub struct PlrAlgo<F: EnvFamily> {
    /// Train on `on_new_levels` cycles too (plain PLR)?
    train_on_new: bool,
    /// Enable mutation cycles (ACCEL)?
    name: &'static str,
    gen: F::Generator,
    mutator: F::Mutator,
    meta: MetaPolicy,
    pub sampler: LevelSampler<F::Level, LevelExtra>,
    env: PlrEnv<F>,
    engine: RolloutEngine,
    traj: Trajectory,
    trainer: PpoTrainer,
    scorer: Scorer,
    apply: Arc<crate::runtime::executor::Executable>,
    num_actions: usize,
    /// Slot indices of the most recent replay batch (mutation parents).
    last_replayed: Vec<usize>,
    b: usize,
}

impl<F: EnvFamily> PlrAlgo<F> {
    /// Driver with its own worker pool sized by `cfg.rollout_threads`.
    pub fn new(family: F, rt: &Runtime, cfg: &TrainConfig) -> Result<PlrAlgo<F>> {
        let pool = Arc::new(WorkerPool::new(cfg.resolve_rollout_threads()));
        Self::with_pool(family, rt, cfg, pool)
    }

    /// Driver over a caller-owned pool (shared across a seed pack).
    pub fn with_pool(
        family: F, rt: &Runtime, cfg: &TrainConfig, pool: Arc<WorkerPool>,
    ) -> Result<PlrAlgo<F>> {
        let (train_on_new, name) = match cfg.algo {
            Algo::Plr => (true, "plr"),
            Algo::RobustPlr => (false, "robust_plr"),
            Algo::Accel => (false, "accel"),
            other => anyhow::bail!("PlrAlgo cannot run {other:?}"),
        };
        let schedule = LrSchedule {
            lr0: cfg.lr,
            anneal: cfg.anneal_lr,
            total_updates: cfg.num_cycles(),
        };
        let prefix = cfg.env.artifact_prefix();
        let trainer = PpoTrainer::new(
            rt,
            "student",
            &rt.resolve_name(prefix, &cfg.student_train_artifact()),
            cfg.seed as i32,
            schedule,
        )?;
        let apply = rt.load_scoped(prefix, &cfg.student_apply_artifact())?;
        let scorer = Scorer::new(
            rt.load_scoped(prefix, &cfg.score_artifact())?,
            cfg.score_fn,
        )?;
        let params = cfg.env_params();
        let env = AutoReplayWrapper::new(family.make_env(&params));
        let (t, b) = trainer.rollout_shape();
        let engine = RolloutEngine::with_pool(&env, b, pool);
        let traj = Trajectory::new(t, b, &env.obs_components());
        let num_actions = env.num_actions();
        Ok(PlrAlgo {
            train_on_new,
            name,
            gen: family.make_generator(&params),
            mutator: family.make_mutator(&params),
            meta: MetaPolicy::new(cfg.replay_prob, cfg.mutation_prob),
            sampler: LevelSampler::new(cfg.sampler_config()),
            env,
            engine,
            traj,
            trainer,
            scorer,
            apply,
            num_actions,
            last_replayed: Vec::new(),
            b,
        })
    }

    fn rollout(
        &mut self, levels: &[F::Level], rng: &mut Pcg64,
    ) -> Result<Vec<ReplayState<F::Env>>> {
        let mut states: Vec<ReplayState<F::Env>> = levels
            .iter()
            .map(|l| self.env.reset_to_level(l, rng))
            .collect();
        let policy = Policy {
            apply: self.apply.clone(),
            params: &self.trainer.params.params,
            num_actions: self.num_actions,
        };
        self.engine.collect(&self.env, &mut states, &policy, &mut self.traj, rng)?;
        Ok(states)
    }

    /// `on_new_levels`: random levels → rollout → score → insert;
    /// plain PLR also trains on the trajectories.
    fn on_new_levels(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        let levels = self.gen.sample_batch(self.b, rng);
        self.rollout(&levels, rng)?;
        let batch = self.scorer.score(&self.traj, &vec![0.0; self.b])?;
        let fingerprints: Vec<u64> = levels.iter().map(|l| l.fingerprint()).collect();
        self.sampler.insert_batch(&levels, &batch.scores, &fingerprints, &batch.extras);
        let ppo = if self.train_on_new {
            Some(self.trainer.update(&self.traj)?)
        } else {
            None
        };
        let stats = self.traj.episode_stats();
        Ok(CycleMetrics::from_rollout("new", ppo, &stats, self.sampler.proportion_filled()))
    }

    /// `on_replay_levels`: sample buffer levels → rollout → train → rescore.
    fn on_replay_levels(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        let indices = self.sampler.sample_replay_indices(self.b, rng);
        // (buffer holds >= B levels whenever replay is gated on; tail-pad
        // by repeating if a tiny buffer config says otherwise)
        let mut idx = indices.clone();
        while idx.len() < self.b {
            idx.push(idx[idx.len() % indices.len().max(1)]);
        }
        let levels: Vec<F::Level> =
            idx.iter().map(|&i| self.sampler.get(i).level.clone()).collect();
        let prev_max: Vec<f32> = idx
            .iter()
            .map(|&i| self.sampler.get(i).extra.max_return)
            .collect();
        self.rollout(&levels, rng)?;
        let batch = self.scorer.score(&self.traj, &prev_max)?;
        self.sampler.update_batch(&idx, &batch.scores, &batch.extras);
        let ppo = self.trainer.update(&self.traj)?;
        self.last_replayed = idx;
        let stats = self.traj.episode_stats();
        Ok(CycleMetrics::from_rollout(
            "replay", Some(ppo), &stats, self.sampler.proportion_filled(),
        ))
    }

    /// `on_mutate_levels`: mutate the last replay batch → rollout → score →
    /// insert children (no policy update — ACCEL evaluates children only).
    fn on_mutate_levels(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        debug_assert!(!self.last_replayed.is_empty());
        let parents: Vec<F::Level> = self
            .last_replayed
            .iter()
            .map(|&i| self.sampler.get(i).level.clone())
            .collect();
        let children = self.mutator.mutate_batch(&parents, rng);
        self.rollout(&children, rng)?;
        let batch = self.scorer.score(&self.traj, &vec![0.0; self.b])?;
        let fingerprints: Vec<u64> = children.iter().map(|l| l.fingerprint()).collect();
        self.sampler.insert_batch(&children, &batch.scores, &fingerprints, &batch.extras);
        let stats = self.traj.episode_stats();
        Ok(CycleMetrics::from_rollout(
            "mutate", None, &stats, self.sampler.proportion_filled(),
        ))
    }
}

impl<F: EnvFamily> UedAlgorithm for PlrAlgo<F> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn cycle(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        let can_replay = self.sampler.can_replay() && self.sampler.len() >= 1;
        let mut m = match self.meta.next(can_replay, rng) {
            Cycle::Dr => self.on_new_levels(rng),
            Cycle::Replay => self.on_replay_levels(rng),
            Cycle::Mutate => self.on_mutate_levels(rng),
        }?;
        m.timers = self.engine.take_timers();
        Ok(m)
    }

    fn student_params(&self) -> &[xla::Literal] {
        &self.trainer.params.params
    }

    fn student_trainer(&mut self) -> &mut PpoTrainer {
        &mut self.trainer
    }

    fn rollout_pool(&self) -> Arc<WorkerPool> {
        self.engine.pool().clone()
    }
}
