//! The replay-based UED family (paper §5.1): PLR, robust PLR (PLR⊥), and
//! ACCEL, as one driver with three subroutines — `on_new_levels`,
//! `on_replay_levels`, `on_mutate_levels` — selected each cycle by the
//! Figure-1 meta-policy.
//!
//! * PLR       (p = 0.5, q = 0): trains on new *and* replay cycles.
//! * PLR⊥      (p = 0.5, q = 0): trains on replay cycles only.
//! * ACCEL     (p = 0.8, q = 1): PLR⊥ + mutation cycles after every replay.
//!
//! Rollouts use `AutoReplayWrapper`: an episode that ends mid-rollout
//! restarts *the same level*, so a level's regret estimate can average over
//! multiple episodes (§5.2).

use anyhow::Result;

use super::meta_policy::{Cycle, MetaPolicy};
use super::scoring::{LevelExtra, Scorer};
use super::{CycleMetrics, UedAlgorithm};
use crate::config::{Algo, TrainConfig};
use crate::env::gen::LevelGenerator;
use crate::env::level::Level;
use crate::env::maze::{MazeEnv, NUM_ACTIONS};
use crate::env::mutate::Mutator;
use crate::env::wrappers::{AutoReplayWrapper, ReplayState};
use crate::env::UnderspecifiedEnv;
use crate::level_sampler::LevelSampler;
use crate::ppo::{LrSchedule, PpoTrainer};
use crate::rollout::{Policy, RolloutEngine, Trajectory};
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

type PlrEnv = AutoReplayWrapper<MazeEnv>;

/// PLR / PLR⊥ / ACCEL driver.
pub struct PlrAlgo {
    /// Train on `on_new_levels` cycles too (plain PLR)?
    train_on_new: bool,
    /// Enable mutation cycles (ACCEL)?
    name: &'static str,
    gen: LevelGenerator,
    mutator: Mutator,
    meta: MetaPolicy,
    pub sampler: LevelSampler<Level, LevelExtra>,
    env: PlrEnv,
    engine: RolloutEngine,
    traj: Trajectory,
    trainer: PpoTrainer,
    scorer: Scorer,
    apply: std::rc::Rc<crate::runtime::executor::Executable>,
    /// Slot indices of the most recent replay batch (mutation parents).
    last_replayed: Vec<usize>,
    b: usize,
}

impl PlrAlgo {
    pub fn new(rt: &Runtime, cfg: &TrainConfig) -> Result<PlrAlgo> {
        let (train_on_new, name) = match cfg.algo {
            Algo::Plr => (true, "plr"),
            Algo::RobustPlr => (false, "robust_plr"),
            Algo::Accel => (false, "accel"),
            other => anyhow::bail!("PlrAlgo cannot run {other:?}"),
        };
        let schedule = LrSchedule {
            lr0: cfg.lr,
            anneal: cfg.anneal_lr,
            total_updates: cfg.num_cycles(),
        };
        let trainer = PpoTrainer::new(
            rt, "student", &cfg.student_train_artifact(), cfg.seed as i32, schedule,
        )?;
        let apply = rt.load(&cfg.student_apply_artifact())?;
        let scorer = Scorer::new(rt.load(&cfg.score_artifact())?, cfg.score_fn)?;
        let env = AutoReplayWrapper::new(MazeEnv::new(cfg.max_episode_steps));
        let (t, b) = trainer.rollout_shape();
        let engine = RolloutEngine::new(&env, b);
        let traj = Trajectory::new(t, b, &env.obs_components());
        Ok(PlrAlgo {
            train_on_new,
            name,
            gen: LevelGenerator::new(cfg.max_walls),
            mutator: Mutator { num_edits: cfg.num_edits, ..Default::default() },
            meta: MetaPolicy::new(cfg.replay_prob, cfg.mutation_prob),
            sampler: LevelSampler::new(cfg.sampler_config()),
            env,
            engine,
            traj,
            trainer,
            scorer,
            apply,
            last_replayed: Vec::new(),
            b,
        })
    }

    fn rollout(
        &mut self, levels: &[Level], rng: &mut Pcg64,
    ) -> Result<Vec<ReplayState<MazeEnv>>> {
        let mut states: Vec<ReplayState<MazeEnv>> = levels
            .iter()
            .map(|l| self.env.reset_to_level(l, rng))
            .collect();
        let policy = Policy {
            apply: self.apply.clone(),
            params: &self.trainer.params.params,
            num_actions: NUM_ACTIONS,
        };
        self.engine.collect(&self.env, &mut states, &policy, &mut self.traj, rng)?;
        Ok(states)
    }

    /// `on_new_levels`: random levels → rollout → score → insert;
    /// plain PLR also trains on the trajectories.
    fn on_new_levels(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        let levels = self.gen.generate_batch(self.b, rng);
        self.rollout(&levels, rng)?;
        let batch = self.scorer.score(&self.traj, &vec![0.0; self.b])?;
        let fingerprints: Vec<u64> = levels.iter().map(|l| l.fingerprint()).collect();
        self.sampler.insert_batch(&levels, &batch.scores, &fingerprints, &batch.extras);
        let ppo = if self.train_on_new {
            Some(self.trainer.update(&self.traj)?)
        } else {
            None
        };
        let stats = self.traj.episode_stats();
        Ok(CycleMetrics::from_rollout("new", ppo, &stats, self.sampler.proportion_filled()))
    }

    /// `on_replay_levels`: sample buffer levels → rollout → train → rescore.
    fn on_replay_levels(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        let indices = self.sampler.sample_replay_indices(self.b, rng);
        // (buffer holds >= B levels whenever replay is gated on; tail-pad
        // by repeating if a tiny buffer config says otherwise)
        let mut idx = indices.clone();
        while idx.len() < self.b {
            idx.push(idx[idx.len() % indices.len().max(1)]);
        }
        let levels: Vec<Level> = idx.iter().map(|&i| self.sampler.get(i).level).collect();
        let prev_max: Vec<f32> = idx
            .iter()
            .map(|&i| self.sampler.get(i).extra.max_return)
            .collect();
        self.rollout(&levels, rng)?;
        let batch = self.scorer.score(&self.traj, &prev_max)?;
        self.sampler.update_batch(&idx, &batch.scores, &batch.extras);
        let ppo = self.trainer.update(&self.traj)?;
        self.last_replayed = idx;
        let stats = self.traj.episode_stats();
        Ok(CycleMetrics::from_rollout(
            "replay", Some(ppo), &stats, self.sampler.proportion_filled(),
        ))
    }

    /// `on_mutate_levels`: mutate the last replay batch → rollout → score →
    /// insert children (no policy update — ACCEL evaluates children only).
    fn on_mutate_levels(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        debug_assert!(!self.last_replayed.is_empty());
        let parents: Vec<Level> = self
            .last_replayed
            .iter()
            .map(|&i| self.sampler.get(i).level)
            .collect();
        let children = self.mutator.mutate_batch(&parents, rng);
        self.rollout(&children, rng)?;
        let batch = self.scorer.score(&self.traj, &vec![0.0; self.b])?;
        let fingerprints: Vec<u64> = children.iter().map(|l| l.fingerprint()).collect();
        self.sampler.insert_batch(&children, &batch.scores, &fingerprints, &batch.extras);
        let stats = self.traj.episode_stats();
        Ok(CycleMetrics::from_rollout(
            "mutate", None, &stats, self.sampler.proportion_filled(),
        ))
    }
}

impl UedAlgorithm for PlrAlgo {
    fn name(&self) -> &'static str {
        self.name
    }

    fn cycle(&mut self, rng: &mut Pcg64) -> Result<CycleMetrics> {
        let can_replay = self.sampler.can_replay() && self.sampler.len() >= 1;
        match self.meta.next(can_replay, rng) {
            Cycle::Dr => self.on_new_levels(rng),
            Cycle::Replay => self.on_replay_levels(rng),
            Cycle::Mutate => self.on_mutate_levels(rng),
        }
    }

    fn student_params(&self) -> &[xla::Literal] {
        &self.trainer.params.params
    }

    fn student_trainer(&mut self) -> &mut PpoTrainer {
        &mut self.trainer
    }
}
