//! Level scoring for the PLR family: bridges rollout trajectories to the
//! `score_*` artifact (PVL / MaxMC regret estimates — a single GAE
//! implementation, the L1 Pallas kernel, serves both scoring and training).
//!
//! The MaxMC estimator needs the highest return ever observed on each
//! level; that carry lives in the buffer's `level_extra` (paper §3.3) and
//! is threaded through the artifact as `prev_max_return`.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::ScoreFn;
use crate::rollout::Trajectory;
use crate::runtime::executor::Executable;
use crate::util::tensor::TensorF32;

/// Per-level auxiliary data stored in the level buffer (`level_extra`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LevelExtra {
    /// Highest discounted return-to-go observed on this level (MaxMC carry).
    pub max_return: f32,
    /// Mean value estimate from the most recent scoring rollout.
    pub mean_value: f32,
}

/// Output of one scoring call.
#[derive(Clone, Debug)]
pub struct ScoreBatch {
    /// Selected regret estimate per level (the buffer score).
    pub scores: Vec<f64>,
    /// Updated `level_extra` per level.
    pub extras: Vec<LevelExtra>,
}

/// Wraps the `score_t{T}_b{B}` artifact.
pub struct Scorer {
    exe: Arc<Executable>,
    pub score_fn: ScoreFn,
    b: usize,
}

impl Scorer {
    pub fn new(exe: Arc<Executable>, score_fn: ScoreFn) -> Result<Scorer> {
        let b = exe.def.b.ok_or_else(|| anyhow::anyhow!("score artifact missing B"))?;
        if exe.def.outputs.len() != 4 {
            bail!("score artifact must have 4 outputs (pvl, maxmc, max_return, mean_value)");
        }
        Ok(Scorer { exe, score_fn, b })
    }

    /// Score a trajectory batch. `prev_max_returns[b]` is the MaxMC carry
    /// for the level in column b (0 for fresh levels).
    pub fn score(&self, traj: &Trajectory, prev_max_returns: &[f32]) -> Result<ScoreBatch> {
        if prev_max_returns.len() != self.b {
            bail!("prev_max_returns has {} entries, B={}", prev_max_returns.len(), self.b);
        }
        let mut args = traj.score_args()?;
        args.push(
            TensorF32::from_vec(&[self.b], prev_max_returns.to_vec())?.to_literal()?,
        );
        let out = self.exe.call(&args)?;
        let pvl = out[0].to_vec::<f32>()?;
        let maxmc = out[1].to_vec::<f32>()?;
        let max_ret = out[2].to_vec::<f32>()?;
        let mean_value = out[3].to_vec::<f32>()?;
        let chosen = match self.score_fn {
            ScoreFn::Pvl => &pvl,
            ScoreFn::MaxMc => &maxmc,
        };
        Ok(ScoreBatch {
            scores: chosen.iter().map(|&x| x as f64).collect(),
            extras: max_ret
                .iter()
                .zip(&mean_value)
                .map(|(&mr, &mv)| LevelExtra { max_return: mr, mean_value: mv })
                .collect(),
        })
    }
}
