//! The replay-based meta-policy (paper Figure 1): a fixed stochastic
//! policy over a two-state MDP that decides which update-cycle to perform
//! next.
//!
//! Transition matrix (rows = current stage, columns = next cycle):
//!
//! ```text
//!              DR            Replay      Mutation
//!   DR      [  1−p           p           0        ]
//!   Replay  [ (1−p)(1−q)     p(1−q)      q        ]
//! ```
//!
//! `p` is the replay probability, `q` the mutation probability (q = 1 for
//! ACCEL — a mutation cycle always follows a replay cycle; q = 0
//! otherwise). Replay is additionally gated on the level buffer being
//! filled past its threshold; when the gate is closed the replay mass
//! falls back to DR.

use crate::util::rng::Pcg64;

/// The kind of update-cycle to perform (paper §5.1 subroutines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cycle {
    /// `on_new_levels`: generate random levels, roll out, score, insert.
    Dr,
    /// `on_replay_levels`: sample from the buffer, roll out, train, update.
    Replay,
    /// `on_mutate_levels`: mutate the last replayed batch, roll out, score.
    Mutate,
}

/// Figure-1 meta-policy state machine.
#[derive(Clone, Debug)]
pub struct MetaPolicy {
    pub p_replay: f64,
    pub q_mutate: f64,
    last: Cycle,
}

impl MetaPolicy {
    pub fn new(p_replay: f64, q_mutate: f64) -> MetaPolicy {
        assert!((0.0..=1.0).contains(&p_replay));
        assert!((0.0..=1.0).contains(&q_mutate));
        MetaPolicy { p_replay, q_mutate, last: Cycle::Dr }
    }

    /// Decide the next update-cycle. `can_replay` is the buffer-fill gate.
    pub fn next(&mut self, can_replay: bool, rng: &mut Pcg64) -> Cycle {
        let cycle = if self.last == Cycle::Replay && rng.gen_bool(self.q_mutate) {
            Cycle::Mutate
        } else if can_replay && rng.gen_bool(self.p_replay) {
            Cycle::Replay
        } else {
            Cycle::Dr
        };
        self.last = cycle;
        cycle
    }

    /// The theoretical transition row for a given stage (tests/diagnostics;
    /// the `jaxued bench-env --meta-policy` subcommand prints this).
    pub fn transition_row(&self, from: Cycle) -> [f64; 3] {
        let (p, q) = (self.p_replay, self.q_mutate);
        match from {
            Cycle::Dr | Cycle::Mutate => [1.0 - p, p, 0.0],
            Cycle::Replay => [(1.0 - p) * (1.0 - q), p * (1.0 - q), q],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::props;

    /// Empirical next-cycle frequencies when the machine is pinned to stage
    /// `from` (measures one row of the transition matrix).
    fn empirical_row(p: f64, q: f64, from: Cycle, n: usize) -> [f64; 3] {
        let mut rng = Pcg64::seed_from_u64(7);
        let mut counts = [0usize; 3];
        let mut mp = MetaPolicy::new(p, q);
        mp.last = from;
        for _ in 0..n {
            let c = mp.next(true, &mut rng);
            counts[c as usize] += 1;
            mp.last = from; // pin the source stage
        }
        [
            counts[0] as f64 / n as f64,
            counts[1] as f64 / n as f64,
            counts[2] as f64 / n as f64,
        ]
    }

    #[test]
    fn dr_row_matches_matrix() {
        let emp = empirical_row(0.5, 1.0, Cycle::Dr, 40_000);
        let theory = MetaPolicy::new(0.5, 1.0).transition_row(Cycle::Dr);
        for (e, t) in emp.iter().zip(&theory) {
            assert!((e - t).abs() < 0.01, "{emp:?} vs {theory:?}");
        }
    }

    #[test]
    fn replay_row_matches_matrix() {
        // q = 0.3 exercises all three columns from the replay stage
        let emp = empirical_row(0.6, 0.3, Cycle::Replay, 40_000);
        let theory = MetaPolicy::new(0.6, 0.3).transition_row(Cycle::Replay);
        for (e, t) in emp.iter().zip(&theory) {
            assert!((e - t).abs() < 0.01, "{emp:?} vs {theory:?}");
        }
    }

    #[test]
    fn accel_always_mutates_after_replay() {
        let mut mp = MetaPolicy::new(0.8, 1.0);
        let mut rng = Pcg64::seed_from_u64(1);
        let mut saw_replay = false;
        for _ in 0..1000 {
            let c = mp.next(true, &mut rng);
            if saw_replay {
                assert_eq!(c, Cycle::Mutate, "q=1 must mutate after replay");
            }
            saw_replay = c == Cycle::Replay;
        }
    }

    #[test]
    fn plr_never_mutates() {
        let mut mp = MetaPolicy::new(0.5, 0.0);
        let mut rng = Pcg64::seed_from_u64(2);
        for _ in 0..2000 {
            assert_ne!(mp.next(true, &mut rng), Cycle::Mutate);
        }
    }

    #[test]
    fn gate_forces_dr() {
        let mut mp = MetaPolicy::new(1.0, 1.0);
        let mut rng = Pcg64::seed_from_u64(3);
        for _ in 0..100 {
            assert_eq!(mp.next(false, &mut rng), Cycle::Dr);
        }
    }

    #[test]
    fn prop_rows_are_distributions() {
        props(100, |g| {
            let p = g.f64_in(0.0, 1.0);
            let q = g.f64_in(0.0, 1.0);
            let mp = MetaPolicy::new(p, q);
            for from in [Cycle::Dr, Cycle::Replay, Cycle::Mutate] {
                let row = mp.transition_row(from);
                let sum: f64 = row.iter().sum();
                prop_assert!((sum - 1.0).abs() < 1e-12, "row sums to {sum}");
                prop_assert!(row.iter().all(|&x| x >= 0.0), "negative prob");
            }
            Ok(())
        });
    }
}
