//! `jaxued` — the launcher.
//!
//! Subcommands:
//!   train       run a UED algorithm (DR | PLR | PLR⊥ | ACCEL | PAIRED)
//!               on any registered env (`--env maze|lava`)
//!   eval        evaluate a checkpoint on the selected env's holdout suite
//!   render      render the maze holdout suite / generated levels to PPM
//!   meta-policy print the Figure-1 transition matrix + empirical rates
//!   info        print manifest + artifact inventory
//!
//! Examples:
//!   jaxued train --algo accel --seed 1 --env-steps 1000000
//!   jaxued train --algo plr --seeds 0..8 --env-steps 1000000
//!   jaxued train --algo paired --env lava --variant small --env-steps 50000
//!   jaxued eval --ckpt runs/dr_s0/student.ckpt
//!   jaxued eval --env lava --ckpt runs/lava_dr_s0/student.ckpt
//!   jaxued render --out figure2.ppm

use std::path::Path;

use anyhow::Result;

use jaxued::algo::meta_policy::{Cycle, MetaPolicy};
use jaxued::algo::{train, train_pack};
use jaxued::config::TrainConfig;
use jaxued::util::stats;
use jaxued::env::gen::MazeLevelGenerator;
use jaxued::env::holdout;
use jaxued::env::render::render_montage;
use jaxued::eval::evaluate_params;
use jaxued::runtime::{ParamSet, Runtime};
use jaxued::util::cli::Args;
use jaxued::util::rng::Pcg64;

const USAGE: &str = "usage: jaxued <train|eval|render|meta-policy|info> [flags]
see README.md for per-command flags";

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "render" => cmd_render(&args),
        "meta-policy" => cmd_meta_policy(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let unknown = args.unknown_flags();
    if !unknown.is_empty() {
        anyhow::bail!("unknown flags: {unknown:?}");
    }
    if !cfg.pack_seeds.is_empty() {
        return cmd_train_pack(&cfg);
    }
    println!(
        "jaxued train: env={} algo={} seed={} variant={} budget={} env steps ({} cycles), {} rollout threads",
        cfg.env.name(), cfg.algo.name(), cfg.seed, cfg.variant.name,
        cfg.env_steps_budget, cfg.num_cycles(), cfg.resolve_rollout_threads(),
    );
    let rt = Runtime::with_geometry(Path::new(&cfg.artifacts_dir), &cfg.env.geometry())?;
    let outcome = train(&rt, &cfg, false)?;
    println!(
        "done: {} cycles, {} env steps in {:.1}s ({:.0} steps/s)",
        outcome.cycles, outcome.env_steps, outcome.wallclock_secs,
        outcome.env_steps as f64 / outcome.wallclock_secs,
    );
    println!(
        "final eval: mean_solve={:.3} iqm_solve={:.3}",
        outcome.final_eval.mean_solve_rate, outcome.final_eval.iqm_solve_rate,
    );
    println!(
        "Table-1 extrapolation: {:.2} h for 245.76M steps",
        outcome.table1_hours,
    );
    Ok(())
}

/// `train --seeds a..b` / `--num-seeds N`: every seed trains concurrently
/// in this process, interleaved cycle-by-cycle over one shared rollout
/// worker pool.
fn cmd_train_pack(cfg: &TrainConfig) -> Result<()> {
    let seeds = cfg.seed_list();
    println!(
        "jaxued train pack: env={} algo={} seeds={:?} variant={} budget={} env steps \
         ({} cycles) per seed, {} concurrent runs on {} driver threads over one \
         {}-thread pool",
        cfg.env.name(), cfg.algo.name(), seeds, cfg.variant.name,
        cfg.env_steps_budget, cfg.num_cycles(), seeds.len(),
        cfg.resolve_drivers(seeds.len()), cfg.resolve_rollout_threads(),
    );
    let rt = Runtime::with_geometry(Path::new(&cfg.artifacts_dir), &cfg.env.geometry())?;
    let pack = train_pack(&rt, cfg, false)?;
    println!("done: {} seeds x {} cycles, {} total env steps", seeds.len(),
        cfg.num_cycles(), pack.total_env_steps());
    for (seed, o) in pack.seeds.iter().zip(&pack.outcomes) {
        println!(
            "  seed {seed}: mean_solve={:.3} iqm_solve={:.3} ({:.0} steps/s)",
            o.final_eval.mean_solve_rate, o.final_eval.iqm_solve_rate,
            o.env_steps as f64 / o.wallclock_secs,
        );
    }
    let finals = pack.final_mean_solves();
    println!(
        "cross-seed final eval (Figure-3 aggregate): mean={:.3} iqm={:.3} stderr={:.3}",
        stats::mean(&finals), stats::iqm(&finals), stats::std_err(&finals),
    );
    println!(
        "pack manifest + per-cycle aggregate.csv in {}",
        pack.pack_dir.display(),
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let default_ckpt = format!("runs/{}/student.ckpt", cfg.run_name());
    let ckpt = args.get_str("ckpt", &default_ckpt);
    let trials = args.get_usize("trials", 10);
    let rt = Runtime::with_geometry(Path::new(&cfg.artifacts_dir), &cfg.env.geometry())?;
    let params = ParamSet::load(Path::new(&ckpt), "student")?;
    let mut rng = Pcg64::new(cfg.seed, 0x6576); // "ev"
    let report = evaluate_params(&rt, &cfg, &params, trials, 20, &mut rng)?;
    println!("{:<22} {:>10} {:>12}", "level", "solve", "mean_steps");
    for l in &report.levels {
        println!("{:<22} {:>10.3} {:>12.1}", l.name, l.solve_rate, l.mean_steps);
    }
    println!(
        "mean={:.3} iqm={:.3}",
        report.mean_solve_rate, report.iqm_solve_rate,
    );
    Ok(())
}

fn cmd_render(args: &Args) -> Result<()> {
    let out = args.get_str("out", "holdout.ppm");
    let n_proc = args.get_usize("procedural", 12);
    let max_walls = args.get_usize("max-walls", 60);
    let seed = args.get_u64("seed", 0xE7A1);
    let mut levels: Vec<_> = holdout::named_levels().into_iter().map(|n| n.level).collect();
    if args.has("random") {
        let gen = MazeLevelGenerator::new(max_walls);
        let mut rng = Pcg64::seed_from_u64(seed);
        levels = gen.generate_batch(n_proc.max(1), &mut rng);
    } else {
        levels.extend(holdout::procedural_suite(n_proc, max_walls, seed));
    }
    let img = render_montage(&levels, 6);
    img.write_ppm(Path::new(&out))?;
    println!("wrote {} levels to {out} ({}x{})", levels.len(), img.width, img.height);
    Ok(())
}

fn cmd_meta_policy(args: &Args) -> Result<()> {
    let p = args.get_f64("p", 0.5);
    let q = args.get_f64("q", 1.0);
    let n = args.get_usize("samples", 100_000);
    let mp = MetaPolicy::new(p, q);
    println!("Figure-1 meta-policy (p={p}, q={q})");
    println!("{:<10} {:>8} {:>8} {:>8}", "stage", "DR", "Replay", "Mutate");
    for (name, stage) in [("DR", Cycle::Dr), ("Replay", Cycle::Replay)] {
        let row = mp.transition_row(stage);
        println!("{:<10} {:>8.3} {:>8.3} {:>8.3}  (theory)", name, row[0], row[1], row[2]);
    }
    // empirical long-run frequencies of each cycle kind
    let mut mp = MetaPolicy::new(p, q);
    let mut rng = Pcg64::seed_from_u64(0);
    let mut counts = [0usize; 3];
    for _ in 0..n {
        counts[mp.next(true, &mut rng) as usize] += 1;
    }
    println!(
        "empirical long-run: DR={:.3} Replay={:.3} Mutate={:.3} ({n} draws)",
        counts[0] as f64 / n as f64,
        counts[1] as f64 / n as f64,
        counts[2] as f64 / n as f64,
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let rt = Runtime::new(Path::new(&dir))?;
    let m = &rt.manifest;
    println!("platform: {}", rt.client.platform_name());
    println!(
        "grid {}x{}, view {}, actions {}, adversary actions {}",
        m.constants.grid_w, m.constants.grid_h, m.constants.view,
        m.constants.num_actions, m.constants.adv_num_actions,
    );
    println!("networks:");
    for (name, net) in &m.networks {
        println!(
            "  {:<10} {} tensors, {} parameters",
            name, net.num_params(), net.total_elements(),
        );
    }
    println!("artifacts ({}):", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!(
            "  {:<34} kind={:<10} {} in / {} out",
            name, a.kind, a.inputs.len(), a.outputs.len(),
        );
    }
    Ok(())
}
