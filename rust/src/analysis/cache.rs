//! Incremental lint cache: per-file front-end results keyed by
//! (mtime, content hash).
//!
//! The expensive part of a lint run is the per-file front-end — lexing,
//! item parsing, body scans, per-file rules. Those depend only on the
//! file's bytes and its (path-derived) profile, so they are cached in a
//! single JSON file keyed by modification time *and* an FNV-1a content
//! hash: mtime alone races with editors that preserve timestamps, a
//! hash alone would still pay for reading — we read anyway, so checking
//! both is free. The global analyses (call graph, taint, panic, lock
//! order) are cross-file and cheap; they always re-run over the cached
//! function summaries, so a one-file edit re-parses one file but still
//! re-checks the whole graph.
//!
//! Cache corruption of any kind — unreadable file, version skew,
//! malformed entries — degrades to a cold run, never an error.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;
use std::time::UNIX_EPOCH;

use crate::util::json::Json;

use super::parser::{Call, FnInfo, HeldCall, LockEdge, LockSite, Site};
use super::{Allow, FileRecord, Rule, Violation};

/// Bump whenever the serialized shape or the per-file pass changes
/// meaning; old caches are then ignored wholesale.
/// v2: per-function CFG/dataflow summaries (`held_may_calls`) and the
/// flow-sensitive per-file findings they feed.
pub const CACHE_VERSION: usize = 2;

/// 64-bit FNV-1a. Not cryptographic — it only needs to catch edits that
/// preserve mtime, and it must not pull in a hash dependency.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The file's mtime in nanoseconds since the epoch, as a string (JSON
/// numbers are f64 and would lose nanosecond precision). Unreadable
/// metadata becomes `"0"`, which simply never matches a stored entry.
pub fn mtime_ns(path: &Path) -> String {
    fs::metadata(path)
        .and_then(|m| m.modified())
        .ok()
        .and_then(|t| t.duration_since(UNIX_EPOCH).ok())
        .map(|d| d.as_nanos().to_string())
        .unwrap_or_else(|| String::from("0"))
}

/// The on-disk cache: entries stay as parsed JSON and deserialize only
/// on a key match, so a stale cache costs nothing.
#[derive(Default)]
pub struct Cache {
    entries: BTreeMap<String, Json>,
}

impl Cache {
    /// Load from `path`; any failure yields an empty cache.
    pub fn load(path: &Path) -> Cache {
        let Ok(text) = fs::read_to_string(path) else {
            return Cache::default();
        };
        let Ok(root) = Json::parse(&text) else {
            return Cache::default();
        };
        if root.get("version").and_then(Json::as_usize) != Some(CACHE_VERSION) {
            return Cache::default();
        }
        let Some(files) = root.get("files").and_then(Json::as_obj) else {
            return Cache::default();
        };
        Cache { entries: files.clone().into_iter().collect() }
    }

    /// The cached record for `rel`, if its key still matches.
    pub fn get(&self, rel: &str, mtime: &str, hash: &str) -> Option<FileRecord> {
        let e = self.entries.get(rel)?;
        if e.get("mtime_ns").and_then(Json::as_str) != Some(mtime)
            || e.get("hash").and_then(Json::as_str) != Some(hash)
        {
            return None;
        }
        record_from_json(e.get("record")?)
    }

    pub fn put(&mut self, rel: &str, mtime: &str, hash: &str, record: &FileRecord) {
        let mut e = BTreeMap::new();
        e.insert(String::from("mtime_ns"), Json::Str(mtime.to_string()));
        e.insert(String::from("hash"), Json::Str(hash.to_string()));
        e.insert(String::from("record"), record_to_json(record));
        self.entries.insert(rel.to_string(), Json::Obj(e));
    }

    /// Persist to `path`. Best-effort: a read-only location loses the
    /// cache, not the lint run.
    pub fn save(&self, path: &Path) {
        let mut root = BTreeMap::new();
        root.insert(String::from("version"), Json::from(CACHE_VERSION));
        root.insert(String::from("files"), Json::Obj(self.entries.clone()));
        let _ = fs::write(path, Json::Obj(root).to_string());
    }
}

fn num(n: usize) -> Json {
    Json::from(n)
}

fn opt_str(s: &Option<String>) -> Json {
    match s {
        Some(s) => Json::Str(s.clone()),
        None => Json::Null,
    }
}

fn site_to_json(s: &Site) -> Json {
    Json::Arr(vec![Json::Str(s.kind.clone()), Json::Str(s.detail.clone()), num(s.line)])
}

fn site_from_json(j: &Json) -> Option<Site> {
    let a = j.as_arr()?;
    Some(Site {
        kind: a.first()?.as_str()?.to_string(),
        detail: a.get(1)?.as_str()?.to_string(),
        line: a.get(2)?.as_usize()?,
    })
}

fn fn_to_json(f: &FnInfo) -> Json {
    let mut m = BTreeMap::new();
    m.insert(String::from("file"), Json::Str(f.file.clone()));
    m.insert(
        String::from("module"),
        Json::Arr(f.module.iter().map(|s| Json::Str(s.clone())).collect()),
    );
    m.insert(String::from("impl"), opt_str(&f.impl_type));
    m.insert(String::from("name"), Json::Str(f.name.clone()));
    m.insert(String::from("start"), num(f.start_line));
    m.insert(String::from("end"), num(f.end_line));
    m.insert(String::from("attr"), num(f.attr_line));
    m.insert(String::from("rr"), Json::Bool(f.returns_result));
    m.insert(
        String::from("calls"),
        Json::Arr(
            f.calls
                .iter()
                .map(|c| {
                    Json::Arr(vec![
                        Json::Str(c.name.clone()),
                        opt_str(&c.qual),
                        Json::Bool(c.is_method),
                        num(c.line),
                    ])
                })
                .collect(),
        ),
    );
    m.insert(String::from("sources"), Json::Arr(f.sources.iter().map(site_to_json).collect()));
    m.insert(String::from("panics"), Json::Arr(f.panics.iter().map(site_to_json).collect()));
    m.insert(String::from("indexes"), Json::Arr(f.indexes.iter().map(|&l| num(l)).collect()));
    m.insert(
        String::from("locks"),
        Json::Arr(
            f.locks
                .iter()
                .map(|l| Json::Arr(vec![Json::Str(l.class.clone()), num(l.line), Json::Bool(l.held)]))
                .collect(),
        ),
    );
    m.insert(
        String::from("edges"),
        Json::Arr(
            f.lock_edges
                .iter()
                .map(|e| {
                    Json::Arr(vec![Json::Str(e.from.clone()), Json::Str(e.to.clone()), num(e.line)])
                })
                .collect(),
        ),
    );
    m.insert(
        String::from("held"),
        Json::Arr(
            f.held_calls
                .iter()
                .map(|(classes, idx)| {
                    Json::Arr(vec![
                        Json::Arr(classes.iter().map(|c| Json::Str(c.clone())).collect()),
                        num(*idx),
                    ])
                })
                .collect(),
        ),
    );
    m.insert(
        String::from("held_may"),
        Json::Arr(
            f.held_may_calls
                .iter()
                .map(|h| {
                    Json::Arr(vec![
                        Json::Arr(h.classes.iter().map(|c| Json::Str(c.clone())).collect()),
                        Json::Str(h.name.clone()),
                        opt_str(&h.qual),
                        Json::Bool(h.is_method),
                        num(h.line),
                    ])
                })
                .collect(),
        ),
    );
    Json::Obj(m)
}

fn fn_from_json(j: &Json) -> Option<FnInfo> {
    let mut f = FnInfo {
        file: j.get("file")?.as_str()?.to_string(),
        module: j
            .get("module")?
            .as_arr()?
            .iter()
            .map(|s| s.as_str().map(String::from))
            .collect::<Option<Vec<_>>>()?,
        impl_type: match j.get("impl")? {
            Json::Null => None,
            other => Some(other.as_str()?.to_string()),
        },
        name: j.get("name")?.as_str()?.to_string(),
        start_line: j.get("start")?.as_usize()?,
        end_line: j.get("end")?.as_usize()?,
        attr_line: j.get("attr")?.as_usize()?,
        returns_result: j.get("rr")?.as_bool()?,
        calls: Vec::new(),
        sources: Vec::new(),
        panics: Vec::new(),
        indexes: Vec::new(),
        locks: Vec::new(),
        lock_edges: Vec::new(),
        held_calls: Vec::new(),
        held_may_calls: Vec::new(),
    };
    for c in j.get("calls")?.as_arr()? {
        let a = c.as_arr()?;
        f.calls.push(Call {
            name: a.first()?.as_str()?.to_string(),
            qual: match a.get(1)? {
                Json::Null => None,
                other => Some(other.as_str()?.to_string()),
            },
            is_method: a.get(2)?.as_bool()?,
            line: a.get(3)?.as_usize()?,
        });
    }
    for s in j.get("sources")?.as_arr()? {
        f.sources.push(site_from_json(s)?);
    }
    for p in j.get("panics")?.as_arr()? {
        f.panics.push(site_from_json(p)?);
    }
    for l in j.get("indexes")?.as_arr()? {
        f.indexes.push(l.as_usize()?);
    }
    for l in j.get("locks")?.as_arr()? {
        let a = l.as_arr()?;
        f.locks.push(LockSite {
            class: a.first()?.as_str()?.to_string(),
            line: a.get(1)?.as_usize()?,
            held: a.get(2)?.as_bool()?,
        });
    }
    for e in j.get("edges")?.as_arr()? {
        let a = e.as_arr()?;
        f.lock_edges.push(LockEdge {
            from: a.first()?.as_str()?.to_string(),
            to: a.get(1)?.as_str()?.to_string(),
            line: a.get(2)?.as_usize()?,
        });
    }
    for h in j.get("held")?.as_arr()? {
        let a = h.as_arr()?;
        let classes = a
            .first()?
            .as_arr()?
            .iter()
            .map(|c| c.as_str().map(String::from))
            .collect::<Option<Vec<_>>>()?;
        f.held_calls.push((classes, a.get(1)?.as_usize()?));
    }
    for h in j.get("held_may")?.as_arr()? {
        let a = h.as_arr()?;
        let classes = a
            .first()?
            .as_arr()?
            .iter()
            .map(|c| c.as_str().map(String::from))
            .collect::<Option<Vec<_>>>()?;
        f.held_may_calls.push(HeldCall {
            classes,
            name: a.get(1)?.as_str()?.to_string(),
            qual: match a.get(2)? {
                Json::Null => None,
                other => Some(other.as_str()?.to_string()),
            },
            is_method: a.get(3)?.as_bool()?,
            line: a.get(4)?.as_usize()?,
        });
    }
    Some(f)
}

fn record_to_json(r: &FileRecord) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        String::from("violations"),
        Json::Arr(
            r.violations
                .iter()
                .map(|v| {
                    Json::Arr(vec![
                        Json::Str(v.rule.name().to_string()),
                        num(v.line),
                        Json::Str(v.message.clone()),
                    ])
                })
                .collect(),
        ),
    );
    m.insert(
        String::from("allows"),
        Json::Arr(
            r.allows
                .iter()
                .map(|a| {
                    Json::Arr(vec![Json::Str(a.rule.name().to_string()), num(a.line), num(a.line_end)])
                })
                .collect(),
        ),
    );
    m.insert(String::from("fns"), Json::Arr(r.fns.iter().map(fn_to_json).collect()));
    Json::Obj(m)
}

fn record_from_json(j: &Json) -> Option<FileRecord> {
    let mut r = FileRecord::default();
    for v in j.get("violations")?.as_arr()? {
        let a = v.as_arr()?;
        let rule = Rule::from_name_any(a.first()?.as_str()?)?;
        r.violations.push(Violation {
            file: String::new(), // refilled by the caller from the cache key
            line: a.get(1)?.as_usize()?,
            rule,
            message: a.get(2)?.as_str()?.to_string(),
        });
    }
    for v in j.get("allows")?.as_arr()? {
        let a = v.as_arr()?;
        r.allows.push(Allow {
            rule: Rule::from_name_any(a.first()?.as_str()?)?,
            line: a.get(1)?.as_usize()?,
            line_end: a.get(2)?.as_usize()?,
        });
    }
    for f in j.get("fns")?.as_arr()? {
        r.fns.push(fn_from_json(f)?);
    }
    Some(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_content_sensitive() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a(b"fn main() {}"), fnv1a(b"fn main() { }"));
        assert_eq!(fnv1a(b"abc"), fnv1a(b"abc"));
    }

    #[test]
    fn record_roundtrips_through_json() {
        let rec = FileRecord {
            violations: vec![Violation {
                file: String::new(),
                line: 3,
                rule: Rule::Wallclock,
                message: String::from("m"),
            }],
            allows: vec![Allow { rule: Rule::DetTaint, line: 5, line_end: 9 }],
            fns: vec![FnInfo {
                file: String::from("a/b.rs"),
                module: vec![String::from("m")],
                impl_type: Some(String::from("T")),
                name: String::from("f"),
                start_line: 1,
                end_line: 9,
                attr_line: 1,
                returns_result: true,
                calls: vec![Call {
                    name: String::from("g"),
                    qual: None,
                    is_method: true,
                    line: 2,
                }],
                sources: vec![Site {
                    kind: String::from("wallclock"),
                    detail: String::from("Instant::now"),
                    line: 3,
                }],
                panics: vec![],
                indexes: vec![4, 5],
                locks: vec![LockSite { class: String::from("T::s"), line: 6, held: true }],
                lock_edges: vec![LockEdge {
                    from: String::from("T::s"),
                    to: String::from("T::t"),
                    line: 7,
                }],
                held_calls: vec![(vec![String::from("T::s")], 0)],
                held_may_calls: vec![HeldCall {
                    classes: vec![String::from("T::s")],
                    name: String::from("forward_direct"),
                    qual: Some(String::from("Engine")),
                    is_method: false,
                    line: 8,
                }],
            }],
        };
        let j = record_to_json(&rec);
        let back = record_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.violations.len(), 1);
        assert_eq!(back.violations[0].rule, Rule::Wallclock);
        assert_eq!(back.allows[0].line_end, 9);
        let f = &back.fns[0];
        assert_eq!(f.qual_name(), "m::T::f");
        assert!(f.returns_result);
        assert_eq!(f.calls[0].name, "g");
        assert_eq!(f.indexes, vec![4, 5]);
        assert_eq!(f.held_calls[0].0, vec![String::from("T::s")]);
        let h = &f.held_may_calls[0];
        assert_eq!(h.classes, vec![String::from("T::s")]);
        assert_eq!(h.name, "forward_direct");
        assert_eq!(h.qual.as_deref(), Some("Engine"));
        assert!(!h.is_method);
        assert_eq!(h.line, 8);
    }

    #[test]
    fn malformed_entries_degrade_to_a_miss() {
        let j = Json::parse(r#"{"violations":[["not-a-rule",1,"m"]],"allows":[],"fns":[]}"#).unwrap();
        assert!(record_from_json(&j).is_none());
    }
}
