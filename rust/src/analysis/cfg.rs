//! Per-function control-flow graph over the [`super::lexer`] token
//! stream.
//!
//! Built by structural recursion over a function's body token range
//! (the same bracket-matching discipline as [`super::parser`], never a
//! grammar): `if`/`else if`/`else` chains, `match` arms, `while`/`for`/
//! `loop` back-edges, early `return` (with `return Err(..)` routed to
//! the error exit), `break`/`continue`, `?` error-propagation edges,
//! and `bail!`/`ensure!` error exits. Closures and anonymous blocks are
//! walked *inline* — the CFG is path-insensitive across closure
//! boundaries, which over-approximates reachability (may false-positive,
//! never false-negative for the "exists a path" analyses built on top).
//!
//! Nodes carry token sub-ranges of the original stream, so the flow
//! analyses ([`super::flow`]) re-scan node spans for their own facts;
//! the graph itself is never cached — it is rebuilt whenever the
//! per-file front-end runs (cache misses only), and only the reduced
//! per-function summaries persist (see [`super::cache`]).

use super::lexer::{Tok, TokKind};
use super::parser::match_close;

/// Edge kinds, for reporting and the golden shape tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeKind {
    /// Straight-line fall-through (also `break` to its loop's join).
    Seq,
    /// One arm of an `if`/`match`/loop condition.
    Branch,
    /// A loop back-edge (`while`/`for`/`loop` body end, `continue`).
    Back,
    /// Error propagation: `?`, `return Err(..)`, `bail!`, `ensure!`.
    Err,
}

/// One CFG node: a token sub-range `[lo, hi)` of the function body.
/// Ranges of structural nodes (joins, loop headers) may be empty.
#[derive(Clone, Copy, Debug)]
pub struct Node {
    pub lo: usize,
    pub hi: usize,
}

/// The per-function CFG. Node 0 is the entry, node [`Cfg::EXIT`] the
/// normal exit, node [`Cfg::ERR_EXIT`] the error exit (`?` targets,
/// `return Err`, `bail!`); both exits have empty spans and no
/// successors.
#[derive(Debug, Default)]
pub struct Cfg {
    pub nodes: Vec<Node>,
    /// `succs[i]` lists `(node, kind)` edges out of node `i`, in
    /// deterministic construction order.
    pub succs: Vec<Vec<(usize, EdgeKind)>>,
}

impl Cfg {
    pub const ENTRY: usize = 0;
    pub const EXIT: usize = 1;
    pub const ERR_EXIT: usize = 2;

    pub fn edge_count(&self) -> usize {
        self.succs.iter().map(Vec::len).sum()
    }

    pub fn err_edge_count(&self) -> usize {
        self.succs.iter().flatten().filter(|(_, k)| *k == EdgeKind::Err).count()
    }

    /// Predecessor lists, for the backward analyses.
    pub fn preds(&self) -> Vec<Vec<usize>> {
        let mut p = vec![Vec::new(); self.nodes.len()];
        for (u, outs) in self.succs.iter().enumerate() {
            for &(v, _) in outs {
                p[v].push(u);
            }
        }
        p
    }
}

struct Builder<'a> {
    toks: &'a [Tok],
    cfg: Cfg,
    /// Innermost-last `(header, join)` loop context for break/continue.
    loops: Vec<(usize, usize)>,
}

/// Build the CFG for a body delimited by `toks[open_i]` (`{`) and
/// `toks[close_i]` (`}`).
pub fn build(toks: &[Tok], open_i: usize, close_i: usize) -> Cfg {
    let mut b = Builder { toks, cfg: Cfg::default(), loops: Vec::new() };
    b.new_node(open_i + 1); // ENTRY
    b.new_node(close_i); // EXIT
    b.new_node(close_i); // ERR_EXIT
    let first = b.new_node(open_i + 1);
    b.edge(Cfg::ENTRY, first, EdgeKind::Seq);
    if let Some(last) = b.walk(open_i + 1, close_i, first) {
        b.extend(last, close_i);
        b.edge(last, Cfg::EXIT, EdgeKind::Seq);
    }
    b.cfg
}

impl Builder<'_> {
    fn new_node(&mut self, lo: usize) -> usize {
        self.cfg.nodes.push(Node { lo, hi: lo });
        self.cfg.succs.push(Vec::new());
        self.cfg.nodes.len() - 1
    }

    fn edge(&mut self, from: usize, to: usize, kind: EdgeKind) {
        self.cfg.succs[from].push((to, kind));
    }

    fn extend(&mut self, node: usize, hi: usize) {
        let n = &mut self.cfg.nodes[node];
        n.hi = n.hi.max(hi);
    }

    fn tok_is(&self, i: usize, text: &str) -> bool {
        self.toks.get(i).is_some_and(|t| t.text == text)
    }

    fn ident_at(&self, i: usize) -> Option<&str> {
        self.toks.get(i).and_then(|t| (t.kind == TokKind::Ident).then_some(t.text.as_str()))
    }

    /// First `{` at bracket depth 0 in `[i, end)` — the body opener of
    /// an `if`/`match`/`while`/`for` whose condition may contain nested
    /// `(..)`/`[..]` groups (never braces: Rust conditions require
    /// parens around struct literals).
    fn find_open_brace(&self, mut i: usize, end: usize) -> usize {
        while i < end {
            match self.toks[i].text.as_str() {
                "{" if self.toks[i].kind == TokKind::Punct => return i,
                "(" => i = match_close(self.toks, i, "(", ")") + 1,
                "[" => i = match_close(self.toks, i, "[", "]") + 1,
                _ => i += 1,
            }
        }
        end
    }

    /// Index of the terminating `sep` at bracket depth 0, or `end`.
    fn scan_to(&self, mut i: usize, end: usize, sep: &str) -> usize {
        while i < end {
            let t = &self.toks[i];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    s if s == sep => return i,
                    "(" => {
                        i = match_close(self.toks, i, "(", ")") + 1;
                        continue;
                    }
                    "[" => {
                        i = match_close(self.toks, i, "[", "]") + 1;
                        continue;
                    }
                    "{" => {
                        i = match_close(self.toks, i, "{", "}") + 1;
                        continue;
                    }
                    _ => {}
                }
            }
            i += 1;
        }
        end
    }

    /// After a diverging statement (`return`/`break`/`continue`/`bail!`):
    /// any trailing tokens are dead code, parked in a fresh unreachable
    /// node so spans stay covered; `None` ends the block.
    fn diverge(&mut self, i: usize, end: usize) -> Option<usize> {
        (i < end).then(|| self.new_node(i))
    }

    /// Walk `[i, end)` accumulating into `cur`; returns the node control
    /// falls out of, or `None` if every path diverged.
    fn walk(&mut self, mut i: usize, end: usize, mut cur: usize) -> Option<usize> {
        while i < end {
            let t = &self.toks[i];
            if t.kind == TokKind::Ident {
                match t.text.as_str() {
                    "if" => {
                        let (ni, nc) = self.handle_if(i, end, cur);
                        i = ni;
                        cur = nc;
                        continue;
                    }
                    "match" => {
                        let (ni, nc) = self.handle_match(i, end, cur);
                        i = ni;
                        cur = nc;
                        continue;
                    }
                    "while" | "for" => {
                        let (ni, nc) = self.handle_loop(i, end, cur, false);
                        i = ni;
                        cur = nc;
                        continue;
                    }
                    "loop" if self.tok_is(i + 1, "{") => {
                        let (ni, nc) = self.handle_loop(i, end, cur, true);
                        i = ni;
                        cur = nc;
                        continue;
                    }
                    "return" => {
                        let j = self.scan_to(i + 1, end, ";");
                        self.extend(cur, j);
                        let is_err = self
                            .toks
                            .get(i + 1)
                            .is_some_and(|t| t.kind == TokKind::Ident && t.text == "Err");
                        if is_err {
                            self.edge(cur, Cfg::ERR_EXIT, EdgeKind::Err);
                        } else {
                            self.edge(cur, Cfg::EXIT, EdgeKind::Seq);
                        }
                        i = if j < end { j + 1 } else { end };
                        match self.diverge(i, end) {
                            Some(n) => cur = n,
                            None => return None,
                        }
                        continue;
                    }
                    "break" | "continue" => {
                        let is_break = t.text == "break";
                        let j = self.scan_to(i + 1, end, ";");
                        self.extend(cur, j);
                        match self.loops.last().copied() {
                            Some((header, join)) => {
                                if is_break {
                                    self.edge(cur, join, EdgeKind::Seq);
                                } else {
                                    self.edge(cur, header, EdgeKind::Back);
                                }
                            }
                            // `break` in a match used as a loop-less
                            // labelled block: treat as normal exit.
                            None => self.edge(cur, Cfg::EXIT, EdgeKind::Seq),
                        }
                        i = if j < end { j + 1 } else { end };
                        match self.diverge(i, end) {
                            Some(n) => cur = n,
                            None => return None,
                        }
                        continue;
                    }
                    "bail" if self.tok_is(i + 1, "!") => {
                        let j = self.scan_to(i + 2, end, ";");
                        self.extend(cur, j);
                        self.edge(cur, Cfg::ERR_EXIT, EdgeKind::Err);
                        i = if j < end { j + 1 } else { end };
                        match self.diverge(i, end) {
                            Some(n) => cur = n,
                            None => return None,
                        }
                        continue;
                    }
                    "ensure" if self.tok_is(i + 1, "!") => {
                        // Conditional error exit: may propagate, may
                        // fall through.
                        self.edge(cur, Cfg::ERR_EXIT, EdgeKind::Err);
                        i += 2;
                        continue;
                    }
                    _ => {}
                }
            }
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "{" => {
                        // Anonymous / `unsafe` / closure block (or a
                        // struct literal): walked inline.
                        let close = match_close(self.toks, i, "{", "}");
                        self.extend(cur, i);
                        match self.walk(i + 1, close.min(end), cur) {
                            Some(sub) => {
                                cur = sub;
                                self.extend(cur, close);
                                i = close + 1;
                            }
                            None => {
                                i = close + 1;
                                match self.diverge(i, end) {
                                    Some(n) => cur = n,
                                    None => return None,
                                }
                            }
                        }
                        continue;
                    }
                    "?" => {
                        // `?` propagation — but not the `?Sized` bound.
                        if self.ident_at(i + 1) != Some("Sized") {
                            self.extend(cur, i + 1);
                            self.edge(cur, Cfg::ERR_EXIT, EdgeKind::Err);
                        }
                        i += 1;
                        continue;
                    }
                    _ => {}
                }
            }
            self.extend(cur, i + 1);
            i += 1;
        }
        self.extend(cur, end);
        Some(cur)
    }

    /// An `if` / `else if` / `else` chain starting at the `if` token.
    /// Returns (index after the chain, join node).
    fn handle_if(&mut self, mut i: usize, end: usize, mut cur: usize) -> (usize, usize) {
        let join = self.new_node(i);
        loop {
            // toks[i] == "if"; the condition tokens stay in `cur`.
            let open = self.find_open_brace(i + 1, end);
            self.extend(cur, open);
            if open >= end {
                self.edge(cur, join, EdgeKind::Branch);
                i = end;
                break;
            }
            let close = match_close(self.toks, open, "{", "}");
            let arm = self.new_node(open + 1);
            self.edge(cur, arm, EdgeKind::Branch);
            if let Some(a_end) = self.walk(open + 1, close.min(end), arm) {
                self.extend(a_end, close);
                self.edge(a_end, join, EdgeKind::Seq);
            }
            i = close + 1;
            if self.ident_at(i) == Some("else") {
                if self.ident_at(i + 1) == Some("if") {
                    // Next condition runs only when this one was false.
                    let c = self.new_node(i + 1);
                    self.edge(cur, c, EdgeKind::Branch);
                    cur = c;
                    i += 1;
                    continue;
                }
                if self.tok_is(i + 1, "{") {
                    let e_open = i + 1;
                    let e_close = match_close(self.toks, e_open, "{", "}");
                    let arm = self.new_node(e_open + 1);
                    self.edge(cur, arm, EdgeKind::Branch);
                    if let Some(a_end) = self.walk(e_open + 1, e_close.min(end), arm) {
                        self.extend(a_end, e_close);
                        self.edge(a_end, join, EdgeKind::Seq);
                    }
                    i = e_close + 1;
                    break;
                }
            }
            // No else: the false path falls straight to the join.
            self.edge(cur, join, EdgeKind::Branch);
            break;
        }
        let n = &mut self.cfg.nodes[join];
        n.lo = i.min(end);
        n.hi = i.min(end);
        (i, join)
    }

    /// A `match` starting at the `match` token: one node per arm body.
    fn handle_match(&mut self, i: usize, end: usize, cur: usize) -> (usize, usize) {
        let open = self.find_open_brace(i + 1, end);
        self.extend(cur, open);
        if open >= end {
            return (end, cur);
        }
        let close = match_close(self.toks, open, "{", "}");
        let join = self.new_node(close + 1);
        let mut arms = 0usize;
        let mut k = open + 1;
        while k < close {
            // Find `=>` (lexed as `=` `>`) at bracket depth 0.
            let mut a = k;
            let mut found = false;
            while a < close {
                let t = &self.toks[a];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "=" if self.tok_is(a + 1, ">") => {
                            found = true;
                            break;
                        }
                        "(" => {
                            a = match_close(self.toks, a, "(", ")") + 1;
                            continue;
                        }
                        "[" => {
                            a = match_close(self.toks, a, "[", "]") + 1;
                            continue;
                        }
                        "{" => {
                            a = match_close(self.toks, a, "{", "}") + 1;
                            continue;
                        }
                        _ => {}
                    }
                }
                a += 1;
            }
            if !found {
                break;
            }
            // Arm body: a block, or an expression up to the depth-0 `,`.
            let (b_lo, b_hi, next) = if self.tok_is(a + 2, "{") {
                let b_close = match_close(self.toks, a + 2, "{", "}");
                let mut nx = b_close + 1;
                if self.tok_is(nx, ",") {
                    nx += 1;
                }
                (a + 3, b_close, nx)
            } else {
                let e = self.scan_to(a + 2, close, ",");
                (a + 2, e, if e < close { e + 1 } else { close })
            };
            let arm = self.new_node(b_lo);
            self.edge(cur, arm, EdgeKind::Branch);
            arms += 1;
            if let Some(a_end) = self.walk(b_lo, b_hi.min(end), arm) {
                self.extend(a_end, b_hi);
                self.edge(a_end, join, EdgeKind::Seq);
            }
            k = next;
        }
        if arms == 0 {
            self.edge(cur, join, EdgeKind::Seq);
        }
        (close + 1, join)
    }

    /// `while`/`for` (condition header, body, back-edge, loop-exit
    /// branch) or `loop` (no exit branch: only `break` reaches the join).
    fn handle_loop(&mut self, i: usize, end: usize, cur: usize, is_loop: bool) -> (usize, usize) {
        let open = if is_loop { i + 1 } else { self.find_open_brace(i + 1, end) };
        self.extend(cur, i);
        if open >= end || !self.tok_is(open, "{") {
            return (end, cur);
        }
        let close = match_close(self.toks, open, "{", "}");
        let header = self.new_node(i);
        self.extend(header, open);
        self.edge(cur, header, EdgeKind::Seq);
        let body = self.new_node(open + 1);
        let join = self.new_node(close + 1);
        if is_loop {
            self.edge(header, body, EdgeKind::Seq);
        } else {
            self.edge(header, body, EdgeKind::Branch);
            self.edge(header, join, EdgeKind::Branch);
        }
        self.loops.push((header, join));
        let b_end = self.walk(open + 1, close.min(end), body);
        self.loops.pop();
        if let Some(b) = b_end {
            self.extend(b, close);
            self.edge(b, header, EdgeKind::Back);
        }
        (close + 1, join)
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    /// Build the CFG of the first fn body in `src`.
    fn cfg_of(src: &str) -> Cfg {
        let lexed = lex(src);
        let open = lexed
            .toks
            .iter()
            .position(|t| t.kind == TokKind::Punct && t.text == "{")
            .expect("fn body");
        let close = match_close(&lexed.toks, open, "{", "}");
        build(&lexed.toks, open, close)
    }

    #[test]
    fn straight_line_shape() {
        // entry, exit, err_exit, one statement node; entry->stmt->exit.
        let c = cfg_of("fn f() { let a = 1; g(a); }");
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.edge_count(), 2);
        assert_eq!(c.err_edge_count(), 0);
    }

    #[test]
    fn if_else_shape() {
        // nodes: 3 fixed + cond/first + then + else + join = 7.
        // edges: entry->cond, cond->then, cond->else, then->join,
        // else->join, join->exit = 6.
        let c = cfg_of("fn f(x: u32) -> u32 { if x > 0 { a(); } else { b(); } c() }");
        assert_eq!(c.nodes.len(), 7);
        assert_eq!(c.edge_count(), 6);
        assert_eq!(c.err_edge_count(), 0);
    }

    #[test]
    fn if_without_else_falls_to_join() {
        // nodes: 3 fixed + cond + then + join = 6; edges: entry->cond,
        // cond->then, cond->join, then->join, join->exit = 5.
        let c = cfg_of("fn f(x: u32) { if x > 0 { a(); } b(); }");
        assert_eq!(c.nodes.len(), 6);
        assert_eq!(c.edge_count(), 5);
    }

    #[test]
    fn question_mark_adds_err_edge() {
        // One `?`: a single Err edge to the error exit, flow falls on.
        let c = cfg_of("fn f() -> Result<u32, E> { let v = g()?; Ok(v) }");
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.err_edge_count(), 1);
        assert_eq!(c.edge_count(), 3); // entry->stmt, stmt->err, stmt->exit
        // `?Sized` in a bound is not an error edge.
        let c2 = cfg_of("fn f() { let b: Box<dyn A + ?Sized> = mk(); }");
        assert_eq!(c2.err_edge_count(), 0);
    }

    #[test]
    fn match_arms_shape() {
        // 3 fixed + scrutinee/first + 2 block arms + join = 7 nodes;
        // edges: entry->s, s->arm0, s->arm1, arm0->join, arm1->join,
        // join->exit = 6.
        let c = cfg_of("fn f(x: O) -> u32 { match x { O::A => { a() } O::B(v) => { b(v) } } }");
        assert_eq!(c.nodes.len(), 7);
        assert_eq!(c.edge_count(), 6);
    }

    #[test]
    fn match_expr_arms_and_guards() {
        // Expression arms (with a guard on the first) still produce one
        // node per arm.
        let c = cfg_of("fn f(x: u32) -> u32 { match x { v if v > 2 => big(v), _ => small(x), } }");
        assert_eq!(c.nodes.len(), 7);
        assert_eq!(c.edge_count(), 6);
    }

    #[test]
    fn while_loop_has_back_edge() {
        // nodes: 3 fixed + first + header + body + join = 7; edges:
        // entry->first, first->header, header->body, header->join,
        // body->header(Back), join->exit = 6.
        let c = cfg_of("fn f(mut n: u32) { while n > 0 { n -= 1; } done(); }");
        assert_eq!(c.nodes.len(), 7);
        assert_eq!(c.edge_count(), 6);
        let backs =
            c.succs.iter().flatten().filter(|(_, k)| *k == EdgeKind::Back).count();
        assert_eq!(backs, 1);
    }

    #[test]
    fn loop_join_reached_only_by_break() {
        let c = cfg_of("fn f() { loop { if done() { break; } step(); } after(); }");
        // The loop's join has exactly one incoming edge: the break.
        let backs =
            c.succs.iter().flatten().filter(|(_, k)| *k == EdgeKind::Back).count();
        assert_eq!(backs, 1, "loop body falls back to the header");
        // and `after()` is reachable: join -> exit edge exists.
        assert!(c.succs.iter().flatten().any(|&(v, _)| v == Cfg::EXIT));
    }

    #[test]
    fn return_err_routes_to_error_exit() {
        let c = cfg_of("fn f(x: bool) -> Result<(), E> { if x { return Err(E); } Ok(()) }");
        assert_eq!(c.err_edge_count(), 1);
        // the then-arm ends at ERR_EXIT, not the join
        let c2 = cfg_of("fn g(x: bool) -> u32 { if x { return 1; } 2 }");
        assert_eq!(c2.err_edge_count(), 0);
    }

    #[test]
    fn bail_and_ensure_are_error_exits() {
        let c = cfg_of("fn f(x: u32) -> Result<u32, E> { ensure!(x > 0, \"positive\"); if x > 9 { bail!(\"too big\"); } Ok(x) }");
        assert_eq!(c.err_edge_count(), 2);
    }

    #[test]
    fn nested_and_anonymous_blocks_walk_inline() {
        let c = cfg_of("fn f() { { let a = 1; } unsafe { g(); } }");
        // anonymous + unsafe blocks add no nodes of their own
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.edge_count(), 2);
    }
}
