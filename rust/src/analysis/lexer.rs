//! A minimal Rust lexer for `ued-lint`.
//!
//! Splits source text into identifier / punctuation / literal / lifetime
//! tokens plus a separate comment stream, each tagged with 1-based line
//! numbers. It understands exactly as much Rust surface syntax as the
//! lint rules need to avoid false positives: line and (nested) block
//! comments, string / raw-string / byte-string literals, char literals
//! vs. lifetimes, and numeric literals. It performs no parsing — the
//! rules in [`super`] pattern-match on the token stream directly.

/// Kind of a lexed token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `use`, `HashMap`, …).
    Ident,
    /// A single punctuation character (`:`, `*`, `;`, …).
    Punct,
    /// String / char / numeric literal (contents are never rule-matched).
    Lit,
    /// A lifetime such as `'a` (distinguished from char literals).
    Lifetime,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// One comment (line or block) with its 1-based line span.
#[derive(Clone, Debug)]
pub struct Comment {
    pub text: String,
    pub line: usize,
    pub line_end: usize,
}

/// The output of [`lex`]: code tokens and comments, separately.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens + comments. Never fails: unrecognized bytes
/// become single-character punctuation tokens, which no rule matches.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut out = Lexed::default();

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also covers `///` and `//!` doc comments).
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment {
                text: b[start..i].iter().collect(),
                line,
                line_end: line,
            });
            continue;
        }
        // Block comment, with nesting (Rust allows it).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.comments.push(Comment {
                text: b[start..i].iter().collect(),
                line: start_line,
                line_end: line,
            });
            continue;
        }
        // Plain string literal.
        if c == '"' {
            let start_line = line;
            i += 1;
            while i < n {
                if b[i] == '\\' {
                    // A `\<newline>` line continuation still ends a line:
                    // losing it would shift every later token's line number
                    // (and with it allow-directive matching) by one.
                    if i + 1 < n && b[i + 1] == '\n' {
                        line += 1;
                    }
                    i += 2;
                } else if b[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            out.toks.push(Tok { kind: TokKind::Lit, text: String::from("\"…\""), line: start_line });
            continue;
        }
        // Raw / byte string forms: r"…", r#"…"#, b"…", br#"…"#.
        if c == 'r' || c == 'b' {
            let mut j = i;
            if b[j] == 'b' {
                j += 1;
            }
            let mut hashes = 0usize;
            let mut k = j;
            let mut raw = false;
            if k < n && b[k] == 'r' {
                raw = true;
                k += 1;
                while k < n && b[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
            }
            if k < n && b[k] == '"' && (raw || j > i) {
                let start_line = line;
                i = k + 1;
                if raw {
                    // Scan for `"` followed by `hashes` hash marks.
                    'scan: while i < n {
                        if b[i] == '\n' {
                            line += 1;
                            i += 1;
                            continue;
                        }
                        if b[i] == '"' {
                            let mut h = 0usize;
                            while h < hashes && i + 1 + h < n && b[i + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                i += 1 + hashes;
                                break 'scan;
                            }
                        }
                        i += 1;
                    }
                } else {
                    // Byte string: ordinary escape rules.
                    while i < n {
                        if b[i] == '\\' {
                            if i + 1 < n && b[i + 1] == '\n' {
                                line += 1;
                            }
                            i += 2;
                        } else if b[i] == '"' {
                            i += 1;
                            break;
                        } else {
                            if b[i] == '\n' {
                                line += 1;
                            }
                            i += 1;
                        }
                    }
                }
                out.toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::from("r\"…\""),
                    line: start_line,
                });
                continue;
            }
            // Raw identifier: `r#ident` lexes as a plain identifier token,
            // so rules see `r#type` and `type` identically.
            if c == 'r' && i + 1 < n && b[i + 1] == '#' && i + 2 < n && is_ident_start(b[i + 2]) {
                let start = i + 2;
                i = start;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Ident,
                    text: b[start..i].iter().collect(),
                    line,
                });
                continue;
            }
            // Byte-char literal `b'x'` / `b'\n'`: drop the `b` and let the
            // char-literal arm below consume the quote (previously this
            // lexed as ident `b` + char literal — harmless — but `b'` at
            // end of input could desync the lifetime heuristic).
            if c == 'b' && i + 1 < n && b[i + 1] == '\'' {
                i += 1;
            }
            // Fall through: it was an ordinary identifier starting with r/b.
        }
        // Char literal vs. lifetime.
        let c = b[i];
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char literal: skip the escape head, then scan to
                // the closing quote (covers '\n', '\'', '\u{…}').
                i += 2;
                if i < n {
                    i += 1; // the character after the backslash
                }
                while i < n && b[i] != '\'' {
                    i += 1;
                }
                i += 1;
                out.toks.push(Tok { kind: TokKind::Lit, text: String::from("'…'"), line });
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' {
                // 'x' — a plain one-character literal.
                i += 3;
                out.toks.push(Tok { kind: TokKind::Lit, text: String::from("'…'"), line });
                continue;
            }
            if i + 1 < n && is_ident_start(b[i + 1]) {
                // A lifetime: 'a, '_, 'static.
                let start = i;
                i += 2;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[start..i].iter().collect(),
                    line,
                });
                continue;
            }
            // Stray quote — emit as punctuation, matched by no rule.
            out.toks.push(Tok { kind: TokKind::Punct, text: String::from("'"), line });
            i += 1;
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Numeric literal (loose: 0xC01, 1_000, 1e9 all lex as one token).
        if c.is_ascii_digit() {
            let start = i;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Lit,
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Everything else: single-character punctuation.
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(lx: &Lexed) -> Vec<&str> {
        lx.toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect()
    }

    #[test]
    fn comments_are_separated_from_code() {
        let src = "// top SAFETY: fine\nlet x = 1; /* block\nspan */ let y = 2;\n";
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 2);
        assert!(lx.comments[0].text.contains("SAFETY"));
        assert_eq!(lx.comments[1].line, 2);
        assert_eq!(lx.comments[1].line_end, 3);
        assert!(idents(&lx).contains(&"x"));
        assert!(idents(&lx).contains(&"y"));
        // words inside comments never become identifier tokens
        assert!(!idents(&lx).contains(&"top"));
        assert!(!idents(&lx).contains(&"span"));
    }

    #[test]
    fn strings_hide_their_contents() {
        let src = "let s = \"not // a comment HashMap\";\nlet r = r#\"raw \"q\" HashSet\"#;\nlet b = b\"bytes\";";
        let lx = lex(src);
        assert!(lx.comments.is_empty());
        assert!(!idents(&lx).contains(&"HashMap"));
        assert!(!idents(&lx).contains(&"HashSet"));
        assert!(!idents(&lx).contains(&"bytes"));
        assert!(idents(&lx).contains(&"s"));
        assert!(idents(&lx).contains(&"r"));
        assert!(idents(&lx).contains(&"b"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a u32) -> &'static u32 { let c = 'y'; let nl = '\\n'; x }";
        let lx = lex(src);
        let lifetimes: Vec<&str> = lx
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, ["'a", "'a", "'static"]);
        // 'y' and '\n' became literals, not identifiers named y / n
        assert!(!idents(&lx).contains(&"y"));
    }

    #[test]
    fn nested_block_comments_terminate() {
        let src = "/* outer /* inner */ still comment */ fn f() {}";
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 1);
        assert!(idents(&lx).contains(&"fn"));
        assert!(!idents(&lx).contains(&"inner"));
    }

    #[test]
    fn line_numbers_track_newlines_everywhere() {
        let src = "let a = 1;\nlet s = \"two\nlines\";\nunsafe {}\n";
        let lx = lex(src);
        let uns = lx
            .toks
            .iter()
            .find(|t| t.text == "unsafe")
            .expect("unsafe token");
        assert_eq!(uns.line, 4);
    }

    #[test]
    fn backslash_newline_continuation_counts_the_line() {
        // `\<newline>` inside a string is an escape pair, but the newline
        // still ends a source line; the token after the string must land
        // on line 4, not line 3.
        let src = "let s = \"one \\\n    two \\\n    three\";\nunsafe {}\n";
        let lx = lex(src);
        let uns = lx
            .toks
            .iter()
            .find(|t| t.text == "unsafe")
            .expect("unsafe token");
        assert_eq!(uns.line, 4);
    }

    #[test]
    fn raw_identifiers_lex_as_plain_identifiers() {
        let src = "let r#type = r#match + other;";
        let lx = lex(src);
        assert!(idents(&lx).contains(&"type"));
        assert!(idents(&lx).contains(&"match"));
        assert!(idents(&lx).contains(&"other"));
        // no stray `r` identifier and no `#` desync
        assert!(!idents(&lx).contains(&"r"));
    }

    #[test]
    fn byte_char_literals_are_single_literals() {
        let src = "let x = b'a'; let y = b'\\n'; let z = b\"s\";";
        let lx = lex(src);
        // the `b` prefix is consumed by the literal, not emitted as an ident
        assert!(!idents(&lx).contains(&"b"));
        assert!(idents(&lx).contains(&"x"));
        assert!(idents(&lx).contains(&"y"));
        assert!(idents(&lx).contains(&"z"));
        // and nothing after a byte-char lexes as a lifetime
        assert!(lx.toks.iter().all(|t| t.kind != TokKind::Lifetime));
    }
}
