//! Item-level recursive-descent parser over the [`super::lexer`] token
//! stream.
//!
//! This is deliberately *not* a Rust parser: it recognizes exactly the
//! item structure the semantic lint pass needs — `fn` / `impl` / `mod`
//! nesting, attribute runs, and per-function body facts (calls, panic
//! sites, lock acquisitions, determinism taint sources) — by bracket
//! matching, never by grammar. `#[cfg(test)]` items and modules are
//! skipped wholesale so test scaffolding can unwrap freely.
//!
//! Known limits (also documented in the README rule catalog):
//!
//! * Guard release is modeled lexically: a guard dies when the brace
//!   scope it was bound in closes (`Drop`-at-scope-end), or earlier at
//!   an explicit `drop(guard)` / `mem::drop(guard)` naming its binding.
//!   Guards moved out of their binding (returned, stored in a struct)
//!   are treated as released at scope end — an under-approximation the
//!   flow pass inherits.
//! * Lock classes are named `{impl type or file stem}::{receiver field}`,
//!   so the same mutex reached through two wrapper types forms two
//!   classes. This fragments (never merges) classes — it can miss an
//!   order cycle, not invent one.
//!
//! Trait *default method* bodies are parsed like inherent methods
//! (`impl_type` = the trait name) so they enter the call graph; bodiless
//! trait-method declarations are still skipped.

use super::lexer::{Lexed, Tok, TokKind};

/// Rust keywords (plus `macro_rules`): never treated as call names.
pub fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "if" | "while"
            | "for"
            | "match"
            | "return"
            | "loop"
            | "as"
            | "in"
            | "let"
            | "fn"
            | "impl"
            | "mod"
            | "use"
            | "pub"
            | "unsafe"
            | "move"
            | "ref"
            | "mut"
            | "where"
            | "dyn"
            | "box"
            | "break"
            | "continue"
            | "else"
            | "enum"
            | "struct"
            | "trait"
            | "type"
            | "const"
            | "static"
            | "crate"
            | "super"
            | "self"
            | "Self"
            | "true"
            | "false"
            | "async"
            | "await"
            | "extern"
            | "macro_rules"
            | "union"
    )
}

/// A determinism-taint or panic site inside a function body.
#[derive(Clone, Debug)]
pub struct Site {
    /// Source class (`wallclock`, `ambient-rng`, `hash-order`) or panic
    /// class (`unwrap`, `expect`, `panic-macro`, `unchecked-arith`).
    pub kind: String,
    /// The concrete token(s) seen, for the report message.
    pub detail: String,
    pub line: usize,
}

/// One call site: `name(..)`, `recv.name(..)`, or `Qual::name(..)`.
#[derive(Clone, Debug)]
pub struct Call {
    pub name: String,
    /// The path qualifier directly before `::name(`, if any.
    pub qual: Option<String>,
    /// True for `.name(` receivers with no qualifier.
    pub is_method: bool,
    pub line: usize,
}

/// One call made while lock guards *may* be held, per the flow pass's
/// branch-sensitive may-held analysis (computed in [`super::flow`], not
/// by the linear body scan — see [`FnInfo::held_may_calls`]).
#[derive(Clone, Debug)]
pub struct HeldCall {
    /// Lock classes possibly held at the call.
    pub classes: Vec<String>,
    pub name: String,
    pub qual: Option<String>,
    pub is_method: bool,
    pub line: usize,
}

/// One `.lock()` acquisition.
#[derive(Clone, Debug)]
pub struct LockSite {
    /// Lock class: `{impl type or file stem}::{receiver tail}`.
    pub class: String,
    pub line: usize,
    /// Bound by a `let` (the guard is held past the statement).
    pub held: bool,
}

/// A direct held→acquired ordering edge inside one body.
#[derive(Clone, Debug)]
pub struct LockEdge {
    pub from: String,
    pub to: String,
    pub line: usize,
}

/// Everything the semantic analyses need to know about one function.
#[derive(Clone, Debug)]
pub struct FnInfo {
    /// File path relative to the lint root (`/`-separated).
    pub file: String,
    /// Inline `mod` nesting inside the file.
    pub module: Vec<String>,
    /// `impl` block type, if the fn is a method.
    pub impl_type: Option<String>,
    pub name: String,
    pub start_line: usize,
    pub end_line: usize,
    /// First line of the attribute/visibility run introducing the item
    /// (== `start_line` when there is none) — allow directives anchor here.
    pub attr_line: usize,
    /// `Result` appears in the return-type tokens.
    pub returns_result: bool,
    pub calls: Vec<Call>,
    pub sources: Vec<Site>,
    pub panics: Vec<Site>,
    /// Lines with `expr[..]` slice/array indexing.
    pub indexes: Vec<usize>,
    pub locks: Vec<LockSite>,
    pub lock_edges: Vec<LockEdge>,
    /// Calls made while guards are held: (held classes, index into `calls`).
    pub held_calls: Vec<(Vec<String>, usize)>,
    /// Calls where the CFG may-held analysis proves a guard *can* be
    /// live — a superset of `held_calls` on branchy code (e.g. a guard
    /// dropped on only one arm of an `if`). Filled by
    /// [`super::flow::held_may_calls`] after parsing; persisted through
    /// the cache so the interprocedural `lock-across-forward` check can
    /// run on cache hits.
    pub held_may_calls: Vec<HeldCall>,
}

impl FnInfo {
    /// Human-readable qualified name for report messages.
    pub fn qual_name(&self) -> String {
        let ty = match &self.impl_type {
            Some(t) => format!("{t}::"),
            None => String::new(),
        };
        if self.module.is_empty() {
            format!("{ty}{}", self.name)
        } else {
            format!("{}::{ty}{}", self.module.join("::"), self.name)
        }
    }
}

/// The line span of one item (fn, struct, enum, …) including its
/// attribute run: an allow directive ending on `attr_line - 1` extends
/// over the whole item.
#[derive(Clone, Copy, Debug)]
pub struct ItemSpan {
    pub attr_line: usize,
    pub end_line: usize,
}

/// Parse result for one file.
#[derive(Debug, Default)]
pub struct ParsedFile {
    pub fns: Vec<FnInfo>,
    pub items: Vec<ItemSpan>,
    /// `(body_open, body_close)` token indexes, aligned with `fns` —
    /// consumed by the flow pass to build per-function CFGs. Token
    /// indexes are only meaningful against the same `Lexed`, so this is
    /// never cached.
    pub bodies: Vec<(usize, usize)>,
}

/// Index of the token matching the `open` bracket at `i` (falls back to
/// the last token on unbalanced input, so parsing always terminates).
pub fn match_close(toks: &[Tok], mut i: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Punct && t.text == open {
            depth += 1;
        } else if t.kind == TokKind::Punct && t.text == close {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Receiver tail of a method call whose name token sits at `i`: the
/// field/binding closest to the `.name()`, walking back over
/// `.`/ident/`[..]` chains; `self.name()` (or an unrecognized receiver)
/// yields `None`. Shared between the body scanner here and the flow
/// pass's guard prescan ([`super::flow`]).
pub(crate) fn receiver_tail(toks: &[Tok], i: usize) -> Option<String> {
    let mut j = i as isize - 2;
    let mut tail: Option<String> = None;
    while j >= 0 {
        let tj = &toks[j as usize];
        if tj.kind == TokKind::Punct && tj.text == "]" {
            let mut d = 0isize;
            while j >= 0 {
                let b = &toks[j as usize];
                if b.text == "]" {
                    d += 1;
                } else if b.text == "[" {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                j -= 1;
            }
            j -= 1;
            continue;
        }
        if tj.kind == TokKind::Ident {
            if tj.text != "self" {
                tail = Some(tj.text.clone());
            }
            break;
        }
        if tj.kind == TokKind::Punct && tj.text == "." {
            j -= 1;
            continue;
        }
        break;
    }
    tail
}

fn tok_is(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.text == text)
}

fn ident_at(toks: &[Tok], i: usize) -> Option<&str> {
    toks.get(i).and_then(|t| (t.kind == TokKind::Ident).then_some(t.text.as_str()))
}

enum Ctx {
    Mod(String, usize),
    Impl(String, usize),
}

impl Ctx {
    fn close(&self) -> usize {
        match self {
            Ctx::Mod(_, c) | Ctx::Impl(_, c) => *c,
        }
    }
}

/// Parse `lexed` into functions + item spans. `file` is the path
/// relative to the lint root and becomes `FnInfo::file` verbatim.
pub fn parse_file(file: &str, lexed: &Lexed) -> ParsedFile {
    let toks = &lexed.toks;
    let n = toks.len();
    let mut out = ParsedFile::default();
    let mut ctx: Vec<Ctx> = Vec::new();
    let mut i = 0usize;
    // The contiguous attribute/visibility run introducing the next item.
    let mut attr_line: Option<usize> = None;
    let mut attr_is_cfg_test = false;

    while i < n {
        while ctx.last().is_some_and(|c| i > c.close()) {
            ctx.pop();
        }
        let t = &toks[i];
        let ln = t.line;

        if t.kind == TokKind::Punct && t.text == "#" {
            // `#[...]` / `#![...]` attribute.
            let mut j = i + 1;
            if tok_is(toks, j, "!") {
                j += 1;
            }
            if tok_is(toks, j, "[") {
                let close = match_close(toks, j, "[", "]");
                attr_line.get_or_insert(ln);
                let mut saw_cfg = false;
                let mut saw_test = false;
                for a in &toks[j..close] {
                    if a.kind == TokKind::Ident {
                        saw_cfg |= a.text == "cfg";
                        saw_test |= a.text == "test";
                    }
                }
                if saw_cfg && saw_test {
                    attr_is_cfg_test = true;
                }
                i = close + 1;
                continue;
            }
            i += 1;
            continue;
        }
        if t.kind != TokKind::Ident {
            // Punctuation other than attribute/bracket glue breaks the
            // attribute run.
            if t.kind == TokKind::Punct && !matches!(t.text.as_str(), "#" | "[" | "]") {
                attr_line = None;
            }
            i += 1;
            continue;
        }

        match t.text.as_str() {
            "pub" => {
                // `pub` / `pub(crate)` — transparent, keep the attr run.
                if tok_is(toks, i + 1, "(") {
                    i = match_close(toks, i + 1, "(", ")") + 1;
                } else {
                    i += 1;
                }
            }
            "unsafe" | "async" | "extern" => {
                // fn/impl modifiers — transparent.
                i += 1;
            }
            "const" if ident_at(toks, i + 1) == Some("fn") => {
                // `const fn` — let the fn arm take it.
                i += 1;
            }
            "mod" => {
                let name = ident_at(toks, i + 1).unwrap_or("?").to_string();
                let j = i + 2;
                if tok_is(toks, j, "{") {
                    let close = match_close(toks, j, "{", "}");
                    if attr_is_cfg_test {
                        i = close + 1; // skip #[cfg(test)] modules wholesale
                    } else {
                        ctx.push(Ctx::Mod(name, close));
                        i = j + 1;
                    }
                } else {
                    i = j + 1; // `mod name;`
                }
                attr_line = None;
                attr_is_cfg_test = false;
            }
            "impl" => {
                // impl [<…>] Type [for Type2] [where …] { … }
                let mut j = i + 1;
                if tok_is(toks, j, "<") {
                    j = match_close(toks, j, "<", ">") + 1;
                }
                let mut ty: Option<String> = None;
                while j < n && !tok_is(toks, j, "{") {
                    let tj = &toks[j];
                    if tj.kind == TokKind::Ident && tj.text == "for" {
                        ty = None; // the *trait* was named first; restart
                    } else if tj.kind == TokKind::Ident && tj.text != "where" && ty.is_none() {
                        ty = Some(tj.text.clone());
                    } else if tj.kind == TokKind::Ident
                        && ty.is_some()
                        && j >= 2
                        && tok_is(toks, j - 1, ":")
                        && tok_is(toks, j - 2, ":")
                    {
                        ty = Some(tj.text.clone()); // path: keep the last segment
                    }
                    if tj.text == "where" {
                        break;
                    }
                    j += 1;
                }
                while j < n && !tok_is(toks, j, "{") {
                    j += 1;
                }
                if j >= n {
                    break;
                }
                let close = match_close(toks, j, "{", "}");
                if attr_is_cfg_test {
                    i = close + 1;
                } else {
                    ctx.push(Ctx::Impl(ty.unwrap_or_else(|| String::from("?")), close));
                    i = j + 1;
                }
                attr_line = None;
                attr_is_cfg_test = false;
            }
            "fn" => {
                let name = ident_at(toks, i + 1).unwrap_or("?").to_string();
                let start_line = ln;
                let mut j = i + 2;
                if tok_is(toks, j, "<") {
                    j = match_close(toks, j, "<", ">") + 1;
                }
                if tok_is(toks, j, "(") {
                    j = match_close(toks, j, "(", ")") + 1;
                }
                let params_end = j;
                // Return type / where clause: scan to `{` or `;`.
                let mut body_open: Option<usize> = None;
                while j < n {
                    if tok_is(toks, j, ";") {
                        break;
                    }
                    if tok_is(toks, j, "{") {
                        body_open = Some(j);
                        break;
                    }
                    if tok_is(toks, j, "<") {
                        j = match_close(toks, j, "<", ">") + 1;
                        continue;
                    }
                    j += 1;
                }
                let Some(body_open) = body_open else {
                    // Bodiless declaration (trait method, extern).
                    attr_line = None;
                    attr_is_cfg_test = false;
                    i = j + 1;
                    continue;
                };
                let close = match_close(toks, body_open, "{", "}");
                if attr_is_cfg_test {
                    attr_line = None;
                    attr_is_cfg_test = false;
                    i = close + 1;
                    continue;
                }
                let end_line = toks[close].line;
                let module: Vec<String> = ctx
                    .iter()
                    .filter_map(|c| match c {
                        Ctx::Mod(m, _) => Some(m.clone()),
                        Ctx::Impl(..) => None,
                    })
                    .collect();
                let impl_type = ctx.iter().rev().find_map(|c| match c {
                    Ctx::Impl(t, _) => Some(t.clone()),
                    Ctx::Mod(..) => None,
                });
                let mut f = FnInfo {
                    file: file.to_string(),
                    module,
                    impl_type,
                    name,
                    start_line,
                    end_line,
                    attr_line: attr_line.unwrap_or(start_line),
                    returns_result: toks[params_end..body_open]
                        .iter()
                        .any(|x| x.kind == TokKind::Ident && x.text == "Result"),
                    calls: Vec::new(),
                    sources: Vec::new(),
                    panics: Vec::new(),
                    indexes: Vec::new(),
                    locks: Vec::new(),
                    lock_edges: Vec::new(),
                    held_calls: Vec::new(),
                    held_may_calls: Vec::new(),
                };
                scan_body(&mut f, toks, body_open, close);
                out.items.push(ItemSpan { attr_line: f.attr_line, end_line });
                out.bodies.push((body_open, close));
                out.fns.push(f);
                attr_line = None;
                attr_is_cfg_test = false;
                i = close + 1;
            }
            "trait" => {
                // `trait Name[<…>][: Bounds] { … }` — descend so *default
                // method bodies* are parsed like inherent methods
                // (`impl_type` = the trait name) and enter the call
                // graph; bodiless declarations are skipped by the `fn`
                // arm as before.
                let name = ident_at(toks, i + 1).unwrap_or("?").to_string();
                let a_line = attr_line.unwrap_or(ln);
                let mut j = i + 2;
                let mut open: Option<usize> = None;
                while j < n {
                    if tok_is(toks, j, ";") {
                        j += 1;
                        break;
                    }
                    if tok_is(toks, j, "{") {
                        open = Some(j);
                        break;
                    }
                    if tok_is(toks, j, "<") {
                        j = match_close(toks, j, "<", ">") + 1;
                        continue;
                    }
                    j += 1;
                }
                if let Some(open) = open {
                    let close = match_close(toks, open, "{", "}");
                    out.items.push(ItemSpan { attr_line: a_line, end_line: toks[close].line });
                    if attr_is_cfg_test {
                        i = close + 1;
                    } else {
                        ctx.push(Ctx::Impl(name, close));
                        i = open + 1;
                    }
                } else {
                    i = j; // `trait Alias = …;` or malformed
                }
                attr_line = None;
                attr_is_cfg_test = false;
            }
            "struct" | "enum" | "union" | "type" | "static" | "const" | "use" => {
                let is_use = t.text == "use";
                let a_line = attr_line.unwrap_or(ln);
                let mut j = i + 1;
                let mut end_line = ln;
                while j < n {
                    if tok_is(toks, j, ";") {
                        end_line = toks[j].line;
                        j += 1;
                        break;
                    }
                    if tok_is(toks, j, "{") {
                        let close = match_close(toks, j, "{", "}");
                        end_line = toks[close].line;
                        j = close + 1;
                        break;
                    }
                    if tok_is(toks, j, "<") {
                        j = match_close(toks, j, "<", ">") + 1;
                        continue;
                    }
                    j += 1;
                }
                if !is_use {
                    out.items.push(ItemSpan { attr_line: a_line, end_line });
                }
                attr_line = None;
                attr_is_cfg_test = false;
                i = j;
            }
            _ => {
                attr_line = None;
                attr_is_cfg_test = false;
                i += 1;
            }
        }
    }
    out
}

/// Scan a function body (`toks[open_i..close_i]`) for the facts the
/// interprocedural analyses consume.
fn scan_body(f: &mut FnInfo, toks: &[Tok], open_i: usize, close_i: usize) {
    let stem = f
        .file
        .rsplit('/')
        .next()
        .unwrap_or(&f.file)
        .trim_end_matches(".rs")
        .to_string();
    // Guards currently held: (let binding, lock class, brace depth).
    let mut held: Vec<(Option<String>, String, usize)> = Vec::new();
    let mut depth = 0usize;
    let mut stmt_has_let = false;
    let mut let_var: Option<String> = None;
    let mut i = open_i;
    while i < close_i {
        let t = &toks[i];
        let ln = t.line;
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth = depth.saturating_sub(1);
                    held.retain(|(_, _, d)| *d <= depth);
                    stmt_has_let = false;
                    let_var = None;
                }
                ";" => {
                    stmt_has_let = false;
                    let_var = None;
                }
                "[" => {
                    // `expr[..]`: an index iff the previous token ends an
                    // expression (ident, `]`, or `)`).
                    if i > 0 {
                        let prev = &toks[i - 1];
                        let indexes = (prev.kind == TokKind::Ident && !is_keyword(&prev.text))
                            || (prev.kind == TokKind::Punct
                                && matches!(prev.text.as_str(), "]" | ")"));
                        if indexes {
                            f.indexes.push(ln);
                        }
                    }
                }
                _ => {}
            }
            i += 1;
            continue;
        }
        if t.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let s = t.text.as_str();
        if s == "let" {
            stmt_has_let = true;
            let mut j = i + 1;
            while ident_at(toks, j) == Some("mut") {
                j += 1;
            }
            let_var = (j < close_i).then(|| ident_at(toks, j)).flatten().map(String::from);
            i += 1;
            continue;
        }

        let nxt_is = |text: &str| tok_is(toks, i + 1, text);
        let is_method = i > 0 && tok_is(toks, i - 1, ".") && toks[i - 1].kind == TokKind::Punct;
        let qualified = i > 1 && tok_is(toks, i - 1, ":") && tok_is(toks, i - 2, ":");

        // Determinism taint sources.
        if matches!(s, "Instant" | "SystemTime")
            && tok_is(toks, i + 1, ":")
            && tok_is(toks, i + 2, ":")
            && ident_at(toks, i + 3) == Some("now")
        {
            f.sources.push(Site {
                kind: String::from("wallclock"),
                detail: format!("{s}::now"),
                line: ln,
            });
        }
        if matches!(s, "thread_rng" | "ThreadRng" | "OsRng" | "from_entropy") {
            f.sources.push(Site {
                kind: String::from("ambient-rng"),
                detail: s.to_string(),
                line: ln,
            });
        }
        if s == "rand"
            && tok_is(toks, i + 1, ":")
            && tok_is(toks, i + 2, ":")
            && ident_at(toks, i + 3) == Some("random")
        {
            f.sources.push(Site {
                kind: String::from("ambient-rng"),
                detail: String::from("rand::random"),
                line: ln,
            });
        }
        if matches!(s, "HashMap" | "HashSet") {
            f.sources.push(Site {
                kind: String::from("hash-order"),
                detail: s.to_string(),
                line: ln,
            });
        }

        // Panic sites.
        if is_method && matches!(s, "unwrap" | "expect") && nxt_is("(") {
            f.panics.push(Site { kind: s.to_string(), detail: s.to_string(), line: ln });
            i += 1;
            continue;
        }
        if is_method
            && matches!(s, "unchecked_add" | "unchecked_sub" | "unchecked_mul")
            && nxt_is("(")
        {
            f.panics.push(Site {
                kind: String::from("unchecked-arith"),
                detail: s.to_string(),
                line: ln,
            });
            i += 1;
            continue;
        }
        if matches!(s, "panic" | "unreachable" | "todo" | "unimplemented") && nxt_is("!") {
            f.panics.push(Site {
                kind: String::from("panic-macro"),
                detail: format!("{s}!"),
                line: ln,
            });
            i += 1;
            continue;
        }

        // Lock acquisition: `recv.lock(`.
        if is_method && s == "lock" && nxt_is("(") {
            let tail = receiver_tail(toks, i);
            let owner = f.impl_type.clone().unwrap_or_else(|| stem.clone());
            let class = format!("{owner}::{}", tail.as_deref().unwrap_or("?"));
            let is_held = stmt_has_let;
            f.locks.push(LockSite { class: class.clone(), line: ln, held: is_held });
            for (_, h, _) in &held {
                if *h != class {
                    f.lock_edges.push(LockEdge { from: h.clone(), to: class.clone(), line: ln });
                }
            }
            if is_held {
                held.push((let_var.clone(), class, depth));
            }
            i += 2;
            continue;
        }

        // Explicit early release: `drop(guard)` / `mem::drop(guard)` /
        // `std::mem::drop(guard)` — never a crate call (`Drop::drop`
        // cannot be invoked explicitly).
        let qual_is_mem = qualified && i >= 3 && ident_at(toks, i - 3) == Some("mem");
        if s == "drop" && !is_method && (!qualified || qual_is_mem) && nxt_is("(") {
            if let Some(var) = ident_at(toks, i + 2) {
                held.retain(|(v, _, _)| v.as_deref() != Some(var));
            }
            i += 2;
            continue;
        }

        // Call sites.
        if nxt_is("(") && !is_keyword(s) {
            if i > 0 && ident_at(toks, i - 1) == Some("fn") {
                i += 1;
                continue;
            }
            let mut qual: Option<String> = None;
            if qualified {
                if i >= 3 {
                    qual = ident_at(toks, i - 3).map(String::from);
                }
            }
            f.calls.push(Call {
                name: s.to_string(),
                qual: qual.clone(),
                is_method: is_method && qual.is_none(),
                line: ln,
            });
            if !held.is_empty() {
                let classes: Vec<String> = held.iter().map(|(_, c, _)| c.clone()).collect();
                f.held_calls.push((classes, f.calls.len() - 1));
            }
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn parse(src: &str) -> ParsedFile {
        parse_file("x.rs", &lex(src))
    }

    #[test]
    fn fns_get_module_and_impl_context() {
        let src = "mod inner {\n  struct S;\n  impl S {\n    pub fn m(&self) -> u32 { 1 }\n  }\n  fn free() {}\n}\nfn top() {}\n";
        let p = parse(src);
        let names: Vec<(String, Option<String>, Vec<String>)> = p
            .fns
            .iter()
            .map(|f| (f.name.clone(), f.impl_type.clone(), f.module.clone()))
            .collect();
        assert_eq!(names[0], ("m".into(), Some("S".into()), vec!["inner".into()]));
        assert_eq!(names[1], ("free".into(), None, vec!["inner".into()]));
        assert_eq!(names[2], ("top".into(), None, vec![]));
    }

    #[test]
    fn trait_impls_take_the_self_type_not_the_trait() {
        let src = "impl fmt::Display for Thing {\n  fn fmt(&self) -> u32 { 0 }\n}\n";
        let p = parse(src);
        assert_eq!(p.fns[0].impl_type.as_deref(), Some("Thing"));
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n  fn helper() { x.unwrap(); }\n}\nfn real() {}\n";
        let p = parse(src);
        assert_eq!(p.fns.len(), 1);
        assert_eq!(p.fns[0].name, "real");
    }

    #[test]
    fn body_facts_are_recorded() {
        let src = "fn f(v: &[u8]) {\n  let t = Instant::now();\n  let x = v.first().unwrap();\n  let y = v[0];\n  helper(x, y);\n  other.run();\n}\n";
        let p = parse(src);
        let f = &p.fns[0];
        assert_eq!(f.sources.len(), 1);
        assert_eq!(f.sources[0].detail, "Instant::now");
        assert_eq!(f.panics.len(), 1);
        assert_eq!(f.indexes, vec![4]);
        let call_names: Vec<&str> = f.calls.iter().map(|c| c.name.as_str()).collect();
        // `.first()` and `.run()` are method calls; `helper` is free.
        assert!(call_names.contains(&"helper"));
        assert!(call_names.contains(&"run"));
        let helper = f.calls.iter().find(|c| c.name == "helper").unwrap();
        assert!(!helper.is_method && helper.qual.is_none());
    }

    #[test]
    fn returns_result_scans_the_return_type_only() {
        let p = parse("fn ok() -> Result<u32, String> { Ok(1) }\n");
        assert!(p.fns[0].returns_result);
        // a Result *parameter* does not make the fn Result-returning
        let p = parse("fn take(r: Result<u32, String>) -> u32 { 0 }\n");
        assert!(!p.fns[0].returns_result);
    }

    #[test]
    fn lock_edges_and_drop_release() {
        let src = "impl P {\n  fn f(&self) {\n    let a = self.alpha.lock().unwrap();\n    let b = self.beta.lock().unwrap();\n    drop(a);\n    let c = self.gamma.lock().unwrap();\n  }\n}\n";
        let p = parse(src);
        let f = &p.fns[0];
        let edges: Vec<(String, String)> =
            f.lock_edges.iter().map(|e| (e.from.clone(), e.to.clone())).collect();
        // alpha held when beta acquired; after drop(a) only beta is held.
        assert!(edges.contains(&("P::alpha".into(), "P::beta".into())));
        assert!(edges.contains(&("P::beta".into(), "P::gamma".into())));
        assert!(!edges.contains(&("P::alpha".into(), "P::gamma".into())));
    }

    #[test]
    fn trait_default_bodies_are_parsed() {
        let src = "trait Ticker {\n  fn id(&self) -> u32;\n  fn tick(&self) -> u64 {\n    let t = Instant::now();\n    self.sample(t)\n  }\n}\n";
        let p = parse(src);
        // The bodiless `id` is skipped; the default body of `tick` is a
        // full FnInfo with the trait as its impl context.
        assert_eq!(p.fns.len(), 1);
        let f = &p.fns[0];
        assert_eq!(f.name, "tick");
        assert_eq!(f.impl_type.as_deref(), Some("Ticker"));
        assert_eq!(f.sources.len(), 1, "wallclock source inside the default body");
        assert!(f.calls.iter().any(|c| c.name == "sample"));
    }

    #[test]
    fn mem_drop_releases_the_guard() {
        let src = "impl P {\n  fn f(&self) {\n    let a = self.alpha.lock().unwrap();\n    mem::drop(a);\n    let b = self.beta.lock().unwrap();\n  }\n}\n";
        let p = parse(src);
        let f = &p.fns[0];
        assert!(
            f.lock_edges.is_empty(),
            "mem::drop(a) released alpha before beta was acquired: {:?}",
            f.lock_edges
        );
    }

    #[test]
    fn guards_release_at_scope_exit() {
        // Drop-at-scope-end: the inner-block guard is dead once its
        // brace closes, so no alpha→beta ordering edge exists.
        let src = "impl P {\n  fn f(&self) {\n    {\n      let a = self.alpha.lock().unwrap();\n      self.bump();\n    }\n    let b = self.beta.lock().unwrap();\n  }\n}\n";
        let p = parse(src);
        let f = &p.fns[0];
        assert!(f.lock_edges.is_empty(), "scope exit released alpha: {:?}", f.lock_edges);
        // …but the call made *inside* the scope saw the guard held.
        assert_eq!(f.held_calls.len(), 1);
        let (classes, idx) = &f.held_calls[0];
        assert_eq!(classes, &vec![String::from("P::alpha")]);
        assert_eq!(f.calls[*idx].name, "bump");
    }

    #[test]
    fn bodies_align_with_fns() {
        let src = "fn a() { one(); }\nfn b() { two(); }\n";
        let p = parse(src);
        assert_eq!(p.bodies.len(), p.fns.len());
        for (f, (open, close)) in p.fns.iter().zip(&p.bodies) {
            assert!(open < close);
            let _ = f;
        }
    }

    #[test]
    fn allow_anchor_is_the_attribute_line() {
        let src = "#[inline]\n#[must_use]\npub fn f() -> u32 { 1 }\n";
        let p = parse(src);
        assert_eq!(p.fns[0].attr_line, 1);
        assert_eq!(p.fns[0].start_line, 3);
        assert_eq!(p.items[0].attr_line, 1);
    }

    #[test]
    fn qualified_calls_keep_their_qualifier() {
        let src = "fn f() { crate::util::helper(); Widget::build(); }\n";
        let p = parse(src);
        let quals: Vec<(String, Option<String>)> =
            p.fns[0].calls.iter().map(|c| (c.name.clone(), c.qual.clone())).collect();
        assert!(quals.contains(&("helper".into(), Some("util".into()))));
        assert!(quals.contains(&("build".into(), Some("Widget".into()))));
    }
}
