//! Module-aware symbol table and intra-crate call graph over the
//! [`super::parser`] output.
//!
//! Resolution is deliberately conservative in *both* directions:
//!
//! * A qualified call (`Qual::f(…)`) resolves only to functions whose
//!   impl type, inline module, or file-derived module matches `Qual`;
//!   an unknown qualifier means an external crate/type and resolves to
//!   nothing (no false edges through `std`).
//! * A bare method call (`recv.f(…)`) can land on any impl fn named `f`
//!   — receiver types are unknown — *except* when `f` is on the
//!   [`STD_SHADOW`] list of ubiquitous std method names, which would
//!   otherwise connect every `.push(…)` to every `push` method in the
//!   crate. A bare free call resolves only to free fns.
//!
//! The over-approximation (same-name methods conflate) can produce
//! spurious reachability, never missed *local* facts; the taint and
//! panic analyses accept that trade and offer per-site allows.

use std::collections::BTreeMap;

use super::parser::{Call, FnInfo};

/// Method names so common in std that a bare `.name(…)` call says
/// nothing about which crate fn (if any) it lands on. Bare method calls
/// with these names resolve to no crate function; a qualified call
/// (`Type::name(…)`) still resolves exactly.
pub const STD_SHADOW: &[&str] = &[
    "new", "default", "clone", "len", "is_empty", "get", "get_mut", "insert", "remove", "push",
    "pop", "push_back", "pop_front", "front", "back", "contains", "contains_key", "iter",
    "iter_mut", "into_iter", "keys", "values", "into_keys", "into_values", "next", "entry",
    "or_insert", "or_default", "or_insert_with", "drain", "extend", "extend_from_slice", "sort",
    "sort_by", "sort_by_key", "sort_unstable", "retain", "clear", "last", "first", "split",
    "split_once", "split_at", "join", "concat", "send", "recv", "try_recv", "lock", "try_lock",
    "read", "write", "wait", "notify_one", "notify_all", "load", "store", "fetch_add",
    "fetch_sub", "compare_exchange", "swap", "take", "replace", "min", "max", "clamp", "abs",
    "floor", "ceil", "round", "to_string", "to_vec", "to_owned", "as_str", "as_bytes", "as_ref",
    "as_mut", "as_slice", "parse", "find", "rfind", "position", "rposition", "any", "all", "map",
    "map_err", "and_then", "or_else", "filter", "filter_map", "fold", "rev", "zip", "enumerate",
    "skip", "chain", "flat_map", "flatten", "collect", "count", "sum", "product", "starts_with",
    "ends_with", "trim", "trim_start", "trim_end", "chars", "bytes", "lines", "windows",
    "chunks", "chunks_exact", "unwrap_or", "unwrap_or_else", "unwrap_or_default", "ok", "err",
    "ok_or", "ok_or_else", "is_some", "is_none", "is_ok", "is_err", "cloned", "copied",
    "resize", "truncate", "reserve", "with_capacity", "from", "into", "try_into", "try_from",
    "eq", "ne", "cmp", "partial_cmp", "hash", "fmt", "flush", "name", "spawn", "abs_diff",
    "wrapping_add", "wrapping_sub", "saturating_add", "saturating_sub", "checked_add",
    "checked_sub", "checked_mul", "checked_div", "to_le_bytes", "to_be_bytes", "from_le_bytes",
    "from_be_bytes",
];

/// The first path component of a fn's file — its top-level module
/// (`rollout/actors.rs` → `rollout`, `lib.rs` → `lib`).
pub fn module_head(f: &FnInfo) -> String {
    let head = f.file.split('/').next().unwrap_or(&f.file);
    head.trim_end_matches(".rs").to_string()
}

/// The module path a file contributes: `util/mod.rs` → `["util"]`,
/// `env/holdout.rs` → `["env", "holdout"]`, `lib.rs` → `[]`.
pub fn file_mods(file: &str) -> Vec<String> {
    let comps: Vec<&str> = file.split('/').collect();
    let last = comps.last().map_or("", |l| l.trim_end_matches(".rs"));
    let mut mods: Vec<String> =
        comps[..comps.len().saturating_sub(1)].iter().map(|s| s.to_string()).collect();
    if last != "mod" && last != "lib" {
        mods.push(last.to_string());
    }
    mods
}

/// The intra-crate call graph: `edges[i]` lists `(callee index, call
/// line)` pairs, deduplicated per callee with the first call line kept.
pub struct CallGraph {
    pub edges: Vec<Vec<(usize, usize)>>,
    by_name: BTreeMap<String, Vec<usize>>,
}

impl CallGraph {
    pub fn build(fns: &[FnInfo]) -> CallGraph {
        let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (idx, f) in fns.iter().enumerate() {
            by_name.entry(f.name.clone()).or_default().push(idx);
        }
        let mut g = CallGraph { edges: vec![Vec::new(); fns.len()], by_name };
        for (idx, f) in fns.iter().enumerate() {
            let mut seen: Vec<usize> = Vec::new();
            for call in &f.calls {
                for c in g.resolve(fns, call, f) {
                    if !seen.contains(&c) {
                        seen.push(c);
                        g.edges[idx].push((c, call.line));
                    }
                }
            }
        }
        g
    }

    /// Candidate callee indices for one call site.
    pub fn resolve(&self, fns: &[FnInfo], call: &Call, caller: &FnInfo) -> Vec<usize> {
        let Some(cands) = self.by_name.get(&call.name) else {
            return Vec::new();
        };
        let qual: Option<&str> = match call.qual.as_deref() {
            Some("Self") => match caller.impl_type.as_deref() {
                Some(t) => Some(t),
                None => return Vec::new(),
            },
            q => q,
        };
        if let Some(q) = qual {
            let exact: Vec<usize> = cands
                .iter()
                .copied()
                .filter(|&c| {
                    fns[c].impl_type.as_deref() == Some(q)
                        || fns[c].module.last().map(String::as_str) == Some(q)
                })
                .collect();
            if !exact.is_empty() {
                return exact;
            }
            // The qualifier may be a file-level module (`util` for
            // util/mod.rs, `batcher` for serve/batcher.rs, …).
            return cands
                .iter()
                .copied()
                .filter(|&c| file_mods(&fns[c].file).last().map(String::as_str) == Some(q))
                .collect();
            // Anything else is an external type/module: unresolved.
        }
        if call.is_method {
            if STD_SHADOW.contains(&call.name.as_str()) {
                return Vec::new();
            }
            return cands.iter().copied().filter(|&c| fns[c].impl_type.is_some()).collect();
        }
        cands.iter().copied().filter(|&c| fns[c].impl_type.is_none()).collect()
    }

    /// Reverse reachability: BFS from `targets` over *incoming* edges
    /// (callee → caller). The returned map covers every fn whose calls
    /// can reach a target; the value is the next hop *toward* the
    /// target (`None` at targets themselves), so a report can walk the
    /// chain down to the blocking leaf.
    pub fn reach_rev(&self, targets: &[usize]) -> BTreeMap<usize, Option<usize>> {
        let mut callers: Vec<Vec<usize>> = vec![Vec::new(); self.edges.len()];
        for (u, outs) in self.edges.iter().enumerate() {
            for &(v, _) in outs {
                callers[v].push(u);
            }
        }
        let mut next: BTreeMap<usize, Option<usize>> = BTreeMap::new();
        for &t in targets {
            next.insert(t, None);
        }
        let mut queue: std::collections::VecDeque<usize> = targets.to_vec().into();
        while let Some(v) = queue.pop_front() {
            for &u in &callers[v] {
                if let std::collections::btree_map::Entry::Vacant(e) = next.entry(u) {
                    e.insert(Some(v));
                    queue.push_back(u);
                }
            }
        }
        next
    }

    /// Depth-first reachability from `roots`; the returned map holds a
    /// BFS/DFS parent per reached fn (`None` for roots) so reports can
    /// print a witness path.
    pub fn reach(&self, roots: &[usize]) -> BTreeMap<usize, Option<usize>> {
        let mut parent: BTreeMap<usize, Option<usize>> = BTreeMap::new();
        for &r in roots {
            parent.insert(r, None);
        }
        let mut stack: Vec<usize> = roots.to_vec();
        while let Some(u) = stack.pop() {
            for &(v, _) in &self.edges[u] {
                if let std::collections::btree_map::Entry::Vacant(e) = parent.entry(v) {
                    e.insert(Some(u));
                    stack.push(v);
                }
            }
        }
        parent
    }
}

/// Render the witness path `root <- … <- v` for a reached fn.
pub fn path_string(fns: &[FnInfo], parent: &BTreeMap<usize, Option<usize>>, v: usize) -> String {
    let mut chain: Vec<String> = Vec::new();
    let mut cur = Some(v);
    while let Some(u) = cur {
        chain.push(fns[u].qual_name());
        cur = parent.get(&u).copied().flatten();
    }
    chain.join(" <- ")
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::super::parser::parse_file;
    use super::*;

    fn graph_of(files: &[(&str, &str)]) -> (Vec<FnInfo>, CallGraph) {
        let mut fns = Vec::new();
        for (file, src) in files {
            fns.extend(parse_file(file, &lex(src)).fns);
        }
        let g = CallGraph::build(&fns);
        (fns, g)
    }

    fn edge(fns: &[FnInfo], g: &CallGraph, from: &str, to: &str) -> bool {
        let fi = fns.iter().position(|f| f.name == from).unwrap();
        g.edges[fi].iter().any(|&(v, _)| fns[v].name == to)
    }

    #[test]
    fn qualified_calls_resolve_through_file_modules() {
        let (fns, g) = graph_of(&[
            ("rollout/mod.rs", "pub fn step() { crate::util::helper(); }\n"),
            ("util/mod.rs", "pub fn helper() {}\n"),
        ]);
        assert!(edge(&fns, &g, "step", "helper"));
    }

    #[test]
    fn unknown_qualifiers_resolve_to_nothing() {
        // `Duration::new` must not link to the crate's own `new` methods.
        let (fns, g) = graph_of(&[
            ("a.rs", "struct W; impl W { pub fn new() -> W { W } }\nfn f() { let _ = Duration::new(); }\n"),
        ]);
        assert!(!edge(&fns, &g, "f", "new"));
    }

    #[test]
    fn std_shadow_blocks_bare_method_names() {
        let src = "struct Q; impl Q {\n  pub fn push(&self) { helper(); }\n  pub fn custom_step(&self) {}\n}\nfn helper() {}\nfn f(q: &Q) { q.push(); q.custom_step(); }\n";
        let (fns, g) = graph_of(&[("a.rs", src)]);
        // `.push(` is on the shadow list → no edge even though Q::push exists …
        assert!(!edge(&fns, &g, "f", "push"));
        // … but an uncommon method name still resolves.
        assert!(edge(&fns, &g, "f", "custom_step"));
        // and a *qualified* `Q::push()` would resolve exactly:
        let (fns2, g2) = graph_of(&[(
            "a.rs",
            "struct Q; impl Q { pub fn push(&self) {} }\nfn f() { Q::push(); }\n",
        )]);
        assert!(edge(&fns2, &g2, "f", "push"));
    }

    #[test]
    fn free_and_method_namespaces_do_not_cross() {
        let src = "struct S; impl S { pub fn dispatch(&self) {} }\nfn dispatch_all(s: &S) { s.dispatch(); }\nfn visit() { run(); }\nfn run() {}\n";
        let (fns, g) = graph_of(&[("a.rs", src)]);
        // bare free call `run()` only lands on the free fn
        assert!(edge(&fns, &g, "visit", "run"));
        // bare method `.dispatch()` only lands on impl fns
        assert!(edge(&fns, &g, "dispatch_all", "dispatch"));
    }

    #[test]
    fn reach_produces_witness_paths() {
        let (fns, g) = graph_of(&[(
            "a.rs",
            "fn root() { middle(); }\nfn middle() { leaf(); }\nfn leaf() {}\n",
        )]);
        let root = fns.iter().position(|f| f.name == "root").unwrap();
        let leaf = fns.iter().position(|f| f.name == "leaf").unwrap();
        let parent = g.reach(&[root]);
        assert!(parent.contains_key(&leaf));
        assert_eq!(path_string(&fns, &parent, leaf), "leaf <- middle <- root");
    }

    #[test]
    fn file_mods_shapes() {
        assert_eq!(file_mods("util/mod.rs"), vec!["util".to_string()]);
        assert_eq!(file_mods("env/holdout.rs"), vec!["env".to_string(), "holdout".to_string()]);
        assert!(file_mods("lib.rs").is_empty());
    }
}
