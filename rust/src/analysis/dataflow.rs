//! Generic worklist dataflow solver over a [`super::cfg::Cfg`].
//!
//! An analysis implements [`Analysis`]: a join-semilattice of facts
//! (`bottom` + `join`) and a per-node `transfer` function. The solver
//! iterates to a fixpoint in either direction; facts must form a finite
//! (or at least ascending-chain-bounded) lattice for termination, which
//! every client here satisfies — the facts are sets over program points
//! of one function, or small `Option`s, so the chain height is bounded
//! by the function size.
//!
//! The solver is deliberately simple: a FIFO worklist seeded in node
//! order, re-queueing successors (or predecessors, backward) whenever a
//! node's out-fact changes, with a large safety cap that turns a
//! non-converging lattice into a loud panic instead of a hang. The
//! convergence test in this module exercises a loop back-edge, the one
//! shape that actually requires iteration.

use super::cfg::Cfg;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Backward,
}

/// A dataflow problem over one CFG.
pub trait Analysis {
    /// The lattice element attached to node entries/exits.
    type Fact: Clone + PartialEq;

    fn direction(&self) -> Direction;

    /// The lattice bottom (initial value everywhere).
    fn bottom(&self) -> Self::Fact;

    /// Least upper bound of two facts (set union for may-analyses).
    fn join(&self, a: &Self::Fact, b: &Self::Fact) -> Self::Fact;

    /// Apply node `n`'s effect to the incoming fact.
    fn transfer(&self, n: usize, input: &Self::Fact) -> Self::Fact;
}

/// The fixpoint: for each node, the fact *entering* it (in the chosen
/// direction — the in-fact for forward analyses, the fact flowing back
/// from successors for backward ones).
pub struct Solution<F> {
    pub input: Vec<F>,
}

/// Run `analysis` to fixpoint over `cfg`.
pub fn solve<A: Analysis>(cfg: &Cfg, analysis: &A) -> Solution<A::Fact> {
    let n = cfg.nodes.len();
    let preds = cfg.preds();
    // flow[i]: the fact entering node i (direction-relative).
    let mut input: Vec<A::Fact> = vec![analysis.bottom(); n];
    let mut output: Vec<A::Fact> = vec![analysis.bottom(); n];
    let mut queue: std::collections::VecDeque<usize> = (0..n).collect();
    let mut queued = vec![true; n];
    // Chain height is bounded by function size; this cap only trips on
    // a lattice whose join/transfer violates monotonicity.
    let mut budget = 64usize.saturating_mul(n.max(1)).saturating_add(4096);
    while let Some(i) = queue.pop_front() {
        queued[i] = false;
        budget = budget.checked_sub(1).expect("dataflow solver failed to converge");
        // Join over direction-relative predecessors.
        let mut inp = analysis.bottom();
        let sources: Vec<usize> = match analysis.direction() {
            Direction::Forward => preds[i].clone(),
            Direction::Backward => cfg.succs[i].iter().map(|&(v, _)| v).collect(),
        };
        for s in sources {
            inp = analysis.join(&inp, &output[s]);
        }
        let out = analysis.transfer(i, &inp);
        input[i] = inp;
        if out != output[i] {
            output[i] = out;
            let dependents: Vec<usize> = match analysis.direction() {
                Direction::Forward => cfg.succs[i].iter().map(|&(v, _)| v).collect(),
                Direction::Backward => preds[i].clone(),
            };
            for d in dependents {
                if !queued[d] {
                    queued[d] = true;
                    queue.push_back(d);
                }
            }
        }
    }
    Solution { input }
}

#[cfg(test)]
mod tests {
    use super::super::cfg::{self, Cfg};
    use super::super::lexer::{lex, TokKind};
    use super::super::parser::match_close;
    use super::*;

    fn cfg_of(src: &str) -> (Cfg, Vec<super::super::lexer::Tok>) {
        let lexed = lex(src);
        let open = lexed
            .toks
            .iter()
            .position(|t| t.kind == TokKind::Punct && t.text == "{")
            .expect("fn body");
        let close = match_close(&lexed.toks, open, "{", "}");
        (cfg::build(&lexed.toks, open, close), lexed.toks)
    }

    /// Forward may-analysis: "set of `mark(..)` call-site token indexes
    /// seen on some path so far". Gen-only, so the loop back-edge forces
    /// a second visit of the header before the fixpoint.
    struct ReachingMarks<'a> {
        toks: &'a [super::super::lexer::Tok],
        cfg: &'a Cfg,
    }

    impl Analysis for ReachingMarks<'_> {
        type Fact = Vec<usize>;
        fn direction(&self) -> Direction {
            Direction::Forward
        }
        fn bottom(&self) -> Vec<usize> {
            Vec::new()
        }
        fn join(&self, a: &Vec<usize>, b: &Vec<usize>) -> Vec<usize> {
            let mut out = a.clone();
            for x in b {
                if !out.contains(x) {
                    out.push(*x);
                }
            }
            out.sort_unstable();
            out
        }
        fn transfer(&self, n: usize, input: &Vec<usize>) -> Vec<usize> {
            let node = self.cfg.nodes[n];
            let mut out = input.clone();
            for i in node.lo..node.hi.min(self.toks.len()) {
                if self.toks[i].kind == TokKind::Ident
                    && self.toks[i].text == "mark"
                    && !out.contains(&i)
                {
                    out.push(i);
                }
            }
            out.sort_unstable();
            out
        }
    }

    #[test]
    fn converges_over_a_loop_back_edge() {
        // The mark inside the loop body must flow around the back-edge
        // into the header's input fact, which requires iteration.
        let (cfg, toks) = cfg_of(
            "fn f(mut n: u32) { while n > 0 { mark(n); n -= 1; } done(); }",
        );
        let analysis = ReachingMarks { toks: &toks, cfg: &cfg };
        let sol = solve(&cfg, &analysis);
        // Find the loop header: the node with an incoming Back edge.
        let mut header = None;
        for (u, outs) in cfg.succs.iter().enumerate() {
            for &(v, k) in outs {
                if k == cfg::EdgeKind::Back {
                    header = Some((u, v));
                }
            }
        }
        let (body_end, header) = header.expect("loop back-edge");
        assert!(
            !sol.input[header].is_empty(),
            "mark must flow around the back-edge into the header"
        );
        assert!(!sol.input[body_end].is_empty());
        // And the node before the loop has no mark reaching it.
        assert!(sol.input[Cfg::ENTRY].is_empty());
    }

    /// Backward analysis: "an `emit` call is reachable ahead".
    struct EmitsAhead<'a> {
        toks: &'a [super::super::lexer::Tok],
        cfg: &'a Cfg,
    }

    impl Analysis for EmitsAhead<'_> {
        type Fact = bool;
        fn direction(&self) -> Direction {
            Direction::Backward
        }
        fn bottom(&self) -> bool {
            false
        }
        fn join(&self, a: &bool, b: &bool) -> bool {
            *a || *b
        }
        fn transfer(&self, n: usize, input: &bool) -> bool {
            let node = self.cfg.nodes[n];
            *input
                || (node.lo..node.hi.min(self.toks.len())).any(|i| {
                    self.toks[i].kind == TokKind::Ident && self.toks[i].text == "emit"
                })
        }
    }

    #[test]
    fn backward_reachability_stops_at_the_call() {
        let (cfg, toks) = cfg_of("fn f() { a(); emit(); b(); }");
        let analysis = EmitsAhead { toks: &toks, cfg: &cfg };
        let sol = solve(&cfg, &analysis);
        // From the entry, an emit lies ahead; from the exit, none does.
        assert!(sol.input[Cfg::ENTRY]);
        assert!(!sol.input[Cfg::EXIT]);
        // the straight-line statement node contains the emit
        let stmt = 3;
        assert!(analysis.transfer(stmt, &false));
    }
}
