//! The interprocedural analyses over the call graph: determinism taint
//! (`det-taint`), serve-path panic freedom (`serve-panic`), lock-order
//! consistency (`lock-order`), and held-guard blocking-call paths
//! (`lock-across-forward`).
//!
//! All consume the same inputs — parsed [`FnInfo`]s, the [`CallGraph`],
//! and the per-file allow tables — and report through the ordinary
//! [`Violation`] channel, so the binary, SARIF writer, and `lint_self`
//! test treat semantic findings exactly like lexical ones.

use std::collections::{BTreeMap, BTreeSet};

use super::callgraph::{module_head, path_string, CallGraph};
use super::parser::{Call, FnInfo};
use super::{Allow, Rule, Violation, DETERMINISTIC_MODULES};

/// Files whose top-level fns are serve-path roots: every request either
/// enters through the router's handlers or the batcher's drain loop.
const SERVE_ROOT_FILES: [&str; 2] = ["serve/router.rs", "serve/batcher.rs"];

type Allows = BTreeMap<String, Vec<Allow>>;

/// Whether `rule` at `file:line` is covered by an allow directive (same
/// line span, or the line directly below — item-extended allows already
/// carry the item's end line).
fn is_allowed(allows: &Allows, file: &str, rule: Rule, line: usize) -> bool {
    allows.get(file).is_some_and(|v| {
        v.iter().any(|a| a.rule == rule && a.line <= line && line <= a.line_end + 1)
    })
}

fn push(out: &mut Vec<Violation>, file: &str, line: usize, rule: Rule, message: String) {
    out.push(Violation { file: file.to_string(), line, rule, message });
}

/// Run the semantic analyses. Returns unsorted violations; the caller
/// merges them with the per-file findings and sorts globally.
pub fn analyze(fns: &[FnInfo], graph: &CallGraph, allows: &Allows) -> Vec<Violation> {
    let mut out = Vec::new();
    det_taint(fns, graph, allows, &mut out);
    serve_panic(fns, graph, allows, &mut out);
    lock_order(fns, graph, allows, &mut out);
    lock_across_forward(fns, graph, allows, &mut out);
    out
}

/// Call names that block on the device or the wire: the PJRT forward
/// entry points and the serve-side socket writer. Matched by name —
/// these are crate-specific enough that name matching is exact, and an
/// *unresolved* method call with one of these names is still a direct
/// finding (the receiver is a device/stream handle, not a crate type).
const BLOCKING_LEAVES: [&str; 3] = ["forward_direct", "forward_into", "write_response"];

/// `lock-across-forward`: a guard that may still be held (per the flow
/// pass's CFG may-held analysis, [`FnInfo::held_may_calls`]) across a
/// blocking call — directly, or through a callee that transitively
/// reaches one of the blocking leaves.
fn lock_across_forward(fns: &[FnInfo], graph: &CallGraph, allows: &Allows, out: &mut Vec<Violation>) {
    let direct: Vec<usize> = (0..fns.len())
        .filter(|&i| fns[i].calls.iter().any(|c| BLOCKING_LEAVES.contains(&c.name.as_str())))
        .collect();
    let next = graph.reach_rev(&direct);
    for f in fns {
        for h in &f.held_may_calls {
            if is_allowed(allows, &f.file, Rule::LockAcrossForward, h.line) {
                continue;
            }
            let classes = h.classes.join(", ");
            if BLOCKING_LEAVES.contains(&h.name.as_str()) {
                push(
                    out,
                    &f.file,
                    h.line,
                    Rule::LockAcrossForward,
                    format!(
                        "guard `{classes}` may be held across blocking call `{}` in {} — \
                         a stalled forward/socket write under the lock stalls every \
                         queued waiter",
                        h.name,
                        f.qual_name()
                    ),
                );
                continue;
            }
            let call = Call {
                name: h.name.clone(),
                qual: h.qual.clone(),
                is_method: h.is_method,
                line: h.line,
            };
            let Some(target) =
                graph.resolve(fns, &call, f).into_iter().find(|c| next.contains_key(c))
            else {
                continue;
            };
            // Walk the chain down to the fn holding the blocking leaf.
            let mut chain = vec![fns[target].qual_name()];
            let mut cur = target;
            while let Some(n) = next.get(&cur).copied().flatten() {
                chain.push(fns[n].qual_name());
                cur = n;
            }
            let leaf = fns[cur]
                .calls
                .iter()
                .find(|c| BLOCKING_LEAVES.contains(&c.name.as_str()))
                .map(|c| c.name.clone())
                .unwrap_or_default();
            push(
                out,
                &f.file,
                h.line,
                Rule::LockAcrossForward,
                format!(
                    "guard `{classes}` may be held across `{}` in {}, which reaches \
                     blocking `{leaf}` via {}",
                    h.name,
                    f.qual_name(),
                    chain.join(" -> ")
                ),
            );
        }
    }
}

/// `det-taint`: any fn transitively reachable from the deterministic
/// module trees must not touch a nondeterminism source (wallclock,
/// ambient RNG, hash-ordered collections) without a sanctioned allow.
/// Sources *inside* the deterministic modules are already covered by the
/// per-file rules; this pass catches the leak through helpers elsewhere.
fn det_taint(fns: &[FnInfo], graph: &CallGraph, allows: &Allows, out: &mut Vec<Violation>) {
    let roots: Vec<usize> = (0..fns.len())
        .filter(|&i| DETERMINISTIC_MODULES.contains(&module_head(&fns[i]).as_str()))
        .collect();
    let parent = graph.reach(&roots);
    for (&i, _) in &parent {
        let f = &fns[i];
        let head = module_head(f);
        if DETERMINISTIC_MODULES.contains(&head.as_str()) {
            continue;
        }
        // The linter's own rule tables (and its binary) necessarily name
        // the banned symbols; they are vocabulary, not uses.
        if head == "analysis" || head == "bin" {
            continue;
        }
        for s in &f.sources {
            if is_allowed(allows, &f.file, Rule::DetTaint, s.line) {
                continue;
            }
            push(
                out,
                &f.file,
                s.line,
                Rule::DetTaint,
                format!(
                    "{} ({}) in {} reachable from deterministic code via {}",
                    s.detail,
                    s.kind,
                    f.qual_name(),
                    path_string(fns, &parent, i)
                ),
            );
        }
    }
}

/// `serve-panic`: the serving path must not panic on untrusted input.
/// Every fn in `serve/` is audited directly (panic sites always; index
/// sites only in fns without a `Result` error path), and panic sites in
/// fns transitively reachable from the router/batcher roots are flagged
/// wherever they live.
fn serve_panic(fns: &[FnInfo], graph: &CallGraph, allows: &Allows, out: &mut Vec<Violation>) {
    let roots: Vec<usize> = (0..fns.len())
        .filter(|&i| SERVE_ROOT_FILES.contains(&fns[i].file.as_str()))
        .collect();
    let parent = graph.reach(&roots);
    for (i, f) in fns.iter().enumerate() {
        if f.file.starts_with("serve/") {
            for p in &f.panics {
                if is_allowed(allows, &f.file, Rule::ServePanic, p.line) {
                    continue;
                }
                push(
                    out,
                    &f.file,
                    p.line,
                    Rule::ServePanic,
                    format!("{} in serve fn {}", p.detail, f.qual_name()),
                );
            }
            // A Result-returning fn has an error path; its index sites
            // are assumed routed through validation. unwrap/expect in
            // such fns stay flagged — they bypass that very path.
            if !f.returns_result {
                for &line in &f.indexes {
                    if is_allowed(allows, &f.file, Rule::ServePanic, line) {
                        continue;
                    }
                    push(
                        out,
                        &f.file,
                        line,
                        Rule::ServePanic,
                        format!("slice/array index in serve fn {}", f.qual_name()),
                    );
                }
            }
        } else if parent.contains_key(&i) {
            for p in &f.panics {
                if is_allowed(allows, &f.file, Rule::ServePanic, p.line) {
                    continue;
                }
                push(
                    out,
                    &f.file,
                    p.line,
                    Rule::ServePanic,
                    format!(
                        "{} in {} reachable from serve via {}",
                        p.detail,
                        f.qual_name(),
                        path_string(fns, &parent, i)
                    ),
                );
            }
        }
    }
}

/// `lock-order`: collect held→acquired edges per fn (direct, plus calls
/// made under a held guard into each callee's transitive lockset) and
/// report any cycle in the resulting order graph.
fn lock_order(fns: &[FnInfo], graph: &CallGraph, allows: &Allows, out: &mut Vec<Violation>) {
    // Transitive lockset per fn, to fixpoint.
    let mut locksets: Vec<BTreeSet<String>> =
        fns.iter().map(|f| f.locks.iter().map(|l| l.class.clone()).collect()).collect();
    let mut changed = true;
    while changed {
        changed = false;
        for i in 0..fns.len() {
            for k in 0..graph.edges[i].len() {
                let (v, _) = graph.edges[i][k];
                if v == i {
                    continue;
                }
                let add: Vec<String> =
                    locksets[v].iter().filter(|c| !locksets[i].contains(*c)).cloned().collect();
                if !add.is_empty() {
                    locksets[i].extend(add);
                    changed = true;
                }
            }
        }
    }

    // Order graph: (from, to) -> first witnessing (file, line).
    let mut order: BTreeMap<(String, String), (String, usize)> = BTreeMap::new();
    for (i, f) in fns.iter().enumerate() {
        for e in &f.lock_edges {
            if is_allowed(allows, &f.file, Rule::LockOrder, e.line) {
                continue;
            }
            order
                .entry((e.from.clone(), e.to.clone()))
                .or_insert_with(|| (f.file.clone(), e.line));
        }
        for (held_classes, call_idx) in &f.held_calls {
            let call = &f.calls[*call_idx];
            if is_allowed(allows, &f.file, Rule::LockOrder, call.line) {
                continue;
            }
            let mut target: BTreeSet<String> = BTreeSet::new();
            for c in graph.resolve(fns, call, f) {
                target.extend(locksets[c].iter().cloned());
            }
            for h in held_classes {
                for c in &target {
                    if c != h {
                        order
                            .entry((h.clone(), c.clone()))
                            .or_insert_with(|| (f.file.clone(), call.line));
                    }
                }
            }
        }
    }

    // Cycle detection: DFS from each node, deduplicating cycles by their
    // (unordered) node set so each is reported once, at the closing edge.
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (a, b) in order.keys() {
        adj.entry(a.as_str()).or_default().push(b.as_str());
    }
    let mut seen_cycles: BTreeSet<Vec<String>> = BTreeSet::new();
    let starts: Vec<&str> = adj.keys().copied().collect();
    for start in starts {
        let mut stack: Vec<(&str, Vec<&str>)> = vec![(start, vec![start])];
        while let Some((u, path)) = stack.pop() {
            for &v in adj.get(u).map(Vec::as_slice).unwrap_or(&[]) {
                if v == start {
                    let mut key: Vec<String> = path.iter().map(|s| s.to_string()).collect();
                    key.sort();
                    if seen_cycles.insert(key) {
                        let (file, line) = &order[&(u.to_string(), start.to_string())];
                        let mut cycle: Vec<&str> = path.clone();
                        cycle.push(start);
                        push(
                            out,
                            file,
                            *line,
                            Rule::LockOrder,
                            format!("lock-order cycle: {}", cycle.join(" -> ")),
                        );
                    }
                } else if !path.contains(&v) {
                    let mut next = path.clone();
                    next.push(v);
                    stack.push((v, next));
                }
            }
        }
    }
}
