//! The per-function flow-sensitive analyses built on [`super::cfg`] +
//! [`super::dataflow`]: the branch-aware *may-held* guard tracking that
//! feeds `lock-across-forward`, the `rng-lineage` stream-aliasing check,
//! and the `flush-on-error` buffered-rows check.
//!
//! All three run inside the per-file front-end (`analyze_file`), so
//! their findings are cached, allow-filtered, and rendered exactly like
//! the lexical rules. Over-approximation direction (documented per rule
//! in the README catalog): path-insensitive across closures and
//! `match`-guard conditions — the analyses may report a path the program
//! never takes (false positive, silenced with a reasoned allow), never
//! the reverse.

use super::cfg::Cfg;
use super::dataflow::{solve, Analysis, Direction};
use super::lexer::{Tok, TokKind};
use super::parser::{is_keyword, match_close, receiver_tail, FnInfo, HeldCall};
use super::{Rule, Violation};

fn tok_is(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.text == text)
}

fn ident_at(toks: &[Tok], i: usize) -> Option<&str> {
    toks.get(i).and_then(|t| (t.kind == TokKind::Ident).then_some(t.text.as_str()))
}

fn is_method_call(toks: &[Tok], i: usize) -> bool {
    i > 0 && toks[i - 1].kind == TokKind::Punct && toks[i - 1].text == "."
}

fn is_qualified(toks: &[Tok], i: usize) -> bool {
    i > 1 && tok_is(toks, i - 1, ":") && tok_is(toks, i - 2, ":")
}

// ---------------------------------------------------------------------
// Guard prescan + may-held dataflow (feeds `lock-across-forward`)
// ---------------------------------------------------------------------

/// One `let`-bound `.lock()` guard in a function body, with its lexical
/// scope bounds. The dataflow tracks these by index.
#[derive(Clone, Debug)]
pub struct Guard {
    /// Token index of the `lock` ident.
    pub tok: usize,
    pub line: usize,
    /// Lock class, same naming as the linear scan:
    /// `{impl type or file stem}::{receiver tail}`.
    pub class: String,
    /// The `let` binding, when recognizable (kills via `drop(var)`).
    pub var: Option<String>,
    /// First token index at which the binding's brace scope has closed
    /// (`Drop`-at-scope-end) — a sound lexical bound on liveness.
    pub scope_end_tok: usize,
}

/// Linear prescan for `let`-bound guards with their scope extents.
pub fn guards(f: &FnInfo, toks: &[Tok], open_i: usize, close_i: usize) -> Vec<Guard> {
    let stem =
        f.file.rsplit('/').next().unwrap_or(&f.file).trim_end_matches(".rs").to_string();
    let mut out: Vec<Guard> = Vec::new();
    // Guards whose scope is still open: (index into `out`, acq depth).
    let mut open_guards: Vec<(usize, usize)> = Vec::new();
    let mut depth = 0usize;
    let mut stmt_has_let = false;
    let mut let_var: Option<String> = None;
    let mut i = open_i;
    while i < close_i {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth = depth.saturating_sub(1);
                    open_guards.retain(|&(g, d)| {
                        if d > depth {
                            out[g].scope_end_tok = i;
                            false
                        } else {
                            true
                        }
                    });
                    stmt_has_let = false;
                    let_var = None;
                }
                ";" => {
                    stmt_has_let = false;
                    let_var = None;
                }
                _ => {}
            }
            i += 1;
            continue;
        }
        if t.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let s = t.text.as_str();
        if s == "let" {
            stmt_has_let = true;
            let mut j = i + 1;
            while ident_at(toks, j) == Some("mut") {
                j += 1;
            }
            let_var = ident_at(toks, j).map(String::from);
            i += 1;
            continue;
        }
        if is_method_call(toks, i) && s == "lock" && tok_is(toks, i + 1, "(") && stmt_has_let {
            let owner = f.impl_type.clone().unwrap_or_else(|| stem.clone());
            let class =
                format!("{owner}::{}", receiver_tail(toks, i).as_deref().unwrap_or("?"));
            out.push(Guard {
                tok: i,
                line: t.line,
                class,
                var: let_var.clone(),
                scope_end_tok: close_i,
            });
            open_guards.push((out.len() - 1, depth));
            i += 2;
            continue;
        }
        i += 1;
    }
    out
}

/// Apply the guard acquire/release effect of token `i` to a may-held
/// state (sorted guard indexes). Scope-end release is *not* an event —
/// it is enforced by the `scope_end_tok` bound at use sites, which keeps
/// the transfer monotone across loop back-edges.
fn guard_event(toks: &[Tok], guards: &[Guard], i: usize, state: &mut Vec<usize>) {
    if let Some(g) = guards.iter().position(|g| g.tok == i) {
        if !state.contains(&g) {
            state.push(g);
            state.sort_unstable();
        }
        return;
    }
    let t = &toks[i];
    if t.kind == TokKind::Ident && t.text == "drop" && tok_is(toks, i + 1, "(") {
        let qualified = is_qualified(toks, i);
        let qual_is_mem = qualified && i >= 3 && ident_at(toks, i - 3) == Some("mem");
        if !is_method_call(toks, i) && (!qualified || qual_is_mem) {
            if let Some(var) = ident_at(toks, i + 2) {
                state.retain(|&g| guards[g].var.as_deref() != Some(var));
            }
        }
    }
}

struct MayHeld<'a> {
    toks: &'a [Tok],
    cfg: &'a Cfg,
    guards: &'a [Guard],
}

impl Analysis for MayHeld<'_> {
    type Fact = Vec<usize>;
    fn direction(&self) -> Direction {
        Direction::Forward
    }
    fn bottom(&self) -> Vec<usize> {
        Vec::new()
    }
    fn join(&self, a: &Vec<usize>, b: &Vec<usize>) -> Vec<usize> {
        let mut out = a.clone();
        for x in b {
            if !out.contains(x) {
                out.push(*x);
            }
        }
        out.sort_unstable();
        out
    }
    fn transfer(&self, n: usize, input: &Vec<usize>) -> Vec<usize> {
        let node = self.cfg.nodes[n];
        let mut st = input.clone();
        for i in node.lo..node.hi.min(self.toks.len()) {
            guard_event(self.toks, self.guards, i, &mut st);
        }
        st
    }
}

/// Whether the ident at `i` (followed by `(`) is a call site by the same
/// rules as the linear body scan — skipping `fn name(` headers, lock
/// acquisitions, drop releases, and the panic-method family (which the
/// scan treats as panic sites, not calls).
fn is_call_site(toks: &[Tok], i: usize) -> bool {
    let t = &toks[i];
    if t.kind != TokKind::Ident || !tok_is(toks, i + 1, "(") || is_keyword(&t.text) {
        return false;
    }
    if i > 0 && ident_at(toks, i - 1) == Some("fn") {
        return false;
    }
    let s = t.text.as_str();
    let method = is_method_call(toks, i);
    if method
        && matches!(
            s,
            "lock" | "unwrap" | "expect" | "unchecked_add" | "unchecked_sub" | "unchecked_mul"
        )
    {
        return false;
    }
    let qualified = is_qualified(toks, i);
    let qual_is_mem = qualified && i >= 3 && ident_at(toks, i - 3) == Some("mem");
    if s == "drop" && !method && (!qualified || qual_is_mem) {
        return false;
    }
    true
}

/// The branch-sensitive replacement for the linear held-call scan: calls
/// where a guard *may* still be live on some path (e.g. dropped on only
/// one arm of an `if`), bounded by each guard's lexical scope.
pub fn held_may_calls(toks: &[Tok], cfg: &Cfg, guards: &[Guard]) -> Vec<HeldCall> {
    if guards.is_empty() {
        return Vec::new();
    }
    let sol = solve(cfg, &MayHeld { toks, cfg, guards });
    let mut found: Vec<(usize, HeldCall)> = Vec::new();
    for (n, node) in cfg.nodes.iter().enumerate() {
        let mut st = sol.input[n].clone();
        for i in node.lo..node.hi.min(toks.len()) {
            if is_call_site(toks, i) {
                let live: Vec<&Guard> = st
                    .iter()
                    .map(|&g| &guards[g])
                    .filter(|g| g.tok <= i && i <= g.scope_end_tok)
                    .collect();
                if !live.is_empty() {
                    let mut classes: Vec<String> =
                        live.iter().map(|g| g.class.clone()).collect();
                    classes.dedup();
                    let qualified = is_qualified(toks, i);
                    let qual = if qualified {
                        ident_at(toks, i.wrapping_sub(3)).map(String::from)
                    } else {
                        None
                    };
                    found.push((
                        i,
                        HeldCall {
                            classes,
                            name: toks[i].text.clone(),
                            qual: qual.clone(),
                            is_method: is_method_call(toks, i) && qual.is_none(),
                            line: toks[i].line,
                        },
                    ));
                }
            }
            guard_event(toks, guards, i, &mut st);
        }
    }
    found.sort_by_key(|&(i, _)| i);
    found.dedup_by_key(|&mut (i, _)| i);
    found.into_iter().map(|(_, h)| h).collect()
}

// ---------------------------------------------------------------------
// rng-lineage
// ---------------------------------------------------------------------

/// One RNG-stream construction site.
#[derive(Clone, Debug)]
struct RngSite {
    /// Token index of the leading ident.
    tok: usize,
    line: usize,
    /// `ctor(normalized args)` — the (seed, index) key as written.
    key: String,
}

/// Normalize the argument tokens of a construction call: top-level
/// commas split, token texts joined with single spaces. Textual keying
/// over-approximates *sameness* only when two spellings are identical —
/// distinct expressions that alias at runtime are not caught (that
/// direction is unsound for a lint and is left to the runtime sweeps).
fn normalize_args(toks: &[Tok], lo: usize, close: usize) -> String {
    let mut args: Vec<String> = Vec::new();
    let mut cur: Vec<&str> = Vec::new();
    let mut depth = 0i32;
    let mut i = lo;
    while i < close {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                "," if depth == 0 => {
                    args.push(cur.join(" "));
                    cur.clear();
                    i += 1;
                    continue;
                }
                _ => {}
            }
        }
        cur.push(t.text.as_str());
        i += 1;
    }
    if !cur.is_empty() {
        args.push(cur.join(" "));
    }
    args.join("; ")
}

/// Find every RNG construction site in `[open_i, close_i)`:
/// `Pcg64::…(…)`, `ColumnRngs::…(…)`, and `adhoc_episode_rng(…)`.
fn rng_sites(toks: &[Tok], open_i: usize, close_i: usize) -> Vec<RngSite> {
    let mut out = Vec::new();
    let mut i = open_i;
    while i < close_i {
        let Some(s) = ident_at(toks, i) else {
            i += 1;
            continue;
        };
        if matches!(s, "Pcg64" | "ColumnRngs")
            && tok_is(toks, i + 1, ":")
            && tok_is(toks, i + 2, ":")
            && ident_at(toks, i + 3).is_some()
            && tok_is(toks, i + 4, "(")
        {
            let ctor = format!("{s}::{}", toks[i + 3].text);
            let close = match_close(toks, i + 4, "(", ")");
            out.push(RngSite {
                tok: i,
                line: toks[i].line,
                key: format!("{ctor}({})", normalize_args(toks, i + 5, close)),
            });
            i += 5;
            continue;
        }
        if s == "adhoc_episode_rng"
            && tok_is(toks, i + 1, "(")
            && !(i > 0 && ident_at(toks, i - 1) == Some("fn"))
        {
            let close = match_close(toks, i + 1, "(", ")");
            out.push(RngSite {
                tok: i,
                line: toks[i].line,
                key: format!("adhoc_episode_rng({})", normalize_args(toks, i + 2, close)),
            });
            i += 2;
            continue;
        }
        i += 1;
    }
    out
}

struct ReachingRng<'a> {
    toks: &'a [Tok],
    cfg: &'a Cfg,
    sites: &'a [RngSite],
}

impl Analysis for ReachingRng<'_> {
    type Fact = Vec<usize>;
    fn direction(&self) -> Direction {
        Direction::Forward
    }
    fn bottom(&self) -> Vec<usize> {
        Vec::new()
    }
    fn join(&self, a: &Vec<usize>, b: &Vec<usize>) -> Vec<usize> {
        let mut out = a.clone();
        for x in b {
            if !out.contains(x) {
                out.push(*x);
            }
        }
        out.sort_unstable();
        out
    }
    fn transfer(&self, n: usize, input: &Vec<usize>) -> Vec<usize> {
        let node = self.cfg.nodes[n];
        let mut st = input.clone();
        for (idx, s) in self.sites.iter().enumerate() {
            if s.tok >= node.lo && s.tok < node.hi.min(self.toks.len()) && !st.contains(&idx) {
                st.push(idx);
            }
        }
        st.sort_unstable();
        st
    }
}

/// `rng-lineage`: flag a second RNG stream built from a (seed, index)
/// key that an earlier stream *on the same path* already used, plus an
/// RNG binding forked with `.clone()`. Branch-exclusive duplicates
/// (match arms, `if`/`else`) are clean — that is the point of running
/// this on the CFG instead of linearly.
pub fn rng_lineage(f: &FnInfo, toks: &[Tok], cfg: &Cfg, open_i: usize, close_i: usize) -> Vec<Violation> {
    let mut out = Vec::new();
    let sites = rng_sites(toks, open_i, close_i);
    if sites.len() > 1 {
        let sol = solve(cfg, &ReachingRng { toks, cfg, sites: &sites });
        for (n, node) in cfg.nodes.iter().enumerate() {
            let mut st = sol.input[n].clone();
            for (idx, s) in sites.iter().enumerate() {
                if s.tok < node.lo || s.tok >= node.hi.min(toks.len()) {
                    continue;
                }
                let dup = st
                    .iter()
                    .filter(|&&r| r != idx && sites[r].key == s.key)
                    .map(|&r| sites[r].line)
                    .min();
                if let Some(first) = dup {
                    out.push(Violation {
                        file: f.file.clone(),
                        line: s.line,
                        rule: Rule::RngLineage,
                        message: format!(
                            "second RNG stream from key `{}` in {} — an identical stream \
                             was already constructed on this path at line {first}; aliased \
                             (seed, index) keys replay the same sequence",
                            s.key,
                            f.qual_name()
                        ),
                    });
                }
                if !st.contains(&idx) {
                    st.push(idx);
                    st.sort_unstable();
                }
            }
        }
    }

    // Clone-fork: a binding holding a fresh stream later `.clone()`d.
    let site_toks: Vec<usize> = sites.iter().map(|s| s.tok).collect();
    let mut rng_vars: Vec<String> = Vec::new();
    let mut let_var: Option<String> = None;
    let mut i = open_i;
    while i < close_i {
        let t = &toks[i];
        if t.kind == TokKind::Punct && matches!(t.text.as_str(), ";" | "}") {
            let_var = None;
            i += 1;
            continue;
        }
        if t.kind == TokKind::Ident && t.text == "let" {
            let mut j = i + 1;
            while ident_at(toks, j) == Some("mut") {
                j += 1;
            }
            let_var = ident_at(toks, j).map(String::from);
            i += 1;
            continue;
        }
        if site_toks.contains(&i) {
            if let Some(v) = &let_var {
                if !rng_vars.contains(v) {
                    rng_vars.push(v.clone());
                }
            }
        }
        i += 1;
    }
    let mut i = open_i;
    while i < close_i {
        if let Some(v) = ident_at(toks, i) {
            if rng_vars.iter().any(|r| r == v)
                && tok_is(toks, i + 1, ".")
                && ident_at(toks, i + 2) == Some("clone")
                && tok_is(toks, i + 3, "(")
            {
                out.push(Violation {
                    file: f.file.clone(),
                    line: toks[i].line,
                    rule: Rule::RngLineage,
                    message: format!(
                        "RNG stream `{v}` forked with `.clone()` in {} — a cloned \
                         generator replays the same sequence into a second consumer; \
                         derive a fresh stream from a distinct (seed, index) key instead",
                        f.qual_name()
                    ),
                });
            }
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------
// flush-on-error
// ---------------------------------------------------------------------

const FLUSH_NAMES: [&str; 2] = ["flush_sinks", "flush"];

/// Backward fact: the line of the nearest error-propagation point
/// (`?`, `return Err(…)`, `bail!`, `ensure!`) reachable ahead with *no*
/// flush call in between — `None` when every path ahead flushes first
/// (or never errors). This is the complement of the must-flush property,
/// evaluated where it matters: at `step_cycle` call sites.
struct BareErrAhead<'a> {
    toks: &'a [Tok],
    cfg: &'a Cfg,
}

/// Reverse-scan one token's effect: flushes clear the fact, error points
/// set it to their own line (they are the *nearest* err ahead).
fn err_event(toks: &[Tok], i: usize, st: &mut Option<usize>) {
    let t = &toks[i];
    if t.kind == TokKind::Ident
        && FLUSH_NAMES.contains(&t.text.as_str())
        && tok_is(toks, i + 1, "(")
    {
        *st = None;
        return;
    }
    let is_err_point = (t.kind == TokKind::Punct
        && t.text == "?"
        && ident_at(toks, i + 1) != Some("Sized"))
        || (t.kind == TokKind::Ident
            && t.text == "return"
            && ident_at(toks, i + 1) == Some("Err"))
        || (t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "bail" | "ensure")
            && tok_is(toks, i + 1, "!"));
    if is_err_point {
        *st = Some(t.line);
    }
}

impl Analysis for BareErrAhead<'_> {
    type Fact = Option<usize>;
    fn direction(&self) -> Direction {
        Direction::Backward
    }
    fn bottom(&self) -> Option<usize> {
        None
    }
    fn join(&self, a: &Option<usize>, b: &Option<usize>) -> Option<usize> {
        match (a, b) {
            (Some(x), Some(y)) => Some(*x.min(y)),
            (Some(x), None) | (None, Some(x)) => Some(*x),
            (None, None) => None,
        }
    }
    fn transfer(&self, n: usize, input: &Option<usize>) -> Option<usize> {
        let node = self.cfg.nodes[n];
        let mut st = *input;
        for i in (node.lo..node.hi.min(self.toks.len())).rev() {
            err_event(self.toks, i, &mut st);
        }
        st
    }
}

/// `flush-on-error`: at every `step_cycle` call site, some error path
/// must not be able to propagate out before `flush_sinks`/`flush` runs —
/// otherwise the metrics rows buffered by the interrupted cycle are lost
/// (the PR 7 data-loss bug as a lint).
pub fn flush_on_error(f: &FnInfo, toks: &[Tok], cfg: &Cfg) -> Vec<Violation> {
    let has_site = f.calls.iter().any(|c| c.name == "step_cycle");
    if !has_site {
        return Vec::new();
    }
    let sol = solve(cfg, &BareErrAhead { toks, cfg });
    let mut out = Vec::new();
    for (n, node) in cfg.nodes.iter().enumerate() {
        let mut st = sol.input[n];
        for i in (node.lo..node.hi.min(toks.len())).rev() {
            if ident_at(toks, i) == Some("step_cycle")
                && tok_is(toks, i + 1, "(")
                && !(i > 0 && ident_at(toks, i - 1) == Some("fn"))
            {
                if let Some(err_line) = st {
                    out.push(Violation {
                        file: f.file.clone(),
                        line: toks[i].line,
                        rule: Rule::FlushOnError,
                        message: format!(
                            "error exit at line {err_line} of {} can propagate before \
                             `flush_sinks`/`flush` runs — metrics rows buffered by this \
                             `step_cycle` cycle are lost on that path",
                            f.qual_name()
                        ),
                    });
                }
            }
            err_event(toks, i, &mut st);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::cfg;
    use super::super::lexer::lex;
    use super::super::parser::parse_file;
    use super::*;

    /// Parse `src` (one fn), returning what the flow pass consumes.
    fn front(src: &str) -> (FnInfo, Vec<Tok>, Cfg, usize, usize) {
        let lexed = lex(src);
        let parsed = parse_file("rollout/mod.rs", &lexed);
        assert_eq!(parsed.fns.len(), 1, "fixture must hold exactly one fn");
        let (open, close) = parsed.bodies[0];
        let c = cfg::build(&lexed.toks, open, close);
        (parsed.fns[0].clone(), lexed.toks, c, open, close)
    }

    #[test]
    fn may_held_sees_the_branchy_drop() {
        // The guard is dropped on only one arm, so the call after the
        // `if` may still hold it — invisible to the linear scan.
        let src = "impl P {\n  fn f(&self, c: bool) {\n    let g = self.inner.lock().unwrap();\n    if c { drop(g); }\n    self.forward_direct();\n  }\n}\n";
        let lexed = lex(src);
        let parsed = parse_file("rollout/mod.rs", &lexed);
        let (open, close) = parsed.bodies[0];
        let c = cfg::build(&lexed.toks, open, close);
        let gs = guards(&parsed.fns[0], &lexed.toks, open, close);
        assert_eq!(gs.len(), 1);
        let held = held_may_calls(&lexed.toks, &c, &gs);
        assert!(
            held.iter().any(|h| h.name == "forward_direct"),
            "guard may be live across forward_direct: {held:?}"
        );
        // …and the linear scan (drop on the taken path) agrees the
        // *unconditional* drop case is clean:
        let clean = "impl P {\n  fn f(&self) {\n    let g = self.inner.lock().unwrap();\n    drop(g);\n    self.forward_direct();\n  }\n}\n";
        let lexed2 = lex(clean);
        let parsed2 = parse_file("rollout/mod.rs", &lexed2);
        let (o2, c2) = parsed2.bodies[0];
        let cfg2 = cfg::build(&lexed2.toks, o2, c2);
        let gs2 = guards(&parsed2.fns[0], &lexed2.toks, o2, c2);
        let held2 = held_may_calls(&lexed2.toks, &cfg2, &gs2);
        assert!(held2.iter().all(|h| h.name != "forward_direct"), "{held2:?}");
    }

    #[test]
    fn scope_end_bounds_the_guard() {
        // Guard lives in an inner block; the call after the block is
        // outside its lexical scope even though the may-state leaks.
        let src = "impl P {\n  fn f(&self) {\n    {\n      let g = self.inner.lock().unwrap();\n      self.bump();\n    }\n    self.forward_direct();\n  }\n}\n";
        let lexed = lex(src);
        let parsed = parse_file("rollout/mod.rs", &lexed);
        let (open, close) = parsed.bodies[0];
        let c = cfg::build(&lexed.toks, open, close);
        let gs = guards(&parsed.fns[0], &lexed.toks, open, close);
        let held = held_may_calls(&lexed.toks, &c, &gs);
        assert!(held.iter().any(|h| h.name == "bump"));
        assert!(held.iter().all(|h| h.name != "forward_direct"), "{held:?}");
    }

    #[test]
    fn rng_lineage_flags_sequential_but_not_branch_exclusive() {
        let seq = "fn f(seed: u64) {\n  let a = Pcg64::new(seed, 1);\n  let b = Pcg64::new(seed, 1);\n  use_both(a, b);\n}\n";
        let (f, toks, c, o, cl) = front(seq);
        let v = rng_lineage(&f, &toks, &c, o, cl);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);

        let branchy = "fn f(seed: u64, fast: bool) {\n  let r = if fast {\n    Pcg64::new(seed, 1)\n  } else {\n    Pcg64::new(seed, 1)\n  };\n  consume(r);\n}\n";
        let (f, toks, c, o, cl) = front(branchy);
        let v = rng_lineage(&f, &toks, &c, o, cl);
        assert!(v.is_empty(), "branch-exclusive duplicates are clean: {v:?}");

        let distinct = "fn f(seed: u64) {\n  let a = Pcg64::new(seed, 1);\n  let b = Pcg64::new(seed, 2);\n  use_both(a, b);\n}\n";
        let (f, toks, c, o, cl) = front(distinct);
        assert!(rng_lineage(&f, &toks, &c, o, cl).is_empty());
    }

    #[test]
    fn rng_clone_fork_is_flagged() {
        let src = "fn f(seed: u64) {\n  let rng = Pcg64::new(seed, 0);\n  let twin = rng.clone();\n  use_both(rng, twin);\n}\n";
        let (f, toks, c, o, cl) = front(src);
        let v = rng_lineage(&f, &toks, &c, o, cl);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn flush_on_error_catches_the_bare_question_mark() {
        // PR 7's shape: the `?` propagates mid-pack, the flush after the
        // loop never runs.
        let src = "fn run(units: &mut [U]) -> Result<(), E> {\n  for u in units {\n    u.step_cycle()?;\n  }\n  flush_sinks();\n  Ok(())\n}\n";
        let (f, toks, c, _, _) = front(src);
        let v = flush_on_error(&f, &toks, &c);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
        assert!(v[0].message.contains("line 3"));
    }

    #[test]
    fn flush_on_error_accepts_the_catch_flush_rethrow_shape() {
        let src = "fn run(units: &mut [U]) -> Result<(), E> {\n  for u in units {\n    match u.step_cycle() {\n      Ok(done) => { if done { break; } }\n      Err(e) => {\n        flush_sinks();\n        return Err(e);\n      }\n    }\n  }\n  flush_sinks();\n  Ok(())\n}\n";
        let (f, toks, c, _, _) = front(src);
        let v = flush_on_error(&f, &toks, &c);
        assert!(v.is_empty(), "flush-before-rethrow is the sanctioned shape: {v:?}");
    }

    #[test]
    fn flush_on_error_ignores_unwrap_drivers() {
        // `.unwrap()` panics instead of propagating — benches drive
        // cycles that way and must stay clean.
        let src = "fn bench(u: &mut U) {\n  for _ in 0..8 {\n    u.step_cycle().unwrap();\n  }\n}\n";
        let (f, toks, c, _, _) = front(src);
        assert!(flush_on_error(&f, &toks, &c).is_empty());
    }
}
