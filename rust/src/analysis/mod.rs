//! `ued-lint`: the repo's in-tree static-analysis pass.
//!
//! The library's headline guarantee — rollouts, evals, and seed packs
//! that are **bit-identical** across thread counts — is structural: it
//! holds because the hot path only uses per-column RNG streams, ordered
//! containers, and column-disjoint writes. This module makes those
//! invariants mechanically checkable at CI time instead of relying on a
//! long determinism sweep to diverge. It is dependency-free (a small
//! hand-rolled lexer in [`lexer`]) and is driven by the `ued_lint`
//! binary (`cargo run --bin ued_lint`) plus the `lint_self` test, which
//! lints the crate's own source.
//!
//! # Rules
//!
//! Determinism rules (enforced in the deterministic modules `rollout`,
//! `algo`, `level_sampler`, `ppo`, `env`):
//!
//! * `hash-collections` — importing `HashMap`/`HashSet` (or naming them
//!   via `collections::`). Hasher iteration order is seeded per process,
//!   so any iteration leaks schedule-dependent order into results; the
//!   lexical pass cannot prove a map is never iterated, so the rule
//!   conservatively bans the types and the escape hatch documents
//!   lookup-only uses.
//! * `thread-rng` — ambient RNGs (`thread_rng`, `ThreadRng`, `OsRng`,
//!   `from_entropy`, `rand::random`): all randomness must flow from the
//!   seeded per-column `Pcg64` streams.
//! * `addr-hash` — casting a pointer/reference address to an integer
//!   (`as *const _ as usize`, `.as_ptr() … as usize`): addresses vary
//!   per run, so address-derived values are nondeterministic.
//!
//! Service modules (`serve`) get a scoped profile: they are *not*
//! deterministic modules (a server's wallclock use — timeouts, latency
//! metrics — is legitimate, so the `wallclock` rule is exempt there),
//! but `hash-collections` still applies: the batcher orders batch
//! columns, and hasher-ordered iteration there would make which request
//! lands in which column schedule-dependent. Request ordering must stay
//! FIFO-deterministic, so serve code uses `Vec`/`BTreeMap` only.
//!
//! Crate-wide rules:
//!
//! * `wallclock` — `Instant::now` / `SystemTime::now`. Real time must
//!   never feed results; the one sanctioned reader is the metrics
//!   stopwatch (wallclock CSV column), which carries an allow. Service
//!   modules are exempt (see above).
//! * `safety-comment` — every `unsafe` token (block, fn, or
//!   `unsafe impl`) must carry a `SAFETY`-bearing comment: on the same
//!   line, in the contiguous comment/attribute block directly above
//!   (doc sections titled `# Safety` count), or on the first line
//!   inside the block.
//! * `unsafe-op-lint` — `lib.rs` must deny `unsafe_op_in_unsafe_fn`
//!   crate-wide, so every unsafe operation sits in an explicit (and
//!   therefore SAFETY-commented) `unsafe` block even inside unsafe fns.
//!
//! # Semantic rules
//!
//! On top of the per-file rules, the pass builds an item-level AST
//! ([`parser`]), a module-aware symbol table and intra-crate call graph
//! ([`callgraph`]), and runs three interprocedural analyses ([`taint`]):
//!
//! * `det-taint` — a nondeterminism source (wallclock, ambient RNG,
//!   hash-ordered collections) in *any* fn transitively reachable from
//!   the deterministic module trees. This is the cross-module closure
//!   of the per-file rules: a helper in `util/` that reads the clock is
//!   invisible to the per-file pass but still taints every rollout that
//!   calls it.
//! * `serve-panic` — `unwrap`/`expect`/`panic!`-family macros,
//!   unchecked arithmetic, and slice indexing reachable from the serve
//!   router handlers or the batcher drain loop. Fns returning `Result`
//!   are exempt from the indexing heuristic (they have an error path);
//!   unwraps there stay flagged.
//! * `lock-order` — per-function lock acquisition orders, propagated
//!   through the call graph (calls made under a held guard inherit the
//!   callee's transitive lockset); any cycle in the resulting order
//!   graph is a potential deadlock.
//!
//! # Flow-sensitive rules
//!
//! The v3 engine adds a per-function control-flow graph ([`cfg`]) and a
//! generic worklist dataflow solver ([`dataflow`]); the analyses on top
//! live in [`flow`] (plus the interprocedural half of
//! `lock-across-forward` in [`taint`]). All three are path-insensitive
//! over-approximations: they may flag a path the program never takes
//! (silence with a reasoned allow), never the reverse.
//!
//! * `rng-lineage` — two RNG streams (`Pcg64`/`ColumnRngs`/
//!   `adhoc_episode_rng`) constructed from the same (seed, index) key on
//!   one path, or a stream forked with `.clone()`: aliased streams
//!   replay the same sequence. Branch-exclusive duplicates are clean —
//!   that is the flow-sensitivity payoff.
//! * `flush-on-error` — a backward analysis at every `step_cycle` call
//!   site proving no error path propagates out before
//!   `flush_sinks`/`flush` runs (PR 7's mid-pack data-loss bug as a
//!   lint).
//! * `lock-across-forward` — a guard that *may* still be held (per the
//!   CFG may-held analysis) across a blocking device call
//!   (`forward_direct`/`forward_into`) or serve-side socket write,
//!   directly or through the call graph.
//!
//! The per-file front-end (lex + parse + scan + per-function flow) is
//! cached keyed by mtime + content hash ([`cache`]); reports can render
//! as SARIF 2.1.0 ([`sarif`]) for code-scanning upload.
//!
//! # Escape hatch
//!
//! A violation is suppressed by a directive comment of the exact form
//! (the reason is mandatory): `ued-lint: allow(<rule>[, <rule>…]) —
//! <reason>` written after the usual comment marker. It covers its own
//! line(s) and the line directly below — and when that next line starts
//! an item (its attribute run included), the whole item. A malformed
//! directive — unknown rule, missing reason — is itself reported
//! (`bad-allow`) and suppresses nothing.

pub mod callgraph;
pub mod cache;
pub mod cfg;
pub mod dataflow;
pub mod flow;
pub mod lexer;
pub mod parser;
pub mod sarif;
pub mod taint;

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use lexer::{Comment, Lexed, Tok, TokKind};
use parser::FnInfo;

/// Top-level source modules whose results must be bit-reproducible.
pub const DETERMINISTIC_MODULES: [&str; 5] = ["rollout", "algo", "level_sampler", "ppo", "env"];

/// Top-level source modules that are long-running services: wallclock use
/// is legitimate there (timeouts, latency metrics), but batch-column
/// ordering must stay FIFO-deterministic, so `hash-collections` still
/// applies.
pub const SERVICE_MODULES: [&str; 1] = ["serve"];

/// Every rule `ued-lint` enforces.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    HashCollections,
    ThreadRng,
    Wallclock,
    AddrHash,
    SafetyComment,
    UnsafeOpLint,
    /// Semantic: nondeterminism source reachable from deterministic code.
    DetTaint,
    /// Semantic: panic site reachable on the serving path.
    ServePanic,
    /// Semantic: inconsistent lock acquisition order through the graph.
    LockOrder,
    /// Flow: two RNG streams from one (seed, index) key on one path.
    RngLineage,
    /// Flow: an error path can propagate before sinks are flushed.
    FlushOnError,
    /// Flow: a guard may be held across a blocking device/socket call.
    LockAcrossForward,
    /// A malformed `ued-lint: allow(...)` directive (not allowable).
    BadAllow,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::HashCollections => "hash-collections",
            Rule::ThreadRng => "thread-rng",
            Rule::Wallclock => "wallclock",
            Rule::AddrHash => "addr-hash",
            Rule::SafetyComment => "safety-comment",
            Rule::UnsafeOpLint => "unsafe-op-lint",
            Rule::DetTaint => "det-taint",
            Rule::ServePanic => "serve-panic",
            Rule::LockOrder => "lock-order",
            Rule::RngLineage => "rng-lineage",
            Rule::FlushOnError => "flush-on-error",
            Rule::LockAcrossForward => "lock-across-forward",
            Rule::BadAllow => "bad-allow",
        }
    }

    pub fn from_name(name: &str) -> Option<Rule> {
        match name {
            "hash-collections" => Some(Rule::HashCollections),
            "thread-rng" => Some(Rule::ThreadRng),
            "wallclock" => Some(Rule::Wallclock),
            "addr-hash" => Some(Rule::AddrHash),
            "safety-comment" => Some(Rule::SafetyComment),
            "unsafe-op-lint" => Some(Rule::UnsafeOpLint),
            "det-taint" => Some(Rule::DetTaint),
            "serve-panic" => Some(Rule::ServePanic),
            "lock-order" => Some(Rule::LockOrder),
            "rng-lineage" => Some(Rule::RngLineage),
            "flush-on-error" => Some(Rule::FlushOnError),
            "lock-across-forward" => Some(Rule::LockAcrossForward),
            _ => None,
        }
    }

    /// Like [`Rule::from_name`] but also maps `bad-allow` — cache
    /// deserialization must round-trip every reportable rule, while
    /// directives must keep rejecting `allow(bad-allow)`.
    pub(crate) fn from_name_any(name: &str) -> Option<Rule> {
        if name == "bad-allow" {
            Some(Rule::BadAllow)
        } else {
            Rule::from_name(name)
        }
    }

    /// The rules an allow directive may name (everything but `bad-allow`).
    pub fn allowable() -> &'static [Rule] {
        &[
            Rule::HashCollections,
            Rule::ThreadRng,
            Rule::Wallclock,
            Rule::AddrHash,
            Rule::SafetyComment,
            Rule::UnsafeOpLint,
            Rule::DetTaint,
            Rule::ServePanic,
            Rule::LockOrder,
            Rule::RngLineage,
            Rule::FlushOnError,
            Rule::LockAcrossForward,
        ]
    }

    /// One-paragraph rationale + over-approximation note, for the
    /// binary's `--explain <rule>` flag.
    pub fn explain(self) -> &'static str {
        match self {
            Rule::HashCollections => {
                "hash-collections: HashMap/HashSet iteration order is seeded per process, \
                 so iterating one leaks schedule-dependent order into results. Banned in \
                 deterministic and order-sensitive modules; allow with a lookup-only \
                 justification."
            }
            Rule::ThreadRng => {
                "thread-rng: ambient RNGs (thread_rng, OsRng, from_entropy, rand::random) \
                 draw from process-global state. All randomness in deterministic modules \
                 must flow from the seeded per-column Pcg64 streams."
            }
            Rule::Wallclock => {
                "wallclock: Instant::now/SystemTime::now must never feed results; the one \
                 sanctioned reader is the metrics stopwatch. Service modules and benches \
                 are exempt by profile."
            }
            Rule::AddrHash => {
                "addr-hash: a pointer address cast to an integer varies per run, so \
                 address-derived values (hashes, keys, seeds) are nondeterministic."
            }
            Rule::SafetyComment => {
                "safety-comment: every `unsafe` token needs a SAFETY comment documenting \
                 the proof obligation — same line, the comment block above, or the first \
                 line inside the block."
            }
            Rule::UnsafeOpLint => {
                "unsafe-op-lint: the crate root must deny unsafe_op_in_unsafe_fn so every \
                 unsafe operation sits in an explicit, SAFETY-commented block."
            }
            Rule::DetTaint => {
                "det-taint: a nondeterminism source in any fn transitively reachable from \
                 the deterministic module trees, found via the call graph. \
                 Over-approximate: same-name bare method calls conflate, so a witness \
                 path may not be a real path."
            }
            Rule::ServePanic => {
                "serve-panic: unwrap/expect/panic!/unchecked arithmetic/indexing reachable \
                 from the serve router or batcher roots — the serving path must not panic \
                 on untrusted input."
            }
            Rule::LockOrder => {
                "lock-order: per-function lock acquisition orders propagated through the \
                 call graph; a cycle in the order graph is a potential deadlock. Lock \
                 classes are receiver-field names, which fragments (never merges) classes."
            }
            Rule::RngLineage => {
                "rng-lineage: two RNG streams (Pcg64/ColumnRngs/adhoc_episode_rng) \
                 constructed from the same textual (seed, index) key on one CFG path, or \
                 an RNG binding forked with .clone() — aliased streams replay the same \
                 sequence. Path-insensitive over-approximation: closures are walked \
                 inline, so a duplicate key in a never-taken path still reports; \
                 branch-exclusive duplicates (if/else, match arms) are clean."
            }
            Rule::FlushOnError => {
                "flush-on-error: a backward dataflow proof, at every step_cycle call \
                 site, that no error path (?, return Err, bail!) can propagate out \
                 before flush_sinks/flush runs — otherwise metrics rows buffered by the \
                 interrupted cycle are silently lost. Path-insensitive: an error exit on \
                 a path the driver never takes still reports."
            }
            Rule::LockAcrossForward => {
                "lock-across-forward: a FifoLock/pool-phase guard that MAY still be held \
                 (per the CFG may-held analysis) across a blocking device call \
                 (forward_direct/forward_into) or serve-side socket write, directly or \
                 through the call graph — one stalled forward under the guard stalls \
                 every queued waiter. May-analysis: a guard dropped on every real path \
                 but not provably so still reports."
            }
            Rule::BadAllow => {
                "bad-allow: a malformed ued-lint allow directive (unknown rule or missing \
                 reason) — reported, never suppressible."
            }
        }
    }
}

/// One reported lint violation.
#[derive(Clone, Debug)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule.name(), self.message)
    }
}

/// Per-file lint configuration. `Default` is the plain crate-wide profile
/// (no determinism rules, wallclock checked); construct scoped profiles
/// with struct-update syntax so future fields don't break call sites.
#[derive(Clone, Copy, Debug, Default)]
pub struct LintConfig {
    /// Apply the determinism rules (`hash-collections`, `thread-rng`,
    /// `addr-hash`) in addition to the crate-wide ones.
    pub deterministic: bool,
    /// Apply `hash-collections` on its own (service modules: batch
    /// ordering must be FIFO-deterministic even though the module as a
    /// whole is not). Implied by `deterministic`.
    pub ordered_collections: bool,
    /// Skip the `wallclock` rule (service modules: timeouts and latency
    /// metrics legitimately read real time).
    pub wallclock_exempt: bool,
    /// Require a `deny(unsafe_op_in_unsafe_fn)` attribute in this file
    /// (set for the crate root).
    pub expect_unsafe_op_deny: bool,
    /// Run the `rng-lineage` flow analysis (deterministic + service +
    /// eval modules; benches deliberately replay streams, so it is off
    /// in the bench profile).
    pub rng_lineage: bool,
}

/// Result of linting a whole source tree.
#[derive(Debug, Default)]
pub struct CrateReport {
    /// Number of `.rs` files visited.
    pub files: usize,
    /// Files whose per-file front-end came from the incremental cache.
    pub cache_hits: usize,
    /// All violations, ordered by (file, line, rule).
    pub violations: Vec<Violation>,
}

/// A parsed, well-formed allow directive for one rule. A comma list in
/// the source (`allow(a, b)`) becomes one `Allow` per rule. `line_end`
/// is extended to the item's last line when the directive sits directly
/// above an item.
#[derive(Clone, Debug)]
pub struct Allow {
    pub rule: Rule,
    pub line: usize,
    pub line_end: usize,
}

/// The cached per-file front-end result: per-file violations (already
/// allow-filtered), parsed function summaries, and the (item-extended)
/// allow table the semantic analyses consult.
#[derive(Debug, Default)]
pub struct FileRecord {
    pub violations: Vec<Violation>,
    pub fns: Vec<FnInfo>,
    pub allows: Vec<Allow>,
}

enum Directive {
    /// The comment is not a `ued-lint:` directive at all.
    None,
    Valid(Vec<Rule>),
    Malformed(String),
}

/// Parse a comment for an allow directive. Only comments whose content
/// *begins* with `ued-lint:` count, so prose that merely mentions the
/// syntax (like this module's docs) is never misread as a directive.
fn parse_directive(comment: &str) -> Directive {
    let body = comment.trim_start_matches(['/', '*', '!']).trim_start();
    let rest = match body.strip_prefix("ued-lint:") {
        Some(r) => r.trim_start(),
        None => return Directive::None,
    };
    let inner = match rest.strip_prefix("allow(") {
        Some(r) => r,
        None => {
            return Directive::Malformed(String::from(
                "unknown ued-lint directive — only `allow(<rule>) — <reason>` exists",
            ))
        }
    };
    let close = match inner.find(')') {
        Some(p) => p,
        None => return Directive::Malformed(String::from("unclosed `allow(` directive")),
    };
    // One or more comma-separated rule names; every one must be known.
    let mut rules: Vec<Rule> = Vec::new();
    for rule_name in inner[..close].split(',') {
        let rule_name = rule_name.trim();
        if rule_name.is_empty() {
            continue;
        }
        match Rule::from_name(rule_name) {
            Some(r) => rules.push(r),
            None => {
                let known: Vec<&str> = Rule::allowable().iter().map(|r| r.name()).collect();
                return Directive::Malformed(format!(
                    "allow names unknown rule `{rule_name}` (known: {})",
                    known.join(", ")
                ));
            }
        }
    }
    if rules.is_empty() {
        return Directive::Malformed(String::from("allow() names no rule"));
    }
    // The reason is mandatory: a dash separator followed by prose.
    let after = inner[close + 1..].trim_start();
    let reason = after
        .strip_prefix('\u{2014}') // em dash
        .or_else(|| after.strip_prefix("--"))
        .or_else(|| after.strip_prefix('-'));
    let reason_ok = match reason {
        Some(r) => !r.trim_start_matches(['-', '\u{2014}']).trim().trim_end_matches("*/").trim().is_empty(),
        None => false,
    };
    if !reason_ok {
        let names: Vec<&str> = rules.iter().map(|r| r.name()).collect();
        let names = names.join(", ");
        return Directive::Malformed(format!(
            "allow({names}) has no reason — write `ued-lint: allow({names}) — <why this is sound>`"
        ));
    }
    Directive::Valid(rules)
}

fn ident_is(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn punct_is(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

/// `toks[i]` begins the path segment pair `<toks[i]> :: <name>` for one
/// of `names`; returns the line of the trailing segment.
fn path_to(toks: &[Tok], i: usize, names: &[&str]) -> Option<(usize, String)> {
    if i + 3 < toks.len()
        && punct_is(&toks[i + 1], ":")
        && punct_is(&toks[i + 2], ":")
        && toks[i + 3].kind == TokKind::Ident
        && names.contains(&toks[i + 3].text.as_str())
    {
        Some((toks[i + 3].line, toks[i + 3].text.clone()))
    } else {
        None
    }
}

fn push(out: &mut Vec<Violation>, file: &str, line: usize, rule: Rule, message: String) {
    out.push(Violation { file: file.to_string(), line, rule, message });
}

/// Token-stream rules: hash collections, ambient RNG, wallclock reads,
/// address-as-hash.
fn scan_tokens(file: &str, toks: &[Tok], cfg: &LintConfig, out: &mut Vec<Violation>) {
    let n = toks.len();
    // `addr-hash` state: a raw-pointer origin (`as *const/mut` cast or an
    // `as_ptr`/`as_mut_ptr` call) is live until the statement-ish
    // boundary tokens `;`, `,`, `{`, `}` reset it.
    let mut ptr_origin_live = false;
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            if matches!(t.text.as_str(), ";" | "," | "{" | "}") {
                ptr_origin_live = false;
            }
            i += 1;
            continue;
        }
        if t.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let s = t.text.as_str();

        // wallclock — crate-wide, except service modules.
        if !cfg.wallclock_exempt
            && (s == "Instant" || s == "SystemTime")
            && path_to(toks, i, &["now"]).is_some()
        {
            push(
                out,
                file,
                t.line,
                Rule::Wallclock,
                format!(
                    "`{s}::now()` — wallclock reads are nondeterministic; route timing \
                     through `metrics::Stopwatch` (the one allowed reader)"
                ),
            );
        }

        // hash-collections — deterministic modules (results must not
        // depend on hasher order) and service modules (batch-column /
        // request ordering must stay FIFO-deterministic).
        if cfg.deterministic || cfg.ordered_collections {
            let scope = if cfg.deterministic { "deterministic" } else { "order-sensitive" };
            // imports …
            if s == "use" {
                let mut j = i + 1;
                while j < n && !punct_is(&toks[j], ";") {
                    if toks[j].kind == TokKind::Ident
                        && (toks[j].text == "HashMap" || toks[j].text == "HashSet")
                    {
                        push(
                            out,
                            file,
                            toks[j].line,
                            Rule::HashCollections,
                            format!(
                                "`{}` imported in a {scope} module — hasher iteration \
                                 order is per-process; use BTreeMap/BTreeSet, or allow with \
                                 a lookup-only justification",
                                toks[j].text
                            ),
                        );
                    }
                    j += 1;
                }
                i = j;
                continue;
            }
            // … and fully-qualified paths outside a `use`.
            if s == "collections" {
                if let Some((line, name)) = path_to(toks, i, &["HashMap", "HashSet"]) {
                    push(
                        out,
                        file,
                        line,
                        Rule::HashCollections,
                        format!("`collections::{name}` named in a {scope} module"),
                    );
                }
            }
        }

        if cfg.deterministic {
            // thread-rng.
            if matches!(s, "thread_rng" | "ThreadRng" | "OsRng" | "from_entropy") {
                push(
                    out,
                    file,
                    t.line,
                    Rule::ThreadRng,
                    format!(
                        "`{s}` — ambient RNG in a deterministic module; draw from the \
                         seeded per-column Pcg64 streams instead"
                    ),
                );
            }
            if s == "rand" && path_to(toks, i, &["random"]).is_some() {
                push(
                    out,
                    file,
                    t.line,
                    Rule::ThreadRng,
                    String::from("`rand::random` — ambient RNG in a deterministic module"),
                );
            }

            // addr-hash.
            if matches!(s, "as_ptr" | "as_mut_ptr") {
                ptr_origin_live = true;
            }
            if s == "as" && i + 2 < n && punct_is(&toks[i + 1], "*") {
                let q = &toks[i + 2];
                if ident_is(q, "const") || ident_is(q, "mut") {
                    ptr_origin_live = true;
                }
            }
            if s == "as"
                && ptr_origin_live
                && i + 1 < n
                && toks[i + 1].kind == TokKind::Ident
                && matches!(toks[i + 1].text.as_str(), "usize" | "isize" | "u64" | "i64")
            {
                push(
                    out,
                    file,
                    t.line,
                    Rule::AddrHash,
                    String::from(
                        "pointer address cast to an integer — addresses vary per run, so \
                         address-derived values (hashes, keys, seeds) are nondeterministic",
                    ),
                );
                ptr_origin_live = false;
            }
        }
        i += 1;
    }
}

/// A comment overlapping `line` whose text carries a safety marker.
fn safety_comment_on(comments: &[Comment], line: usize) -> bool {
    comments.iter().any(|c| {
        c.line <= line
            && line <= c.line_end
            && (c.text.contains("SAFETY") || c.text.contains("# Safety"))
    })
}

/// The unsafety audit: every `unsafe` token needs SAFETY coverage.
fn scan_unsafe(file: &str, lexed: &Lexed, lines: &[&str], out: &mut Vec<Violation>) {
    let mut checked_lines: Vec<usize> = Vec::new();
    for t in &lexed.toks {
        if !ident_is(t, "unsafe") {
            continue;
        }
        if checked_lines.contains(&t.line) {
            continue;
        }
        checked_lines.push(t.line);
        if unsafe_is_covered(&lexed.comments, lines, t.line) {
            continue;
        }
        push(
            out,
            file,
            t.line,
            Rule::SafetyComment,
            String::from(
                "`unsafe` without a SAFETY comment — document the proof obligation on \
                 this line, in the comment block directly above, or on the first line \
                 inside the block (`// SAFETY: …`, or a `# Safety` doc section)",
            ),
        );
    }
}

fn unsafe_is_covered(comments: &[Comment], lines: &[&str], line: usize) -> bool {
    // Same line.
    if safety_comment_on(comments, line) {
        return true;
    }
    // First line inside the block (`|i| unsafe {` followed by the comment).
    if line < lines.len() {
        let below = lines[line].trim_start(); // 0-indexed: this is line+1
        if below.starts_with("//") && safety_comment_on(comments, line + 1) {
            return true;
        }
    }
    // The contiguous comment/attribute block directly above (doc comments
    // and attributes like `#[allow(...)]` extend the block upward).
    let mut k = line;
    while k > 1 {
        k -= 1;
        let above = lines[k - 1].trim_start();
        if above.starts_with("//") {
            if safety_comment_on(comments, k) {
                return true;
            }
        } else if above.starts_with('#') {
            // attribute — keep scanning upward
        } else {
            break;
        }
    }
    false
}

/// Crate-root check: `unsafe_op_in_unsafe_fn` must be denied.
fn check_unsafe_op_deny(file: &str, toks: &[Tok], out: &mut Vec<Violation>) {
    for (i, t) in toks.iter().enumerate() {
        if ident_is(t, "unsafe_op_in_unsafe_fn") {
            let lo = i.saturating_sub(4);
            if toks[lo..i].iter().any(|p| ident_is(p, "deny")) {
                return;
            }
        }
    }
    push(
        out,
        file,
        1,
        Rule::UnsafeOpLint,
        String::from(
            "crate root must carry `#![deny(unsafe_op_in_unsafe_fn)]` so unsafe \
             operations need explicit (SAFETY-commented) blocks even in unsafe fns",
        ),
    );
}

/// The per-file front-end: lex, parse directives and items, run the
/// per-file rules, and filter through the (item-extended) allow table.
/// This is the unit the incremental cache stores.
pub fn analyze_file(file: &str, src: &str, cfg: &LintConfig) -> FileRecord {
    let lexed = lexer::lex(src);
    let lines: Vec<&str> = src.lines().collect();

    let mut raw: Vec<Violation> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    for c in &lexed.comments {
        match parse_directive(&c.text) {
            Directive::None => {}
            Directive::Valid(rules) => {
                for rule in rules {
                    allows.push(Allow { rule, line: c.line, line_end: c.line_end });
                }
            }
            Directive::Malformed(msg) => push(&mut raw, file, c.line, Rule::BadAllow, msg),
        }
    }

    let mut parsed = parser::parse_file(file, &lexed);
    // Item extension: an allow ending on the line directly above an
    // item's attribute run covers the whole item.
    for a in &mut allows {
        for it in &parsed.items {
            if a.line_end + 1 == it.attr_line {
                a.line_end = a.line_end.max(it.end_line);
            }
        }
    }

    // The flow-sensitive per-function pass: build each fn's CFG, compute
    // the may-held call summary (consumed interprocedurally by
    // `lock-across-forward`), and run the per-function analyses. Their
    // findings join `raw` here so they are cached and allow-filtered
    // exactly like the lexical rules.
    for (k, f) in parsed.fns.iter_mut().enumerate() {
        let (body_open, body_close) = parsed.bodies[k];
        let g = cfg::build(&lexed.toks, body_open, body_close);
        let guards = flow::guards(f, &lexed.toks, body_open, body_close);
        f.held_may_calls = flow::held_may_calls(&lexed.toks, &g, &guards);
        raw.extend(flow::flush_on_error(f, &lexed.toks, &g));
        if cfg.rng_lineage {
            raw.extend(flow::rng_lineage(f, &lexed.toks, &g, body_open, body_close));
        }
    }

    scan_tokens(file, &lexed.toks, cfg, &mut raw);
    scan_unsafe(file, &lexed, &lines, &mut raw);
    if cfg.expect_unsafe_op_deny {
        check_unsafe_op_deny(file, &lexed.toks, &mut raw);
    }

    raw.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    raw.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);
    // An allow suppresses matching violations on its own line(s) and the
    // line directly below (or through the covered item). `bad-allow`
    // itself is never suppressible.
    raw.retain(|v| {
        v.rule == Rule::BadAllow
            || !allows
                .iter()
                .any(|a| a.rule == v.rule && v.line >= a.line && v.line <= a.line_end + 1)
    });
    FileRecord { violations: raw, fns: parsed.fns, allows }
}

/// Lint one source file with the per-file rules. `file` is a display
/// label only. (The semantic rules need the whole tree — see
/// [`lint_crate`].)
pub fn lint_source(file: &str, src: &str, cfg: &LintConfig) -> Vec<Violation> {
    analyze_file(file, src, cfg).violations
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path.strip_prefix(root).unwrap_or(&path).to_path_buf();
            out.push(rel);
        }
    }
    Ok(())
}

fn first_component(rel: &Path) -> Option<String> {
    let first = rel.components().next()?.as_os_str().to_string_lossy().into_owned();
    Some(first.strip_suffix(".rs").unwrap_or(&first).to_string())
}

/// Whether a path (relative to `src/`) belongs to a deterministic module.
pub fn is_deterministic_module(rel: &Path) -> bool {
    first_component(rel).is_some_and(|n| DETERMINISTIC_MODULES.contains(&n.as_str()))
}

/// Whether a path (relative to `src/`) belongs to a service module.
pub fn is_service_module(rel: &Path) -> bool {
    first_component(rel).is_some_and(|n| SERVICE_MODULES.contains(&n.as_str()))
}

/// The lint profile for a file at `rel` (relative to `src/`): deterministic
/// modules get the full determinism rule set, service modules keep
/// `hash-collections` but drop `wallclock`, and the crate root must deny
/// `unsafe_op_in_unsafe_fn`.
pub fn config_for(rel: &Path) -> LintConfig {
    let service = is_service_module(rel);
    let det = is_deterministic_module(rel);
    LintConfig {
        deterministic: det,
        ordered_collections: service,
        wallclock_exempt: service,
        expect_unsafe_op_deny: rel.as_os_str() == "lib.rs",
        // Stream-lineage hygiene applies wherever streams are minted:
        // the deterministic trees, the serve path (its eval replays),
        // and the content-keyed episode RNG in `eval/`.
        rng_lineage: det || service || first_component(rel).as_deref() == Some("eval"),
    }
}

/// Which source tree is being linted — selects the per-file profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TreeKind {
    /// The crate's `src/`: full module-scoped profiles.
    Src,
    /// `benches/`: wallclock reads are the whole point of a benchmark
    /// and deliberate stream replay is a bench technique, so the
    /// `wallclock` and `rng-lineage` rules are off; everything else
    /// (including `flush-on-error` and `lock-across-forward`) applies.
    Bench,
    /// `examples/`: the plain crate-wide profile.
    Example,
}

/// The lint profile for a file at `rel` within a tree of `kind`.
pub fn config_for_tree(kind: TreeKind, rel: &Path) -> LintConfig {
    match kind {
        TreeKind::Src => config_for(rel),
        TreeKind::Bench => LintConfig { wallclock_exempt: true, ..LintConfig::default() },
        TreeKind::Example => LintConfig::default(),
    }
}

/// Options for [`lint_tree_with`] / [`lint_crate_with`].
#[derive(Clone, Debug)]
pub struct LintOptions {
    /// Run the cross-file interprocedural analyses (`det-taint`,
    /// `serve-panic`, `lock-order`, `lock-across-forward`) on top of
    /// the per-file rules. The per-function flow rules (`rng-lineage`,
    /// `flush-on-error`) are part of the per-file front-end and run
    /// regardless.
    pub semantic: bool,
    /// Persist/reuse the per-file front-end via this cache file.
    pub cache_path: Option<PathBuf>,
}

impl Default for LintOptions {
    fn default() -> LintOptions {
        LintOptions { semantic: true, cache_path: None }
    }
}

/// Lint every `.rs` file under `root` with the profile family of
/// `kind`. Files are visited in sorted order and the final report is
/// re-sorted by (file, line, rule), so the report itself is
/// deterministic. Each tree is linted independently — its own call
/// graph and its own cache file.
pub fn lint_tree_with(root: &Path, kind: TreeKind, opts: &LintOptions) -> io::Result<CrateReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs_files(root, root, &mut files)?;
    files.sort();

    let mut store = match &opts.cache_path {
        Some(p) => cache::Cache::load(p),
        None => cache::Cache::default(),
    };
    let mut cache_hits = 0usize;

    let mut violations: Vec<Violation> = Vec::new();
    let mut all_fns: Vec<FnInfo> = Vec::new();
    let mut allows_by_file: BTreeMap<String, Vec<Allow>> = BTreeMap::new();
    for rel in &files {
        let path = root.join(rel);
        let src = fs::read_to_string(&path)?;
        // `/`-separated even on Windows so reports and caches are portable.
        let rel_str = rel
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let mtime = cache::mtime_ns(&path);
        let hash = format!("{:016x}", cache::fnv1a(src.as_bytes()));
        let record = match store.get(&rel_str, &mtime, &hash) {
            Some(mut rec) => {
                cache_hits += 1;
                for v in &mut rec.violations {
                    v.file = rel_str.clone();
                }
                rec
            }
            None => {
                let rec = analyze_file(&rel_str, &src, &config_for_tree(kind, rel));
                store.put(&rel_str, &mtime, &hash, &rec);
                rec
            }
        };
        violations.extend(record.violations);
        all_fns.extend(record.fns);
        allows_by_file.insert(rel_str, record.allows);
    }

    if opts.semantic {
        let graph = callgraph::CallGraph::build(&all_fns);
        violations.extend(taint::analyze(&all_fns, &graph, &allows_by_file));
    }

    violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    if let Some(p) = &opts.cache_path {
        store.save(p);
    }
    Ok(CrateReport { files: files.len(), cache_hits, violations })
}

/// [`lint_tree_with`] over a `src/` tree — the historical entry point;
/// fixture corpora and the self-lint go through here.
pub fn lint_crate_with(src_root: &Path, opts: &LintOptions) -> io::Result<CrateReport> {
    lint_tree_with(src_root, TreeKind::Src, opts)
}

/// [`lint_crate_with`] with the default options: semantic analyses on,
/// no cache.
pub fn lint_crate(src_root: &Path) -> io::Result<CrateReport> {
    lint_crate_with(src_root, &LintOptions::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det() -> LintConfig {
        LintConfig { deterministic: true, ..LintConfig::default() }
    }

    fn service() -> LintConfig {
        LintConfig { ordered_collections: true, wallclock_exempt: true, ..LintConfig::default() }
    }

    fn rules_of(v: &[Violation]) -> Vec<Rule> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn directive_must_start_the_comment() {
        // prose mentioning the syntax is not a directive
        let lx = lexer::lex("// the syntax is `ued-lint: allow(x) — reason`\nlet a = 1;\n");
        assert_eq!(lx.comments.len(), 1);
        match parse_directive(&lx.comments[0].text) {
            Directive::None => {}
            _ => panic!("backtick-prefixed prose must not parse as a directive"),
        }
    }

    #[test]
    fn allow_requires_reason_and_known_rule() {
        match parse_directive("// ued-lint: allow(wallclock) — stopwatch is sanctioned") {
            Directive::Valid(rules) => assert_eq!(rules, [Rule::Wallclock]),
            _ => panic!("well-formed allow must parse"),
        }
        assert!(matches!(
            parse_directive("// ued-lint: allow(wallclock)"),
            Directive::Malformed(_)
        ));
        assert!(matches!(
            parse_directive("// ued-lint: allow(no-such-rule) — reason"),
            Directive::Malformed(_)
        ));
        // `bad-allow` is reportable but not allowable
        assert!(matches!(
            parse_directive("// ued-lint: allow(bad-allow) — nice try"),
            Directive::Malformed(_)
        ));
    }

    #[test]
    fn comma_separated_allow_names_each_rule() {
        match parse_directive("// ued-lint: allow(wallclock, det-taint) — sanctioned stopwatch") {
            Directive::Valid(rules) => assert_eq!(rules, [Rule::Wallclock, Rule::DetTaint]),
            _ => panic!("comma list must parse"),
        }
        // one unknown name poisons the whole directive
        assert!(matches!(
            parse_directive("// ued-lint: allow(wallclock, nope) — reason"),
            Directive::Malformed(_)
        ));
        assert!(matches!(parse_directive("// ued-lint: allow() — reason"), Directive::Malformed(_)));
    }

    #[test]
    fn item_allow_covers_the_whole_item_but_not_the_next() {
        // The allow sits directly above `fn f`, whose body reads the
        // clock three lines further down: without item extension the
        // violation would escape the directive's two-line window.
        let src = "\
// ued-lint: allow(wallclock) — benchmark helper, results unused
fn f() {
    let _pad = 1;
    let _t = Instant::now();
}

fn g() {
    let _t = Instant::now();
}
";
        let v = lint_source("x.rs", src, &LintConfig::default());
        // f's read is allowed; g's is not — the allow must not leak past
        // the item it annotates.
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, Rule::Wallclock);
        assert_eq!(v[0].line, 8);
    }

    #[test]
    fn item_allow_anchors_on_the_attribute_run() {
        let src = "\
// ued-lint: allow(wallclock) — timing shim for tests
#[inline]
pub fn f() {
    let _a = 0;
    let _t = Instant::now();
}
";
        assert!(lint_source("x.rs", src, &LintConfig::default()).is_empty());
    }

    #[test]
    fn hash_import_flagged_only_in_scoped_modules() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_of(&lint_source("x.rs", src, &det())), [Rule::HashCollections]);
        assert!(lint_source("x.rs", src, &LintConfig::default()).is_empty());
    }

    #[test]
    fn wallclock_is_crate_wide() {
        let src = "fn t() { let _ = Instant::now(); }\n";
        assert_eq!(
            rules_of(&lint_source("x.rs", src, &LintConfig::default())),
            [Rule::Wallclock]
        );
    }

    #[test]
    fn service_profile_exempts_wallclock_but_keeps_hash_collections() {
        // A service module legitimately reads wallclock (timeouts, latency
        // metrics) …
        let clock = "fn t() { let _ = Instant::now(); }\n";
        assert!(lint_source("serve/http.rs", clock, &service()).is_empty());
        // … but code that could order batch columns through a hasher is
        // still flagged: request ordering must stay FIFO-deterministic.
        let hash = "use std::collections::HashMap;\nfn t() { let _ = Instant::now(); }\n";
        assert_eq!(
            rules_of(&lint_source("serve/batcher.rs", hash, &service())),
            [Rule::HashCollections]
        );
    }

    #[test]
    fn addr_hash_needs_a_pointer_origin() {
        let flagged = "fn f(x: &u64) -> usize { &*x as *const u64 as usize }\n";
        assert_eq!(rules_of(&lint_source("x.rs", flagged, &det())), [Rule::AddrHash]);
        // a plain integer cast is not an address
        let clean = "fn g(n: u32) -> usize { n as usize }\n";
        assert!(lint_source("x.rs", clean, &det()).is_empty());
        // a pointer origin neutralized by a statement boundary is clean
        let reset = "fn h(v: &[u8]) -> usize { let _p = v.as_ptr(); v.len() as usize }\n";
        assert!(lint_source("x.rs", reset, &det()).is_empty());
    }

    #[test]
    fn safety_coverage_positions() {
        let same_line = "fn f(p: *const u8) -> u8 { unsafe { *p } } // SAFETY: caller checks\n";
        assert!(lint_source("x.rs", same_line, &det()).is_empty());
        let above = "// SAFETY: caller checks\nfn g(p: *const u8) -> u8 { unsafe { *p } }\n";
        // the comment block above belongs to the fn, and the unsafe sits
        // on the same line as the fn header here
        assert!(lint_source("x.rs", above, &det()).is_empty());
        let inside = "fn h(p: *const u8) -> u8 {\n    unsafe {\n        // SAFETY: caller checks\n        *p\n    }\n}\n";
        assert!(lint_source("x.rs", inside, &det()).is_empty());
        let uncovered = "fn k(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules_of(&lint_source("x.rs", uncovered, &det())), [Rule::SafetyComment]);
    }

    #[test]
    fn unsafe_in_strings_and_comments_is_ignored() {
        let src = "// unsafe in prose\nfn f() -> &'static str { \"unsafe { }\" }\n";
        assert!(lint_source("x.rs", src, &det()).is_empty());
    }

    #[test]
    fn unsafe_op_deny_detected() {
        let good = "#![deny(unsafe_op_in_unsafe_fn)]\nfn main() {}\n";
        let cfg = LintConfig { expect_unsafe_op_deny: true, ..LintConfig::default() };
        assert!(lint_source("lib.rs", good, &cfg).is_empty());
        let bad = "fn main() {}\n";
        assert_eq!(rules_of(&lint_source("lib.rs", bad, &cfg)), [Rule::UnsafeOpLint]);
    }

    #[test]
    fn module_classification() {
        assert!(is_deterministic_module(Path::new("rollout/actors.rs")));
        assert!(is_deterministic_module(Path::new("env.rs")));
        assert!(!is_deterministic_module(Path::new("metrics/mod.rs")));
        assert!(!is_deterministic_module(Path::new("runtime/mod.rs")));
        assert!(!is_deterministic_module(Path::new("bin/ued_lint.rs")));
        assert!(is_service_module(Path::new("serve/batcher.rs")));
        assert!(is_service_module(Path::new("serve/mod.rs")));
        assert!(!is_service_module(Path::new("bin/ued_serve.rs")));
        assert!(!is_deterministic_module(Path::new("serve/batcher.rs")));
    }

    #[test]
    fn config_for_maps_scopes() {
        let serve = config_for(Path::new("serve/cache.rs"));
        assert!(serve.ordered_collections && serve.wallclock_exempt && !serve.deterministic);
        let roll = config_for(Path::new("rollout/engine.rs"));
        assert!(roll.deterministic && !roll.wallclock_exempt);
        let root = config_for(Path::new("lib.rs"));
        assert!(root.expect_unsafe_op_deny && !root.deterministic);
        // bin/ued_serve.rs is *not* a service module: the launcher gets the
        // plain crate-wide profile, wallclock included.
        let launcher = config_for(Path::new("bin/ued_serve.rs"));
        assert!(!launcher.wallclock_exempt && !launcher.ordered_collections);
    }
}
