//! SARIF 2.1.0 output for `ued-lint`, consumable by GitHub code
//! scanning (`upload-sarif`) and most editor SARIF viewers.
//!
//! One run, one tool (`ued-lint`), one result per violation. File URIs
//! are emitted relative to the repository root via the caller-supplied
//! prefix (the binary passes `rust/src/` for the default tree), since
//! the lint itself works with src-relative paths.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::{CrateReport, Rule};

const ALL_RULES: [Rule; 13] = [
    Rule::HashCollections,
    Rule::ThreadRng,
    Rule::Wallclock,
    Rule::AddrHash,
    Rule::SafetyComment,
    Rule::UnsafeOpLint,
    Rule::DetTaint,
    Rule::ServePanic,
    Rule::LockOrder,
    Rule::RngLineage,
    Rule::FlushOnError,
    Rule::LockAcrossForward,
    Rule::BadAllow,
];

fn short_desc(rule: Rule) -> &'static str {
    match rule {
        Rule::HashCollections => "HashMap/HashSet in an order-sensitive module",
        Rule::ThreadRng => "ambient RNG in a deterministic module",
        Rule::Wallclock => "wallclock read outside the sanctioned stopwatch",
        Rule::AddrHash => "pointer address cast to an integer",
        Rule::SafetyComment => "unsafe without a SAFETY comment",
        Rule::UnsafeOpLint => "crate root missing deny(unsafe_op_in_unsafe_fn)",
        Rule::DetTaint => "nondeterminism source reachable from deterministic code",
        Rule::ServePanic => "panic site reachable on the serving path",
        Rule::LockOrder => "inconsistent lock acquisition order (potential deadlock)",
        Rule::RngLineage => "two RNG streams constructed from the same (seed, index) key",
        Rule::FlushOnError => "error path can propagate before metrics sinks are flushed",
        Rule::LockAcrossForward => "guard may be held across a blocking forward/socket call",
        Rule::BadAllow => "malformed ued-lint allow directive",
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Serialize `report` as a SARIF 2.1.0 log. `uri_prefix` is prepended to
/// every (src-relative) file path to make URIs repo-relative.
pub fn to_sarif(report: &CrateReport, uri_prefix: &str) -> String {
    let rules: Vec<Json> = ALL_RULES
        .iter()
        .map(|&r| {
            obj(vec![
                ("id", Json::from(r.name())),
                ("shortDescription", obj(vec![("text", Json::from(short_desc(r)))])),
            ])
        })
        .collect();
    let results: Vec<Json> = report
        .violations
        .iter()
        .map(|v| {
            obj(vec![
                ("ruleId", Json::from(v.rule.name())),
                ("level", Json::from("error")),
                ("message", obj(vec![("text", Json::from(v.message.as_str()))])),
                (
                    "locations",
                    Json::Arr(vec![obj(vec![(
                        "physicalLocation",
                        obj(vec![
                            (
                                "artifactLocation",
                                obj(vec![(
                                    "uri",
                                    Json::Str(format!("{uri_prefix}{}", v.file)),
                                )]),
                            ),
                            ("region", obj(vec![("startLine", Json::from(v.line.max(1)))])),
                        ]),
                    )])]),
                ),
            ])
        })
        .collect();
    let driver = obj(vec![
        ("name", Json::from("ued-lint")),
        ("informationUri", Json::from("https://github.com/")),
        ("version", Json::from("1.0.0")),
        ("rules", Json::Arr(rules)),
    ]);
    let run = obj(vec![
        ("tool", obj(vec![("driver", driver)])),
        ("results", Json::Arr(results)),
    ]);
    obj(vec![
        (
            "$schema",
            Json::from(
                "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json",
            ),
        ),
        ("version", Json::from("2.1.0")),
        ("runs", Json::Arr(vec![run])),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::super::Violation;
    use super::*;

    #[test]
    fn sarif_shape_is_valid_and_prefixed() {
        let report = CrateReport {
            files: 2,
            cache_hits: 0,
            violations: vec![Violation {
                file: String::from("serve/router.rs"),
                line: 7,
                rule: Rule::ServePanic,
                message: String::from("unwrap in serve fn handle"),
            }],
        };
        let text = to_sarif(&report, "rust/src/");
        let j = Json::parse(&text).expect("sarif must be valid json");
        assert_eq!(j.get("version").and_then(Json::as_str), Some("2.1.0"));
        let runs = j.get("runs").unwrap().as_arr().unwrap();
        let driver = runs[0].get("tool").unwrap().get("driver").unwrap();
        assert_eq!(driver.get("name").and_then(Json::as_str), Some("ued-lint"));
        // every enforced rule is declared
        assert_eq!(driver.get("rules").unwrap().as_arr().unwrap().len(), ALL_RULES.len());
        let res = &runs[0].get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(res.get("ruleId").and_then(Json::as_str), Some("serve-panic"));
        let uri = res.get("locations").unwrap().as_arr().unwrap()[0]
            .get("physicalLocation")
            .unwrap()
            .get("artifactLocation")
            .unwrap()
            .get("uri")
            .unwrap()
            .as_str()
            .unwrap();
        assert_eq!(uri, "rust/src/serve/router.rs");
        let line = res.get("locations").unwrap().as_arr().unwrap()[0]
            .get("physicalLocation")
            .unwrap()
            .get("region")
            .unwrap()
            .get("startLine")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(line, 7);
    }

    #[test]
    fn empty_report_still_serializes() {
        let report = CrateReport { files: 0, cache_hits: 0, violations: vec![] };
        let j = Json::parse(&to_sarif(&report, "")).unwrap();
        let runs = j.get("runs").unwrap().as_arr().unwrap();
        assert!(runs[0].get("results").unwrap().as_arr().unwrap().is_empty());
    }
}
