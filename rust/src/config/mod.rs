//! Training configuration: the paper's Table 3 hyperparameters plus
//! algorithm *and environment* selection, resolvable from CLI flags
//! (`--algo` picks the UED method, `--env` picks the [`EnvId`] family).
//!
//! PPO-loss constants (γ, λ, clip, epochs, …) are *baked into the
//! artifacts* at AOT time and are therefore not here; this config owns
//! everything the Rust coordinator decides at runtime: learning-rate
//! schedule, level-sampler settings, meta-policy probabilities, rollout
//! variant, budgets and evaluation cadence, and the env-layer knobs it
//! hands to the selected family via [`TrainConfig::env_params`].

use anyhow::{bail, Result};

use crate::env::{EnvId, EnvParams};
use crate::level_sampler::prioritization::Prioritization;
use crate::level_sampler::SamplerConfig;
use crate::util::cli::Args;

/// Which UED algorithm to run (paper §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Domain randomization (§5.2).
    Dr,
    /// Prioritized Level Replay — trains on new levels too (§5.1).
    Plr,
    /// Robust PLR (PLR⊥) — gradient updates only on replay cycles.
    RobustPlr,
    /// ACCEL — robust PLR + mutation cycles.
    Accel,
    /// PAIRED — learned adversary (§5.3).
    Paired,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dr" => Algo::Dr,
            "plr" => Algo::Plr,
            "robust_plr" | "plr_robust" | "plr^" | "plr-perp" | "rplr" => Algo::RobustPlr,
            "accel" => Algo::Accel,
            "paired" => Algo::Paired,
            other => bail!("unknown algo {other:?} (dr|plr|robust_plr|accel|paired)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Algo::Dr => "dr",
            Algo::Plr => "plr",
            Algo::RobustPlr => "robust_plr",
            Algo::Accel => "accel",
            Algo::Paired => "paired",
        }
    }
}

/// Regret-estimate scoring function (Table 3: MaxMC default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreFn {
    MaxMc,
    Pvl,
}

impl ScoreFn {
    pub fn parse(s: &str) -> Result<ScoreFn> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "maxmc" | "max_mc" => ScoreFn::MaxMc,
            "pvl" => ScoreFn::Pvl,
            other => bail!("unknown score fn {other:?} (maxmc|pvl)"),
        })
    }
}

/// Rollout-shape variant, fixed at artifact build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Variant {
    pub name: &'static str,
    /// PPO rollout length T (Table 3: 256).
    pub t: usize,
    /// Parallel environments B (Table 3: 32).
    pub b: usize,
}

pub const VARIANT_STD: Variant = Variant { name: "std", t: 256, b: 32 };
pub const VARIANT_SMALL: Variant = Variant { name: "small", t: 32, b: 8 };

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "std" => VARIANT_STD,
            "small" => VARIANT_SMALL,
            other => bail!("unknown variant {other:?} (std|small)"),
        })
    }
}

/// Largest seed pack a single process will build. Every seed owns a full
/// driver (trainer, engine, trajectory, evaluator), so packs beyond this
/// are a typo (`--seeds 0..10000000000`), not a sweep — reject eagerly
/// instead of OOMing while materializing the range.
pub const MAX_PACK_SEEDS: u64 = 1024;

/// Parse a `--seeds` specification: `a..b` (half-open), `a..=b`
/// (inclusive), a comma list `0,3,7`, or a single seed (a pack of one).
/// Duplicates are rejected — two identical seeds would race on one run
/// directory — and the pack is capped at [`MAX_PACK_SEEDS`].
pub fn parse_seed_spec(spec: &str) -> Result<Vec<u64>> {
    let s = spec.trim();
    let one = |t: &str| -> Result<u64> {
        t.trim()
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("bad seed {t:?} in --seeds {spec:?}"))
    };
    let check_len = |n: u64| -> Result<()> {
        if n > MAX_PACK_SEEDS {
            bail!("--seeds {spec:?} names {n} seeds (max {MAX_PACK_SEEDS} per pack)");
        }
        Ok(())
    };
    let seeds: Vec<u64> = if let Some((a, b)) = s.split_once("..=") {
        let (a, b) = (one(a)?, one(b)?);
        if a > b {
            bail!("empty seed range --seeds {spec:?}");
        }
        check_len((b - a).saturating_add(1))?;
        (a..=b).collect()
    } else if let Some((a, b)) = s.split_once("..") {
        let (a, b) = (one(a)?, one(b)?);
        if a >= b {
            bail!("empty seed range --seeds {spec:?}");
        }
        check_len(b - a)?;
        (a..b).collect()
    } else if s.contains(',') {
        let list = s.split(',').map(one).collect::<Result<Vec<u64>>>()?;
        check_len(list.len() as u64)?;
        list
    } else {
        vec![one(s)?]
    };
    let mut uniq = seeds.clone();
    uniq.sort_unstable();
    uniq.dedup();
    if uniq.len() != seeds.len() {
        bail!("duplicate seeds in --seeds {spec:?}");
    }
    Ok(seeds)
}

/// The full runtime configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub algo: Algo,
    /// Which environment family to train in (`--env`).
    pub env: EnvId,
    pub seed: u64,
    /// Seed pack (`--seeds a..b` / `--num-seeds N`): every listed seed
    /// trains concurrently in one process over one shared rollout worker
    /// pool. Empty = single-seed mode using `seed`.
    pub pack_seeds: Vec<u64>,
    pub variant: Variant,
    /// Total environment-interaction budget (paper: 245,760,000).
    pub env_steps_budget: u64,
    /// Adam learning rate (Table 3: 1e-4) and linear annealing flag.
    pub lr: f64,
    pub anneal_lr: bool,
    /// Base DR distribution wall budget (paper Figure 3: 25 or 60).
    pub max_walls: usize,
    /// Base DR distribution hazard-tile budget (lava family; the maze
    /// ignores it).
    pub max_hazards: usize,
    /// Student episode horizon.
    pub max_episode_steps: usize,
    /// Host-side rollout worker threads (`--rollout-threads`; 0 = auto,
    /// i.e. available parallelism). Per-column RNG streams make rollout
    /// results bit-identical at any setting.
    pub rollout_threads: usize,
    /// Seed-pack driver threads (`--drivers`; 0 = auto, i.e. one per
    /// seed up to available parallelism). Each driver steps a contiguous
    /// chunk of the pack's seeds so one seed's device forward overlaps
    /// the others' host work; results are bit-identical at any setting.
    /// Ignored outside pack mode.
    pub drivers: usize,

    // -- PLR family (Table 3) ------------------------------------------------
    /// Replay probability p (0.5 for PLR, 0.8 for ACCEL).
    pub replay_prob: f64,
    pub buffer_size: usize,
    pub score_fn: ScoreFn,
    pub prioritization: Prioritization,
    pub temperature: f64,
    pub staleness_coef: f64,
    pub min_fill_ratio: f64,

    // -- ACCEL ---------------------------------------------------------------
    /// Mutation probability q (1.0 when ACCEL: always mutate after replay).
    pub mutation_prob: f64,
    pub num_edits: usize,

    // -- PAIRED --------------------------------------------------------------
    /// Editor steps for the adversary (paper: 25 or 60).
    pub editor_steps: usize,

    // -- evaluation / logging -------------------------------------------------
    /// Evaluate every N update cycles (0 = only at the end).
    pub eval_interval: usize,
    /// Episodes per holdout level at evaluation.
    pub eval_trials: usize,
    pub out_dir: String,
    pub artifacts_dir: String,
}

impl TrainConfig {
    /// Paper defaults (Table 3) for the given algorithm.
    pub fn defaults(algo: Algo) -> TrainConfig {
        TrainConfig {
            algo,
            env: EnvId::Maze,
            seed: 0,
            pack_seeds: Vec::new(),
            variant: VARIANT_STD,
            env_steps_budget: 245_760_000,
            lr: 1e-4,
            anneal_lr: true,
            max_walls: 60,
            max_hazards: 12,
            max_episode_steps: 250,
            rollout_threads: 0,
            drivers: 0,
            replay_prob: if algo == Algo::Accel { 0.8 } else { 0.5 },
            buffer_size: 4000,
            score_fn: ScoreFn::MaxMc,
            prioritization: Prioritization::Rank,
            temperature: 0.3,
            staleness_coef: 0.3,
            min_fill_ratio: 0.5,
            mutation_prob: if algo == Algo::Accel { 1.0 } else { 0.0 },
            num_edits: 20,
            editor_steps: 60,
            eval_interval: 64,
            eval_trials: 3,
            out_dir: "runs".into(),
            artifacts_dir: "artifacts".into(),
        }
    }

    /// Resolve from CLI flags (unspecified flags keep Table 3 defaults).
    pub fn from_args(args: &Args) -> Result<TrainConfig> {
        let algo = Algo::parse(&args.get_str("algo", "dr"))?;
        let mut c = TrainConfig::defaults(algo);
        c.env = EnvId::parse(&args.get_str("env", c.env.name()))?;
        let seed_given = args.has("seed");
        c.seed = args.get_u64("seed", c.seed);
        let seeds_spec = args.get("seeds").map(str::to_string);
        let num_seeds = args.get_usize("num-seeds", 0);
        c.pack_seeds = match (&seeds_spec, num_seeds) {
            (Some(_), n) if n > 0 => {
                bail!("--seeds and --num-seeds are mutually exclusive")
            }
            (Some(spec), _) => parse_seed_spec(spec)?,
            (None, 0) => Vec::new(),
            (None, n) => {
                if n as u64 > MAX_PACK_SEEDS {
                    bail!("--num-seeds {n} exceeds the per-pack max of {MAX_PACK_SEEDS}");
                }
                (0..n as u64).collect()
            }
        };
        if !c.pack_seeds.is_empty() && seed_given {
            bail!("--seed conflicts with --seeds/--num-seeds (the pack supplies per-run seeds)");
        }
        c.variant = Variant::parse(&args.get_str("variant", c.variant.name))?;
        c.env_steps_budget = args.get_u64("env-steps", c.env_steps_budget);
        c.lr = args.get_f64("lr", c.lr);
        c.anneal_lr = args.get_bool("anneal-lr", c.anneal_lr);
        c.max_walls = args.get_usize("max-walls", c.max_walls);
        c.max_hazards = args.get_usize("max-hazards", c.max_hazards);
        c.max_episode_steps = args.get_usize("max-episode-steps", c.max_episode_steps);
        c.rollout_threads = args.get_usize("rollout-threads", c.rollout_threads);
        c.drivers = args.get_usize("drivers", c.drivers);
        c.replay_prob = args.get_f64("replay-prob", c.replay_prob);
        c.buffer_size = args.get_usize("buffer-size", c.buffer_size);
        c.score_fn = ScoreFn::parse(&args.get_str(
            "score-fn",
            match c.score_fn {
                ScoreFn::MaxMc => "maxmc",
                ScoreFn::Pvl => "pvl",
            },
        ))?;
        c.temperature = args.get_f64("temperature", c.temperature);
        c.staleness_coef = args.get_f64("staleness-coef", c.staleness_coef);
        c.min_fill_ratio = args.get_f64("min-fill", c.min_fill_ratio);
        c.mutation_prob = args.get_f64("mutation-prob", c.mutation_prob);
        c.num_edits = args.get_usize("num-edits", c.num_edits);
        c.editor_steps = args.get_usize("editor-steps", c.editor_steps);
        c.eval_interval = args.get_usize("eval-interval", c.eval_interval);
        c.eval_trials = args.get_usize("eval-trials", c.eval_trials);
        c.out_dir = args.get_str("out-dir", &c.out_dir);
        c.artifacts_dir = args.get_str("artifacts", &c.artifacts_dir);
        Ok(c)
    }

    /// Env steps consumed by one update cycle under the paper's accounting
    /// (§6: PAIRED counts both students; editor steps are excluded).
    pub fn env_steps_per_cycle(&self) -> u64 {
        let base = (self.variant.t * self.variant.b) as u64;
        match self.algo {
            Algo::Paired => 2 * base,
            _ => base,
        }
    }

    /// Total update cycles implied by the env-step budget.
    pub fn num_cycles(&self) -> usize {
        (self.env_steps_budget / self.env_steps_per_cycle()).max(1) as usize
    }

    /// Concrete rollout worker count: `--rollout-threads`, or the host's
    /// available parallelism when left at 0/auto.
    pub fn resolve_rollout_threads(&self) -> usize {
        if self.rollout_threads == 0 {
            crate::rollout::auto_threads()
        } else {
            self.rollout_threads
        }
    }

    /// Concrete driver-thread count for a pack of `num_seeds` seeds:
    /// `--drivers` clamped to the pack size, or — when left at 0/auto —
    /// one driver per seed capped at the host's available parallelism.
    /// Always at least 1.
    pub fn resolve_drivers(&self, num_seeds: usize) -> usize {
        let cap = num_seeds.max(1);
        if self.drivers == 0 {
            cap.min(crate::rollout::auto_threads())
        } else {
            self.drivers.min(cap)
        }
        .max(1)
    }

    /// The env-layer knobs handed to the selected [`EnvId`] family.
    pub fn env_params(&self) -> EnvParams {
        EnvParams {
            max_episode_steps: self.max_episode_steps,
            max_walls: self.max_walls,
            max_hazards: self.max_hazards,
            num_edits: self.num_edits,
            editor_steps: self.editor_horizon(),
        }
    }

    /// Run-directory name. The maze keeps the legacy `{algo}_s{seed}` so
    /// existing tooling keeps working; other families are scoped as
    /// `{env}_{algo}_s{seed}`.
    pub fn run_name(&self) -> String {
        match self.env {
            EnvId::Maze => format!("{}_s{}", self.algo.name(), self.seed),
            e => format!("{}_{}_s{}", e.name(), self.algo.name(), self.seed),
        }
    }

    /// The seeds this invocation trains: the pack, or the single `--seed`.
    pub fn seed_list(&self) -> Vec<u64> {
        if self.pack_seeds.is_empty() {
            vec![self.seed]
        } else {
            self.pack_seeds.clone()
        }
    }

    /// Per-seed view of a pack config: `seed` pinned, pack field cleared,
    /// everything else shared — each pack member is exactly the config a
    /// solo `--seed N` run would get (the bit-identity requirement).
    pub fn for_seed(&self, seed: u64) -> TrainConfig {
        let mut c = self.clone();
        c.seed = seed;
        c.pack_seeds = Vec::new();
        c
    }

    /// Pack directory name under `out_dir` (the per-seed run dirs stay
    /// flat beside it): `{env}_{algo}_pack_s{min}-{max}_n{count}` for a
    /// contiguous ascending range, with every seed spelled out
    /// (`s0+2+4`) otherwise — two different comma-list packs must never
    /// resolve to one directory and clobber each other's aggregates.
    pub fn pack_name(&self) -> String {
        let seeds = self.seed_list();
        let min = seeds.iter().min().copied().unwrap_or(0);
        let max = seeds.iter().max().copied().unwrap_or(0);
        let contiguous = seeds.len() as u64 == max.wrapping_sub(min).wrapping_add(1)
            && seeds.windows(2).all(|w| w[1] == w[0] + 1);
        let tag = if contiguous {
            format!("s{min}-{max}")
        } else {
            let mut sorted = seeds.clone();
            sorted.sort_unstable();
            let joined: Vec<String> = sorted.iter().map(u64::to_string).collect();
            format!("s{}", joined.join("+"))
        };
        format!(
            "{}_{}_pack_{}_n{}",
            self.env.name(),
            self.algo.name(),
            tag,
            seeds.len(),
        )
    }

    /// Sampler config view.
    pub fn sampler_config(&self) -> SamplerConfig {
        SamplerConfig {
            capacity: self.buffer_size,
            prioritization: self.prioritization,
            temperature: self.temperature,
            staleness_coef: self.staleness_coef,
            min_fill_ratio: self.min_fill_ratio,
            duplicate_check: true,
        }
    }

    /// Editor horizon for the PAIRED adversary artifacts. Only `std`
    /// shipped horizons 25/60; `small` bakes 13.
    pub fn editor_horizon(&self) -> usize {
        if self.variant.name == "small" {
            13
        } else {
            self.editor_steps
        }
    }

    // -- artifact name resolution --------------------------------------------
    //
    // Names are geometry-keyed (T/B); the runtime additionally prefers an
    // env-scoped `"{env}_{name}"` when `env.artifact_prefix()` is set and
    // the manifest carries one (see `Runtime::resolve_name`), falling back
    // to these shared names — the lava family matches the maze observation
    // geometry exactly, so the shared artifacts serve both.

    pub fn student_train_artifact(&self) -> String {
        format!("student_train_step_t{}_b{}", self.variant.t, self.variant.b)
    }

    pub fn student_apply_artifact(&self) -> String {
        format!("student_apply_b{}", self.variant.b)
    }

    pub fn score_artifact(&self) -> String {
        format!("score_t{}_b{}", self.variant.t, self.variant.b)
    }

    pub fn adversary_train_artifact(&self) -> String {
        format!("adversary_train_step_t{}_b{}", self.editor_horizon(), self.variant.b)
    }

    pub fn adversary_apply_artifact(&self) -> String {
        format!("adversary_apply_b{}", self.variant.b)
    }
}

/// Configuration for the `ued-serve` policy-zoo evaluation server.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address (`--serve-addr`; port 0 binds an ephemeral port —
    /// the tests use this).
    pub addr: String,
    /// Which environment family the server evaluates in (`--env`).
    pub env: EnvId,
    /// Checkpoint zoo directory (`--zoo-dir`): scanned at startup for
    /// `<id>.ckpt` files and `<id>/student.ckpt` run dirs.
    pub zoo_dir: String,
    /// Compiled-artifact directory (checkpoint-backed policies only).
    pub artifacts_dir: String,
    /// Batch columns B the batcher fills per forward — must match an
    /// `apply_b{B}` artifact when serving checkpoint policies
    /// (`--max-batch`).
    pub max_batch: usize,
    /// Result-cache capacity in per-(policy, level, trials, master)
    /// entries (`--cache-cap`).
    pub cache_cap: usize,
    /// How many policies stay resident at once; least-recently-used
    /// entries are evicted past this (`--zoo-cap`).
    pub zoo_cap: usize,
    /// Add N synthetic policies (`synthetic0..`) to the zoo — the
    /// artifact-free backend CI smoke and the integration tests use
    /// (`--synthetic-zoo`).
    pub synthetic_zoo: usize,
    /// Default trials per level when a request omits `"trials"`.
    pub trials: usize,
    /// Hard per-request trials ceiling.
    pub max_trials: usize,
    /// Hard per-request level-count ceiling.
    pub max_levels: usize,
    /// Episode step cap.
    pub max_steps: usize,
    /// Pending eval requests the batch queue holds before shedding load
    /// with 503s (`--queue-cap`).
    pub queue_cap: usize,
    /// Rollout worker threads for the batcher's engine
    /// (`--rollout-threads`; 0 = auto).
    pub rollout_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8321".into(),
            env: EnvId::Maze,
            zoo_dir: "runs".into(),
            artifacts_dir: "artifacts".into(),
            max_batch: 8,
            cache_cap: 65_536,
            zoo_cap: 8,
            synthetic_zoo: 0,
            trials: 10,
            max_trials: 100,
            max_levels: 512,
            max_steps: 250,
            queue_cap: 256,
            rollout_threads: 1,
        }
    }
}

impl ServeConfig {
    /// Resolve from CLI flags (unspecified flags keep the defaults).
    pub fn from_args(args: &Args) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        let c = ServeConfig {
            addr: args.get_str("serve-addr", &d.addr),
            env: EnvId::parse(&args.get_str("env", d.env.name()))?,
            zoo_dir: args.get_str("zoo-dir", &d.zoo_dir),
            artifacts_dir: args.get_str("artifacts", &d.artifacts_dir),
            max_batch: args.get_usize("max-batch", d.max_batch),
            cache_cap: args.get_usize("cache-cap", d.cache_cap),
            zoo_cap: args.get_usize("zoo-cap", d.zoo_cap),
            synthetic_zoo: args.get_usize("synthetic-zoo", d.synthetic_zoo),
            trials: args.get_usize("trials", d.trials),
            max_trials: args.get_usize("max-trials", d.max_trials),
            max_levels: args.get_usize("max-levels", d.max_levels),
            max_steps: args.get_usize("max-episode-steps", d.max_steps),
            queue_cap: args.get_usize("queue-cap", d.queue_cap),
            rollout_threads: args.get_usize("rollout-threads", d.rollout_threads),
        };
        if c.max_batch == 0 {
            bail!("--max-batch must be positive");
        }
        if c.trials == 0 || c.trials > c.max_trials {
            bail!("--trials must be in 1..=--max-trials ({})", c.max_trials);
        }
        if c.zoo_cap == 0 {
            bail!("--zoo-cap must be positive");
        }
        if c.queue_cap == 0 {
            bail!("--queue-cap must be positive");
        }
        Ok(c)
    }

    /// The apply artifact checkpoint-backed policies are served through.
    pub fn student_apply_artifact(&self) -> String {
        format!("student_apply_b{}", self.max_batch)
    }

    /// Env-layer knobs for the serving env (generation budgets keep the
    /// family defaults).
    pub fn env_params(&self) -> EnvParams {
        EnvParams { max_episode_steps: self.max_steps, ..EnvParams::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> TrainConfig {
        TrainConfig::from_args(&Args::parse_from(s.split_whitespace().map(String::from)))
            .unwrap()
    }

    #[test]
    fn table3_defaults() {
        let c = TrainConfig::defaults(Algo::Plr);
        assert_eq!(c.env_steps_budget, 245_760_000);
        assert_eq!(c.variant.t, 256);
        assert_eq!(c.variant.b, 32);
        assert_eq!(c.lr, 1e-4);
        assert!(c.anneal_lr);
        assert_eq!(c.replay_prob, 0.5);
        assert_eq!(c.buffer_size, 4000);
        assert_eq!(c.score_fn, ScoreFn::MaxMc);
        assert_eq!(c.prioritization, Prioritization::Rank);
        assert_eq!(c.temperature, 0.3);
        assert_eq!(c.staleness_coef, 0.3);
    }

    #[test]
    fn accel_defaults_differ() {
        let c = TrainConfig::defaults(Algo::Accel);
        assert_eq!(c.replay_prob, 0.8);
        assert_eq!(c.mutation_prob, 1.0);
        assert_eq!(c.num_edits, 20);
    }

    #[test]
    fn env_step_accounting() {
        let mut c = TrainConfig::defaults(Algo::Dr);
        assert_eq!(c.env_steps_per_cycle(), 256 * 32);
        c.algo = Algo::Paired;
        assert_eq!(c.env_steps_per_cycle(), 2 * 256 * 32);
        // paper: 245.76M steps == 30k updates of 256×32
        let c = TrainConfig::defaults(Algo::Dr);
        assert_eq!(c.num_cycles(), 30_000);
    }

    #[test]
    fn cli_overrides() {
        let c = parse("--algo accel --seed 7 --variant small --env-steps 100000 --max-walls 25");
        assert_eq!(c.algo, Algo::Accel);
        assert_eq!(c.env, EnvId::Maze, "maze is the default family");
        assert_eq!(c.seed, 7);
        assert_eq!(c.variant.b, 8);
        assert_eq!(c.max_walls, 25);
    }

    #[test]
    fn rollout_threads_flag() {
        let c = parse("--algo dr");
        assert_eq!(c.rollout_threads, 0, "default is auto");
        assert!(c.resolve_rollout_threads() >= 1);
        let c = parse("--algo dr --rollout-threads 3");
        assert_eq!(c.rollout_threads, 3);
        assert_eq!(c.resolve_rollout_threads(), 3);
    }

    #[test]
    fn drivers_flag() {
        let c = parse("--algo dr");
        assert_eq!(c.drivers, 0, "default is auto");
        // auto: one driver per seed, capped by host parallelism
        assert_eq!(c.resolve_drivers(1), 1);
        assert!(c.resolve_drivers(4) >= 1);
        assert!(c.resolve_drivers(4) <= 4);
        let c = parse("--algo dr --drivers 2");
        assert_eq!(c.drivers, 2);
        assert_eq!(c.resolve_drivers(8), 2);
        assert_eq!(c.resolve_drivers(1), 1, "clamped to the pack size");
        // an explicit oversized request clamps instead of spawning idle
        // threads, and a degenerate pack still gets one driver
        let c = parse("--algo dr --drivers 64");
        assert_eq!(c.resolve_drivers(3), 3);
        assert_eq!(c.resolve_drivers(0), 1);
    }

    #[test]
    fn env_selection_and_run_names() {
        let c = parse("--algo dr");
        assert_eq!(c.run_name(), "dr_s0", "maze keeps the legacy run name");
        let c = parse("--algo accel --env lava --seed 3 --max-hazards 6");
        assert_eq!(c.env, EnvId::Lava);
        assert_eq!(c.max_hazards, 6);
        assert_eq!(c.run_name(), "lava_accel_s3");
        let p = c.env_params();
        assert_eq!(p.max_hazards, 6);
        assert_eq!(p.editor_steps, c.editor_horizon());
    }

    #[test]
    fn artifact_names() {
        let c = TrainConfig::defaults(Algo::Paired);
        assert_eq!(c.student_train_artifact(), "student_train_step_t256_b32");
        assert_eq!(c.student_apply_artifact(), "student_apply_b32");
        assert_eq!(c.score_artifact(), "score_t256_b32");
        assert_eq!(c.adversary_train_artifact(), "adversary_train_step_t60_b32");
        let mut c25 = c.clone();
        c25.editor_steps = 25;
        assert_eq!(c25.adversary_train_artifact(), "adversary_train_step_t25_b32");
    }

    #[test]
    fn algo_parse_aliases() {
        assert_eq!(Algo::parse("PLR^").unwrap(), Algo::RobustPlr);
        assert!(Algo::parse("zzz").is_err());
    }

    #[test]
    fn seed_spec_forms() {
        assert_eq!(parse_seed_spec("0..4").unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(parse_seed_spec("2..=4").unwrap(), vec![2, 3, 4]);
        assert_eq!(parse_seed_spec("7,1,3").unwrap(), vec![7, 1, 3]);
        assert_eq!(parse_seed_spec("5").unwrap(), vec![5]);
        assert_eq!(parse_seed_spec(" 1 .. 3 ").unwrap(), vec![1, 2]);
        assert!(parse_seed_spec("4..4").is_err(), "empty half-open range");
        assert!(parse_seed_spec("5..=4").is_err(), "inverted range");
        assert!(parse_seed_spec("1,1").is_err(), "duplicates race on run dirs");
        assert!(parse_seed_spec("x..2").is_err());
        assert!(parse_seed_spec("").is_err());
        // a typo'd range errors eagerly instead of materializing 80 GB
        assert!(parse_seed_spec("0..10000000000").is_err(), "pack size cap");
        assert!(parse_seed_spec("0..=18446744073709551615").is_err(), "no overflow");
        assert_eq!(parse_seed_spec("0..1024").unwrap().len(), 1024, "cap is inclusive");
    }

    #[test]
    fn pack_flags() {
        let c = parse("--algo dr");
        assert!(c.pack_seeds.is_empty(), "default is single-seed");
        assert_eq!(c.seed_list(), vec![0]);

        let c = parse("--algo dr --seeds 0..4");
        assert_eq!(c.pack_seeds, vec![0, 1, 2, 3]);
        assert_eq!(c.seed_list(), vec![0, 1, 2, 3]);
        assert_eq!(c.pack_name(), "maze_dr_pack_s0-3_n4");

        let c = parse("--algo accel --env lava --num-seeds 3");
        assert_eq!(c.pack_seeds, vec![0, 1, 2]);
        assert_eq!(c.pack_name(), "lava_accel_pack_s0-2_n3");

        // non-contiguous packs spell out every seed so two different
        // comma lists with equal min/max/count cannot share a directory
        let a = parse("--algo dr --seeds 0,2,4");
        let b = parse("--algo dr --seeds 0,1,4");
        assert_eq!(a.pack_name(), "maze_dr_pack_s0+2+4_n3");
        assert_eq!(b.pack_name(), "maze_dr_pack_s0+1+4_n3");
        assert_ne!(a.pack_name(), b.pack_name());

        // per-seed views are exactly the solo configs
        let s3 = c.for_seed(3);
        assert_eq!(s3.seed, 3);
        assert!(s3.pack_seeds.is_empty());
        assert_eq!(s3.run_name(), "lava_accel_s3");
    }

    #[test]
    fn pack_flag_conflicts() {
        let args = Args::parse_from(
            "--algo dr --seeds 0..2 --num-seeds 4"
                .split_whitespace()
                .map(String::from),
        );
        assert!(TrainConfig::from_args(&args).is_err());
        let args = Args::parse_from(
            "--algo dr --seed 1 --seeds 0..2"
                .split_whitespace()
                .map(String::from),
        );
        assert!(TrainConfig::from_args(&args).is_err());
    }

    fn parse_serve(s: &str) -> Result<ServeConfig> {
        ServeConfig::from_args(&Args::parse_from(s.split_whitespace().map(String::from)))
    }

    #[test]
    fn serve_defaults_and_overrides() {
        let c = parse_serve("").unwrap();
        assert_eq!(c.env, EnvId::Maze);
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.trials, 10);
        assert_eq!(c.student_apply_artifact(), "student_apply_b8");
        assert_eq!(c.env_params().max_episode_steps, c.max_steps);

        let c = parse_serve(
            "--serve-addr 127.0.0.1:0 --env lava --max-batch 4 --synthetic-zoo 2 \
             --trials 3 --queue-cap 16 --max-episode-steps 40",
        )
        .unwrap();
        assert_eq!(c.addr, "127.0.0.1:0");
        assert_eq!(c.env, EnvId::Lava);
        assert_eq!(c.synthetic_zoo, 2);
        assert_eq!(c.trials, 3);
        assert_eq!(c.max_steps, 40);
        assert_eq!(c.student_apply_artifact(), "student_apply_b4");
    }

    #[test]
    fn serve_rejects_degenerate_knobs() {
        assert!(parse_serve("--max-batch 0").is_err());
        assert!(parse_serve("--trials 0").is_err());
        assert!(parse_serve("--trials 200").is_err(), "trials above --max-trials");
        assert!(parse_serve("--trials 200 --max-trials 200").is_ok());
        assert!(parse_serve("--zoo-cap 0").is_err());
        assert!(parse_serve("--queue-cap 0").is_err());
        assert!(parse_serve("--env marioland").is_err());
    }
}
