//! PPO driver: one `update()` = one PJRT call into the `*_train_step`
//! artifact (GAE → 5 epochs → Adam, all fused inside the module — see
//! DESIGN.md decision 1). This module owns parameter state and the
//! learning-rate schedule (Table 3: linear anneal).

use std::sync::Arc;

use anyhow::Result;

use crate::rollout::Trajectory;
use crate::runtime::executor::Executable;
use crate::runtime::{ParamSet, Runtime};

/// Metrics returned by a train step (names from the manifest ABI).
#[derive(Clone, Debug)]
pub struct UpdateMetrics {
    pub names: Vec<String>,
    pub values: Vec<f32>,
}

impl UpdateMetrics {
    pub fn get(&self, name: &str) -> Option<f32> {
        self.names.iter().position(|n| n == name).map(|i| self.values[i])
    }

    pub fn total_loss(&self) -> f32 {
        self.get("total_loss").unwrap_or(f32::NAN)
    }
}

/// Linear learning-rate schedule (Table 3: anneal to 0 over the budget).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub lr0: f64,
    pub anneal: bool,
    pub total_updates: usize,
}

impl LrSchedule {
    pub fn at(&self, update: usize) -> f32 {
        if !self.anneal || self.total_updates == 0 {
            return self.lr0 as f32;
        }
        let frac = 1.0 - (update.min(self.total_updates) as f64 / self.total_updates as f64);
        (self.lr0 * frac) as f32
    }
}

/// PPO trainer for one network (student, antagonist, or adversary).
pub struct PpoTrainer {
    pub params: ParamSet,
    train_exe: Arc<Executable>,
    metric_names: Vec<String>,
    /// Structured `[T, B, …]` observation shapes from the artifact ABI.
    obs_dims: Vec<Vec<usize>>,
    pub schedule: LrSchedule,
    pub updates_done: usize,
}

impl PpoTrainer {
    /// Build a trainer: initializes parameters via `<network>_init` and
    /// compiles the given train-step artifact.
    pub fn new(
        rt: &Runtime, network: &str, train_artifact: &str, seed: i32, schedule: LrSchedule,
    ) -> Result<PpoTrainer> {
        let params = rt.init_params(network, seed)?;
        let train_exe = rt.load(train_artifact)?;
        let net = rt.manifest.network(network)?;
        let p = net.num_params();
        let n_obs = net.n_obs;
        let obs_dims: Vec<Vec<usize>> = train_exe.def.inputs[3 * p + 2..3 * p + 2 + n_obs]
            .iter()
            .map(|spec| spec.shape.clone())
            .collect();
        Ok(PpoTrainer {
            params,
            train_exe,
            metric_names: rt.manifest.metric_names.clone(),
            obs_dims,
            schedule,
            updates_done: 0,
        })
    }

    /// Restore parameters from a checkpoint (schedule position resumes from
    /// the stored Adam count / epochs).
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        self.params = ParamSet::load(path, &self.params.network)?;
        Ok(())
    }

    /// The rollout shape this trainer's artifact was lowered for.
    pub fn rollout_shape(&self) -> (usize, usize) {
        (
            self.train_exe.def.t.expect("train artifact has T"),
            self.train_exe.def.b.expect("train artifact has B"),
        )
    }

    /// One PPO update-cycle on a full trajectory.
    pub fn update(&mut self, traj: &Trajectory) -> Result<UpdateMetrics> {
        let lr = self.schedule.at(self.updates_done);
        let mut args = self.params.train_args();
        args.push(xla::Literal::scalar(lr));
        args.extend(traj.train_args(&self.obs_dims)?);
        let outputs = self.train_exe.call(&args)?;
        let rest = self.params.absorb_train_outputs(outputs)?;
        self.updates_done += 1;
        let values = rest[0].to_vec::<f32>()?;
        Ok(UpdateMetrics { names: self.metric_names.clone(), values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_linear() {
        let s = LrSchedule { lr0: 1e-4, anneal: true, total_updates: 100 };
        assert!((s.at(0) - 1e-4).abs() < 1e-12);
        assert!((s.at(50) - 0.5e-4).abs() < 1e-9);
        assert_eq!(s.at(100), 0.0);
        assert_eq!(s.at(999), 0.0);
    }

    #[test]
    fn lr_schedule_constant() {
        let s = LrSchedule { lr0: 3e-4, anneal: false, total_updates: 100 };
        assert_eq!(s.at(0), s.at(99));
    }
}
