//! Holdout evaluation (paper §6.1 / Figure 3 / Table 2), generic over the
//! environment family.
//!
//! Runs the student policy on each holdout level for `trials` stochastic
//! episodes and reports per-level solve rates plus the paper's aggregates:
//! mean solve rate (Table 2) and IQM with min–max over seeds (Figure 3,
//! aggregated by the bench harness across runs). The evaluator contains no
//! env-specific types: any [`UnderspecifiedEnv`] plus a named level list
//! works, and [`for_family`] / [`evaluate_params`] build the family's
//! default suite from the registry.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::env::registry::{dispatch, EnvVisitor};
use crate::env::{EnvFamily, UnderspecifiedEnv};
use crate::rollout::{Policy, RolloutEngine};
use crate::runtime::{ParamSet, Runtime};
use crate::util::rng::Pcg64;
use crate::util::stats;

/// Per-level evaluation result.
#[derive(Clone, Debug)]
pub struct LevelResult {
    pub name: String,
    pub solve_rate: f64,
    pub mean_steps: f64,
}

/// Full evaluation report.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub levels: Vec<LevelResult>,
    /// Mean over levels of per-level solve rate (Table 2 number).
    pub mean_solve_rate: f64,
    /// IQM over levels (Figure 3 number).
    pub iqm_solve_rate: f64,
}

/// The evaluation suite: an environment plus named holdout levels.
pub struct Evaluator<E: UnderspecifiedEnv> {
    pub levels: Vec<(String, E::Level)>,
    pub env: E,
    pub trials: usize,
    /// Episode step cap driven by the engine (envs also self-truncate).
    pub max_steps: usize,
    b: usize,
}

impl<E: UnderspecifiedEnv> Evaluator<E> {
    pub fn new(
        env: E, levels: Vec<(String, E::Level)>, trials: usize, b: usize,
        max_steps: usize,
    ) -> Evaluator<E> {
        assert!(!levels.is_empty(), "empty holdout suite");
        Evaluator { levels, env, trials, max_steps, b }
    }

    /// Student policy action count (for building the eval [`Policy`]).
    pub fn num_actions(&self) -> usize {
        self.env.num_actions()
    }

    /// Evaluate a policy. Episodes are batched B at a time through the
    /// fixed-shape apply artifact (tail batches padded with repeats).
    pub fn run(&self, policy: &Policy, rng: &mut Pcg64) -> Result<EvalReport> {
        let mut engine = RolloutEngine::new(&self.env, self.b);
        // Build the work list: every (level, trial) pair.
        let mut work: Vec<usize> = Vec::with_capacity(self.levels.len() * self.trials);
        for i in 0..self.levels.len() {
            for _ in 0..self.trials {
                work.push(i);
            }
        }
        let mut solves = vec![0u32; self.levels.len()];
        let mut steps_sum = vec![0u64; self.levels.len()];
        let mut runs = vec![0u32; self.levels.len()];

        for chunk in work.chunks(self.b) {
            // Pad the tail with repeats of the first work item; padded
            // columns are run but ignored.
            let mut states: Vec<_> = chunk
                .iter()
                .map(|&i| self.env.reset_to_level(&self.levels[i].1, rng))
                .collect();
            while states.len() < self.b {
                states.push(self.env.reset_to_level(&self.levels[chunk[0]].1, rng));
            }
            let outcomes = engine.run_episodes(
                &self.env, &mut states, policy, self.max_steps, rng, false,
            )?;
            for (j, &i) in chunk.iter().enumerate() {
                runs[i] += 1;
                steps_sum[i] += outcomes[j].steps as u64;
                if outcomes[j].solved {
                    solves[i] += 1;
                }
            }
        }

        let levels: Vec<LevelResult> = self
            .levels
            .iter()
            .enumerate()
            .map(|(i, (name, _))| LevelResult {
                name: name.clone(),
                solve_rate: solves[i] as f64 / runs[i].max(1) as f64,
                mean_steps: steps_sum[i] as f64 / runs[i].max(1) as f64,
            })
            .collect();
        let rates: Vec<f64> = levels.iter().map(|l| l.solve_rate).collect();
        Ok(EvalReport {
            mean_solve_rate: stats::mean(&rates),
            iqm_solve_rate: stats::iqm(&rates),
            levels,
        })
    }
}

/// A family's default suite: its named holdout levels + `n_procedural`
/// deterministic solvable draws.
pub fn for_family<F: EnvFamily>(
    family: F, cfg: &TrainConfig, trials: usize, n_procedural: usize,
) -> Evaluator<F::Env> {
    let params = cfg.env_params();
    Evaluator::new(
        family.make_env(&params),
        family.holdout(n_procedural),
        trials,
        cfg.variant.b,
        params.max_episode_steps,
    )
}

/// Evaluate a parameter set on the default holdout suite of the env the
/// config selects — the env-erased entry point for `jaxued eval` and the
/// examples (internally dispatches through the registry).
pub fn evaluate_params(
    rt: &Runtime, cfg: &TrainConfig, params: &ParamSet, trials: usize,
    n_procedural: usize, rng: &mut Pcg64,
) -> Result<EvalReport> {
    struct V<'a> {
        rt: &'a Runtime,
        cfg: &'a TrainConfig,
        params: &'a ParamSet,
        trials: usize,
        n_procedural: usize,
        rng: &'a mut Pcg64,
    }
    impl EnvVisitor for V<'_> {
        type Out = Result<EvalReport>;
        fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
            let evaluator = for_family(family, self.cfg, self.trials, self.n_procedural);
            let apply = self.rt.load_scoped(
                self.cfg.env.artifact_prefix(),
                &self.cfg.student_apply_artifact(),
            )?;
            let policy = Policy {
                apply,
                params: &self.params.params,
                num_actions: evaluator.num_actions(),
            };
            evaluator.run(&policy, self.rng)
        }
    }
    dispatch(cfg.env, V { rt, cfg, params, trials, n_procedural, rng })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algo;
    use crate::env::{LavaFamily, MazeFamily};

    #[test]
    fn suite_composition() {
        let cfg = TrainConfig::defaults(Algo::Dr);
        let e = for_family(MazeFamily, &cfg, 2, 10);
        assert_eq!(e.levels.len(), 12 + 10);
        // all names unique
        let mut names: Vec<&String> = e.levels.iter().map(|(n, _)| n).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 22);
    }

    #[test]
    fn lava_suite_composition() {
        let mut cfg = TrainConfig::defaults(Algo::Dr);
        cfg.env = crate::env::EnvId::Lava;
        let e = for_family(LavaFamily, &cfg, 2, 8);
        assert_eq!(e.levels.len(), 6 + 8);
        assert_eq!(e.num_actions(), 3);
    }
}
