//! Holdout evaluation (paper §6.1 / Figure 3 / Table 2), generic over the
//! environment family.
//!
//! Runs the student policy on each holdout level for `trials` stochastic
//! episodes and reports per-level solve rates plus the paper's aggregates:
//! mean solve rate (Table 2) and IQM with min–max over seeds (Figure 3,
//! aggregated by the bench harness across runs). The evaluator contains no
//! env-specific types: any [`UnderspecifiedEnv`] plus a named level list
//! works, and [`for_family`] / [`evaluate_params`] build the family's
//! default suite from the registry.
//!
//! # Scheduling: work-queue vs padded chunks
//!
//! Every (level, trial) pair is one work item with its own deterministic
//! RNG stream (`Pcg64::new(master, EPISODE_STREAM + item)`), so an
//! episode's outcome is a pure function of the item id — independent of
//! which batch column runs it, when, or at what thread count. Two
//! schedulers consume the queue ([`EvalMode`]):
//!
//! * [`EvalMode::WorkQueue`] (default) — a finished column is refilled
//!   with the next pending episode each step, keeping the fixed-shape
//!   `apply_b{B}` batch full instead of computing discarded logits for
//!   dead columns.
//! * [`EvalMode::Chunked`] — the legacy scheme (B-episode chunks, tails
//!   padded with repeats), kept as the reference implementation: the
//!   `rollout_determinism` suite asserts both modes produce identical
//!   per-level results, with the work-queue issuing fewer device calls
//!   ([`EvalReport::forward_passes`]).

use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::env::registry::{dispatch, EnvVisitor};
use crate::env::{EnvFamily, UnderspecifiedEnv};
use crate::rollout::{EpisodeOutcome, PolicyModel, RolloutEngine, WorkerPool};
use crate::runtime::{ParamSet, Runtime};
use crate::util::rng::Pcg64;
use crate::util::stats;

/// Stream-id offset for per-episode eval streams (disjoint from the
/// rollout column streams and the drivers' subsystem streams).
const EPISODE_STREAM_BASE: u64 = 0xE7A1;

/// How the evaluator schedules episodes onto batch columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalMode {
    /// Refill finished columns from the pending-episode queue (default).
    WorkQueue,
    /// Legacy padded B-chunks (reference implementation).
    Chunked,
}

/// Per-level evaluation result.
#[derive(Clone, Debug)]
pub struct LevelResult {
    pub name: String,
    pub solve_rate: f64,
    pub mean_steps: f64,
}

/// Full evaluation report.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub levels: Vec<LevelResult>,
    /// Mean over levels of per-level solve rate (Table 2 number).
    pub mean_solve_rate: f64,
    /// IQM over levels (Figure 3 number).
    pub iqm_solve_rate: f64,
    /// Device forward calls the evaluation issued (batch-utilization
    /// metric: the work-queue scheduler needs fewer than padded chunks).
    pub forward_passes: u64,
}

/// The evaluation suite: an environment plus named holdout levels.
pub struct Evaluator<E: UnderspecifiedEnv> {
    pub levels: Vec<(String, E::Level)>,
    pub env: E,
    pub trials: usize,
    /// Episode step cap driven by the engine (envs also self-truncate).
    pub max_steps: usize,
    /// Scheduling mode used by [`run`](Evaluator::run).
    pub mode: EvalMode,
    b: usize,
    pool: Arc<WorkerPool>,
}

impl<E: UnderspecifiedEnv> Evaluator<E> {
    /// Single-threaded evaluator (work-queue mode).
    pub fn new(
        env: E, levels: Vec<(String, E::Level)>, trials: usize, b: usize,
        max_steps: usize,
    ) -> Evaluator<E> {
        Self::with_pool(env, levels, trials, b, max_steps, Arc::new(WorkerPool::new(1)))
    }

    /// Evaluator sharing a caller-owned worker pool.
    pub fn with_pool(
        env: E, levels: Vec<(String, E::Level)>, trials: usize, b: usize,
        max_steps: usize, pool: Arc<WorkerPool>,
    ) -> Evaluator<E> {
        assert!(!levels.is_empty(), "empty holdout suite");
        Evaluator { levels, env, trials, max_steps, mode: EvalMode::WorkQueue, b, pool }
    }

    /// Student policy action count (for building the eval [`Policy`]).
    ///
    /// [`Policy`]: crate::rollout::Policy
    pub fn num_actions(&self) -> usize {
        self.env.num_actions()
    }

    /// Evaluate a policy under the evaluator's configured [`EvalMode`].
    pub fn run<P: PolicyModel>(&self, policy: &P, rng: &mut Pcg64) -> Result<EvalReport> {
        self.run_with_mode(self.mode, policy, rng)
    }

    /// Evaluate a policy under an explicit scheduling mode. Both modes
    /// consume one `next_u64` master draw from `rng` and derive identical
    /// per-episode streams, so their reports match exactly.
    pub fn run_with_mode<P: PolicyModel>(
        &self, mode: EvalMode, policy: &P, rng: &mut Pcg64,
    ) -> Result<EvalReport> {
        let master = rng.next_u64();
        let n = self.levels.len() * self.trials;
        let mut engine = RolloutEngine::with_pool(&self.env, self.b, self.pool.clone());
        let episode_rng = |e: usize| Pcg64::new(master, EPISODE_STREAM_BASE + e as u64);

        let (outcomes, forward_passes) = match mode {
            EvalMode::WorkQueue => {
                let outcomes = engine.run_episode_queue(
                    &self.env,
                    policy,
                    n,
                    self.max_steps,
                    false,
                    |e| {
                        let mut r = episode_rng(e);
                        let s = self
                            .env
                            .reset_to_level(&self.levels[e / self.trials].1, &mut r);
                        (s, r)
                    },
                )?;
                (outcomes, engine.forward_passes())
            }
            EvalMode::Chunked => {
                let mut outcomes = vec![EpisodeOutcome::default(); n];
                let mut forwards = 0u64;
                let items: Vec<usize> = (0..n).collect();
                for chunk in items.chunks(self.b) {
                    let mut states = Vec::with_capacity(self.b);
                    let mut rngs = Vec::with_capacity(self.b);
                    for &e in chunk {
                        let mut r = episode_rng(e);
                        states.push(
                            self.env
                                .reset_to_level(&self.levels[e / self.trials].1, &mut r),
                        );
                        rngs.push(r);
                    }
                    // Pad the tail with repeats of the chunk's first
                    // episode; padded columns are run but ignored.
                    while states.len() < self.b {
                        let pad_state = states[0].clone();
                        let pad_rng = rngs[0].clone();
                        states.push(pad_state);
                        rngs.push(pad_rng);
                    }
                    let outs = engine.run_episodes(
                        &self.env, &mut states, policy, self.max_steps, &mut rngs, false,
                    )?;
                    forwards += engine.forward_passes();
                    for (j, &e) in chunk.iter().enumerate() {
                        outcomes[e] = outs[j];
                    }
                }
                (outcomes, forwards)
            }
        };

        let mut solves = vec![0u32; self.levels.len()];
        let mut steps_sum = vec![0u64; self.levels.len()];
        let mut runs = vec![0u32; self.levels.len()];
        for (e, o) in outcomes.iter().enumerate() {
            let i = e / self.trials;
            runs[i] += 1;
            steps_sum[i] += o.steps as u64;
            if o.solved {
                solves[i] += 1;
            }
        }

        let levels: Vec<LevelResult> = self
            .levels
            .iter()
            .enumerate()
            .map(|(i, (name, _))| LevelResult {
                name: name.clone(),
                solve_rate: solves[i] as f64 / runs[i].max(1) as f64,
                mean_steps: steps_sum[i] as f64 / runs[i].max(1) as f64,
            })
            .collect();
        let rates: Vec<f64> = levels.iter().map(|l| l.solve_rate).collect();
        Ok(EvalReport {
            mean_solve_rate: stats::mean(&rates),
            iqm_solve_rate: stats::iqm(&rates),
            forward_passes,
            levels,
        })
    }
}

/// A family's default suite: its named holdout levels + `n_procedural`
/// deterministic solvable draws, with its own worker pool sized by
/// `cfg.rollout_threads` (standalone-eval entry point; the training loop
/// uses [`for_family_with_pool`] to share the driver's pool instead).
pub fn for_family<F: EnvFamily>(
    family: F, cfg: &TrainConfig, trials: usize, n_procedural: usize,
) -> Evaluator<F::Env> {
    for_family_with_pool(
        family,
        cfg,
        trials,
        n_procedural,
        Arc::new(WorkerPool::new(cfg.resolve_rollout_threads())),
    )
}

/// [`for_family`] over a caller-provided pool, so one process runs one
/// pool (the training loop hands in the algorithm driver's).
pub fn for_family_with_pool<F: EnvFamily>(
    family: F, cfg: &TrainConfig, trials: usize, n_procedural: usize,
    pool: Arc<WorkerPool>,
) -> Evaluator<F::Env> {
    let params = cfg.env_params();
    Evaluator::with_pool(
        family.make_env(&params),
        family.holdout(n_procedural),
        trials,
        cfg.variant.b,
        params.max_episode_steps,
        pool,
    )
}

/// Evaluate a parameter set on the default holdout suite of the env the
/// config selects — the env-erased entry point for `jaxued eval` and the
/// examples (internally dispatches through the registry).
pub fn evaluate_params(
    rt: &Runtime, cfg: &TrainConfig, params: &ParamSet, trials: usize,
    n_procedural: usize, rng: &mut Pcg64,
) -> Result<EvalReport> {
    struct V<'a> {
        rt: &'a Runtime,
        cfg: &'a TrainConfig,
        params: &'a ParamSet,
        trials: usize,
        n_procedural: usize,
        rng: &'a mut Pcg64,
    }
    impl EnvVisitor for V<'_> {
        type Out = Result<EvalReport>;
        fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
            let evaluator = for_family(family, self.cfg, self.trials, self.n_procedural);
            let apply = self.rt.load_scoped(
                self.cfg.env.artifact_prefix(),
                &self.cfg.student_apply_artifact(),
            )?;
            let policy = crate::rollout::Policy {
                apply,
                params: &self.params.params,
                num_actions: evaluator.num_actions(),
            };
            evaluator.run(&policy, self.rng)
        }
    }
    dispatch(cfg.env, V { rt, cfg, params, trials, n_procedural, rng })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algo;
    use crate::env::{LavaFamily, MazeFamily};

    #[test]
    fn suite_composition() {
        let cfg = TrainConfig::defaults(Algo::Dr);
        let e = for_family(MazeFamily, &cfg, 2, 10);
        assert_eq!(e.levels.len(), 12 + 10);
        assert_eq!(e.mode, EvalMode::WorkQueue);
        // all names unique
        let mut names: Vec<&String> = e.levels.iter().map(|(n, _)| n).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 22);
    }

    #[test]
    fn lava_suite_composition() {
        let mut cfg = TrainConfig::defaults(Algo::Dr);
        cfg.env = crate::env::EnvId::Lava;
        let e = for_family(LavaFamily, &cfg, 2, 8);
        assert_eq!(e.levels.len(), 6 + 8);
        assert_eq!(e.num_actions(), 3);
    }
}
