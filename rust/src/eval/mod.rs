//! Holdout evaluation (paper §6.1 / Figure 3 / Table 2).
//!
//! Runs the student policy on each holdout level for `trials` stochastic
//! episodes and reports per-level solve rates plus the paper's aggregates:
//! mean solve rate (Table 2) and IQM with min–max over seeds (Figure 3,
//! aggregated by the bench harness across runs).

use anyhow::Result;

use crate::env::holdout::{named_levels, procedural_suite};
use crate::env::level::Level;
use crate::env::maze::MazeEnv;
use crate::env::UnderspecifiedEnv;
use crate::rollout::{Policy, RolloutEngine};
use crate::util::rng::Pcg64;
use crate::util::stats;

/// Per-level evaluation result.
#[derive(Clone, Debug)]
pub struct LevelResult {
    pub name: String,
    pub solve_rate: f64,
    pub mean_steps: f64,
}

/// Full evaluation report.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub levels: Vec<LevelResult>,
    /// Mean over levels of per-level solve rate (Table 2 number).
    pub mean_solve_rate: f64,
    /// IQM over levels (Figure 3 number).
    pub iqm_solve_rate: f64,
}

/// The evaluation suite: named mazes + a deterministic procedural batch.
pub struct Evaluator {
    pub levels: Vec<(String, Level)>,
    pub env: MazeEnv,
    pub trials: usize,
    b: usize,
}

impl Evaluator {
    /// The default suite: 12 named mazes + `n_procedural` seeded minimax-
    /// recipe levels (solvable, ≤ 60 walls).
    pub fn default_suite(
        b: usize, trials: usize, n_procedural: usize, max_episode_steps: usize,
    ) -> Evaluator {
        let mut levels: Vec<(String, Level)> = named_levels()
            .into_iter()
            .map(|nl| (nl.name.to_string(), nl.level))
            .collect();
        for (i, l) in procedural_suite(n_procedural, 60, 0xE7A1).into_iter().enumerate() {
            levels.push((format!("Proc{i:02}"), l));
        }
        Evaluator { levels, env: MazeEnv::new(max_episode_steps), trials, b }
    }

    /// Evaluate a policy. Episodes are batched B at a time through the
    /// fixed-shape apply artifact (tail batches padded with repeats).
    pub fn run(&self, policy: &Policy, rng: &mut Pcg64) -> Result<EvalReport> {
        let mut engine = RolloutEngine::new(&self.env, self.b);
        // Build the work list: every (level, trial) pair.
        let mut work: Vec<usize> = Vec::with_capacity(self.levels.len() * self.trials);
        for i in 0..self.levels.len() {
            for _ in 0..self.trials {
                work.push(i);
            }
        }
        let mut solves = vec![0u32; self.levels.len()];
        let mut steps_sum = vec![0u64; self.levels.len()];
        let mut runs = vec![0u32; self.levels.len()];

        for chunk in work.chunks(self.b) {
            // Pad the tail with repeats of the first work item; padded
            // columns are run but ignored.
            let mut states: Vec<_> = chunk
                .iter()
                .map(|&i| self.env.reset_to_level(&self.levels[i].1, rng))
                .collect();
            while states.len() < self.b {
                states.push(self.env.reset_to_level(&self.levels[chunk[0]].1, rng));
            }
            let outcomes = engine.run_episodes(
                &self.env, &mut states, policy, self.env.max_steps, rng, false,
            )?;
            for (j, &i) in chunk.iter().enumerate() {
                runs[i] += 1;
                steps_sum[i] += outcomes[j].steps as u64;
                if outcomes[j].solved {
                    solves[i] += 1;
                }
            }
        }

        let levels: Vec<LevelResult> = self
            .levels
            .iter()
            .enumerate()
            .map(|(i, (name, _))| LevelResult {
                name: name.clone(),
                solve_rate: solves[i] as f64 / runs[i].max(1) as f64,
                mean_steps: steps_sum[i] as f64 / runs[i].max(1) as f64,
            })
            .collect();
        let rates: Vec<f64> = levels.iter().map(|l| l.solve_rate).collect();
        Ok(EvalReport {
            mean_solve_rate: stats::mean(&rates),
            iqm_solve_rate: stats::iqm(&rates),
            levels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_composition() {
        let e = Evaluator::default_suite(8, 2, 10, 250);
        assert_eq!(e.levels.len(), 12 + 10);
        // all names unique
        let mut names: Vec<&String> = e.levels.iter().map(|(n, _)| n).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 22);
    }
}
