//! Holdout evaluation (paper §6.1 / Figure 3 / Table 2), generic over the
//! environment family.
//!
//! Runs the student policy on each holdout level for `trials` stochastic
//! episodes and reports per-level solve rates plus the paper's aggregates:
//! mean solve rate (Table 2) and IQM with min–max over seeds (Figure 3,
//! aggregated by the bench harness across runs). The evaluator contains no
//! env-specific types: any [`UnderspecifiedEnv`] plus a named level list
//! works, and [`for_family`] / [`evaluate_params`] build the family's
//! default suite from the registry.
//!
//! # Scheduling: work-queue vs padded chunks
//!
//! Every (level, trial) pair is one work item with its own deterministic
//! RNG stream (`Pcg64::new(master, EPISODE_STREAM + item)`), so an
//! episode's outcome is a pure function of the item id — independent of
//! which batch column runs it, when, or at what thread count. Two
//! schedulers consume the queue ([`EvalMode`]):
//!
//! * [`EvalMode::WorkQueue`] (default) — a finished column is refilled
//!   with the next pending episode each step, keeping the fixed-shape
//!   `apply_b{B}` batch full instead of computing discarded logits for
//!   dead columns.
//! * [`EvalMode::Chunked`] — the legacy scheme (B-episode chunks, tails
//!   padded with repeats), kept as the reference implementation: the
//!   `rollout_determinism` suite asserts both modes produce identical
//!   per-level results, with the work-queue issuing fewer device calls
//!   ([`EvalReport::forward_passes`]).

use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::env::registry::{dispatch, EnvVisitor};
use crate::env::{EnvFamily, LevelMeta, UnderspecifiedEnv};
use crate::rollout::{EpisodeOutcome, PolicyModel, RolloutEngine, WorkerPool};
use crate::runtime::{ParamSet, Runtime};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats;

/// Stream-id offset for per-episode eval streams (disjoint from the
/// rollout column streams and the drivers' subsystem streams).
const EPISODE_STREAM_BASE: u64 = 0xE7A1;

/// Per-level master seed for ad-hoc (served) evaluation: FNV-1a over the
/// request master and the level's canonical byte encoding. Keying the
/// stream by *content* rather than by the level's position in a request is
/// what makes per-level results cacheable across requests and batched
/// evaluation bit-identical to solo [`evaluate_levels`] runs — a level's
/// outcome cannot depend on what it was submitted alongside.
pub fn level_master(master: u64, level_bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    };
    for b in master.to_le_bytes() {
        eat(b);
    }
    for &b in level_bytes {
        eat(b);
    }
    h
}

/// The per-episode RNG stream for trial `trial` of the level encoded as
/// `level_bytes` under request master `master`. The single derivation rule
/// shared by the solo path ([`evaluate_levels`]) and the serving batcher.
pub fn adhoc_episode_rng(master: u64, level_bytes: &[u8], trial: usize) -> Pcg64 {
    Pcg64::new(
        level_master(master, level_bytes),
        EPISODE_STREAM_BASE + trial as u64,
    )
}

/// How the evaluator schedules episodes onto batch columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalMode {
    /// Refill finished columns from the pending-episode queue (default).
    WorkQueue,
    /// Legacy padded B-chunks (reference implementation).
    Chunked,
}

/// Per-level evaluation result.
#[derive(Clone, Debug)]
pub struct LevelResult {
    pub name: String,
    pub solve_rate: f64,
    pub mean_steps: f64,
}

impl LevelResult {
    /// Aggregate one level's trial outcomes. The single arithmetic path for
    /// per-level numbers — the holdout evaluator, the solo ad-hoc path, and
    /// the serving batcher all fold through here, which is what makes their
    /// results bit-comparable.
    pub fn from_outcomes(name: String, outcomes: &[EpisodeOutcome]) -> LevelResult {
        let mut solves = 0u32;
        let mut steps_sum = 0u64;
        for o in outcomes {
            steps_sum += o.steps as u64;
            if o.solved {
                solves += 1;
            }
        }
        let runs = (outcomes.len() as u32).max(1);
        LevelResult {
            name,
            solve_rate: solves as f64 / runs as f64,
            mean_steps: steps_sum as f64 / runs as f64,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::from(self.name.as_str()));
        m.insert("solve_rate".to_string(), Json::Num(self.solve_rate));
        m.insert("mean_steps".to_string(), Json::Num(self.mean_steps));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<LevelResult> {
        Ok(LevelResult {
            name: j
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("level name must be a string"))?
                .to_string(),
            solve_rate: j
                .req("solve_rate")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("solve_rate must be a number"))?,
            mean_steps: j
                .req("mean_steps")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("mean_steps must be a number"))?,
        })
    }
}

/// Full evaluation report.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub levels: Vec<LevelResult>,
    /// Mean over levels of per-level solve rate (Table 2 number).
    pub mean_solve_rate: f64,
    /// IQM over levels (Figure 3 number).
    pub iqm_solve_rate: f64,
    /// Device forward calls the evaluation issued (batch-utilization
    /// metric: the work-queue scheduler needs fewer than padded chunks).
    pub forward_passes: u64,
}

impl EvalReport {
    /// Assemble a report from per-level results. Shared by the holdout
    /// evaluator, the solo ad-hoc path, and the server's response builder
    /// so the mean/IQM arithmetic is identical everywhere.
    pub fn from_level_results(levels: Vec<LevelResult>, forward_passes: u64) -> EvalReport {
        let rates: Vec<f64> = levels.iter().map(|l| l.solve_rate).collect();
        EvalReport {
            mean_solve_rate: stats::mean(&rates),
            iqm_solve_rate: stats::iqm(&rates),
            forward_passes,
            levels,
        }
    }

    /// JSON form shared by `ued-serve` responses and on-disk eval
    /// artifacts. Round-trips through [`from_json`](EvalReport::from_json)
    /// bit-exactly for finite values (the writer emits shortest-exact
    /// float reprs).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "levels".to_string(),
            Json::Arr(self.levels.iter().map(|l| l.to_json()).collect()),
        );
        m.insert("mean_solve_rate".to_string(), Json::Num(self.mean_solve_rate));
        m.insert("iqm_solve_rate".to_string(), Json::Num(self.iqm_solve_rate));
        m.insert(
            "forward_passes".to_string(),
            Json::Num(self.forward_passes as f64),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<EvalReport> {
        let levels = j
            .req("levels")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("levels must be an array"))?
            .iter()
            .map(LevelResult::from_json)
            .collect::<Result<Vec<_>>>()?;
        let num = |key: &str| -> Result<f64> {
            j.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{key} must be a number"))
        };
        Ok(EvalReport {
            levels,
            mean_solve_rate: num("mean_solve_rate")?,
            iqm_solve_rate: num("iqm_solve_rate")?,
            forward_passes: num("forward_passes")? as u64,
        })
    }
}

/// The evaluation suite: an environment plus named holdout levels.
pub struct Evaluator<E: UnderspecifiedEnv> {
    pub levels: Vec<(String, E::Level)>,
    pub env: E,
    pub trials: usize,
    /// Episode step cap driven by the engine (envs also self-truncate).
    pub max_steps: usize,
    /// Scheduling mode used by [`run`](Evaluator::run).
    pub mode: EvalMode,
    b: usize,
    pool: Arc<WorkerPool>,
}

impl<E: UnderspecifiedEnv> Evaluator<E> {
    /// Single-threaded evaluator (work-queue mode).
    pub fn new(
        env: E, levels: Vec<(String, E::Level)>, trials: usize, b: usize,
        max_steps: usize,
    ) -> Evaluator<E> {
        Self::with_pool(env, levels, trials, b, max_steps, Arc::new(WorkerPool::new(1)))
    }

    /// Evaluator sharing a caller-owned worker pool.
    pub fn with_pool(
        env: E, levels: Vec<(String, E::Level)>, trials: usize, b: usize,
        max_steps: usize, pool: Arc<WorkerPool>,
    ) -> Evaluator<E> {
        assert!(!levels.is_empty(), "empty holdout suite");
        Evaluator { levels, env, trials, max_steps, mode: EvalMode::WorkQueue, b, pool }
    }

    /// Student policy action count (for building the eval [`Policy`]).
    ///
    /// [`Policy`]: crate::rollout::Policy
    pub fn num_actions(&self) -> usize {
        self.env.num_actions()
    }

    /// Evaluate a policy under the evaluator's configured [`EvalMode`].
    pub fn run<P: PolicyModel>(&self, policy: &P, rng: &mut Pcg64) -> Result<EvalReport> {
        self.run_with_mode(self.mode, policy, rng)
    }

    /// Evaluate a policy under an explicit scheduling mode. Both modes
    /// consume one `next_u64` master draw from `rng` and derive identical
    /// per-episode streams, so their reports match exactly.
    pub fn run_with_mode<P: PolicyModel>(
        &self, mode: EvalMode, policy: &P, rng: &mut Pcg64,
    ) -> Result<EvalReport> {
        let master = rng.next_u64();
        let n = self.levels.len() * self.trials;
        let mut engine = RolloutEngine::with_pool(&self.env, self.b, self.pool.clone());
        let episode_rng = |e: usize| Pcg64::new(master, EPISODE_STREAM_BASE + e as u64);

        let (outcomes, forward_passes) = match mode {
            EvalMode::WorkQueue => {
                let outcomes = engine.run_episode_queue(
                    &self.env,
                    policy,
                    n,
                    self.max_steps,
                    false,
                    |e| {
                        let mut r = episode_rng(e);
                        let s = self
                            .env
                            .reset_to_level(&self.levels[e / self.trials].1, &mut r);
                        (s, r)
                    },
                )?;
                (outcomes, engine.forward_passes())
            }
            EvalMode::Chunked => {
                let mut outcomes = vec![EpisodeOutcome::default(); n];
                let mut forwards = 0u64;
                let items: Vec<usize> = (0..n).collect();
                for chunk in items.chunks(self.b) {
                    let mut states = Vec::with_capacity(self.b);
                    let mut rngs = Vec::with_capacity(self.b);
                    for &e in chunk {
                        let mut r = episode_rng(e);
                        states.push(
                            self.env
                                .reset_to_level(&self.levels[e / self.trials].1, &mut r),
                        );
                        rngs.push(r);
                    }
                    // Pad the tail with repeats of the chunk's first
                    // episode; padded columns are run but ignored.
                    while states.len() < self.b {
                        let pad_state = states[0].clone();
                        let pad_rng = rngs[0].clone();
                        states.push(pad_state);
                        rngs.push(pad_rng);
                    }
                    let outs = engine.run_episodes(
                        &self.env, &mut states, policy, self.max_steps, &mut rngs, false,
                    )?;
                    forwards += engine.forward_passes();
                    for (j, &e) in chunk.iter().enumerate() {
                        outcomes[e] = outs[j];
                    }
                }
                (outcomes, forwards)
            }
        };

        // Episode e belongs to level e / trials, so outcomes fall into
        // contiguous per-level chunks of `trials`.
        let levels: Vec<LevelResult> = self
            .levels
            .iter()
            .zip(outcomes.chunks(self.trials))
            .map(|((name, _), outs)| LevelResult::from_outcomes(name.clone(), outs))
            .collect();
        Ok(EvalReport::from_level_results(levels, forward_passes))
    }
}

/// Solo ad-hoc evaluation: run `policy` on an arbitrary named level list
/// for `trials` episodes each, with **content-keyed** RNG streams
/// ([`adhoc_episode_rng`]) instead of the holdout evaluator's position-keyed
/// ones. This is the reference implementation the `ued-serve` batcher must
/// match bit-for-bit: because each episode's stream depends only on
/// (master, level bytes, trial), merging levels from many concurrent
/// requests into one work-queue pass cannot change any level's result.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_levels<E: UnderspecifiedEnv, P: PolicyModel>(
    env: &E, policy: &P, levels: &[(String, E::Level)], trials: usize,
    max_steps: usize, b: usize, master: u64, pool: Arc<WorkerPool>,
) -> Result<EvalReport> {
    assert!(!levels.is_empty(), "empty level list");
    assert!(trials > 0, "trials must be positive");
    let encodings: Vec<Vec<u8>> = levels.iter().map(|(_, l)| l.encode()).collect();
    let mut engine = RolloutEngine::with_pool(env, b, pool);
    let n = levels.len() * trials;
    let outcomes = engine.run_episode_queue(env, policy, n, max_steps, false, |e| {
        let (li, trial) = (e / trials, e % trials);
        let mut r = adhoc_episode_rng(master, &encodings[li], trial);
        let s = env.reset_to_level(&levels[li].1, &mut r);
        (s, r)
    })?;
    let results: Vec<LevelResult> = levels
        .iter()
        .zip(outcomes.chunks(trials))
        .map(|((name, _), outs)| LevelResult::from_outcomes(name.clone(), outs))
        .collect();
    Ok(EvalReport::from_level_results(results, engine.forward_passes()))
}

/// A family's default suite: its named holdout levels + `n_procedural`
/// deterministic solvable draws, with its own worker pool sized by
/// `cfg.rollout_threads` (standalone-eval entry point; the training loop
/// uses [`for_family_with_pool`] to share the driver's pool instead).
pub fn for_family<F: EnvFamily>(
    family: F, cfg: &TrainConfig, trials: usize, n_procedural: usize,
) -> Evaluator<F::Env> {
    for_family_with_pool(
        family,
        cfg,
        trials,
        n_procedural,
        Arc::new(WorkerPool::new(cfg.resolve_rollout_threads())),
    )
}

/// [`for_family`] over a caller-provided pool, so one process runs one
/// pool (the training loop hands in the algorithm driver's).
pub fn for_family_with_pool<F: EnvFamily>(
    family: F, cfg: &TrainConfig, trials: usize, n_procedural: usize,
    pool: Arc<WorkerPool>,
) -> Evaluator<F::Env> {
    let params = cfg.env_params();
    Evaluator::with_pool(
        family.make_env(&params),
        family.holdout(n_procedural),
        trials,
        cfg.variant.b,
        params.max_episode_steps,
        pool,
    )
}

/// Evaluate a parameter set on the default holdout suite of the env the
/// config selects — the env-erased entry point for `jaxued eval` and the
/// examples (internally dispatches through the registry).
pub fn evaluate_params(
    rt: &Runtime, cfg: &TrainConfig, params: &ParamSet, trials: usize,
    n_procedural: usize, rng: &mut Pcg64,
) -> Result<EvalReport> {
    struct V<'a> {
        rt: &'a Runtime,
        cfg: &'a TrainConfig,
        params: &'a ParamSet,
        trials: usize,
        n_procedural: usize,
        rng: &'a mut Pcg64,
    }
    impl EnvVisitor for V<'_> {
        type Out = Result<EvalReport>;
        fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
            let evaluator = for_family(family, self.cfg, self.trials, self.n_procedural);
            let apply = self.rt.load_scoped(
                self.cfg.env.artifact_prefix(),
                &self.cfg.student_apply_artifact(),
            )?;
            let policy = crate::rollout::Policy {
                apply,
                params: &self.params.params,
                num_actions: evaluator.num_actions(),
            };
            evaluator.run(&policy, self.rng)
        }
    }
    dispatch(cfg.env, V { rt, cfg, params, trials, n_procedural, rng })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algo;
    use crate::env::{LavaFamily, MazeFamily};

    #[test]
    fn suite_composition() {
        let cfg = TrainConfig::defaults(Algo::Dr);
        let e = for_family(MazeFamily, &cfg, 2, 10);
        assert_eq!(e.levels.len(), 12 + 10);
        assert_eq!(e.mode, EvalMode::WorkQueue);
        // all names unique
        let mut names: Vec<&String> = e.levels.iter().map(|(n, _)| n).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 22);
    }

    #[test]
    fn lava_suite_composition() {
        let mut cfg = TrainConfig::defaults(Algo::Dr);
        cfg.env = crate::env::EnvId::Lava;
        let e = for_family(LavaFamily, &cfg, 2, 8);
        assert_eq!(e.levels.len(), 6 + 8);
        assert_eq!(e.num_actions(), 3);
    }

    #[test]
    fn report_json_roundtrip_is_bit_exact() {
        let report = EvalReport::from_level_results(
            vec![
                LevelResult { name: "a".into(), solve_rate: 1.0 / 3.0, mean_steps: 17.5 },
                LevelResult { name: "b\"quoted\"".into(), solve_rate: 0.0, mean_steps: 250.0 },
                LevelResult { name: "c".into(), solve_rate: 0.7, mean_steps: 0.1 + 0.2 },
            ],
            12345,
        );
        let text = report.to_json().to_string();
        let back = EvalReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.levels.len(), report.levels.len());
        for (l, r) in report.levels.iter().zip(&back.levels) {
            assert_eq!(l.name, r.name);
            assert_eq!(l.solve_rate.to_bits(), r.solve_rate.to_bits());
            assert_eq!(l.mean_steps.to_bits(), r.mean_steps.to_bits());
        }
        assert_eq!(report.mean_solve_rate.to_bits(), back.mean_solve_rate.to_bits());
        assert_eq!(report.iqm_solve_rate.to_bits(), back.iqm_solve_rate.to_bits());
        assert_eq!(report.forward_passes, back.forward_passes);
    }

    #[test]
    fn report_from_json_rejects_malformed() {
        for bad in [
            r#"{"levels":[],"mean_solve_rate":0}"#,
            r#"{"levels":[{"name":1,"solve_rate":0,"mean_steps":0}],"mean_solve_rate":0,"iqm_solve_rate":0,"forward_passes":0}"#,
            r#"{"levels":"x","mean_solve_rate":0,"iqm_solve_rate":0,"forward_passes":0}"#,
        ] {
            assert!(EvalReport::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn adhoc_results_are_position_independent() {
        // The content-keyed derivation: a level's result must not depend on
        // where it sits in the submitted list or what it shares it with.
        use crate::env::holdout;
        use crate::env::maze::MazeEnv;
        use crate::rollout::SyntheticPolicy;
        let env = MazeEnv::new(40);
        let policy = SyntheticPolicy { num_actions: env.num_actions() };
        let named: Vec<_> = holdout::named_levels()
            .into_iter()
            .take(3)
            .map(|n| (n.name.to_string(), n.level))
            .collect();
        let pool = Arc::new(WorkerPool::new(1));
        let fwd = evaluate_levels(&env, &policy, &named, 3, 40, 4, 7, pool.clone()).unwrap();
        let mut rev_levels = named.clone();
        rev_levels.reverse();
        let rev = evaluate_levels(&env, &policy, &rev_levels, 3, 40, 4, 7, pool).unwrap();
        for l in &fwd.levels {
            let r = rev.levels.iter().find(|r| r.name == l.name).unwrap();
            assert_eq!(l.solve_rate.to_bits(), r.solve_rate.to_bits(), "{}", l.name);
            assert_eq!(l.mean_steps.to_bits(), r.mean_steps.to_bits(), "{}", l.name);
        }
    }

    #[test]
    fn level_master_discriminates() {
        let a = level_master(1, &[1, 2, 3]);
        assert_ne!(a, level_master(2, &[1, 2, 3]), "master must matter");
        assert_ne!(a, level_master(1, &[1, 2, 4]), "bytes must matter");
        assert_eq!(a, level_master(1, &[1, 2, 3]), "must be a pure function");
    }
}
