//! `ued-lint` — run the in-repo determinism/unsafety analysis pass over
//! the crate source and fail (exit 1) on any violation.
//!
//! Usage: `cargo run --release --bin ued_lint [-- <src-dir>] [options]`
//!
//! Options:
//! * `--format human|sarif` — report format (default `human`; `sarif`
//!   emits a SARIF 2.1.0 log on stdout for code-scanning upload).
//! * `--only <rule>` — report only violations of the named rule (the
//!   full pass still runs; other findings are filtered from the report
//!   and the exit code).
//! * `--explain <rule>` — print the rule's rationale, what it
//!   over-approximates, and how to allow sanctioned cases; then exit.
//! * `--no-semantic` — per-file rules only, skip the call-graph
//!   analyses (`det-taint`, `serve-panic`, `lock-order`,
//!   `lock-across-forward`).
//! * `--no-cache` — ignore and don't write the incremental cache.
//! * `--cache-path <file>` — cache location (default: per-tree files
//!   `target/ued-lint-cache-<tree>.json` next to the crate's `src/`;
//!   with an explicit directory argument, a single
//!   `target/ued-lint-cache.json`). In the default multi-tree mode an
//!   explicit path names the `src/` cache and sibling trees append
//!   `.benches` / `.examples` to it.
//!
//! With no directory argument it lints the crate's `src/` (relative to
//! the working directory, falling back to the crate's own `src/` when
//! invoked from elsewhere) **plus** the sibling `benches/` tree and the
//! repository-level `examples/` tree, each under its own profile:
//! benches are wallclock-exempt (timing is their job) and skip the
//! deterministic-module RNG-lineage gating, examples get the plain
//! default profile. Paths in the merged report are repo-relative
//! (`rust/src/…`, `rust/benches/…`, `examples/…`). An explicit
//! directory argument lints just that tree under the `src/` profile,
//! as before.
//!
//! See `jaxued::analysis` for the rule set, the deterministic-module
//! list, and the allow-comment escape hatch; the README's "Determinism
//! invariants" section is the human-facing summary. CI runs this as a
//! required job and uploads the SARIF to code scanning.
//!
//! Timing and cache statistics go to stderr so they never corrupt the
//! SARIF stream on stdout.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use jaxued::analysis::{
    lint_tree_with, sarif, CrateReport, LintOptions, Rule, TreeKind, DETERMINISTIC_MODULES,
};
use jaxued::metrics::Stopwatch;

fn usage() {
    eprintln!(
        "usage: ued_lint [<src-dir>] [--format human|sarif] [--only <rule>] \
         [--explain <rule>] [--no-semantic] [--no-cache] [--cache-path <file>]"
    );
    eprintln!(
        "lints every .rs file under <src-dir>; with no argument, the crate's \
         src/, benches/, and the repo's examples/"
    );
}

/// One tree of the default multi-tree run.
struct Tree {
    root: PathBuf,
    kind: TreeKind,
    /// Repo-relative prefix for every reported path in this tree.
    prefix: &'static str,
    /// Suffix distinguishing this tree's cache file.
    cache_tag: &'static str,
}

fn tree_cache_path(explicit: &Option<PathBuf>, src_root: &Path, tag: &str) -> Option<PathBuf> {
    match explicit {
        Some(p) if tag == "src" => Some(p.clone()),
        Some(p) => {
            let mut s = p.as_os_str().to_owned();
            s.push(".");
            s.push(tag);
            Some(PathBuf::from(s))
        }
        None => src_root
            .parent()
            .map(|p| p.join("target").join(format!("ued-lint-cache-{tag}.json"))),
    }
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut format_sarif = false;
    let mut semantic = true;
    let mut use_cache = true;
    let mut cache_path: Option<PathBuf> = None;
    let mut only: Option<Rule> = None;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "-h" | "--help" => {
                usage();
                return ExitCode::SUCCESS;
            }
            "--format" => match args.next().as_deref() {
                Some("human") => format_sarif = false,
                Some("sarif") => format_sarif = true,
                other => {
                    eprintln!("ued-lint: --format takes `human` or `sarif`, got {other:?}");
                    return ExitCode::from(2);
                }
            },
            "--only" => match args.next().as_deref().and_then(Rule::from_name) {
                Some(r) => only = Some(r),
                None => {
                    eprintln!("ued-lint: --only needs a known rule name (see --explain)");
                    return ExitCode::from(2);
                }
            },
            "--explain" => match args.next() {
                Some(name) => match Rule::from_name(&name) {
                    Some(r) => {
                        println!("{}", r.explain());
                        return ExitCode::SUCCESS;
                    }
                    None => {
                        eprintln!("ued-lint: unknown rule `{name}`");
                        return ExitCode::from(2);
                    }
                },
                None => {
                    eprintln!("ued-lint: --explain needs a rule name");
                    return ExitCode::from(2);
                }
            },
            "--no-semantic" => semantic = false,
            "--no-cache" => use_cache = false,
            "--cache-path" => match args.next() {
                Some(p) => cache_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("ued-lint: --cache-path needs a file argument");
                    return ExitCode::from(2);
                }
            },
            other if root.is_none() && !other.starts_with('-') => {
                root = Some(PathBuf::from(other));
            }
            other => {
                eprintln!("ued-lint: unexpected argument `{other}`");
                usage();
                return ExitCode::from(2);
            }
        }
    }

    // Resolve the trees to lint. An explicit directory keeps the legacy
    // single-tree behavior (src profile, src-relative paths); the
    // default lints src/ + benches/ + examples/ with repo-relative
    // reported paths.
    let (trees, uri_prefix, label) = match root {
        Some(r) => {
            if !r.is_dir() {
                eprintln!("ued-lint: source directory `{}` not found", r.display());
                return ExitCode::from(2);
            }
            let uri_prefix = {
                let canon = r.canonicalize().unwrap_or_else(|_| r.clone());
                if canon.ends_with("rust/src") {
                    String::from("rust/src/")
                } else {
                    format!("{}/", r.display())
                }
            };
            let label = r.display().to_string();
            (
                vec![Tree { root: r, kind: TreeKind::Src, prefix: "", cache_tag: "src" }],
                uri_prefix,
                label,
            )
        }
        None => {
            let src = {
                let cwd_src = PathBuf::from("src");
                if cwd_src.is_dir() {
                    cwd_src
                } else {
                    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
                }
            };
            if !src.is_dir() {
                eprintln!("ued-lint: source directory `{}` not found", src.display());
                return ExitCode::from(2);
            }
            let crate_root = src.parent().map(Path::to_path_buf).unwrap_or_default();
            let mut trees = vec![Tree {
                root: src,
                kind: TreeKind::Src,
                prefix: "rust/src/",
                cache_tag: "src",
            }];
            let benches = crate_root.join("benches");
            if benches.is_dir() {
                trees.push(Tree {
                    root: benches,
                    kind: TreeKind::Bench,
                    prefix: "rust/benches/",
                    cache_tag: "benches",
                });
            }
            let examples = crate_root.parent().map(|p| p.join("examples"));
            if let Some(examples) = examples.filter(|p| p.is_dir()) {
                trees.push(Tree {
                    root: examples,
                    kind: TreeKind::Example,
                    prefix: "examples/",
                    cache_tag: "examples",
                });
            }
            // Paths are already repo-relative; nothing to prepend.
            (trees, String::new(), String::from("src+benches+examples"))
        }
    };

    let src_root = trees[0].root.clone();
    let watch = Stopwatch::new();
    let mut merged = CrateReport::default();
    for t in &trees {
        let opts = LintOptions {
            semantic,
            cache_path: if use_cache {
                tree_cache_path(&cache_path, &src_root, t.cache_tag)
            } else {
                None
            },
        };
        match lint_tree_with(&t.root, t.kind, &opts) {
            Err(e) => {
                eprintln!("ued-lint: i/o error walking `{}`: {e}", t.root.display());
                return ExitCode::from(2);
            }
            Ok(mut report) => {
                for v in &mut report.violations {
                    v.file = format!("{}{}", t.prefix, v.file);
                }
                merged.files += report.files;
                merged.cache_hits += report.cache_hits;
                merged.violations.extend(report.violations);
            }
        }
    }
    if let Some(rule) = only {
        merged.violations.retain(|v| v.rule == rule);
    }
    merged.violations.sort_by(|a, b| {
        (&a.file, a.line, a.rule.name()).cmp(&(&b.file, b.line, b.rule.name()))
    });

    let ok = merged.violations.is_empty();
    if format_sarif {
        println!("{}", sarif::to_sarif(&merged, &uri_prefix));
    } else if ok {
        println!(
            "ued-lint: clean — {} files under `{label}` ({} deterministic modules: {})",
            merged.files,
            DETERMINISTIC_MODULES.len(),
            DETERMINISTIC_MODULES.join(", ")
        );
    } else {
        for v in &merged.violations {
            println!("{v}");
        }
        println!("ued-lint: {} violation(s) in {} files", merged.violations.len(), merged.files);
    }
    eprintln!(
        "ued-lint: {} files in {:.3}s ({} cache hit(s), semantic {})",
        merged.files,
        watch.elapsed_secs(),
        merged.cache_hits,
        if semantic { "on" } else { "off" },
    );
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
