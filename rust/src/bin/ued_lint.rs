//! `ued-lint` — run the in-repo determinism/unsafety analysis pass over
//! the crate source and fail (exit 1) on any violation.
//!
//! Usage: `cargo run --release --bin ued_lint [-- <src-dir>] [options]`
//!
//! Options:
//! * `--format human|sarif` — report format (default `human`; `sarif`
//!   emits a SARIF 2.1.0 log on stdout for code-scanning upload).
//! * `--no-semantic` — per-file rules only, skip the call-graph
//!   analyses (`det-taint`, `serve-panic`, `lock-order`).
//! * `--no-cache` — ignore and don't write the incremental cache.
//! * `--cache-path <file>` — cache location (default
//!   `target/ued-lint-cache.json` next to the linted `src/`).
//!
//! With no directory argument it lints `src/` relative to the working
//! directory (falling back to the crate's own `src/` when invoked from
//! elsewhere, e.g. the repository root). See `jaxued::analysis` for the
//! rule set, the deterministic-module list, and the allow-comment
//! escape hatch; the README's "Determinism invariants" section is the
//! human-facing summary. CI runs this as a required job and uploads the
//! SARIF to code scanning.
//!
//! Timing and cache statistics go to stderr so they never corrupt the
//! SARIF stream on stdout.

use std::path::PathBuf;
use std::process::ExitCode;

use jaxued::analysis::{lint_crate_with, sarif, LintOptions, DETERMINISTIC_MODULES};
use jaxued::metrics::Stopwatch;

fn usage() {
    eprintln!(
        "usage: ued_lint [<src-dir>] [--format human|sarif] [--no-semantic] \
         [--no-cache] [--cache-path <file>]"
    );
    eprintln!("lints every .rs file under <src-dir> (default: src/)");
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut format_sarif = false;
    let mut semantic = true;
    let mut use_cache = true;
    let mut cache_path: Option<PathBuf> = None;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "-h" | "--help" => {
                usage();
                return ExitCode::SUCCESS;
            }
            "--format" => match args.next().as_deref() {
                Some("human") => format_sarif = false,
                Some("sarif") => format_sarif = true,
                other => {
                    eprintln!("ued-lint: --format takes `human` or `sarif`, got {other:?}");
                    return ExitCode::from(2);
                }
            },
            "--no-semantic" => semantic = false,
            "--no-cache" => use_cache = false,
            "--cache-path" => match args.next() {
                Some(p) => cache_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("ued-lint: --cache-path needs a file argument");
                    return ExitCode::from(2);
                }
            },
            other if root.is_none() && !other.starts_with('-') => {
                root = Some(PathBuf::from(other));
            }
            other => {
                eprintln!("ued-lint: unexpected argument `{other}`");
                usage();
                return ExitCode::from(2);
            }
        }
    }

    let root = root.unwrap_or_else(|| {
        let cwd_src = PathBuf::from("src");
        if cwd_src.is_dir() {
            cwd_src
        } else {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
        }
    });
    if !root.is_dir() {
        eprintln!("ued-lint: source directory `{}` not found", root.display());
        return ExitCode::from(2);
    }

    let cache_path = if use_cache {
        cache_path.or_else(|| {
            // Default next to the linted tree, inside target/ (ignored by
            // git); a missing target/ just means a cold run every time.
            root.parent().map(|p| p.join("target").join("ued-lint-cache.json"))
        })
    } else {
        None
    };
    let opts = LintOptions { semantic, cache_path };

    // SARIF URIs should be repository-relative. When the linted tree is
    // the crate's own src/, that prefix is `rust/src/`; otherwise fall
    // back to the path as given.
    let uri_prefix = {
        let canon = root.canonicalize().unwrap_or_else(|_| root.clone());
        if canon.ends_with("rust/src") {
            String::from("rust/src/")
        } else {
            format!("{}/", root.display())
        }
    };

    let watch = Stopwatch::new();
    match lint_crate_with(&root, &opts) {
        Err(e) => {
            eprintln!("ued-lint: i/o error walking `{}`: {e}", root.display());
            ExitCode::from(2)
        }
        Ok(report) => {
            let ok = report.violations.is_empty();
            if format_sarif {
                println!("{}", sarif::to_sarif(&report, &uri_prefix));
            } else if ok {
                println!(
                    "ued-lint: clean — {} files under `{}` ({} deterministic modules: {})",
                    report.files,
                    root.display(),
                    DETERMINISTIC_MODULES.len(),
                    DETERMINISTIC_MODULES.join(", ")
                );
            } else {
                for v in &report.violations {
                    println!("{v}");
                }
                println!(
                    "ued-lint: {} violation(s) in {} files",
                    report.violations.len(),
                    report.files
                );
            }
            eprintln!(
                "ued-lint: {} files in {:.3}s ({} cache hit(s), semantic {})",
                report.files,
                watch.elapsed_secs(),
                report.cache_hits,
                if semantic { "on" } else { "off" },
            );
            if ok {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
    }
}
