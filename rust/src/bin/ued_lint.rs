//! `ued-lint` — run the in-repo determinism/unsafety analysis pass over
//! the crate source and fail (exit 1) on any violation.
//!
//! Usage: `cargo run --release --bin ued_lint [-- <src-dir>]`
//!
//! With no argument it lints `src/` relative to the working directory
//! (falling back to the crate's own `src/` when invoked from elsewhere,
//! e.g. the repository root). See `jaxued::analysis` for the rule set,
//! the deterministic-module list, and the allow-comment escape hatch;
//! the README's "Determinism invariants" section is the human-facing
//! summary. CI runs this as a required job.

use std::path::PathBuf;
use std::process::ExitCode;

use jaxued::analysis::{lint_crate, DETERMINISTIC_MODULES};

fn usage() {
    eprintln!("usage: ued_lint [<src-dir>]");
    eprintln!("lints every .rs file under <src-dir> (default: src/)");
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        if arg == "-h" || arg == "--help" {
            usage();
            return ExitCode::SUCCESS;
        }
        if root.is_none() {
            root = Some(PathBuf::from(arg));
        } else {
            eprintln!("ued-lint: unexpected argument `{arg}`");
            usage();
            return ExitCode::from(2);
        }
    }
    let root = root.unwrap_or_else(|| {
        let cwd_src = PathBuf::from("src");
        if cwd_src.is_dir() {
            cwd_src
        } else {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
        }
    });
    if !root.is_dir() {
        eprintln!("ued-lint: source directory `{}` not found", root.display());
        return ExitCode::from(2);
    }

    match lint_crate(&root) {
        Err(e) => {
            eprintln!("ued-lint: i/o error walking `{}`: {e}", root.display());
            ExitCode::from(2)
        }
        Ok(report) if report.violations.is_empty() => {
            println!(
                "ued-lint: clean — {} files under `{}` ({} deterministic modules: {})",
                report.files,
                root.display(),
                DETERMINISTIC_MODULES.len(),
                DETERMINISTIC_MODULES.join(", ")
            );
            ExitCode::SUCCESS
        }
        Ok(report) => {
            for v in &report.violations {
                println!("{v}");
            }
            println!(
                "ued-lint: {} violation(s) in {} files",
                report.violations.len(),
                report.files
            );
            ExitCode::FAILURE
        }
    }
}
