//! `ued-serve` — the batched policy-zoo evaluation server.
//!
//! Usage:
//! `cargo run --release --bin ued_serve -- [--serve-addr 127.0.0.1:8321]
//!  [--env maze] [--zoo-dir runs] [--artifacts artifacts]
//!  [--synthetic-zoo N] [--max-batch B] [--trials T] …`
//!
//! See `jaxued::config::ServeConfig` for every knob and
//! `jaxued::serve` for the architecture. The process runs until SIGINT
//! or SIGTERM, then drains in-flight batches and exits 0.

use std::path::Path;
use std::process::ExitCode;
use std::time::Duration;

use jaxued::config::ServeConfig;
use jaxued::env::registry::{dispatch, EnvVisitor};
use jaxued::env::EnvFamily;
use jaxued::runtime::Runtime;
use jaxued::serve;
use jaxued::util::cli::Args;

struct Launch {
    cfg: ServeConfig,
    runtime: Option<Runtime>,
}

impl EnvVisitor for Launch {
    type Out = anyhow::Result<serve::ServerHandle>;

    fn visit<F: EnvFamily>(self, family: F) -> Self::Out {
        serve::serve(family, self.cfg, self.runtime)
    }
}

fn main() -> ExitCode {
    let args = Args::parse();
    let cfg = match ServeConfig::from_args(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("ued-serve: {e:#}");
            return ExitCode::from(2);
        }
    };
    let unknown = args.unknown_flags();
    if !unknown.is_empty() {
        eprintln!("ued-serve: unknown flag(s): --{}", unknown.join(" --"));
        return ExitCode::from(2);
    }

    serve::install_signal_handlers();

    // Checkpoint-backed policies need compiled apply artifacts; without a
    // manifest the zoo is synthetic-only.
    let artifacts = Path::new(&cfg.artifacts_dir);
    let runtime = if artifacts.join("manifest.json").exists() {
        match Runtime::with_geometry(artifacts, &cfg.env.geometry()) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("ued-serve: failed to open artifacts at {artifacts:?}: {e:#}");
                return ExitCode::from(2);
            }
        }
    } else {
        eprintln!(
            "ued-serve: no artifact manifest at {:?}; serving without a runtime \
             (synthetic policies only)",
            artifacts.join("manifest.json")
        );
        None
    };

    let env = cfg.env;
    let handle = match dispatch(env, Launch { cfg, runtime }) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("ued-serve: {e:#}");
            return ExitCode::from(1);
        }
    };
    println!(
        "ued-serve: listening on http://{} (env {}, zoo of {})",
        handle.addr,
        env.name(),
        handle.catalog.len()
    );

    while !serve::shutdown_requested() {
        std::thread::sleep(Duration::from_millis(50));
    }
    println!("ued-serve: signal received, draining…");
    handle.shutdown_and_join();
    println!("ued-serve: clean shutdown");
    ExitCode::SUCCESS
}
