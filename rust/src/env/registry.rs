//! The environment registry: `--env` selects an [`EnvFamily`] the way
//! `--algo` selects a UED method.
//!
//! Families carry associated types (env, level, generator, mutator,
//! editor), so the registry cannot be a map of trait objects; instead it is
//! the idiomatic Rust equivalent — a closed [`EnvId`] enum plus a visitor
//! [`dispatch`] that re-enters generic code with the statically-known
//! family. Adding an environment is: implement `EnvFamily`, add an `EnvId`
//! variant, extend the two match arms here. No algorithm, rollout, or
//! evaluation code changes.

use anyhow::{bail, Result};

use super::editor::{EditorEnv, EditorState};
use super::gen::MazeLevelGenerator;
use super::holdout::{named_levels, procedural_suite};
use super::lava::{self, LavaEnv, LavaLevel, LavaLevelGenerator, LavaMutator};
use super::level::Level;
use super::maze::MazeEnv;
use super::mutate::MazeMutator;
use super::{EnvFamily, EnvGeometry, EnvParams};

/// Which environment family to run (the `--env` flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnvId {
    /// The paper's 13×13 MiniGrid-style maze.
    Maze,
    /// The lava-grid maze variant (hazard tiles).
    Lava,
}

impl EnvId {
    pub const ALL: [EnvId; 2] = [EnvId::Maze, EnvId::Lava];

    pub fn parse(s: &str) -> Result<EnvId> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "maze" => EnvId::Maze,
            "lava" | "lava_maze" | "lavagrid" => EnvId::Lava,
            other => bail!("unknown env {other:?} (maze|lava)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EnvId::Maze => "maze",
            EnvId::Lava => "lava",
        }
    }

    /// Artifact-name scope: `None` keeps the unprefixed legacy names (the
    /// maze family the artifacts were first compiled for); `Some(p)` makes
    /// the runtime prefer `"{p}_{name}"` over `"{name}"` in the manifest.
    pub fn artifact_prefix(self) -> Option<&'static str> {
        match self {
            EnvId::Maze => None,
            EnvId::Lava => Some("lava"),
        }
    }

    /// The family's artifact geometry, without naming its concrete types.
    pub fn geometry(self) -> EnvGeometry {
        struct G;
        impl EnvVisitor for G {
            type Out = EnvGeometry;
            fn visit<F: EnvFamily>(self, family: F) -> EnvGeometry {
                family.geometry()
            }
        }
        dispatch(self, G)
    }
}

/// Re-enter generic code with the statically-known family for an [`EnvId`].
pub trait EnvVisitor {
    type Out;
    fn visit<F: EnvFamily>(self, family: F) -> Self::Out;
}

/// Run `v` with the family selected by `id`.
pub fn dispatch<V: EnvVisitor>(id: EnvId, v: V) -> V::Out {
    match id {
        EnvId::Maze => v.visit(MazeFamily),
        EnvId::Lava => v.visit(LavaFamily),
    }
}

// ---------------------------------------------------------------------------
// Maze family
// ---------------------------------------------------------------------------

/// Procedural-holdout generation constants shared by both families (the
/// paper's minimax recipe: 60-wall budget, fixed seed).
const HOLDOUT_MAX_WALLS: usize = 60;
const HOLDOUT_SEED: u64 = 0xE7A1;
/// Lava holdout hazard budget (kept modest so rejection sampling stays
/// cheap while the suite still exercises hazard avoidance).
const HOLDOUT_MAX_LAVA: usize = 10;

/// The paper's maze UPOMDP family.
#[derive(Clone, Copy, Debug, Default)]
pub struct MazeFamily;

impl EnvFamily for MazeFamily {
    type Env = MazeEnv;
    type Level = Level;
    type Generator = MazeLevelGenerator;
    type Mutator = MazeMutator;
    type Editor = EditorEnv;

    fn id(&self) -> &'static str {
        "maze"
    }

    fn geometry(&self) -> EnvGeometry {
        EnvGeometry::maze_default()
    }

    fn make_env(&self, p: &EnvParams) -> MazeEnv {
        MazeEnv::new(p.max_episode_steps)
    }

    fn make_generator(&self, p: &EnvParams) -> MazeLevelGenerator {
        MazeLevelGenerator::new(p.max_walls)
    }

    fn make_mutator(&self, p: &EnvParams) -> MazeMutator {
        MazeMutator::new(p.num_edits)
    }

    fn make_editor(&self, p: &EnvParams) -> EditorEnv {
        EditorEnv::new(p.editor_steps)
    }

    fn editor_level(&self, s: &EditorState) -> Level {
        s.to_level()
    }

    fn holdout(&self, n_procedural: usize) -> Vec<(String, Level)> {
        let mut levels: Vec<(String, Level)> = named_levels()
            .into_iter()
            .map(|nl| (nl.name.to_string(), nl.level))
            .collect();
        for (i, l) in procedural_suite(n_procedural, HOLDOUT_MAX_WALLS, HOLDOUT_SEED)
            .into_iter()
            .enumerate()
        {
            levels.push((format!("Proc{i:02}"), l));
        }
        levels
    }
}

// ---------------------------------------------------------------------------
// Lava family
// ---------------------------------------------------------------------------

/// The lava-grid UPOMDP family (hazard tiles; observation geometry shared
/// with the maze so the compiled artifacts serve both).
#[derive(Clone, Copy, Debug, Default)]
pub struct LavaFamily;

impl EnvFamily for LavaFamily {
    type Env = LavaEnv;
    type Level = LavaLevel;
    type Generator = LavaLevelGenerator;
    type Mutator = LavaMutator;
    type Editor = EditorEnv;

    fn id(&self) -> &'static str {
        "lava"
    }

    fn geometry(&self) -> EnvGeometry {
        // Identical to the maze by construction (hazards ride in the
        // obstacle channel at half intensity).
        EnvGeometry::maze_default()
    }

    fn make_env(&self, p: &EnvParams) -> LavaEnv {
        LavaEnv::new(p.max_episode_steps)
    }

    fn make_generator(&self, p: &EnvParams) -> LavaLevelGenerator {
        LavaLevelGenerator::new(p.max_walls, p.max_hazards)
    }

    fn make_mutator(&self, p: &EnvParams) -> LavaMutator {
        LavaMutator::new(p.num_edits)
    }

    fn make_editor(&self, p: &EnvParams) -> EditorEnv {
        EditorEnv::with_palette(p.editor_steps, 3)
    }

    fn editor_level(&self, s: &EditorState) -> LavaLevel {
        LavaLevel::from_editor(s)
    }

    fn holdout(&self, n_procedural: usize) -> Vec<(String, LavaLevel)> {
        lava::holdout_suite(
            n_procedural, HOLDOUT_MAX_WALLS, HOLDOUT_MAX_LAVA, HOLDOUT_SEED,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::conformance::check_family_conformance;

    #[test]
    fn env_id_parse_and_names() {
        assert_eq!(EnvId::parse("maze").unwrap(), EnvId::Maze);
        assert_eq!(EnvId::parse("LAVA").unwrap(), EnvId::Lava);
        assert_eq!(EnvId::parse("lava_maze").unwrap(), EnvId::Lava);
        assert!(EnvId::parse("pong").is_err());
        for id in EnvId::ALL {
            assert_eq!(EnvId::parse(id.name()).unwrap(), id);
        }
    }

    #[test]
    fn artifact_prefixes() {
        assert_eq!(EnvId::Maze.artifact_prefix(), None);
        assert_eq!(EnvId::Lava.artifact_prefix(), Some("lava"));
    }

    #[test]
    fn geometries_share_artifact_shape() {
        // The lava family deliberately matches the maze geometry so one
        // compiled artifact set serves both.
        assert_eq!(EnvId::Maze.geometry(), EnvId::Lava.geometry());
    }

    #[test]
    fn maze_family_passes_conformance() {
        check_family_conformance(MazeFamily, &EnvParams::default(), 100);
    }

    #[test]
    fn lava_family_passes_conformance() {
        check_family_conformance(LavaFamily, &EnvParams::default(), 100);
    }

    #[test]
    fn holdout_suites_nonempty_and_distinctly_named() {
        fn check<F: EnvFamily>(family: F) {
            let suite = family.holdout(10);
            assert!(suite.len() >= 10);
            let mut names: Vec<&String> = suite.iter().map(|(n, _)| n).collect();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), suite.len(), "duplicate holdout names");
        }
        check(MazeFamily);
        check(LavaFamily);
    }
}
