//! Environment wrappers (paper §3.2).
//!
//! `UnderspecifiedEnv` deliberately has no implicit reset distribution, so
//! it cannot auto-reset on episode end. Training wants auto-reset; the two
//! wrappers reintroduce it explicitly, and the choice between them is the
//! §5.2 semantic difference between DR and the PLR family:
//!
//! * [`AutoReplayWrapper`] — reset to *the same level* (PLR-family rollouts:
//!   several episodes on one level sharpen its regret estimate).
//! * [`AutoResetWrapper`] — sample a *new level* from an injected
//!   distribution (DR semantics: trailing episodes continue across update
//!   boundaries like standard RL).
//!
//! Both transform an `UnderspecifiedEnv` into another `UnderspecifiedEnv`,
//! inheriting observation behaviour.

use super::{LevelGenerator, StepResult, UnderspecifiedEnv};
use crate::util::rng::Pcg64;

/// On episode end, re-reset to the level that was just played.
pub struct AutoReplayWrapper<E: UnderspecifiedEnv> {
    pub env: E,
}

/// State pairs the inner state with the level to replay.
#[derive(Debug)]
pub struct ReplayState<E: UnderspecifiedEnv> {
    pub inner: E::State,
    pub level: E::Level,
    /// Episodes completed on this level so far (diagnostics / scoring).
    pub episodes: u32,
}

// Manual impl: derive would demand `E: Clone`, but only the associated
// state/level types need to be cloneable.
impl<E: UnderspecifiedEnv> Clone for ReplayState<E> {
    fn clone(&self) -> Self {
        ReplayState {
            inner: self.inner.clone(),
            level: self.level.clone(),
            episodes: self.episodes,
        }
    }
}

impl<E: UnderspecifiedEnv> AutoReplayWrapper<E> {
    pub fn new(env: E) -> Self {
        AutoReplayWrapper { env }
    }
}

impl<E: UnderspecifiedEnv> UnderspecifiedEnv for AutoReplayWrapper<E> {
    type State = ReplayState<E>;
    type Level = E::Level;

    fn num_actions(&self) -> usize {
        self.env.num_actions()
    }

    fn reset_to_level(&self, level: &Self::Level, rng: &mut Pcg64) -> Self::State {
        ReplayState {
            inner: self.env.reset_to_level(level, rng),
            level: level.clone(),
            episodes: 0,
        }
    }

    fn step(&self, s: &mut Self::State, action: usize, rng: &mut Pcg64) -> StepResult {
        let r = self.env.step(&mut s.inner, action, rng);
        if r.done {
            s.episodes += 1;
            s.inner = self.env.reset_to_level(&s.level, rng);
        }
        r
    }

    fn observe(&self, s: &Self::State, obs: &mut [f32]) {
        self.env.observe(&s.inner, obs)
    }

    fn obs_len(&self) -> usize {
        self.env.obs_len()
    }

    fn obs_components(&self) -> Vec<usize> {
        self.env.obs_components()
    }
}

/// On episode end, sample a fresh level from the injected distribution and
/// reset to it (dependency injection of the level distribution — the
/// wrapper owns a [`LevelGenerator`], not the env; ad-hoc closures fit via
/// [`FnLevelGen`](crate::env::FnLevelGen)).
pub struct AutoResetWrapper<E: UnderspecifiedEnv, G: LevelGenerator<Level = E::Level>> {
    pub env: E,
    pub generator: G,
}

impl<E: UnderspecifiedEnv, G: LevelGenerator<Level = E::Level>> AutoResetWrapper<E, G> {
    pub fn new(env: E, generator: G) -> Self {
        AutoResetWrapper { env, generator }
    }
}

impl<E: UnderspecifiedEnv, G: LevelGenerator<Level = E::Level>> UnderspecifiedEnv
    for AutoResetWrapper<E, G>
{
    type State = E::State;
    type Level = E::Level;

    fn num_actions(&self) -> usize {
        self.env.num_actions()
    }

    fn reset_to_level(&self, level: &Self::Level, rng: &mut Pcg64) -> Self::State {
        self.env.reset_to_level(level, rng)
    }

    fn step(&self, s: &mut Self::State, action: usize, rng: &mut Pcg64) -> StepResult {
        let r = self.env.step(s, action, rng);
        if r.done {
            let level = self.generator.sample_level(rng);
            *s = self.env.reset_to_level(&level, rng);
        }
        r
    }

    fn observe(&self, s: &Self::State, obs: &mut [f32]) {
        self.env.observe(s, obs)
    }

    fn obs_len(&self) -> usize {
        self.env.obs_len()
    }

    fn obs_components(&self) -> Vec<usize> {
        self.env.obs_components()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::gen::MazeLevelGenerator;
    use crate::env::level::{Dir, Level};
    use crate::env::maze::{MazeEnv, ACT_FORWARD};
    use crate::env::FnLevelGen;

    fn short_goal_level() -> Level {
        let mut l = Level::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Right;
        l.goal_pos = (1, 0);
        l
    }

    #[test]
    fn auto_replay_resets_to_same_level() {
        let env = AutoReplayWrapper::new(MazeEnv::default());
        let mut rng = Pcg64::seed_from_u64(0);
        let level = short_goal_level();
        let mut s = env.reset_to_level(&level, &mut rng);
        let r = env.step(&mut s, ACT_FORWARD, &mut rng);
        assert!(r.done && r.reward > 0.0);
        // after auto-replay the inner state is back at the SAME start
        assert_eq!(s.inner.pos, level.agent_pos);
        assert_eq!(s.inner.level, level);
        assert_eq!(s.episodes, 1);
        // and the level is immediately solvable again
        let r2 = env.step(&mut s, ACT_FORWARD, &mut rng);
        assert!(r2.done);
        assert_eq!(s.episodes, 2);
    }

    #[test]
    fn auto_reset_samples_new_level() {
        let gen = MazeLevelGenerator::new(0); // open mazes, always solvable
        let env = AutoResetWrapper::new(MazeEnv::default(), gen);
        let mut rng = Pcg64::seed_from_u64(1);
        let level = short_goal_level();
        let mut s = env.reset_to_level(&level, &mut rng);
        let r = env.step(&mut s, ACT_FORWARD, &mut rng);
        assert!(r.done);
        // state was re-initialized from a *fresh* level (t reset)
        assert_eq!(s.t, 0);
        // overwhelmingly unlikely to be the same 2-cell toy level
        assert_ne!(s.level, level);
    }

    #[test]
    fn auto_reset_accepts_closure_generators() {
        // FnLevelGen adapts an ad-hoc distribution to the trait.
        let fixed = short_goal_level();
        let env = AutoResetWrapper::new(
            MazeEnv::default(),
            FnLevelGen::new(move |_r: &mut Pcg64| fixed),
        );
        let mut rng = Pcg64::seed_from_u64(2);
        let mut s = env.reset_to_level(&fixed, &mut rng);
        let r = env.step(&mut s, ACT_FORWARD, &mut rng);
        assert!(r.done);
        assert_eq!(s.level, fixed, "closure generator resampled the fixed level");
    }

    #[test]
    fn wrappers_preserve_obs_interface() {
        let inner = MazeEnv::default();
        let obs_len = inner.obs_len();
        let comps = inner.obs_components();
        let w = AutoReplayWrapper::new(inner);
        assert_eq!(w.obs_len(), obs_len);
        assert_eq!(w.obs_components(), comps);
    }
}
