//! JIT-free shortest path: BFS distances from the goal to every cell.
//!
//! The paper ships a JIT-compiled all-positions shortest-path routine used
//! for level analysis (solvability filtering of holdout levels, optimal
//! path lengths). Here a plain BFS over the 4-connected free cells runs in
//! O(N) per level (N = 169 cells), beating the paper's O(N²) bound — the
//! paper's version pays for bounded-iteration JAX semantics.

use super::level::{Level, GRID_CELLS, GRID_H, GRID_W};

pub const UNREACHABLE: u16 = u16::MAX;

/// Distance (in moves, ignoring turns) from every cell to the goal.
/// `UNREACHABLE` marks walls and disconnected cells.
#[derive(Clone, Debug)]
pub struct DistanceField {
    pub dist: [u16; GRID_CELLS],
}

impl DistanceField {
    pub fn get(&self, x: usize, y: usize) -> u16 {
        self.dist[y * GRID_W + x]
    }
}

/// BFS from `goal` over the 4-connected cells for which `blocked` is false.
/// The core routine behind every environment's solvability analysis: the
/// maze treats walls as blocked, the lava variant walls *and* hazards.
pub fn distance_field_from(
    goal: (usize, usize), blocked: impl Fn(usize, usize) -> bool,
) -> DistanceField {
    let mut dist = [UNREACHABLE; GRID_CELLS];
    let (gx, gy) = goal;
    let mut queue = [0usize; GRID_CELLS];
    let (mut head, mut tail) = (0usize, 0usize);
    let start = gy * GRID_W + gx;
    dist[start] = 0;
    queue[tail] = start;
    tail += 1;
    while head < tail {
        let cur = queue[head];
        head += 1;
        let (x, y) = (cur % GRID_W, cur / GRID_W);
        let d = dist[cur];
        let push = |nx: usize, ny: usize, dist_arr: &mut [u16; GRID_CELLS],
                        q: &mut [usize; GRID_CELLS], t: &mut usize| {
            let ni = ny * GRID_W + nx;
            if dist_arr[ni] == UNREACHABLE && !blocked(nx, ny) {
                dist_arr[ni] = d + 1;
                q[*t] = ni;
                *t += 1;
            }
        };
        if x > 0 {
            push(x - 1, y, &mut dist, &mut queue, &mut tail);
        }
        if x + 1 < GRID_W {
            push(x + 1, y, &mut dist, &mut queue, &mut tail);
        }
        if y > 0 {
            push(x, y - 1, &mut dist, &mut queue, &mut tail);
        }
        if y + 1 < GRID_H {
            push(x, y + 1, &mut dist, &mut queue, &mut tail);
        }
    }
    DistanceField { dist }
}

/// BFS from the goal over free (non-wall) cells.
pub fn distance_field(level: &Level) -> DistanceField {
    distance_field_from(
        (level.goal_pos.0 as usize, level.goal_pos.1 as usize),
        |x, y| level.wall_at(x, y),
    )
}

/// Moves from the agent start to the goal, or None if unsolvable.
pub fn solve_distance(level: &Level) -> Option<u16> {
    let df = distance_field(level);
    let d = df.get(level.agent_pos.0 as usize, level.agent_pos.1 as usize);
    (d != UNREACHABLE).then_some(d)
}

/// A level is solvable iff a free path start→goal exists.
pub fn is_solvable(level: &Level) -> bool {
    solve_distance(level).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::level::Dir;

    #[test]
    fn open_grid_manhattan() {
        let mut l = Level::empty();
        l.agent_pos = (0, 0);
        l.goal_pos = (12, 12);
        assert_eq!(solve_distance(&l), Some(24));
    }

    #[test]
    fn wall_forces_detour() {
        // Vertical wall at x=6 with a gap at y=12.
        let mut l = Level::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Right;
        l.goal_pos = (12, 0);
        for y in 0..12 {
            l.walls.set(6, y, true);
        }
        // path must go down to y=12 and back: 12 right + 12 down + 12 up = detour
        let d = solve_distance(&l).unwrap();
        assert_eq!(d, 12 + 12 + 12);
    }

    #[test]
    fn sealed_goal_unsolvable() {
        let mut l = Level::empty();
        l.agent_pos = (0, 0);
        l.goal_pos = (6, 6);
        for (dx, dy) in [(-1, 0), (1, 0), (0, -1), (0, 1)] {
            l.walls.set((6 + dx) as usize, (6 + dy) as usize, true);
        }
        assert!(!is_solvable(&l));
    }

    #[test]
    fn goal_cell_distance_zero() {
        let l = Level::empty();
        let df = distance_field(&l);
        assert_eq!(df.get(l.goal_pos.0 as usize, l.goal_pos.1 as usize), 0);
    }

    #[test]
    fn walls_unreachable() {
        let mut l = Level::empty();
        l.walls.set(4, 4, true);
        let df = distance_field(&l);
        assert_eq!(df.get(4, 4), UNREACHABLE);
    }

    #[test]
    fn distances_monotone_neighbors() {
        // every free cell with finite distance has a neighbor one closer
        let mut l = Level::empty();
        for i in 0..10 {
            l.walls.set(1 + i % 11, (i * 3) % 13, true);
        }
        l.walls.set(
            l.agent_pos.0 as usize + 1, l.agent_pos.1 as usize, false,
        );
        let df = distance_field(&l);
        for y in 0..GRID_H {
            for x in 0..GRID_W {
                let d = df.get(x, y);
                if d == UNREACHABLE || d == 0 {
                    continue;
                }
                let mut best = UNREACHABLE;
                if x > 0 {
                    best = best.min(df.get(x - 1, y));
                }
                if x + 1 < GRID_W {
                    best = best.min(df.get(x + 1, y));
                }
                if y > 0 {
                    best = best.min(df.get(x, y - 1));
                }
                if y + 1 < GRID_H {
                    best = best.min(df.get(x, y + 1));
                }
                assert_eq!(best, d - 1, "cell ({x},{y})");
            }
        }
    }
}
