//! Level and trajectory rendering (paper §4 "Efficient rendering").
//!
//! Produces RGB images (binary PPM, viewable everywhere, zero deps):
//! single levels, holdout montages (Figure 2), and step-by-step trajectory
//! frame sequences. The palette follows MiniGrid: grey walls, dark floor,
//! green goal, red agent triangle.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use super::level::{Dir, Level, GRID_H, GRID_W};
use super::maze::MazeState;

/// Pixels per grid cell.
pub const CELL_PX: usize = 8;

const FLOOR: [u8; 3] = [28, 28, 28];
const WALL: [u8; 3] = [120, 120, 120];
const GOAL: [u8; 3] = [40, 160, 40];
const AGENT: [u8; 3] = [200, 40, 40];
const GRIDLINE: [u8; 3] = [46, 46, 46];

/// A simple owned RGB image.
#[derive(Clone, Debug)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub data: Vec<u8>, // RGB, row-major
}

impl Image {
    pub fn new(width: usize, height: usize) -> Image {
        Image { width, height, data: vec![0; width * height * 3] }
    }

    #[inline]
    pub fn put(&mut self, x: usize, y: usize, c: [u8; 3]) {
        debug_assert!(x < self.width && y < self.height);
        let i = (y * self.width + x) * 3;
        self.data[i..i + 3].copy_from_slice(&c);
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> [u8; 3] {
        let i = (y * self.width + x) * 3;
        [self.data[i], self.data[i + 1], self.data[i + 2]]
    }

    pub fn fill_rect(&mut self, x0: usize, y0: usize, w: usize, h: usize, c: [u8; 3]) {
        for y in y0..(y0 + h).min(self.height) {
            for x in x0..(x0 + w).min(self.width) {
                self.put(x, y, c);
            }
        }
    }

    /// Write as binary PPM (P6).
    pub fn write_ppm(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        write!(f, "P6\n{} {}\n255\n", self.width, self.height)?;
        f.write_all(&self.data)?;
        Ok(())
    }
}

fn draw_cell(img: &mut Image, cx: usize, cy: usize, color: [u8; 3], ox: usize, oy: usize) {
    img.fill_rect(ox + cx * CELL_PX, oy + cy * CELL_PX, CELL_PX, CELL_PX, color);
}

/// Draw the agent as a direction-indicating triangle inside its cell.
fn draw_agent(img: &mut Image, cx: usize, cy: usize, dir: Dir, ox: usize, oy: usize) {
    let x0 = ox + cx * CELL_PX;
    let y0 = oy + cy * CELL_PX;
    let n = CELL_PX;
    for py in 0..n {
        for px in 0..n {
            // Triangle pointing up in local coords, then rotate by dir.
            let (tx, ty) = match dir {
                Dir::Up => (px, py),
                Dir::Right => (n - 1 - py, px),
                Dir::Down => (n - 1 - px, n - 1 - py),
                Dir::Left => (py, n - 1 - px),
            };
            // up-pointing triangle: widens with ty
            let half_width = ty / 2 + 1;
            let mid = n / 2;
            let inside = tx + half_width > mid && tx < mid + half_width && ty >= 1 && ty < n - 1;
            if inside {
                img.put(x0 + px, y0 + py, AGENT);
            }
        }
    }
}

/// Render a single level (optionally with the agent at a live state
/// position rather than its start).
pub fn render_level(level: &Level, state: Option<&MazeState>) -> Image {
    let mut img = Image::new(GRID_W * CELL_PX, GRID_H * CELL_PX);
    for y in 0..GRID_H {
        for x in 0..GRID_W {
            let c = if level.wall_at(x, y) { WALL } else { FLOOR };
            draw_cell(&mut img, x, y, c, 0, 0);
            // 1px gridline at cell borders for readability
            for i in 0..CELL_PX {
                img.put(x * CELL_PX, y * CELL_PX + i, GRIDLINE);
                img.put(x * CELL_PX + i, y * CELL_PX, GRIDLINE);
            }
        }
    }
    let (gx, gy) = (level.goal_pos.0 as usize, level.goal_pos.1 as usize);
    draw_cell(&mut img, gx, gy, GOAL, 0, 0);
    let (pos, dir) = match state {
        Some(s) => (s.pos, s.dir),
        None => (level.agent_pos, level.agent_dir),
    };
    draw_agent(&mut img, pos.0 as usize, pos.1 as usize, dir, 0, 0);
    img
}

/// Render a batch of levels as a `cols`-wide montage with 2px separators
/// (Figure 2 style).
pub fn render_montage(levels: &[Level], cols: usize) -> Image {
    assert!(cols > 0 && !levels.is_empty());
    let rows = levels.len().div_ceil(cols);
    let sep = 2;
    let tile_w = GRID_W * CELL_PX;
    let tile_h = GRID_H * CELL_PX;
    let mut img = Image::new(
        cols * tile_w + (cols - 1) * sep,
        rows * tile_h + (rows - 1) * sep,
    );
    // white background separators
    img.data.fill(255);
    for (i, level) in levels.iter().enumerate() {
        let tile = render_level(level, None);
        let ox = (i % cols) * (tile_w + sep);
        let oy = (i / cols) * (tile_h + sep);
        for y in 0..tile_h {
            for x in 0..tile_w {
                img.put(ox + x, oy + y, tile.get(x, y));
            }
        }
    }
    img
}

/// Render a trajectory as numbered PPM frames in `dir`.
pub fn render_trajectory(
    level: &Level, states: &[MazeState], dir: &Path, prefix: &str,
) -> Result<Vec<std::path::PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut paths = Vec::with_capacity(states.len());
    for (i, s) in states.iter().enumerate() {
        let img = render_level(level, Some(s));
        let p = dir.join(format!("{prefix}_{i:04}.ppm"));
        img.write_ppm(&p)?;
        paths.push(p);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::gen::MazeLevelGenerator;
    use crate::util::rng::Pcg64;

    #[test]
    fn image_dimensions() {
        let l = Level::empty();
        let img = render_level(&l, None);
        assert_eq!(img.width, GRID_W * CELL_PX);
        assert_eq!(img.height, GRID_H * CELL_PX);
        assert_eq!(img.data.len(), img.width * img.height * 3);
    }

    #[test]
    fn goal_and_wall_pixels_colored() {
        let mut l = Level::empty();
        l.walls.set(5, 5, true);
        l.goal_pos = (7, 7);
        l.agent_pos = (1, 1);
        let img = render_level(&l, None);
        let center = |c: usize| c * CELL_PX + CELL_PX / 2;
        assert_eq!(img.get(center(5), center(5)), WALL);
        assert_eq!(img.get(center(7), center(7)), GOAL);
        assert_eq!(img.get(center(1), center(1)), AGENT);
        assert_eq!(img.get(center(3), center(3)), FLOOR);
    }

    #[test]
    fn agent_triangle_rotates() {
        let mut l = Level::empty();
        l.agent_pos = (6, 6);
        let imgs: Vec<Image> = crate::env::level::Dir::ALL
            .iter()
            .map(|&d| {
                let mut lv = l;
                lv.agent_dir = d;
                render_level(&lv, None)
            })
            .collect();
        // the four renderings must differ pairwise
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_ne!(imgs[i].data, imgs[j].data, "dirs {i} vs {j}");
            }
        }
    }

    #[test]
    fn montage_shape() {
        let g = MazeLevelGenerator::new(30);
        let mut rng = Pcg64::seed_from_u64(0);
        let levels = g.generate_batch(10, &mut rng);
        let img = render_montage(&levels, 4);
        let tile = GRID_W * CELL_PX;
        assert_eq!(img.width, 4 * tile + 3 * 2);
        assert_eq!(img.height, 3 * (GRID_H * CELL_PX) + 2 * 2);
    }

    #[test]
    fn ppm_roundtrip_header() {
        let l = Level::empty();
        let img = render_level(&l, None);
        let dir = std::env::temp_dir().join("jaxued_render_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.ppm");
        img.write_ppm(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let header = format!("P6\n{} {}\n255\n", img.width, img.height);
        assert!(bytes.starts_with(header.as_bytes()));
        assert_eq!(bytes.len(), header.len() + img.data.len());
    }
}
