//! Reusable env-trait conformance suite.
//!
//! Every [`EnvFamily`] must uphold the same contract for the generic
//! training stack to be correct: deterministic `reset_to_level` under a
//! fixed RNG, `observe` writing exactly `obs_len` values, `obs_components`
//! summing to `obs_len`, generators emitting structurally valid levels,
//! mutation preserving validity, round-trippable level encodings, and an
//! editor whose finished episodes yield valid levels. The suite is plain
//! library code (not test-gated) so unit tests, integration tests, and
//! future env PRs can all run it against any family:
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the xla rpath link flag
//! use jaxued::env::conformance::check_family_conformance;
//! use jaxued::env::{EnvParams, MazeFamily};
//! check_family_conformance(MazeFamily, &EnvParams::default(), 100);
//! ```

use super::editor::EditorTask;
use super::{
    EnvFamily, EnvParams, LevelGenerator, LevelMeta, LevelMutator, UnderspecifiedEnv,
};
use crate::util::rng::Pcg64;

/// Sentinel poured into observation buffers to detect unwritten slots.
const SENTINEL: f32 = -7_777.25;

/// Run the full conformance suite against `family` with `cases` sampled
/// levels. Panics (with a labelled message) on the first violation.
// ued-lint: allow(rng-lineage) — the harness constructs identical seeded streams on purpose: resetting/stepping twice from the same key is how it proves the family deterministic
pub fn check_family_conformance<F: EnvFamily>(family: F, params: &EnvParams, cases: usize) {
    let id = family.id();
    let env = family.make_env(params);
    let gen = family.make_generator(params);
    let mutator = family.make_mutator(params);

    // -- observation geometry ------------------------------------------------
    let comps = env.obs_components();
    assert!(!comps.is_empty(), "[{id}] obs_components empty");
    assert_eq!(
        comps.iter().sum::<usize>(),
        env.obs_len(),
        "[{id}] obs_components must sum to obs_len"
    );
    assert!(env.num_actions() > 0, "[{id}] num_actions must be positive");

    let mut rng = Pcg64::new(0xC0FF_EE00, 1);
    for case in 0..cases {
        // -- generator contract ----------------------------------------------
        let level = gen.sample_level(&mut rng);
        assert!(level.is_valid(), "[{id}] case {case}: generated level invalid");
        assert!(
            level.complexity() >= 0.0,
            "[{id}] case {case}: negative complexity"
        );

        // -- encoding round-trip + fingerprint stability ---------------------
        let bytes = level.encode();
        let back = <F::Level as LevelMeta>::decode(&bytes)
            .unwrap_or_else(|e| panic!("[{id}] case {case}: decode failed: {e}"));
        assert_eq!(
            back.encode(),
            bytes,
            "[{id}] case {case}: encode/decode not a round-trip"
        );
        assert_eq!(
            back.fingerprint(),
            level.fingerprint(),
            "[{id}] case {case}: fingerprint unstable across encode/decode"
        );

        // -- deterministic reset under a fixed RNG ---------------------------
        let seed = 0xAB00 + case as u64;
        let sa = env.reset_to_level(&level, &mut Pcg64::seed_from_u64(seed));
        let sb = env.reset_to_level(&level, &mut Pcg64::seed_from_u64(seed));
        let mut oa = vec![SENTINEL; env.obs_len()];
        let mut ob = vec![SENTINEL; env.obs_len()];
        env.observe(&sa, &mut oa);
        env.observe(&sb, &mut ob);
        assert_eq!(oa, ob, "[{id}] case {case}: reset_to_level not deterministic");

        // -- observe fills exactly obs_len -----------------------------------
        assert!(
            oa.iter().all(|&v| v != SENTINEL),
            "[{id}] case {case}: observe left unwritten slots"
        );
        assert!(
            oa.iter().all(|v| v.is_finite()),
            "[{id}] case {case}: non-finite observation values"
        );

        // -- stepping is RNG-deterministic and observation stays well-formed -
        let mut s1 = env.reset_to_level(&level, &mut Pcg64::seed_from_u64(seed));
        let mut s2 = env.reset_to_level(&level, &mut Pcg64::seed_from_u64(seed));
        let mut r1 = Pcg64::seed_from_u64(seed ^ 0x51E9);
        let mut r2 = Pcg64::seed_from_u64(seed ^ 0x51E9);
        for step in 0..8 {
            let action = (case + step) % env.num_actions();
            let t1 = env.step(&mut s1, action, &mut r1);
            let t2 = env.step(&mut s2, action, &mut r2);
            assert_eq!(t1, t2, "[{id}] case {case}: step not deterministic");
            assert!(t1.reward.is_finite(), "[{id}] case {case}: non-finite reward");
            if t1.done {
                break;
            }
        }
        oa.fill(SENTINEL);
        env.observe(&s1, &mut oa);
        assert!(
            oa.iter().all(|&v| v != SENTINEL && v.is_finite()),
            "[{id}] case {case}: post-step observation ill-formed"
        );

        // -- mutation preserves validity -------------------------------------
        let child = mutator.mutate_level(&level, &mut rng);
        assert!(
            child.is_valid(),
            "[{id}] case {case}: mutation produced an invalid level"
        );
    }

    // -- solvable levels exist in the base distribution ----------------------
    let mut rng = Pcg64::new(0xC0FF_EE01, 2);
    let solvable = (0..200)
        .filter(|_| gen.sample_level(&mut rng).is_solvable())
        .count();
    assert!(
        solvable > 0,
        "[{id}] base distribution produced no solvable level in 200 draws"
    );

    // -- editor episodes yield valid levels ----------------------------------
    check_editor_conformance(family, params, (cases / 4).max(4));

    // -- holdout suite is valid and solvable ---------------------------------
    for (name, level) in family.holdout(8) {
        assert!(level.is_valid(), "[{id}] holdout {name} invalid");
        assert!(level.is_solvable(), "[{id}] holdout {name} unsolvable");
    }
}

/// Decode hardening sub-suite: `LevelMeta::decode` is a trust boundary (the
/// serving layer feeds it raw network bytes), so it must never panic or
/// index out of bounds on hostile input, and any `Ok` level must (a) be
/// canonical — re-encoding reproduces the input bytes exactly — and (b) be
/// safe to interrogate and, when structurally valid, to reset and observe.
pub fn check_decode_hardening<F: EnvFamily>(family: F, params: &EnvParams, cases: usize) {
    let id = family.id();
    let env = family.make_env(params);
    let gen = family.make_generator(params);
    let mut rng = Pcg64::new(0xDEC0_DE00, 4);
    let canon_len = gen.sample_level(&mut rng).encode().len();

    let probe = |label: &str, case: usize, bytes: &[u8]| {
        if let Ok(l) = <F::Level as LevelMeta>::decode(bytes) {
            assert_eq!(
                l.encode(),
                bytes,
                "[{id}] {label} case {case}: Ok decode is not canonical"
            );
            // Interrogating a decoded level must be safe regardless of
            // validity; a valid one must additionally survive reset/observe
            // (this is what a served eval request will do with it).
            let _ = l.complexity();
            let _ = l.fingerprint();
            if l.is_valid() {
                let _ = l.is_solvable();
                let s = env.reset_to_level(&l, &mut Pcg64::seed_from_u64(case as u64));
                let mut obs = vec![SENTINEL; env.obs_len()];
                env.observe(&s, &mut obs);
                assert!(
                    obs.iter().all(|&v| v != SENTINEL && v.is_finite()),
                    "[{id}] {label} case {case}: decoded level observes ill-formed"
                );
            }
        }
    };

    for case in 0..cases {
        // Arbitrary bytes at arbitrary lengths: must never panic.
        let n = rng.gen_range(2 * canon_len + 2);
        let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        probe("junk", case, &junk);

        // Single-bit corruptions of a genuine encoding: the nastiest
        // near-valid inputs. Err or canonical-and-safe Ok, nothing else.
        let mut enc = gen.sample_level(&mut rng).encode();
        let bit = rng.gen_range(enc.len() * 8);
        enc[bit / 8] ^= 1 << (bit % 8);
        probe("bitflip", case, &enc);

        // Truncations of a genuine encoding must always be rejected.
        let keep = rng.gen_range(canon_len);
        assert!(
            <F::Level as LevelMeta>::decode(&enc[..keep]).is_err(),
            "[{id}] case {case}: truncated encoding ({keep} bytes) decoded Ok"
        );
    }
}

/// Editor sub-suite: random full episodes must produce valid levels, and
/// the editor's observation geometry must be internally consistent.
pub fn check_editor_conformance<F: EnvFamily>(family: F, params: &EnvParams, episodes: usize) {
    let id = family.id();
    let editor = family.make_editor(params);
    assert_eq!(
        editor.obs_components().iter().sum::<usize>(),
        editor.obs_len(),
        "[{id}] editor obs_components must sum to obs_len"
    );
    let mut rng = Pcg64::new(0xC0FF_EE02, 3);
    for ep in 0..episodes {
        let task = EditorTask::sample(&mut rng);
        let mut s = editor.reset_to_level(&task, &mut rng);
        let mut obs = vec![SENTINEL; editor.obs_len()];
        loop {
            editor.observe(&s, &mut obs);
            assert!(
                obs.iter().all(|&v| v != SENTINEL && v.is_finite()),
                "[{id}] editor ep {ep}: ill-formed observation"
            );
            let action = rng.gen_range(editor.num_actions());
            if editor.step(&mut s, action, &mut rng).done {
                break;
            }
        }
        let level = family.editor_level(&s);
        assert!(
            level.is_valid(),
            "[{id}] editor ep {ep}: finished episode yielded an invalid level"
        );
    }
}
