//! The lava-grid maze: the second `UnderspecifiedEnv` family, proving the
//! training stack is env-generic (every algorithm runs on it with zero
//! algorithm-code changes — only `--env lava`).
//!
//! Semantics extend the maze with hazard tiles:
//!   * actions: 0 = turn left, 1 = turn right, 2 = move forward (as maze)
//!   * forward into a wall or out of bounds is a no-op
//!   * forward *into lava* moves the agent and terminates the episode with
//!     zero reward — hazards are traversable but fatal, so the optimal
//!     policy must path around them rather than being physically blocked
//!   * reaching the goal terminates with reward `1 − 0.9·t/T_max`
//!   * episodes truncate (done, zero reward) at `T_max` steps
//!   * observation: identical geometry to the maze (egocentric 5×5 crop,
//!     channels {obstacle, goal, out-of-bounds} + facing one-hot). Lava
//!     renders at [`LAVA_INTENSITY`] in the obstacle channel (walls at
//!     1.0), keeping the flat observation length — and therefore the
//!     compiled policy artifacts — shared with the maze family.
//!
//! Levels carry *distinct parameters*: a wall set, a disjoint lava set,
//! agent start, and goal. Their byte encoding is 53 bytes (the maze's 29
//! plus three lava words).

use anyhow::{bail, Result};

use super::level::{Dir, Level, WallSet, GRID_CELLS, GRID_H, GRID_W};
use super::maze::{DIR_LEN, IMG_LEN, NUM_ACTIONS, OBS_CHANNELS, OBS_LEN, VIEW};
use super::shortest_path::{distance_field_from, UNREACHABLE};
use super::{editor::EditorState, LevelGenerator, LevelMeta, LevelMutator};
use super::{StepResult, UnderspecifiedEnv};
use crate::util::rng::Pcg64;

/// Lava intensity in the obstacle observation channel (walls are 1.0).
pub const LAVA_INTENSITY: f32 = 0.5;

/// Byte length of the [`LavaLevel`] encoding.
pub const LAVA_LEVEL_BYTES: usize = 53;

/// A lava level θ: walls + hazards + agent start + goal. Walls and lava
/// are disjoint tile sets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LavaLevel {
    pub walls: WallSet,
    pub lava: WallSet,
    pub agent_pos: (u8, u8),
    pub agent_dir: Dir,
    pub goal_pos: (u8, u8),
}

impl LavaLevel {
    /// An empty level with agent at top-left facing right, goal
    /// bottom-right, no hazards.
    pub fn empty() -> LavaLevel {
        let base = Level::empty();
        LavaLevel {
            walls: base.walls,
            lava: WallSet::empty(),
            agent_pos: base.agent_pos,
            agent_dir: base.agent_dir,
            goal_pos: base.goal_pos,
        }
    }

    pub fn wall_at(&self, x: usize, y: usize) -> bool {
        self.walls.get(x, y)
    }

    pub fn lava_at(&self, x: usize, y: usize) -> bool {
        self.lava.get(x, y)
    }

    pub fn num_walls(&self) -> usize {
        self.walls.count()
    }

    pub fn num_lava(&self) -> usize {
        self.lava.count()
    }

    /// Structural validity: agent/goal distinct, in bounds, on neither
    /// walls nor lava; wall and lava sets disjoint.
    pub fn is_valid(&self) -> bool {
        let (ax, ay) = (self.agent_pos.0 as usize, self.agent_pos.1 as usize);
        let (gx, gy) = (self.goal_pos.0 as usize, self.goal_pos.1 as usize);
        if !(ax < GRID_W && ay < GRID_H && gx < GRID_W && gy < GRID_H) {
            return false;
        }
        if self.agent_pos == self.goal_pos {
            return false;
        }
        for &(x, y) in &[(ax, ay), (gx, gy)] {
            if self.walls.get(x, y) || self.lava.get(x, y) {
                return false;
            }
        }
        // Disjointness of the tile sets.
        for y in 0..GRID_H {
            for x in 0..GRID_W {
                if self.walls.get(x, y) && self.lava.get(x, y) {
                    return false;
                }
            }
        }
        true
    }

    /// A safe path start→goal exists (lava counts as blocked: entering it
    /// ends the episode unrewarded).
    pub fn is_solvable(&self) -> bool {
        self.solve_distance().is_some()
    }

    /// Moves along the shortest safe path, or None if unsolvable.
    pub fn solve_distance(&self) -> Option<u16> {
        let df = distance_field_from(
            (self.goal_pos.0 as usize, self.goal_pos.1 as usize),
            |x, y| self.walls.get(x, y) || self.lava.get(x, y),
        );
        let d = df.get(self.agent_pos.0 as usize, self.agent_pos.1 as usize);
        (d != UNREACHABLE).then_some(d)
    }

    /// FNV-1a hash over the canonical byte encoding.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.to_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    }

    /// Binary encoding (fixed 53 bytes) for checkpoints and the buffer.
    pub fn to_bytes(&self) -> [u8; LAVA_LEVEL_BYTES] {
        let mut out = [0u8; LAVA_LEVEL_BYTES];
        let w = self.walls.words();
        let l = self.lava.words();
        for (i, word) in w.iter().chain(l.iter()).enumerate() {
            out[i * 8..(i + 1) * 8].copy_from_slice(&word.to_le_bytes());
        }
        out[48] = self.agent_pos.0;
        out[49] = self.agent_pos.1;
        out[50] = self.agent_dir.index() as u8;
        out[51] = self.goal_pos.0;
        out[52] = self.goal_pos.1;
        out
    }

    /// Decode the fixed 53-byte encoding. Like `Level::from_bytes` this is
    /// a trust boundary: stray bits in either tile plane, out-of-bounds
    /// positions, and direction bytes >= 4 are rejected (previously stray
    /// bits were silently dropped, so `Ok` did not imply a canonical
    /// round-trip). `Ok(l)` guarantees `l.to_bytes() == input`.
    pub fn from_bytes(b: &[u8]) -> Result<LavaLevel> {
        if b.len() != LAVA_LEVEL_BYTES {
            bail!("lava level encoding must be {LAVA_LEVEL_BYTES} bytes, got {}", b.len());
        }
        let word = |i: usize| u64::from_le_bytes(b[i * 8..(i + 1) * 8].try_into().unwrap());
        let walls = WallSet::from_words([word(0), word(1), word(2)])?;
        let lava = WallSet::from_words([word(3), word(4), word(5)])?;
        for (what, x, y) in [("agent", b[48], b[49]), ("goal", b[51], b[52])] {
            if x as usize >= GRID_W || y as usize >= GRID_H {
                bail!("{what} position ({x},{y}) out of the {GRID_W}x{GRID_H} grid");
            }
        }
        if b[50] >= 4 {
            bail!("direction byte {} out of range (expected 0..=3)", b[50]);
        }
        Ok(LavaLevel {
            walls,
            lava,
            agent_pos: (b[48], b[49]),
            agent_dir: Dir::from_index(b[50] as usize),
            goal_pos: (b[51], b[52]),
        })
    }

    /// Extract from a finished editor episode (three-tile palette): walls
    /// and hazards from the tile sets, agent/goal cells force-cleared.
    pub fn from_editor(s: &EditorState) -> LavaLevel {
        let ((apos, adir), gpos) = s.placements();
        let mut walls = s.walls;
        let mut lava = s.hazards;
        for &(x, y) in &[
            (apos.0 as usize, apos.1 as usize),
            (gpos.0 as usize, gpos.1 as usize),
        ] {
            walls.set(x, y, false);
            lava.set(x, y, false);
        }
        LavaLevel { walls, lava, agent_pos: apos, agent_dir: adir, goal_pos: gpos }
    }
}

impl LevelMeta for LavaLevel {
    fn is_valid(&self) -> bool {
        LavaLevel::is_valid(self)
    }

    fn is_solvable(&self) -> bool {
        LavaLevel::is_solvable(self)
    }

    fn complexity(&self) -> f64 {
        // Hazards weigh double: they constrain paths *and* punish errors.
        self.num_walls() as f64 + 2.0 * self.num_lava() as f64
    }

    fn fingerprint(&self) -> u64 {
        LavaLevel::fingerprint(self)
    }

    fn encode(&self) -> Vec<u8> {
        self.to_bytes().to_vec()
    }

    fn decode(bytes: &[u8]) -> Result<LavaLevel> {
        LavaLevel::from_bytes(bytes)
    }
}

/// Full environment state (level embedded by value, as in the maze).
#[derive(Clone, Debug)]
pub struct LavaState {
    pub level: LavaLevel,
    pub pos: (u8, u8),
    pub dir: Dir,
    pub t: u32,
}

impl LavaState {
    pub fn at_goal(&self) -> bool {
        self.pos == self.level.goal_pos
    }

    pub fn in_lava(&self) -> bool {
        self.level.lava_at(self.pos.0 as usize, self.pos.1 as usize)
    }
}

/// The lava-grid UPOMDP.
#[derive(Clone, Debug)]
pub struct LavaEnv {
    pub max_steps: usize,
}

impl Default for LavaEnv {
    fn default() -> Self {
        LavaEnv { max_steps: super::maze::DEFAULT_MAX_STEPS }
    }
}

impl LavaEnv {
    pub fn new(max_steps: usize) -> Self {
        LavaEnv { max_steps }
    }

    #[inline]
    fn goal_reward(&self, t: u32) -> f32 {
        1.0 - 0.9 * (t as f32 / self.max_steps as f32)
    }
}

impl UnderspecifiedEnv for LavaEnv {
    type State = LavaState;
    type Level = LavaLevel;

    fn num_actions(&self) -> usize {
        NUM_ACTIONS
    }

    fn reset_to_level(&self, level: &LavaLevel, _rng: &mut Pcg64) -> LavaState {
        debug_assert!(level.is_valid(), "reset to invalid lava level");
        LavaState {
            level: *level,
            pos: level.agent_pos,
            dir: level.agent_dir,
            t: 0,
        }
    }

    fn step(&self, s: &mut LavaState, action: usize, _rng: &mut Pcg64) -> StepResult {
        s.t += 1;
        match action {
            super::maze::ACT_LEFT => s.dir = s.dir.turn_left(),
            super::maze::ACT_RIGHT => s.dir = s.dir.turn_right(),
            super::maze::ACT_FORWARD => {
                let (dx, dy) = s.dir.delta();
                let nx = s.pos.0 as isize + dx;
                let ny = s.pos.1 as isize + dy;
                if nx >= 0
                    && ny >= 0
                    && (nx as usize) < GRID_W
                    && (ny as usize) < GRID_H
                    && !s.level.wall_at(nx as usize, ny as usize)
                {
                    s.pos = (nx as u8, ny as u8);
                }
            }
            // ued-lint: allow(serve-panic) — actions come from policy argmax over num_actions; an out-of-range action is engine corruption, not client input
            a => panic!("invalid lava-grid action {a}"),
        }
        if s.in_lava() {
            return StepResult { reward: 0.0, done: true };
        }
        if s.at_goal() {
            return StepResult { reward: self.goal_reward(s.t), done: true };
        }
        if s.t as usize >= self.max_steps {
            return StepResult { reward: 0.0, done: true };
        }
        StepResult { reward: 0.0, done: false }
    }

    fn observe(&self, s: &LavaState, obs: &mut [f32]) {
        debug_assert_eq!(obs.len(), OBS_LEN);
        obs.fill(0.0);
        let (ax, ay) = (s.pos.0 as isize, s.pos.1 as isize);
        let half = (VIEW / 2) as isize;
        for vy in 0..VIEW {
            let f = (VIEW - 1 - vy) as isize;
            for vx in 0..VIEW {
                let l = vx as isize - half;
                let (dx, dy) = match s.dir {
                    Dir::Up => (l, -f),
                    Dir::Right => (f, l),
                    Dir::Down => (-l, f),
                    Dir::Left => (-f, -l),
                };
                let (wx, wy) = (ax + dx, ay + dy);
                let base = (vy * VIEW + vx) * OBS_CHANNELS;
                if wx < 0 || wy < 0 || wx >= GRID_W as isize || wy >= GRID_H as isize {
                    obs[base] = 1.0; // out-of-bounds reads as wall…
                    obs[base + 2] = 1.0; // …and is marked oob
                } else {
                    let (wx, wy) = (wx as usize, wy as usize);
                    if s.level.wall_at(wx, wy) {
                        obs[base] = 1.0;
                    } else if s.level.lava_at(wx, wy) {
                        obs[base] = LAVA_INTENSITY;
                    }
                    if (wx as u8, wy as u8) == s.level.goal_pos {
                        obs[base + 1] = 1.0;
                    }
                }
            }
        }
        obs[IMG_LEN + s.dir.index()] = 1.0;
    }

    fn obs_len(&self) -> usize {
        OBS_LEN
    }

    fn obs_components(&self) -> Vec<usize> {
        vec![IMG_LEN, DIR_LEN]
    }
}

/// Base-distribution parameters: independent wall and lava budgets.
#[derive(Clone, Copy, Debug)]
pub struct LavaLevelGenerator {
    pub max_walls: usize,
    pub max_lava: usize,
}

impl LavaLevelGenerator {
    pub fn new(max_walls: usize, max_lava: usize) -> Self {
        assert!(
            max_walls + max_lava <= GRID_CELLS - 2,
            "must leave room for agent+goal"
        );
        LavaLevelGenerator { max_walls, max_lava }
    }

    /// One draw: wall count ~ U[0, max_walls], lava count ~ U[0, max_lava],
    /// all tiles plus agent and goal on distinct cells. Structurally valid;
    /// solvability not guaranteed (same DR contract as the maze).
    pub fn generate(&self, rng: &mut Pcg64) -> LavaLevel {
        let n_walls = rng.gen_range(self.max_walls + 1);
        let n_lava = rng.gen_range(self.max_lava + 1);
        let cells = rng.sample_indices(GRID_CELLS, n_walls + n_lava + 2);
        let mut walls = WallSet::empty();
        let mut lava = WallSet::empty();
        for &c in &cells[..n_walls] {
            walls.set(c % GRID_W, c / GRID_W, true);
        }
        for &c in &cells[n_walls..n_walls + n_lava] {
            lava.set(c % GRID_W, c / GRID_W, true);
        }
        let g = cells[n_walls + n_lava];
        let a = cells[n_walls + n_lava + 1];
        LavaLevel {
            walls,
            lava,
            agent_pos: ((a % GRID_W) as u8, (a / GRID_W) as u8),
            agent_dir: Dir::from_index(rng.gen_range(4)),
            goal_pos: ((g % GRID_W) as u8, (g / GRID_W) as u8),
        }
    }

    /// Rejection-sample a solvable level (evaluation suites).
    pub fn generate_solvable(&self, rng: &mut Pcg64, max_tries: usize) -> LavaLevel {
        for _ in 0..max_tries {
            let l = self.generate(rng);
            if l.is_solvable() {
                return l;
            }
        }
        panic!(
            "no solvable lava level in {max_tries} tries (walls={}, lava={})",
            self.max_walls, self.max_lava
        );
    }
}

impl LevelGenerator for LavaLevelGenerator {
    type Level = LavaLevel;

    fn sample_level(&self, rng: &mut Pcg64) -> LavaLevel {
        self.generate(rng)
    }
}

/// ACCEL edit operator for lava levels: toggle a wall, toggle a lava tile,
/// relocate the goal, or relocate the agent. Edits preserve tile
/// disjointness and structural validity.
#[derive(Clone, Copy, Debug)]
pub struct LavaMutator {
    pub num_edits: usize,
    /// Probability an edit toggles a wall.
    pub p_wall: f64,
    /// Probability an edit toggles a lava tile (remainder splits evenly
    /// between moving the goal and moving the agent).
    pub p_lava: f64,
}

impl Default for LavaMutator {
    fn default() -> Self {
        LavaMutator { num_edits: 20, p_wall: 0.6, p_lava: 0.2 }
    }
}

impl LavaMutator {
    pub fn new(num_edits: usize) -> Self {
        LavaMutator { num_edits, ..Default::default() }
    }

    /// Apply one random edit in place.
    pub fn edit(&self, level: &mut LavaLevel, rng: &mut Pcg64) {
        let u = rng.next_f64();
        let p_move = (1.0 - self.p_wall - self.p_lava) / 2.0;
        if u < self.p_wall {
            // Toggle a wall on a non-agent, non-goal, non-lava cell.
            loop {
                let c = rng.gen_range(GRID_CELLS);
                let (x, y) = (c % GRID_W, c / GRID_W);
                let pos = (x as u8, y as u8);
                if pos != level.agent_pos && pos != level.goal_pos && !level.lava.get(x, y) {
                    level.walls.toggle(x, y);
                    break;
                }
            }
        } else if u < self.p_wall + self.p_lava {
            // Toggle lava on a non-agent, non-goal, non-wall cell.
            loop {
                let c = rng.gen_range(GRID_CELLS);
                let (x, y) = (c % GRID_W, c / GRID_W);
                let pos = (x as u8, y as u8);
                if pos != level.agent_pos && pos != level.goal_pos && !level.walls.get(x, y) {
                    level.lava.toggle(x, y);
                    break;
                }
            }
        } else if u < self.p_wall + self.p_lava + p_move {
            // Move the goal to a random free, non-agent cell.
            loop {
                let c = rng.gen_range(GRID_CELLS);
                let (x, y) = (c % GRID_W, c / GRID_W);
                let pos = (x as u8, y as u8);
                if pos != level.agent_pos && !level.walls.get(x, y) && !level.lava.get(x, y) {
                    level.goal_pos = pos;
                    break;
                }
            }
        } else {
            // Move the agent to a random free, non-goal cell + random dir.
            loop {
                let c = rng.gen_range(GRID_CELLS);
                let (x, y) = (c % GRID_W, c / GRID_W);
                let pos = (x as u8, y as u8);
                if pos != level.goal_pos && !level.walls.get(x, y) && !level.lava.get(x, y) {
                    level.agent_pos = pos;
                    level.agent_dir = Dir::from_index(rng.gen_range(4));
                    break;
                }
            }
        }
    }

    pub fn mutate(&self, parent: &LavaLevel, rng: &mut Pcg64) -> LavaLevel {
        let mut child = *parent;
        for _ in 0..self.num_edits {
            self.edit(&mut child, rng);
        }
        debug_assert!(child.is_valid());
        child
    }
}

impl LevelMutator for LavaMutator {
    type Level = LavaLevel;

    fn mutate_level(&self, parent: &LavaLevel, rng: &mut Pcg64) -> LavaLevel {
        self.mutate(parent, rng)
    }
}

// ---------------------------------------------------------------------------
// Holdout suite
// ---------------------------------------------------------------------------

/// The named lava holdout levels plus `n` deterministic solvable-filtered
/// procedural draws (the lava analogue of the maze suite).
pub fn holdout_suite(n_procedural: usize, max_walls: usize, max_lava: usize, seed: u64)
    -> Vec<(String, LavaLevel)> {
    let mut out: Vec<(String, LavaLevel)> = named_levels()
        .into_iter()
        .map(|(n, l)| (n.to_string(), l))
        .collect();
    let gen = LavaLevelGenerator::new(max_walls, max_lava);
    let mut rng = Pcg64::new(seed, 0x4c41_5641); // "LAVA"
    for i in 0..n_procedural {
        out.push((format!("LavaProc{i:02}"), gen.generate_solvable(&mut rng, 1000)));
    }
    out
}

/// Hand-built named lava levels, all verified solvable by unit tests.
pub fn named_levels() -> Vec<(&'static str, LavaLevel)> {
    vec![
        ("LavaEmpty", empty_crossing()),
        ("LavaGap", gap(6)),
        ("LavaGapWide", gap(3)),
        ("LavaMoat", moat()),
        ("LavaRiverBridge", river_bridge(9)),
        ("LavaCorridors", corridors()),
    ]
}

/// No hazards at all: the baseline open room.
fn empty_crossing() -> LavaLevel {
    let mut l = LavaLevel::empty();
    l.agent_pos = (0, 12);
    l.agent_dir = Dir::Up;
    l.goal_pos = (12, 0);
    l
}

/// A full-width lava band at row 6 with one safe gap at column `gap_x`.
fn gap(gap_x: usize) -> LavaLevel {
    let mut l = LavaLevel::empty();
    for x in 0..GRID_W {
        if x != gap_x {
            l.lava.set(x, 6, true);
        }
    }
    l.agent_pos = (6, 12);
    l.agent_dir = Dir::Up;
    l.goal_pos = (6, 0);
    l
}

/// A lava ring around the goal with a single wall-protected entrance.
fn moat() -> LavaLevel {
    let mut l = LavaLevel::empty();
    for i in 4..=8 {
        l.lava.set(i, 4, true);
        l.lava.set(i, 8, true);
        l.lava.set(4, i, true);
        l.lava.set(8, i, true);
    }
    // entrance at the top-center
    l.lava.set(6, 4, false);
    l.agent_pos = (0, 0);
    l.agent_dir = Dir::Right;
    l.goal_pos = (6, 6);
    l
}

/// A vertical lava river with a wall-lined bridge at row `bridge_y`.
fn river_bridge(bridge_y: usize) -> LavaLevel {
    let mut l = LavaLevel::empty();
    for y in 0..GRID_H {
        for x in 5..=7 {
            if y != bridge_y {
                l.lava.set(x, y, true);
            }
        }
    }
    // guard rails above and below the bridge mouth
    if bridge_y > 0 {
        l.walls.set(4, bridge_y - 1, true);
    }
    if bridge_y + 1 < GRID_H {
        l.walls.set(4, bridge_y + 1, true);
    }
    l.agent_pos = (1, 1);
    l.agent_dir = Dir::Down;
    l.goal_pos = (11, 1);
    l
}

/// Wall corridors whose floors are partially lava: mixed tile reasoning.
fn corridors() -> LavaLevel {
    let mut l = LavaLevel::empty();
    for x in 0..GRID_W {
        l.walls.set(x, 4, true);
        l.walls.set(x, 8, true);
    }
    l.walls.set(2, 4, false);
    l.walls.set(10, 8, false);
    // lava pockets inside the middle band
    for x in [4usize, 5, 6] {
        l.lava.set(x, 6, true);
    }
    l.agent_pos = (6, 0);
    l.agent_dir = Dir::Down;
    l.goal_pos = (6, 12);
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::maze::{ACT_FORWARD, ACT_LEFT};
    use crate::prop_assert;
    use crate::util::proptest::props;

    fn rng() -> Pcg64 {
        Pcg64::seed_from_u64(0)
    }

    #[test]
    fn forward_into_lava_is_fatal_and_unrewarded() {
        let mut l = LavaLevel::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Right;
        l.lava.set(1, 0, true);
        l.goal_pos = (5, 5);
        let e = LavaEnv::default();
        let mut s = e.reset_to_level(&l, &mut rng());
        let r = e.step(&mut s, ACT_FORWARD, &mut rng());
        assert!(r.done);
        assert_eq!(r.reward, 0.0);
        assert_eq!(s.pos, (1, 0), "agent moved into the lava tile");
    }

    #[test]
    fn walls_still_block() {
        let mut l = LavaLevel::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Right;
        l.walls.set(1, 0, true);
        l.goal_pos = (5, 5);
        let e = LavaEnv::default();
        let mut s = e.reset_to_level(&l, &mut rng());
        let r = e.step(&mut s, ACT_FORWARD, &mut rng());
        assert!(!r.done);
        assert_eq!(s.pos, (0, 0));
    }

    #[test]
    fn goal_reward_matches_maze_shape() {
        let mut l = LavaLevel::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Right;
        l.goal_pos = (1, 0);
        let e = LavaEnv::default();
        let mut s = e.reset_to_level(&l, &mut rng());
        let r = e.step(&mut s, ACT_FORWARD, &mut rng());
        assert!(r.done);
        let expect = 1.0 - 0.9 * (1.0 / e.max_steps as f32);
        assert!((r.reward - expect).abs() < 1e-6);
    }

    #[test]
    fn truncation_at_max_steps() {
        let e = LavaEnv::new(3);
        let l = LavaLevel::empty();
        let mut s = e.reset_to_level(&l, &mut rng());
        assert!(!e.step(&mut s, ACT_LEFT, &mut rng()).done);
        assert!(!e.step(&mut s, ACT_LEFT, &mut rng()).done);
        let r = e.step(&mut s, ACT_LEFT, &mut rng());
        assert!(r.done);
        assert_eq!(r.reward, 0.0);
    }

    #[test]
    fn observation_distinguishes_wall_from_lava() {
        let mut l = LavaLevel::empty();
        l.agent_pos = (5, 5);
        l.agent_dir = Dir::Up;
        l.walls.set(5, 4, true); // one ahead: wall
        l.lava.set(5, 3, true); // two ahead: lava
        l.goal_pos = (12, 12);
        let e = LavaEnv::default();
        let s = e.reset_to_level(&l, &mut rng());
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        let ahead = ((VIEW - 2) * VIEW + VIEW / 2) * OBS_CHANNELS;
        let two_ahead = ((VIEW - 3) * VIEW + VIEW / 2) * OBS_CHANNELS;
        assert_eq!(obs[ahead], 1.0, "wall at full intensity");
        assert_eq!(obs[two_ahead], LAVA_INTENSITY, "lava at half intensity");
    }

    #[test]
    fn obs_geometry_matches_maze_artifacts() {
        let e = LavaEnv::default();
        assert_eq!(e.obs_len(), OBS_LEN);
        assert_eq!(e.obs_components(), vec![IMG_LEN, DIR_LEN]);
        assert_eq!(e.obs_components().iter().sum::<usize>(), e.obs_len());
        assert_eq!(e.num_actions(), NUM_ACTIONS);
    }

    #[test]
    fn bytes_roundtrip() {
        let g = LavaLevelGenerator::new(40, 12);
        let mut r = rng();
        for _ in 0..50 {
            let l = g.generate(&mut r);
            let l2 = LavaLevel::from_bytes(&l.to_bytes()).unwrap();
            assert_eq!(l, l2);
        }
        assert!(LavaLevel::from_bytes(&[0u8; 29]).is_err());
    }

    #[test]
    fn from_bytes_rejects_hostile_input() {
        let good = LavaLevel::empty().to_bytes();
        assert!(LavaLevel::from_bytes(&good[..52]).is_err(), "truncated");
        let mut oob = good;
        oob[48] = GRID_W as u8;
        assert!(LavaLevel::from_bytes(&oob).is_err(), "agent x OOB");
        let mut bad_dir = good;
        bad_dir[50] = 7;
        assert!(LavaLevel::from_bytes(&bad_dir).is_err(), "dir >= 4");
        let mut stray_wall = good;
        stray_wall[23] = 0x80; // bit 63 of wall word 2, past cell 168
        assert!(LavaLevel::from_bytes(&stray_wall).is_err(), "stray wall bit");
        let mut stray_lava = good;
        stray_lava[47] = 0x80; // bit 63 of lava word 2
        assert!(LavaLevel::from_bytes(&stray_lava).is_err(), "stray lava bit");
    }

    #[test]
    fn from_bytes_ok_is_canonical() {
        let g = LavaLevelGenerator::new(40, 12);
        let mut r = rng();
        for _ in 0..50 {
            let b = g.generate(&mut r).to_bytes();
            assert_eq!(LavaLevel::from_bytes(&b).unwrap().to_bytes(), b);
        }
    }

    #[test]
    fn generator_respects_budgets_and_validity() {
        let g = LavaLevelGenerator::new(30, 8);
        let mut r = rng();
        for _ in 0..200 {
            let l = g.generate(&mut r);
            assert!(l.is_valid(), "{l:?}");
            assert!(l.num_walls() <= 30);
            assert!(l.num_lava() <= 8);
        }
    }

    #[test]
    fn solvability_accounts_for_lava() {
        // A lava wall fully separating agent from goal: unsolvable even
        // though no physical wall blocks the way.
        let mut l = LavaLevel::empty();
        for x in 0..GRID_W {
            l.lava.set(x, 6, true);
        }
        l.agent_pos = (6, 12);
        l.agent_dir = Dir::Up;
        l.goal_pos = (6, 0);
        assert!(l.is_valid());
        assert!(!l.is_solvable());
        // Open one gap and it becomes solvable.
        l.lava.set(3, 6, false);
        assert!(l.is_solvable());
    }

    #[test]
    fn named_holdouts_valid_solvable_distinct() {
        let levels = named_levels();
        for (name, l) in &levels {
            assert!(l.is_valid(), "{name} invalid");
            assert!(l.is_solvable(), "{name} unsolvable");
        }
        for i in 0..levels.len() {
            for j in (i + 1)..levels.len() {
                assert_ne!(
                    levels[i].1.fingerprint(),
                    levels[j].1.fingerprint(),
                    "{} == {}", levels[i].0, levels[j].0
                );
            }
        }
    }

    #[test]
    fn holdout_suite_deterministic() {
        let a = holdout_suite(10, 40, 10, 7);
        let b = holdout_suite(10, 40, 10, 7);
        assert_eq!(a.len(), named_levels().len() + 10);
        for ((na, la), (nb, lb)) in a.iter().zip(&b) {
            assert_eq!(na, nb);
            assert_eq!(la, lb);
        }
    }

    #[test]
    fn prop_mutation_preserves_validity_and_disjointness() {
        props(200, |g| {
            let edits = g.usize_in(0, 30);
            let gen = LavaLevelGenerator::new(30, 10);
            let m = LavaMutator::new(edits);
            let parent = gen.generate(g.rng());
            let child = m.mutate(&parent, g.rng());
            prop_assert!(child.is_valid(), "invalid child {:?}", child);
            Ok(())
        });
    }
}
