//! Maze level mutation (paper §4), the ACCEL edit operator, implementing
//! the [`LevelMutator`](crate::env::LevelMutator) trait.
//!
//! ACCEL (Parker-Holder et al., 2022) evolves high-regret levels by applying
//! a small number of random edits to replayed levels. Following
//! JaxUED/ACCEL, each edit is drawn from: toggle a wall at a random cell
//! (the dominant move), relocate the goal, or relocate the agent. Edits
//! never produce structurally invalid levels.

use super::level::{Dir, Level, GRID_CELLS, GRID_W};
use super::LevelMutator;
use crate::util::rng::Pcg64;

/// Mutation-operator parameters. `num_edits` matches Table 3 (20).
#[derive(Clone, Copy, Debug)]
pub struct MazeMutator {
    pub num_edits: usize,
    /// Probability an edit toggles a wall (the remainder splits evenly
    /// between moving the goal and moving the agent).
    pub p_wall: f64,
}

impl Default for MazeMutator {
    fn default() -> Self {
        MazeMutator { num_edits: 20, p_wall: 0.8 }
    }
}

impl MazeMutator {
    pub fn new(num_edits: usize) -> Self {
        MazeMutator { num_edits, ..Default::default() }
    }

    /// Apply one random edit in place.
    pub fn edit(&self, level: &mut Level, rng: &mut Pcg64) {
        let u = rng.next_f64();
        if u < self.p_wall {
            // Toggle a wall anywhere except under the agent or goal.
            loop {
                let c = rng.gen_range(GRID_CELLS);
                let pos = ((c % GRID_W) as u8, (c / GRID_W) as u8);
                if pos != level.agent_pos && pos != level.goal_pos {
                    level.walls.toggle(pos.0 as usize, pos.1 as usize);
                    break;
                }
            }
        } else if u < self.p_wall + (1.0 - self.p_wall) / 2.0 {
            // Move the goal to a random free, non-agent cell.
            loop {
                let c = rng.gen_range(GRID_CELLS);
                let (x, y) = (c % GRID_W, c / GRID_W);
                let pos = (x as u8, y as u8);
                if pos != level.agent_pos && !level.walls.get(x, y) {
                    level.goal_pos = pos;
                    break;
                }
            }
        } else {
            // Move the agent to a random free, non-goal cell + random dir.
            loop {
                let c = rng.gen_range(GRID_CELLS);
                let (x, y) = (c % GRID_W, c / GRID_W);
                let pos = (x as u8, y as u8);
                if pos != level.goal_pos && !level.walls.get(x, y) {
                    level.agent_pos = pos;
                    level.agent_dir = Dir::from_index(rng.gen_range(4));
                    break;
                }
            }
        }
    }

    /// Produce a mutated child: `num_edits` independent edits.
    pub fn mutate(&self, parent: &Level, rng: &mut Pcg64) -> Level {
        let mut child = *parent;
        for _ in 0..self.num_edits {
            self.edit(&mut child, rng);
        }
        debug_assert!(child.is_valid());
        child
    }
}

impl LevelMutator for MazeMutator {
    type Level = Level;

    fn mutate_level(&self, parent: &Level, rng: &mut Pcg64) -> Level {
        self.mutate(parent, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::gen::MazeLevelGenerator;
    use crate::prop_assert;
    use crate::util::proptest::props;

    #[test]
    fn children_always_valid() {
        let g = MazeLevelGenerator::new(60);
        let m = MazeMutator::default();
        let mut rng = Pcg64::seed_from_u64(0);
        for _ in 0..200 {
            let parent = g.generate(&mut rng);
            let child = m.mutate(&parent, &mut rng);
            assert!(child.is_valid());
        }
    }

    #[test]
    fn zero_edits_is_identity() {
        let g = MazeLevelGenerator::new(30);
        let m = MazeMutator::new(0);
        let mut rng = Pcg64::seed_from_u64(1);
        let parent = g.generate(&mut rng);
        assert_eq!(m.mutate(&parent, &mut rng), parent);
    }

    #[test]
    fn edits_change_levels() {
        let g = MazeLevelGenerator::new(30);
        let m = MazeMutator::new(20);
        let mut rng = Pcg64::seed_from_u64(2);
        let mut changed = 0;
        for _ in 0..50 {
            let parent = g.generate(&mut rng);
            if m.mutate(&parent, &mut rng) != parent {
                changed += 1;
            }
        }
        assert!(changed >= 49, "20 edits almost surely change the level");
    }

    #[test]
    fn wall_only_mutator_preserves_positions() {
        let g = MazeLevelGenerator::new(30);
        let m = MazeMutator { num_edits: 10, p_wall: 1.0 };
        let mut rng = Pcg64::seed_from_u64(3);
        for _ in 0..50 {
            let parent = g.generate(&mut rng);
            let child = m.mutate(&parent, &mut rng);
            assert_eq!(child.agent_pos, parent.agent_pos);
            assert_eq!(child.goal_pos, parent.goal_pos);
        }
    }

    #[test]
    fn prop_mutation_validity_and_wall_delta() {
        props(200, |gen| {
            let edits = gen.usize_in(0, 30);
            let g = MazeLevelGenerator::new(40);
            let m = MazeMutator::new(edits);
            let parent = g.generate(gen.rng());
            let child = m.mutate(&parent, gen.rng());
            prop_assert!(child.is_valid(), "invalid child");
            let delta = (child.num_walls() as isize - parent.num_walls() as isize).abs();
            prop_assert!(
                delta <= edits as isize,
                "wall count changed by {delta} > {edits} edits"
            );
            Ok(())
        });
    }
}
