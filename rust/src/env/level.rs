//! Maze levels: the underspecified parameters θ of the UPOMDP.
//!
//! A level is a 13×13 wall configuration plus agent start (position +
//! direction) and goal position — exactly the parameterization in the paper
//! (§4). Levels are value types: hashable (for LevelSampler de-duplication),
//! serializable (checkpoints), and parse/print round-trippable through the
//! ASCII-art format used to define the named holdout mazes.

use anyhow::{bail, Result};

/// Grid width/height. Matches `model.GRID_W/H` on the python side; the
/// manifest cross-checks it at runtime-load time.
pub const GRID_W: usize = 13;
pub const GRID_H: usize = 13;
pub const GRID_CELLS: usize = GRID_W * GRID_H;

/// Facing direction. Ordering matters: turning right increments mod 4, and
/// the one-hot observation uses this index.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Dir {
    Up = 0,
    Right = 1,
    Down = 2,
    Left = 3,
}

impl Dir {
    pub const ALL: [Dir; 4] = [Dir::Up, Dir::Right, Dir::Down, Dir::Left];

    pub fn from_index(i: usize) -> Dir {
        Self::ALL[i % 4]
    }

    pub fn index(self) -> usize {
        self as usize
    }

    /// Unit step (dx, dy); y grows downward.
    pub fn delta(self) -> (isize, isize) {
        match self {
            Dir::Up => (0, -1),
            Dir::Right => (1, 0),
            Dir::Down => (0, 1),
            Dir::Left => (-1, 0),
        }
    }

    pub fn turn_right(self) -> Dir {
        Dir::from_index(self.index() + 1)
    }

    pub fn turn_left(self) -> Dir {
        Dir::from_index(self.index() + 3)
    }
}

/// 169-bit wall set, packed into three u64 words.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub struct WallSet {
    bits: [u64; 3],
}

impl WallSet {
    pub fn empty() -> Self {
        Self::default()
    }

    #[inline]
    fn check(x: usize, y: usize) -> usize {
        debug_assert!(x < GRID_W && y < GRID_H);
        y * GRID_W + x
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> bool {
        let i = Self::check(x, y);
        (self.bits[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: bool) {
        let i = Self::check(x, y);
        if v {
            self.bits[i / 64] |= 1 << (i % 64);
        } else {
            self.bits[i / 64] &= !(1 << (i % 64));
        }
    }

    pub fn toggle(&mut self, x: usize, y: usize) {
        let i = Self::check(x, y);
        self.bits[i / 64] ^= 1 << (i % 64);
    }

    pub fn count(&self) -> usize {
        self.bits.iter().map(|b| b.count_ones() as usize).sum()
    }

    pub fn words(&self) -> [u64; 3] {
        self.bits
    }

    /// Rebuild from three packed words, rejecting bits beyond cell
    /// `GRID_CELLS - 1`. Decoders use this so an `Ok` wall set always has a
    /// canonical encoding (stray padding bits would otherwise survive into
    /// `words()` and break `decode(encode(l)) == l` byte equality).
    pub fn from_words(words: [u64; 3]) -> Result<WallSet> {
        if words[2] >> (GRID_CELLS - 128) != 0 {
            bail!("wall words have stray bits beyond cell {GRID_CELLS}");
        }
        Ok(WallSet { bits: words })
    }
}

/// A maze level θ: walls + agent start + goal.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Level {
    pub walls: WallSet,
    pub agent_pos: (u8, u8),
    pub agent_dir: Dir,
    pub goal_pos: (u8, u8),
}

impl Level {
    /// An empty level with agent at top-left facing right, goal bottom-right.
    pub fn empty() -> Level {
        Level {
            walls: WallSet::empty(),
            agent_pos: (0, 0),
            agent_dir: Dir::Right,
            goal_pos: ((GRID_W - 1) as u8, (GRID_H - 1) as u8),
        }
    }

    pub fn wall_at(&self, x: usize, y: usize) -> bool {
        self.walls.get(x, y)
    }

    pub fn num_walls(&self) -> usize {
        self.walls.count()
    }

    /// Structural validity: agent/goal distinct, in bounds, not inside walls.
    pub fn is_valid(&self) -> bool {
        let (ax, ay) = (self.agent_pos.0 as usize, self.agent_pos.1 as usize);
        let (gx, gy) = (self.goal_pos.0 as usize, self.goal_pos.1 as usize);
        ax < GRID_W
            && ay < GRID_H
            && gx < GRID_W
            && gy < GRID_H
            && self.agent_pos != self.goal_pos
            && !self.walls.get(ax, ay)
            && !self.walls.get(gx, gy)
    }

    /// FNV-1a hash over the canonical byte encoding — the LevelSampler
    /// de-duplication key.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        };
        for w in self.walls.words() {
            for b in w.to_le_bytes() {
                eat(b);
            }
        }
        eat(self.agent_pos.0);
        eat(self.agent_pos.1);
        eat(self.agent_dir.index() as u8);
        eat(self.goal_pos.0);
        eat(self.goal_pos.1);
        h
    }

    /// Binary encoding (fixed 29 bytes) for checkpoints.
    pub fn to_bytes(&self) -> [u8; 29] {
        let mut out = [0u8; 29];
        let words = self.walls.words();
        out[0..8].copy_from_slice(&words[0].to_le_bytes());
        out[8..16].copy_from_slice(&words[1].to_le_bytes());
        out[16..24].copy_from_slice(&words[2].to_le_bytes());
        out[24] = self.agent_pos.0;
        out[25] = self.agent_pos.1;
        out[26] = self.agent_dir.index() as u8;
        out[27] = self.goal_pos.0;
        out[28] = self.goal_pos.1;
        out
    }

    /// Decode the fixed 29-byte encoding. This is a trust boundary (the
    /// serving layer feeds it raw network bytes), so every field is
    /// validated: stray wall bits, out-of-bounds positions, and direction
    /// bytes >= 4 are all rejected rather than masked or silently dropped.
    /// `Ok(l)` guarantees `l.to_bytes() == input` and that `l`'s positions
    /// are safe to index with.
    // ued-lint: allow(serve-panic) — the length gate above each use makes the 8-byte try_intos infallible
    pub fn from_bytes(b: &[u8]) -> Result<Level> {
        if b.len() != 29 {
            bail!("level encoding must be 29 bytes, got {}", b.len());
        }
        let w0 = u64::from_le_bytes(b[0..8].try_into().unwrap());
        let w1 = u64::from_le_bytes(b[8..16].try_into().unwrap());
        let w2 = u64::from_le_bytes(b[16..24].try_into().unwrap());
        let walls = WallSet::from_words([w0, w1, w2])?;
        for (what, x, y) in [("agent", b[24], b[25]), ("goal", b[27], b[28])] {
            if x as usize >= GRID_W || y as usize >= GRID_H {
                bail!("{what} position ({x},{y}) out of the {GRID_W}x{GRID_H} grid");
            }
        }
        if b[26] >= 4 {
            bail!("direction byte {} out of range (expected 0..=3)", b[26]);
        }
        Ok(Level {
            walls,
            agent_pos: (b[24], b[25]),
            agent_dir: Dir::from_index(b[26] as usize),
            goal_pos: (b[27], b[28]),
        })
    }

    /// Parse from ASCII art: `#` wall, `.`/` ` empty, `G` goal, and the
    /// agent as `^`/`>`/`v`/`<` (facing up/right/down/left). Rows separated
    /// by newlines; must be exactly 13×13.
    pub fn from_ascii(art: &str) -> Result<Level> {
        let rows: Vec<&str> = art
            .lines()
            .map(|l| l.trim())
            .filter(|l| !l.is_empty())
            .collect();
        if rows.len() != GRID_H {
            bail!("expected {GRID_H} rows, got {}", rows.len());
        }
        let mut level = Level::empty();
        let mut agent = None;
        let mut goal = None;
        for (y, row) in rows.iter().enumerate() {
            let cells: Vec<char> = row.chars().collect();
            if cells.len() != GRID_W {
                bail!("row {y} has {} cells, expected {GRID_W}", cells.len());
            }
            for (x, c) in cells.iter().enumerate() {
                match c {
                    '#' => level.walls.set(x, y, true),
                    '.' | ' ' => {}
                    'G' => {
                        if goal.replace((x as u8, y as u8)).is_some() {
                            bail!("multiple goals");
                        }
                    }
                    '^' | '>' | 'v' | '<' => {
                        let dir = match c {
                            '^' => Dir::Up,
                            '>' => Dir::Right,
                            'v' => Dir::Down,
                            _ => Dir::Left,
                        };
                        if agent.replace(((x as u8, y as u8), dir)).is_some() {
                            bail!("multiple agents");
                        }
                    }
                    c => bail!("unknown cell {c:?} at ({x},{y})"),
                }
            }
        }
        let ((ax, ay), dir) = agent.ok_or_else(|| anyhow::anyhow!("no agent"))?;
        let (gx, gy) = goal.ok_or_else(|| anyhow::anyhow!("no goal"))?;
        level.agent_pos = (ax, ay);
        level.agent_dir = dir;
        level.goal_pos = (gx, gy);
        if !level.is_valid() {
            bail!("parsed level is structurally invalid");
        }
        Ok(level)
    }

    /// Render to the same ASCII format `from_ascii` reads.
    pub fn to_ascii(&self) -> String {
        let mut out = String::with_capacity((GRID_W + 1) * GRID_H);
        for y in 0..GRID_H {
            for x in 0..GRID_W {
                let c = if (x as u8, y as u8) == self.agent_pos {
                    match self.agent_dir {
                        Dir::Up => '^',
                        Dir::Right => '>',
                        Dir::Down => 'v',
                        Dir::Left => '<',
                    }
                } else if (x as u8, y as u8) == self.goal_pos {
                    'G'
                } else if self.walls.get(x, y) {
                    '#'
                } else {
                    '.'
                };
                out.push(c);
            }
            out.push('\n');
        }
        out
    }
}

/// The maze level as seen by the env-generic layers: obstacle count is the
/// complexity proxy, the 29-byte binary encoding backs checkpoints and the
/// PLR buffer.
impl crate::env::LevelMeta for Level {
    fn is_valid(&self) -> bool {
        Level::is_valid(self)
    }

    fn is_solvable(&self) -> bool {
        crate::env::shortest_path::is_solvable(self)
    }

    fn complexity(&self) -> f64 {
        self.num_walls() as f64
    }

    fn fingerprint(&self) -> u64 {
        Level::fingerprint(self)
    }

    fn encode(&self) -> Vec<u8> {
        self.to_bytes().to_vec()
    }

    fn decode(bytes: &[u8]) -> Result<Level> {
        Level::from_bytes(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wallset_get_set_toggle() {
        let mut w = WallSet::empty();
        assert!(!w.get(5, 7));
        w.set(5, 7, true);
        assert!(w.get(5, 7));
        assert_eq!(w.count(), 1);
        w.toggle(5, 7);
        assert!(!w.get(5, 7));
        assert_eq!(w.count(), 0);
    }

    #[test]
    fn wallset_corner_bits() {
        let mut w = WallSet::empty();
        w.set(0, 0, true);
        w.set(GRID_W - 1, GRID_H - 1, true);
        assert!(w.get(0, 0));
        assert!(w.get(GRID_W - 1, GRID_H - 1));
        assert_eq!(w.count(), 2);
    }

    #[test]
    fn dir_turns() {
        assert_eq!(Dir::Up.turn_right(), Dir::Right);
        assert_eq!(Dir::Up.turn_left(), Dir::Left);
        assert_eq!(Dir::Left.turn_right(), Dir::Up);
        for d in Dir::ALL {
            assert_eq!(d.turn_left().turn_right(), d);
            assert_eq!(
                d.turn_right().turn_right().turn_right().turn_right(),
                d
            );
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let mut l = Level::empty();
        l.walls.set(3, 4, true);
        l.walls.set(12, 12, true);
        l.agent_pos = (2, 9);
        l.agent_dir = Dir::Down;
        l.goal_pos = (6, 1);
        let l2 = Level::from_bytes(&l.to_bytes()).unwrap();
        assert_eq!(l, l2);
    }

    #[test]
    fn from_bytes_rejects_hostile_input() {
        let good = Level::empty().to_bytes();
        assert!(Level::from_bytes(&good[..28]).is_err(), "truncated");
        assert!(Level::from_bytes(&[0u8; 30]).is_err(), "oversized");
        let mut oob_agent = good;
        oob_agent[24] = GRID_W as u8; // x == 13, one past the edge
        assert!(Level::from_bytes(&oob_agent).is_err(), "agent x OOB");
        let mut oob_goal = good;
        oob_goal[28] = 255;
        assert!(Level::from_bytes(&oob_goal).is_err(), "goal y OOB");
        let mut bad_dir = good;
        bad_dir[26] = 4;
        assert!(Level::from_bytes(&bad_dir).is_err(), "dir >= 4");
        let mut stray = good;
        stray[23] = 0x80; // bit 63 of word 2 == cell 191, past cell 168
        assert!(Level::from_bytes(&stray).is_err(), "stray wall bits");
    }

    #[test]
    fn from_bytes_ok_is_canonical() {
        let mut l = Level::empty();
        l.walls.set(12, 12, true); // the last valid cell (bit 40 of word 2)
        l.goal_pos = (11, 12);
        let b = l.to_bytes();
        let back = Level::from_bytes(&b).unwrap();
        assert_eq!(back.to_bytes(), b);
        assert_eq!(back, l);
    }

    #[test]
    fn ascii_roundtrip() {
        let mut l = Level::empty();
        l.walls.set(1, 1, true);
        l.walls.set(11, 3, true);
        l.agent_pos = (0, 12);
        l.agent_dir = Dir::Up;
        l.goal_pos = (12, 0);
        let art = l.to_ascii();
        assert_eq!(Level::from_ascii(&art).unwrap(), l);
    }

    #[test]
    fn ascii_rejects_bad() {
        assert!(Level::from_ascii("###").is_err());
        // missing agent
        let empty13 = format!("{}\n", ".".repeat(13)).repeat(13);
        assert!(Level::from_ascii(&empty13).is_err());
    }

    #[test]
    fn fingerprint_distinguishes() {
        let a = Level::empty();
        let mut b = a;
        b.walls.set(6, 6, true);
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = a;
        c.agent_dir = Dir::Down;
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn validity() {
        let mut l = Level::empty();
        assert!(l.is_valid());
        l.walls.set(0, 0, true); // wall under agent
        assert!(!l.is_valid());
        l.walls.set(0, 0, false);
        l.goal_pos = l.agent_pos;
        assert!(!l.is_valid());
    }
}
