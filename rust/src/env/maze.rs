//! The maze environment (paper §4): a fully-deterministic, simplified
//! MiniGrid. A partially-observable agent must navigate to a goal; levels
//! are wall configurations plus agent start and goal positions.
//!
//! Semantics match MiniGrid/JaxUED:
//!   * actions: 0 = turn left, 1 = turn right, 2 = move forward
//!   * forward into a wall or out of bounds is a no-op
//!   * reaching the goal terminates with reward `1 − 0.9·t/T_max`
//!   * episodes truncate (done, zero reward) at `T_max` steps
//!   * observation: egocentric `VIEW×VIEW` crop in front of the agent
//!     (agent at bottom-center, facing "up" in the crop), channels
//!     {wall, goal, out-of-bounds}, plus a 4-dim one-hot of the absolute
//!     facing direction.

use super::level::{Dir, Level, GRID_H, GRID_W};
use super::{StepResult, UnderspecifiedEnv};
use crate::util::rng::Pcg64;

/// Egocentric view side length (must equal `model.VIEW` — cross-checked
/// against the manifest at startup).
pub const VIEW: usize = 5;
pub const OBS_CHANNELS: usize = 3;
pub const IMG_LEN: usize = VIEW * VIEW * OBS_CHANNELS;
pub const DIR_LEN: usize = 4;
pub const OBS_LEN: usize = IMG_LEN + DIR_LEN;
pub const NUM_ACTIONS: usize = 3;

pub const ACT_LEFT: usize = 0;
pub const ACT_RIGHT: usize = 1;
pub const ACT_FORWARD: usize = 2;

/// Default episode horizon (DCD/JaxUED use 250 for 13×13 mazes).
pub const DEFAULT_MAX_STEPS: usize = 250;

/// Full environment state. The level is embedded by value (29 bytes) so
/// states are self-contained and trivially cloneable.
#[derive(Clone, Debug)]
pub struct MazeState {
    pub level: Level,
    pub pos: (u8, u8),
    pub dir: Dir,
    pub t: u32,
}

impl MazeState {
    pub fn at_goal(&self) -> bool {
        self.pos == self.level.goal_pos
    }
}

/// The maze UPOMDP.
#[derive(Clone, Debug)]
pub struct MazeEnv {
    pub max_steps: usize,
}

impl Default for MazeEnv {
    fn default() -> Self {
        MazeEnv { max_steps: DEFAULT_MAX_STEPS }
    }
}

impl MazeEnv {
    pub fn new(max_steps: usize) -> Self {
        MazeEnv { max_steps }
    }

    /// Reward for reaching the goal at step `t` (after increment).
    #[inline]
    fn goal_reward(&self, t: u32) -> f32 {
        1.0 - 0.9 * (t as f32 / self.max_steps as f32)
    }
}

impl UnderspecifiedEnv for MazeEnv {
    type State = MazeState;
    type Level = Level;

    fn num_actions(&self) -> usize {
        NUM_ACTIONS
    }

    fn reset_to_level(&self, level: &Level, _rng: &mut Pcg64) -> MazeState {
        debug_assert!(level.is_valid(), "reset to invalid level");
        MazeState {
            level: *level,
            pos: level.agent_pos,
            dir: level.agent_dir,
            t: 0,
        }
    }

    fn step(&self, s: &mut MazeState, action: usize, _rng: &mut Pcg64) -> StepResult {
        s.t += 1;
        match action {
            ACT_LEFT => s.dir = s.dir.turn_left(),
            ACT_RIGHT => s.dir = s.dir.turn_right(),
            ACT_FORWARD => {
                let (dx, dy) = s.dir.delta();
                let nx = s.pos.0 as isize + dx;
                let ny = s.pos.1 as isize + dy;
                if nx >= 0
                    && ny >= 0
                    && (nx as usize) < GRID_W
                    && (ny as usize) < GRID_H
                    && !s.level.wall_at(nx as usize, ny as usize)
                {
                    s.pos = (nx as u8, ny as u8);
                }
            }
            // ued-lint: allow(serve-panic) — actions come from policy argmax over num_actions; an out-of-range action is engine corruption, not client input
            a => panic!("invalid maze action {a}"),
        }
        if s.at_goal() {
            return StepResult { reward: self.goal_reward(s.t), done: true };
        }
        if s.t as usize >= self.max_steps {
            return StepResult { reward: 0.0, done: true };
        }
        StepResult { reward: 0.0, done: false }
    }

    fn observe(&self, s: &MazeState, obs: &mut [f32]) {
        debug_assert_eq!(obs.len(), OBS_LEN);
        obs.fill(0.0);
        let (ax, ay) = (s.pos.0 as isize, s.pos.1 as isize);
        let half = (VIEW / 2) as isize;
        for vy in 0..VIEW {
            // forward distance: bottom row (vy = VIEW-1) is the agent's row
            let f = (VIEW - 1 - vy) as isize;
            for vx in 0..VIEW {
                let l = vx as isize - half; // lateral, right-positive
                let (dx, dy) = match s.dir {
                    Dir::Up => (l, -f),
                    Dir::Right => (f, l),
                    Dir::Down => (-l, f),
                    Dir::Left => (-f, -l),
                };
                let (wx, wy) = (ax + dx, ay + dy);
                let base = (vy * VIEW + vx) * OBS_CHANNELS;
                if wx < 0 || wy < 0 || wx >= GRID_W as isize || wy >= GRID_H as isize {
                    obs[base] = 1.0; // out-of-bounds reads as wall…
                    obs[base + 2] = 1.0; // …and is marked oob
                } else {
                    let (wx, wy) = (wx as usize, wy as usize);
                    if s.level.wall_at(wx, wy) {
                        obs[base] = 1.0;
                    }
                    if (wx as u8, wy as u8) == s.level.goal_pos {
                        obs[base + 1] = 1.0;
                    }
                }
            }
        }
        obs[IMG_LEN + s.dir.index()] = 1.0;
    }

    fn obs_len(&self) -> usize {
        OBS_LEN
    }

    fn obs_components(&self) -> Vec<usize> {
        vec![IMG_LEN, DIR_LEN]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> MazeEnv {
        MazeEnv::default()
    }

    fn rng() -> Pcg64 {
        Pcg64::seed_from_u64(0)
    }

    #[test]
    fn reset_places_agent() {
        let mut l = Level::empty();
        l.agent_pos = (3, 4);
        l.agent_dir = Dir::Down;
        let s = env().reset_to_level(&l, &mut rng());
        assert_eq!(s.pos, (3, 4));
        assert_eq!(s.dir, Dir::Down);
        assert_eq!(s.t, 0);
    }

    #[test]
    fn turning() {
        let l = Level::empty();
        let e = env();
        let mut s = e.reset_to_level(&l, &mut rng());
        let d0 = s.dir;
        e.step(&mut s, ACT_LEFT, &mut rng());
        assert_eq!(s.dir, d0.turn_left());
        e.step(&mut s, ACT_RIGHT, &mut rng());
        assert_eq!(s.dir, d0);
        assert_eq!(s.pos, l.agent_pos);
    }

    #[test]
    fn forward_moves_and_blocks() {
        let mut l = Level::empty();
        l.agent_pos = (5, 5);
        l.agent_dir = Dir::Right;
        l.walls.set(7, 5, true);
        let e = env();
        let mut s = e.reset_to_level(&l, &mut rng());
        e.step(&mut s, ACT_FORWARD, &mut rng());
        assert_eq!(s.pos, (6, 5));
        // wall at (7,5): blocked
        e.step(&mut s, ACT_FORWARD, &mut rng());
        assert_eq!(s.pos, (6, 5));
    }

    #[test]
    fn boundary_blocks() {
        let mut l = Level::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Up;
        l.goal_pos = (12, 12);
        let e = env();
        let mut s = e.reset_to_level(&l, &mut rng());
        e.step(&mut s, ACT_FORWARD, &mut rng());
        assert_eq!(s.pos, (0, 0));
    }

    #[test]
    fn reaching_goal_rewards_and_terminates() {
        let mut l = Level::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Right;
        l.goal_pos = (1, 0);
        let e = env();
        let mut s = e.reset_to_level(&l, &mut rng());
        let r = e.step(&mut s, ACT_FORWARD, &mut rng());
        assert!(r.done);
        let expect = 1.0 - 0.9 * (1.0 / DEFAULT_MAX_STEPS as f32);
        assert!((r.reward - expect).abs() < 1e-6);
    }

    #[test]
    fn slower_solutions_get_less_reward() {
        let mut l = Level::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Right;
        l.goal_pos = (2, 0);
        let e = env();
        let mut s = e.reset_to_level(&l, &mut rng());
        e.step(&mut s, ACT_FORWARD, &mut rng());
        let r = e.step(&mut s, ACT_FORWARD, &mut rng());
        assert!(r.done);
        let fast = 1.0 - 0.9 * (2.0 / DEFAULT_MAX_STEPS as f32);
        assert!((r.reward - fast).abs() < 1e-6);

        // waste two turns first
        let mut s = e.reset_to_level(&l, &mut rng());
        e.step(&mut s, ACT_LEFT, &mut rng());
        e.step(&mut s, ACT_RIGHT, &mut rng());
        e.step(&mut s, ACT_FORWARD, &mut rng());
        let r2 = e.step(&mut s, ACT_FORWARD, &mut rng());
        assert!(r2.done);
        assert!(r2.reward < r.reward);
    }

    #[test]
    fn truncation_at_max_steps() {
        let e = MazeEnv::new(5);
        let l = Level::empty();
        let mut s = e.reset_to_level(&l, &mut rng());
        for i in 0..5 {
            let r = e.step(&mut s, ACT_LEFT, &mut rng());
            if i < 4 {
                assert!(!r.done);
            } else {
                assert!(r.done);
                assert_eq!(r.reward, 0.0);
            }
        }
    }

    #[test]
    fn observation_shape_and_dir_onehot() {
        let e = env();
        let l = Level::empty();
        let s = e.reset_to_level(&l, &mut rng());
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        let dir: Vec<f32> = obs[IMG_LEN..].to_vec();
        assert_eq!(dir.iter().sum::<f32>(), 1.0);
        assert_eq!(dir[s.dir.index()], 1.0);
    }

    #[test]
    fn observation_sees_wall_ahead() {
        let mut l = Level::empty();
        l.agent_pos = (5, 5);
        l.agent_dir = Dir::Up;
        l.walls.set(5, 4, true); // directly ahead
        let e = env();
        let s = e.reset_to_level(&l, &mut rng());
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        // agent at bottom-center (vy=VIEW-1, vx=2); ahead = (vy=VIEW-2, vx=2)
        let base = ((VIEW - 2) * VIEW + VIEW / 2) * OBS_CHANNELS;
        assert_eq!(obs[base], 1.0, "wall channel ahead");
        assert_eq!(obs[base + 2], 0.0, "not oob");
    }

    #[test]
    fn observation_rotates_with_agent() {
        // Wall to the agent's *east*; facing East it appears straight ahead,
        // facing North it appears to the right.
        let mut l = Level::empty();
        l.agent_pos = (5, 5);
        l.walls.set(6, 5, true);
        let e = env();

        let mut le = l;
        le.agent_dir = Dir::Right;
        let s = e.reset_to_level(&le, &mut rng());
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        let ahead = ((VIEW - 2) * VIEW + VIEW / 2) * OBS_CHANNELS;
        assert_eq!(obs[ahead], 1.0);

        let mut ln = l;
        ln.agent_dir = Dir::Up;
        let s = e.reset_to_level(&ln, &mut rng());
        e.observe(&s, &mut obs);
        let right = ((VIEW - 1) * VIEW + VIEW / 2 + 1) * OBS_CHANNELS;
        assert_eq!(obs[right], 1.0);
    }

    #[test]
    fn observation_oob_channel() {
        let mut l = Level::empty();
        l.agent_pos = (0, 0);
        l.agent_dir = Dir::Up;
        l.goal_pos = (5, 5);
        let e = env();
        let s = e.reset_to_level(&l, &mut rng());
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        // Everything ahead is out of bounds: top row of the view.
        for vx in 0..VIEW {
            let base = vx * OBS_CHANNELS;
            assert_eq!(obs[base], 1.0, "oob reads as wall");
            assert_eq!(obs[base + 2], 1.0, "oob channel set");
        }
    }

    #[test]
    fn observation_sees_goal() {
        let mut l = Level::empty();
        l.agent_pos = (5, 5);
        l.agent_dir = Dir::Up;
        l.goal_pos = (5, 3); // two ahead
        let e = env();
        let s = e.reset_to_level(&l, &mut rng());
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        let base = ((VIEW - 3) * VIEW + VIEW / 2) * OBS_CHANNELS;
        assert_eq!(obs[base + 1], 1.0, "goal channel");
    }

    #[test]
    fn obs_components_sum_to_len() {
        let e = env();
        assert_eq!(e.obs_components().iter().sum::<usize>(), e.obs_len());
    }
}
