//! The `UnderspecifiedEnv` interface (paper §3.1) and the level-lifecycle
//! capability traits that make the training stack environment-generic.
//!
//! UED operates over Underspecified POMDPs: a *collection* of POMDPs indexed
//! by free parameters ("levels"). Conventional env interfaces bake an
//! implicit level distribution into `reset()`; `UnderspecifiedEnv` instead
//! exposes `reset_to_level`, pushing level-distribution management to the
//! caller (a UED algorithm, an evaluation routine, a wrapper). Levels are
//! decoupled from states: a level induces a (possibly stochastic) initial
//! state distribution.
//!
//! The interface is split into capability traits so every layer above the
//! rollout engine can be written once, for any environment:
//!
//! * [`UnderspecifiedEnv`] — reset/step/observe over an associated
//!   `State`/`Level` pair (the paper's core interface).
//! * [`LevelGenerator`] — the base "domain randomization" distribution
//!   (the paper's `sample_random_level`), used by DR and by the PLR
//!   family's `on_new_levels` cycle.
//! * [`LevelMutator`] — the ACCEL edit operator: small random perturbations
//!   of a parent level.
//! * [`LevelMeta`] — level introspection: validity, solvability, a
//!   complexity proxy, a de-duplication fingerprint, and a compact byte
//!   encoding for checkpoints and the PLR buffer.
//! * [`EnvFamily`] — one environment's full bundle (env + generator +
//!   mutator + PAIRED editor + holdout suite + artifact geometry). The
//!   [`registry`] maps `--env` names onto families the way `--algo` maps
//!   onto methods, so algorithms (`algo/`), evaluation (`eval/`) and the
//!   rollout engine contain no env-specific types at all.
//!
//! Concrete families live below: [`maze`] (the paper's 13×13 MiniGrid-style
//! maze) and [`lava`] (a hazard-tile variant proving the stack is generic).

pub mod conformance;
pub mod editor;
pub mod gen;
pub mod holdout;
pub mod lava;
pub mod level;
pub mod maze;
pub mod mutate;
pub mod registry;
pub mod render;
pub mod shortest_path;
pub mod wrappers;

pub use level::Level;
pub use registry::{EnvId, LavaFamily, MazeFamily};

use anyhow::Result;

use crate::util::rng::Pcg64;

/// Result of one environment transition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepResult {
    pub reward: f32,
    /// Episode terminated at this transition (goal reached or truncation).
    pub done: bool,
}

/// A POMDP family indexed by levels (paper §3.1).
///
/// `State` is the full environment state; `Level` the underspecified
/// parameters; observations are written into a caller-owned flat buffer
/// (the rollout engine owns the backing storage — observation writing is
/// allocation-free).
///
/// Envs are `Sync + Send` and states are `Send` so the rollout engine can
/// fan `observe()`/`step()` out across its worker pool (the env is shared
/// read-only while each batch column's state is stepped by exactly one
/// worker) and so whole algorithm drivers — which own their env — can move
/// onto seed-pack driver threads (`UedAlgorithm: Send`). Every
/// implementation is plain data, so these bounds are auto-derived — they
/// only become visible if an env tries to smuggle in un-shareable interior
/// state (which would also break rollout determinism).
pub trait UnderspecifiedEnv: Sync + Send {
    type State: Clone + Send;
    type Level: Clone + Send + Sync;

    /// Number of discrete actions.
    fn num_actions(&self) -> usize;

    /// Stochastically initialize a state from a level (never an implicit
    /// level distribution — that is the caller's job).
    fn reset_to_level(&self, level: &Self::Level, rng: &mut Pcg64) -> Self::State;

    /// Transition. Returns reward and termination; mutates the state.
    fn step(&self, state: &mut Self::State, action: usize, rng: &mut Pcg64) -> StepResult;

    /// Write the observation of `state` into `obs` (length = obs_len()).
    fn observe(&self, state: &Self::State, obs: &mut [f32]);

    /// Flat observation length.
    fn obs_len(&self) -> usize;

    /// Lengths of the observation's components, in the order the policy
    /// artifact expects its observation inputs (e.g. the student policy
    /// takes `[img(75), dir(4)]`). The flat `observe` buffer is the
    /// concatenation of these.
    fn obs_components(&self) -> Vec<usize> {
        vec![self.obs_len()]
    }
}

/// The base level distribution (paper's `sample_random_level`): one draw
/// per call, structurally valid but *not* necessarily solvable — unsolvable
/// draws are part of the DR distribution and it is UED's job to cope.
///
/// `Sync` because `AutoResetWrapper` embeds its generator inside an env
/// that the rollout workers share (auto-reset draws happen on the
/// stepping worker's own column stream); `Send` because the algorithm
/// drivers that own generators move onto seed-pack driver threads.
pub trait LevelGenerator: Sync + Send {
    type Level: Clone;

    /// One draw from the base distribution.
    fn sample_level(&self, rng: &mut Pcg64) -> Self::Level;

    /// A batch of independent draws.
    fn sample_batch(&self, n: usize, rng: &mut Pcg64) -> Vec<Self::Level> {
        (0..n).map(|_| self.sample_level(rng)).collect()
    }
}

/// The ACCEL edit operator: produce a slightly-perturbed child level.
/// Mutation must preserve structural validity (`LevelMeta::is_valid`).
/// `Send` for the same reason as [`LevelGenerator`]: the owning driver
/// may live on a seed-pack driver thread.
pub trait LevelMutator: Send {
    type Level: Clone;

    /// Produce a mutated child of `parent`.
    fn mutate_level(&self, parent: &Self::Level, rng: &mut Pcg64) -> Self::Level;

    /// Mutate a batch of parents (one child per parent).
    fn mutate_batch(&self, parents: &[Self::Level], rng: &mut Pcg64) -> Vec<Self::Level> {
        parents.iter().map(|p| self.mutate_level(p, rng)).collect()
    }
}

/// Level introspection and serialization: everything the UED layers above
/// the env need to know about a level without knowing its concrete type —
/// buffer de-duplication, checkpointing, curriculum diagnostics.
pub trait LevelMeta: Clone {
    /// Structural validity (agent/goal placement, tile invariants).
    fn is_valid(&self) -> bool;

    /// A free path from start to goal exists.
    fn is_solvable(&self) -> bool;

    /// Scalar complexity proxy (e.g. obstacle count) for curriculum
    /// diagnostics; larger = richer level.
    fn complexity(&self) -> f64;

    /// Stable hash over the canonical encoding — the LevelSampler
    /// de-duplication key.
    fn fingerprint(&self) -> u64;

    /// Compact byte encoding for checkpoints and the PLR buffer.
    fn encode(&self) -> Vec<u8>;

    /// Inverse of [`encode`](LevelMeta::encode).
    fn decode(bytes: &[u8]) -> Result<Self>;
}

/// Env-layer knobs extracted from the training config (so `env/` does not
/// depend on the full `TrainConfig`).
#[derive(Clone, Copy, Debug)]
pub struct EnvParams {
    /// Student episode horizon.
    pub max_episode_steps: usize,
    /// Base-distribution wall budget (paper Figure 3: 25 or 60).
    pub max_walls: usize,
    /// Base-distribution hazard-tile budget (lava family; ignored by maze).
    pub max_hazards: usize,
    /// ACCEL edits per mutation (Table 3: 20).
    pub num_edits: usize,
    /// PAIRED adversary edit budget.
    pub editor_steps: usize,
}

impl Default for EnvParams {
    fn default() -> Self {
        EnvParams {
            max_episode_steps: 250,
            max_walls: 60,
            max_hazards: 12,
            num_edits: 20,
            editor_steps: 60,
        }
    }
}

/// Environment geometry the AOT artifacts were compiled against. The
/// runtime cross-checks this against the manifest constants at startup so
/// an incompatible artifact set fails loudly, not numerically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EnvGeometry {
    pub grid_w: usize,
    pub grid_h: usize,
    pub view: usize,
    pub obs_channels: usize,
    pub num_actions: usize,
    /// Student flat observation component lengths (artifact input order).
    pub obs_components: Vec<usize>,
    pub adv_num_actions: usize,
    pub adv_noise_dim: usize,
}

impl EnvGeometry {
    /// The maze family's geometry — also the compiled-artifact default.
    pub fn maze_default() -> EnvGeometry {
        EnvGeometry {
            grid_w: level::GRID_W,
            grid_h: level::GRID_H,
            view: maze::VIEW,
            obs_channels: maze::OBS_CHANNELS,
            num_actions: maze::NUM_ACTIONS,
            obs_components: vec![maze::IMG_LEN, maze::DIR_LEN],
            adv_num_actions: level::GRID_CELLS,
            adv_noise_dim: editor::NOISE_DIM,
        }
    }
}

/// One environment's complete capability bundle. Implementations are
/// zero-sized tags (`MazeFamily`, `LavaFamily`); the [`registry`] selects
/// one from `--env` and every layer above is generic over it.
///
/// The `'static` bounds (including the env-state where-clause) let
/// algorithm drivers built from a family live behind
/// `Box<dyn UedAlgorithm>`; `Send` lets those drivers (which may hold the
/// family tag) move onto seed-pack driver threads. Implementations are
/// zero-sized, so both are free.
pub trait EnvFamily: Copy + Default + Send + 'static
where
    <Self::Env as UnderspecifiedEnv>::State: 'static,
{
    /// The student UPOMDP.
    type Env: UnderspecifiedEnv<Level = Self::Level> + 'static;
    /// Its level type.
    type Level: LevelMeta + 'static;
    /// The base DR distribution.
    type Generator: LevelGenerator<Level = Self::Level> + 'static;
    /// The ACCEL edit operator.
    type Mutator: LevelMutator<Level = Self::Level> + 'static;
    /// The PAIRED adversary's level-construction UPOMDP.
    type Editor: UnderspecifiedEnv<Level = editor::EditorTask, State = editor::EditorState>
        + 'static;

    /// Stable family name (`--env` key, run-dir and artifact scoping).
    fn id(&self) -> &'static str;

    /// Geometry the artifacts must match.
    fn geometry(&self) -> EnvGeometry;

    fn make_env(&self, p: &EnvParams) -> Self::Env;
    fn make_generator(&self, p: &EnvParams) -> Self::Generator;
    fn make_mutator(&self, p: &EnvParams) -> Self::Mutator;
    fn make_editor(&self, p: &EnvParams) -> Self::Editor;

    /// Extract a playable level from a finished editor episode.
    fn editor_level(&self, s: &editor::EditorState) -> Self::Level;

    /// The named holdout levels plus `n_procedural` deterministic
    /// solvable-filtered draws (paper §6.1 evaluation suite).
    fn holdout(&self, n_procedural: usize) -> Vec<(String, Self::Level)>;
}

/// Adapter: any `Fn(&mut Pcg64) -> L` closure as a [`LevelGenerator`]
/// (ad-hoc level distributions for tests and tools).
pub struct FnLevelGen<L, F: Fn(&mut Pcg64) -> L> {
    f: F,
    _marker: std::marker::PhantomData<fn() -> L>,
}

impl<L, F: Fn(&mut Pcg64) -> L> FnLevelGen<L, F> {
    pub fn new(f: F) -> Self {
        FnLevelGen { f, _marker: std::marker::PhantomData }
    }
}

impl<L: Clone, F: Fn(&mut Pcg64) -> L + Sync + Send> LevelGenerator for FnLevelGen<L, F> {
    type Level = L;

    fn sample_level(&self, rng: &mut Pcg64) -> L {
        (self.f)(rng)
    }
}
