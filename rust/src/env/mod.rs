//! The `UnderspecifiedEnv` interface (paper §3.1) and the maze environments.
//!
//! UED operates over Underspecified POMDPs: a *collection* of POMDPs indexed
//! by free parameters ("levels"). Conventional env interfaces bake an
//! implicit level distribution into `reset()`; `UnderspecifiedEnv` instead
//! exposes `reset_to_level`, pushing level-distribution management to the
//! caller (a UED algorithm, an evaluation routine, a wrapper). Levels are
//! decoupled from states: a level induces a (possibly stochastic) initial
//! state distribution.

pub mod editor;
pub mod gen;
pub mod holdout;
pub mod level;
pub mod maze;
pub mod mutate;
pub mod render;
pub mod shortest_path;
pub mod wrappers;

pub use level::Level;

use crate::util::rng::Pcg64;

/// Result of one environment transition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepResult {
    pub reward: f32,
    /// Episode terminated at this transition (goal reached or truncation).
    pub done: bool,
}

/// A POMDP family indexed by levels (paper §3.1).
///
/// `State` is the full environment state; `Level` the underspecified
/// parameters; `Obs` an associated observation buffer the env writes into
/// (the rollout engine owns the backing storage — observation writing is
/// allocation-free).
pub trait UnderspecifiedEnv {
    type State: Clone;
    type Level: Clone;

    /// Number of discrete actions.
    fn num_actions(&self) -> usize;

    /// Stochastically initialize a state from a level (never an implicit
    /// level distribution — that is the caller's job).
    fn reset_to_level(&self, level: &Self::Level, rng: &mut Pcg64) -> Self::State;

    /// Transition. Returns reward and termination; mutates the state.
    fn step(&self, state: &mut Self::State, action: usize, rng: &mut Pcg64) -> StepResult;

    /// Write the observation of `state` into `obs` (length = obs_len()).
    fn observe(&self, state: &Self::State, obs: &mut [f32]);

    /// Flat observation length.
    fn obs_len(&self) -> usize;

    /// Lengths of the observation's components, in the order the policy
    /// artifact expects its observation inputs (e.g. the student policy
    /// takes `[img(75), dir(4)]`). The flat `observe` buffer is the
    /// concatenation of these.
    fn obs_components(&self) -> Vec<usize> {
        vec![self.obs_len()]
    }
}
