//! Holdout evaluation suites (paper §6.1 / Figure 2).
//!
//! The paper evaluates on (a) the procedurally-generated minimax holdout
//! levels and (b) implicitly, the classic named mazes from the DCD
//! literature. We cannot ship minimax's exact generated files, so we
//! reproduce its *recipe* deterministically (uniform wall budget,
//! solvable-filtered, fixed seed — see DESIGN.md substitutions) and provide
//! programmatic constructions of the classic named layouts (Labyrinth,
//! SixteenRooms, FourRooms, perfect DFS mazes, corridors, …). All
//! constructions are verified solvable by unit tests.

use super::gen::MazeLevelGenerator;
use super::level::{Dir, Level, WallSet, GRID_H, GRID_W};
use crate::util::rng::Pcg64;

/// A named evaluation level.
#[derive(Clone, Debug)]
pub struct NamedLevel {
    pub name: &'static str,
    pub level: Level,
}

/// The deterministic procedural holdout suite: `n` solvable levels drawn
/// from the DR distribution with the given wall budget — the minimax
/// `generate_eval_levels` recipe with a fixed seed.
pub fn procedural_suite(n: usize, max_walls: usize, seed: u64) -> Vec<Level> {
    let gen = MazeLevelGenerator::new(max_walls);
    let mut rng = Pcg64::new(seed, 0x4544); // "ED"
    (0..n).map(|_| gen.generate_solvable(&mut rng, 1000)).collect()
}

/// All named holdout levels.
pub fn named_levels() -> Vec<NamedLevel> {
    vec![
        NamedLevel { name: "Empty", level: empty() },
        NamedLevel { name: "FourRooms", level: four_rooms() },
        NamedLevel { name: "SixteenRooms", level: sixteen_rooms(0) },
        NamedLevel { name: "SixteenRooms2", level: sixteen_rooms(1) },
        NamedLevel { name: "Labyrinth", level: labyrinth(false) },
        NamedLevel { name: "LabyrinthFlipped", level: labyrinth(true) },
        NamedLevel { name: "Maze", level: dfs_maze(7) },
        NamedLevel { name: "Maze2", level: dfs_maze(21) },
        NamedLevel { name: "Maze3", level: dfs_maze(1729) },
        NamedLevel { name: "Crossing", level: crossing() },
        NamedLevel { name: "SmallCorridor", level: corridor(4) },
        NamedLevel { name: "LargeCorridor", level: corridor(11) },
    ]
}

fn empty() -> Level {
    let mut l = Level::empty();
    l.agent_pos = (0, 12);
    l.agent_dir = Dir::Up;
    l.goal_pos = (12, 0);
    l
}

/// Four 6×6 rooms with one door per internal wall.
fn four_rooms() -> Level {
    let mut w = WallSet::empty();
    for i in 0..GRID_W {
        w.set(6, i, true);
        w.set(i, 6, true);
    }
    // doors
    w.set(6, 3, false);
    w.set(6, 9, false);
    w.set(3, 6, false);
    w.set(9, 6, false);
    Level {
        walls: w,
        agent_pos: (1, 11),
        agent_dir: Dir::Up,
        goal_pos: (11, 1),
    }
}

/// 4×4 grid of small rooms, dividers at {3, 7, 11}? — use {3, 6, 9} with
/// per-segment doors; `variant` shifts the door positions.
fn sixteen_rooms(variant: usize) -> Level {
    let mut w = WallSet::empty();
    let lines = [3usize, 6, 9];
    for &c in &lines {
        for i in 0..GRID_W {
            w.set(c, i, true);
            w.set(i, c, true);
        }
    }
    // carve one door per wall segment; segments between lines
    let spans = [(0usize, 2usize), (4, 5), (7, 8), (10, 12)];
    for (si, &(lo, hi)) in spans.iter().enumerate() {
        for (li, &c) in lines.iter().enumerate() {
            let door = lo + (si + li + variant) % (hi - lo + 1);
            w.set(c, door, false); // vertical wall door
            let door2 = lo + (si + 2 * li + variant) % (hi - lo + 1);
            w.set(door2, c, false); // horizontal wall door
        }
    }
    Level {
        walls: w,
        agent_pos: (0, 0),
        agent_dir: Dir::Down,
        goal_pos: (12, 12),
    }
}

/// Spiral labyrinth: concentric rings with alternating gaps, goal at the
/// center. `flipped` mirrors it horizontally.
fn labyrinth(flipped: bool) -> Level {
    let mut w = WallSet::empty();
    // rings at offset 1, 3, 5 (square annuli)
    for (ring, &off) in [1usize, 3, 5].iter().enumerate() {
        let hi = GRID_W - 1 - off;
        for i in off..=hi {
            w.set(i, off, true);
            w.set(i, hi, true);
            w.set(off, i, true);
            w.set(hi, i, true);
        }
        // gap: alternate bottom-center / top-center per ring
        if ring % 2 == 0 {
            w.set(6, hi, false);
        } else {
            w.set(6, off, false);
        }
    }
    let mut l = Level {
        walls: w,
        agent_pos: (0, 12),
        agent_dir: Dir::Up,
        goal_pos: (6, 6),
    };
    if flipped {
        let mut fw = WallSet::empty();
        for y in 0..GRID_H {
            for x in 0..GRID_W {
                if l.walls.get(x, y) {
                    fw.set(GRID_W - 1 - x, y, true);
                }
            }
        }
        l.walls = fw;
        l.agent_pos = (12, 12);
    }
    l
}

/// Perfect maze via recursive backtracker on the 7×7 odd-cell lattice
/// (cells at even coordinates, walls between). Deterministic per seed.
fn dfs_maze(seed: u64) -> Level {
    let mut rng = Pcg64::new(seed, 0x6d61_7a65); // "maze"
    // start from all-walls; carve cells and passages
    let mut w = WallSet::empty();
    for y in 0..GRID_H {
        for x in 0..GRID_W {
            w.set(x, y, true);
        }
    }
    let lattice = 7; // cells at (2i, 2j)
    let mut visited = [[false; 7]; 7];
    let mut stack = vec![(0usize, 0usize)];
    visited[0][0] = true;
    w.set(0, 0, false);
    while let Some(&(cx, cy)) = stack.last() {
        // unvisited lattice neighbors
        let mut nbrs: Vec<(usize, usize)> = Vec::with_capacity(4);
        if cx > 0 && !visited[cy][cx - 1] {
            nbrs.push((cx - 1, cy));
        }
        if cx + 1 < lattice && !visited[cy][cx + 1] {
            nbrs.push((cx + 1, cy));
        }
        if cy > 0 && !visited[cy - 1][cx] {
            nbrs.push((cx, cy - 1));
        }
        if cy + 1 < lattice && !visited[cy + 1][cx] {
            nbrs.push((cx, cy + 1));
        }
        if nbrs.is_empty() {
            stack.pop();
            continue;
        }
        let (nx, ny) = *nbrs.get(rng.gen_range(nbrs.len())).unwrap();
        visited[ny][nx] = true;
        w.set(2 * nx, 2 * ny, false);
        // carve the wall between
        w.set(cx + nx, cy + ny, false);
        stack.push((nx, ny));
    }
    Level {
        walls: w,
        agent_pos: (0, 0),
        agent_dir: Dir::Right,
        goal_pos: (12, 12),
    }
}

/// Horizontal walls with staggered gaps (MiniGrid "SimpleCrossing" style).
fn crossing() -> Level {
    let mut w = WallSet::empty();
    for (i, &y) in [2usize, 5, 8, 11].iter().enumerate() {
        for x in 0..GRID_W {
            w.set(x, y, true);
        }
        let gap = if i % 2 == 0 { 1 } else { GRID_W - 2 };
        w.set(gap, y, false);
    }
    Level {
        walls: w,
        agent_pos: (6, 0),
        agent_dir: Dir::Down,
        goal_pos: (6, 12),
    }
}

/// Corridor: the agent starts in a dead-end corridor of the given length
/// and must exit it to find the goal behind the other branch.
fn corridor(len: usize) -> Level {
    assert!((2..=11).contains(&len));
    let mut w = WallSet::empty();
    // two parallel corridors at y=5..7 separated from the rest
    for x in 0..GRID_W {
        w.set(x, 4, true);
        w.set(x, 8, true);
    }
    for x in 1..GRID_W {
        w.set(x, 6, true); // divider between the two corridors
    }
    // seal corridor ends except the shared mouth at x=0
    w.set(12, 5, true);
    w.set(12, 7, true);
    // goal sits inside the lower corridor at depth `len`
    Level {
        walls: w,
        agent_pos: (1, 5),
        agent_dir: Dir::Left,
        goal_pos: (len as u8, 7),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::shortest_path::{is_solvable, solve_distance};

    #[test]
    fn all_named_levels_valid_and_solvable() {
        for nl in named_levels() {
            assert!(nl.level.is_valid(), "{} invalid", nl.name);
            assert!(is_solvable(&nl.level), "{} unsolvable", nl.name);
        }
    }

    #[test]
    fn named_levels_distinct() {
        let levels = named_levels();
        for i in 0..levels.len() {
            for j in (i + 1)..levels.len() {
                assert_ne!(
                    levels[i].level.fingerprint(),
                    levels[j].level.fingerprint(),
                    "{} == {}", levels[i].name, levels[j].name
                );
            }
        }
    }

    #[test]
    fn labyrinth_is_long() {
        // spiral must force a long path to the center
        let d = solve_distance(&labyrinth(false)).unwrap();
        assert!(d >= 30, "labyrinth too easy: {d}");
    }

    #[test]
    fn labyrinth_flip_is_mirror() {
        let a = labyrinth(false);
        let b = labyrinth(true);
        for y in 0..GRID_H {
            for x in 0..GRID_W {
                assert_eq!(a.walls.get(x, y), b.walls.get(GRID_W - 1 - x, y));
            }
        }
    }

    #[test]
    fn dfs_maze_is_perfect_ish() {
        // Perfect maze on the lattice: all 49 lattice cells reachable.
        let m = dfs_maze(7);
        let df = crate::env::shortest_path::distance_field(&m);
        for cy in 0..7 {
            for cx in 0..7 {
                assert_ne!(
                    df.get(2 * cx, 2 * cy),
                    crate::env::shortest_path::UNREACHABLE,
                    "lattice cell ({cx},{cy}) unreachable"
                );
            }
        }
    }

    #[test]
    fn dfs_maze_seeds_differ() {
        assert_ne!(dfs_maze(7).fingerprint(), dfs_maze(21).fingerprint());
    }

    #[test]
    fn corridor_lengths_affect_difficulty() {
        let short = solve_distance(&corridor(4)).unwrap();
        let long = solve_distance(&corridor(11)).unwrap();
        assert!(long > short);
    }

    #[test]
    fn procedural_suite_deterministic_and_solvable() {
        let a = procedural_suite(20, 60, 42);
        let b = procedural_suite(20, 60, 42);
        assert_eq!(a, b);
        for l in &a {
            assert!(is_solvable(l));
            assert!(l.num_walls() <= 60);
        }
        let c = procedural_suite(20, 60, 43);
        assert_ne!(a, c);
    }
}
