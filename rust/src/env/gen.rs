//! Maze level generation (paper §4): the base Domain-Randomization
//! distribution used by DR and by the PLR family's `on_new_levels` cycle,
//! implementing the [`LevelGenerator`](crate::env::LevelGenerator) trait.
//!
//! Recipe (matching JaxUED/minimax `make_level_generator`): sample a wall
//! count uniformly in [0, max_walls], place that many walls at distinct
//! random cells, then place the goal and the agent (random direction) on
//! distinct free cells. The paper's Figure 3 sweeps `max_walls ∈ {25, 60}`.

use super::level::{Dir, Level, WallSet, GRID_CELLS, GRID_W};
use super::shortest_path::is_solvable;
use super::LevelGenerator;
use crate::util::rng::Pcg64;

/// Base-distribution parameters for the maze family.
#[derive(Clone, Copy, Debug)]
pub struct MazeLevelGenerator {
    pub max_walls: usize,
}

impl MazeLevelGenerator {
    pub fn new(max_walls: usize) -> Self {
        assert!(max_walls <= GRID_CELLS - 2, "must leave room for agent+goal");
        MazeLevelGenerator { max_walls }
    }

    /// One draw from the DR distribution. Always structurally valid;
    /// solvability is *not* guaranteed (faithful to the paper — unsolvable
    /// draws are part of the DR distribution and it is UED's job to cope).
    pub fn generate(&self, rng: &mut Pcg64) -> Level {
        let n_walls = rng.gen_range(self.max_walls + 1);
        // Distinct cells for walls + goal + agent via partial Fisher-Yates
        // over the 169 cells.
        let cells = rng.sample_indices(GRID_CELLS, n_walls + 2);
        let mut walls = WallSet::empty();
        for &c in &cells[..n_walls] {
            walls.set(c % GRID_W, c / GRID_W, true);
        }
        let g = cells[n_walls];
        let a = cells[n_walls + 1];
        Level {
            walls,
            agent_pos: ((a % GRID_W) as u8, (a / GRID_W) as u8),
            agent_dir: Dir::from_index(rng.gen_range(4)),
            goal_pos: ((g % GRID_W) as u8, (g / GRID_W) as u8),
        }
    }

    /// Rejection-sample a solvable level (used for evaluation suites, which
    /// are solvable-filtered in minimax). Panics if `max_tries` exhausted —
    /// with max_walls ≤ 60 on a 169-cell grid the acceptance rate is high.
    pub fn generate_solvable(&self, rng: &mut Pcg64, max_tries: usize) -> Level {
        for _ in 0..max_tries {
            let l = self.generate(rng);
            if is_solvable(&l) {
                return l;
            }
        }
        panic!("no solvable level in {max_tries} tries (max_walls={})", self.max_walls);
    }

    /// A batch of independent draws.
    pub fn generate_batch(&self, n: usize, rng: &mut Pcg64) -> Vec<Level> {
        (0..n).map(|_| self.generate(rng)).collect()
    }
}

impl LevelGenerator for MazeLevelGenerator {
    type Level = Level;

    fn sample_level(&self, rng: &mut Pcg64) -> Level {
        self.generate(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::props;

    #[test]
    fn generated_levels_valid() {
        let g = MazeLevelGenerator::new(60);
        let mut rng = Pcg64::seed_from_u64(0);
        for _ in 0..200 {
            let l = g.generate(&mut rng);
            assert!(l.is_valid());
            assert!(l.num_walls() <= 60);
        }
    }

    #[test]
    fn respects_wall_budget_25() {
        let g = MazeLevelGenerator::new(25);
        let mut rng = Pcg64::seed_from_u64(1);
        for _ in 0..200 {
            assert!(g.generate(&mut rng).num_walls() <= 25);
        }
    }

    #[test]
    fn wall_count_roughly_uniform() {
        let g = MazeLevelGenerator::new(10);
        let mut rng = Pcg64::seed_from_u64(2);
        let mut counts = [0usize; 11];
        let n = 22_000;
        for _ in 0..n {
            counts[g.generate(&mut rng).num_walls()] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 11.0;
            assert!((c as f64 - expect).abs() < expect * 0.15, "{counts:?}");
        }
    }

    #[test]
    fn solvable_generator_is_solvable() {
        let g = MazeLevelGenerator::new(60);
        let mut rng = Pcg64::seed_from_u64(3);
        for _ in 0..50 {
            let l = g.generate_solvable(&mut rng, 100);
            assert!(is_solvable(&l));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = MazeLevelGenerator::new(40);
        let a = g.generate_batch(5, &mut Pcg64::seed_from_u64(9));
        let b = g.generate_batch(5, &mut Pcg64::seed_from_u64(9));
        assert_eq!(a, b);
    }

    #[test]
    fn trait_and_inherent_draws_agree() {
        let g = MazeLevelGenerator::new(40);
        let a = g.generate(&mut Pcg64::seed_from_u64(11));
        let b = g.sample_level(&mut Pcg64::seed_from_u64(11));
        assert_eq!(a, b);
    }

    #[test]
    fn prop_agent_goal_never_on_walls() {
        props(300, |gen| {
            let max_walls = gen.usize_in(0, 100);
            let g = MazeLevelGenerator::new(max_walls);
            let l = g.generate(gen.rng());
            prop_assert!(l.is_valid(), "invalid level {:?}", l);
            prop_assert!(
                l.num_walls() <= max_walls,
                "wall budget exceeded: {} > {max_walls}", l.num_walls()
            );
            Ok(())
        });
    }
}
