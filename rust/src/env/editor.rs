//! The level-*editor* environment (paper §4): the UPOMDP in which the
//! PAIRED adversary acts. The adversary policy sequentially constructs a
//! level via atomic modifications; its episode return is set externally to
//! the estimated regret (paper §5.3), so `step` always yields zero reward.
//!
//! Protocol (Dennis et al., 2020): each action is a flat cell index in the
//! 13×13 grid. Step 0 places the agent (with a random facing drawn at
//! placement), step 1 places the goal (deterministically displaced if it
//! collides with the agent), and every later step cycles the tile at the
//! targeted cell through the family's palette (no-op on the agent/goal
//! cells). With the default two-tile palette a cell cycles empty ↔ wall
//! (the classic wall toggle); the lava family's three-tile palette cycles
//! empty → wall → lava → empty. Both palettes share the 169-action space
//! and the observation layout (lava reads as 0.5 in the wall channel), so
//! one compiled adversary artifact drives every family. The episode ends
//! after `max_steps` edits.
//!
//! The editor's *level* is the conditioning noise vector z — PAIRED samples
//! a fresh z per generated level so the adversary can produce diverse
//! batches (without z, an argmax policy would emit 32 identical levels).

use super::level::{Dir, Level, WallSet, GRID_CELLS, GRID_H, GRID_W};
use super::{StepResult, UnderspecifiedEnv};
use crate::util::rng::Pcg64;

pub const NOISE_DIM: usize = 16;
pub const GRID_LEN: usize = GRID_CELLS * 3; // {tile, agent, goal} one-hot
pub const EDITOR_OBS_LEN: usize = GRID_LEN + 1 + NOISE_DIM;

/// Wall intensity in the editor's tile channel.
pub const TILE_WALL: f32 = 1.0;
/// Hazard (lava) intensity in the editor's tile channel.
pub const TILE_HAZARD: f32 = 0.5;

/// The editor env's underspecified parameter: the conditioning noise.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EditorTask {
    pub noise: [f32; NOISE_DIM],
}

impl EditorTask {
    pub fn sample(rng: &mut Pcg64) -> Self {
        let mut noise = [0.0; NOISE_DIM];
        for n in noise.iter_mut() {
            *n = rng.next_normal() as f32;
        }
        EditorTask { noise }
    }
}

/// Editor state: the partially-built level. Walls and hazards are disjoint
/// tile sets; the maze palette never populates `hazards`.
#[derive(Clone, Debug)]
pub struct EditorState {
    pub walls: WallSet,
    pub hazards: WallSet,
    pub agent: Option<((u8, u8), Dir)>,
    pub goal: Option<(u8, u8)>,
    pub t: u32,
    pub noise: [f32; NOISE_DIM],
}

impl EditorState {
    /// The placed agent and goal. Panics before both placements (t < 2).
    pub fn placements(&self) -> (((u8, u8), Dir), (u8, u8)) {
        match (self.agent, self.goal) {
            (Some(a), Some(g)) => (a, g),
            _ => panic!("level extraction before agent+goal placed (t={})", self.t),
        }
    }

    /// Extract the constructed maze level (two-tile palette). Valid once
    /// t >= 2. Hazard tiles, if any, are dropped — use the owning family's
    /// `editor_level` for hazard-aware extraction.
    pub fn to_level(&self) -> Level {
        let ((apos, adir), gpos) = self.placements();
        let mut walls = self.walls;
        // Placement protocol guarantees agent/goal cells are wall-free, but
        // keep the invariant explicit.
        walls.set(apos.0 as usize, apos.1 as usize, false);
        walls.set(gpos.0 as usize, gpos.1 as usize, false);
        Level { walls, agent_pos: apos, agent_dir: adir, goal_pos: gpos }
    }
}

/// The level-editor UPOMDP, parameterized by the tile palette size.
#[derive(Clone, Copy, Debug)]
pub struct EditorEnv {
    /// Total edit budget (the paper's PAIRED-25 / PAIRED-60 editor steps).
    pub max_steps: usize,
    /// Palette size including empty: 2 = {empty, wall} (maze),
    /// 3 = {empty, wall, hazard} (lava).
    pub tile_kinds: u8,
}

impl EditorEnv {
    /// The classic maze editor: empty ↔ wall toggling.
    pub fn new(max_steps: usize) -> Self {
        Self::with_palette(max_steps, 2)
    }

    pub fn with_palette(max_steps: usize, tile_kinds: u8) -> Self {
        assert!(max_steps >= 2, "need at least agent+goal placement steps");
        assert!((2..=3).contains(&tile_kinds), "palette must be 2 or 3 tiles");
        EditorEnv { max_steps, tile_kinds }
    }
}

fn cell_xy(action: usize) -> (u8, u8) {
    debug_assert!(action < GRID_CELLS);
    ((action % GRID_W) as u8, (action / GRID_W) as u8)
}

impl UnderspecifiedEnv for EditorEnv {
    type State = EditorState;
    type Level = EditorTask;

    fn num_actions(&self) -> usize {
        GRID_CELLS
    }

    fn reset_to_level(&self, task: &EditorTask, _rng: &mut Pcg64) -> EditorState {
        EditorState {
            walls: WallSet::empty(),
            hazards: WallSet::empty(),
            agent: None,
            goal: None,
            t: 0,
            noise: task.noise,
        }
    }

    // ued-lint: allow(serve-panic) — the t=0/t=1 arms place agent and goal before any t>=2 step can read them; the expects encode that phase invariant
    fn step(&self, s: &mut EditorState, action: usize, rng: &mut Pcg64) -> StepResult {
        let pos = cell_xy(action);
        match s.t {
            0 => {
                let dir = Dir::from_index(rng.gen_range(4));
                s.agent = Some((pos, dir));
            }
            1 => {
                let apos = s.agent.expect("agent placed at t=0").0;
                let mut g = pos;
                if g == apos {
                    // Deterministic displacement: next cell in scan order.
                    let flat = (g.1 as usize * GRID_W + g.0 as usize + 1) % GRID_CELLS;
                    g = cell_xy(flat);
                }
                s.goal = Some(g);
            }
            _ => {
                let apos = s.agent.expect("agent placed").0;
                let gpos = s.goal.expect("goal placed");
                if pos != apos && pos != gpos {
                    let (x, y) = (pos.0 as usize, pos.1 as usize);
                    // Cycle the tile through the palette:
                    // empty → wall → (hazard →) empty.
                    if s.walls.get(x, y) {
                        s.walls.set(x, y, false);
                        if self.tile_kinds >= 3 {
                            s.hazards.set(x, y, true);
                        }
                    } else if s.hazards.get(x, y) {
                        s.hazards.set(x, y, false);
                    } else {
                        s.walls.set(x, y, true);
                    }
                }
            }
        }
        s.t += 1;
        StepResult { reward: 0.0, done: s.t as usize >= self.max_steps }
    }

    fn observe(&self, s: &EditorState, obs: &mut [f32]) {
        debug_assert_eq!(obs.len(), EDITOR_OBS_LEN);
        obs.fill(0.0);
        for y in 0..GRID_H {
            for x in 0..GRID_W {
                let base = (y * GRID_W + x) * 3;
                if s.walls.get(x, y) {
                    obs[base] = TILE_WALL;
                } else if s.hazards.get(x, y) {
                    obs[base] = TILE_HAZARD;
                }
            }
        }
        if let Some(((ax, ay), _)) = s.agent {
            obs[(ay as usize * GRID_W + ax as usize) * 3 + 1] = 1.0;
        }
        if let Some((gx, gy)) = s.goal {
            obs[(gy as usize * GRID_W + gx as usize) * 3 + 2] = 1.0;
        }
        obs[GRID_LEN] = s.t as f32 / self.max_steps as f32;
        obs[GRID_LEN + 1..].copy_from_slice(&s.noise);
    }

    fn obs_len(&self) -> usize {
        EDITOR_OBS_LEN
    }

    fn obs_components(&self) -> Vec<usize> {
        vec![GRID_LEN, 1, NOISE_DIM]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::props;

    fn rng() -> Pcg64 {
        Pcg64::seed_from_u64(0)
    }

    #[test]
    fn placement_protocol() {
        let e = EditorEnv::new(10);
        let mut r = rng();
        let task = EditorTask::sample(&mut r);
        let mut s = e.reset_to_level(&task, &mut r);
        e.step(&mut s, 5, &mut r); // agent at (5,0)
        assert_eq!(s.agent.unwrap().0, (5, 0));
        e.step(&mut s, 20, &mut r); // goal at (7,1)
        assert_eq!(s.goal.unwrap(), (7, 1));
        e.step(&mut s, 40, &mut r); // wall toggle
        assert!(s.walls.get(40 % GRID_W, 40 / GRID_W));
        e.step(&mut s, 40, &mut r); // toggle back
        assert!(!s.walls.get(40 % GRID_W, 40 / GRID_W));
        assert_eq!(s.hazards.count(), 0, "two-tile palette never places hazards");
    }

    #[test]
    fn three_tile_palette_cycles_through_hazard() {
        let e = EditorEnv::with_palette(8, 3);
        let mut r = rng();
        let mut s = e.reset_to_level(&EditorTask::sample(&mut r), &mut r);
        e.step(&mut s, 0, &mut r);
        e.step(&mut s, 1, &mut r);
        let c = 40;
        e.step(&mut s, c, &mut r); // empty → wall
        assert!(s.walls.get(c % GRID_W, c / GRID_W));
        e.step(&mut s, c, &mut r); // wall → hazard
        assert!(!s.walls.get(c % GRID_W, c / GRID_W));
        assert!(s.hazards.get(c % GRID_W, c / GRID_W));
        e.step(&mut s, c, &mut r); // hazard → empty
        assert!(!s.hazards.get(c % GRID_W, c / GRID_W));
    }

    #[test]
    fn goal_collision_displaces() {
        let e = EditorEnv::new(5);
        let mut r = rng();
        let mut s = e.reset_to_level(&EditorTask::sample(&mut r), &mut r);
        e.step(&mut s, 0, &mut r);
        e.step(&mut s, 0, &mut r); // same cell as agent
        assert_ne!(s.goal.unwrap(), s.agent.unwrap().0);
        assert_eq!(s.goal.unwrap(), (1, 0));
    }

    #[test]
    fn wall_on_agent_goal_is_noop() {
        let e = EditorEnv::new(6);
        let mut r = rng();
        let mut s = e.reset_to_level(&EditorTask::sample(&mut r), &mut r);
        e.step(&mut s, 10, &mut r);
        e.step(&mut s, 20, &mut r);
        e.step(&mut s, 10, &mut r); // agent cell: no wall
        e.step(&mut s, 20, &mut r); // goal cell: no wall
        assert_eq!(s.walls.count(), 0);
    }

    #[test]
    fn terminates_at_budget() {
        let e = EditorEnv::new(4);
        let mut r = rng();
        let mut s = e.reset_to_level(&EditorTask::sample(&mut r), &mut r);
        assert!(!e.step(&mut s, 0, &mut r).done);
        assert!(!e.step(&mut s, 1, &mut r).done);
        assert!(!e.step(&mut s, 2, &mut r).done);
        assert!(e.step(&mut s, 3, &mut r).done);
    }

    #[test]
    fn observation_layout() {
        let e = EditorEnv::new(8);
        let mut r = rng();
        let task = EditorTask::sample(&mut r);
        let mut s = e.reset_to_level(&task, &mut r);
        e.step(&mut s, 0, &mut r); // agent (0,0)
        e.step(&mut s, 168, &mut r); // goal (12,12)
        e.step(&mut s, 6, &mut r); // wall (6,0)
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        assert_eq!(obs[0 * 3 + 1], 1.0, "agent channel");
        assert_eq!(obs[168 * 3 + 2], 1.0, "goal channel");
        assert_eq!(obs[6 * 3], TILE_WALL, "wall channel");
        assert!((obs[GRID_LEN] - 3.0 / 8.0).abs() < 1e-6, "timestep");
        assert_eq!(&obs[GRID_LEN + 1..], &task.noise[..]);
    }

    #[test]
    fn hazard_observation_intensity() {
        let e = EditorEnv::with_palette(8, 3);
        let mut r = rng();
        let mut s = e.reset_to_level(&EditorTask::sample(&mut r), &mut r);
        e.step(&mut s, 0, &mut r);
        e.step(&mut s, 1, &mut r);
        e.step(&mut s, 6, &mut r); // wall
        e.step(&mut s, 6, &mut r); // → hazard
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        assert_eq!(obs[6 * 3], TILE_HAZARD);
    }

    #[test]
    fn prop_full_episode_yields_valid_level() {
        props(100, |g| {
            let budget = g.usize_in(2, 60);
            let e = EditorEnv::new(budget);
            let task = EditorTask::sample(g.rng());
            let mut s = e.reset_to_level(&task, g.rng());
            let mut done = false;
            while !done {
                let a = g.usize_in(0, GRID_CELLS - 1);
                done = e.step(&mut s, a, g.rng()).done;
            }
            let level = s.to_level();
            prop_assert!(level.is_valid(), "editor produced invalid level: {:?}", level);
            Ok(())
        });
    }
}
