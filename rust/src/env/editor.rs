//! The maze-*editor* environment (paper §4): the UPOMDP in which the PAIRED
//! adversary acts. The adversary policy sequentially constructs a maze
//! level via atomic modifications; its episode return is set externally to
//! the estimated regret (paper §5.3), so `step` always yields zero reward.
//!
//! Protocol (Dennis et al., 2020): each action is a flat cell index in the
//! 13×13 grid. Step 0 places the agent (with a random facing drawn at
//! placement), step 1 places the goal (deterministically displaced if it
//! collides with the agent), and every later step toggles a wall (no-op on
//! the agent/goal cells). The episode ends after `max_steps` edits.
//!
//! The editor's *level* is the conditioning noise vector z — PAIRED samples
//! a fresh z per generated level so the adversary can produce diverse
//! batches (without z, an argmax policy would emit 32 identical levels).

use super::level::{Dir, Level, WallSet, GRID_CELLS, GRID_H, GRID_W};
use super::{StepResult, UnderspecifiedEnv};
use crate::util::rng::Pcg64;

pub const NOISE_DIM: usize = 16;
pub const GRID_LEN: usize = GRID_CELLS * 3; // {wall, agent, goal} one-hot
pub const EDITOR_OBS_LEN: usize = GRID_LEN + 1 + NOISE_DIM;

/// The editor env's underspecified parameter: the conditioning noise.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EditorTask {
    pub noise: [f32; NOISE_DIM],
}

impl EditorTask {
    pub fn sample(rng: &mut Pcg64) -> Self {
        let mut noise = [0.0; NOISE_DIM];
        for n in noise.iter_mut() {
            *n = rng.next_normal() as f32;
        }
        EditorTask { noise }
    }
}

/// Editor state: the partially-built level.
#[derive(Clone, Debug)]
pub struct EditorState {
    pub walls: WallSet,
    pub agent: Option<((u8, u8), Dir)>,
    pub goal: Option<(u8, u8)>,
    pub t: u32,
    pub noise: [f32; NOISE_DIM],
}

impl EditorState {
    /// Extract the constructed level. Valid once t >= 2.
    pub fn to_level(&self) -> Level {
        let ((apos, adir), gpos) = match (self.agent, self.goal) {
            (Some(a), Some(g)) => (a, g),
            _ => panic!("to_level before agent+goal placed (t={})", self.t),
        };
        let mut walls = self.walls;
        // Placement protocol guarantees agent/goal cells are wall-free, but
        // keep the invariant explicit.
        walls.set(apos.0 as usize, apos.1 as usize, false);
        walls.set(gpos.0 as usize, gpos.1 as usize, false);
        Level { walls, agent_pos: apos, agent_dir: adir, goal_pos: gpos }
    }
}

/// The maze-editor UPOMDP.
#[derive(Clone, Copy, Debug)]
pub struct EditorEnv {
    /// Total edit budget (the paper's PAIRED-25 / PAIRED-60 editor steps).
    pub max_steps: usize,
}

impl EditorEnv {
    pub fn new(max_steps: usize) -> Self {
        assert!(max_steps >= 2, "need at least agent+goal placement steps");
        EditorEnv { max_steps }
    }
}

fn cell_xy(action: usize) -> (u8, u8) {
    debug_assert!(action < GRID_CELLS);
    ((action % GRID_W) as u8, (action / GRID_W) as u8)
}

impl UnderspecifiedEnv for EditorEnv {
    type State = EditorState;
    type Level = EditorTask;

    fn num_actions(&self) -> usize {
        GRID_CELLS
    }

    fn reset_to_level(&self, task: &EditorTask, _rng: &mut Pcg64) -> EditorState {
        EditorState {
            walls: WallSet::empty(),
            agent: None,
            goal: None,
            t: 0,
            noise: task.noise,
        }
    }

    fn step(&self, s: &mut EditorState, action: usize, rng: &mut Pcg64) -> StepResult {
        let pos = cell_xy(action);
        match s.t {
            0 => {
                let dir = Dir::from_index(rng.gen_range(4));
                s.agent = Some((pos, dir));
            }
            1 => {
                let apos = s.agent.expect("agent placed at t=0").0;
                let mut g = pos;
                if g == apos {
                    // Deterministic displacement: next cell in scan order.
                    let flat = (g.1 as usize * GRID_W + g.0 as usize + 1) % GRID_CELLS;
                    g = cell_xy(flat);
                }
                s.goal = Some(g);
            }
            _ => {
                let apos = s.agent.expect("agent placed").0;
                let gpos = s.goal.expect("goal placed");
                if pos != apos && pos != gpos {
                    s.walls.toggle(pos.0 as usize, pos.1 as usize);
                }
            }
        }
        s.t += 1;
        StepResult { reward: 0.0, done: s.t as usize >= self.max_steps }
    }

    fn observe(&self, s: &EditorState, obs: &mut [f32]) {
        debug_assert_eq!(obs.len(), EDITOR_OBS_LEN);
        obs.fill(0.0);
        for y in 0..GRID_H {
            for x in 0..GRID_W {
                let base = (y * GRID_W + x) * 3;
                if s.walls.get(x, y) {
                    obs[base] = 1.0;
                }
            }
        }
        if let Some(((ax, ay), _)) = s.agent {
            obs[(ay as usize * GRID_W + ax as usize) * 3 + 1] = 1.0;
        }
        if let Some((gx, gy)) = s.goal {
            obs[(gy as usize * GRID_W + gx as usize) * 3 + 2] = 1.0;
        }
        obs[GRID_LEN] = s.t as f32 / self.max_steps as f32;
        obs[GRID_LEN + 1..].copy_from_slice(&s.noise);
    }

    fn obs_len(&self) -> usize {
        EDITOR_OBS_LEN
    }

    fn obs_components(&self) -> Vec<usize> {
        vec![GRID_LEN, 1, NOISE_DIM]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::props;

    fn rng() -> Pcg64 {
        Pcg64::seed_from_u64(0)
    }

    #[test]
    fn placement_protocol() {
        let e = EditorEnv::new(10);
        let mut r = rng();
        let task = EditorTask::sample(&mut r);
        let mut s = e.reset_to_level(&task, &mut r);
        e.step(&mut s, 5, &mut r); // agent at (5,0)
        assert_eq!(s.agent.unwrap().0, (5, 0));
        e.step(&mut s, 20, &mut r); // goal at (7,1)
        assert_eq!(s.goal.unwrap(), (7, 1));
        e.step(&mut s, 40, &mut r); // wall toggle
        assert!(s.walls.get(40 % GRID_W, 40 / GRID_W));
        e.step(&mut s, 40, &mut r); // toggle back
        assert!(!s.walls.get(40 % GRID_W, 40 / GRID_W));
    }

    #[test]
    fn goal_collision_displaces() {
        let e = EditorEnv::new(5);
        let mut r = rng();
        let mut s = e.reset_to_level(&EditorTask::sample(&mut r), &mut r);
        e.step(&mut s, 0, &mut r);
        e.step(&mut s, 0, &mut r); // same cell as agent
        assert_ne!(s.goal.unwrap(), s.agent.unwrap().0);
        assert_eq!(s.goal.unwrap(), (1, 0));
    }

    #[test]
    fn wall_on_agent_goal_is_noop() {
        let e = EditorEnv::new(6);
        let mut r = rng();
        let mut s = e.reset_to_level(&EditorTask::sample(&mut r), &mut r);
        e.step(&mut s, 10, &mut r);
        e.step(&mut s, 20, &mut r);
        e.step(&mut s, 10, &mut r); // agent cell: no wall
        e.step(&mut s, 20, &mut r); // goal cell: no wall
        assert_eq!(s.walls.count(), 0);
    }

    #[test]
    fn terminates_at_budget() {
        let e = EditorEnv::new(4);
        let mut r = rng();
        let mut s = e.reset_to_level(&EditorTask::sample(&mut r), &mut r);
        assert!(!e.step(&mut s, 0, &mut r).done);
        assert!(!e.step(&mut s, 1, &mut r).done);
        assert!(!e.step(&mut s, 2, &mut r).done);
        assert!(e.step(&mut s, 3, &mut r).done);
    }

    #[test]
    fn observation_layout() {
        let e = EditorEnv::new(8);
        let mut r = rng();
        let task = EditorTask::sample(&mut r);
        let mut s = e.reset_to_level(&task, &mut r);
        e.step(&mut s, 0, &mut r); // agent (0,0)
        e.step(&mut s, 168, &mut r); // goal (12,12)
        e.step(&mut s, 6, &mut r); // wall (6,0)
        let mut obs = vec![0.0; e.obs_len()];
        e.observe(&s, &mut obs);
        assert_eq!(obs[0 * 3 + 1], 1.0, "agent channel");
        assert_eq!(obs[168 * 3 + 2], 1.0, "goal channel");
        assert_eq!(obs[6 * 3], 1.0, "wall channel");
        assert!((obs[GRID_LEN] - 3.0 / 8.0).abs() < 1e-6, "timestep");
        assert_eq!(&obs[GRID_LEN + 1..], &task.noise[..]);
    }

    #[test]
    fn prop_full_episode_yields_valid_level() {
        props(100, |g| {
            let budget = g.usize_in(2, 60);
            let e = EditorEnv::new(budget);
            let task = EditorTask::sample(g.rng());
            let mut s = e.reset_to_level(&task, g.rng());
            let mut done = false;
            while !done {
                let a = g.usize_in(0, GRID_CELLS - 1);
                done = e.step(&mut s, a, g.rng()).done;
            }
            let level = s.to_level();
            prop_assert!(level.is_valid(), "editor produced invalid level: {:?}", level);
            Ok(())
        });
    }
}
