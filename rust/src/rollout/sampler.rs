//! Host-side categorical action sampling from policy logits.
//!
//! The apply artifact returns `logits [B, A]`; sampling and log-prob
//! evaluation happen on the host (B·A is tiny — 32×3 for the student —
//! so a device round-trip per step would cost far more than the flops).
//! Numerically stable log-softmax; Gumbel-max sampling keeps a single
//! uniform draw per action.

use crate::util::rng::Pcg64;

/// Sample an action and return `(action, log_prob)` from one logits row.
pub fn sample_action(logits: &[f32], rng: &mut Pcg64) -> (usize, f32) {
    debug_assert!(!logits.is_empty());
    let mut best = 0usize;
    let mut best_val = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        // Gumbel(0,1) = -ln(-ln(U)); clamp away 0.
        let u = rng.next_f32().max(1e-12);
        let g = -(-(u.ln())).ln();
        let v = l + g;
        if v > best_val {
            best_val = v;
            best = i;
        }
    }
    (best, log_prob(logits, best))
}

/// Greedy argmax action (evaluation-mode option).
pub fn argmax_action(logits: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..logits.len() {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best
}

/// Stable log-softmax probability of `action`.
pub fn log_prob(logits: &[f32], action: usize) -> f32 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&l| (l - m).exp()).sum::<f32>().ln() + m;
    logits[action] - lse
}

/// Policy entropy from one logits row (diagnostics).
pub fn entropy(logits: &[f32]) -> f32 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exp: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
    let z: f32 = exp.iter().sum();
    let mut h = 0.0;
    for e in exp {
        let p = e / z;
        if p > 0.0 {
            h -= p * p.ln();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logprob_matches_softmax() {
        let logits = [1.0f32, 2.0, 3.0];
        let z: f32 = logits.iter().map(|l| l.exp()).sum();
        for (a, &l) in logits.iter().enumerate() {
            let expect = (l.exp() / z).ln();
            assert!((log_prob(&logits, a) - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn logprob_stable_large_logits() {
        let logits = [1000.0f32, 999.0, 998.0];
        let p = log_prob(&logits, 0);
        assert!(p.is_finite() && p < 0.0);
    }

    #[test]
    fn sampling_follows_distribution() {
        let logits = [0.0f32, (3.0f32).ln()]; // p = [0.25, 0.75]
        let mut rng = Pcg64::seed_from_u64(0);
        let n = 40_000;
        let mut count1 = 0;
        for _ in 0..n {
            let (a, lp) = sample_action(&logits, &mut rng);
            if a == 1 {
                count1 += 1;
                assert!((lp - 0.75f32.ln()).abs() < 1e-5);
            }
        }
        let frac = count1 as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax_action(&[0.1, 0.9, 0.5]), 1);
    }

    #[test]
    fn entropy_bounds() {
        let uniform = [0.0f32; 4];
        assert!((entropy(&uniform) - (4.0f32).ln()).abs() < 1e-5);
        let peaked = [100.0f32, 0.0, 0.0, 0.0];
        assert!(entropy(&peaked) < 1e-3);
    }
}
