//! Trajectory storage: the `[T, B, …]` buffers the train-step ABI wants.
//!
//! One `Trajectory` is allocated per rollout shape and reused across
//! update-cycles (the hot loop does not allocate). Observation components
//! are stored as separate tensors matching the artifact's positional
//! observation inputs.

use anyhow::Result;

use crate::util::tensor::{TensorF32, TensorI32};

/// Fixed-shape rollout storage.
pub struct Trajectory {
    pub t: usize,
    pub b: usize,
    /// One `[T, B, comp]` tensor per observation component.
    pub obs: Vec<TensorF32>,
    pub actions: TensorI32,
    pub logp: TensorF32,
    pub values: TensorF32,
    pub rewards: TensorF32,
    pub dones: TensorF32,
    pub last_value: TensorF32,
}

impl Trajectory {
    pub fn new(t: usize, b: usize, obs_components: &[usize]) -> Trajectory {
        Trajectory {
            t,
            b,
            obs: obs_components
                .iter()
                .map(|&c| TensorF32::zeros(&[t, b, c]))
                .collect(),
            actions: TensorI32::zeros(&[t, b]),
            logp: TensorF32::zeros(&[t, b]),
            values: TensorF32::zeros(&[t, b]),
            rewards: TensorF32::zeros(&[t, b]),
            dones: TensorF32::zeros(&[t, b]),
            last_value: TensorF32::zeros(&[b]),
        }
    }

    /// Trajectory-tensor argument tail for the train-step artifact:
    /// obs…, actions, old_logp, old_values, rewards, dones, last_value.
    /// `obs_dims` gives the artifact's structured observation shapes
    /// (e.g. `[T, B, 5, 5, 3]`) for the flat `[T, B, comp]` buffers.
    pub fn train_args(&self, obs_dims: &[Vec<usize>]) -> Result<Vec<xla::Literal>> {
        assert_eq!(obs_dims.len(), self.obs.len());
        let mut out = Vec::with_capacity(self.obs.len() + 6);
        for (o, dims) in self.obs.iter().zip(obs_dims) {
            out.push(o.to_literal_as(dims)?);
        }
        out.push(self.actions.to_literal()?);
        out.push(self.logp.to_literal()?);
        out.push(self.values.to_literal()?);
        out.push(self.rewards.to_literal()?);
        out.push(self.dones.to_literal()?);
        out.push(self.last_value.to_literal()?);
        Ok(out)
    }

    /// Argument list for the score artifact:
    /// values, rewards, dones, last_value (+ caller appends prev_max_return).
    pub fn score_args(&self) -> Result<Vec<xla::Literal>> {
        Ok(vec![
            self.values.to_literal()?,
            self.rewards.to_literal()?,
            self.dones.to_literal()?,
            self.last_value.to_literal()?,
        ])
    }

    /// Per-env (column) episode statistics from the stored rewards/dones.
    /// An episode counts as "solved" iff its terminal reward is positive
    /// (the goal-reward convention every registered env family follows).
    /// Returns, per column: (episodes completed, episodes solved, summed
    /// reward).
    pub fn episode_stats(&self) -> Vec<EpisodeStats> {
        let mut stats = vec![EpisodeStats::default(); self.b];
        for t in 0..self.t {
            for b in 0..self.b {
                let i = t * self.b + b;
                let r = self.rewards.data()[i];
                stats[b].reward_sum += r as f64;
                if self.dones.data()[i] > 0.5 {
                    stats[b].episodes += 1;
                    if r > 0.0 {
                        stats[b].solved += 1;
                    }
                    stats[b].max_end_reward = stats[b].max_end_reward.max(r);
                    stats[b].mean_end_reward += r as f64;
                }
            }
        }
        for s in stats.iter_mut() {
            if s.episodes > 0 {
                s.mean_end_reward /= s.episodes as f64;
            }
        }
        stats
    }
}

/// Per-column episode summary (PAIRED regret and logging use this).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EpisodeStats {
    pub episodes: u32,
    pub solved: u32,
    pub reward_sum: f64,
    /// Highest terminal reward across completed episodes (antagonist max).
    pub max_end_reward: f32,
    /// Mean terminal reward across completed episodes (protagonist mean).
    pub mean_end_reward: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let tr = Trajectory::new(4, 3, &[75, 4]);
        assert_eq!(tr.obs[0].shape(), &[4, 3, 75]);
        assert_eq!(tr.obs[1].shape(), &[4, 3, 4]);
        assert_eq!(tr.actions.shape(), &[4, 3]);
        assert_eq!(tr.last_value.shape(), &[3]);
    }

    #[test]
    fn episode_stats_counts() {
        let mut tr = Trajectory::new(4, 2, &[1]);
        // col 0: solve at t=1 (r=0.9), truncate at t=3 (r=0)
        tr.rewards.set(&[1, 0], 0.9);
        tr.dones.set(&[1, 0], 1.0);
        tr.dones.set(&[3, 0], 1.0);
        // col 1: nothing finishes
        let s = tr.episode_stats();
        assert_eq!(s[0].episodes, 2);
        assert_eq!(s[0].solved, 1);
        assert!((s[0].max_end_reward - 0.9).abs() < 1e-6);
        assert!((s[0].mean_end_reward - 0.45).abs() < 1e-6);
        assert_eq!(s[1].episodes, 0);
    }

    #[test]
    fn train_args_count() {
        let tr = Trajectory::new(2, 2, &[75, 4]);
        let dims = vec![vec![2, 2, 5, 5, 3], vec![2, 2, 4]];
        assert_eq!(tr.train_args(&dims).unwrap().len(), 2 + 6);
        assert_eq!(tr.score_args().unwrap().len(), 4);
    }
}
