//! The actor-pool substrate of the rollout engine: a persistent worker
//! pool for column-parallel host work, per-column RNG streams, and the
//! column-disjoint shared-access primitive the parallel phases use.
//!
//! Design invariants:
//!
//! * **Determinism is structural, not scheduled.** Every batch column owns
//!   a private [`Pcg64`] stream ([`ColumnRngs`]) and writes only its own
//!   disjoint slices, so the result of a parallel phase is a pure function
//!   of (master seed, column index) — bit-identical at any
//!   `--rollout-threads` setting, including 1. The integration test
//!   `rollout_determinism` pins this invariant.
//! * **Threads persist.** [`WorkerPool`] spawns its workers once and
//!   reuses them for every phase of every step of every rollout (the
//!   paper's hot loop runs millions of steps; per-step thread spawning
//!   would dominate). Work is broadcast as one type-erased closure per
//!   phase; workers take fixed contiguous column shards, which keeps the
//!   partition deterministic and cache-friendly.
//! * **The calling thread is worker 0.** `run` keeps the caller busy with
//!   its own shard; `run_overlapped` instead gives the caller a different
//!   task (the PJRT forward call) to overlap with the workers' column
//!   sweep.
//! * **Unsafety is audited and raced-checked.** Every `unsafe` site
//!   carries a SAFETY comment (enforced by `ued-lint`), and in debug
//!   builds [`ColumnAccess`] carries a per-element atomic claim map that
//!   panics with a column/thread diagnostic the moment two threads touch
//!   the same index within one phase. In release builds the claim map is
//!   compiled out entirely — [`race_detector_enabled`] reports which
//!   build you have, and `bench_rollout` asserts the accessor is back to
//!   two words (no atomics on the hot path).

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use crate::util::rng::Pcg64;

/// Stream-id offset for per-column rollout streams, keeping them disjoint
/// from the subsystem streams the drivers derive (`"rain"`, `"ev"`, …).
const COLUMN_STREAM_BASE: u64 = 0xC01;

/// Host worker threads to use when `--rollout-threads` is 0/auto.
pub fn auto_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Whether this build carries the [`ColumnAccess`] race detector
/// (debug builds only). Release builds compile the per-element claim
/// map out entirely: zero atomics touched, accessor back to two words —
/// `bench_rollout` guards on this so benchmarks never measure the
/// detector.
pub fn race_detector_enabled() -> bool {
    cfg!(debug_assertions)
}

/// One deterministic [`Pcg64`] stream per batch column.
///
/// Streams are reseeded per rollout from a master seed drawn off the
/// caller's serial RNG; column `i` gets the stream `(master, BASE + i)`,
/// so per-column draws are independent of each other and of how columns
/// are scheduled across workers.
pub struct ColumnRngs {
    streams: Vec<Pcg64>,
}

impl ColumnRngs {
    /// `b` placeholder streams; call [`reseed`](ColumnRngs::reseed) before
    /// use (the engine reseeds at the top of every rollout).
    pub fn new(b: usize) -> ColumnRngs {
        let mut rngs = ColumnRngs { streams: Vec::with_capacity(b) };
        for i in 0..b {
            rngs.streams.push(Pcg64::new(0, COLUMN_STREAM_BASE + i as u64));
        }
        rngs
    }

    /// Reset every column stream from a fresh master seed.
    pub fn reseed(&mut self, master_seed: u64) {
        for (i, s) in self.streams.iter_mut().enumerate() {
            *s = Pcg64::new(master_seed, COLUMN_STREAM_BASE + i as u64);
        }
    }

    pub fn len(&self) -> usize {
        self.streams.len()
    }

    pub fn is_empty(&self) -> bool {
        self.streams.is_empty()
    }

    pub fn streams_mut(&mut self) -> &mut [Pcg64] {
        &mut self.streams
    }
}

/// Debug-only overlap detection for [`ColumnAccess`]: a per-element
/// atomic claim map. The first thread to touch an element owns it for
/// the lifetime of the access object (one phase); any *other* thread
/// claiming the same element is, by definition, a data race in the
/// making, and the detector panics with a column/thread diagnostic
/// before the aliasing reference is ever created. Same-thread re-claims
/// are fine — a single thread cannot race itself within a phase.
#[cfg(debug_assertions)]
mod claims {
    use std::sync::atomic::{AtomicU32, Ordering};

    /// Compact 1-based id of the calling thread (0 means "unclaimed").
    /// Ids are assigned on first use and stable for the thread's life.
    fn thread_claim_id() -> u32 {
        static NEXT: AtomicU32 = AtomicU32::new(1);
        thread_local! {
            static ID: u32 = NEXT.fetch_add(1, Ordering::Relaxed);
        }
        ID.with(|id| *id)
    }

    /// One atomic claim slot per element of the wrapped slice.
    pub struct ClaimMap {
        slots: Vec<AtomicU32>,
    }

    impl ClaimMap {
        pub fn new(len: usize) -> ClaimMap {
            let mut slots = Vec::with_capacity(len);
            for _ in 0..len {
                slots.push(AtomicU32::new(0));
            }
            ClaimMap { slots }
        }

        /// Claim element `i` for the calling thread; panics with a
        /// diagnostic if a different thread already holds it.
        pub fn claim(&self, i: usize, via: &str) {
            let me = thread_claim_id();
            if let Err(owner) =
                self.slots[i].compare_exchange(0, me, Ordering::AcqRel, Ordering::Acquire)
            {
                if owner != me {
                    let cur = std::thread::current();
                    // ued-lint: allow(serve-panic) — deliberate debug-build race detector; a tripped claim IS the bug being reported
                    panic!(
                        "ColumnAccess race: overlapping claim on element {i} via {via}: \
                         thread {me} ({name:?}) vs owning thread {owner} — two threads \
                         were handed the same index within one phase, violating the \
                         column-disjointness contract",
                        name = cur.name().unwrap_or("unnamed"),
                    );
                }
            }
        }

        pub fn claim_range(&self, start: usize, len: usize, via: &str) {
            for i in start..start + len {
                self.claim(i, via);
            }
        }
    }
}

/// Column-disjoint shared access to a mutable slice.
///
/// The parallel phases hand every worker the *same* view of a buffer and
/// rely on the column partition for exclusivity; this wrapper carries the
/// raw pointer across the closure boundary while the `PhantomData` keeps
/// the underlying borrow alive for the phase's duration.
///
/// In debug builds the wrapper also carries the per-element claim map
/// (the race detector, see [`claims`]); in release builds it is exactly
/// `(*mut T, usize)` and every access compiles to a pointer offset.
pub struct ColumnAccess<'a, T> {
    ptr: *mut T,
    len: usize,
    #[cfg(debug_assertions)]
    claims: claims::ClaimMap,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access is handed between threads, but the unsafe accessors
// require (and the engine upholds, checked in debug by the claim map)
// that concurrently-touched indices are disjoint, so sending the access
// is equivalent to sending disjoint `&mut` sub-slices.
unsafe impl<T: Send> Send for ColumnAccess<'_, T> {}
// SAFETY: same argument as `Send` — shared references to the access only
// ever mint exclusive references to disjoint elements.
unsafe impl<T: Send> Sync for ColumnAccess<'_, T> {}

impl<'a, T> ColumnAccess<'a, T> {
    pub fn new(slice: &'a mut [T]) -> ColumnAccess<'a, T> {
        ColumnAccess {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            #[cfg(debug_assertions)]
            claims: claims::ClaimMap::new(slice.len()),
            _marker: PhantomData,
        }
    }

    /// Exclusive access to element `i`.
    ///
    /// # Safety
    /// No two live references from this access may target the same index;
    /// the engine guarantees it by giving each column a disjoint index
    /// set within a phase. Debug builds verify the contract: the claim
    /// map panics if a second thread touches an element this phase.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len, "ColumnAccess::get_mut index {i} out of bounds (len {})", self.len);
        #[cfg(debug_assertions)]
        self.claims.claim(i, "get_mut");
        // SAFETY: `i` was bounds-checked above, the backing borrow is
        // held alive by `_marker`, and the caller contract (checked by
        // the debug claim map) makes this the only live reference to
        // element `i`.
        unsafe { &mut *self.ptr.add(i) }
    }

    /// Exclusive access to `len` elements starting at `start`.
    ///
    /// # Safety
    /// Same contract as [`get_mut`](ColumnAccess::get_mut): ranges handed
    /// out concurrently must not overlap. Debug builds claim every index
    /// in the range, so any overlap — even partial — panics.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        let end = start.checked_add(len);
        debug_assert!(
            end.is_some_and(|e| e <= self.len),
            "ColumnAccess::slice_mut range [{start}, {start}+{len}) out of bounds (len {})",
            self.len
        );
        #[cfg(debug_assertions)]
        self.claims.claim_range(start, len, "slice_mut");
        // SAFETY: the range was overflow- and bounds-checked above, the
        // backing borrow is held alive by `_marker`, and the caller
        // contract (checked by the debug claim map) keeps concurrent
        // ranges disjoint.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }
}

/// FIFO ("ticket") lock serializing whole phases across the engines that
/// share one pool. Tickets are granted strictly in arrival order, so when
/// several drivers contend — a seed pack's per-seed engines, PAIRED's
/// three agents, a trainer plus its evaluator — none can be starved by an
/// unfair mutex wake-up race: every queued phase runs before any later
/// arrival, which keeps per-seed progress even.
struct FifoLock {
    state: Mutex<TicketState>,
    cv: Condvar,
}

struct TicketState {
    /// Next ticket to hand out.
    next: u64,
    /// Ticket currently allowed to hold the lock.
    serving: u64,
}

struct FifoGuard<'a> {
    lock: &'a FifoLock,
}

impl FifoLock {
    fn new() -> FifoLock {
        FifoLock {
            state: Mutex::new(TicketState { next: 0, serving: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Take a ticket and block until it is served.
    fn lock(&self) -> FifoGuard<'_> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let ticket = st.next;
        st.next += 1;
        while st.serving != ticket {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        drop(st);
        FifoGuard { lock: self }
    }

    /// Tickets issued but not yet released (the holder plus the queue) —
    /// test observability for the fairness invariant.
    #[cfg(test)]
    fn contenders(&self) -> u64 {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.next - st.serving
    }
}

impl Drop for FifoGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.lock.state.lock().unwrap_or_else(|e| e.into_inner());
        st.serving += 1;
        drop(st);
        self.lock.cv.notify_all();
    }
}

/// A broadcast work item: one phase closure plus its column count and
/// whether the calling thread takes a shard too.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    n_items: usize,
    /// Shards the items are split into — clamped to the item count, so
    /// surplus workers skip the epoch instead of syncing over an empty
    /// range (matters when B is small and the pool is host-sized).
    total_shards: usize,
    main_participates: bool,
}

struct PoolState {
    epoch: u64,
    job: Option<Job>,
    /// Spawned workers still processing the current epoch.
    running: usize,
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// Erase the lifetime of a phase closure so it can sit in the pool's
/// shared job slot (the slot is a plain `'static` field; the closure it
/// holds borrows the dispatching caller's stack).
///
/// # Safety
///
/// The result aliases `f` with its borrow erased, so the caller must
/// uphold the pool's **phase barrier**: no thread may read the returned
/// reference after the dispatching call returns. `run`/`run_overlapped`
/// guarantee this by blocking in `wait_done` — which waits until every
/// participating worker has finished the epoch (`running == 0` under the
/// state mutex) and then clears the job slot — before returning, even
/// when the caller-side task panics. A worker can only re-execute a job
/// after the epoch counter advances, and the counter only advances
/// inside a later `dispatch`, which installs a fresh closure first; the
/// handoff ordering is pinned step-by-step by the
/// `phase_closure_borrow_ends_before_run_returns` test.
unsafe fn erase_phase_closure(f: &(dyn Fn(usize) + Sync)) -> &'static (dyn Fn(usize) + Sync) {
    // SAFETY: pure lifetime erasure — same pointer, same vtable. The
    // caller contract above bounds every use of the result to the phase
    // in which `f` is still borrowed.
    unsafe { std::mem::transmute(f) }
}

/// Persistent scoped-thread worker pool for column-parallel phases.
///
/// `threads` counts the calling thread: `WorkerPool::new(1)` spawns
/// nothing and runs phases inline (the zero-overhead serial mode), while
/// `new(n)` spawns `n - 1` workers that live until the pool drops.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serializes whole phases: the pool has one job slot, so concurrent
    /// `run`/`run_overlapped` callers (engines sharing one `Arc`) must
    /// not interleave dispatch/wait — the second caller blocks here until
    /// the first phase fully drains. FIFO, so contending engines (a seed
    /// pack's drivers, PAIRED's three agents) are scheduled fairly in
    /// arrival order. Uncontended in a single driver (one phase at a
    /// time), but it makes the `&self` API sound.
    phase_guard: FifoLock,
    /// Scheduling hint set by the pack orchestrator: when several seed
    /// driver threads share this pool, engines must not hold the phase
    /// lock across a device forward (it would serialize every other
    /// driver's host sweep behind the device call) — they run the
    /// forward outside any phase and fuse the writeback into the step
    /// phase instead. Purely a scheduling mode: results are
    /// bit-identical either way (pinned by `rollout_determinism`).
    multi_driver: AtomicBool,
    threads: usize,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool of `threads` total workers (minimum 1 = inline).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                running: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads.saturating_sub(1));
        for id in 1..threads {
            let sh = shared.clone();
            let h = thread::Builder::new()
                .name(format!("rollout-worker-{id}"))
                .spawn(move || worker_loop(&sh, id))
                .expect("spawning rollout worker");
            handles.push(h);
        }
        WorkerPool {
            shared,
            phase_guard: FifoLock::new(),
            multi_driver: AtomicBool::new(false),
            threads,
            handles,
        }
    }

    /// Pool sized to the host (`auto_threads()`).
    pub fn auto() -> WorkerPool {
        WorkerPool::new(auto_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Declare whether multiple driver threads share this pool (set once
    /// by the pack orchestrator before training starts). Engines consult
    /// this to pick the fused schedule that keeps device forwards
    /// outside the phase lock.
    pub fn set_multi_driver(&self, on: bool) {
        self.multi_driver.store(on, Ordering::Relaxed);
    }

    /// Whether the multi-driver schedule is in effect
    /// (see [`set_multi_driver`](WorkerPool::set_multi_driver)).
    pub fn multi_driver(&self) -> bool {
        self.multi_driver.load(Ordering::Relaxed)
    }

    /// Run `f(i)` for every `i in 0..n_items`, the calling thread working
    /// shard 0 alongside the pool. Returns after all items complete.
    /// Concurrent callers are serialized (whole phases never interleave).
    pub fn run<F: Fn(usize) + Sync>(&self, n_items: usize, f: F) {
        if self.threads == 1 || n_items == 0 {
            for i in 0..n_items {
                f(i);
            }
            return;
        }
        let guard = self.phase_guard.lock();
        let shards = self.dispatch(&f, n_items, true);
        let main = catch_unwind(AssertUnwindSafe(|| {
            run_shard(&f, 0, shards, n_items);
        }));
        self.wait_done();
        drop(guard);
        if let Err(p) = main {
            resume_unwind(p);
        }
    }

    /// Run `f(i)` for every item on the pool's workers while the calling
    /// thread runs `main_task` (e.g. the device forward call), returning
    /// `main_task`'s result once both sides finish. With a single-thread
    /// pool the items run inline first, then `main_task` — same data
    /// effects, no concurrency.
    pub fn run_overlapped<R, F, G>(&self, n_items: usize, f: F, main_task: G) -> R
    where
        F: Fn(usize) + Sync,
        G: FnOnce() -> R,
    {
        if self.threads == 1 || n_items == 0 {
            for i in 0..n_items {
                f(i);
            }
            return main_task();
        }
        let guard = self.phase_guard.lock();
        self.dispatch(&f, n_items, false);
        let main = catch_unwind(AssertUnwindSafe(main_task));
        self.wait_done();
        drop(guard);
        match main {
            Ok(r) => r,
            Err(p) => resume_unwind(p),
        }
    }

    /// Post a job; returns the shard count it was split into.
    fn dispatch(
        &self, f: &(dyn Fn(usize) + Sync), n_items: usize, main_participates: bool,
    ) -> usize {
        debug_assert!(self.threads > 1);
        let available = if main_participates { self.threads } else { self.threads - 1 };
        let total_shards = available.min(n_items);
        let participating_workers = total_shards - usize::from(main_participates);
        // SAFETY: `dispatch` is only reachable from `run`/`run_overlapped`,
        // both of which block in `wait_done` until every worker finished
        // this epoch (and the job slot is cleared) before returning — the
        // phase barrier `erase_phase_closure`'s contract requires.
        let f_static = unsafe { erase_phase_closure(f) };
        // ued-lint: allow(serve-panic) — pool-state mutex is poisoned only after a worker panic, which wait_done re-raises anyway
        let mut st = self.shared.state.lock().unwrap();
        st.epoch = st.epoch.wrapping_add(1);
        st.job = Some(Job { f: f_static, n_items, total_shards, main_participates });
        st.running = participating_workers;
        drop(st);
        self.shared.work_cv.notify_all();
        total_shards
    }

    // ued-lint: allow(serve-panic) — lock/wait unwraps fire only on a poisoned pool, and the panic! deliberately re-raises a worker's panic on the caller
    fn wait_done(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.running > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let panicked = st.panicked;
        st.panicked = false;
        drop(st);
        if panicked {
            panic!("rollout worker panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Items of shard `shard` (of `shards`) over `n` items: the fixed
/// contiguous partition `[shard*n/shards, (shard+1)*n/shards)`.
fn run_shard(f: &dyn Fn(usize), shard: usize, shards: usize, n: usize) {
    let lo = shard * n / shards;
    let hi = (shard + 1) * n / shards;
    for i in lo..hi {
        f(i);
    }
}

fn worker_loop(shared: &Shared, id: usize) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    if let Some(job) = st.job {
                        last_epoch = st.epoch;
                        break job;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // Shard 0 belongs to the caller when it participates; this worker
        // is surplus for the epoch if the clamp left it without a shard
        // (it was never counted in `running`, so just go back to waiting).
        let shard = if job.main_participates { id } else { id - 1 };
        if shard >= job.total_shards {
            continue;
        }
        let res = catch_unwind(AssertUnwindSafe(|| {
            run_shard(job.f, shard, job.total_shards, job.n_items);
        }));
        let mut st = shared.state.lock().unwrap();
        if res.is_err() {
            st.panicked = true;
        }
        st.running -= 1;
        if st.running == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_every_item_once() {
        for threads in [1, 2, 4] {
            let pool = WorkerPool::new(threads);
            let n = 103;
            let mut hits = vec![0u32; n];
            let acc = ColumnAccess::new(&mut hits[..]);
            // SAFETY: each index is visited by exactly one shard per phase.
            pool.run(n, |i| unsafe {
                *acc.get_mut(i) += 1;
            });
            assert!(hits.iter().all(|&h| h == 1), "threads={threads}: {hits:?}");
        }
    }

    #[test]
    fn overlapped_runs_main_and_items() {
        let pool = WorkerPool::new(3);
        let n = 64;
        let mut out = vec![0usize; n];
        let acc = ColumnAccess::new(&mut out[..]);
        let counter = AtomicUsize::new(0);
        let r = pool.run_overlapped(
            n,
            |i| {
                // SAFETY: each index is visited by exactly one shard.
                unsafe { *acc.get_mut(i) = i * 2 };
                counter.fetch_add(1, Ordering::Relaxed);
            },
            || 41 + 1,
        );
        assert_eq!(r, 42);
        assert_eq!(counter.load(Ordering::Relaxed), n);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i * 2));
    }

    #[test]
    fn pool_is_reusable_across_phases() {
        let pool = WorkerPool::new(4);
        let mut total = 0u64;
        for phase in 0..50u64 {
            let mut buf = vec![0u64; 17];
            let acc = ColumnAccess::new(&mut buf[..]);
            // SAFETY: each index is visited by exactly one shard per phase.
            pool.run(17, |i| unsafe {
                *acc.get_mut(i) = phase + i as u64;
            });
            total += buf.iter().sum::<u64>();
        }
        // sum of (phase + i) over phases 0..50, i 0..17
        let expect: u64 = (0..50u64).map(|p| 17 * p + (0..17u64).sum::<u64>()).sum();
        assert_eq!(total, expect);
    }

    #[test]
    fn column_rngs_are_schedule_independent() {
        let mut a = ColumnRngs::new(8);
        let mut b = ColumnRngs::new(8);
        a.reseed(99);
        b.reseed(99);
        // draw in different interleavings; per-column sequences must match
        let mut out_a = vec![Vec::new(); 8];
        for col in 0..8 {
            for _ in 0..16 {
                out_a[col].push(a.streams_mut()[col].next_u64());
            }
        }
        let mut out_b = vec![Vec::new(); 8];
        for _round in 0..16 {
            for col in (0..8).rev() {
                out_b[col].push(b.streams_mut()[col].next_u64());
            }
        }
        assert_eq!(out_a, out_b);
        // distinct columns: distinct streams
        assert_ne!(out_a[0], out_a[1]);
    }

    #[test]
    fn concurrent_callers_are_serialized() {
        // Two threads hammer the same pool; the phase guard must keep
        // whole phases atomic, so each thread sees only its own writes.
        let pool = Arc::new(WorkerPool::new(3));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let p = pool.clone();
            handles.push(thread::spawn(move || {
                let mut buf = vec![0u64; 64];
                for round in 0..50u64 {
                    let acc = ColumnAccess::new(&mut buf[..]);
                    // SAFETY: each index is visited by exactly one shard.
                    p.run(64, |i| unsafe {
                        *acc.get_mut(i) += round + t;
                    });
                }
                buf.iter().sum::<u64>()
            }));
        }
        let sums: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let base: u64 = (0..50u64).map(|r| 64 * r).sum();
        assert_eq!(sums[0], base);
        assert_eq!(sums[1], base + 50 * 64);
    }

    #[test]
    fn phase_lock_grants_in_arrival_order() {
        // The pack orchestrator's fairness invariant: engines queued on
        // one pool get their phases in arrival order, never reordered by
        // an unfair wake-up.
        let lock = Arc::new(FifoLock::new());
        let order = Arc::new(Mutex::new(Vec::new()));
        let held = lock.lock(); // everyone below queues behind this
        let mut handles = Vec::new();
        for id in 0..8u64 {
            let l = lock.clone();
            let o = order.clone();
            handles.push(thread::spawn(move || {
                let _g = l.lock();
                o.lock().unwrap().push(id);
            }));
            // wait until thread `id` holds its ticket before spawning the
            // next, so arrival order is exactly 0..8 (holder counts as 1)
            while lock.contenders() < id + 2 {
                thread::yield_now();
            }
        }
        drop(held);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives_drop() {
        let pool = WorkerPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // pool still usable after a panic epoch
        let n = AtomicUsize::new(0);
        pool.run(8, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }

    /// Loom-style handoff-ordering argument for the `'static` erasure in
    /// [`erase_phase_closure`], checked by construction:
    ///
    /// * (A) T0 `dispatch`: installs the erased closure in the job slot
    ///   and advances the epoch, all **under the state mutex**.
    /// * (B) W `worker_loop`: observes the new epoch and copies the job
    ///   **under the same mutex** — so (A) happens-before (B).
    /// * (C) W finishes its shard, then decrements `running` under the
    ///   mutex; the last worker signals `done_cv`.
    /// * (D) T0 `wait_done`: observes `running == 0` under the mutex —
    ///   so every (C) happens-before (D) — and clears the job slot
    ///   before returning.
    /// * (E) After (D), no worker can reach the closure again: workers
    ///   only run a job on a *fresh* epoch, and the epoch only advances
    ///   inside a later `dispatch`, which installs a new closure first.
    ///
    /// Therefore the erased borrow never outlives the `run` call. The
    /// test drives the chain with a stack-captured value (dangling if
    /// the borrow escaped) and proves (E) by counting invocations.
    #[test]
    fn phase_closure_borrow_ends_before_run_returns() {
        let pool = WorkerPool::new(4);
        let calls = AtomicUsize::new(0);
        {
            let local = 7u64; // stack data borrowed by the erased closure
            pool.run(32, |_i| {
                assert_eq!(local, 7);
                calls.fetch_add(1, Ordering::SeqCst);
            });
        } // ← borrow of `local` ends here; (A)–(D) all completed above
        assert_eq!(calls.load(Ordering::SeqCst), 32);
        // (E): a later phase with a different closure must not re-invoke
        // the first one — its epoch is stale and its slot overwritten.
        pool.run(32, |_i| {});
        assert_eq!(calls.load(Ordering::SeqCst), 32);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "overlapping claim")]
    fn race_detector_catches_cross_thread_get_mut_overlap() {
        // Seeded overlap: a worker thread claims element 0, then the test
        // thread claims the same element through the same access object.
        // The detector must abort the second claim before it can mint an
        // aliasing &mut. (The worker's reference is already dead, so the
        // test itself is race-free — only the *claims* overlap.)
        let mut buf = vec![0u32; 4];
        let acc = ColumnAccess::new(&mut buf[..]);
        thread::scope(|s| {
            s.spawn(|| {
                // SAFETY: only this spawned thread touches element 0 at
                // this point; the claim is the intentional seed.
                unsafe {
                    *acc.get_mut(0) = 1;
                }
            })
            .join()
            .unwrap();
            // SAFETY: deliberately violates the disjointness contract to
            // prove the detector fires (the panic precedes the &mut).
            let _overlap = unsafe { acc.get_mut(0) };
        });
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "overlapping claim")]
    fn race_detector_catches_partial_slice_overlap() {
        let mut buf = vec![0u8; 8];
        let acc = ColumnAccess::new(&mut buf[..]);
        thread::scope(|s| {
            s.spawn(|| {
                // SAFETY: the seed claim — this thread alone holds [0, 4).
                let _a = unsafe { acc.slice_mut(0, 4) };
            })
            .join()
            .unwrap();
            // SAFETY: deliberately overlaps [2, 6) with the claim above to
            // prove partial slice overlaps are caught.
            let _b = unsafe { acc.slice_mut(2, 4) };
        });
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "overlapping claim")]
    fn race_detector_catches_fused_phase_column_overlap() {
        // Shape of the engine's fused writeback+step phase (multi-driver
        // packs): each column writes an obs row *slice* plus a scalar
        // through two access objects in one closure. A mis-partition
        // that hands two threads the same column must trip on the row
        // slice even when the scalar claims stay disjoint.
        let comp = 4;
        let mut obs = vec![0f32; 4 * comp];
        let mut scalars = vec![0f32; 4];
        let obs_acc = ColumnAccess::new(&mut obs[..]);
        let sc_acc = ColumnAccess::new(&mut scalars[..]);
        thread::scope(|s| {
            s.spawn(|| {
                // SAFETY: the seed claim — this thread alone owns column
                // 1's obs row and scalar at this point.
                unsafe {
                    obs_acc.slice_mut(comp, comp)[0] = 1.0;
                    *sc_acc.get_mut(1) = 1.0;
                }
            })
            .join()
            .unwrap();
            // SAFETY: scalar 3 is genuinely disjoint — must not panic.
            unsafe {
                *sc_acc.get_mut(3) = 2.0;
            }
            // SAFETY: deliberately re-claims column 1's obs row from a
            // second thread to prove the fused phase's slice writes are
            // covered by the detector.
            let _overlap = unsafe { obs_acc.slice_mut(comp, comp) };
        });
    }

    #[test]
    fn multi_driver_flag_round_trips() {
        let pool = WorkerPool::new(1);
        assert!(!pool.multi_driver(), "pools default to single-driver");
        pool.set_multi_driver(true);
        assert!(pool.multi_driver());
        pool.set_multi_driver(false);
        assert!(!pool.multi_driver());
    }

    #[test]
    fn race_detector_allows_same_thread_reclaims() {
        // One thread re-touching its own column repeatedly is not a race.
        let mut buf = vec![0u64; 3];
        let acc = ColumnAccess::new(&mut buf[..]);
        for _ in 0..4 {
            // SAFETY: single-threaded — every claim is from this thread.
            unsafe {
                *acc.get_mut(1) += 1;
            }
        }
        assert_eq!(buf[1], 4);
    }

    #[test]
    fn race_detector_claims_are_per_access_not_per_buffer() {
        // Different threads may own the same element in *different*
        // phases: each fresh ColumnAccess gets a fresh claim map.
        let mut buf = vec![0u64; 1];
        for round in 0..2u64 {
            let acc = ColumnAccess::new(&mut buf[..]);
            thread::scope(|s| {
                s.spawn(|| {
                    // SAFETY: only this spawned thread touches element 0
                    // within this phase.
                    unsafe {
                        *acc.get_mut(0) += round + 1;
                    }
                });
            });
        }
        assert_eq!(buf[0], 3);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn release_access_is_two_words_no_detector() {
        // The race detector must vanish in release builds: no claim map
        // field, no atomics — the accessor is exactly (ptr, len).
        assert!(!race_detector_enabled());
        assert_eq!(
            std::mem::size_of::<ColumnAccess<'static, f32>>(),
            std::mem::size_of::<*mut f32>() + std::mem::size_of::<usize>(),
        );
    }
}
