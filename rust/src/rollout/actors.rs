//! The actor-pool substrate of the rollout engine: a persistent worker
//! pool for column-parallel host work, per-column RNG streams, and the
//! column-disjoint shared-access primitive the parallel phases use.
//!
//! Design invariants:
//!
//! * **Determinism is structural, not scheduled.** Every batch column owns
//!   a private [`Pcg64`] stream ([`ColumnRngs`]) and writes only its own
//!   disjoint slices, so the result of a parallel phase is a pure function
//!   of (master seed, column index) — bit-identical at any
//!   `--rollout-threads` setting, including 1. The integration test
//!   `rollout_determinism` pins this invariant.
//! * **Threads persist.** [`WorkerPool`] spawns its workers once and
//!   reuses them for every phase of every step of every rollout (the
//!   paper's hot loop runs millions of steps; per-step thread spawning
//!   would dominate). Work is broadcast as one type-erased closure per
//!   phase; workers take fixed contiguous column shards, which keeps the
//!   partition deterministic and cache-friendly.
//! * **The calling thread is worker 0.** `run` keeps the caller busy with
//!   its own shard; `run_overlapped` instead gives the caller a different
//!   task (the PJRT forward call) to overlap with the workers' column
//!   sweep.

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use crate::util::rng::Pcg64;

/// Stream-id offset for per-column rollout streams, keeping them disjoint
/// from the subsystem streams the drivers derive (`"rain"`, `"ev"`, …).
const COLUMN_STREAM_BASE: u64 = 0xC01;

/// Host worker threads to use when `--rollout-threads` is 0/auto.
pub fn auto_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One deterministic [`Pcg64`] stream per batch column.
///
/// Streams are reseeded per rollout from a master seed drawn off the
/// caller's serial RNG; column `i` gets the stream `(master, BASE + i)`,
/// so per-column draws are independent of each other and of how columns
/// are scheduled across workers.
pub struct ColumnRngs {
    streams: Vec<Pcg64>,
}

impl ColumnRngs {
    /// `b` placeholder streams; call [`reseed`](ColumnRngs::reseed) before
    /// use (the engine reseeds at the top of every rollout).
    pub fn new(b: usize) -> ColumnRngs {
        let mut rngs = ColumnRngs { streams: Vec::with_capacity(b) };
        for i in 0..b {
            rngs.streams.push(Pcg64::new(0, COLUMN_STREAM_BASE + i as u64));
        }
        rngs
    }

    /// Reset every column stream from a fresh master seed.
    pub fn reseed(&mut self, master_seed: u64) {
        for (i, s) in self.streams.iter_mut().enumerate() {
            *s = Pcg64::new(master_seed, COLUMN_STREAM_BASE + i as u64);
        }
    }

    pub fn len(&self) -> usize {
        self.streams.len()
    }

    pub fn is_empty(&self) -> bool {
        self.streams.is_empty()
    }

    pub fn streams_mut(&mut self) -> &mut [Pcg64] {
        &mut self.streams
    }
}

/// Column-disjoint shared access to a mutable slice.
///
/// The parallel phases hand every worker the *same* view of a buffer and
/// rely on the column partition for exclusivity; this wrapper carries the
/// raw pointer across the closure boundary while the `PhantomData` keeps
/// the underlying borrow alive for the phase's duration.
pub struct ColumnAccess<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access is handed between threads, but the unsafe accessors
// require (and the engine upholds) that concurrently-touched indices are
// disjoint, so this is equivalent to sending disjoint `&mut` sub-slices.
unsafe impl<T: Send> Send for ColumnAccess<'_, T> {}
unsafe impl<T: Send> Sync for ColumnAccess<'_, T> {}

impl<'a, T> ColumnAccess<'a, T> {
    pub fn new(slice: &'a mut [T]) -> ColumnAccess<'a, T> {
        ColumnAccess { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// Exclusive access to element `i`.
    ///
    /// # Safety
    /// No two live references from this access may target the same index;
    /// the engine guarantees it by giving each column a disjoint index
    /// set within a phase.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }

    /// Exclusive access to `len` elements starting at `start`.
    ///
    /// # Safety
    /// Same contract as [`get_mut`](ColumnAccess::get_mut): ranges handed
    /// out concurrently must not overlap.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// FIFO ("ticket") lock serializing whole phases across the engines that
/// share one pool. Tickets are granted strictly in arrival order, so when
/// several drivers contend — a seed pack's per-seed engines, PAIRED's
/// three agents, a trainer plus its evaluator — none can be starved by an
/// unfair mutex wake-up race: every queued phase runs before any later
/// arrival, which keeps per-seed progress even.
struct FifoLock {
    state: Mutex<TicketState>,
    cv: Condvar,
}

struct TicketState {
    /// Next ticket to hand out.
    next: u64,
    /// Ticket currently allowed to hold the lock.
    serving: u64,
}

struct FifoGuard<'a> {
    lock: &'a FifoLock,
}

impl FifoLock {
    fn new() -> FifoLock {
        FifoLock {
            state: Mutex::new(TicketState { next: 0, serving: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Take a ticket and block until it is served.
    fn lock(&self) -> FifoGuard<'_> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let ticket = st.next;
        st.next += 1;
        while st.serving != ticket {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        drop(st);
        FifoGuard { lock: self }
    }

    /// Tickets issued but not yet released (the holder plus the queue) —
    /// test observability for the fairness invariant.
    #[cfg(test)]
    fn contenders(&self) -> u64 {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.next - st.serving
    }
}

impl Drop for FifoGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.lock.state.lock().unwrap_or_else(|e| e.into_inner());
        st.serving += 1;
        drop(st);
        self.lock.cv.notify_all();
    }
}

/// A broadcast work item: one phase closure plus its column count and
/// whether the calling thread takes a shard too.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    n_items: usize,
    /// Shards the items are split into — clamped to the item count, so
    /// surplus workers skip the epoch instead of syncing over an empty
    /// range (matters when B is small and the pool is host-sized).
    total_shards: usize,
    main_participates: bool,
}

struct PoolState {
    epoch: u64,
    job: Option<Job>,
    /// Spawned workers still processing the current epoch.
    running: usize,
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// Persistent scoped-thread worker pool for column-parallel phases.
///
/// `threads` counts the calling thread: `WorkerPool::new(1)` spawns
/// nothing and runs phases inline (the zero-overhead serial mode), while
/// `new(n)` spawns `n - 1` workers that live until the pool drops.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serializes whole phases: the pool has one job slot, so concurrent
    /// `run`/`run_overlapped` callers (engines sharing one `Arc`) must
    /// not interleave dispatch/wait — the second caller blocks here until
    /// the first phase fully drains. FIFO, so contending engines (a seed
    /// pack's drivers, PAIRED's three agents) are scheduled fairly in
    /// arrival order. Uncontended in a single driver (one phase at a
    /// time), but it makes the `&self` API sound.
    phase_guard: FifoLock,
    threads: usize,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool of `threads` total workers (minimum 1 = inline).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                running: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads.saturating_sub(1));
        for id in 1..threads {
            let sh = shared.clone();
            let h = thread::Builder::new()
                .name(format!("rollout-worker-{id}"))
                .spawn(move || worker_loop(&sh, id))
                .expect("spawning rollout worker");
            handles.push(h);
        }
        WorkerPool { shared, phase_guard: FifoLock::new(), threads, handles }
    }

    /// Pool sized to the host (`auto_threads()`).
    pub fn auto() -> WorkerPool {
        WorkerPool::new(auto_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i in 0..n_items`, the calling thread working
    /// shard 0 alongside the pool. Returns after all items complete.
    /// Concurrent callers are serialized (whole phases never interleave).
    pub fn run<F: Fn(usize) + Sync>(&self, n_items: usize, f: F) {
        if self.threads == 1 || n_items == 0 {
            for i in 0..n_items {
                f(i);
            }
            return;
        }
        let guard = self.phase_guard.lock();
        let shards = self.dispatch(&f, n_items, true);
        let main = catch_unwind(AssertUnwindSafe(|| {
            run_shard(&f, 0, shards, n_items);
        }));
        self.wait_done();
        drop(guard);
        if let Err(p) = main {
            resume_unwind(p);
        }
    }

    /// Run `f(i)` for every item on the pool's workers while the calling
    /// thread runs `main_task` (e.g. the device forward call), returning
    /// `main_task`'s result once both sides finish. With a single-thread
    /// pool the items run inline first, then `main_task` — same data
    /// effects, no concurrency.
    pub fn run_overlapped<R, F, G>(&self, n_items: usize, f: F, main_task: G) -> R
    where
        F: Fn(usize) + Sync,
        G: FnOnce() -> R,
    {
        if self.threads == 1 || n_items == 0 {
            for i in 0..n_items {
                f(i);
            }
            return main_task();
        }
        let guard = self.phase_guard.lock();
        self.dispatch(&f, n_items, false);
        let main = catch_unwind(AssertUnwindSafe(main_task));
        self.wait_done();
        drop(guard);
        match main {
            Ok(r) => r,
            Err(p) => resume_unwind(p),
        }
    }

    /// Post a job; returns the shard count it was split into.
    fn dispatch(
        &self, f: &(dyn Fn(usize) + Sync), n_items: usize, main_participates: bool,
    ) -> usize {
        debug_assert!(self.threads > 1);
        let available = if main_participates { self.threads } else { self.threads - 1 };
        let total_shards = available.min(n_items);
        let participating_workers = total_shards - usize::from(main_participates);
        // SAFETY: the borrow behind `f` outlives the job because both
        // `run` and `run_overlapped` call `wait_done` (which blocks until
        // every worker finished the epoch) before returning — even on
        // panic of the caller-side task.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let mut st = self.shared.state.lock().unwrap();
        st.epoch = st.epoch.wrapping_add(1);
        st.job = Some(Job { f: f_static, n_items, total_shards, main_participates });
        st.running = participating_workers;
        drop(st);
        self.shared.work_cv.notify_all();
        total_shards
    }

    fn wait_done(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.running > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let panicked = st.panicked;
        st.panicked = false;
        drop(st);
        if panicked {
            panic!("rollout worker panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Items of shard `shard` (of `shards`) over `n` items: the fixed
/// contiguous partition `[shard*n/shards, (shard+1)*n/shards)`.
fn run_shard(f: &dyn Fn(usize), shard: usize, shards: usize, n: usize) {
    let lo = shard * n / shards;
    let hi = (shard + 1) * n / shards;
    for i in lo..hi {
        f(i);
    }
}

fn worker_loop(shared: &Shared, id: usize) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    if let Some(job) = st.job {
                        last_epoch = st.epoch;
                        break job;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // Shard 0 belongs to the caller when it participates; this worker
        // is surplus for the epoch if the clamp left it without a shard
        // (it was never counted in `running`, so just go back to waiting).
        let shard = if job.main_participates { id } else { id - 1 };
        if shard >= job.total_shards {
            continue;
        }
        let res = catch_unwind(AssertUnwindSafe(|| {
            run_shard(job.f, shard, job.total_shards, job.n_items);
        }));
        let mut st = shared.state.lock().unwrap();
        if res.is_err() {
            st.panicked = true;
        }
        st.running -= 1;
        if st.running == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_every_item_once() {
        for threads in [1, 2, 4] {
            let pool = WorkerPool::new(threads);
            let n = 103;
            let mut hits = vec![0u32; n];
            let acc = ColumnAccess::new(&mut hits[..]);
            pool.run(n, |i| unsafe {
                *acc.get_mut(i) += 1;
            });
            assert!(hits.iter().all(|&h| h == 1), "threads={threads}: {hits:?}");
        }
    }

    #[test]
    fn overlapped_runs_main_and_items() {
        let pool = WorkerPool::new(3);
        let n = 64;
        let mut out = vec![0usize; n];
        let acc = ColumnAccess::new(&mut out[..]);
        let counter = AtomicUsize::new(0);
        let r = pool.run_overlapped(
            n,
            |i| {
                unsafe { *acc.get_mut(i) = i * 2 };
                counter.fetch_add(1, Ordering::Relaxed);
            },
            || 41 + 1,
        );
        assert_eq!(r, 42);
        assert_eq!(counter.load(Ordering::Relaxed), n);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i * 2));
    }

    #[test]
    fn pool_is_reusable_across_phases() {
        let pool = WorkerPool::new(4);
        let mut total = 0u64;
        for phase in 0..50u64 {
            let mut buf = vec![0u64; 17];
            let acc = ColumnAccess::new(&mut buf[..]);
            pool.run(17, |i| unsafe {
                *acc.get_mut(i) = phase + i as u64;
            });
            total += buf.iter().sum::<u64>();
        }
        // sum of (phase + i) over phases 0..50, i 0..17
        let expect: u64 = (0..50u64).map(|p| 17 * p + (0..17u64).sum::<u64>()).sum();
        assert_eq!(total, expect);
    }

    #[test]
    fn column_rngs_are_schedule_independent() {
        let mut a = ColumnRngs::new(8);
        let mut b = ColumnRngs::new(8);
        a.reseed(99);
        b.reseed(99);
        // draw in different interleavings; per-column sequences must match
        let mut out_a = vec![Vec::new(); 8];
        for col in 0..8 {
            for _ in 0..16 {
                out_a[col].push(a.streams_mut()[col].next_u64());
            }
        }
        let mut out_b = vec![Vec::new(); 8];
        for _round in 0..16 {
            for col in (0..8).rev() {
                out_b[col].push(b.streams_mut()[col].next_u64());
            }
        }
        assert_eq!(out_a, out_b);
        // distinct columns: distinct streams
        assert_ne!(out_a[0], out_a[1]);
    }

    #[test]
    fn concurrent_callers_are_serialized() {
        // Two threads hammer the same pool; the phase guard must keep
        // whole phases atomic, so each thread sees only its own writes.
        let pool = Arc::new(WorkerPool::new(3));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let p = pool.clone();
            handles.push(thread::spawn(move || {
                let mut buf = vec![0u64; 64];
                for round in 0..50u64 {
                    let acc = ColumnAccess::new(&mut buf[..]);
                    p.run(64, |i| unsafe {
                        *acc.get_mut(i) += round + t;
                    });
                }
                buf.iter().sum::<u64>()
            }));
        }
        let sums: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let base: u64 = (0..50u64).map(|r| 64 * r).sum();
        assert_eq!(sums[0], base);
        assert_eq!(sums[1], base + 50 * 64);
    }

    #[test]
    fn phase_lock_grants_in_arrival_order() {
        // The pack orchestrator's fairness invariant: engines queued on
        // one pool get their phases in arrival order, never reordered by
        // an unfair wake-up.
        let lock = Arc::new(FifoLock::new());
        let order = Arc::new(Mutex::new(Vec::new()));
        let held = lock.lock(); // everyone below queues behind this
        let mut handles = Vec::new();
        for id in 0..8u64 {
            let l = lock.clone();
            let o = order.clone();
            handles.push(thread::spawn(move || {
                let _g = l.lock();
                o.lock().unwrap().push(id);
            }));
            // wait until thread `id` holds its ticket before spawning the
            // next, so arrival order is exactly 0..8 (holder counts as 1)
            while lock.contenders() < id + 2 {
                thread::yield_now();
            }
        }
        drop(held);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives_drop() {
        let pool = WorkerPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // pool still usable after a panic epoch
        let n = AtomicUsize::new(0);
        pool.run(8, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }
}
