//! The pipelined rollout engine: batched policy-driven stepping of B
//! environments with column-parallel host work.
//!
//! Each timestep runs three phases:
//!
//! 1. **stage** — `observe()` every column into the `[B, comp]` staging
//!    tensors, columns fanned out across the [`WorkerPool`];
//! 2. **forward ∥ writeback** — the calling thread runs the device
//!    forward call while the workers copy the freshly-staged observation
//!    row into the trajectory (`run_overlapped`);
//! 3. **act/step** — sample an action per column from its own RNG stream
//!    and `env.step()` it, again column-parallel, writing trajectory
//!    scalars in place.
//!
//! When several seed drivers share one pool
//! ([`WorkerPool::multi_driver`]), phase 2 would hold the pool's phase
//! lock across the device call and serialize every other driver behind
//! it; the engine instead runs the forward *outside* any pool phase and
//! fuses the writeback into phase 3, so one seed's device forward
//! overlaps every other seed's host column sweep. Both schedules write
//! the same bytes from the same per-column RNG draws, so results are
//! bit-identical across modes (and at any thread count — see
//! `rollout/actors.rs`).
//!
//! Forward staging is device-resident-style: a [`ForwardWorkspace`]
//! keeps the parameter + observation literals alive between steps
//! (write-into instead of realloc-and-upload), and outputs land in
//! engine-owned reusable buffers ([`PolicyModel::forward_into`]).
//! [`PhaseTimers`] counts per-phase wall time (via the sanctioned
//! [`Stopwatch`]) so the overlap is observable in `metrics.csv`.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::actors::{ColumnAccess, ColumnRngs, WorkerPool};
use super::sampler;
use super::storage::Trajectory;
use crate::env::UnderspecifiedEnv;
use crate::metrics::Stopwatch;
use crate::runtime::executor::Executable;
use crate::util::rng::Pcg64;
use crate::util::tensor::TensorF32;

/// Reusable staged-argument state for [`PolicyModel::forward_into`]: the
/// parameter and observation literals stay alive between steps, so the
/// hot path refills them in place (`Literal::copy_from` /
/// `copy_from_literal`) instead of re-cloning the parameters and
/// re-uploading fresh observation literals on every single forward call.
/// With a real device binding these become resident device buffers; the
/// vendored stub's in-place update API keeps the swap a drop-in.
#[derive(Default)]
pub struct ForwardWorkspace {
    /// Staged call arguments: `[params.., obs..]` in artifact input order.
    args: Vec<xla::Literal>,
    /// How many leading `args` are parameters (the split point).
    n_params: usize,
}

/// Cumulative per-phase wall times in nanoseconds — the observability
/// needed to verify the forward/host overlap actually overlaps. Purely
/// informational: read from the sanctioned [`Stopwatch`], and nothing in
/// the training path depends on the values.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseTimers {
    /// Observe-staging phase.
    pub stage_ns: u64,
    /// Device forward calls.
    pub forward_ns: u64,
    /// Action-sampling + env-step phase. In multi-driver mode this is
    /// the fused writeback+step phase, so the writeback cost lands here
    /// and `writeback_ns` stays 0.
    pub step_ns: u64,
    /// Time the overlapped writeback phase ran *beyond* the forward call
    /// it overlaps with (single-driver mode; 0 when fully hidden).
    pub writeback_ns: u64,
}

impl PhaseTimers {
    /// Fold another engine's counters in (PAIRED sums its engines).
    pub fn accumulate(&mut self, o: PhaseTimers) {
        self.stage_ns += o.stage_ns;
        self.forward_ns += o.forward_ns;
        self.step_ns += o.step_ns;
        self.writeback_ns += o.writeback_ns;
    }
}

/// A batched policy: anything that maps staged `[B, comp]` observation
/// tensors to `logits [B*A]` / `values [B]`, writing into caller-owned
/// reusable buffers. Row `bi` of the output must depend only on row `bi`
/// of the input (true of the per-example networks every artifact lowers),
/// which is what lets the work-queue evaluator mix unrelated episodes in
/// one batch.
pub trait PolicyModel {
    fn num_actions(&self) -> usize;

    /// Batched forward into reusable buffers (cleared and refilled),
    /// staging arguments through the caller's [`ForwardWorkspace`] (kept
    /// alive between steps; backends that don't stage literals ignore it).
    fn forward_into(
        &self,
        obs: &[TensorF32],
        ws: &mut ForwardWorkspace,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) -> Result<()>;
}

/// A policy backed by an `*_apply_b{B}` artifact plus its parameters.
/// The executable is `Arc`-shared so pack driver threads can each hold
/// the same compiled artifact.
pub struct Policy<'p> {
    pub apply: Arc<Executable>,
    pub params: &'p [xla::Literal],
    pub num_actions: usize,
}

impl Policy<'_> {
    /// Allocation-per-call convenience wrapper over
    /// [`forward_into`](PolicyModel::forward_into) (cold workspace each
    /// call — use an engine-held workspace on hot paths).
    pub fn forward(&self, obs: &[TensorF32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut ws = ForwardWorkspace::default();
        let mut logits = Vec::new();
        let mut values = Vec::new();
        self.forward_buffers(obs, &mut ws, &mut logits, &mut values)?;
        Ok((logits, values))
    }

    /// Refill a matching workspace in place; `false` means a shape/dtype
    /// drift (different policy geometry) and the caller must rebuild.
    fn refresh_workspace(&self, obs: &[TensorF32], ws: &mut ForwardWorkspace) -> bool {
        let p = self.params.len();
        for (dst, src) in ws.args[..p].iter_mut().zip(self.params) {
            if dst.copy_from_literal(src).is_err() {
                return false;
            }
        }
        for (dst, o) in ws.args[p..].iter_mut().zip(obs) {
            if dst.copy_from(o.data()).is_err() {
                return false;
            }
        }
        true
    }

    fn forward_buffers(
        &self,
        obs: &[TensorF32],
        ws: &mut ForwardWorkspace,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) -> Result<()> {
        let p = self.params.len();
        let n_in = self.apply.def.inputs.len();
        if p + obs.len() != n_in {
            bail!(
                "apply {} wants {} inputs, got {} params + {} obs",
                self.apply.def.name, n_in, p, obs.len()
            );
        }
        // Hot path: the workspace already stages literals of this exact
        // geometry — overwrite them in place (no allocation, no clone).
        // Any mismatch (first call, or a different policy geometry
        // reusing the workspace) falls through to a full rebuild.
        let hot =
            ws.n_params == p && ws.args.len() == n_in && self.refresh_workspace(obs, ws);
        if !hot {
            ws.args.clear();
            ws.args.reserve(n_in);
            ws.args.extend(self.params.iter().cloned());
            for (o, spec) in obs.iter().zip(&self.apply.def.inputs[p..]) {
                ws.args.push(o.to_literal_as(&spec.shape)?);
            }
            ws.n_params = p;
        }
        let out = self.apply.call(&ws.args)?;
        // `to_vec_into` copies off the device into the caller's reusable
        // buffers — no per-call output allocation once the buffers have
        // grown to size.
        out[0].to_vec_into(logits)?;
        out[1].to_vec_into(values)?;
        Ok(())
    }
}

impl PolicyModel for Policy<'_> {
    fn num_actions(&self) -> usize {
        self.num_actions
    }

    fn forward_into(
        &self,
        obs: &[TensorF32],
        ws: &mut ForwardWorkspace,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) -> Result<()> {
        self.forward_buffers(obs, ws, logits, values)
    }
}

/// Result of one evaluation episode.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EpisodeOutcome {
    pub solved: bool,
    pub steps: u32,
    pub terminal_reward: f32,
}

/// Work-queue slot bookkeeping (`episode == usize::MAX` marks a dead pad
/// slot whose batch row is computed and discarded).
#[derive(Clone, Copy)]
struct SlotMeta {
    episode: usize,
    steps: u32,
    live: bool,
}

/// Reusable staging + buffer state for B-way rollouts over one env type.
pub struct RolloutEngine {
    pub b: usize,
    obs_components: Vec<usize>,
    /// Per-component `[B, comp]` staging tensors for the apply artifact.
    obs_step: Vec<TensorF32>,
    /// Per-column flat observation scratch (each column owns one so the
    /// stage phase needs no cross-column synchronization).
    flats: Vec<Vec<f32>>,
    /// Reusable forward-output buffers.
    logits_buf: Vec<f32>,
    values_buf: Vec<f32>,
    /// Resident forward-argument staging, reused across steps.
    ws: ForwardWorkspace,
    /// Per-column RNG streams, reseeded per rollout.
    rngs: ColumnRngs,
    pool: Arc<WorkerPool>,
    forward_passes: u64,
    /// Per-phase wall-time counters since the last `take_timers`.
    timers: PhaseTimers,
}

impl RolloutEngine {
    /// Serial engine (single-thread pool) — same results as any pool size.
    pub fn new<E: UnderspecifiedEnv>(env: &E, b: usize) -> RolloutEngine {
        Self::with_pool(env, b, Arc::new(WorkerPool::new(1)))
    }

    /// Engine sharing a caller-owned worker pool (PAIRED runs three
    /// engines over one pool; the evaluator shares the trainer's).
    pub fn with_pool<E: UnderspecifiedEnv>(
        env: &E, b: usize, pool: Arc<WorkerPool>,
    ) -> RolloutEngine {
        let obs_components = env.obs_components();
        RolloutEngine {
            b,
            obs_step: obs_components
                .iter()
                .map(|&c| TensorF32::zeros(&[b, c]))
                .collect(),
            flats: (0..b).map(|_| vec![0.0; env.obs_len()]).collect(),
            obs_components,
            logits_buf: Vec::new(),
            values_buf: Vec::new(),
            ws: ForwardWorkspace::default(),
            rngs: ColumnRngs::new(b),
            pool,
            forward_passes: 0,
            timers: PhaseTimers::default(),
        }
    }

    /// Device forward calls issued by the most recent
    /// `collect`/`run_episodes`/`run_episode_queue`.
    pub fn forward_passes(&self) -> u64 {
        self.forward_passes
    }

    /// The engine's worker pool (for sharing with sibling engines).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Per-phase wall-time counters accumulated since the last call,
    /// resetting them to zero (drivers drain these into `metrics.csv`
    /// once per cycle).
    pub fn take_timers(&mut self) -> PhaseTimers {
        std::mem::take(&mut self.timers)
    }

    /// Phase 1: observe all columns into the step staging tensors.
    fn stage_obs<E: UnderspecifiedEnv>(&mut self, env: &E, states: &mut [E::State]) {
        let sw = Stopwatch::new();
        let b = self.b;
        debug_assert_eq!(states.len(), b);
        let comps: &[usize] = &self.obs_components;
        let obs_acc: Vec<ColumnAccess<f32>> = self
            .obs_step
            .iter_mut()
            .map(|t| ColumnAccess::new(t.data_mut()))
            .collect();
        let flat_acc = ColumnAccess::new(&mut self.flats[..]);
        let st_acc = ColumnAccess::new(states);
        self.pool.run(b, |bi| {
            // SAFETY: column `bi` is visited by exactly one shard per
            // phase, so this is the only live reference to state `bi`.
            let state = unsafe { st_acc.get_mut(bi) };
            // SAFETY: same disjointness — each column owns its private
            // flat scratch buffer.
            let flat = unsafe { flat_acc.get_mut(bi) };
            env.observe(state, flat);
            let mut off = 0;
            for (k, &comp) in comps.iter().enumerate() {
                // SAFETY: rows `[bi*comp, (bi+1)*comp)` of each staging
                // tensor belong to column `bi` alone.
                let dst = unsafe { obs_acc[k].slice_mut(bi * comp, comp) };
                dst.copy_from_slice(&flat[off..off + comp]);
                off += comp;
            }
        });
        self.timers.stage_ns += sw.elapsed_ns();
    }

    /// One device forward outside any pool phase: the bootstrap value
    /// pass, the episode runners, and the multi-driver collect schedule
    /// (where holding the pool's phase lock across the device call would
    /// stall every other driver).
    fn forward_direct<P: PolicyModel>(&mut self, policy: &P) -> Result<()> {
        let sw = Stopwatch::new();
        policy.forward_into(
            &self.obs_step, &mut self.ws, &mut self.logits_buf, &mut self.values_buf,
        )?;
        self.forward_passes += 1;
        self.timers.forward_ns += sw.elapsed_ns();
        Ok(())
    }

    /// Phase 2 (single-driver): run the device forward on the calling
    /// thread while the workers copy the staged observation row into
    /// trajectory row `t`.
    fn forward_with_writeback<P: PolicyModel>(
        &mut self, policy: &P, traj: &mut Trajectory, t: usize,
    ) -> Result<()> {
        let b = self.b;
        let comps: &[usize] = &self.obs_components;
        let obs_step: &[TensorF32] = &self.obs_step;
        let traj_obs_acc: Vec<ColumnAccess<f32>> = traj
            .obs
            .iter_mut()
            .map(|o| ColumnAccess::new(o.data_mut()))
            .collect();
        let logits = &mut self.logits_buf;
        let values = &mut self.values_buf;
        let ws = &mut self.ws;
        let mut fwd_ns = 0u64;
        let phase = Stopwatch::new();
        let res = self.pool.run_overlapped(
            b,
            |bi| {
                for (k, &comp) in comps.iter().enumerate() {
                    let src = &obs_step[k].data()[bi * comp..(bi + 1) * comp];
                    // SAFETY: trajectory row `t`, column `bi` — disjoint
                    // ranges across columns; `obs_step` is only read here
                    // and by the (concurrent, read-only) forward call.
                    let dst = unsafe { traj_obs_acc[k].slice_mut((t * b + bi) * comp, comp) };
                    dst.copy_from_slice(src);
                }
            },
            || {
                let sw = Stopwatch::new();
                let r = policy.forward_into(obs_step, ws, logits, values);
                fwd_ns = sw.elapsed_ns();
                r
            },
        );
        self.forward_passes += 1;
        self.timers.forward_ns += fwd_ns;
        // The writeback sweep is hidden behind the forward; only the
        // tail it ran beyond the device call is real wall time.
        self.timers.writeback_ns += phase.elapsed_ns().saturating_sub(fwd_ns);
        res
    }

    /// Phase 3 (single-driver): per-column action sampling + env step +
    /// trajectory scalar writes.
    fn step_into_traj<E: UnderspecifiedEnv>(
        &mut self, env: &E, states: &mut [E::State], traj: &mut Trajectory, t: usize,
        a: usize,
    ) {
        let sw = Stopwatch::new();
        let b = self.b;
        let logits: &[f32] = &self.logits_buf;
        let values: &[f32] = &self.values_buf;
        let rng_acc = ColumnAccess::new(self.rngs.streams_mut());
        let st_acc = ColumnAccess::new(states);
        let act_acc = ColumnAccess::new(traj.actions.data_mut());
        let logp_acc = ColumnAccess::new(traj.logp.data_mut());
        let val_acc = ColumnAccess::new(traj.values.data_mut());
        let rew_acc = ColumnAccess::new(traj.rewards.data_mut());
        let done_acc = ColumnAccess::new(traj.dones.data_mut());
        self.pool.run(b, |bi| {
            // SAFETY: column `bi` is visited by exactly one shard per
            // phase, so its RNG stream has no other user.
            let rng = unsafe { rng_acc.get_mut(bi) };
            // SAFETY: same per-column disjointness for the env state.
            let state = unsafe { st_acc.get_mut(bi) };
            let row = &logits[bi * a..(bi + 1) * a];
            let (action, lp) = sampler::sample_action(row, rng);
            let step = env.step(state, action, rng);
            let i = t * b + bi;
            // SAFETY: trajectory scalars at `[t, bi]` — index `i` is
            // unique to this column within the phase.
            unsafe {
                *act_acc.get_mut(i) = action as i32;
                *logp_acc.get_mut(i) = lp;
                *val_acc.get_mut(i) = values[bi];
                *rew_acc.get_mut(i) = step.reward;
                *done_acc.get_mut(i) = if step.done { 1.0 } else { 0.0 };
            }
        });
        self.timers.step_ns += sw.elapsed_ns();
    }

    /// Phases 2b+3 fused (multi-driver): the trajectory-obs writeback
    /// folded into the act/step sweep as a single pool phase, run after
    /// [`forward_direct`](Self::forward_direct) already produced the
    /// logits outside the pool's phase lock. Writes exactly the bytes
    /// the overlapped schedule writes — same disjoint per-column
    /// locations, same per-column RNG draw order — so results stay
    /// bit-identical across driver modes (pinned by
    /// `rollout_determinism`).
    fn fused_writeback_step<E: UnderspecifiedEnv>(
        &mut self, env: &E, states: &mut [E::State], traj: &mut Trajectory, t: usize,
        a: usize,
    ) {
        let sw = Stopwatch::new();
        let b = self.b;
        let comps: &[usize] = &self.obs_components;
        let obs_step: &[TensorF32] = &self.obs_step;
        let logits: &[f32] = &self.logits_buf;
        let values: &[f32] = &self.values_buf;
        let rng_acc = ColumnAccess::new(self.rngs.streams_mut());
        let st_acc = ColumnAccess::new(states);
        let traj_obs_acc: Vec<ColumnAccess<f32>> = traj
            .obs
            .iter_mut()
            .map(|o| ColumnAccess::new(o.data_mut()))
            .collect();
        let act_acc = ColumnAccess::new(traj.actions.data_mut());
        let logp_acc = ColumnAccess::new(traj.logp.data_mut());
        let val_acc = ColumnAccess::new(traj.values.data_mut());
        let rew_acc = ColumnAccess::new(traj.rewards.data_mut());
        let done_acc = ColumnAccess::new(traj.dones.data_mut());
        self.pool.run(b, |bi| {
            for (k, &comp) in comps.iter().enumerate() {
                let src = &obs_step[k].data()[bi * comp..(bi + 1) * comp];
                // SAFETY: trajectory row `t`, column `bi` — disjoint
                // ranges across columns (debug claim map checks), and
                // `obs_step` is read-only within this phase.
                let dst = unsafe { traj_obs_acc[k].slice_mut((t * b + bi) * comp, comp) };
                dst.copy_from_slice(src);
            }
            // SAFETY: column `bi` is visited by exactly one shard per
            // phase, so its RNG stream has no other user.
            let rng = unsafe { rng_acc.get_mut(bi) };
            // SAFETY: same per-column disjointness for the env state.
            let state = unsafe { st_acc.get_mut(bi) };
            let row = &logits[bi * a..(bi + 1) * a];
            let (action, lp) = sampler::sample_action(row, rng);
            let step = env.step(state, action, rng);
            let i = t * b + bi;
            // SAFETY: trajectory scalars at `[t, bi]` — index `i` is
            // unique to this column within the phase.
            unsafe {
                *act_acc.get_mut(i) = action as i32;
                *logp_acc.get_mut(i) = lp;
                *val_acc.get_mut(i) = values[bi];
                *rew_acc.get_mut(i) = step.reward;
                *done_acc.get_mut(i) = if step.done { 1.0 } else { 0.0 };
            }
        });
        // Fused mode folds the writeback into this phase, so its cost
        // lands in `step_ns` and `writeback_ns` stays 0.
        self.timers.step_ns += sw.elapsed_ns();
    }

    fn check_forward_shape(&self, a: usize) -> Result<()> {
        ensure!(
            self.logits_buf.len() == self.b * a && self.values_buf.len() == self.b,
            "policy forward produced {} logits / {} values for B={} A={a}",
            self.logits_buf.len(),
            self.values_buf.len(),
            self.b
        );
        Ok(())
    }

    /// Collect a fixed-length `[T, B]` rollout into `traj`, stepping the
    /// given states in place. `rng` only seeds the per-column streams (one
    /// `next_u64` draw), so results are bit-identical at any pool size —
    /// and across driver modes: with [`WorkerPool::multi_driver`] set the
    /// forward runs outside the pool's phase lock and the writeback fuses
    /// into the step phase, but the data written is identical.
    pub fn collect<E: UnderspecifiedEnv, P: PolicyModel>(
        &mut self, env: &E, states: &mut [E::State], policy: &P,
        traj: &mut Trajectory, rng: &mut Pcg64,
    ) -> Result<()> {
        let (t_len, b) = (traj.t, traj.b);
        assert_eq!(b, self.b);
        assert_eq!(states.len(), b);
        let a = policy.num_actions();
        self.rngs.reseed(rng.next_u64());
        self.forward_passes = 0;
        let fused = self.pool.multi_driver();
        for t in 0..t_len {
            self.stage_obs(env, states);
            if fused {
                self.forward_direct(policy)?;
                self.check_forward_shape(a)?;
                self.fused_writeback_step(env, states, traj, t, a);
            } else {
                self.forward_with_writeback(policy, traj, t)?;
                self.check_forward_shape(a)?;
                self.step_into_traj(env, states, traj, t, a);
            }
        }
        // Bootstrap values for the post-rollout states.
        self.stage_obs(env, states);
        self.forward_direct(policy)?;
        self.check_forward_shape(a)?;
        traj.last_value.data_mut().copy_from_slice(&self.values_buf);
        Ok(())
    }

    /// Run one episode per column to completion (no trajectory recording):
    /// the fixed-batch evaluation primitive. Column `bi` draws from
    /// `rngs[bi]` only, so outcomes are independent of scheduling. Columns
    /// whose episode finished are skipped (their batch rows are still
    /// computed by the fixed-shape forward, then discarded) and the loop
    /// exits once every column is done — the padded-chunk waste the
    /// work-queue variant [`run_episode_queue`](Self::run_episode_queue)
    /// eliminates.
    pub fn run_episodes<E: UnderspecifiedEnv, P: PolicyModel>(
        &mut self, env: &E, states: &mut [E::State], policy: &P, max_steps: usize,
        rngs: &mut [Pcg64], greedy: bool,
    ) -> Result<Vec<EpisodeOutcome>> {
        let b = self.b;
        assert_eq!(states.len(), b);
        assert_eq!(rngs.len(), b);
        let a = policy.num_actions();
        self.forward_passes = 0;
        let mut outcomes = vec![EpisodeOutcome::default(); b];
        let mut live = vec![true; b];
        for _step in 0..max_steps {
            if !live.iter().any(|&l| l) {
                break;
            }
            self.stage_obs(env, states);
            self.forward_direct(policy)?;
            self.check_forward_shape(a)?;
            self.step_episode_columns(env, states, rngs, &mut live, &mut outcomes, greedy, a);
        }
        Ok(outcomes)
    }

    #[allow(clippy::too_many_arguments)]
    fn step_episode_columns<E: UnderspecifiedEnv>(
        &mut self, env: &E, states: &mut [E::State], rngs: &mut [Pcg64],
        live: &mut [bool], outcomes: &mut [EpisodeOutcome], greedy: bool, a: usize,
    ) {
        let sw = Stopwatch::new();
        let logits: &[f32] = &self.logits_buf;
        let rng_acc = ColumnAccess::new(rngs);
        let st_acc = ColumnAccess::new(states);
        let live_acc = ColumnAccess::new(live);
        let out_acc = ColumnAccess::new(outcomes);
        self.pool.run(self.b, |bi| {
            // SAFETY: column `bi` is visited by exactly one shard per
            // phase; every access in this closure touches index `bi` only.
            let alive = unsafe { live_acc.get_mut(bi) };
            if !*alive {
                return;
            }
            // SAFETY: same per-column disjointness for the RNG stream.
            let rng = unsafe { rng_acc.get_mut(bi) };
            // SAFETY: same per-column disjointness for the env state.
            let state = unsafe { st_acc.get_mut(bi) };
            // SAFETY: same per-column disjointness for the outcome slot.
            let out = unsafe { out_acc.get_mut(bi) };
            let row = &logits[bi * a..(bi + 1) * a];
            let action = if greedy {
                sampler::argmax_action(row)
            } else {
                sampler::sample_action(row, rng).0
            };
            let step = env.step(state, action, rng);
            out.steps += 1;
            if step.done {
                out.solved = step.reward > 0.0;
                out.terminal_reward = step.reward;
                *alive = false;
            }
        });
        self.timers.step_ns += sw.elapsed_ns();
    }

    /// Work-queue episode runner: completes `n_episodes` episodes while
    /// keeping the fixed-shape `apply_b{B}` batch full — a finished column
    /// is immediately refilled with the next pending episode instead of
    /// computing discarded logits until its chunk drains.
    ///
    /// `reset(e)` must return episode `e`'s initial state *and* its
    /// private RNG stream; because each episode carries its own stream,
    /// outcomes are bit-identical to running the same episodes through
    /// [`run_episodes`](Self::run_episodes) in padded chunks — never at a
    /// higher forward-pass count, and strictly lower whenever episode
    /// lengths are ragged (see [`forward_passes`](Self::forward_passes)).
    pub fn run_episode_queue<E, P, R>(
        &mut self, env: &E, policy: &P, n_episodes: usize, max_steps: usize,
        greedy: bool, mut reset: R,
    ) -> Result<Vec<EpisodeOutcome>>
    where
        E: UnderspecifiedEnv,
        P: PolicyModel,
        R: FnMut(usize) -> (E::State, Pcg64),
    {
        let b = self.b;
        let a = policy.num_actions();
        self.forward_passes = 0;
        let mut outcomes = vec![EpisodeOutcome::default(); n_episodes];
        if n_episodes == 0 {
            return Ok(outcomes);
        }

        let mut states: Vec<E::State> = Vec::with_capacity(b);
        let mut rngs: Vec<Pcg64> = Vec::with_capacity(b);
        let mut meta: Vec<SlotMeta> = Vec::with_capacity(b);
        let mut next = 0usize;
        while states.len() < b && next < n_episodes {
            let (s, r) = reset(next);
            states.push(s);
            rngs.push(r);
            meta.push(SlotMeta { episode: next, steps: 0, live: true });
            next += 1;
        }
        // Fewer episodes than columns: pad the fixed-shape batch with
        // dead clones of slot 0 (computed, discarded).
        while states.len() < b {
            let pad_state = states[0].clone();
            let pad_rng = rngs[0].clone();
            states.push(pad_state);
            rngs.push(pad_rng);
            meta.push(SlotMeta { episode: usize::MAX, steps: 0, live: false });
        }

        while meta.iter().any(|m| m.live) {
            self.stage_obs(env, &mut states);
            self.forward_direct(policy)?;
            self.check_forward_shape(a)?;
            self.step_queue_columns(
                env, &mut states, &mut rngs, &mut meta, &mut outcomes, greedy, a, max_steps,
            );
            // Serial refill of columns whose episode just finished (the
            // queue pop is ordered by column index, so it too is
            // schedule-independent).
            for bi in 0..b {
                if !meta[bi].live && meta[bi].episode != usize::MAX {
                    if next < n_episodes {
                        let (s, r) = reset(next);
                        states[bi] = s;
                        rngs[bi] = r;
                        meta[bi] = SlotMeta { episode: next, steps: 0, live: true };
                        next += 1;
                    } else {
                        meta[bi].episode = usize::MAX;
                    }
                }
            }
        }
        Ok(outcomes)
    }

    #[allow(clippy::too_many_arguments)]
    fn step_queue_columns<E: UnderspecifiedEnv>(
        &mut self, env: &E, states: &mut [E::State], rngs: &mut [Pcg64],
        meta: &mut [SlotMeta], outcomes: &mut [EpisodeOutcome], greedy: bool, a: usize,
        max_steps: usize,
    ) {
        let sw = Stopwatch::new();
        let logits: &[f32] = &self.logits_buf;
        let rng_acc = ColumnAccess::new(rngs);
        let st_acc = ColumnAccess::new(states);
        let meta_acc = ColumnAccess::new(meta);
        let out_acc = ColumnAccess::new(outcomes);
        self.pool.run(self.b, |bi| {
            // SAFETY: column `bi` is visited by exactly one shard per
            // phase, so slot metadata `bi` has no other user.
            let m = unsafe { meta_acc.get_mut(bi) };
            if !m.live {
                return;
            }
            // SAFETY: same per-column disjointness for the slot's RNG.
            let rng = unsafe { rng_acc.get_mut(bi) };
            // SAFETY: same per-column disjointness for the slot's state.
            let state = unsafe { st_acc.get_mut(bi) };
            let row = &logits[bi * a..(bi + 1) * a];
            let action = if greedy {
                sampler::argmax_action(row)
            } else {
                sampler::sample_action(row, rng).0
            };
            let step = env.step(state, action, rng);
            m.steps += 1;
            if step.done || m.steps as usize >= max_steps {
                // SAFETY: `m.episode` ids are unique across live slots, so
                // no two columns ever write the same outcome element.
                let out = unsafe { out_acc.get_mut(m.episode) };
                *out = EpisodeOutcome {
                    solved: step.done && step.reward > 0.0,
                    steps: m.steps,
                    terminal_reward: if step.done { step.reward } else { 0.0 },
                };
                m.live = false;
            }
        });
        self.timers.step_ns += sw.elapsed_ns();
    }
}
