//! Vectorized rollout engine: steps B environments in lockstep, calling the
//! AOT-compiled policy artifact once per timestep for the whole batch.
//!
//! The hot loop is allocation-free: observation staging tensors and the
//! per-env flat buffer are owned by the engine and reused; trajectory
//! tensors are written in place. The only per-step heap traffic is the
//! literal staging into PJRT (one upload per observation component).

pub mod sampler;
pub mod storage;

use std::rc::Rc;

use anyhow::{bail, Result};

pub use storage::{EpisodeStats, Trajectory};

use crate::env::UnderspecifiedEnv;
use crate::runtime::executor::Executable;
use crate::util::rng::Pcg64;
use crate::util::tensor::TensorF32;

/// A policy backed by an `*_apply_b{B}` artifact plus its parameters.
pub struct Policy<'p> {
    pub apply: Rc<Executable>,
    pub params: &'p [xla::Literal],
    pub num_actions: usize,
}

impl<'p> Policy<'p> {
    /// Batched forward: obs component tensors (flat `[B, comp]`) →
    /// (logits `[B*A]`, values `[B]`). Observation literals are staged with
    /// the artifact's structured shapes from the manifest.
    pub fn forward(&self, obs: &[TensorF32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let p = self.params.len();
        let n_in = self.apply.def.inputs.len();
        if p + obs.len() != n_in {
            bail!(
                "apply {} wants {} inputs, got {} params + {} obs",
                self.apply.def.name, n_in, p, obs.len()
            );
        }
        let mut args: Vec<xla::Literal> = Vec::with_capacity(n_in);
        args.extend(self.params.iter().cloned());
        for (o, spec) in obs.iter().zip(&self.apply.def.inputs[p..]) {
            args.push(o.to_literal_as(&spec.shape)?);
        }
        let out = self.apply.call(&args)?;
        let logits = out[0].to_vec::<f32>()?;
        let values = out[1].to_vec::<f32>()?;
        Ok((logits, values))
    }
}

/// Reusable staging state for B-way rollouts over one env type.
pub struct RolloutEngine {
    pub b: usize,
    obs_components: Vec<usize>,
    /// Per-component `[B, comp]` staging tensors for the apply artifact.
    obs_step: Vec<TensorF32>,
    /// Per-env flat observation scratch.
    flat: Vec<f32>,
}

impl RolloutEngine {
    pub fn new<E: UnderspecifiedEnv>(env: &E, b: usize) -> RolloutEngine {
        let obs_components = env.obs_components();
        RolloutEngine {
            b,
            obs_step: obs_components
                .iter()
                .map(|&c| TensorF32::zeros(&[b, c]))
                .collect(),
            obs_components,
            flat: vec![0.0; env.obs_len()],
        }
    }

    /// Write observations of all states into the step staging tensors and
    /// (optionally) into trajectory row `t`.
    fn stage_obs<E: UnderspecifiedEnv>(
        &mut self, env: &E, states: &[E::State], traj_row: Option<(&mut Trajectory, usize)>,
    ) {
        let b = self.b;
        debug_assert_eq!(states.len(), b);
        for (bi, state) in states.iter().enumerate() {
            env.observe(state, &mut self.flat);
            let mut off = 0;
            for (k, &comp) in self.obs_components.iter().enumerate() {
                let dst = &mut self.obs_step[k].data_mut()[bi * comp..(bi + 1) * comp];
                dst.copy_from_slice(&self.flat[off..off + comp]);
                off += comp;
            }
        }
        if let Some((traj, t)) = traj_row {
            for (k, &comp) in self.obs_components.iter().enumerate() {
                let src = self.obs_step[k].data();
                traj.obs[k].slice_mut(t).copy_from_slice(&src[..b * comp]);
            }
        }
    }

    /// Collect a fixed-length `[T, B]` rollout into `traj`, stepping the
    /// given states in place. Returns nothing; all data lands in `traj`.
    pub fn collect<E: UnderspecifiedEnv>(
        &mut self, env: &E, states: &mut [E::State], policy: &Policy,
        traj: &mut Trajectory, rng: &mut Pcg64,
    ) -> Result<()> {
        let (t_len, b) = (traj.t, traj.b);
        assert_eq!(b, self.b);
        assert_eq!(states.len(), b);
        for t in 0..t_len {
            self.stage_obs(env, states, Some((traj, t)));
            let (logits, values) = policy.forward(&self.obs_step)?;
            let a = policy.num_actions;
            debug_assert_eq!(logits.len(), b * a);
            for bi in 0..b {
                let row = &logits[bi * a..(bi + 1) * a];
                let (action, lp) = sampler::sample_action(row, rng);
                let step = env.step(&mut states[bi], action, rng);
                let i = t * b + bi;
                traj.actions.data_mut()[i] = action as i32;
                traj.logp.data_mut()[i] = lp;
                traj.values.data_mut()[i] = values[bi];
                traj.rewards.data_mut()[i] = step.reward;
                traj.dones.data_mut()[i] = if step.done { 1.0 } else { 0.0 };
            }
        }
        // Bootstrap values for the post-rollout states.
        self.stage_obs(env, states, None);
        let (_, values) = policy.forward(&self.obs_step)?;
        traj.last_value.data_mut().copy_from_slice(&values);
        Ok(())
    }

    /// Run episodes to completion (no trajectory recording): used by the
    /// evaluator. Each column runs exactly one episode from its level;
    /// returns per-column (solved, steps, terminal reward). Columns whose
    /// episode already finished are *skipped* — their states are not
    /// stepped again (their logits are still computed as part of the
    /// fixed-shape batched forward pass, then discarded), and the loop
    /// exits early once every column is done.
    pub fn run_episodes<E: UnderspecifiedEnv>(
        &mut self, env: &E, states: &mut [E::State], policy: &Policy,
        max_steps: usize, rng: &mut Pcg64, greedy: bool,
    ) -> Result<Vec<EpisodeOutcome>> {
        let b = self.b;
        let mut outcomes = vec![EpisodeOutcome::default(); b];
        let mut live = vec![true; b];
        let mut remaining = b;
        for _step in 0..max_steps {
            if remaining == 0 {
                break;
            }
            self.stage_obs(env, states, None);
            let (logits, _) = policy.forward(&self.obs_step)?;
            let a = policy.num_actions;
            for bi in 0..b {
                if !live[bi] {
                    continue;
                }
                let row = &logits[bi * a..(bi + 1) * a];
                let action = if greedy {
                    sampler::argmax_action(row)
                } else {
                    sampler::sample_action(row, rng).0
                };
                let step = env.step(&mut states[bi], action, rng);
                outcomes[bi].steps += 1;
                if step.done {
                    outcomes[bi].solved = step.reward > 0.0;
                    outcomes[bi].terminal_reward = step.reward;
                    live[bi] = false;
                    remaining -= 1;
                }
            }
        }
        Ok(outcomes)
    }
}

/// Result of one evaluation episode.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpisodeOutcome {
    pub solved: bool,
    pub steps: u32,
    pub terminal_reward: f32,
}
