//! Pipelined, multi-threaded rollout stack: steps B environments in
//! lockstep, calling the AOT-compiled policy artifact once per timestep
//! for the whole batch, with all host-side work parallelized across
//! columns.
//!
//! # Architecture: actor pool + per-column RNG streams
//!
//! The stack splits into two layers:
//!
//! * [`actors`] — the substrate: a persistent [`WorkerPool`]
//!   (`--rollout-threads`, default = available parallelism) whose threads
//!   outlive every rollout, per-column [`Pcg64`](crate::util::rng::Pcg64)
//!   streams ([`ColumnRngs`]), and the column-disjoint shared-access
//!   primitive the parallel phases use.
//! * [`engine`] — the [`RolloutEngine`]: per timestep it (1) stages
//!   `observe()` of every column in parallel, (2) runs the device forward
//!   call on the calling thread *while* workers copy the staged row into
//!   the trajectory, and (3) samples + `env.step()`s every column in
//!   parallel. Forward outputs land in engine-owned reusable buffers via
//!   [`PolicyModel::forward_into`].
//!
//! **Determinism invariant.** Every batch column draws from a private RNG
//! stream seeded by (master seed, column index) and writes only its own
//! tensor slices, so results are *bit-identical at any thread count* —
//! `--rollout-threads 1` and `--rollout-threads 16` produce the same
//! trajectories, episode stats, and eval reports. The
//! `rollout_determinism` integration test pins this for both env
//! families; it is the refactor's safety net.
//!
//! Two mechanical checks back the invariant (see the README's
//! "Determinism invariants" section). `ued-lint` ([`crate::analysis`])
//! statically bans ambient RNGs, hash-ordered collections, wallclock
//! reads, and address-derived values from this module tree, and audits
//! every `unsafe` site for a SAFETY comment. And in debug builds the
//! column-disjointness contract itself is *checked at runtime*: every
//! [`ColumnAccess`](actors::ColumnAccess) carries a per-element atomic
//! claim map that panics with a column/thread diagnostic the moment two
//! threads claim the same index within a phase. Release builds compile
//! the detector out entirely ([`race_detector_enabled`] tells you which
//! build you have; `bench_rollout` asserts it is off).
//!
//! # Evaluation primitives
//!
//! [`RolloutEngine::run_episodes`] is the legacy fixed-chunk episode
//! runner (finished columns keep burning batch rows until the chunk
//! drains); [`RolloutEngine::run_episode_queue`] is the work-queue
//! variant that refills a finished column with the next pending (level,
//! trial) episode so the fixed-shape `apply_b{B}` batch stays full. Both
//! count their device calls ([`RolloutEngine::forward_passes`]); the
//! work-queue needs strictly fewer on ragged episode lengths. The
//! evaluator exposes both as [`EvalMode`](crate::eval::EvalMode) and the
//! determinism suite asserts they produce identical per-level results.
//!
//! All host-side staging is reused: observation staging tensors, the
//! per-column flat buffers, the logits/values buffers, *and* the staged
//! forward-argument literals (a [`ForwardWorkspace`] per engine, refilled
//! in place each step instead of realloc-and-upload) are owned by the
//! engine; trajectory tensors are written in place. Beyond that, each
//! parallel phase builds a few element-sized accessor `Vec`s, noise next
//! to the device call.
//!
//! # Seed packs: multi-driver scheduling
//!
//! A seed pack gives every seed its own driver thread over one shared
//! pool. Because a pool phase holds the FIFO phase lock, the overlapped
//! phase-2 schedule would pin the lock across the device forward and
//! stall every other driver; [`WorkerPool::set_multi_driver`] therefore
//! switches engines to a fused schedule — forward outside any phase,
//! writeback folded into the step phase — so one seed's device call
//! overlaps every other seed's host sweep. Both schedules are
//! bit-identical; [`PhaseTimers`] (surfaced as `metrics.csv` columns)
//! makes the overlap observable.

pub mod actors;
pub mod engine;
pub mod sampler;
pub mod storage;
pub mod synthetic;

pub use actors::{auto_threads, race_detector_enabled, ColumnRngs, WorkerPool};
pub use engine::{
    EpisodeOutcome, ForwardWorkspace, PhaseTimers, Policy, PolicyModel, RolloutEngine,
};
pub use storage::{EpisodeStats, Trajectory};
pub use synthetic::SyntheticPolicy;
