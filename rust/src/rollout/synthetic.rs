//! Synthetic stand-in policy: a fixed pseudo-random linear map per action
//! over the flattened observation.
//!
//! Plain library code (like `env/conformance.rs`) so the artifact-free
//! determinism tests and the rollout bench share one definition. It has
//! exactly the properties the engine assumes of compiled `apply`
//! artifacts — row `bi` of the output depends only on row `bi` of the
//! input, and accumulation order is fixed — so it exercises every host
//! path (staging, sampling, stepping, writeback, work-queue scheduling)
//! without a PJRT backend.

use anyhow::Result;

use super::engine::{ForwardWorkspace, PolicyModel};
use crate::util::tensor::TensorF32;

/// Deterministic row-independent linear policy.
pub struct SyntheticPolicy {
    pub num_actions: usize,
}

/// Fixed pseudo-random weight in [-0.5, 0.5) for (action, input index) —
/// a splitmix-style hash, so no state and no platform dependence.
fn weight(a: usize, i: usize) -> f32 {
    let h = (a as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(i as u64)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    ((h >> 40) as f32 / (1u64 << 24) as f32) - 0.5
}

impl PolicyModel for SyntheticPolicy {
    fn num_actions(&self) -> usize {
        self.num_actions
    }

    fn forward_into(
        &self,
        obs: &[TensorF32],
        _ws: &mut ForwardWorkspace,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) -> Result<()> {
        // No device boundary — nothing to stage in the workspace.
        let b = obs[0].shape()[0];
        logits.clear();
        values.clear();
        for bi in 0..b {
            for a in 0..self.num_actions {
                let mut z = 0.0f32;
                let mut base = 0usize;
                for t in obs {
                    let comp = t.shape()[1];
                    let row = &t.data()[bi * comp..(bi + 1) * comp];
                    for (i, &x) in row.iter().enumerate() {
                        z += x * weight(a, base + i);
                    }
                    base += comp;
                }
                logits.push(z);
            }
            values.push(0.25);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_independent_and_deterministic() {
        let p = SyntheticPolicy { num_actions: 3 };
        // batch of 4: rows 0/2 identical, rows 1/3 identical
        let mut obs = TensorF32::zeros(&[4, 5]);
        for i in 0..5 {
            obs.set(&[0, i], i as f32 * 0.1);
            obs.set(&[2, i], i as f32 * 0.1);
            obs.set(&[1, i], 1.0 - i as f32 * 0.2);
            obs.set(&[3, i], 1.0 - i as f32 * 0.2);
        }
        let mut ws = ForwardWorkspace::default();
        let (mut l1, mut v1) = (Vec::new(), Vec::new());
        p.forward_into(&[obs.clone()], &mut ws, &mut l1, &mut v1).unwrap();
        assert_eq!(l1.len(), 12);
        assert_eq!(v1.len(), 4);
        assert_eq!(l1[0..3], l1[6..9], "identical rows must give identical logits");
        assert_eq!(l1[3..6], l1[9..12]);
        assert_ne!(l1[0..3], l1[3..6], "distinct rows should differ");
        // repeat call: bit-identical, buffers reused
        let (mut l2, mut v2) = (Vec::new(), Vec::new());
        p.forward_into(&[obs], &mut ws, &mut l2, &mut v2).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(v1, v2);
    }
}
