//! The `LevelSampler` (paper §3.3): a rolling buffer of levels with
//! associated regret scores and staleness, implementing the adversary of
//! replay-based UED methods (PLR, PLR⊥, ACCEL).
//!
//! Supports: replay decisions, batch insertion with capacity eviction,
//! batch score updates, optional de-duplication (insertion of a known level
//! updates it in place), staleness-mixed prioritized sampling, and
//! arbitrary per-level auxiliary data (`level_extra` — e.g. the running max
//! return that the MaxMC score needs).

pub mod prioritization;

// ued-lint: allow(hash-collections) — lookup-only fingerprint→slot map, never iterated
use std::collections::HashMap;

use prioritization::{replay_weights, Prioritization};

use crate::util::rng::Pcg64;

/// Sampler hyperparameters (paper Table 3 defaults).
#[derive(Clone, Debug)]
pub struct SamplerConfig {
    /// Buffer size K.
    pub capacity: usize,
    /// Score→weight transform.
    pub prioritization: Prioritization,
    /// Temperature β.
    pub temperature: f64,
    /// Staleness mixing coefficient ρ.
    pub staleness_coef: f64,
    /// Fraction of capacity that must be filled before replay is allowed
    /// (paper §5.1: 50% by default).
    pub min_fill_ratio: f64,
    /// De-duplicate on insert by level fingerprint.
    pub duplicate_check: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            capacity: 4000,
            prioritization: Prioritization::Rank,
            temperature: 0.3,
            staleness_coef: 0.3,
            min_fill_ratio: 0.5,
            duplicate_check: true,
        }
    }
}

/// A buffered level with its bookkeeping.
#[derive(Clone, Debug)]
pub struct Slot<L, E> {
    pub level: L,
    pub score: f64,
    /// Sampler tick when this level was last inserted/updated/sampled.
    pub last_touch: u64,
    /// Arbitrary auxiliary data (the paper's `level_extra`).
    pub extra: E,
    pub fingerprint: u64,
}

/// Rolling prioritized level buffer.
pub struct LevelSampler<L: Clone, E: Clone + Default> {
    pub config: SamplerConfig,
    slots: Vec<Slot<L, E>>,
    by_fingerprint: HashMap<u64, usize>,
    /// Monotone tick counting insert/sample events (staleness clock).
    tick: u64,
}

impl<L: Clone, E: Clone + Default> LevelSampler<L, E> {
    pub fn new(config: SamplerConfig) -> Self {
        LevelSampler {
            slots: Vec::with_capacity(config.capacity.min(1 << 20)),
            by_fingerprint: HashMap::new(),
            tick: 0,
            config,
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn proportion_filled(&self) -> f64 {
        self.slots.len() as f64 / self.config.capacity as f64
    }

    /// Replay is allowed once the buffer passes the fill threshold.
    pub fn can_replay(&self) -> bool {
        self.proportion_filled() >= self.config.min_fill_ratio
    }

    /// The replay decision (paper Fig. 1): Bernoulli(p) gated on fill.
    pub fn sample_replay_decision(&self, p_replay: f64, rng: &mut Pcg64) -> bool {
        self.can_replay() && rng.gen_bool(p_replay)
    }

    pub fn get(&self, idx: usize) -> &Slot<L, E> {
        &self.slots[idx]
    }

    pub fn get_mut(&mut self, idx: usize) -> &mut Slot<L, E> {
        &mut self.slots[idx]
    }

    pub fn scores(&self) -> Vec<f64> {
        self.slots.iter().map(|s| s.score).collect()
    }

    fn touches(&self) -> Vec<u64> {
        self.slots.iter().map(|s| s.last_touch).collect()
    }

    /// Insert one level. Returns its slot index, or None if it was rejected
    /// (NaN score, or buffer full and score below the current minimum).
    ///
    /// * NaN score (e.g. a MaxMC 0/0 regret estimate): rejected outright —
    ///   a NaN must never enter the replay distribution.
    /// * duplicate (when `duplicate_check`): update score/extra in place.
    /// * buffer not full: append.
    /// * buffer full: evict the lowest-score slot if the new score beats it.
    pub fn insert(&mut self, level: L, score: f64, fingerprint: u64, extra: E) -> Option<usize> {
        if score.is_nan() {
            return None;
        }
        self.tick += 1;
        if self.config.duplicate_check {
            if let Some(&idx) = self.by_fingerprint.get(&fingerprint) {
                let slot = &mut self.slots[idx];
                slot.score = score;
                slot.extra = extra;
                slot.last_touch = self.tick;
                return Some(idx);
            }
        }
        if self.slots.len() < self.config.capacity {
            let idx = self.slots.len();
            self.slots.push(Slot {
                level, score, last_touch: self.tick, extra, fingerprint,
            });
            self.by_fingerprint.insert(fingerprint, idx);
            return Some(idx);
        }
        // Evict the minimum-score slot (ties: lowest index). NaN sorts as
        // the lowest priority, so a NaN-scored slot (possible only via
        // direct `get_mut` mutation) is the first eviction candidate
        // instead of a `partial_cmp().unwrap()` panic that kills training.
        let (min_idx, min_score) = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, s)| (i, s.score))
            .min_by(|a, b| cmp_scores_nan_lowest(a.1, b.1))
            .unwrap();
        // NaN min_score fails this check (any comparison with NaN is
        // false), so the new finite score always beats a NaN slot.
        if score <= min_score {
            return None;
        }
        self.by_fingerprint.remove(&self.slots[min_idx].fingerprint);
        self.by_fingerprint.insert(fingerprint, min_idx);
        self.slots[min_idx] = Slot {
            level, score, last_touch: self.tick, extra, fingerprint,
        };
        Some(min_idx)
    }

    /// Insert a batch; returns per-level slot indices (None = rejected).
    pub fn insert_batch(
        &mut self, levels: &[L], scores: &[f64], fingerprints: &[u64], extras: &[E],
    ) -> Vec<Option<usize>> {
        assert_eq!(levels.len(), scores.len());
        assert_eq!(levels.len(), fingerprints.len());
        assert_eq!(levels.len(), extras.len());
        levels
            .iter()
            .zip(scores)
            .zip(fingerprints)
            .zip(extras)
            .map(|(((l, &s), &f), e)| self.insert(l.clone(), s, f, e.clone()))
            .collect()
    }

    /// Update scores/extras of existing slots (after replaying them).
    ///
    /// A NaN score carries no information (a degenerate regret estimate),
    /// so it keeps the slot's previous score; the extra and staleness
    /// clock still update, since the level *was* replayed.
    pub fn update_batch(&mut self, indices: &[usize], scores: &[f64], extras: &[E]) {
        assert_eq!(indices.len(), scores.len());
        self.tick += 1;
        for ((&i, &s), e) in indices.iter().zip(scores).zip(extras) {
            let slot = &mut self.slots[i];
            if !s.is_nan() {
                slot.score = s;
            }
            slot.extra = e.clone();
            slot.last_touch = self.tick;
        }
    }

    /// Sample `n` distinct slots from the staleness-mixed prioritized
    /// replay distribution; marks them as touched (resets staleness).
    /// Once the positive-weight slots are exhausted, the remaining draws
    /// are uniform over the undrawn slots (the defined degenerate-draw
    /// behavior — see the fallback below).
    pub fn sample_replay_indices(&mut self, n: usize, rng: &mut Pcg64) -> Vec<usize> {
        assert!(!self.slots.is_empty(), "sampling from empty buffer");
        let n = n.min(self.slots.len());
        let mut weights = replay_weights(
            &self.scores(),
            &self.touches(),
            self.tick,
            self.config.prioritization,
            self.config.temperature,
            self.config.staleness_coef,
        );
        let mut drawn = vec![false; weights.len()];
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let total: f64 = weights.iter().sum();
            let i = if total > 0.0 {
                let cand = rng.sample_weighted(&weights);
                if drawn[cand] {
                    // float-edge: rounding in the cumulative scan can
                    // push sample_weighted onto its end-of-slice
                    // fallback, which may be a zeroed (already drawn)
                    // slot; remap to the highest undrawn index so the
                    // without-replacement guarantee survives.
                    (0..drawn.len()).rfind(|&j| !drawn[j]).unwrap()
                } else {
                    cand
                }
            } else {
                // Degenerate draw: every positive-weight slot is already
                // drawn (n exceeds the positive-weight count, e.g. under
                // greedy or proportional prioritization with zero
                // staleness). Fall back to a uniform draw over the
                // undrawn slots instead of handing `sample_weighted` an
                // all-zero vector, whose behavior is unspecified.
                let undrawn: Vec<usize> =
                    (0..drawn.len()).filter(|&j| !drawn[j]).collect();
                undrawn[rng.gen_range(undrawn.len())]
            };
            out.push(i);
            drawn[i] = true;
            weights[i] = 0.0; // without replacement
        }
        self.tick += 1;
        for &i in &out {
            self.slots[i].last_touch = self.tick;
        }
        out
    }

    /// The current replay distribution (diagnostics / tests).
    pub fn replay_distribution(&self) -> Vec<f64> {
        replay_weights(
            &self.scores(),
            &self.touches(),
            self.tick,
            self.config.prioritization,
            self.config.temperature,
            self.config.staleness_coef,
        )
    }
}

/// Total order on scores with NaN as the lowest priority, so a NaN slot
/// is always the first eviction candidate and never wins an insertion
/// race. (`f64::total_cmp` would sort +NaN *above* +inf — exactly wrong
/// for a priority.)
fn cmp_scores_nan_lowest(a: f64, b: f64) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Less,
        (false, true) => Ordering::Greater,
        (false, false) => a.partial_cmp(&b).unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::props;

    type S = LevelSampler<u32, f32>;

    fn sampler(capacity: usize) -> S {
        LevelSampler::new(SamplerConfig { capacity, ..Default::default() })
    }

    #[test]
    fn insert_until_capacity_then_evict_min() {
        let mut s = sampler(3);
        assert_eq!(s.insert(10, 0.5, 10, 0.0), Some(0));
        assert_eq!(s.insert(11, 0.2, 11, 0.0), Some(1));
        assert_eq!(s.insert(12, 0.8, 12, 0.0), Some(2));
        // full; score below min rejected
        assert_eq!(s.insert(13, 0.1, 13, 0.0), None);
        assert_eq!(s.len(), 3);
        // score above min evicts the 0.2 slot (index 1)
        assert_eq!(s.insert(14, 0.9, 14, 0.0), Some(1));
        assert_eq!(s.get(1).level, 14);
    }

    #[test]
    fn dedup_updates_in_place() {
        let mut s = sampler(4);
        s.insert(7, 0.3, 777, 1.0);
        let idx = s.insert(7, 0.6, 777, 2.0);
        assert_eq!(idx, Some(0));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(0).score, 0.6);
        assert_eq!(s.get(0).extra, 2.0);
    }

    #[test]
    fn dedup_disabled_appends() {
        let mut s: S = LevelSampler::new(SamplerConfig {
            capacity: 4,
            duplicate_check: false,
            ..Default::default()
        });
        s.insert(7, 0.3, 777, 0.0);
        s.insert(7, 0.6, 777, 0.0);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn replay_gating() {
        let mut s = sampler(4);
        let mut rng = Pcg64::seed_from_u64(0);
        assert!(!s.sample_replay_decision(1.0, &mut rng)); // empty
        s.insert(1, 0.5, 1, 0.0);
        assert!(!s.can_replay()); // 25% < 50%
        s.insert(2, 0.5, 2, 0.0);
        assert!(s.can_replay());
        assert!(s.sample_replay_decision(1.0, &mut rng));
        assert!(!s.sample_replay_decision(0.0, &mut rng));
    }

    #[test]
    fn sampling_prefers_high_scores() {
        let mut s = sampler(10);
        for i in 0..10u32 {
            s.insert(i, i as f64 / 10.0, i as u64, 0.0);
        }
        let mut rng = Pcg64::seed_from_u64(1);
        let mut counts = [0usize; 10];
        for _ in 0..2000 {
            let idx = s.sample_replay_indices(1, &mut rng)[0];
            counts[s.get(idx).level as usize] += 1;
        }
        assert!(counts[9] > counts[0], "{counts:?}");
        assert!(counts[9] > counts[5], "{counts:?}");
    }

    #[test]
    fn sampling_without_replacement() {
        let mut s = sampler(8);
        for i in 0..8u32 {
            s.insert(i, 0.5, i as u64, 0.0);
        }
        let mut rng = Pcg64::seed_from_u64(2);
        let idx = s.sample_replay_indices(8, &mut rng);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
    }

    #[test]
    fn staleness_resets_on_sample() {
        let mut s = sampler(4);
        s.insert(1, 0.9, 1, 0.0);
        s.insert(2, 0.9, 2, 0.0);
        let mut rng = Pcg64::seed_from_u64(3);
        let idx = s.sample_replay_indices(1, &mut rng)[0];
        let other = 1 - idx;
        // the sampled slot is fresher than the other
        assert!(s.get(idx).last_touch > s.get(other).last_touch);
    }

    #[test]
    fn update_batch_bumps_scores_and_touch() {
        let mut s = sampler(4);
        s.insert(1, 0.1, 1, 0.0);
        s.insert(2, 0.2, 2, 0.0);
        let t0 = s.get(0).last_touch;
        s.update_batch(&[0], &[0.7], &[3.5]);
        assert_eq!(s.get(0).score, 0.7);
        assert_eq!(s.get(0).extra, 3.5);
        assert!(s.get(0).last_touch > t0);
    }

    #[test]
    fn staleness_influences_sampling() {
        let mut s: S = LevelSampler::new(SamplerConfig {
            capacity: 2,
            staleness_coef: 0.9,
            ..Default::default()
        });
        s.insert(1, 0.99, 1, 0.0); // high score
        s.insert(2, 0.01, 2, 0.0); // low score
        let mut rng = Pcg64::seed_from_u64(4);
        // repeatedly sample; high staleness coef must let the low-score
        // level through regularly because it goes stale whenever unsampled
        let mut low_hits = 0;
        for _ in 0..200 {
            let idx = s.sample_replay_indices(1, &mut rng)[0];
            if s.get(idx).level == 2 {
                low_hits += 1;
            }
        }
        assert!(low_hits > 30, "staleness ignored: {low_hits}");
    }

    #[test]
    fn nan_insert_rejected() {
        // Regression: a single NaN regret score (MaxMC 0/0) used to panic
        // inside the full-buffer eviction's partial_cmp().unwrap().
        let mut s = sampler(2);
        assert_eq!(s.insert(1, f64::NAN, 1, 0.0), None, "non-full buffer");
        assert_eq!(s.len(), 0);
        s.insert(1, 0.4, 1, 0.0);
        s.insert(2, 0.6, 2, 0.0);
        assert_eq!(s.insert(3, f64::NAN, 3, 0.0), None, "full buffer");
        assert_eq!(s.len(), 2);
        assert!(s.scores().iter().all(|x| !x.is_nan()));
        // dedup path: NaN must not clobber an existing finite score
        assert_eq!(s.insert(1, f64::NAN, 1, 9.0), None);
        assert_eq!(s.get(0).score, 0.4);
    }

    #[test]
    fn nan_slot_evicted_first() {
        let mut s = sampler(2);
        s.insert(1, 0.9, 1, 0.0);
        s.insert(2, 0.8, 2, 0.0);
        // a NaN can only enter via direct mutation; eviction must still
        // treat it as lowest priority instead of panicking
        s.get_mut(0).score = f64::NAN;
        let idx = s.insert(3, 0.1, 3, 0.0);
        assert_eq!(idx, Some(0), "NaN slot is the eviction candidate");
        assert_eq!(s.get(0).level, 3);
        assert!(s.scores().iter().all(|x| !x.is_nan()));
    }

    #[test]
    fn update_batch_nan_keeps_previous_score() {
        let mut s = sampler(4);
        s.insert(1, 0.5, 1, 1.0);
        let t0 = s.get(0).last_touch;
        s.update_batch(&[0], &[f64::NAN], &[2.0]);
        assert_eq!(s.get(0).score, 0.5, "NaN carries no score information");
        assert_eq!(s.get(0).extra, 2.0, "extra still updates");
        assert!(s.get(0).last_touch > t0, "staleness clock still resets");
    }

    #[test]
    fn degenerate_draw_falls_back_to_uniform() {
        // Proportional weights with zero staleness: only one slot has
        // positive weight, so draws 2..4 exhaust the weight vector.
        let mut s: S = LevelSampler::new(SamplerConfig {
            capacity: 4,
            prioritization: Prioritization::Proportional,
            temperature: 1.0,
            staleness_coef: 0.0,
            ..Default::default()
        });
        s.insert(0, 1.0, 0, 0.0);
        for i in 1..4u32 {
            s.insert(i, 0.0, i as u64, 0.0);
        }
        let mut rng = Pcg64::seed_from_u64(7);
        for _ in 0..50 {
            let idx = s.sample_replay_indices(4, &mut rng);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "all slots drawn exactly once");
        }
        // the positive-weight slot always wins the first (weighted) draw
        let idx = s.sample_replay_indices(3, &mut rng);
        assert_eq!(idx[0], 0);
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn prop_fingerprint_map_consistent() {
        props(100, |g| {
            let cap = g.usize_in(1, 16);
            let n_ops = g.usize_in(1, 60);
            let mut s: S = LevelSampler::new(SamplerConfig {
                capacity: cap,
                ..Default::default()
            });
            for _ in 0..n_ops {
                let fp = g.usize_in(0, 24) as u64;
                let score = g.f64_in(0.0, 1.0);
                s.insert(fp as u32, score, fp, 0.0);
            }
            prop_assert!(s.len() <= cap, "len {} > cap {cap}", s.len());
            // fingerprint map matches slots exactly
            for i in 0..s.len() {
                let fp = s.get(i).fingerprint;
                prop_assert!(
                    s.by_fingerprint.get(&fp) == Some(&i),
                    "map inconsistent at slot {i}"
                );
            }
            prop_assert!(
                s.by_fingerprint.len() == s.len(),
                "map size {} != slots {}", s.by_fingerprint.len(), s.len()
            );
            Ok(())
        });
    }

    #[test]
    fn prop_evict_reinsert_cycles_with_nan() {
        // Hammer the buffer with interleaved insert / duplicate-update /
        // rescore / sample ops, including NaN scores, and check the
        // fingerprint map and the no-stored-NaN invariant survive
        // arbitrary evict-reinsert cycles.
        props(100, |g| {
            let cap = g.usize_in(1, 8);
            let n_ops = g.usize_in(1, 80);
            let mut s: S = LevelSampler::new(SamplerConfig {
                capacity: cap,
                ..Default::default()
            });
            for _ in 0..n_ops {
                match g.usize_in(0, 3) {
                    0 | 1 => {
                        let fp = g.usize_in(0, 12) as u64;
                        let score = if g.bool(0.15) {
                            f64::NAN
                        } else {
                            g.f64_in(0.0, 1.0)
                        };
                        s.insert(fp as u32, score, fp, 0.0);
                    }
                    2 => {
                        if !s.is_empty() {
                            let i = g.usize_in(0, s.len() - 1);
                            let score = if g.bool(0.15) {
                                f64::NAN
                            } else {
                                g.f64_in(0.0, 1.0)
                            };
                            s.update_batch(&[i], &[score], &[1.0]);
                        }
                    }
                    _ => {
                        if !s.is_empty() {
                            let n = g.usize_in(1, s.len());
                            let idx = s.sample_replay_indices(n, g.rng());
                            let mut sorted = idx.clone();
                            sorted.sort_unstable();
                            sorted.dedup();
                            prop_assert!(
                                sorted.len() == n,
                                "replay draw repeated a slot: {idx:?}"
                            );
                        }
                    }
                }
            }
            prop_assert!(s.len() <= cap, "len {} > cap {cap}", s.len());
            for i in 0..s.len() {
                let slot = s.get(i);
                prop_assert!(
                    !slot.score.is_nan(),
                    "NaN score stored at slot {i}"
                );
                prop_assert!(
                    s.by_fingerprint.get(&slot.fingerprint) == Some(&i),
                    "map inconsistent at slot {i}"
                );
            }
            prop_assert!(
                s.by_fingerprint.len() == s.len(),
                "map size {} != slots {}", s.by_fingerprint.len(), s.len()
            );
            Ok(())
        });
    }

    #[test]
    fn prop_distribution_normalized() {
        props(50, |g| {
            let n = g.usize_in(1, 30);
            let mut s = sampler(64);
            for i in 0..n {
                s.insert(i as u32, g.f64_in(0.0, 1.0), i as u64, 0.0);
            }
            let w = s.replay_distribution();
            let total: f64 = w.iter().sum();
            prop_assert!((total - 1.0).abs() < 1e-9, "sum {total}");
            prop_assert!(w.iter().all(|&x| x >= 0.0), "negative weight");
            Ok(())
        });
    }
}
