//! Score→probability transforms for the level sampler (Jiang et al. 2021b).
//!
//! The replay distribution mixes a score-prioritized term with a staleness
//! term:  P = (1 − ρ)·P_score + ρ·P_stale.  P_score supports rank
//! prioritization (the paper's default, Table 3: rank with temperature
//! β = 0.3), proportional, and greedy; P_stale is proportional to the time
//! since a level was last sampled.

/// How scores become sampling weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Prioritization {
    /// weight_i = (1 / rank_i)^(1/β); rank 1 = highest score.
    Rank,
    /// weight_i = score_i^(1/β) (scores must be non-negative).
    Proportional,
    /// All mass on the argmax score.
    Greedy,
}

/// Normalized score-prioritized distribution over `scores`.
pub fn score_weights(
    scores: &[f64], prioritization: Prioritization, temperature: f64,
) -> Vec<f64> {
    assert!(temperature > 0.0);
    let n = scores.len();
    if n == 0 {
        return Vec::new();
    }
    let mut w = vec![0.0; n];
    match prioritization {
        Prioritization::Rank => {
            // argsort by score descending; ties broken by index (stable).
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
            });
            for (rank0, &i) in order.iter().enumerate() {
                w[i] = (1.0 / (rank0 + 1) as f64).powf(1.0 / temperature);
            }
        }
        Prioritization::Proportional => {
            for (i, &s) in scores.iter().enumerate() {
                debug_assert!(s >= 0.0, "proportional prioritization wants non-negative scores");
                w[i] = s.max(0.0).powf(1.0 / temperature);
            }
        }
        Prioritization::Greedy => {
            let mut best = 0;
            for i in 1..n {
                if scores[i] > scores[best] {
                    best = i;
                }
            }
            w[best] = 1.0;
        }
    }
    normalize(&mut w);
    w
}

/// Normalized staleness distribution: proportional to `now − last_touch`;
/// uniform when nothing is stale.
pub fn staleness_weights(last_touch: &[u64], now: u64) -> Vec<f64> {
    let n = last_touch.len();
    if n == 0 {
        return Vec::new();
    }
    let mut w: Vec<f64> = last_touch
        .iter()
        .map(|&t| now.saturating_sub(t) as f64)
        .collect();
    if w.iter().sum::<f64>() <= 0.0 {
        w.iter_mut().for_each(|x| *x = 1.0);
    }
    normalize(&mut w);
    w
}

/// Final replay distribution.
pub fn replay_weights(
    scores: &[f64], last_touch: &[u64], now: u64,
    prioritization: Prioritization, temperature: f64, staleness_coef: f64,
) -> Vec<f64> {
    let ps = score_weights(scores, prioritization, temperature);
    if staleness_coef <= 0.0 {
        return ps;
    }
    let pt = staleness_weights(last_touch, now);
    ps.iter()
        .zip(&pt)
        .map(|(&a, &b)| (1.0 - staleness_coef) * a + staleness_coef * b)
        .collect()
}

fn normalize(w: &mut [f64]) {
    let total: f64 = w.iter().sum();
    if total > 0.0 {
        w.iter_mut().for_each(|x| *x /= total);
    } else if !w.is_empty() {
        let u = 1.0 / w.len() as f64;
        w.iter_mut().for_each(|x| *x = u);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-9
    }

    #[test]
    fn rank_orders_weights() {
        let w = score_weights(&[0.1, 0.9, 0.5], Prioritization::Rank, 0.3);
        assert!(w[1] > w[2] && w[2] > w[0]);
        assert!(close(w.iter().sum(), 1.0));
    }

    #[test]
    fn rank_temperature_sharpens() {
        let sharp = score_weights(&[0.1, 0.9, 0.5], Prioritization::Rank, 0.1);
        let flat = score_weights(&[0.1, 0.9, 0.5], Prioritization::Rank, 10.0);
        assert!(sharp[1] > flat[1]);
        assert!(flat[0] > sharp[0]);
    }

    #[test]
    fn rank_invariant_to_scale() {
        let a = score_weights(&[1.0, 2.0, 3.0], Prioritization::Rank, 0.3);
        let b = score_weights(&[10.0, 20.0, 30.0], Prioritization::Rank, 0.3);
        for (x, y) in a.iter().zip(&b) {
            assert!(close(*x, *y));
        }
    }

    #[test]
    fn proportional_weights() {
        let w = score_weights(&[1.0, 3.0], Prioritization::Proportional, 1.0);
        assert!(close(w[0], 0.25) && close(w[1], 0.75));
    }

    #[test]
    fn greedy_all_mass_on_max() {
        let w = score_weights(&[0.2, 0.9, 0.4], Prioritization::Greedy, 0.3);
        assert_eq!(w, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn staleness_proportional() {
        let w = staleness_weights(&[10, 0, 5], 10);
        assert!(close(w[0], 0.0));
        assert!(close(w[1], 10.0 / 15.0));
        assert!(close(w[2], 5.0 / 15.0));
    }

    #[test]
    fn staleness_uniform_when_fresh() {
        let w = staleness_weights(&[5, 5], 5);
        assert!(close(w[0], 0.5) && close(w[1], 0.5));
    }

    #[test]
    fn replay_mixes() {
        let scores = [0.9, 0.1];
        let touch = [10, 0]; // second level much staler
        let w_pure = replay_weights(&scores, &touch, 10, Prioritization::Rank, 0.3, 0.0);
        let w_mixed = replay_weights(&scores, &touch, 10, Prioritization::Rank, 0.3, 0.5);
        assert!(w_pure[0] > w_mixed[0], "staleness should pull mass to level 1");
        assert!(close(w_mixed.iter().sum(), 1.0));
    }

    #[test]
    fn empty_inputs() {
        assert!(score_weights(&[], Prioritization::Rank, 0.3).is_empty());
        assert!(staleness_weights(&[], 0).is_empty());
    }
}
