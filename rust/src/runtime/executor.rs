//! Compiled-artifact wrapper: shape-checked positional calls into PJRT.
//!
//! Wraps `xla::PjRtLoadedExecutable` with the manifest signature so every
//! call validates argument count (and, in debug builds, shapes) before
//! hitting the C API, and unpacks the tuple result into a flat literal
//! list. All compute artifacts return tuples (`return_tuple=True` at
//! lowering), so `call` always untuples.

use anyhow::{bail, Context, Result};

use super::manifest::ArtifactDef;

/// A compiled, callable artifact.
pub struct Executable {
    pub def: ArtifactDef,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    pub fn compile(
        client: &xla::PjRtClient, def: &ArtifactDef, dir: &std::path::Path,
    ) -> Result<Executable> {
        let path = dir.join(&def.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", def.name))?;
        Ok(Executable { def: def.clone(), exe })
    }

    /// Execute with positional literal arguments; returns the untupled
    /// output literals (order per `def.outputs`).
    pub fn call(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.def.inputs.len() {
            bail!(
                "artifact {} wants {} inputs, got {}",
                self.def.name,
                self.def.inputs.len(),
                args.len()
            );
        }
        #[cfg(debug_assertions)]
        for (i, (a, spec)) in args.iter().zip(&self.def.inputs).enumerate() {
            let n = a.element_count();
            if n != spec.elements() {
                bail!(
                    "artifact {} input {i}: {} elements, expected {:?}",
                    self.def.name, n, spec.shape
                );
            }
        }
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.def.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.def.name))?;
        let parts = literal.to_tuple()?;
        if parts.len() != self.def.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                self.def.name,
                parts.len(),
                self.def.outputs.len()
            );
        }
        Ok(parts)
    }
}
