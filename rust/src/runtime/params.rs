//! Parameter + optimizer-state management and checkpoints.
//!
//! A `ParamSet` owns the flat literal lists the train-step ABI threads
//! through every update: `params…, m…, v…, count`. It is produced by the
//! `*_init` artifact, consumed/updated by `*_train_step`, and its `params`
//! prefix feeds `*_apply`. Checkpointing uses a self-describing little-
//! endian binary format (magic, network name, per-tensor shape + f32 data).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::NetworkDef;

const CKPT_MAGIC: &[u8; 8] = b"JAXUED01";

/// Adam-optimized parameter state for one network.
pub struct ParamSet {
    /// Which network this belongs to (checkpoint sanity checks).
    pub network: String,
    /// P parameter tensors, manifest order.
    pub params: Vec<xla::Literal>,
    /// Adam first/second moments, same order.
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    /// Adam step count (scalar f32).
    pub count: xla::Literal,
}

impl ParamSet {
    /// Build from the `*_init` artifact's output list.
    pub fn from_init_outputs(
        network: &str, net: &NetworkDef, mut outputs: Vec<xla::Literal>,
    ) -> Result<ParamSet> {
        let p = net.num_params();
        if outputs.len() != 3 * p + 1 {
            bail!("init returned {} tensors, expected {}", outputs.len(), 3 * p + 1);
        }
        let count = outputs.pop().unwrap();
        let v = outputs.split_off(2 * p);
        let m = outputs.split_off(p);
        Ok(ParamSet { network: network.to_string(), params: outputs, m, v, count })
    }

    /// Flat argument prefix for `*_train_step`: params…, m…, v…, count.
    pub fn train_args(&self) -> Vec<xla::Literal> {
        let mut out = Vec::with_capacity(3 * self.params.len() + 1);
        out.extend(self.params.iter().cloned());
        out.extend(self.m.iter().cloned());
        out.extend(self.v.iter().cloned());
        out.push(self.count.clone());
        out
    }

    /// Absorb the `params'…, m'…, v'…, count'` prefix of a train-step
    /// result; returns the remaining outputs (the metrics tail).
    pub fn absorb_train_outputs(&mut self, mut outputs: Vec<xla::Literal>) -> Result<Vec<xla::Literal>> {
        let p = self.params.len();
        if outputs.len() < 3 * p + 1 {
            bail!("train step returned {} tensors, need >= {}", outputs.len(), 3 * p + 1);
        }
        let rest = outputs.split_off(3 * p + 1);
        self.count = outputs.pop().unwrap();
        self.v = outputs.split_off(2 * p);
        self.m = outputs.split_off(p);
        self.params = outputs;
        Ok(rest)
    }

    /// Adam step count as an integer (diagnostics).
    pub fn step_count(&self) -> Result<u64> {
        Ok(self.count.to_vec::<f32>()?[0] as u64)
    }

    /// Serialize params + optimizer state.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(CKPT_MAGIC)?;
        let name = self.network.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        let groups: [&[xla::Literal]; 3] = [&self.params, &self.m, &self.v];
        f.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for group in groups {
            for lit in group {
                write_tensor(&mut f, lit)?;
            }
        }
        write_tensor(&mut f, &self.count)?;
        Ok(())
    }

    /// Load a checkpoint previously written by `save`.
    pub fn load(path: &Path, expect_network: &str) -> Result<ParamSet> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != CKPT_MAGIC {
            bail!("{path:?} is not a jaxued checkpoint");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let mut name = vec![0u8; u32::from_le_bytes(len4) as usize];
        f.read_exact(&mut name)?;
        let network = String::from_utf8(name)?;
        if network != expect_network {
            bail!("checkpoint is for network {network:?}, expected {expect_network:?}");
        }
        f.read_exact(&mut len4)?;
        let p = u32::from_le_bytes(len4) as usize;
        let read_group = |f: &mut dyn Read| -> Result<Vec<xla::Literal>> {
            (0..p).map(|_| read_tensor(f)).collect()
        };
        let params = read_group(&mut f)?;
        let m = read_group(&mut f)?;
        let v = read_group(&mut f)?;
        let count = read_tensor(&mut f)?;
        Ok(ParamSet { network, params, m, v, count })
    }

    /// Total parameter count (excluding optimizer state).
    pub fn num_parameters(&self) -> usize {
        self.params.iter().map(|l| l.element_count()).sum()
    }
}

fn write_tensor(f: &mut dyn Write, lit: &xla::Literal) -> Result<()> {
    let shape = lit.array_shape()?;
    let dims = shape.dims();
    f.write_all(&(dims.len() as u32).to_le_bytes())?;
    for &d in dims {
        f.write_all(&(d as u64).to_le_bytes())?;
    }
    let data = lit.to_vec::<f32>()?;
    for x in data {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_tensor(f: &mut dyn Read) -> Result<xla::Literal> {
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let rank = u32::from_le_bytes(b4) as usize;
    let mut dims = Vec::with_capacity(rank);
    let mut b8 = [0u8; 8];
    for _ in 0..rank {
        f.read_exact(&mut b8)?;
        dims.push(u64::from_le_bytes(b8) as i64);
    }
    let n: i64 = dims.iter().product::<i64>().max(1);
    let mut data = vec![0f32; n as usize];
    let mut buf = [0u8; 4];
    for x in data.iter_mut() {
        f.read_exact(&mut buf)?;
        *x = f32::from_le_bytes(buf);
    }
    let lit = xla::Literal::vec1(&data);
    if rank == 0 {
        // scalar: vec1 gives shape [1]; reshape to []
        Ok(lit.reshape(&[])?)
    } else {
        Ok(lit.reshape(&dims)?)
    }
}
