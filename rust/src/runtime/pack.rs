//! Seed-pack run manifest: records which per-seed run directories a
//! `--seeds` pack produced, plus enough config to interpret them, so
//! downstream tooling (Figure-3 aggregation, `jaxued info`, resume
//! logic) can locate every member run without globbing `out_dir`.
//!
//! Written as `pack_manifest.json` inside the pack directory by the
//! orchestrator, next to the cross-seed `aggregate.csv`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// File name of the manifest inside a pack directory.
pub const PACK_MANIFEST_NAME: &str = "pack_manifest.json";

/// What a seed pack ran and where each member run lives.
///
/// Seeds are stored as JSON numbers, exact up to 2^53 — far beyond any
/// seed a sweep would use.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackManifest {
    pub env: String,
    pub algo: String,
    pub variant: String,
    pub seeds: Vec<u64>,
    /// Per-seed run-directory names (relative to the pack's parent
    /// `out_dir`), in `seeds` order.
    pub run_dirs: Vec<String>,
    /// Cross-seed aggregate CSV file name inside the pack directory.
    pub aggregate_csv: String,
    pub env_steps_budget: u64,
    /// Worker threads of the single shared rollout pool.
    pub rollout_threads: usize,
}

impl PackManifest {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("env".to_string(), Json::from(self.env.as_str()));
        m.insert("algo".to_string(), Json::from(self.algo.as_str()));
        m.insert("variant".to_string(), Json::from(self.variant.as_str()));
        m.insert(
            "seeds".to_string(),
            Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect()),
        );
        m.insert(
            "run_dirs".to_string(),
            Json::Arr(
                self.run_dirs
                    .iter()
                    .map(|d| Json::from(d.as_str()))
                    .collect(),
            ),
        );
        m.insert(
            "aggregate_csv".to_string(),
            Json::from(self.aggregate_csv.as_str()),
        );
        m.insert(
            "env_steps_budget".to_string(),
            Json::Num(self.env_steps_budget as f64),
        );
        m.insert(
            "rollout_threads".to_string(),
            Json::from(self.rollout_threads),
        );
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<PackManifest> {
        let str_field = |key: &str| -> Result<String> {
            Ok(j.req(key)?
                .as_str()
                .with_context(|| format!("pack manifest {key:?} is not a string"))?
                .to_string())
        };
        let seeds = j
            .req("seeds")?
            .as_arr()
            .context("pack manifest seeds is not an array")?
            .iter()
            .map(|x| {
                let v = x
                    .as_f64()
                    .context("pack manifest seed is not a number")?;
                anyhow::ensure!(
                    v >= 0.0 && v.fract() == 0.0,
                    "pack manifest seed {v} is not a non-negative integer"
                );
                Ok(v as u64)
            })
            .collect::<Result<Vec<u64>>>()?;
        let run_dirs = j
            .req("run_dirs")?
            .as_arr()
            .context("pack manifest run_dirs is not an array")?
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .context("pack manifest run_dir is not a string")
            })
            .collect::<Result<Vec<String>>>()?;
        anyhow::ensure!(
            seeds.len() == run_dirs.len(),
            "pack manifest has {} seeds but {} run dirs",
            seeds.len(),
            run_dirs.len()
        );
        Ok(PackManifest {
            env: str_field("env")?,
            algo: str_field("algo")?,
            variant: str_field("variant")?,
            seeds,
            run_dirs,
            aggregate_csv: str_field("aggregate_csv")?,
            env_steps_budget: j
                .req("env_steps_budget")?
                .as_f64()
                .context("pack manifest env_steps_budget is not a number")?
                as u64,
            rollout_threads: j
                .req("rollout_threads")?
                .as_usize()
                .context("pack manifest rollout_threads is not a number")?,
        })
    }

    /// Write `pack_manifest.json` into `pack_dir` (created if missing);
    /// returns the file path.
    pub fn write(&self, pack_dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(pack_dir)
            .with_context(|| format!("creating pack dir {}", pack_dir.display()))?;
        let path = pack_dir.join(PACK_MANIFEST_NAME);
        std::fs::write(&path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    /// Load the manifest from a pack directory.
    pub fn load(pack_dir: &Path) -> Result<PackManifest> {
        let path = pack_dir.join(PACK_MANIFEST_NAME);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PackManifest {
        PackManifest {
            env: "maze".into(),
            algo: "accel".into(),
            variant: "small".into(),
            seeds: vec![0, 1, 3],
            run_dirs: vec!["accel_s0".into(), "accel_s1".into(), "accel_s3".into()],
            aggregate_csv: "aggregate.csv".into(),
            env_steps_budget: 245_760_000,
            rollout_threads: 8,
        }
    }

    #[test]
    fn roundtrips_through_disk() {
        let dir = std::env::temp_dir().join("jaxued_pack_manifest_test");
        let m = sample();
        let path = m.write(&dir).unwrap();
        assert!(path.ends_with(PACK_MANIFEST_NAME));
        let back = PackManifest::load(&dir).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_mismatched_lengths() {
        let dir = std::env::temp_dir().join("jaxued_pack_manifest_bad");
        let mut m = sample();
        m.run_dirs.pop();
        m.write(&dir).unwrap();
        assert!(PackManifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_non_integer_seeds() {
        let dir = std::env::temp_dir().join("jaxued_pack_manifest_fracseed");
        std::fs::create_dir_all(&dir).unwrap();
        let good = sample().to_json().to_string();
        let bad = good.replace("[0,1,3]", "[1.5,-1,3]");
        assert_ne!(good, bad, "replacement must hit the seeds array");
        std::fs::write(dir.join(PACK_MANIFEST_NAME), bad).unwrap();
        assert!(PackManifest::load(&dir).is_err());
    }

    #[test]
    fn load_missing_is_err() {
        let dir = std::env::temp_dir().join("jaxued_pack_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(PackManifest::load(&dir).is_err());
    }
}
