//! The artifact manifest: the ABI contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! `artifacts/manifest.json` records, for every lowered HLO module, its
//! positional input/output signature, plus the parameter ordering of each
//! network and the environment geometry constants baked into the python
//! model. The runtime cross-checks those constants against the Rust env at
//! startup so an incompatible artifact set fails loudly, not numerically.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .req("shape")?
            .as_arr()
            .context("shape not an array")?
            .iter()
            .map(|x| x.as_usize().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.req("dtype")?.as_str().context("bad dtype")?.to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One lowered artifact.
#[derive(Clone, Debug)]
pub struct ArtifactDef {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub network: Option<String>,
    pub t: Option<usize>,
    pub b: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// A network's parameter layout.
#[derive(Clone, Debug)]
pub struct NetworkDef {
    pub param_order: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub n_obs: usize,
}

impl NetworkDef {
    pub fn num_params(&self) -> usize {
        self.param_order.len()
    }

    pub fn total_elements(&self) -> usize {
        self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// Environment/model constants baked at AOT time.
#[derive(Clone, Debug)]
pub struct Constants {
    pub grid_w: usize,
    pub grid_h: usize,
    pub view: usize,
    pub obs_channels: usize,
    pub num_actions: usize,
    pub num_directions: usize,
    pub adv_num_actions: usize,
    pub adv_noise_dim: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub constants: Constants,
    pub metric_names: Vec<String>,
    pub score_output_names: Vec<String>,
    pub networks: BTreeMap<String, NetworkDef>,
    pub artifacts: BTreeMap<String, ArtifactDef>,
}

fn str_list(j: &Json) -> Result<Vec<String>> {
    Ok(j.as_arr()
        .context("expected array")?
        .iter()
        .filter_map(|x| x.as_str().map(String::from))
        .collect())
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let c = j.req("constants")?;
        let constant = |k: &str| -> Result<usize> {
            c.req(k)?.as_usize().with_context(|| format!("constant {k}"))
        };
        let constants = Constants {
            grid_w: constant("grid_w")?,
            grid_h: constant("grid_h")?,
            view: constant("view")?,
            obs_channels: constant("obs_channels")?,
            num_actions: constant("num_actions")?,
            num_directions: constant("num_directions")?,
            adv_num_actions: constant("adv_num_actions")?,
            adv_noise_dim: constant("adv_noise_dim")?,
        };

        let mut networks = BTreeMap::new();
        for (name, nd) in j.req("networks")?.as_obj().context("networks")? {
            let param_order = str_list(nd.req("param_order")?)?;
            let param_shapes = nd
                .req("params")?
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| {
                    p.req("shape")?
                        .as_arr()
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let n_obs = nd.req("n_obs")?.as_usize().context("n_obs")?;
            networks.insert(
                name.clone(),
                NetworkDef { param_order, param_shapes, n_obs },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in j.req("artifacts")?.as_arr().context("artifacts")? {
            let name = a.req("name")?.as_str().context("name")?.to_string();
            let def = ArtifactDef {
                name: name.clone(),
                file: a.req("file")?.as_str().context("file")?.to_string(),
                kind: a.req("kind")?.as_str().context("kind")?.to_string(),
                network: a.get("network").and_then(|x| x.as_str()).map(String::from),
                t: a.get("T").and_then(|x| x.as_usize()),
                b: a.get("B").and_then(|x| x.as_usize()),
                inputs: a
                    .req("inputs")?
                    .as_arr()
                    .context("inputs")?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .req("outputs")?
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<Vec<_>>>()?,
            };
            artifacts.insert(name, def);
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            constants,
            metric_names: str_list(j.req("metric_names")?)?,
            score_output_names: str_list(j.req("score_output_names")?)?,
            networks,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactDef> {
        self.artifacts
            .get(name)
            .with_context(|| {
                format!(
                    "artifact {name:?} not in manifest (have: {:?})",
                    self.artifacts.keys().collect::<Vec<_>>()
                )
            })
    }

    pub fn network(&self, name: &str) -> Result<&NetworkDef> {
        self.networks
            .get(name)
            .with_context(|| format!("network {name:?} not in manifest"))
    }

    /// Cross-check baked constants against an environment family's
    /// geometry. Called at runtime startup; a mismatch means artifacts were
    /// built from a different model than the selected env expects.
    pub fn validate_geometry(&self, g: &crate::env::EnvGeometry) -> Result<()> {
        let c = &self.constants;
        if c.grid_w != g.grid_w || c.grid_h != g.grid_h {
            bail!("grid {}x{} != env {}x{}", c.grid_w, c.grid_h, g.grid_w, g.grid_h);
        }
        if c.view != g.view || c.obs_channels != g.obs_channels {
            bail!(
                "view/channels {}x{} != env {}x{}",
                c.view, c.obs_channels, g.view, g.obs_channels
            );
        }
        if c.num_actions != g.num_actions {
            bail!("num_actions {} != env {}", c.num_actions, g.num_actions);
        }
        if c.adv_num_actions != g.adv_num_actions {
            bail!("adv_num_actions {} != {}", c.adv_num_actions, g.adv_num_actions);
        }
        if c.adv_noise_dim != g.adv_noise_dim {
            bail!("adv_noise_dim {} != {}", c.adv_noise_dim, g.adv_noise_dim);
        }
        // The student ABI is [egocentric crop, facing one-hot]: the env's
        // flat observation must fill exactly that many artifact inputs.
        let flat: usize = g.obs_components.iter().sum();
        let expect = c.view * c.view * c.obs_channels + c.num_directions;
        if flat != expect {
            bail!(
                "env obs components {:?} sum to {flat}, artifacts expect {expect} \
                 (view²·channels + directions)",
                g.obs_components
            );
        }
        Ok(())
    }

    /// [`validate_geometry`](Manifest::validate_geometry) against the
    /// compiled-artifact default (maze) geometry.
    pub fn validate_against_env(&self) -> Result<()> {
        self.validate_geometry(&crate::env::EnvGeometry::maze_default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
        assert!(m.artifacts.len() >= 7, "{:?}", m.artifacts.keys());
        m.validate_against_env().unwrap();
        assert_eq!(m.metric_names.len(), 8);
        let student = m.network("student").unwrap();
        assert_eq!(student.num_params(), 8);
        assert_eq!(student.n_obs, 2);
    }

    #[test]
    fn init_artifact_signature() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let a = m.artifact("student_init").unwrap();
        assert_eq!(a.kind, "init");
        assert_eq!(a.inputs.len(), 1);
        // params + m + v + count
        assert_eq!(a.outputs.len(), 3 * 8 + 1);
    }

    #[test]
    fn train_step_shapes_consistent() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        for a in m.artifacts.values().filter(|a| a.kind == "train_step") {
            let p = m.network(a.network.as_ref().unwrap()).unwrap().num_params();
            let n_obs = m.network(a.network.as_ref().unwrap()).unwrap().n_obs;
            // params,m,v + count,lr + obs… + act,logp,val,rew,done + last_val
            assert_eq!(a.inputs.len(), 3 * p + 2 + n_obs + 5 + 1, "{}", a.name);
            assert_eq!(a.outputs.len(), 3 * p + 2, "{}", a.name);
            let (t, b) = (a.t.unwrap(), a.b.unwrap());
            // actions tensor is (T, B) i32
            let act = &a.inputs[3 * p + 2 + n_obs];
            assert_eq!(act.shape, vec![t, b]);
            assert_eq!(act.dtype, "int32");
        }
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.artifact("nope").is_err());
    }
}
