//! The PJRT runtime: loads AOT artifacts (HLO text → compile → execute).
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables are cached per artifact name; the training hot
//! path never recompiles.

pub mod executor;
pub mod manifest;
pub mod pack;
pub mod params;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

pub use executor::Executable;
pub use manifest::Manifest;
pub use pack::PackManifest;
pub use params::ParamSet;

/// The runtime: PJRT client + manifest + executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Start a CPU PJRT client and load the manifest from `dir`, validating
    /// against the compiled-artifact default (maze) geometry.
    pub fn new(dir: &Path) -> Result<Runtime> {
        Self::with_geometry(dir, &crate::env::EnvGeometry::maze_default())
    }

    /// Start a runtime validated against a specific environment family's
    /// geometry (`EnvId::geometry()`), so an incompatible artifact set
    /// fails loudly at startup rather than numerically at rollout time.
    pub fn with_geometry(dir: &Path, geometry: &crate::env::EnvGeometry) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        manifest
            .validate_geometry(geometry)
            .context("artifact/env geometry mismatch — rebuild artifacts")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifacts directory: $JAXUED_ARTIFACTS or ./artifacts.
    pub fn from_env() -> Result<Runtime> {
        Self::from_env_with_geometry(&crate::env::EnvGeometry::maze_default())
    }

    /// [`from_env`](Runtime::from_env)'s directory lookup, validated
    /// against a specific family's geometry.
    pub fn from_env_with_geometry(geometry: &crate::env::EnvGeometry) -> Result<Runtime> {
        let dir = std::env::var("JAXUED_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::with_geometry(Path::new(&dir), geometry)
    }

    /// Resolve an artifact name under an optional env scope: prefer
    /// `"{prefix}_{base}"` when the manifest carries it, falling back to
    /// the shared `base` (families with identical observation geometry —
    /// e.g. lava vs maze — share one compiled artifact set).
    pub fn resolve_name(&self, prefix: Option<&str>, base: &str) -> String {
        if let Some(p) = prefix {
            let scoped = format!("{p}_{base}");
            if self.manifest.artifacts.contains_key(&scoped) {
                return scoped;
            }
        }
        base.to_string()
    }

    /// [`load`](Runtime::load) through [`resolve_name`](Runtime::resolve_name).
    pub fn load_scoped(
        &self, prefix: Option<&str>, base: &str,
    ) -> Result<Arc<Executable>> {
        self.load(&self.resolve_name(prefix, base))
    }

    /// Fetch (compiling + caching on first use) an artifact by name.
    /// Executables are `Arc`-shared so seed-pack driver threads can each
    /// hold the same compiled artifact (`TrainSeedRun` is `Send`).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let def = self.manifest.artifact(name)?;
        let exe = Arc::new(Executable::compile(&self.client, def, &self.manifest.dir)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Initialize a fresh `ParamSet` for `network` with the given seed.
    pub fn init_params(&self, network: &str, seed: i32) -> Result<ParamSet> {
        let init = self.load(&format!("{network}_init"))?;
        let outputs = init.call(&[xla::Literal::scalar(seed)])?;
        let net = self.manifest.network(network)?;
        ParamSet::from_init_outputs(network, net, outputs)
    }
}
