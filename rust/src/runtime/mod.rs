//! The PJRT runtime: loads AOT artifacts (HLO text → compile → execute).
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables are cached per artifact name; the training hot
//! path never recompiles.

pub mod executor;
pub mod manifest;
pub mod pack;
pub mod params;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

pub use executor::Executable;
pub use manifest::Manifest;
pub use pack::PackManifest;
pub use params::ParamSet;

/// The runtime: PJRT client + manifest + executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Start a CPU PJRT client and load the manifest from `dir`, validating
    /// against the compiled-artifact default (maze) geometry.
    pub fn new(dir: &Path) -> Result<Runtime> {
        Self::with_geometry(dir, &crate::env::EnvGeometry::maze_default())
    }

    /// Start a runtime validated against a specific environment family's
    /// geometry (`EnvId::geometry()`), so an incompatible artifact set
    /// fails loudly at startup rather than numerically at rollout time.
    pub fn with_geometry(dir: &Path, geometry: &crate::env::EnvGeometry) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        manifest
            .validate_geometry(geometry)
            .context("artifact/env geometry mismatch — rebuild artifacts")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifacts directory: $JAXUED_ARTIFACTS or ./artifacts.
    pub fn from_env() -> Result<Runtime> {
        Self::from_env_with_geometry(&crate::env::EnvGeometry::maze_default())
    }

    /// [`from_env`](Runtime::from_env)'s directory lookup, validated
    /// against a specific family's geometry.
    pub fn from_env_with_geometry(geometry: &crate::env::EnvGeometry) -> Result<Runtime> {
        let dir = std::env::var("JAXUED_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::with_geometry(Path::new(&dir), geometry)
    }

    /// Resolve an artifact name under an optional env scope: prefer
    /// `"{prefix}_{base}"` when the manifest carries it, falling back to
    /// the shared `base` (families with identical observation geometry —
    /// e.g. lava vs maze — share one compiled artifact set).
    pub fn resolve_name(&self, prefix: Option<&str>, base: &str) -> String {
        if let Some(p) = prefix {
            let scoped = format!("{p}_{base}");
            if self.manifest.artifacts.contains_key(&scoped) {
                return scoped;
            }
        }
        base.to_string()
    }

    /// [`load`](Runtime::load) through [`resolve_name`](Runtime::resolve_name).
    pub fn load_scoped(
        &self, prefix: Option<&str>, base: &str,
    ) -> Result<Arc<Executable>> {
        self.load(&self.resolve_name(prefix, base))
    }

    /// Fetch (compiling + caching on first use) an artifact by name.
    /// Executables are `Arc`-shared so seed-pack driver threads can each
    /// hold the same compiled artifact (`TrainSeedRun` is `Send`).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let def = self.manifest.artifact(name)?;
        let exe = Arc::new(Executable::compile(&self.client, def, &self.manifest.dir)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Initialize a fresh `ParamSet` for `network` with the given seed.
    pub fn init_params(&self, network: &str, seed: i32) -> Result<ParamSet> {
        let init = self.load(&format!("{network}_init"))?;
        let outputs = init.call(&[xla::Literal::scalar(seed)])?;
        let net = self.manifest.network(network)?;
        ParamSet::from_init_outputs(network, net, outputs)
    }
}

/// Scan a policy-zoo directory for trained checkpoints. Two layouts are
/// recognized, so both a curated zoo of exported files and a raw `runs/`
/// training directory serve as-is:
///
/// - `<dir>/<id>.ckpt`            → policy id `<id>`
/// - `<dir>/<id>/student.ckpt`    → policy id `<id>` (run-dir layout)
///
/// Returns `(policy_id, checkpoint_path)` pairs sorted by id — the zoo
/// listing is deterministic regardless of readdir order. A missing zoo
/// directory is an empty zoo, not an error (servers routinely start with
/// a synthetic-only zoo).
pub fn discover_checkpoints(dir: &Path) -> Result<Vec<(String, std::path::PathBuf)>> {
    let mut found = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(found),
        Err(e) => return Err(e).context(format!("scanning zoo dir {}", dir.display())),
    };
    for entry in entries {
        let entry = entry.context("reading zoo dir entry")?;
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if path.is_file() {
            if let Some(id) = name.strip_suffix(".ckpt") {
                if !id.is_empty() {
                    found.push((id.to_string(), path.clone()));
                }
            }
        } else if path.is_dir() {
            let ckpt = path.join("student.ckpt");
            if ckpt.is_file() {
                found.push((name.to_string(), ckpt));
            }
        }
    }
    found.sort();
    Ok(found)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_discovery_layouts_and_ordering() {
        let dir = std::env::temp_dir().join("jaxued_zoo_discovery_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("run_b")).unwrap();
        std::fs::create_dir_all(dir.join("not_a_run")).unwrap();
        std::fs::write(dir.join("zeta.ckpt"), b"z").unwrap();
        std::fs::write(dir.join("alpha.ckpt"), b"a").unwrap();
        std::fs::write(dir.join("run_b").join("student.ckpt"), b"b").unwrap();
        std::fs::write(dir.join("notes.txt"), b"ignored").unwrap();
        std::fs::write(dir.join(".ckpt"), b"empty id is ignored").unwrap();

        let zoo = discover_checkpoints(&dir).unwrap();
        let ids: Vec<&str> = zoo.iter().map(|(id, _)| id.as_str()).collect();
        assert_eq!(ids, ["alpha", "run_b", "zeta"], "sorted by id");
        let by_id = |want: &str| {
            zoo.iter().find(|(id, _)| id == want).map(|(_, p)| p.clone()).unwrap()
        };
        assert_eq!(by_id("alpha"), dir.join("alpha.ckpt"));
        assert_eq!(by_id("run_b"), dir.join("run_b").join("student.ckpt"));

        // a missing directory is an empty zoo, not an error
        assert!(discover_checkpoints(&dir.join("missing")).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
