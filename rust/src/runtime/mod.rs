//! The PJRT runtime: loads AOT artifacts (HLO text → compile → execute).
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables are cached per artifact name; the training hot
//! path never recompiles.

pub mod executor;
pub mod manifest;
pub mod params;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

pub use executor::Executable;
pub use manifest::Manifest;
pub use params::ParamSet;

/// The runtime: PJRT client + manifest + executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Start a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        manifest
            .validate_against_env()
            .context("artifact/env geometry mismatch — rebuild artifacts")?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifacts directory: $JAXUED_ARTIFACTS or ./artifacts.
    pub fn from_env() -> Result<Runtime> {
        let dir = std::env::var("JAXUED_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(Path::new(&dir))
    }

    /// Fetch (compiling + caching on first use) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let def = self.manifest.artifact(name)?;
        let exe = Rc::new(Executable::compile(&self.client, def, &self.manifest.dir)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Initialize a fresh `ParamSet` for `network` with the given seed.
    pub fn init_params(&self, network: &str, seed: i32) -> Result<ParamSet> {
        let init = self.load(&format!("{network}_init"))?;
        let outputs = init.call(&[xla::Literal::scalar(seed)])?;
        let net = self.manifest.network(network)?;
        ParamSet::from_init_outputs(network, net, outputs)
    }
}
