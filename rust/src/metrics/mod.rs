//! Metric logging and wallclock accounting.
//!
//! The paper logs to Weights & Biases; we substitute a CSV sink plus
//! stdout (DESIGN.md substitutions). [`CrossSeedSink`] adds the seed-pack
//! aggregation layer: one row per cycle with mean / IQM / stderr over the
//! pack's seeds (the Figure-3 quantities, computed online). `Stopwatch`
//! provides the Table-1 wallclock accounting: cumulative seconds and
//! env-steps/s, with extrapolation to the paper's full 245.76M-step
//! budget.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::util::stats;

/// Rows between flushes for [`CsvSink::create`]. Small enough that a
/// crashed run loses at most a few seconds of curve, large enough that N
/// pack seeds logging every cycle don't turn the `BufWriter` into a
/// per-row syscall.
pub const DEFAULT_FLUSH_EVERY: usize = 64;

/// Append-only CSV metric sink. Columns are fixed at creation.
///
/// Rows are buffered and flushed every `flush_every` rows, plus a
/// best-effort flush on drop (the inner `BufWriter`'s own `Drop`) —
/// flushing per row would defeat the `BufWriter` (one syscall per row ×
/// N pack seeds × 30k cycles). The column-arity error stays eager: a
/// malformed row fails at `write_row`, never at flush time.
pub struct CsvSink {
    file: std::io::BufWriter<std::fs::File>,
    columns: Vec<String>,
    flush_every: usize,
    rows_since_flush: usize,
}

impl CsvSink {
    /// Sink with the default flush cadence ([`DEFAULT_FLUSH_EVERY`]).
    pub fn create(path: &Path, columns: &[&str]) -> Result<CsvSink> {
        Self::with_flush_interval(path, columns, DEFAULT_FLUSH_EVERY)
    }

    /// Sink flushing every `flush_every` rows (1 = per row, the old
    /// behavior, useful when a live `tail -f` matters more than syscalls).
    pub fn with_flush_interval(
        path: &Path, columns: &[&str], flush_every: usize,
    ) -> Result<CsvSink> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", columns.join(","))?;
        // header lands immediately so monitoring tools see the schema
        file.flush()?;
        Ok(CsvSink {
            file,
            columns: columns.iter().map(|s| s.to_string()).collect(),
            flush_every: flush_every.max(1),
            rows_since_flush: 0,
        })
    }

    /// Write one row; values must match the column count (checked
    /// eagerly, before any buffering).
    pub fn write_row(&mut self, values: &[f64]) -> Result<()> {
        anyhow::ensure!(
            values.len() == self.columns.len(),
            "row has {} values, sink has {} columns", values.len(), self.columns.len()
        );
        let row: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.file, "{}", row.join(","))?;
        self.rows_since_flush += 1;
        if self.rows_since_flush >= self.flush_every {
            self.flush()?;
        }
        Ok(())
    }

    /// Force buffered rows to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        self.rows_since_flush = 0;
        Ok(())
    }
}

/// Cross-seed aggregate sink for seed packs: one row per update cycle
/// carrying mean / IQM / standard error over the pack's seeds for each
/// tracked metric — the Figure-3 aggregation, computed online instead of
/// by a post-hoc pass over N per-seed CSVs. Columns are
/// `cycle,env_steps` followed by `{metric}_{mean,iqm,stderr}` triples.
pub struct CrossSeedSink {
    csv: CsvSink,
    n_metrics: usize,
    n_seeds: usize,
}

impl CrossSeedSink {
    pub fn create(
        path: &Path, metrics: &[&str], n_seeds: usize,
    ) -> Result<CrossSeedSink> {
        anyhow::ensure!(n_seeds > 0, "cross-seed sink needs at least one seed");
        let mut columns: Vec<String> =
            vec!["cycle".to_string(), "env_steps".to_string()];
        for m in metrics {
            columns.push(format!("{m}_mean"));
            columns.push(format!("{m}_iqm"));
            columns.push(format!("{m}_stderr"));
        }
        let cols: Vec<&str> = columns.iter().map(|s| s.as_str()).collect();
        Ok(CrossSeedSink {
            csv: CsvSink::create(path, &cols)?,
            n_metrics: metrics.len(),
            n_seeds,
        })
    }

    /// Write one cycle's aggregates. `per_seed[m]` holds metric `m`'s
    /// value for every seed, in pack order.
    pub fn write_cycle(
        &mut self, cycle: usize, env_steps: u64, per_seed: &[Vec<f64>],
    ) -> Result<()> {
        anyhow::ensure!(
            per_seed.len() == self.n_metrics,
            "cycle row has {} metrics, sink has {}", per_seed.len(), self.n_metrics
        );
        let mut row = Vec::with_capacity(2 + 3 * self.n_metrics);
        row.push(cycle as f64);
        row.push(env_steps as f64);
        for vals in per_seed {
            anyhow::ensure!(
                vals.len() == self.n_seeds,
                "metric has {} seed values, pack has {} seeds",
                vals.len(), self.n_seeds
            );
            if vals.iter().any(|v| v.is_nan()) {
                // A NaN member (e.g. eval metrics before the first
                // --eval-interval evaluation) makes the aggregate
                // undefined; emit NaN rather than let the IQM's sort
                // panic on an unordered value.
                row.extend_from_slice(&[f64::NAN; 3]);
            } else {
                row.push(stats::mean(vals));
                row.push(stats::iqm(vals));
                row.push(stats::std_err(vals));
            }
        }
        self.csv.write_row(&row)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.csv.flush()
    }
}

/// The stopwatch's time source: real monotonic time in production, a
/// manually-advanced duration in tests (so rate assertions are exact and
/// never sleep).
enum Clock {
    Monotonic { start: Instant },
    Manual { elapsed: std::time::Duration },
}

/// Wallclock + throughput accounting for Table 1.
pub struct Stopwatch {
    clock: Clock,
    pub env_steps: u64,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Real-time stopwatch (starts now).
    pub fn new() -> Stopwatch {
        // ued-lint: allow(wallclock, det-taint) — the sanctioned Table-1 stopwatch; results never depend on it
        Stopwatch { clock: Clock::Monotonic { start: Instant::now() }, env_steps: 0 }
    }

    /// Deterministic stopwatch driven by [`advance`](Stopwatch::advance).
    pub fn manual() -> Stopwatch {
        Stopwatch {
            clock: Clock::Manual { elapsed: std::time::Duration::ZERO },
            env_steps: 0,
        }
    }

    /// Advance a [`manual`](Stopwatch::manual) stopwatch's clock.
    /// Panics on a real-time stopwatch (real time cannot be injected).
    pub fn advance(&mut self, d: std::time::Duration) {
        match &mut self.clock {
            Clock::Manual { elapsed } => *elapsed += d,
            Clock::Monotonic { .. } => {
                panic!("Stopwatch::advance on a monotonic stopwatch")
            }
        }
    }

    pub fn add_steps(&mut self, n: u64) {
        self.env_steps += n;
    }

    pub fn elapsed_secs(&self) -> f64 {
        match &self.clock {
            Clock::Monotonic { start } => start.elapsed().as_secs_f64(),
            Clock::Manual { elapsed } => elapsed.as_secs_f64(),
        }
    }

    /// Elapsed nanoseconds, saturating at `u64::MAX` (~584 years) — the
    /// rollout engine's per-phase counters read the sanctioned clock
    /// through this.
    pub fn elapsed_ns(&self) -> u64 {
        let d = match &self.clock {
            Clock::Monotonic { start } => start.elapsed(),
            Clock::Manual { elapsed } => *elapsed,
        };
        u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
    }

    /// Environment interactions per second so far.
    pub fn steps_per_sec(&self) -> f64 {
        let e = self.elapsed_secs();
        if e > 0.0 {
            self.env_steps as f64 / e
        } else {
            0.0
        }
    }

    /// Hours this run would take to reach `budget` env steps at the
    /// observed rate (the Table-1 number).
    pub fn extrapolate_hours(&self, budget: u64) -> f64 {
        let rate = self.steps_per_sec();
        if rate > 0.0 {
            budget as f64 / rate / 3600.0
        } else {
            f64::INFINITY
        }
    }
}

/// Shared counters for the `ued-serve` evaluation server, exposed at
/// `GET /metrics`. Every field is a relaxed atomic: the accept loop,
/// connection handlers, and the batcher thread all bump them without a
/// lock, and `/metrics` reads a best-effort snapshot (counters are
/// monotonic, so a torn multi-field read can only be momentarily
/// inconsistent, never wrong per field).
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// HTTP requests accepted (any endpoint, any outcome).
    pub requests: std::sync::atomic::AtomicU64,
    /// `POST /eval` requests admitted past validation.
    pub eval_requests: std::sync::atomic::AtomicU64,
    /// `POST /levels/generate` requests admitted past validation.
    pub generate_requests: std::sync::atomic::AtomicU64,
    /// Requests rejected with a 4xx.
    pub bad_requests: std::sync::atomic::AtomicU64,
    /// Per-level eval results served from the result cache.
    pub cache_hits: std::sync::atomic::AtomicU64,
    /// Per-level eval results that had to be computed.
    pub cache_misses: std::sync::atomic::AtomicU64,
    /// Device (or interpreter) forward passes issued by the batcher.
    pub forward_passes: std::sync::atomic::AtomicU64,
    /// Batched engine runs (one per policy group per drain cycle).
    pub batches: std::sync::atomic::AtomicU64,
    /// Episodes executed across all engine runs (occupancy numerator).
    pub batched_episodes: std::sync::atomic::AtomicU64,
    /// Eval requests shed with 503 because the queue was full.
    pub shed_requests: std::sync::atomic::AtomicU64,
    /// Rollout phase nanoseconds, folded in from the batcher's engine.
    pub stage_ns: std::sync::atomic::AtomicU64,
    pub forward_ns: std::sync::atomic::AtomicU64,
    pub step_ns: std::sync::atomic::AtomicU64,
    pub writeback_ns: std::sync::atomic::AtomicU64,
}

impl ServeMetrics {
    /// Fold one engine run's per-phase timers in.
    pub fn add_phase_timers(&self, t: &crate::rollout::PhaseTimers) {
        use std::sync::atomic::Ordering::Relaxed;
        self.stage_ns.fetch_add(t.stage_ns, Relaxed);
        self.forward_ns.fetch_add(t.forward_ns, Relaxed);
        self.step_ns.fetch_add(t.step_ns, Relaxed);
        self.writeback_ns.fetch_add(t.writeback_ns, Relaxed);
    }

    /// Snapshot as `(name, value)` pairs — raw counters plus the two
    /// derived gauges the ISSUE asks for: cache hit rate and mean batch
    /// occupancy (episodes per drain cycle).
    pub fn snapshot(&self) -> Vec<(&'static str, f64)> {
        use std::sync::atomic::Ordering::Relaxed;
        let hits = self.cache_hits.load(Relaxed);
        let misses = self.cache_misses.load(Relaxed);
        let batches = self.batches.load(Relaxed);
        let episodes = self.batched_episodes.load(Relaxed);
        let rate = if hits + misses > 0 {
            hits as f64 / (hits + misses) as f64
        } else {
            0.0
        };
        let occupancy = if batches > 0 {
            episodes as f64 / batches as f64
        } else {
            0.0
        };
        vec![
            ("requests", self.requests.load(Relaxed) as f64),
            ("eval_requests", self.eval_requests.load(Relaxed) as f64),
            ("generate_requests", self.generate_requests.load(Relaxed) as f64),
            ("bad_requests", self.bad_requests.load(Relaxed) as f64),
            ("shed_requests", self.shed_requests.load(Relaxed) as f64),
            ("cache_hits", hits as f64),
            ("cache_misses", misses as f64),
            ("cache_hit_rate", rate),
            ("forward_passes", self.forward_passes.load(Relaxed) as f64),
            ("batches", batches as f64),
            ("batched_episodes", episodes as f64),
            ("batch_occupancy", occupancy),
            ("stage_ns", self.stage_ns.load(Relaxed) as f64),
            ("forward_ns", self.forward_ns.load(Relaxed) as f64),
            ("step_ns", self.step_ns.load(Relaxed) as f64),
            ("writeback_ns", self.writeback_ns.load(Relaxed) as f64),
        ]
    }
}

/// Pretty-print a metric row to stdout.
pub fn log_stdout(cycle: usize, env_steps: u64, pairs: &[(&str, f64)]) {
    log_stdout_tagged("", cycle, env_steps, pairs);
}

/// [`log_stdout`] with a run tag (e.g. `"s3 "`), so interleaved seed-pack
/// logs stay attributable.
pub fn log_stdout_tagged(tag: &str, cycle: usize, env_steps: u64, pairs: &[(&str, f64)]) {
    let body: Vec<String> = pairs
        .iter()
        .map(|(k, v)| format!("{k}={v:.4}"))
        .collect();
    println!("[{tag}cycle {cycle:>6} | steps {env_steps:>12}] {}", body.join(" "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_rows() {
        let dir = std::env::temp_dir().join("jaxued_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.csv");
        {
            let mut s = CsvSink::create(&p, &["a", "b"]).unwrap();
            s.write_row(&[1.0, 2.5]).unwrap();
            s.write_row(&[3.0, -4.0]).unwrap();
            assert!(s.write_row(&[1.0]).is_err());
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.trim().lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "1,2.5");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn csv_flushes_on_interval_and_drop() {
        let dir = std::env::temp_dir().join("jaxued_metrics_test_flush");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("interval.csv");
        {
            let mut s = CsvSink::with_flush_interval(&p, &["a"], 2).unwrap();
            // header is flushed eagerly at creation
            assert_eq!(std::fs::read_to_string(&p).unwrap(), "a\n");
            s.write_row(&[1.0]).unwrap();
            // one row < interval: still buffered
            assert_eq!(std::fs::read_to_string(&p).unwrap(), "a\n");
            s.write_row(&[2.0]).unwrap();
            // interval reached: both rows on disk
            assert_eq!(std::fs::read_to_string(&p).unwrap(), "a\n1\n2\n");
            s.write_row(&[3.0]).unwrap();
            // arity errors stay eager even while rows are buffered
            assert!(s.write_row(&[1.0, 2.0]).is_err());
        }
        // drop flushed the tail row
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "a\n1\n2\n3\n");
    }

    #[test]
    fn cross_seed_sink_aggregates() {
        let dir = std::env::temp_dir().join("jaxued_metrics_test_pack");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("aggregate.csv");
        {
            let mut s = CrossSeedSink::create(&p, &["loss", "solve"], 4).unwrap();
            s.write_cycle(
                0,
                1024,
                &[vec![1.0, 2.0, 3.0, 4.0], vec![0.5, 0.5, 0.5, 0.5]],
            )
            .unwrap();
            // a NaN member (pre-first-eval) yields NaN aggregates, not a
            // panic inside the IQM sort
            s.write_cycle(
                1,
                2048,
                &[vec![1.0, f64::NAN, 3.0, 4.0], vec![0.5; 4]],
            )
            .unwrap();
            // wrong metric count / wrong seed count fail eagerly
            assert!(s.write_cycle(2, 0, &[vec![1.0; 4]]).is_err());
            assert!(s
                .write_cycle(2, 0, &[vec![1.0; 3], vec![1.0; 3]])
                .is_err());
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.trim().lines().collect();
        assert_eq!(
            lines[0],
            "cycle,env_steps,loss_mean,loss_iqm,loss_stderr,solve_mean,solve_iqm,solve_stderr"
        );
        let row: Vec<f64> = lines[1].split(',').map(|x| x.parse().unwrap()).collect();
        assert_eq!(row[0], 0.0);
        assert_eq!(row[1], 1024.0);
        assert!((row[2] - 2.5).abs() < 1e-12, "loss mean");
        assert!((row[3] - 2.5).abs() < 1e-12, "loss iqm");
        // stderr of 1..4: sample std sqrt(5/3) / sqrt(4)
        assert!((row[4] - (5.0f64 / 3.0).sqrt() / 2.0).abs() < 1e-12);
        assert!((row[5] - 0.5).abs() < 1e-12);
        assert_eq!(row[7], 0.0, "constant metric has zero stderr");
        let nan_row: Vec<&str> = lines[2].split(',').collect();
        assert_eq!(nan_row[2], "NaN");
        assert_eq!(nan_row[3], "NaN");
        assert_eq!(nan_row[4], "NaN");
        assert_eq!(nan_row[5], "0.5", "finite metric still aggregates");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn stopwatch_rates() {
        // deterministic: a manual clock replaces the old real 20 ms sleep
        let mut w = Stopwatch::manual();
        w.add_steps(1000);
        w.advance(std::time::Duration::from_millis(20));
        assert_eq!(w.elapsed_secs(), 0.02);
        assert_eq!(w.steps_per_sec(), 50_000.0);
        assert_eq!(w.extrapolate_hours(1_000_000_000), 1e9 / 50_000.0 / 3600.0);
        assert_eq!(w.env_steps, 1000);
        w.advance(std::time::Duration::from_millis(20));
        assert_eq!(w.steps_per_sec(), 25_000.0);
    }

    #[test]
    fn stopwatch_elapsed_ns() {
        let mut w = Stopwatch::manual();
        assert_eq!(w.elapsed_ns(), 0);
        w.advance(std::time::Duration::from_micros(1500));
        assert_eq!(w.elapsed_ns(), 1_500_000);
    }

    #[test]
    fn stopwatch_zero_elapsed_is_safe() {
        let w = Stopwatch::manual();
        assert_eq!(w.steps_per_sec(), 0.0);
        assert!(w.extrapolate_hours(1).is_infinite());
    }

    #[test]
    fn serve_metrics_derived_gauges() {
        use std::sync::atomic::Ordering::Relaxed;
        let m = ServeMetrics::default();
        let get = |m: &ServeMetrics, k: &str| {
            m.snapshot().iter().find(|(n, _)| *n == k).map(|(_, v)| *v).unwrap()
        };
        // fresh server: derived gauges are 0, not NaN
        assert_eq!(get(&m, "cache_hit_rate"), 0.0);
        assert_eq!(get(&m, "batch_occupancy"), 0.0);

        m.cache_hits.fetch_add(3, Relaxed);
        m.cache_misses.fetch_add(1, Relaxed);
        m.batches.fetch_add(2, Relaxed);
        m.batched_episodes.fetch_add(12, Relaxed);
        m.forward_passes.fetch_add(7, Relaxed);
        assert_eq!(get(&m, "cache_hit_rate"), 0.75);
        assert_eq!(get(&m, "batch_occupancy"), 6.0);
        assert_eq!(get(&m, "forward_passes"), 7.0);

        m.add_phase_timers(&crate::rollout::PhaseTimers {
            stage_ns: 10,
            forward_ns: 20,
            step_ns: 30,
            writeback_ns: 40,
        });
        m.add_phase_timers(&crate::rollout::PhaseTimers {
            stage_ns: 1,
            forward_ns: 2,
            step_ns: 3,
            writeback_ns: 4,
        });
        assert_eq!(get(&m, "stage_ns"), 11.0);
        assert_eq!(get(&m, "writeback_ns"), 44.0);
    }
}
