//! Metric logging and wallclock accounting.
//!
//! The paper logs to Weights & Biases; we substitute a CSV sink plus
//! stdout (DESIGN.md substitutions). `Stopwatch` provides the Table-1
//! wallclock accounting: cumulative seconds and env-steps/s, with
//! extrapolation to the paper's full 245.76M-step budget.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

/// Append-only CSV metric sink. Columns are fixed at creation.
pub struct CsvSink {
    file: std::io::BufWriter<std::fs::File>,
    columns: Vec<String>,
}

impl CsvSink {
    pub fn create(path: &Path, columns: &[&str]) -> Result<CsvSink> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", columns.join(","))?;
        Ok(CsvSink {
            file,
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// Write one row; values must match the column count.
    pub fn write_row(&mut self, values: &[f64]) -> Result<()> {
        anyhow::ensure!(
            values.len() == self.columns.len(),
            "row has {} values, sink has {} columns", values.len(), self.columns.len()
        );
        let row: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.file, "{}", row.join(","))?;
        self.file.flush()?;
        Ok(())
    }
}

/// The stopwatch's time source: real monotonic time in production, a
/// manually-advanced duration in tests (so rate assertions are exact and
/// never sleep).
enum Clock {
    Monotonic { start: Instant },
    Manual { elapsed: std::time::Duration },
}

/// Wallclock + throughput accounting for Table 1.
pub struct Stopwatch {
    clock: Clock,
    pub env_steps: u64,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Real-time stopwatch (starts now).
    pub fn new() -> Stopwatch {
        Stopwatch { clock: Clock::Monotonic { start: Instant::now() }, env_steps: 0 }
    }

    /// Deterministic stopwatch driven by [`advance`](Stopwatch::advance).
    pub fn manual() -> Stopwatch {
        Stopwatch {
            clock: Clock::Manual { elapsed: std::time::Duration::ZERO },
            env_steps: 0,
        }
    }

    /// Advance a [`manual`](Stopwatch::manual) stopwatch's clock.
    /// Panics on a real-time stopwatch (real time cannot be injected).
    pub fn advance(&mut self, d: std::time::Duration) {
        match &mut self.clock {
            Clock::Manual { elapsed } => *elapsed += d,
            Clock::Monotonic { .. } => {
                panic!("Stopwatch::advance on a monotonic stopwatch")
            }
        }
    }

    pub fn add_steps(&mut self, n: u64) {
        self.env_steps += n;
    }

    pub fn elapsed_secs(&self) -> f64 {
        match &self.clock {
            Clock::Monotonic { start } => start.elapsed().as_secs_f64(),
            Clock::Manual { elapsed } => elapsed.as_secs_f64(),
        }
    }

    /// Environment interactions per second so far.
    pub fn steps_per_sec(&self) -> f64 {
        let e = self.elapsed_secs();
        if e > 0.0 {
            self.env_steps as f64 / e
        } else {
            0.0
        }
    }

    /// Hours this run would take to reach `budget` env steps at the
    /// observed rate (the Table-1 number).
    pub fn extrapolate_hours(&self, budget: u64) -> f64 {
        let rate = self.steps_per_sec();
        if rate > 0.0 {
            budget as f64 / rate / 3600.0
        } else {
            f64::INFINITY
        }
    }
}

/// Pretty-print a metric row to stdout.
pub fn log_stdout(cycle: usize, env_steps: u64, pairs: &[(&str, f64)]) {
    let body: Vec<String> = pairs
        .iter()
        .map(|(k, v)| format!("{k}={v:.4}"))
        .collect();
    println!("[cycle {cycle:>6} | steps {env_steps:>12}] {}", body.join(" "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_rows() {
        let dir = std::env::temp_dir().join("jaxued_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.csv");
        {
            let mut s = CsvSink::create(&p, &["a", "b"]).unwrap();
            s.write_row(&[1.0, 2.5]).unwrap();
            s.write_row(&[3.0, -4.0]).unwrap();
            assert!(s.write_row(&[1.0]).is_err());
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.trim().lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "1,2.5");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn stopwatch_rates() {
        // deterministic: a manual clock replaces the old real 20 ms sleep
        let mut w = Stopwatch::manual();
        w.add_steps(1000);
        w.advance(std::time::Duration::from_millis(20));
        assert_eq!(w.elapsed_secs(), 0.02);
        assert_eq!(w.steps_per_sec(), 50_000.0);
        assert_eq!(w.extrapolate_hours(1_000_000_000), 1e9 / 50_000.0 / 3600.0);
        assert_eq!(w.env_steps, 1000);
        w.advance(std::time::Duration::from_millis(20));
        assert_eq!(w.steps_per_sec(), 25_000.0);
    }

    #[test]
    fn stopwatch_zero_elapsed_is_safe() {
        let w = Stopwatch::manual();
        assert_eq!(w.steps_per_sec(), 0.0);
        assert!(w.extrapolate_hours(1).is_infinite());
    }
}
