//! Sharded, byte-keyed result cache for served evaluations.
//!
//! Key = `(policy id, trials, master seed, level bytes)` — exactly the
//! inputs the content-keyed RNG derivation
//! ([`adhoc_episode_rng`](crate::eval::adhoc_episode_rng)) makes a
//! per-level result a pure function of. A hit therefore returns a value
//! bit-identical to what re-running the episodes would produce, with zero
//! forward passes (the integration suite asserts this through the
//! `/metrics` forward-pass counter).
//!
//! Sharded FIFO: N independent mutex-guarded shards, each an ordered map
//! plus an insertion queue, evicting oldest-first past its per-shard cap.
//! `BTreeMap` rather than a hash map — `serve/` is lint-scoped
//! order-sensitive (batch assembly must stay FIFO-deterministic), and the
//! key-derived shard index below is a fixed function, not a per-process
//! hasher.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;

use crate::eval::LevelResult;

/// Shard count: enough to keep concurrent handler threads from
/// serializing on one lock, small enough that tiny caches still shard.
const SHARDS: usize = 16;

struct Shard {
    map: BTreeMap<Vec<u8>, LevelResult>,
    order: VecDeque<Vec<u8>>,
}

/// The server-wide per-level result cache.
pub struct ResultCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
}

/// Build the canonical cache key. Length-prefix free: the fixed-width
/// trials/master fields sit between the policy id and the level bytes, and
/// the `0xFF` separator cannot appear in a policy id (ids are UTF-8 and
/// checked printable at catalog build).
pub fn cache_key(policy: &str, trials: usize, master: u64, level_bytes: &[u8]) -> Vec<u8> {
    let mut k = Vec::with_capacity(policy.len() + 1 + 16 + level_bytes.len());
    k.extend_from_slice(policy.as_bytes());
    k.push(0xFF);
    k.extend_from_slice(&(trials as u64).to_le_bytes());
    k.extend_from_slice(&master.to_le_bytes());
    k.extend_from_slice(level_bytes);
    k
}

/// Deterministic shard index: FNV-1a over the key. A fixed function of
/// the bytes (unlike `RandomState`), so shard residency is reproducible
/// run to run.
fn shard_of(key: &[u8]) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    (h % SHARDS as u64) as usize
}

impl ResultCache {
    /// Cache bounded at ~`cap` entries total (rounded up per shard).
    pub fn new(cap: usize) -> ResultCache {
        let per_shard_cap = cap.div_ceil(SHARDS).max(1);
        ResultCache {
            shards: (0..SHARDS)
                .map(|_| {
                    Mutex::new(Shard { map: BTreeMap::new(), order: VecDeque::new() })
                })
                .collect(),
            per_shard_cap,
        }
    }

    // ued-lint: allow(serve-panic) — shard_of is % SHARDS so the index is in range; the expect fires only on a poisoned shard
    pub fn get(&self, key: &[u8]) -> Option<LevelResult> {
        let shard = self.shards[shard_of(key)].lock().expect("cache shard poisoned");
        shard.map.get(key).cloned()
    }

    /// Insert, evicting the shard's oldest entry past the cap. Re-inserting
    /// an existing key overwrites in place (results are pure functions of
    /// the key, so the value cannot actually differ).
    // ued-lint: allow(serve-panic) — same shard_of bound + poisoned-shard expect as get
    pub fn insert(&self, key: Vec<u8>, result: LevelResult) {
        let mut shard = self.shards[shard_of(&key)].lock().expect("cache shard poisoned");
        if shard.map.insert(key.clone(), result).is_none() {
            shard.order.push_back(key);
            while shard.order.len() > self.per_shard_cap {
                if let Some(old) = shard.order.pop_front() {
                    shard.map.remove(&old);
                }
            }
        }
    }

    /// Total resident entries (metrics).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            // ued-lint: allow(serve-panic) — poisoned-shard expect; see get
            .map(|s| s.lock().expect("cache shard poisoned").map.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(name: &str, rate: f64) -> LevelResult {
        LevelResult { name: name.into(), solve_rate: rate, mean_steps: 1.0 }
    }

    #[test]
    fn key_discriminates_every_field() {
        let base = cache_key("p", 3, 7, &[1, 2]);
        assert_eq!(base, cache_key("p", 3, 7, &[1, 2]), "pure function");
        assert_ne!(base, cache_key("q", 3, 7, &[1, 2]));
        assert_ne!(base, cache_key("p", 4, 7, &[1, 2]));
        assert_ne!(base, cache_key("p", 3, 8, &[1, 2]));
        assert_ne!(base, cache_key("p", 3, 7, &[1, 3]));
    }

    #[test]
    fn hit_miss_and_overwrite() {
        let c = ResultCache::new(64);
        let k = cache_key("p", 1, 0, &[9]);
        assert!(c.get(&k).is_none());
        c.insert(k.clone(), result("a", 0.5));
        assert_eq!(c.get(&k).unwrap().solve_rate, 0.5);
        assert_eq!(c.len(), 1);
        // overwrite does not duplicate the FIFO entry
        c.insert(k.clone(), result("a", 0.5));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_is_bounded_and_oldest_first() {
        // cap 16 → 1 entry per shard: the second insert landing in a shard
        // evicts that shard's first.
        let c = ResultCache::new(16);
        let keys: Vec<Vec<u8>> =
            (0..200u32).map(|i| cache_key("p", 1, 0, &i.to_le_bytes())).collect();
        for k in &keys {
            c.insert(k.clone(), result("x", 0.0));
        }
        assert!(c.len() <= SHARDS, "cap 16 → at most one entry per shard, got {}", c.len());
        assert!(!c.is_empty());
        // the newest key in some shard must still be resident
        assert!(keys.iter().any(|k| c.get(k).is_some()));
    }
}
