//! The policy zoo: which policies exist, which are resident, and how the
//! batcher gets a [`PolicyModel`] for one.
//!
//! Split in two because the runtime is not `Sync`:
//!
//! * [`ZooCatalog`] — the shared, immutable id list plus a residency set,
//!   read by connection handlers (`GET /zoo`, 404 checks) and updated by
//!   the batcher as it loads/evicts.
//! * [`PolicyStore`] — owned exclusively by the batcher thread; holds the
//!   `Runtime` and the LRU-bounded set of loaded policies. Checkpoints
//!   are discovered at startup ([`discover_checkpoints`]) but loaded
//!   lazily on the first request naming them.
//!
//! [`discover_checkpoints`]: crate::runtime::discover_checkpoints

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::rollout::{
    ForwardWorkspace, Policy, PolicyModel, SyntheticPolicy,
};
use crate::runtime::{Executable, ParamSet, Runtime};
use crate::util::tensor::TensorF32;

/// Where a zoo entry's weights come from.
#[derive(Clone, Debug)]
pub enum ZooSource {
    /// Deterministic logits from observation bytes — no runtime needed
    /// (CI smoke and the integration tests run synthetic-only zoos).
    Synthetic { num_actions: usize },
    /// A trained `student` checkpoint on disk.
    Checkpoint { path: PathBuf },
}

/// The shared zoo listing: every known policy id plus which are resident.
pub struct ZooCatalog {
    entries: Vec<(String, ZooSource)>,
    loaded: Mutex<BTreeSet<String>>,
}

impl ZooCatalog {
    pub fn new(entries: Vec<(String, ZooSource)>) -> ZooCatalog {
        ZooCatalog { entries, loaded: Mutex::new(BTreeSet::new()) }
    }

    pub fn contains(&self, id: &str) -> bool {
        self.entries.iter().any(|(e, _)| e == id)
    }

    pub fn source(&self, id: &str) -> Option<&ZooSource> {
        self.entries.iter().find(|(e, _)| e == id).map(|(_, s)| s)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn loaded_count(&self) -> usize {
        // ued-lint: allow(serve-panic) — poisoned catalog mutex means a batcher thread already panicked
        self.loaded.lock().expect("catalog poisoned").len()
    }

    /// `(id, loaded, synthetic)` rows for `GET /zoo`, in catalog order.
    pub fn rows(&self) -> Vec<(String, bool, bool)> {
        // ued-lint: allow(serve-panic) — poisoned-catalog expect; see loaded_count
        let loaded = self.loaded.lock().expect("catalog poisoned");
        self.entries
            .iter()
            .map(|(id, src)| {
                (
                    id.clone(),
                    loaded.contains(id),
                    matches!(src, ZooSource::Synthetic { .. }),
                )
            })
            .collect()
    }

    fn mark_loaded(&self, id: &str) {
        // ued-lint: allow(serve-panic) — poisoned-catalog expect; see loaded_count
        self.loaded.lock().expect("catalog poisoned").insert(id.to_string());
    }

    fn mark_evicted(&self, id: &str) {
        // ued-lint: allow(serve-panic) — poisoned-catalog expect; see loaded_count
        self.loaded.lock().expect("catalog poisoned").remove(id);
    }
}

/// A resident policy the batcher can evaluate with.
enum LoadedPolicy {
    Synthetic(SyntheticPolicy),
    Checkpoint { apply: Arc<Executable>, params: ParamSet },
}

/// Batcher-owned policy residency: lazy loads, LRU eviction past `cap`,
/// catalog residency flags kept in sync.
pub struct PolicyStore {
    runtime: Option<Runtime>,
    /// Artifact-name scope of the serving family
    /// (`EnvId::artifact_prefix`).
    prefix: Option<&'static str>,
    /// Apply artifact to serve checkpoints through (`student_apply_b{B}`).
    apply_name: String,
    num_actions: usize,
    cap: usize,
    catalog: Arc<ZooCatalog>,
    /// Most-recently-used at the back.
    loaded: Vec<(String, LoadedPolicy)>,
}

impl PolicyStore {
    pub fn new(
        runtime: Option<Runtime>, prefix: Option<&'static str>, apply_name: String,
        num_actions: usize, cap: usize, catalog: Arc<ZooCatalog>,
    ) -> PolicyStore {
        PolicyStore {
            runtime,
            prefix,
            apply_name,
            num_actions,
            cap: cap.max(1),
            catalog,
            loaded: Vec::new(),
        }
    }

    /// Run `f` with policy `id`'s model, loading (and possibly evicting)
    /// first. The model is borrowed for the duration of the call only —
    /// eviction can't invalidate a model mid-evaluation.
    pub fn with_model<R>(
        &mut self, id: &str, f: impl FnOnce(&dyn PolicyModel) -> Result<R>,
    ) -> Result<R> {
        if let Some(pos) = self.loaded.iter().position(|(l, _)| l == id) {
            // LRU touch: move to the back.
            let entry = self.loaded.remove(pos);
            self.loaded.push(entry);
        } else {
            let policy = self.load(id)?;
            self.loaded.push((id.to_string(), policy));
            self.catalog.mark_loaded(id);
            while self.loaded.len() > self.cap {
                let (evicted, _) = self.loaded.remove(0);
                self.catalog.mark_evicted(&evicted);
            }
        }
        // ued-lint: allow(serve-panic) — both branches above leave the entry at the back of `loaded`
        let (_, model) = self.loaded.last().expect("just pushed");
        match model {
            LoadedPolicy::Synthetic(s) => f(s),
            LoadedPolicy::Checkpoint { apply, params } => {
                let policy = Policy {
                    apply: apply.clone(),
                    params: &params.params,
                    num_actions: self.num_actions,
                };
                f(&policy)
            }
        }
    }

    fn load(&self, id: &str) -> Result<LoadedPolicy> {
        let Some(source) = self.catalog.source(id) else {
            bail!("policy {id:?} is not in the zoo");
        };
        Ok(match source {
            ZooSource::Synthetic { num_actions } => {
                LoadedPolicy::Synthetic(SyntheticPolicy { num_actions: *num_actions })
            }
            ZooSource::Checkpoint { path } => {
                let Some(rt) = self.runtime.as_ref() else {
                    bail!(
                        "policy {id:?} is checkpoint-backed but the server has no \
                         artifact runtime (start with --artifacts pointing at a \
                         compiled artifact set)"
                    );
                };
                let params = ParamSet::load(path, "student")
                    .with_context(|| format!("loading checkpoint for {id:?}"))?;
                let apply = rt
                    .load_scoped(self.prefix, &self.apply_name)
                    .with_context(|| format!("compiling {} for {id:?}", self.apply_name))?;
                LoadedPolicy::Checkpoint { apply, params }
            }
        })
    }
}

/// Borrow-erased [`PolicyModel`]: the engine's entry points are generic
/// over `P: PolicyModel`, and [`PolicyStore::with_model`] hands out
/// `&dyn PolicyModel` — this adapter bridges the two.
pub struct DynPolicy<'a>(pub &'a dyn PolicyModel);

impl PolicyModel for DynPolicy<'_> {
    fn num_actions(&self) -> usize {
        self.0.num_actions()
    }

    fn forward_into(
        &self, obs: &[TensorF32], ws: &mut ForwardWorkspace, logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) -> Result<()> {
        self.0.forward_into(obs, ws, logits, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_catalog(n: usize) -> Arc<ZooCatalog> {
        Arc::new(ZooCatalog::new(
            (0..n)
                .map(|i| {
                    (format!("synthetic{i}"), ZooSource::Synthetic { num_actions: 4 })
                })
                .collect(),
        ))
    }

    #[test]
    fn catalog_rows_and_lookup() {
        let c = synthetic_catalog(2);
        assert_eq!(c.len(), 2);
        assert!(c.contains("synthetic1"));
        assert!(!c.contains("nope"));
        let rows = c.rows();
        assert_eq!(rows[0], ("synthetic0".to_string(), false, true));
        assert_eq!(c.loaded_count(), 0);
    }

    #[test]
    fn store_loads_lazily_and_evicts_lru() {
        let catalog = synthetic_catalog(3);
        let mut store = PolicyStore::new(None, None, "student_apply_b8".into(), 4, 2, catalog.clone());
        let actions = |store: &mut PolicyStore, id: &str| {
            store.with_model(id, |m| Ok(m.num_actions())).unwrap()
        };
        assert_eq!(actions(&mut store, "synthetic0"), 4);
        assert_eq!(actions(&mut store, "synthetic1"), 4);
        assert_eq!(catalog.loaded_count(), 2);
        // touch 0 (now MRU), then load 2: the LRU (1) is evicted
        assert_eq!(actions(&mut store, "synthetic0"), 4);
        assert_eq!(actions(&mut store, "synthetic2"), 4);
        assert_eq!(catalog.loaded_count(), 2);
        let rows = catalog.rows();
        let loaded = |id: &str| rows.iter().find(|(i, _, _)| i == id).unwrap().1;
        assert!(loaded("synthetic0"));
        assert!(!loaded("synthetic1"), "LRU entry must be evicted");
        assert!(loaded("synthetic2"));
    }

    #[test]
    fn unknown_and_runtimeless_policies_error() {
        let catalog = Arc::new(ZooCatalog::new(vec![(
            "trained".to_string(),
            ZooSource::Checkpoint { path: PathBuf::from("/nonexistent.ckpt") },
        )]));
        let mut store = PolicyStore::new(None, None, "student_apply_b8".into(), 4, 2, catalog);
        assert!(store.with_model("missing", |_| Ok(())).is_err());
        let err = store.with_model("trained", |_| Ok(())).unwrap_err();
        assert!(err.to_string().contains("no artifact runtime"), "{err}");
    }
}
